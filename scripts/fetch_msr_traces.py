#!/usr/bin/env python
"""Fetch the SNIA MSR-Cambridge block-I/O traces into a trace directory.

The paper's evaluation replays the MSR-Cambridge production volumes
(Narayanan et al., FAST'08; SNIA IOTTA trace set 388).  Those traces are
*not redistributable*, so this repo checks in only two tiny MSR-format
excerpts for tests (``tests/data/*.csv.gz``).  This script downloads any
of the 36 real volumes into a local trace directory, after which the
workload registry resolves them with **no repo changes**:

    # one-time: fetch two volumes into $REPRO_TRACE_DIR (or ./traces)
    python scripts/fetch_msr_traces.py web_0 src1_1

    # then anywhere in the run APIs:
    simulate("msr:web_0", AGED, "pr2ar2", gc="prepass")

Trace-dir convention
--------------------
File-scheme workload specs (``msr:NAME``, ``blktrace:NAME``) resolve
``NAME`` against, in order: ``$REPRO_TRACE_DIR``, ``./traces``,
``./tests/data``, and the checkout's ``tests/data``
(:func:`repro.flashsim.workloads.registry.trace_search_paths`).  This
script writes to ``--dest``, else ``$REPRO_TRACE_DIR``, else
``./traces`` — i.e. wherever it downloads, the registry already looks.

Integrity
---------
SNIA distributes the volumes through a click-through portal, so the
exact bytes can vary by mirror (some serve ``.csv``, some ``.csv.gz``).
Integrity is therefore manifest-based: after each download the file's
SHA-256 is recorded in ``msr_manifest.json`` next to the traces
(trust-on-first-use), and any later re-download of the same volume is
verified against the pinned digest.  A site-wide pin set can be
supplied up front with ``--checksum-file`` (JSON:
``{"web_0.csv.gz": "<sha256>", ...}``); mismatches abort before the
file is moved into place.  Every completed file is also sanity-parsed
with the repo's MSR loader before being accepted.

The default ``--base-url`` points at the SNIA IOTTA MSR-Cambridge
directory; pass your mirror if you have one (the portal may require a
free SNIA account — download there manually and drop the files into the
trace dir if so; the manifest/verify path works the same for files this
script did not download via ``--verify-only``).
"""

from __future__ import annotations

import argparse
import gzip
import hashlib
import http.client
import json
import os
import random
import shutil
import sys
import tempfile
import time
import urllib.error
import urllib.request
from pathlib import Path

#: The 36 MSR-Cambridge per-volume traces (13 servers), as named by the
#: SNIA IOTTA repository (``<volume>.csv.gz``).
MSR_VOLUMES = (
    "hm_0", "hm_1",
    "mds_0", "mds_1",
    "prn_0", "prn_1",
    "proj_0", "proj_1", "proj_2", "proj_3", "proj_4",
    "prxy_0", "prxy_1",
    "rsrch_0", "rsrch_1", "rsrch_2",
    "src1_0", "src1_1", "src1_2",
    "src2_0", "src2_1", "src2_2",
    "stg_0", "stg_1",
    "ts_0",
    "usr_0", "usr_1", "usr_2",
    "wdev_0", "wdev_1", "wdev_2", "wdev_3",
    "web_0", "web_1", "web_2", "web_3",
)

DEFAULT_BASE_URL = (
    "https://iotta.snia.org/traces/block-io/388/download/MSR-Cambridge"
)

MANIFEST_NAME = "msr_manifest.json"


def default_dest() -> Path:
    """--dest > $REPRO_TRACE_DIR > ./traces (the registry search order)."""
    env = os.environ.get("REPRO_TRACE_DIR")
    return Path(env) if env else Path.cwd() / "traces"


def sha256_file(path: Path, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                break
            h.update(block)
    return h.hexdigest()


def load_manifest(dest: Path) -> dict:
    path = dest / MANIFEST_NAME
    if path.exists():
        with open(path) as f:
            return json.load(f)
    return {}


def save_manifest(dest: Path, manifest: dict) -> None:
    path = dest / MANIFEST_NAME
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")


def verify_pin(name: str, digest: str, pins: dict) -> None:
    """Raise if ``digest`` contradicts a pinned checksum for ``name``."""
    pinned = pins.get(name)
    if pinned is not None and pinned.lower() != digest.lower():
        raise RuntimeError(
            f"{name}: SHA-256 mismatch — expected {pinned}, got {digest} "
            f"(corrupt download or a different mirror revision; delete "
            f"the pin to re-trust)"
        )


def sanity_parse(path: Path, max_rows: int = 1000) -> int:
    """Parse the head of a downloaded volume with the repo's MSR loader.

    Real volumes run to gigabytes, so only the first ``max_rows`` lines
    are extracted (gzip-aware) into a temp file and run through
    :func:`repro.flashsim.workloads.load_msr_csv`.  Returns the number
    of requests parsed; raises on malformed files (wrong column count,
    non-FILETIME timestamps, truncated gzip).
    """
    from repro.flashsim.workloads import load_msr_csv

    opener = gzip.open if is_gzip(path) else open
    with opener(path, "rt") as f:
        head = []
        for i, line in enumerate(f):
            if i >= max_rows:
                break
            head.append(line)
    if not head:
        raise RuntimeError(f"{path.name}: empty trace file")
    with tempfile.NamedTemporaryFile(
        "wt", suffix=".csv", delete=False
    ) as tmp:
        tmp.writelines(head)
        tmp_path = Path(tmp.name)
    try:
        trace = load_msr_csv(tmp_path)
    finally:
        tmp_path.unlink()
    if len(trace.arrival_us) == 0:
        raise RuntimeError(f"{path.name}: no parseable MSR rows")
    return len(trace.arrival_us)


def download(url: str, out_path: Path, timeout: float = 60.0,
             max_retries: int = 4, backoff_s: float = 1.0,
             jitter: float = 0.25, sleep=time.sleep) -> None:
    """Download ``url`` to ``out_path`` with bounded retry and resume.

    Transient failures — connection errors/resets, timeouts, truncated
    bodies, HTTP 408/429/5xx — are retried up to ``max_retries`` times
    with exponential backoff (``backoff_s * 2**attempt``) plus up to
    ``jitter`` proportional random jitter (decorrelates CI jobs
    hammering the same mirror).  Bytes already on disk are kept between
    attempts and the retry asks the server to resume with a ``Range``
    header: a 206 appends from where the failure cut off, a 200 means
    the server ignored Range and the file restarts from scratch, and a
    416 (range not satisfiable — stale partial) drops the partial and
    restarts clean.  Other 4xx responses are permanent and raise
    immediately.  ``sleep`` is injectable for tests.
    """
    attempt = 0
    while True:
        resume_from = out_path.stat().st_size if out_path.exists() else 0
        headers = {"User-Agent": "repro-flashsim-trace-fetch/1.0"}
        if resume_from > 0:
            headers["Range"] = f"bytes={resume_from}-"
        req = urllib.request.Request(url, headers=headers)
        try:
            with urllib.request.urlopen(req, timeout=timeout) as resp:
                status = getattr(resp, "status", 200)
                mode = "ab" if (resume_from > 0 and status == 206) else "wb"
                with open(out_path, mode) as out:
                    shutil.copyfileobj(resp, out)
            return
        except urllib.error.HTTPError as e:
            if e.code == 416 and resume_from > 0:
                # The partial can't be extended (the file changed or
                # shrank on the mirror): drop it and restart without
                # Range.  No attempt consumed — with no partial left,
                # the next loop cannot 416 again.
                out_path.unlink()
                continue
            if e.code < 500 and e.code not in (408, 429):
                raise                   # permanent (404, 403, 416, ...)
            err: Exception = e
        except (urllib.error.URLError, http.client.HTTPException,
                ConnectionError, TimeoutError) as e:
            err = e
        attempt += 1
        if attempt > max_retries:
            raise err
        sleep(backoff_s * (2 ** (attempt - 1))
              * (1.0 + jitter * random.random()))


def is_gzip(path: Path) -> bool:
    with open(path, "rb") as f:
        return f.read(2) == b"\x1f\x8b"


def recompress_csv(path: Path) -> None:
    """Gzip a plain-CSV download in place, reproducibly.

    Streamed (volumes run to GiB, never loaded whole) and with mtime=0 /
    no name in the gzip header, so recompressing identical CSV bytes
    always yields identical archive bytes — the manifest/pin SHA-256
    stays stable across re-downloads.  Raises if the content does not
    look like MSR CSV (e.g. a portal login page).
    """
    with open(path, "rb") as f:
        head = f.read(64)
    if not head.lstrip()[:1].isdigit():
        raise RuntimeError(
            f"{path.name}: response is neither gzip nor MSR CSV "
            f"(portal login page? use --base-url with a direct "
            f"mirror, or download manually)"
        )
    gz_tmp = Path(str(path) + ".gz")
    try:
        with open(path, "rb") as src, open(gz_tmp, "wb") as dst:
            # filename="" keeps the temp file's (random) name out of
            # the header; mtime=0 pins the timestamp field.
            with gzip.GzipFile(filename="", fileobj=dst, mode="wb",
                               mtime=0) as zf:
                shutil.copyfileobj(src, zf)
        gz_tmp.replace(path)
    finally:
        if gz_tmp.exists():
            gz_tmp.unlink()


def fetch_volume(name: str, dest: Path, base_url: str, pins: dict,
                 manifest: dict, force: bool = False,
                 skip_parse: bool = False) -> Path:
    """Download one volume (TOFU-verified), returning the final path."""
    fname = f"{name}.csv.gz"
    final = dest / fname
    if final.exists() and not force:
        digest = sha256_file(final)
        verify_pin(fname, digest, pins)
        verify_pin(fname, digest, manifest)
        manifest[fname] = digest
        print(f"  {fname}: already present ({digest[:12]}…), verified")
        return final
    url = f"{base_url.rstrip('/')}/{fname}"
    # Deterministic partial name so an interrupted run's bytes are
    # resumed (Range request) by the next invocation.  The partial is
    # kept only on *network* failure; content that fails integrity or
    # parsing is dropped so a bad mirror revision can't poison resumes.
    tmp = dest / f".{fname}.part"
    if force and tmp.exists():
        tmp.unlink()
    verb = "resuming" if tmp.exists() and tmp.stat().st_size else \
        "downloading"
    print(f"  {fname}: {verb} {url}")
    download(url, tmp)
    try:
        if not is_gzip(tmp):
            # Mirror served the uncompressed CSV: gzip it (reproducibly)
            # so the name matches what the registry's loaders expect.
            recompress_csv(tmp)
        digest = sha256_file(tmp)
        verify_pin(fname, digest, pins)
        verify_pin(fname, digest, manifest)
        if not skip_parse:
            n = sanity_parse(tmp)
            print(f"  {fname}: parsed {n} head requests OK")
    except Exception:
        if tmp.exists():
            tmp.unlink()
        raise
    tmp.replace(final)
    manifest[fname] = digest
    print(f"  {fname}: done (sha256 {digest[:12]}…)")
    return final


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="download SNIA MSR-Cambridge volumes into the trace "
                    "directory the workload registry searches"
    )
    ap.add_argument("volumes", nargs="*",
                    help="volume names (e.g. web_0 src1_1); default: "
                         "the two volumes the benchmark replays")
    ap.add_argument("--all", action="store_true",
                    help="fetch all 36 volumes (several GiB)")
    ap.add_argument("--list", action="store_true",
                    help="list known volume names and exit")
    ap.add_argument("--dest", type=Path, default=None,
                    help="target directory (default: $REPRO_TRACE_DIR "
                         "or ./traces)")
    ap.add_argument("--base-url", default=DEFAULT_BASE_URL,
                    help="mirror base URL serving <volume>.csv[.gz]")
    ap.add_argument("--checksum-file", type=Path, default=None,
                    help="JSON of {filename: sha256} pins to verify "
                         "against (in addition to the local manifest)")
    ap.add_argument("--verify-only", action="store_true",
                    help="no network: hash + sanity-parse files already "
                         "in the trace dir and update the manifest")
    ap.add_argument("--force", action="store_true",
                    help="re-download even if the file exists")
    ap.add_argument("--skip-parse", action="store_true",
                    help="skip the MSR-loader sanity parse")
    args = ap.parse_args(argv)

    if args.list:
        print("\n".join(MSR_VOLUMES))
        return 0

    volumes = list(args.volumes)
    if args.all:
        volumes = list(MSR_VOLUMES)
    elif not volumes:
        volumes = ["web_0", "src1_1"]   # the benchmark's replay cells
    unknown = [v for v in volumes if v not in MSR_VOLUMES]
    if unknown:
        ap.error(f"unknown volume(s): {', '.join(unknown)} "
                 f"(--list shows the 36 MSR-Cambridge names)")

    dest = args.dest if args.dest is not None else default_dest()
    dest.mkdir(parents=True, exist_ok=True)
    pins = {}
    if args.checksum_file is not None:
        with open(args.checksum_file) as f:
            pins = json.load(f)
    manifest = load_manifest(dest)

    print(f"trace dir: {dest}  (registry search order: $REPRO_TRACE_DIR, "
          f"./traces, ./tests/data)")
    failures = 0
    for name in volumes:
        try:
            if args.verify_only:
                fname = f"{name}.csv.gz"
                path = dest / fname
                if not path.exists():
                    raise FileNotFoundError(f"{fname} not in {dest}")
                digest = sha256_file(path)
                verify_pin(fname, digest, pins)
                verify_pin(fname, digest, manifest)
                if not args.skip_parse:
                    sanity_parse(path)
                manifest[fname] = digest
                print(f"  {fname}: verified ({digest[:12]}…)")
            else:
                fetch_volume(name, dest, args.base_url, pins, manifest,
                             force=args.force, skip_parse=args.skip_parse)
        except (RuntimeError, OSError, urllib.error.URLError) as e:
            failures += 1
            print(f"  {name}: FAILED — {e}", file=sys.stderr)
    save_manifest(dest, manifest)
    if failures:
        print(f"{failures} volume(s) failed; manifest saved for the rest",
              file=sys.stderr)
        return 1
    print(f"manifest: {dest / MANIFEST_NAME}")
    return 0


if __name__ == "__main__":
    sys.path.insert(
        0, str(Path(__file__).resolve().parents[1] / "src")
    )
    sys.exit(main())
