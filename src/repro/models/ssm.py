"""Mamba-2 SSD (state-space duality) block: chunked train, recurrent decode.

Follows the Mamba-2 architecture (Dao & Gu, arXiv:2405.21060):
  in_proj -> [z | xBC | dt], causal depthwise conv over xBC, scalar-decay
  SSM per head (A scalar per head, B/C shared across heads, ngroups=1),
  gated RMSNorm, out_proj.

Training/prefill uses the chunked SSD algorithm: within a chunk the output
is a masked (decay-weighted) quadratic form; across chunks a linear
recurrence carries the (heads, head_dim, d_state) state — O(T * L) instead
of O(T^2), and the inter-chunk pass is a lax.scan.  Decode carries
(conv_state, ssm_state) and costs O(1) per token: this is why mamba2 runs
the long_500k cell.

The chunked pass is also the oracle for the Pallas kernel in
repro.kernels.ssd_scan.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, SSMConfig
from repro.distributed.sharding import constrain
from repro.models.common import init_dense, rmsnorm


def ssm_init(key, cfg: ModelConfig) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    ds = s.d_state
    conv_dim = di + 2 * ds                       # xBC channels
    ks = jax.random.split(key, 4)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba default).
    u = jax.random.uniform(ks[2], (nh,), jnp.float32)
    dt0 = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))    # inverse softplus
    return {
        "in_proj": init_dense(ks[0], (d, 2 * di + 2 * ds + nh)),
        "conv_w": 0.1 * jax.random.normal(ks[1], (s.d_conv, conv_dim), jnp.float32),
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "dt_bias": dt_bias,
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.zeros((di,), jnp.float32),
        "out_proj": init_dense(ks[3], (di, d)),
    }


def _split_proj(cfg: ModelConfig, p, u):
    s = cfg.ssm
    d = cfg.d_model
    di, ds, nh = s.d_inner(d), s.d_state, s.n_heads(d)
    zxbcdt = jnp.einsum("btd,de->bte", u, p["in_proj"].astype(u.dtype))
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di : 2 * di + 2 * ds]
    dt = zxbcdt[..., 2 * di + 2 * ds :]
    return z, xBC, dt


def _causal_conv(xBC, w, b, init_state=None):
    """Depthwise causal conv along seq. xBC: (B,T,C); w: (K,C)."""
    K = w.shape[0]
    if init_state is None:
        pad = jnp.zeros(xBC.shape[:1] + (K - 1,) + xBC.shape[2:], xBC.dtype)
    else:
        pad = init_state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)
    out = sum(
        xp[:, i : i + xBC.shape[1]] * w[i].astype(xBC.dtype) for i in range(K)
    )
    out = jax.nn.silu(out + b.astype(xBC.dtype))
    new_state = xp[:, -(K - 1) :] if K > 1 else None
    return out, new_state


def _heads(cfg: ModelConfig, xBC, dt, p):
    s = cfg.ssm
    d = cfg.d_model
    di, ds, nh = s.d_inner(d), s.d_state, s.n_heads(d)
    x = xBC[..., :di]
    Bm = xBC[..., di : di + ds]                   # (B,T,ds)
    Cm = xBC[..., di + ds :]                      # (B,T,ds)
    B_, T = x.shape[:2]
    x = x.reshape(B_, T, nh, s.head_dim)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,T,nh)
    A = -jnp.exp(p["a_log"])                       # (nh,) negative
    return x, Bm, Cm, dt, A


def ssd_chunked(x, Bm, Cm, dt, A, chunk: int):
    """Chunked SSD scan (streaming over chunks to bound the L x L temps).

    x: (B,T,nh,hd); Bm/Cm: (B,T,ds); dt: (B,T,nh); A: (nh,).
    Returns y: (B,T,nh,hd), final_state: (B,nh,hd,ds).
    """
    B_, T, nh, hd = x.shape
    ds = Bm.shape[-1]
    L = min(chunk, T)
    pad = (-T) % L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    nc = x.shape[1] // L

    # Chunk-major for the scan: (nc, B, L, ...).
    xc = jnp.moveaxis(x.reshape(B_, nc, L, nh, hd), 1, 0)
    Bc = jnp.moveaxis(Bm.reshape(B_, nc, L, ds), 1, 0)
    Cc = jnp.moveaxis(Cm.reshape(B_, nc, L, ds), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(B_, nc, L, nh), 1, 0)
    mask = jnp.tril(jnp.ones((L, L), bool))

    def chunk_step(H, inp):
        xk, Bk, Ck, dtk = inp                       # (B,L,nh,hd) (B,L,ds) ...
        dA = dtk * A                                # (B,L,nh) log-decay <= 0
        cum = jnp.cumsum(dA, axis=1)
        total = cum[:, -1, :]                       # (B,nh)
        # Intra-chunk quadratic with decay weighting.
        scores = jnp.einsum(
            "bld,bmd->blm", Ck, Bk, preferred_element_type=jnp.float32
        )
        decay = jnp.exp(
            jnp.clip(cum[:, :, None, :] - cum[:, None, :, :], -60.0, 0.0)
        )                                            # (B,L,L,nh)
        w = scores[..., None] * decay
        w = jnp.where(mask[None, :, :, None], w, 0.0)
        xdt = (xk * dtk[..., None]).astype(jnp.float32)
        y_intra = jnp.einsum("blmh,bmhp->blhp", w, xdt)
        # Contribution of the carried state.
        y_inter = jnp.einsum(
            "bld,bhpd,blh->blhp", Ck.astype(jnp.float32), H,
            jnp.exp(jnp.clip(cum, -60.0, 0.0)),
        )
        # Chunk summary + recurrence.
        seg = jnp.exp(jnp.clip(total[:, None, :] - cum, -60.0, 0.0))
        S = jnp.einsum(
            "bld,blh,blhp->bhpd",
            Bk.astype(jnp.float32), seg * dtk, xk.astype(jnp.float32),
        )
        H_new = H * jnp.exp(jnp.clip(total, -60.0, 0.0))[:, :, None, None] + S
        return H_new, (y_intra + y_inter).astype(x.dtype)

    H0 = jnp.zeros((B_, nh, hd, ds), jnp.float32)
    H_final, y_chunks = jax.lax.scan(chunk_step, H0, (xc, Bc, Cc, dtc))
    y = jnp.moveaxis(y_chunks, 0, 1).reshape(B_, nc * L, nh, hd)[:, :T]
    return y, H_final


def _pallas_ssd_mode() -> str:
    """"off" (pure-jnp chunked scan — the baseline/oracle), "kernel"
    (real Pallas: TPU, or interpret on CPU tests), or "opaque" (dry-run
    stand-in, see kernels/opaque.py)."""
    import os

    flag = os.environ.get("REPRO_PALLAS_SSD", "auto")
    if flag == "auto":
        return "kernel" if jax.default_backend() == "tpu" else "off"
    if flag == "opaque":
        from repro.kernels import opaque

        return "opaque" if opaque.opaque_mode() else "kernel"
    return "off" if flag in ("0", "false", "off") else "kernel"


def ssm_fullseq(cfg: ModelConfig, p: dict, u, return_cache: bool = True):
    """Full-sequence SSD block. u: (B,T,d) -> (y, cache)."""
    s = cfg.ssm
    z, xBC, dt = _split_proj(cfg, p, u)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    x, Bm, Cm, dtv, A = _heads(cfg, xBC, dt, p)
    mode = _pallas_ssd_mode()
    if mode == "opaque":
        from repro.kernels.opaque import make_ssd_opaque

        y, H = make_ssd_opaque(s.chunk)(x, Bm, Cm, dtv, A)
    elif mode == "kernel":
        from repro.kernels.ssd_scan import ssd_scan

        y, H = ssd_scan(x, Bm, Cm, dtv, A, chunk=s.chunk)
    else:
        y, H = ssd_chunked(x, Bm, Cm, dtv, A, s.chunk)
    y = y + x * p["d_skip"][None, None, :, None].astype(x.dtype)
    di = s.d_inner(cfg.d_model)
    y = y.reshape(y.shape[0], y.shape[1], di)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(y.dtype))
    if not return_cache:
        return out, None
    return out, {"conv": conv_state, "ssm": H}


def ssm_decode(cfg: ModelConfig, p: dict, u, cache: dict):
    """Single-token recurrent step. u: (B,1,d)."""
    s = cfg.ssm
    d = cfg.d_model
    di, ds, nh = s.d_inner(d), s.d_state, s.n_heads(d)
    z, xBC, dt = _split_proj(cfg, p, u)

    # Conv ring update.
    conv = cache["conv"]                           # (B, K-1, C)
    window = jnp.concatenate([conv.astype(xBC.dtype), xBC], axis=1)  # (B,K,C)
    w = p["conv_w"].astype(xBC.dtype)
    out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(xBC.dtype)
    xBC_t = jax.nn.silu(out)[:, None, :]
    new_conv = window[:, 1:]

    x, Bm, Cm, dtv, A = _heads(cfg, xBC_t, dt, p)
    # x: (B,1,nh,hd); Bm/Cm: (B,1,ds); dtv: (B,1,nh)
    H = cache["ssm"].astype(jnp.float32)           # (B,nh,hd,ds)
    g = jnp.exp(dtv[:, 0, :, None, None] * A[None, :, None, None])
    dBx = jnp.einsum(
        "bd,bhp,bh->bhpd", Bm[:, 0].astype(jnp.float32),
        x[:, 0].astype(jnp.float32), dtv[:, 0]
    )
    H_new = H * g + dBx
    y = jnp.einsum("bd,bhpd->bhp", Cm[:, 0].astype(jnp.float32), H_new)
    y = y + x[:, 0].astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(y.shape[0], 1, di).astype(u.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"].astype(y.dtype))
    return out, {"conv": new_conv, "ssm": H_new}
