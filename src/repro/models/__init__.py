"""Model zoo: the 10 assigned architectures behind one API."""

from repro.models.api import Model, build_model, input_specs

__all__ = ["Model", "build_model", "input_specs"]
