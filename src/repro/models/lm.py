"""Decoder-only LM assembled from the block pattern.

Layers are stacked by scanning over repeated pattern *units* (e.g. gemma2
scans 13 units of [local, global]); unit parameters carry a leading U dim.
Compile time is therefore O(pattern) not O(n_layers) — a 95-layer model
lowers as fast as a 2-layer one.  Remat (jax.checkpoint) wraps each unit
in training.  A remainder tail (recurrentgemma: 26 = 8*3 + 2) runs
unscanned after the scan.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import blocks as B
from repro.models.common import (
    apply_norm,
    cross_entropy,
    dtype_of,
    embed_apply,
    embed_init,
    logits_apply,
    norm_init,
)


def _moe_here(cfg: ModelConfig, member_idx: int) -> bool:
    if cfg.moe is None:
        return False
    il = cfg.moe.interleave
    return member_idx % il == il - 1


def lm_init(key, cfg: ModelConfig) -> dict:
    U = cfg.unit_count()
    pattern = cfg.block_pattern

    def unit_init(k):
        ks = jax.random.split(k, len(pattern))
        return {
            f"b{i}": B.block_init(ks[i], cfg, kind, _moe_here(cfg, i))
            for i, kind in enumerate(pattern)
        }

    keys = jax.random.split(jax.random.fold_in(key, 1), U)
    params = {
        "embed_p": embed_init(jax.random.fold_in(key, 0), cfg),
        "units": jax.vmap(unit_init)(keys),
        "final_norm": norm_init(cfg, cfg.d_model),
    }
    tail = cfg.tail_pattern()
    if tail:
        tks = jax.random.split(jax.random.fold_in(key, 2), len(tail))
        params["tail"] = [
            B.block_init(tks[i], cfg, kind, _moe_here(cfg, i))
            for i, kind in enumerate(tail)
        ]
    return params


def _unit_fullseq(cfg, unit_p, x, positions, mode, cache_len=None):
    caches = {}
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.block_pattern):
        x, c = B.block_fullseq(cfg, kind, unit_p[f"b{i}"], x, positions, mode,
                               cache_len=cache_len)
        if mode == "prefill":
            caches[f"b{i}"] = c
        elif c is not None and "aux" in c:
            aux = aux + c["aux"]
    if mode == "train":
        return x, aux
    return x, caches


def backbone_fullseq(cfg: ModelConfig, params, x, positions, mode: str, cache_len=None):
    """x: (B,T,d) embedded input -> (x_out, cache_pytree|None)."""
    x = constrain(x, ("batch", None, None))

    if mode == "train":
        def body(carry, unit_p):
            h, aux_in = carry
            # Sequence-parallel unit boundary: the remat-saved carry is
            # sharded over ("model",) along seq, shrinking saved
            # activations by the TP degree (16x on the production mesh).
            h = constrain(h, ("batch", "act_seq", None))
            h, aux = jax.checkpoint(
                lambda hh, pp: _unit_fullseq(cfg, pp, hh, positions, "train"),
            )(h, unit_p)
            return (h, aux_in + aux), None
        (x, aux_total), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), params["units"])
        caches = None
    else:
        def body(carry, unit_p):
            h, cache = _unit_fullseq(cfg, unit_p, carry, positions, "prefill", cache_len)
            return h, cache
        x, caches = jax.lax.scan(body, x, params["units"])

    tail_caches = []
    for i, kind in enumerate(cfg.tail_pattern()):
        x, c = B.block_fullseq(cfg, kind, params["tail"][i], x, positions, mode,
                               cache_len=cache_len)
        tail_caches.append(c)
    if mode == "train":
        return x, aux_total
    cache = {"units": caches}
    if tail_caches:
        cache["tail"] = tail_caches
    return x, cache


def backbone_decode(cfg: ModelConfig, params, x, cache, pos):
    def body(carry, xs):
        unit_p, cache_in = xs
        h = carry
        new_caches = {}
        for i, kind in enumerate(cfg.block_pattern):
            h, c = B.block_decode(cfg, kind, unit_p[f"b{i}"], h, cache_in[f"b{i}"], pos)
            new_caches[f"b{i}"] = c
        return h, new_caches

    x, new_unit_caches = jax.lax.scan(body, x, (params["units"], cache["units"]))
    new_cache = {"units": new_unit_caches}
    if "tail" in cache:
        tail_caches = []
        for i, kind in enumerate(cfg.tail_pattern()):
            x, c = B.block_decode(cfg, kind, params["tail"][i], x, cache["tail"][i], pos)
            tail_caches.append(c)
        new_cache["tail"] = tail_caches
    return x, new_cache


# -- entry points ---------------------------------------------------------------


def _embed_tokens(cfg, params, tokens):
    return embed_apply(cfg, params["embed_p"], tokens)


def _prepend_patches(cfg, x_tok, patches):
    """VLM: prepend projected patch embeddings (stub frontend output)."""
    return jnp.concatenate([patches.astype(x_tok.dtype), x_tok], axis=1)


def train_loss(cfg: ModelConfig, params, batch) -> jax.Array:
    tokens = batch["tokens"]
    x = _embed_tokens(cfg, params, tokens)
    n_prefix = 0
    if cfg.family == "vlm":
        x = _prepend_patches(cfg, x, batch["patches"])
        n_prefix = batch["patches"].shape[1]
    T = x.shape[1]
    positions = jnp.arange(T, dtype=jnp.int32)
    x, aux = backbone_fullseq(cfg, params, x, positions, "train")
    x = apply_norm(cfg, params["final_norm"], x)
    if n_prefix:
        x = x[:, n_prefix:]
    logits = logits_apply(cfg, params["embed_p"], x)
    loss = cross_entropy(logits[:, :-1], batch["labels"][:, 1:])
    if cfg.moe is not None:
        loss = loss + 0.01 * aux   # load-balance coefficient (OLMoE uses 0.01)
    return loss


def prefill(cfg: ModelConfig, params, batch, cache_len=None):
    tokens = batch["tokens"]
    x = _embed_tokens(cfg, params, tokens)
    if cfg.family == "vlm":
        x = _prepend_patches(cfg, x, batch["patches"])
    T = x.shape[1]
    positions = jnp.arange(T, dtype=jnp.int32)
    x, cache = backbone_fullseq(cfg, params, x, positions, "prefill", cache_len)
    x = apply_norm(cfg, params["final_norm"], x)
    logits = logits_apply(cfg, params["embed_p"], x[:, -1:])
    return logits, cache


def decode_step(cfg: ModelConfig, params, batch):
    """batch: {"token": (B,1), "pos": scalar, "cache": pytree}."""
    x = _embed_tokens(cfg, params, batch["token"])
    x, new_cache = backbone_decode(cfg, params, x, batch["cache"], batch["pos"])
    x = apply_norm(cfg, params["final_norm"], x)
    logits = logits_apply(cfg, params["embed_p"], x)
    return logits, new_cache
