"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Block structure (the "recurrent block" of Griffin):

    x -> [gate branch: Linear(d->w) -> GeLU]
      -> [rec branch:  Linear(d->w) -> causal conv1d(4) -> RG-LRU]
    y = gate * rglru_out -> Linear(w->d)

RG-LRU recurrence (real-gated linear recurrence unit):
    r_t = sigmoid(a_gate(x_t));  i_t = sigmoid(x_gate(x_t))
    log a_t = -c * softplus(Lambda) * r_t           (c = 8)
    h_t = exp(log a_t) * h_{t-1} + sqrt(1 - exp(2 log a_t)) * (i_t * x_t)

Full-sequence mode uses lax.associative_scan on the linear recurrence
(h_t = a_t h_{t-1} + b_t is associative), giving O(T log T) depth-parallel
training; decode carries (conv_state, h) with O(1) work per token — this
plus the 2048-window local attention is why recurrentgemma runs long_500k.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import init_dense

_C = 8.0


def rglru_init(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.rglru.lru_width or d
    K = cfg.rglru.d_conv
    ks = jax.random.split(key, 6)
    # Lambda init so a^c spans ~(0.9, 0.999) (Griffin appendix).
    u = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / (2.0 * _C)))   # inv-softplus
    return {
        "w_gate": init_dense(ks[0], (d, w)),
        "w_rec": init_dense(ks[1], (d, w)),
        "w_out": init_dense(ks[2], (w, d)),
        "conv_w": 0.1 * jax.random.normal(ks[3], (K, w), jnp.float32),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "lambda_p": lam,
        "a_gate": init_dense(ks[5], (w, w)),
        "x_gate": init_dense(jax.random.fold_in(ks[5], 1), (w, w)),
        "a_gate_b": jnp.zeros((w,), jnp.float32),
        "x_gate_b": jnp.zeros((w,), jnp.float32),
    }


def _conv(x, w, b, init_state=None):
    K = w.shape[0]
    if init_state is None:
        pad = jnp.zeros(x.shape[:1] + (K - 1,) + x.shape[2:], x.dtype)
    else:
        pad = init_state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * w[i].astype(x.dtype) for i in range(K))
    new_state = xp[:, -(K - 1) :] if K > 1 else None
    return out + b.astype(x.dtype), new_state


def _gates(p, x):
    """x: (B,T,w) -> (log_a, b_t) of the recurrence h = a h + b."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ p["a_gate"] + p["a_gate_b"])
    i = jax.nn.sigmoid(xf @ p["x_gate"] + p["x_gate_b"])
    log_a = -_C * jax.nn.softplus(p["lambda_p"]) * r
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    b = beta * (i * xf)
    return log_a, b


def rglru_fullseq(cfg: ModelConfig, p: dict, x, return_cache: bool = True):
    """x: (B,T,d) -> (y, cache)."""
    dt = x.dtype
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["w_gate"].astype(dt)))
    u = jnp.einsum("btd,dw->btw", x, p["w_rec"].astype(dt))
    u, conv_state = _conv(u, p["conv_w"], p["conv_b"])
    log_a, b = _gates(p, u)
    a = jnp.exp(log_a)

    def combine(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = h.astype(dt)
    y = jnp.einsum("btw,wd->btd", gate * h, p["w_out"].astype(dt))
    if not return_cache:
        return y, None
    return y, {"conv": conv_state, "h": h[:, -1].astype(jnp.float32)}


def rglru_decode(cfg: ModelConfig, p: dict, x, cache: dict):
    """x: (B,1,d); O(1) recurrent step."""
    dt = x.dtype
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, p["w_gate"].astype(dt)))
    u = jnp.einsum("btd,dw->btw", x, p["w_rec"].astype(dt))
    conv = cache["conv"]                       # (B, K-1, w)
    window = jnp.concatenate([conv.astype(dt), u], axis=1)
    w_ = p["conv_w"].astype(dt)
    u_t = jnp.einsum("bkw,kw->bw", window, w_) + p["conv_b"].astype(dt)
    log_a, b = _gates(p, u_t[:, None, :])
    h = cache["h"] * jnp.exp(log_a[:, 0]) + b[:, 0]
    y = jnp.einsum(
        "btw,wd->btd", gate * h[:, None, :].astype(dt), p["w_out"].astype(dt)
    )
    return y, {"conv": window[:, 1:], "h": h}
