"""Whisper-style encoder-decoder (audio backbone; conv frontend is a stub).

Encoder: precomputed frame embeddings (the stub frontend output per the
task block) + sinusoidal positions -> n_enc_layers bidirectional blocks.
Decoder: token embeddings + learned positions -> n_layers blocks with
causal self-attention and cross-attention over the encoder output.
LayerNorm + GELU MLP, tied unembedding (whisper convention).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ENC_ATTN, ModelConfig
from repro.distributed.sharding import constrain
from repro.models import blocks as B
from repro.models.common import (
    apply_norm,
    cross_entropy,
    dtype_of,
    embed_apply,
    embed_init,
    logits_apply,
    norm_init,
)


def sinusoids(length: int, channels: int):
    """Whisper's sinusoidal position embedding."""
    log_timescale = jnp.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2, dtype=jnp.float32))
    t = jnp.arange(length, dtype=jnp.float32)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(t), jnp.cos(t)], axis=1)


def encdec_init(key, cfg: ModelConfig) -> dict:
    ek, dk, pk, emk = jax.random.split(key, 4)

    enc_units = cfg.n_enc_layers  # uniform pattern: one block per unit
    dec_units = cfg.unit_count()

    def enc_unit(k):
        return {"b0": B.block_init(k, cfg, ENC_ATTN, False)}

    def dec_unit(k):
        return {"b0": B.block_init(k, cfg, ATTN, False, cross=True)}

    return {
        "embed_p": embed_init(emk, cfg),
        "pos_embed": 0.01 * jax.random.normal(
            pk, (cfg.max_positions, cfg.d_model), jnp.float32
        ),
        "enc_units": jax.vmap(enc_unit)(jax.random.split(ek, enc_units)),
        "enc_norm": norm_init(cfg, cfg.d_model),
        "dec_units": jax.vmap(dec_unit)(jax.random.split(dk, dec_units)),
        "final_norm": norm_init(cfg, cfg.d_model),
    }


def encode(cfg: ModelConfig, params, audio_embed):
    """audio_embed: (B, S, d) stub-frontend output -> (B, S, d)."""
    S = audio_embed.shape[1]
    x = audio_embed.astype(dtype_of(cfg, "act"))
    x = x + sinusoids(S, cfg.d_model).astype(x.dtype)[None]
    x = constrain(x, ("batch", None, None))
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(carry, unit_p):
        h, _ = B.block_fullseq(cfg, ENC_ATTN, unit_p["b0"], carry, positions, "train")
        return h, None

    x, _ = jax.lax.scan(body, x, params["enc_units"])
    return apply_norm(cfg, params["enc_norm"], x)


def _decoder_fullseq(cfg, params, tokens, enc_out, mode: str, cache_len=None):
    T = tokens.shape[1]
    positions = jnp.arange(T, dtype=jnp.int32)
    x = embed_apply(cfg, params["embed_p"], tokens)
    x = x + params["pos_embed"][:T].astype(x.dtype)[None]
    x = constrain(x, ("batch", None, None))
    enc_positions = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def body(carry, unit_p):
        h, cache = B.block_fullseq(
            cfg, ATTN, unit_p["b0"], carry, positions, mode,
            enc_out=enc_out, enc_positions=enc_positions, cache_len=cache_len,
        )
        return h, ({"b0": cache} if mode == "prefill" else None)

    x, caches = jax.lax.scan(body, x, params["dec_units"])
    x = apply_norm(cfg, params["final_norm"], x)
    return x, caches


def train_loss(cfg: ModelConfig, params, batch) -> jax.Array:
    enc_out = encode(cfg, params, batch["audio_embed"])
    x, _ = _decoder_fullseq(cfg, params, batch["tokens"], enc_out, "train")
    logits = logits_apply(cfg, params["embed_p"], x)
    return cross_entropy(logits[:, :-1], batch["labels"][:, 1:])


def prefill(cfg: ModelConfig, params, batch, cache_len=None):
    enc_out = encode(cfg, params, batch["audio_embed"])
    x, caches = _decoder_fullseq(
        cfg, params, batch["tokens"], enc_out, "prefill", cache_len
    )
    logits = logits_apply(cfg, params["embed_p"], x[:, -1:])
    return logits, {"units": caches}


def decode_step(cfg: ModelConfig, params, batch):
    pos = batch["pos"]
    x = embed_apply(cfg, params["embed_p"], batch["token"])
    x = x + jax.lax.dynamic_slice_in_dim(
        params["pos_embed"], pos, 1, axis=0
    ).astype(x.dtype)[None]

    def body(carry, xs):
        unit_p, cache_in = xs
        h, c = B.block_decode(cfg, ATTN, unit_p["b0"], carry, cache_in["b0"], pos)
        return h, {"b0": c}

    x, new_caches = jax.lax.scan(body, x, (params["dec_units"], batch["cache"]["units"]))
    x = apply_norm(cfg, params["final_norm"], x)
    logits = logits_apply(cfg, params["embed_p"], x)
    return logits, {"units": new_caches}
