"""Shared model primitives: norms, RoPE, MLPs, embeddings, losses."""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig, kind: str = "param"):
    return jnp.dtype(cfg.param_dtype if kind == "param" else cfg.activation_dtype)


def init_dense(key, shape, scale: Optional[float] = None, in_dims: int = 1):
    """Truncated-normal fan-in init (stddev 1/sqrt(fan_in) by default)."""
    fan_in = 1
    for s in shape[:in_dims]:
        fan_in *= s
    stddev = scale if scale is not None else fan_in**-0.5
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)


# -- norms -------------------------------------------------------------------


def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layernorm(x, scale, bias, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm_init(cfg: ModelConfig, d: int):
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32), "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}


def apply_norm(cfg: ModelConfig, p, x):
    if cfg.norm == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


# -- rotary embeddings ---------------------------------------------------------


def rope(x, positions, theta: float):
    """Apply rotary embedding; x: (..., seq, heads, head_dim), positions: (seq,) or (..., seq)."""
    if theta <= 0:
        return x
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd // 2, dtype=jnp.float32) / (hd // 2))
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    ang = ang[..., None, :]                                  # broadcast heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap: Optional[float]):
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# -- MLPs ----------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d: int, ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "wi": init_dense(k1, (d, ff)),
            "wg": init_dense(k2, (d, ff)),
            "wd": init_dense(k3, (ff, d)),
        }
    return {"wi": init_dense(k1, (d, ff)), "wd": init_dense(k3, (ff, d))}


def mlp_apply(cfg: ModelConfig, p, x):
    from repro.distributed.sharding import constrain

    dt = x.dtype
    h = jnp.einsum("btd,df->btf", x, p["wi"].astype(dt))
    if cfg.mlp in ("swiglu", "geglu"):
        g = jnp.einsum("btd,df->btf", x, p["wg"].astype(dt))
        act = jax.nn.silu if cfg.mlp == "swiglu" else jax.nn.gelu
        h = act(g) * h
    else:
        h = jax.nn.gelu(h)
    h = constrain(h, ("batch", None, "ff"))
    return jnp.einsum("btf,fd->btd", h, p["wd"].astype(dt))


# -- embeddings / head ---------------------------------------------------------


def embed_init(key, cfg: ModelConfig):
    p = {"embed": 0.02 * jax.random.normal(key, (cfg.vocab, cfg.d_model), jnp.float32)}
    if not cfg.tie_embeddings:
        p["unembed"] = init_dense(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab)
        )
    return p


def embed_apply(cfg: ModelConfig, p, tokens):
    x = p["embed"][tokens].astype(dtype_of(cfg, "act"))
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model**0.5, x.dtype)
    return x


def logits_apply(cfg: ModelConfig, p, x):
    from repro.distributed.sharding import constrain

    w = p["embed"].T if cfg.tie_embeddings else p["unembed"]
    logits = jnp.einsum(
        "btd,dv->btv", x, w.astype(x.dtype), preferred_element_type=jnp.float32
    )
    logits = constrain(logits, ("batch", None, "vocab"))
    return softcap(logits, cfg.final_softcap)


def cross_entropy(logits, labels, mask=None):
    """Mean token cross-entropy in fp32; mask: 1.0 = count this position.

    Written so a vocab-sharded logits tensor never gets all-gathered:
    logsumexp and the gold-logit pick are both reductions over the vocab
    dim (select+reduce fuses; XLA turns them into partial reductions +
    a scalar all-reduce across the model axis).
    """
    logits = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    logz = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = labels[..., None] == jnp.arange(logits.shape[-1], dtype=labels.dtype)
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
