"""Mixture-of-Experts FFN: top-k router + capacity-based scatter dispatch.

The dispatch is the Switch/Mixtral-style dropping implementation: each
expert owns a (capacity, d) buffer; token slots are scatter-placed by
their position-in-expert (cumsum over the routing one-hot), tokens past
capacity are dropped (their residual path carries them through).  Compute
is a batched einsum over the expert dimension, which shards cleanly over
the "model" axis (expert parallelism), with FSDP on d_model.

FLOP cost: 2 * E * capacity * d * ff per projection = top_k * cf * the
ideal active-expert FLOPs — no dense-all-experts blowup.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.distributed.sharding import constrain
from repro.models.common import init_dense, mlp_apply, mlp_init


def moe_init(key, cfg: ModelConfig, moe: MoEConfig) -> dict:
    d, ff, E = cfg.d_model, moe.d_ff_expert, moe.n_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": init_dense(ks[0], (d, E), scale=0.02),
        "moe_wi": init_dense(ks[1], (E, d, ff), in_dims=2) * (moe.n_experts**0.5),
        "moe_wg": init_dense(ks[2], (E, d, ff), in_dims=2) * (moe.n_experts**0.5),
        "moe_wd": init_dense(ks[3], (E, ff, d), in_dims=2) * (moe.n_experts**0.5),
    }
    # in_dims=2 treats (E, d) as fan-in; rescale so each expert is 1/sqrt(d).
    if moe.shared_expert:
        p["shared"] = mlp_init(ks[4], cfg, d, moe.d_ff_expert)
    return p


def moe_apply(
    cfg: ModelConfig, moe: MoEConfig, p: dict, x, with_aux: bool = False
):
    """x: (B, T, d) -> (B, T, d) [, load-balance aux loss]."""
    if _use_ep():
        return moe_apply_ep(cfg, moe, p, x, with_aux)
    B, T, d = x.shape
    dt = x.dtype
    N = B * T
    E, k = moe.n_experts, moe.top_k
    tokens = x.reshape(N, d)

    logits = jnp.einsum(
        "nd,de->ne", tokens, p["router"].astype(dt),
        preferred_element_type=jnp.float32,
    )
    if moe.router == "sigmoid":
        probs = jax.nn.sigmoid(logits)
        gate_v, gate_i = jax.lax.top_k(probs, k)            # (N, k)
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gate_v, gate_i = jax.lax.top_k(probs, k)
        gate_v = gate_v / jnp.maximum(gate_v.sum(-1, keepdims=True), 1e-9)

    aux = None
    if with_aux:
        # Switch-style: E * sum_e fraction_routed_e * mean_prob_e.
        frac = jnp.mean(
            jax.nn.one_hot(gate_i[:, 0], E, dtype=jnp.float32), axis=0
        )
        mean_prob = jnp.mean(
            probs if moe.router == "softmax"
            else probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9),
            axis=0,
        )
        aux = E * jnp.sum(frac * mean_prob)

    capacity = max(int(N * k / E * moe.capacity_factor), 4)

    # Position of each assignment within its expert (dropping past capacity).
    flat_e = gate_i.reshape(N * k)                           # (Nk,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # (Nk, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot           # (Nk, E)
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < capacity

    # Scatter tokens into (E, capacity, d) buffers.
    tok_rep = jnp.repeat(tokens, k, axis=0)                  # (Nk, d)
    safe_pos = jnp.where(keep, pos, capacity - 1)
    buf = jnp.zeros((E, capacity, d), dt)
    buf = buf.at[flat_e, safe_pos].add(
        tok_rep * keep[:, None].astype(dt), mode="drop"
    )
    buf = constrain(buf, ("experts", None, None))

    # Expert SwiGLU (batched over E).
    h = jnp.einsum("ecd,edf->ecf", buf, p["moe_wi"].astype(dt))
    g = jnp.einsum("ecd,edf->ecf", buf, p["moe_wg"].astype(dt))
    h = jax.nn.silu(g) * h
    # experts already occupy the "model" axis; ff stays unsharded here.
    h = constrain(h, ("experts", None, None))
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["moe_wd"].astype(dt))

    # Gather back and combine with gates.
    out_tok = out_buf[flat_e, safe_pos]                      # (Nk, d)
    out_tok = out_tok * (keep[:, None] * gate_v.reshape(N * k, 1)).astype(dt)
    y = out_tok.reshape(N, k, d).sum(axis=1)

    if moe.shared_expert:
        y = y + mlp_apply(cfg, p["shared"], x).reshape(N, d)
    y = y.reshape(B, T, d)
    return (y, aux) if with_aux else y


# ---------------------------------------------------------------------------
# Expert-parallel dispatch (shard_map over the "model" axis).
# ---------------------------------------------------------------------------


def _use_ep() -> bool:
    import os

    return os.environ.get("REPRO_MOE_EP", "0") == "1"


def moe_apply_ep(
    cfg: ModelConfig, moe: MoEConfig, p: dict, x, with_aux: bool = False
):
    """Expert-parallel MoE: experts shard over "model"; tokens stay local.

    The dense dispatch (moe_apply) scatter-adds every device's tokens into
    one *global* (E, capacity, d) buffer — under SPMD that lowers to
    all-reduces of the whole buffer plus an all-gather for the global
    position-in-expert cumsum (~0.9 TB/device/step on the olmoe train
    cell).  Here each model-rank owns E/TP experts and dispatches its
    (replicated) local tokens to them with a *local* cumsum and *local*
    capacity; the only cross-rank communication is one psum of the (B, T,
    d) combine — the same all-reduce a dense TP FFN already pays.

    Capacity note: local capacity cap_l = N_local*k/E*cf gives the same
    expected drop rate as the global buffer (token->expert assignment is
    iid across data shards), matching the paper-faithful semantics in
    expectation; tests assert parity at generous cf.
    """
    from repro.distributed import sharding as SH

    mesh = SH.current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return moe_apply(cfg, moe, p, x, with_aux)
    tp = mesh.shape["model"]
    E, k = moe.n_experts, moe.top_k
    if E % tp != 0:
        return moe_apply(cfg, moe, p, x, with_aux)
    E_local = E // tp
    B, T, d = x.shape
    rules = SH.current_rules() or SH.rules_for_mesh(mesh)
    b_axes = rules["batch"]
    b_size = 1
    for ax in b_axes:
        b_size *= mesh.shape[ax]
    x_b = b_axes if B % b_size == 0 else None

    from jax.sharding import PartitionSpec as P

    def body(xl, router_w, wi, wg, wd):
        rank = jax.lax.axis_index("model")
        e0 = rank * E_local
        Bl, Tl, _ = xl.shape
        N = Bl * Tl
        dt = xl.dtype
        tokens = xl.reshape(N, d)

        logits = jnp.einsum(
            "nd,de->ne", tokens, router_w.astype(dt),
            preferred_element_type=jnp.float32,
        )
        if moe.router == "sigmoid":
            probs = jax.nn.sigmoid(logits)
            gate_v, gate_i = jax.lax.top_k(probs, k)
        else:
            probs = jax.nn.softmax(logits, axis=-1)
            gate_v, gate_i = jax.lax.top_k(probs, k)
            gate_v = gate_v / jnp.maximum(gate_v.sum(-1, keepdims=True), 1e-9)

        aux = jnp.zeros((), jnp.float32)
        if with_aux:
            frac = jnp.mean(
                jax.nn.one_hot(gate_i[:, 0], E, dtype=jnp.float32), axis=0
            )
            mean_prob = jnp.mean(
                probs if moe.router == "softmax"
                else probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9),
                axis=0,
            )
            aux = E * jnp.sum(frac * mean_prob)

        cap = max(int(N * k / E * moe.capacity_factor), 4)
        flat_e = gate_i.reshape(N * k)
        mine = (flat_e >= e0) & (flat_e < e0 + E_local)
        le = jnp.where(mine, flat_e - e0, E_local)       # E_local = drop row
        onehot = jax.nn.one_hot(le, E_local + 1, dtype=jnp.int32)
        pos = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=0) - onehot, le[:, None], axis=1
        )[:, 0]
        keep = mine & (pos < cap)
        safe_pos = jnp.where(keep, pos, cap - 1)
        safe_le = jnp.where(keep, le, 0)

        tok_rep = jnp.repeat(tokens, k, axis=0)
        buf = jnp.zeros((E_local, cap, d), dt)
        buf = buf.at[safe_le, safe_pos].add(
            tok_rep * keep[:, None].astype(dt), mode="drop"
        )

        h = jnp.einsum("ecd,edf->ecf", buf, wi.astype(dt))
        g = jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt))
        h = jax.nn.silu(g) * h
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd.astype(dt))

        out_tok = out_buf[safe_le, safe_pos]
        out_tok = out_tok * (keep[:, None] * gate_v.reshape(N * k, 1)).astype(dt)
        y = out_tok.reshape(N, k, d).sum(axis=1).reshape(Bl, Tl, d)
        # combine across expert owners (every token's k experts may live
        # on different ranks) — the single cross-rank collective.
        y = jax.lax.psum(y, "model")
        return y, aux

    y, aux = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(x_b, None, None),
            P(None, None),            # router replicated
            P("model", None, None),   # per-rank expert slices
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(x_b, None, None), P()),
        check_vma=False,
    )(x, p["router"], p["moe_wi"], p["moe_wg"], p["moe_wd"])

    if moe.shared_expert:
        y = y + mlp_apply(cfg, p["shared"], x)
    return (y, aux) if with_aux else y


def router_aux_loss(cfg: ModelConfig, moe: MoEConfig, p: dict, x) -> jax.Array:
    """Load-balance auxiliary loss (Switch-style fraction * prob)."""
    B, T, d = x.shape
    tokens = x.reshape(B * T, d)
    logits = jnp.einsum(
        "nd,de->ne", tokens, p["router"].astype(x.dtype),
        preferred_element_type=jnp.float32,
    )
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, moe.n_experts, dtype=jnp.float32), axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    return moe.n_experts * jnp.sum(frac * mean_prob)
