"""Public model API: build_model(cfg) -> Model bundle + input_specs.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input
of a given (shape kind) cell — weak-type-correct, shardable, and never
allocating device memory.  Decode cache specs are derived with
jax.eval_shape over the prefill function, so they are consistent with the
real cache structure by construction.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import lm as LM


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    train_loss: Callable          # (params, batch) -> scalar loss
    prefill: Callable             # (params, batch) -> (logits, cache)
    decode_step: Callable         # (params, batch{token,pos,cache}) -> (logits, cache)

    def input_specs(self, shape: ShapeConfig, batch_override: Optional[int] = None) -> Dict:
        return input_specs(self.cfg, shape, batch_override)


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return Model(
            cfg=cfg,
            init=functools.partial(ED.encdec_init, cfg=cfg),
            train_loss=functools.partial(ED.train_loss, cfg),
            prefill=functools.partial(ED.prefill, cfg),
            decode_step=functools.partial(ED.decode_step, cfg),
        )
    return Model(
        cfg=cfg,
        init=functools.partial(LM.lm_init, cfg=cfg),
        train_loss=functools.partial(LM.train_loss, cfg),
        prefill=functools.partial(LM.prefill, cfg),
        decode_step=functools.partial(LM.decode_step, cfg),
    )


def _frontend_specs(cfg: ModelConfig, B: int) -> Dict:
    """Stub modality frontends: precomputed frame/patch embeddings."""
    act = jnp.dtype(cfg.activation_dtype)
    if cfg.family == "encdec":
        return {
            "audio_embed": jax.ShapeDtypeStruct((B, cfg.enc_positions, cfg.d_model), act)
        }
    if cfg.family == "vlm":
        return {"patches": jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), act)}
    return {}


def input_specs(
    cfg: ModelConfig, shape: ShapeConfig, batch_override: Optional[int] = None
) -> Dict:
    """ShapeDtypeStruct inputs for one grid cell.

    train  -> {tokens, labels, frontend...}
    prefill-> {tokens, frontend...}
    decode -> {token, pos, cache} with a seq_len-deep cache.
    """
    B = batch_override or shape.global_batch
    i32 = jnp.int32

    if shape.kind == "train":
        T = shape.seq_len
        specs = {
            "tokens": jax.ShapeDtypeStruct((B, T), i32),
            "labels": jax.ShapeDtypeStruct((B, T), i32),
        }
        specs.update(_frontend_specs(cfg, B))
        return specs

    if shape.kind == "prefill":
        T = shape.seq_len
        if cfg.family == "vlm":
            T = max(T - cfg.n_patches, 1)   # patches occupy context slots
        specs = {"tokens": jax.ShapeDtypeStruct((B, T), i32)}
        specs.update(_frontend_specs(cfg, B))
        return specs

    # decode: cache specs come from eval_shape over prefill at depth seq_len.
    model = build_model(cfg)
    pre_shape = dataclasses.replace(shape, kind="prefill")
    pre_specs = input_specs(cfg, pre_shape, batch_override=B)
    _, cache_spec = jax.eval_shape(model.prefill, _params_spec(cfg), pre_specs)
    return {
        "token": jax.ShapeDtypeStruct((B, 1), i32),
        "pos": jax.ShapeDtypeStruct((), i32),
        "cache": cache_spec,
    }


@functools.lru_cache(maxsize=32)
def _params_spec(cfg: ModelConfig):
    """Abstract parameter tree (no allocation)."""
    model = build_model(cfg)
    return jax.eval_shape(lambda k: model.init(k), jax.ShapeDtypeStruct((2,), jnp.uint32))
