"""Residual block dispatch: one init/apply pair per block kind.

Every layer is (norm -> temporal mixer -> residual) + (norm -> FFN ->
residual); the mixer is the block kind from the config pattern (global/
local attention, SSD, RG-LRU).  MoE layers replace the dense FFN.  The
whisper decoder adds a cross-attention sub-block.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, ENC_ATTN, LOCAL, RGLRU, SSM, ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import ssm as SSMM
from repro.models.common import apply_norm, mlp_apply, mlp_init, norm_init


def _sp(x):
    """Sequence-parallel residual stream (flash/Megatron-SP mode only):
    elementwise + norms run on T/TP tokens; projection outputs
    reduce-scatter into this layout instead of all-reducing."""
    if A.seq_parallel_mode():
        return constrain(x, ("batch", "act_seq", None))
    return x


def _is_attn(kind: str) -> bool:
    return kind in (ATTN, LOCAL, ENC_ATTN)


def block_init(
    key, cfg: ModelConfig, kind: str, moe_here: bool, cross: bool = False
) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p: dict = {"ln1": norm_init(cfg, d)}
    if _is_attn(kind):
        p["attn"] = A.attn_init(ks[0], cfg)
    elif kind == SSM:
        p["ssm"] = SSMM.ssm_init(ks[0], cfg)
    elif kind == RGLRU:
        p["rglru"] = RG.rglru_init(ks[0], cfg)
    else:
        raise ValueError(kind)
    if cross:
        p["lnx"] = norm_init(cfg, d)
        p["xattn"] = A.attn_init(ks[1], cfg)
    if kind != SSM:  # SSD blocks are the whole mixer+channel layer
        p["ln2"] = norm_init(cfg, d)
        if moe_here:
            p["moe"] = MOE.moe_init(ks[2], cfg, cfg.moe)
        else:
            p["mlp"] = mlp_init(ks[2], cfg, d, cfg.d_ff)
    return p


def block_fullseq(
    cfg: ModelConfig,
    kind: str,
    p: dict,
    x,
    positions,
    mode: str,                      # "train" | "prefill"
    enc_out=None,
    enc_positions=None,
    cache_len=None,
) -> Tuple[jax.Array, Optional[dict]]:
    """Full-sequence block application; returns (x, cache or None)."""
    want_cache = mode == "prefill"
    cache: dict = {}
    h = apply_norm(cfg, p["ln1"], x)
    if _is_attn(kind):
        attn_kind = {ATTN: "causal", LOCAL: "local", ENC_ATTN: "bidir"}[kind]
        y, c = A.attention_fullseq(
            cfg, p["attn"], h, positions, attn_kind, return_cache=want_cache,
            cache_len=cache_len,
        )
        if want_cache:
            cache["attn"] = c
    elif kind == SSM:
        y, c = SSMM.ssm_fullseq(cfg, p["ssm"], h, return_cache=want_cache)
        if want_cache:
            cache["ssm"] = c
        return x + y, cache or None
    else:  # RGLRU
        y, c = RG.rglru_fullseq(cfg, p["rglru"], h, return_cache=want_cache)
        if want_cache:
            cache["rglru"] = c
    x = _sp(x + y)
    if "xattn" in p:
        h = apply_norm(cfg, p["lnx"], x)
        y, c = A.attention_fullseq(
            cfg, p["xattn"], h, positions, "cross",
            enc_out=enc_out, enc_positions=enc_positions,
            return_cache=want_cache,
        )
        if want_cache:
            cache["xattn"] = c
        x = _sp(x + y)
    h = apply_norm(cfg, p["ln2"], x)
    if "moe" in p:
        if mode == "train":
            y, aux = MOE.moe_apply(cfg, cfg.moe, p["moe"], h, with_aux=True)
            cache["aux"] = aux
        else:
            y = MOE.moe_apply(cfg, cfg.moe, p["moe"], h)
    else:
        y = mlp_apply(cfg, p["mlp"], h)
    return _sp(x + y), (cache or None)


def block_decode(
    cfg: ModelConfig,
    kind: str,
    p: dict,
    x,
    cache: dict,
    pos,
) -> Tuple[jax.Array, dict]:
    new_cache: dict = {}
    h = apply_norm(cfg, p["ln1"], x)
    if _is_attn(kind):
        attn_kind = "local" if kind == LOCAL else "causal"
        y, c = A.attention_decode(cfg, p["attn"], h, cache["attn"], pos, attn_kind)
        new_cache["attn"] = c
    elif kind == SSM:
        y, c = SSMM.ssm_decode(cfg, p["ssm"], h, cache["ssm"])
        new_cache["ssm"] = c
        return x + y, new_cache
    else:
        y, c = RG.rglru_decode(cfg, p["rglru"], h, cache["rglru"])
        new_cache["rglru"] = c
    x = x + y
    if "xattn" in p:
        h = apply_norm(cfg, p["lnx"], x)
        y, c = A.attention_decode(cfg, p["xattn"], h, cache["xattn"], pos, "cross")
        new_cache["xattn"] = c
        x = x + y
    h = apply_norm(cfg, p["ln2"], x)
    if "moe" in p:
        y = MOE.moe_apply(cfg, cfg.moe, p["moe"], h)
    else:
        y = mlp_apply(cfg, p["mlp"], h)
    return x + y, new_cache
