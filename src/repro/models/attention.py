"""GQA attention: global/local/bidirectional/cross, train + cached decode.

Full-sequence paths use a blockwise online-softmax (nested lax.scan over
query and key blocks), so the T x T score matrix is never materialized —
required for the 32k-prefill shapes (a 32k x 32k fp32 score tensor per
head would not fit HBM).  This pure-jnp implementation is also the oracle
for the Pallas flash-attention kernel (repro.kernels.flash_attention).

Layouts:
  q:        (B, T, K, G, hd)   with H = K * G  (G = query groups per KV head)
  k, v:     (B, S, K, hd)
  caches:   global (B, K, S, hd) absolute-position slots;
            local  (B, K, W, hd) shift-ring (roll per step).
"""

from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.common import init_dense, rmsnorm, rope, softcap

NEG = -1e30


def _attn_impl() -> str:
    """"blockwise" (paper-faithful baseline: pure-XLA online softmax) or
    "flash" (optimized: Pallas kernel on TPU / opaque stand-in in the
    dry-run / blockwise fallback on CPU tests)."""
    return os.environ.get("REPRO_ATTN_IMPL", "blockwise")


def _kv_int8() -> bool:
    """int8 fast-tier KV cache (the AR² adaptation) for decode cells."""
    return os.environ.get("REPRO_KV_INT8", "0") == "1"


def seq_parallel_mode() -> bool:
    """Megatron-SP residual stream: active alongside the flash kernel
    (whose queries are context-parallel over the "model" axis), so
    norms/elementwise run on T/TP tokens and projection outputs
    reduce-scatter instead of all-reduce."""
    return _attn_impl() == "flash"


def _flash_dispatch(cfg, q, k, v, causal, window):
    """Returns o or None (caller falls back to blockwise)."""
    if _attn_impl() != "flash":
        return None
    from repro.kernels import opaque

    if opaque.opaque_mode():
        return opaque.make_flash_opaque(causal, window)(q, k, v)
    if jax.default_backend() == "tpu":
        from repro.kernels.flash_attention.ops import flash_attention

        return flash_attention(
            q, k, v, causal=causal, window=window, softcap=cfg.attn_softcap
        )
    return None  # CPU numerics: blockwise reference


def attn_init(key, cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, K = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], (d, H, hd)),
        "wk": init_dense(ks[1], (d, K, hd)),
        "wv": init_dense(ks[2], (d, K, hd)),
        "wo": init_dense(ks[3], (H, hd, d), in_dims=2),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.zeros((hd,), jnp.float32)
        p["k_scale"] = jnp.zeros((hd,), jnp.float32)
    return p


def _project_qkv(cfg: ModelConfig, p, x, kv_x=None):
    """-> q (B,T,K,G,hd), k/v (B,S,K,hd) before rope."""
    dt = x.dtype
    K = cfg.n_kv_heads
    G = cfg.n_heads // K
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    src = x if kv_x is None else kv_x
    k = jnp.einsum("bsd,dnk->bsnk", src, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dnk->bsnk", src, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_scale"])
        k = rmsnorm(k, p["k_scale"])
    B, T = q.shape[:2]
    q = q.reshape(B, T, K, G, q.shape[-1])
    return q, k, v


def _merge_out(cfg: ModelConfig, p, o):
    """o: (B,T,K,G,hd) -> (B,T,d)."""
    B, T = o.shape[:2]
    o = o.reshape(B, T, cfg.n_heads, o.shape[-1])
    return jnp.einsum("bthk,hkd->btd", o, p["wo"].astype(o.dtype))


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _online_softmax_block(q, kb, vb, bias, scale, cap):
    """One (q-block, kv-block) tile. q: (B,K,G,bq,hd); kb/vb: (B,bk,K,hd)."""
    s = jnp.einsum(
        "bkgqh,bskh->bkgqs", q, kb, preferred_element_type=jnp.float32
    ) * scale
    s = softcap(s, cap)
    s = s + bias  # (bq, bk) or broadcastable
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(vb.dtype), vb)
    return m, l, o


def blockwise_attention(
    cfg: ModelConfig,
    q,                      # (B, T, K, G, hd), already roped
    k, v,                   # (B, S, K, hd), already roped
    q_positions,            # (T,) int32 absolute positions
    kv_positions,           # (S,) int32 (NEG-masked entries < 0)
    causal: bool,
    window: Optional[int] = None,
    bq: int = 512,
    bk: int = 1024,
) -> jax.Array:
    """Nested-scan online-softmax attention. Returns (B, T, K, G, hd)."""
    B, T, K, G, hd = q.shape
    S = k.shape[1]
    scale = hd**-0.5
    cap = cfg.attn_softcap
    bq = min(bq, max(T, 1))
    bk = min(bk, max(S, 1))

    qp = _pad_to(q_positions, bq, 0)
    kp = _pad_to(jnp.where(kv_positions < 0, -1, kv_positions), bk, 0)
    # Mark key padding invalid.
    kp = jnp.where(jnp.arange(kp.shape[0]) < S, kp, -1)
    kp = jnp.where(kv_positions.shape[0] == kp.shape[0], kp, kp)
    q_pad = _pad_to(q, bq, 1)
    k_pad = _pad_to(k, bk, 1)
    v_pad = _pad_to(v, bk, 1)
    nq, nk = q_pad.shape[1] // bq, k_pad.shape[1] // bk

    q_blocks = q_pad.reshape(B, nq, bq, K, G, hd).transpose(1, 0, 3, 4, 2, 5)
    qp_blocks = qp.reshape(nq, bq)
    k_blocks = k_pad.reshape(B, nk, bk, K, hd).transpose(1, 0, 2, 3, 4)
    v_blocks = v_pad.reshape(B, nk, bk, K, hd).transpose(1, 0, 2, 3, 4)
    kp_blocks = kp.reshape(nk, bk)

    def q_step(_, q_in):
        qb, qpos = q_in  # (B,K,G,bq,hd), (bq,)

        # flash-attention discipline: never keep the per-tile probability
        # matrix for the backward pass — recompute it (jax.checkpoint on
        # the tile body), otherwise the scan linearization stores
        # O(T^2 / bq / bk) tiles and blows HBM.
        @jax.checkpoint
        def kv_step(carry, kv_in):
            m, l, acc = carry
            kb, vb, kpos = kv_in
            bias = jnp.where(kpos[None, :] >= 0, 0.0, NEG)
            if causal:
                bias = bias + jnp.where(kpos[None, :] <= qpos[:, None], 0.0, NEG)
            if window is not None:
                bias = bias + jnp.where(
                    qpos[:, None] - kpos[None, :] < window, 0.0, NEG
                )
            mb, lb, ob = _online_softmax_block(qb, kb, vb, bias, scale, cap)
            m_new = jnp.maximum(m, mb)
            c_old = jnp.exp(m - m_new)
            c_blk = jnp.exp(mb - m_new)
            l_new = l * c_old + lb * c_blk
            acc_new = acc * c_old[..., None] + ob * c_blk[..., None]
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, K, G, bq), NEG, jnp.float32),
            jnp.zeros((B, K, G, bq), jnp.float32),
            jnp.zeros((B, K, G, bq, hd), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, (k_blocks, v_blocks, kp_blocks))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, o_blocks = jax.lax.scan(jax.checkpoint(q_step), None, (q_blocks, qp_blocks))
    # (nq, B, K, G, bq, hd) -> (B, T, K, G, hd)
    o = o_blocks.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * bq, K, G, hd)
    return o[:, :T]


def windowed_attention(
    cfg: ModelConfig,
    q, k, v,
    q_positions,
    causal_window: int,
    bq: int = 256,
) -> jax.Array:
    """Local (sliding-window) attention: each q block attends to a slice
    [start, start + window + bq) of the left-padded K/V — O(T * window)
    instead of O(T^2)."""
    B, T, K, G, hd = q.shape
    w = causal_window
    scale = hd**-0.5
    cap = cfg.attn_softcap
    bq = min(bq, T)

    q_pad = _pad_to(q, bq, 1)
    qp = _pad_to(q_positions, bq, 0)
    nq = q_pad.shape[1] // bq

    # Left-pad keys by window so the slice for q block i starts at i*bq.
    k_pad = jnp.pad(k, ((0, 0), (w, 0), (0, 0), (0, 0)))
    v_pad = jnp.pad(v, ((0, 0), (w, 0), (0, 0), (0, 0)))
    kpos_full = jnp.concatenate(
        [jnp.full((w,), -1, jnp.int32), jnp.arange(T, dtype=jnp.int32)]
    )
    span = w + bq

    q_blocks = q_pad.reshape(B, nq, bq, K, G, hd).transpose(1, 0, 3, 4, 2, 5)
    qp_blocks = qp.reshape(nq, bq)

    @jax.checkpoint
    def q_step(_, q_in):
        i, qb, qpos = q_in
        start = i * bq
        kb = jax.lax.dynamic_slice_in_dim(k_pad, start, span, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v_pad, start, span, axis=1)
        kpos = jax.lax.dynamic_slice_in_dim(kpos_full, start, span, axis=0)
        bias = jnp.where(kpos[None, :] >= 0, 0.0, NEG)
        bias = bias + jnp.where(kpos[None, :] <= qpos[:, None], 0.0, NEG)
        bias = bias + jnp.where(qpos[:, None] - kpos[None, :] < w, 0.0, NEG)
        m, l, o = _online_softmax_block(qb, kb, vb, bias, scale, cap)
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    idx = jnp.arange(nq, dtype=jnp.int32)
    _, o_blocks = jax.lax.scan(q_step, None, (idx, q_blocks, qp_blocks))
    o = o_blocks.transpose(1, 0, 4, 2, 3, 5).reshape(B, nq * bq, K, G, hd)
    return o[:, :T]


# ---------------------------------------------------------------------------
# int8 KV tier (AR² adaptation): per-page symmetric quantization over hd.
# ---------------------------------------------------------------------------


def _quant_kv(x):
    """x (..., hd) -> (int8 data, f32 scales (..., 1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def _dequant_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def _maybe_quantize_cache(cache: dict) -> dict:
    if not _kv_int8():
        return cache
    kq, ks = _quant_kv(cache["k"])
    vq, vs = _quant_kv(cache["v"])
    return {"k": kq, "k_s": ks, "v": vq, "v_s": vs}


# ---------------------------------------------------------------------------
# Public layer entry points.
# ---------------------------------------------------------------------------


def attention_fullseq(
    cfg: ModelConfig,
    p: dict,
    x,                         # (B, T, d)
    positions,                 # (T,)
    kind: str,                 # "causal" | "local" | "bidir" | "cross"
    enc_out=None,              # (B, S, d) for cross
    enc_positions=None,
    return_cache: bool = True,
    cache_len: Optional[int] = None,
) -> Tuple[jax.Array, Optional[dict]]:
    q, k, v = _project_qkv(cfg, p, x, kv_x=enc_out)
    kv_pos = positions if enc_out is None else enc_positions
    q = rope(q.reshape(q.shape[:2] + (-1, q.shape[-1])), positions, cfg.rope_theta).reshape(q.shape)
    if kind != "cross":
        k = rope(k, kv_pos, cfg.rope_theta)
    # Sequence-parallel mode keeps q context-sharded end to end (None on T
    # would force a full-T re-gather just for the kernel to re-slice it).
    q_t = "act_seq" if seq_parallel_mode() else None
    q = constrain(q, ("batch", q_t, "kv_heads", None, None))
    k = constrain(k, ("batch", None, "kv_heads", None))
    v = constrain(v, ("batch", None, "kv_heads", None))
    if kind == "local":
        o = _flash_dispatch(cfg, q, k, v, causal=True, window=cfg.window)
        if o is None:
            o = windowed_attention(cfg, q, k, v, positions, cfg.window)
    else:
        causal = kind == "causal"
        o = _flash_dispatch(cfg, q, k, v, causal=causal, window=None)
        if o is None:
            o = blockwise_attention(cfg, q, k, v, positions, kv_pos, causal=causal)
    if seq_parallel_mode():
        # keep the kernel's context-parallel layout through the output
        # projection (wo contracts heads only), so o is never re-gathered.
        o = constrain(o, ("batch", "act_seq", None, None, None))
    y = _merge_out(cfg, p, o)
    if not return_cache:
        return y, None
    if kind == "local":
        w = cfg.window
        kc = k[:, -w:].transpose(0, 2, 1, 3)
        vc = v[:, -w:].transpose(0, 2, 1, 3)
        if kc.shape[2] < w:  # left-pad ring to full window
            pad = w - kc.shape[2]
            kc = jnp.pad(kc, ((0, 0), (0, 0), (pad, 0), (0, 0)))
            vc = jnp.pad(vc, ((0, 0), (0, 0), (pad, 0), (0, 0)))
        cache = {"k": kc, "v": vc}
    elif kind == "cross":
        cache = {"k": k.transpose(0, 2, 1, 3), "v": v.transpose(0, 2, 1, 3)}
    else:
        kc = k.transpose(0, 2, 1, 3)
        vc = v.transpose(0, 2, 1, 3)
        if cache_len is not None and cache_len > kc.shape[2]:
            # Headroom for subsequent decode steps (decode writes at pos).
            pad = cache_len - kc.shape[2]
            kc = jnp.pad(kc, ((0, 0), (0, 0), (0, pad), (0, 0)))
            vc = jnp.pad(vc, ((0, 0), (0, 0), (0, pad), (0, 0)))
        cache = {"k": kc, "v": vc}
    return y, _maybe_quantize_cache(cache)


def attention_decode(
    cfg: ModelConfig,
    p: dict,
    x,                       # (B, 1, d)
    cache: dict,             # {"k": (B,K,S|W,hd), "v": ...}
    pos,                     # scalar int32: index of the new token
    kind: str,               # "causal" | "local" | "cross"
) -> Tuple[jax.Array, dict]:
    dt = x.dtype
    K = cfg.n_kv_heads
    G = cfg.n_heads // K
    hd = cfg.resolved_head_dim
    B = x.shape[0]

    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(dt))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_scale"])
    q = rope(q, jnp.full((1,), pos, jnp.int32), cfg.rope_theta)
    q = q.reshape(B, 1, K, G, hd)

    int8_cache = "k_s" in cache
    scales = None
    if kind == "cross":
        ck, cv = cache["k"], cache["v"]           # static (B,K,S,hd)
        S = ck.shape[2]
        valid = jnp.ones((S,), bool)
        new_cache = cache
        if int8_cache:
            scales = (cache["k_s"], cache["v_s"])
    else:
        knew = jnp.einsum("btd,dnk->btnk", x, p["wk"].astype(dt))
        vnew = jnp.einsum("btd,dnk->btnk", x, p["wv"].astype(dt))
        if cfg.qk_norm:
            knew = rmsnorm(knew, p["k_scale"])
        knew = rope(knew, jnp.full((1,), pos, jnp.int32), cfg.rope_theta)
        knew = knew.transpose(0, 2, 1, 3)          # (B,K,1,hd)
        vnew = vnew.transpose(0, 2, 1, 3)
        if int8_cache:
            knew, ks_new = _quant_kv(knew)
            vnew, vs_new = _quant_kv(vnew)
        if kind == "local":
            # Shift-ring: slot W-1 always holds the newest token.
            ck = jnp.concatenate([cache["k"][:, :, 1:], knew], axis=2)
            cv = jnp.concatenate([cache["v"][:, :, 1:], vnew], axis=2)
            W = ck.shape[2]
            n_valid = jnp.minimum(pos + 1, W)
            valid = jnp.arange(W) >= (W - n_valid)
            if int8_cache:
                scales = (
                    jnp.concatenate([cache["k_s"][:, :, 1:], ks_new], axis=2),
                    jnp.concatenate([cache["v_s"][:, :, 1:], vs_new], axis=2),
                )
        else:
            dus = functools.partial(
                jax.lax.dynamic_update_slice_in_dim, start_index=pos, axis=2
            )
            ck = dus(cache["k"], update=knew)
            cv = dus(cache["v"], update=vnew)
            S = ck.shape[2]
            valid = jnp.arange(S) <= pos
            if int8_cache:
                scales = (
                    dus(cache["k_s"], update=ks_new),
                    dus(cache["v_s"], update=vs_new),
                )
        new_cache = {"k": ck, "v": cv}
        if int8_cache:
            new_cache["k_s"], new_cache["v_s"] = scales

    from repro.kernels import opaque as OPQ

    if _attn_impl() == "flash" and OPQ.opaque_mode():
        # Fused KV-read + attend: one opaque call whose operand bytes are
        # the honest HBM traffic (int8 fast tier when enabled — AR²).
        o = OPQ.decode_attention_opaque(
            q, ck, cv, pos, int8=int8_cache, scales=scales
        )
    else:
        if int8_cache:
            ck = _dequant_kv(ck, scales[0], dt)
            cv = _dequant_kv(cv, scales[1], dt)
        s = jnp.einsum(
            "bqkgh,bksh->bkgqs", q, ck, preferred_element_type=jnp.float32
        ) * (hd**-0.5)
        s = softcap(s, cfg.attn_softcap)
        s = jnp.where(valid[None, None, None, None, :], s, NEG)
        w = jax.nn.softmax(s, axis=-1).astype(dt)
        o = jnp.einsum("bkgqs,bksh->bqkgh", w, cv)
    y = _merge_out(cfg, p, o)
    return y, new_cache
