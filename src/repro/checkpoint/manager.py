"""Checkpoint/restart manager: rotation, latest-valid restore, elasticity.

The restart path is the fault-tolerance contract for the 1000+-node
posture: training can resume (a) after losing any single shard per parity
group of the newest checkpoint, (b) after losing the *whole* newest
checkpoint (falls back to the previous one), and (c) onto a *different*
mesh — restored arrays are host numpy, re-placed by the caller's
``jax.device_put`` with the target mesh's NamedShardings (the elastic
re-mesh plan in distributed/elastic.py computes those).
"""

from __future__ import annotations

import json
import shutil
import time
from pathlib import Path
from typing import Any, Optional, Tuple

from repro.checkpoint.ckpt import RestoreStats, restore, save


class CheckpointManager:
    def __init__(
        self,
        root: str | Path,
        *,
        keep: int = 3,
        save_every: int = 100,
        parity_group: int = 4,
        shard_bytes: int = 1 << 24,
        pipelined_restore: bool = True,
    ):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.save_every = save_every
        self.parity_group = parity_group
        self.shard_bytes = shard_bytes
        self.pipelined_restore = pipelined_restore

    # -- paths -------------------------------------------------------------

    def _dir(self, step: int) -> Path:
        return self.root / f"step_{step:09d}"

    def steps(self):
        out = []
        for d in sorted(self.root.glob("step_*")):
            if (d / "manifest.json").exists() and (d / "COMMITTED").exists():
                out.append(int(d.name.split("_")[1]))
        return out

    # -- save ----------------------------------------------------------------

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.save_every == 0

    def save(self, step: int, state: Any) -> Path:
        d = self._dir(step)
        if d.exists():
            shutil.rmtree(d)
        t0 = time.perf_counter()
        save(d, state, parity_group=self.parity_group,
             shard_bytes=self.shard_bytes)
        # Commit marker makes partially-written checkpoints invisible to
        # restore (a crash mid-save must not shadow the previous good one).
        (d / "COMMITTED").write_text(json.dumps({"step": step, "t": time.time()}))
        self._gc()
        dt = time.perf_counter() - t0
        (d / "SAVE_STATS").write_text(json.dumps({"save_s": dt}))
        return d

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._dir(s), ignore_errors=True)

    # -- restore ---------------------------------------------------------------

    def restore_latest(
        self, tree_like: Any
    ) -> Tuple[Optional[int], Optional[Any], Optional[RestoreStats]]:
        """Restore the newest checkpoint that verifies; walk back on failure."""
        for step in reversed(self.steps()):
            try:
                tree, stats = restore(
                    self._dir(step), tree_like, pipelined=self.pipelined_restore
                )
                return step, tree, stats
            except (IOError, KeyError, json.JSONDecodeError):
                continue  # exceeded parity margin -> previous checkpoint
        return None, None, None
