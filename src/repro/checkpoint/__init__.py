from repro.checkpoint.ckpt import (
    RestoreStats,
    corrupt_shard,
    delete_shard,
    restore,
    save,
)
from repro.checkpoint.manager import CheckpointManager

__all__ = [
    "save", "restore", "RestoreStats", "corrupt_shard", "delete_shard",
    "CheckpointManager",
]
