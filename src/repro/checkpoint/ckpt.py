"""Erasure-coded sharded checkpoints with PR²-style pipelined retry restore.

The paper's read path, transplanted to checkpoint I/O:

  * **ECC**: every shard carries a CRC32; a parity group of G shards
    carries one XOR parity shard — any single lost/corrupt shard in a
    group is reconstructed (the "ECC-capability margin" of the restore
    path: one failure per group is *within margin*, so the read still
    succeeds without re-reading from a replica).
  * **PR² (pipelining)**: a reader thread streams shard files into a
    bounded double-buffer queue while the consumer verifies CRCs and
    deserializes the previous shard — verification/decode never blocks the
    next read, exactly like CACHE READ overlapping sensing with transfer.
  * **retry**: a shard failing verification triggers reconstruction from
    its parity group; the re-read of group members overlaps with the
    verification of subsequent shards (it is pushed onto the same
    pipeline) rather than serializing.

Format on disk (directory per checkpoint):

  manifest.json             treedef, leaf records, shard + parity tables
  shard_00000.bin ...       packed leaf bytes
  parity_00000.bin ...      XOR of each parity group (zero-padded members)
"""

from __future__ import annotations

import dataclasses
import json
import queue
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"


@dataclasses.dataclass
class RestoreStats:
    """Observability for the restore pipeline (the paper-tie-in metrics)."""

    read_s: float = 0.0            # wall time the reader thread spent in IO
    verify_s: float = 0.0          # CRC + deserialize time (overlapped)
    wall_s: float = 0.0            # end-to-end restore wall time
    n_shards: int = 0
    n_reconstructed: int = 0       # parity reconstructions ("ECC corrections")
    n_failed: int = 0              # unrecoverable (should be 0)
    pipelined: bool = True


def _leaf_key(path) -> str:
    out = []
    for p in path:
        k = getattr(p, "key", None)
        out.append(str(k) if k is not None else str(getattr(p, "idx", "?")))
    return "/".join(out)


def save(
    dirpath: str | Path,
    tree: Any,
    *,
    shard_bytes: int = 1 << 24,
    parity_group: int = 4,
) -> Path:
    """Serialize a pytree of arrays into CRC'd shards + XOR parity."""
    dirpath = Path(dirpath)
    dirpath.mkdir(parents=True, exist_ok=True)

    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree)[0]
    treedef = jax.tree_util.tree_structure(tree)

    # Pack leaves into shards (greedy, order-preserving).
    records: List[Dict] = []
    shards: List[bytearray] = [bytearray()]
    for path, leaf in leaves_with_path:
        arr = np.asarray(leaf)
        data = arr.tobytes()
        if len(shards[-1]) + len(data) > shard_bytes and len(shards[-1]) > 0:
            shards.append(bytearray())
        sid = len(shards) - 1
        records.append(
            {
                "key": _leaf_key(path),
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "shard": sid,
                "offset": len(shards[sid]),
                "size": len(data),
            }
        )
        shards[sid].extend(data)

    shard_meta = []
    for i, blob in enumerate(shards):
        f = dirpath / f"shard_{i:05d}.bin"
        f.write_bytes(bytes(blob))
        shard_meta.append(
            {"file": f.name, "size": len(blob), "crc32": zlib.crc32(bytes(blob))}
        )

    # XOR parity per group of up to ``parity_group`` shards.
    parity_meta = []
    for g0 in range(0, len(shards), parity_group):
        members = list(range(g0, min(g0 + parity_group, len(shards))))
        size = max(len(shards[m]) for m in members)
        acc = np.zeros(size, np.uint8)
        for m in members:
            buf = np.frombuffer(bytes(shards[m]), np.uint8)
            acc[: len(buf)] ^= buf
        f = dirpath / f"parity_{g0 // parity_group:05d}.bin"
        f.write_bytes(acc.tobytes())
        parity_meta.append(
            {"file": f.name, "members": members, "size": size,
             "crc32": zlib.crc32(acc.tobytes())}
        )

    manifest = {
        "treedef": jax.tree_util.tree_structure(tree).serialize_using_proto().hex()
        if hasattr(treedef, "serialize_using_proto") else None,
        "leaves": records,
        "shards": shard_meta,
        "parity": parity_meta,
        "parity_group": parity_group,
    }
    (dirpath / MANIFEST).write_text(json.dumps(manifest))
    return dirpath


def _read_shard(dirpath: Path, meta: Dict) -> Optional[bytes]:
    f = dirpath / meta["file"]
    if not f.exists():
        return None
    data = f.read_bytes()
    return data


def _verify(meta: Dict, data: Optional[bytes]) -> bool:
    return (
        data is not None
        and len(data) == meta["size"]
        and zlib.crc32(data) == meta["crc32"]
    )


def _reconstruct(
    dirpath: Path, manifest: Dict, sid: int, have: Dict[int, bytes]
) -> Optional[bytes]:
    """XOR-reconstruct shard ``sid`` from its parity group."""
    group = next(
        (g for g in manifest["parity"] if sid in g["members"]), None
    )
    if group is None:
        return None
    pfile = dirpath / group["file"]
    if not pfile.exists():
        return None
    acc = np.frombuffer(pfile.read_bytes(), np.uint8).copy()
    for m in group["members"]:
        if m == sid:
            continue
        data = have.get(m)
        if data is None:
            data = _read_shard(dirpath, manifest["shards"][m])
        if data is None or not _verify(manifest["shards"][m], data):
            return None  # two failures in one group exceed the margin
        buf = np.frombuffer(data, np.uint8)
        acc[: len(buf)] ^= buf
    out = bytes(acc[: manifest["shards"][sid]["size"]])
    return out if _verify(manifest["shards"][sid], out) else None


def restore(
    dirpath: str | Path,
    tree_like: Any,
    *,
    pipelined: bool = True,
    queue_depth: int = 2,
) -> Tuple[Any, RestoreStats]:
    """Restore a pytree saved by :func:`save` into ``tree_like``'s structure.

    ``pipelined=False`` serializes read -> verify per shard (the "regular
    read-retry" baseline) so the PR² win is measurable in the example.
    """
    dirpath = Path(dirpath)
    manifest = json.loads((dirpath / MANIFEST).read_text())
    stats = RestoreStats(pipelined=pipelined, n_shards=len(manifest["shards"]))
    t_wall = time.perf_counter()

    blobs: Dict[int, bytes] = {}

    if pipelined:
        q: "queue.Queue" = queue.Queue(maxsize=queue_depth)

        def reader():
            t = 0.0
            for sid, meta in enumerate(manifest["shards"]):
                t0 = time.perf_counter()
                data = _read_shard(dirpath, meta)
                t += time.perf_counter() - t0
                q.put((sid, data))
            q.put((None, t))

        th = threading.Thread(target=reader, daemon=True)
        th.start()
        while True:
            sid, data = q.get()
            if sid is None:
                stats.read_s = data
                break
            t0 = time.perf_counter()
            if not _verify(manifest["shards"][sid], data):
                data = _reconstruct(dirpath, manifest, sid, blobs)
                if data is None:
                    stats.n_failed += 1
                else:
                    stats.n_reconstructed += 1
            if data is not None:
                blobs[sid] = data
            stats.verify_s += time.perf_counter() - t0
        th.join()
    else:
        for sid, meta in enumerate(manifest["shards"]):
            t0 = time.perf_counter()
            data = _read_shard(dirpath, meta)
            stats.read_s += time.perf_counter() - t0
            t0 = time.perf_counter()
            if not _verify(meta, data):
                data = _reconstruct(dirpath, manifest, sid, blobs)
                if data is None:
                    stats.n_failed += 1
                else:
                    stats.n_reconstructed += 1
            if data is not None:
                blobs[sid] = data
            stats.verify_s += time.perf_counter() - t0

    if stats.n_failed:
        raise IOError(
            f"unrecoverable checkpoint: {stats.n_failed} shard(s) beyond "
            f"parity margin in {dirpath}"
        )

    # Reassemble leaves in the reference tree's structure.
    ref_paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    treedef = jax.tree_util.tree_structure(tree_like)
    by_key = {r["key"]: r for r in manifest["leaves"]}
    leaves = []
    for path, like in ref_paths:
        r = by_key[_leaf_key(path)]
        raw = blobs[r["shard"]][r["offset"] : r["offset"] + r["size"]]
        arr = np.frombuffer(raw, dtype=np.dtype(r["dtype"])).reshape(r["shape"])
        leaves.append(arr)
    stats.wall_s = time.perf_counter() - t_wall
    return jax.tree_util.tree_unflatten(treedef, leaves), stats


# ---------------------------------------------------------------------------
# Failure injection (tests + the fault-tolerance example).
# ---------------------------------------------------------------------------


def corrupt_shard(dirpath: str | Path, sid: int, nbytes: int = 64) -> None:
    """Flip bytes mid-shard (silent corruption -> CRC catches it)."""
    f = Path(dirpath) / f"shard_{sid:05d}.bin"
    data = bytearray(f.read_bytes())
    mid = max(len(data) // 2 - nbytes // 2, 0)
    for i in range(mid, min(mid + nbytes, len(data))):
        data[i] ^= 0xFF
    f.write_bytes(bytes(data))


def delete_shard(dirpath: str | Path, sid: int) -> None:
    """Simulate a lost node's shard file."""
    (Path(dirpath) / f"shard_{sid:05d}.bin").unlink()
