"""int8 gradient all-reduce compression with error feedback.

The collective roofline term of the train cells is dominated by gradient
reductions; quantizing the reduced tensors to int8 cuts that traffic 4x at
the cost of quantization noise, which the error-feedback accumulator
(Seide et al.; 1-bit SGD lineage) re-injects next step so the *expected*
gradient stays unbiased and SGD convergence is preserved.

Usage inside a train step::

    grads, ef_state = compress_grads(grads, ef_state)     # pre-reduce
    # psum / sharded mean happens on the int8-scaled representation via
    # the float wire format below (XLA collectives do not take int8 +
    # per-tensor scales natively, so we quantize, reduce the dequantized
    # bf16 tensor, and charge 1/4 traffic in the roofline accounting —
    # on real TPU the int8 all-reduce is a documented runtime feature).

The module also provides the pure (de)quantizers the tests property-check
(error feedback drives the *cumulative* compression error to zero).
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_feedback(grads: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def compress_grads(
    grads: Any, ef: Optional[Any] = None
) -> Tuple[Any, Any]:
    """Quantize a gradient pytree with error feedback.

    Returns (decompressed grads ready for the all-reduce wire, new ef).
    The returned grads carry only int8-representable information; the
    residual lives in ``ef`` and is added back before the *next* step's
    quantization.
    """
    if ef is None:
        ef = init_error_feedback(grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return deq.astype(g.dtype), corrected - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_g, new_e


def compressed_wire_bytes(grads: Any) -> int:
    """Roofline accounting: bytes on the wire with int8 compression."""
    return sum(x.size for x in jax.tree.leaves(grads))  # 1 B/element


def uncompressed_wire_bytes(grads: Any) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(grads))
