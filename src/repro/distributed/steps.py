"""Step builders: jitted train/prefill/decode with explicit shardings.

These produce the exact jit-wrapped functions the launcher, the dry-run,
and the examples use, with in/out shardings derived from the logical
rules in repro.distributed.sharding.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as SH
from repro.models.api import Model, build_model, input_specs
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def batch_shardings(cfg: ModelConfig, mesh: Mesh, specs: Dict) -> Dict:
    """Sharding for a batch dict (tokens/labels/frontends/decode inputs)."""
    rules = SH.rules_for_mesh(mesh)
    b = rules["batch"]
    b_size = 1
    for ax in b:
        b_size *= mesh.shape[ax]

    def spec_for(name, leaf):
        if name == "pos":
            return P()
        # batch=1 cells (long_500k) cannot shard the batch dim: replicate.
        bb = b if leaf.shape[0] % b_size == 0 else None
        if name in ("tokens", "labels", "token"):
            return P(bb, None)
        if name in ("patches", "audio_embed"):
            return P(bb, None, None)
        if name == "pos":
            return P()
        raise KeyError(name)

    out = {}
    for name, leaf in specs.items():
        if name == "cache":
            out["cache"] = cache_shardings(cfg, mesh, leaf)
        else:
            out[name] = _ns(mesh, spec_for(name, leaf))
    return out


def cache_shardings(cfg: ModelConfig, mesh: Mesh, cache_spec) -> Any:
    """Decode-cache shardings: batch over (pod, data); the largest
    model-divisible non-batch dim over "model" (heads when divisible,
    else the KV sequence dim — the storage-layout rule from DESIGN.md)."""
    rules = SH.rules_for_mesh(mesh)
    b_axes = rules["batch"]
    batch_size = 1
    for ax in b_axes:
        batch_size *= mesh.shape[ax]
    model_size = mesh.shape["model"]

    def leaf_spec(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
        stacked = "units" in keys
        n_lead = 1 if stacked else 0
        ndim = leaf.ndim
        parts = [None] * ndim
        if ndim > n_lead and leaf.shape[n_lead] % batch_size == 0:
            parts[n_lead] = b_axes  # batch dim right after the unit dim
        # pick the largest dim after batch divisible by the model axis
        cand = [
            (leaf.shape[i], i)
            for i in range(n_lead + 1, ndim)
            if leaf.shape[i] % model_size == 0 and leaf.shape[i] >= model_size
        ]
        if cand:
            _, i = max(cand)
            parts[i] = ("model",)
        return _ns(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(leaf_spec, cache_spec)


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def make_train_state_specs(cfg: ModelConfig, mesh: Mesh):
    model = build_model(cfg)
    params_spec = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_shard = SH.named_shardings(params_spec, mesh)
    opt_cfg = AdamWConfig(moment_dtype=cfg.moment_dtype)
    opt_spec = jax.eval_shape(lambda p: init_opt_state(p, opt_cfg), params_spec)
    # moments share the param sharding; step is replicated
    m_shard = {
        "m": jax.tree.map(lambda s: s, p_shard),
        "v": jax.tree.map(lambda s: s, p_shard),
        "step": _ns(mesh, P()),
    }
    state_spec = {"params": params_spec, "opt": opt_spec}
    state_shard = {"params": p_shard, "opt": m_shard}
    return state_spec, state_shard


def make_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    opt: Optional[AdamWConfig] = None,
    batch_shard=None,
):
    """-> (step_fn, state_shardings); step_fn(state, batch) -> (state, metrics),
    jitted with donated state."""
    model = build_model(cfg)
    opt = opt or AdamWConfig(moment_dtype=cfg.moment_dtype)

    def step(state, batch):
        with SH.use_mesh(mesh):
            loss, grads = jax.value_and_grad(model.train_loss)(state["params"], batch)
            new_params, new_opt, metrics = adamw_update(
                grads, state["opt"], state["params"], opt
            )
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    _, state_shard = make_train_state_specs(cfg, mesh)
    jitted = jax.jit(
        step,
        in_shardings=(state_shard, batch_shard),
        out_shardings=(state_shard, None),
        donate_argnums=(0,),
    )
    return jitted, state_shard


def train_input_shardings(cfg: ModelConfig, mesh: Mesh, shape: ShapeConfig):
    specs = input_specs(cfg, shape)
    return specs, batch_shardings(cfg, mesh, specs)


# ---------------------------------------------------------------------------
# Serve
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, mesh: Mesh):
    model = build_model(cfg)
    params_spec = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_shard = SH.named_shardings(params_spec, mesh)

    def fn(params, batch):
        with SH.use_mesh(mesh):
            return model.prefill(params, batch)

    return fn, p_shard


def make_decode_step(cfg: ModelConfig, mesh: Mesh):
    model = build_model(cfg)
    params_spec = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_shard = SH.named_shardings(params_spec, mesh)

    def fn(params, batch):
        with SH.use_mesh(mesh):
            return model.decode_step(params, batch)

    return fn, p_shard


# ---------------------------------------------------------------------------
# One-stop cell builder for the dry-run.
# ---------------------------------------------------------------------------


def _logits_sharding(mesh: Mesh, global_batch: int):
    """(B, T, V) logits: batch axes only when B divides; vocab over model."""
    rules = SH.rules_for_mesh(mesh)
    b = rules["batch"]
    b_size = 1
    for ax in b:
        b_size *= mesh.shape[ax]
    return _ns(mesh, P(b if global_batch % b_size == 0 else None, None, None))


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh):
    """-> (jitted_fn, example_args_specs) for one (arch x shape x mesh)."""
    model = build_model(cfg)
    specs = input_specs(cfg, shape)
    b_shard = batch_shardings(cfg, mesh, specs)

    if shape.kind == "train":
        step, state_shard = make_train_step(cfg, mesh, batch_shard=b_shard)
        state_spec, _ = make_train_state_specs(cfg, mesh)
        return step, (state_spec, specs), (state_shard, b_shard)

    if shape.kind == "prefill":
        fn, p_shard = make_prefill_step(cfg, mesh)
        params_spec = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
        # serve params in bf16 (production serving convention)
        params_spec = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape, jnp.bfloat16 if l.dtype == jnp.float32 else l.dtype
            ),
            params_spec,
        )
        logits_shard = _logits_sharding(mesh, shape.global_batch)
        cache_sp = jax.eval_shape(fn, params_spec, specs)[1]
        out_shard = (logits_shard, cache_shardings(cfg, mesh, cache_sp))
        jitted = jax.jit(fn, in_shardings=(p_shard, b_shard), out_shardings=out_shard)
        return jitted, (params_spec, specs), (p_shard, b_shard)

    # decode
    fn, p_shard = make_decode_step(cfg, mesh)
    params_spec = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    params_spec = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            l.shape, jnp.bfloat16 if l.dtype == jnp.float32 else l.dtype
        ),
        params_spec,
    )
    logits_shard = _logits_sharding(mesh, shape.global_batch)
    out_shard = (logits_shard, b_shard["cache"])
    jitted = jax.jit(
        fn,
        in_shardings=(p_shard, b_shard),
        out_shardings=out_shard,
        donate_argnums=(1,),
    )
    return jitted, (params_spec, specs), (p_shard, b_shard)
