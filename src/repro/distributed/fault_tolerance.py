"""Fault-tolerance control plane: heartbeats, stragglers, restart policy.

Single-controller posture (the JAX multi-controller runtime handles SPMD
execution; this module is the *policy* layer a production launcher runs
on the coordinator):

  * ``HeartbeatMonitor`` — workers report per-step heartbeats; a worker
    whose heartbeat age exceeds ``dead_after_s`` is declared dead (node
    failure -> restart from checkpoint on a shrunken mesh, see
    elastic.py); one whose *step time* exceeds ``straggler_factor`` times
    the fleet median is flagged a straggler.
  * ``StragglerMitigator`` — deadline-based re-dispatch of input shards:
    a straggler's next input shard is speculatively duplicated onto the
    fastest healthy worker (work stealing); whichever copy finishes first
    wins.  This is the PR² discipline at the fleet level: the speculative
    duplicate overlaps the slow path instead of waiting for it to fail.
  * ``RestartPolicy`` — decides between in-place retry (transient), mesh
    shrink (dead node), and abort (too many failures in a window).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class WorkerState:
    worker_id: int
    last_heartbeat: float
    last_step: int = 0
    step_times: deque = dataclasses.field(
        default_factory=lambda: deque(maxlen=32)
    )
    alive: bool = True

    def mean_step_time(self) -> float:
        return float(np.mean(self.step_times)) if self.step_times else 0.0


class HeartbeatMonitor:
    def __init__(self, n_workers: int, dead_after_s: float = 60.0,
                 straggler_factor: float = 2.0, clock=time.monotonic):
        self.clock = clock
        self.dead_after_s = dead_after_s
        self.straggler_factor = straggler_factor
        now = self.clock()
        self.workers: Dict[int, WorkerState] = {
            i: WorkerState(i, now) for i in range(n_workers)
        }

    def beat(self, worker_id: int, step: int, step_time_s: float):
        w = self.workers[worker_id]
        w.last_heartbeat = self.clock()
        w.last_step = step
        w.step_times.append(step_time_s)
        w.alive = True

    def dead_workers(self) -> List[int]:
        now = self.clock()
        out = []
        for w in self.workers.values():
            if w.alive and now - w.last_heartbeat > self.dead_after_s:
                w.alive = False
            if not w.alive:
                out.append(w.worker_id)
        return out

    def stragglers(self) -> List[int]:
        times = [
            w.mean_step_time() for w in self.workers.values()
            if w.alive and w.step_times
        ]
        if len(times) < 2:
            return []
        median = float(np.median(times))
        if median <= 0:
            return []
        return [
            w.worker_id
            for w in self.workers.values()
            if w.alive and w.step_times
            and w.mean_step_time() > self.straggler_factor * median
        ]


class StragglerMitigator:
    """Deadline-based speculative re-dispatch of input shards."""

    def __init__(self, monitor: HeartbeatMonitor):
        self.monitor = monitor
        self.duplicated: Dict[int, int] = {}   # shard -> backup worker
        self.n_duplicates = 0

    def plan(self, step: int, shard_owner: Dict[int, int]) -> Dict[int, int]:
        """Given shard->owner, return shard->backup for straggler owners."""
        stragglers = set(self.monitor.stragglers())
        if not stragglers:
            return {}
        healthy = sorted(
            (
                w for w in self.monitor.workers.values()
                if w.alive and w.worker_id not in stragglers
            ),
            key=lambda w: w.mean_step_time() or float("inf"),
        )
        if not healthy:
            return {}
        plan = {}
        hi = 0
        for shard, owner in shard_owner.items():
            if owner in stragglers:
                plan[shard] = healthy[hi % len(healthy)].worker_id
                hi += 1
        self.duplicated.update(plan)
        self.n_duplicates += len(plan)
        return plan


@dataclasses.dataclass
class RestartDecision:
    action: str          # "retry" | "shrink" | "abort"
    dead_workers: Tuple[int, ...] = ()
    reason: str = ""


class RestartPolicy:
    def __init__(self, max_failures_per_hour: int = 8):
        self.max_per_hour = max_failures_per_hour
        self.failures: deque = deque()

    def on_failure(
        self, monitor: HeartbeatMonitor, transient: bool, now=None
    ) -> RestartDecision:
        now = time.monotonic() if now is None else now
        self.failures.append(now)
        while self.failures and now - self.failures[0] > 3600.0:
            self.failures.popleft()
        if len(self.failures) > self.max_per_hour:
            return RestartDecision("abort", reason="failure budget exhausted")
        dead = tuple(monitor.dead_workers())
        if transient and not dead:
            return RestartDecision("retry", reason="transient, all alive")
        return RestartDecision(
            "shrink", dead_workers=dead,
            reason=f"{len(dead)} dead worker(s): restart on shrunken mesh",
        )
