"""Distribution: sharding rules, step builders, fault tolerance, elasticity."""

from repro.distributed.compress import (
    compress_grads,
    compressed_wire_bytes,
    init_error_feedback,
    uncompressed_wire_bytes,
)
from repro.distributed.elastic import ElasticPlan, build_mesh_from_plan, plan_mesh
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    RestartPolicy,
    StragglerMitigator,
)

__all__ = [
    "compress_grads", "init_error_feedback",
    "compressed_wire_bytes", "uncompressed_wire_bytes",
    "ElasticPlan", "plan_mesh", "build_mesh_from_plan",
    "HeartbeatMonitor", "StragglerMitigator", "RestartPolicy",
]
