"""Elastic re-mesh: resume a checkpoint on a degraded (or grown) fleet.

The sharding layer (repro.distributed.sharding) is *logical*: parameter
and activation placements are derived from axis rules + a mesh, never
hard-coded.  Elasticity is therefore a plan, not a migration: given the
new device count, pick the best (data, model) factorization, rebuild the
NamedShardings from the same rules, and device_put the host-restored
checkpoint (checkpoint/ restores to host numpy precisely so the target
mesh can differ from the source mesh).

Constraints honoured by ``plan_mesh``:
  * ``model`` axis preserved if possible (TP degree changes re-partition
    every weight, which is fine but costs a full reshard; keeping it
    avoids that) — unless the new world size forces otherwise;
  * ``data`` axis takes the remaining factor; global batch must divide
    the new data size for deterministic replay, otherwise the plan
    reports the required gradient-accumulation factor.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    old_shape: Tuple[int, ...]
    new_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    tp_preserved: bool
    grad_accum_factor: int
    note: str

    def describe(self) -> str:
        return (
            f"{'x'.join(map(str, self.old_shape))} -> "
            f"{'x'.join(map(str, self.new_shape))} ({'.'.join(self.axis_names)}); "
            f"tp_preserved={self.tp_preserved} "
            f"grad_accum x{self.grad_accum_factor}; {self.note}"
        )


def _largest_divisor_leq(n: int, cap: int) -> int:
    for d in range(min(cap, n), 0, -1):
        if n % d == 0:
            return d
    return 1


def plan_mesh(
    n_devices: int,
    old_mesh_shape: Tuple[int, ...] = (16, 16),
    axis_names: Tuple[str, ...] = ("data", "model"),
    global_batch: int = 256,
) -> ElasticPlan:
    """Choose (data, model) for the new world size."""
    old_model = old_mesh_shape[-1]
    if n_devices % old_model == 0:
        model = old_model
        tp_preserved = True
        note = "model axis kept; only data-parallel width changed"
    else:
        model = _largest_divisor_leq(n_devices, old_model)
        tp_preserved = False
        note = "model axis re-factored (full weight reshard on restore)"
    data = n_devices // model
    accum = 1
    if global_batch % data != 0:
        # per-replica batch must be integral: accumulate
        per = max(global_batch // data, 1)
        accum = -(-global_batch // (per * data))
        note += f"; batch {global_batch} !% data {data}"
    return ElasticPlan(
        old_shape=tuple(old_mesh_shape),
        new_shape=(data, model),
        axis_names=tuple(axis_names[-2:]),
        tp_preserved=tp_preserved,
        grad_accum_factor=accum,
        note=note,
    )


def build_mesh_from_plan(plan: ElasticPlan) -> Mesh:
    return jax.make_mesh(plan.new_shape, plan.axis_names)


def reshard_state(state, mesh: Mesh, shardings) -> object:
    """device_put a host-restored state onto the new mesh's shardings."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), state, shardings
    )
