"""Logical-axis sharding: rules, activation constraints, param specs.

Model code annotates *activations* with logical axes via :func:`constrain`
(a no-op outside a mesh context).  Parameter and optimizer-state shardings
are derived from the param-tree paths by :func:`param_specs` — 2-D
FSDP x TP: tensor-parallel over ``model`` along heads/ff/vocab/expert
dims, fully-sharded over ``data`` along a complementary dim, so optimizer
state is ZeRO-sharded across the whole mesh by construction.

The ``pod`` axis (multi-pod mesh) extends data parallelism: batch shards
over ("pod", "data") and FSDP dims over ("pod", "data") likewise.
"""

from __future__ import annotations

import contextlib
import re
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules():
    return getattr(_state, "rules", None)


#: logical axis name -> mesh axes (single-pod). The multi-pod mesh extends
#: "data"-mapped axes with the "pod" axis.
DEFAULT_RULES = {
    "batch": ("data",),
    "seq": None,
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "fsdp": ("data",),       # weight dim sharded over the data axis
    "kv_seq": ("model",),    # KV-cache seq dim when heads cannot shard
    #: inter-unit activation carry: sequence sharded over the model axis
    #: (Megatron sequence-parallel style) so remat saves are 1/model-size.
    "act_seq": ("model",),
}


def rules_for_mesh(mesh: Mesh) -> dict:
    rules = dict(DEFAULT_RULES)
    if "pod" in mesh.axis_names:
        rules["batch"] = ("pod", "data")
        rules["fsdp"] = ("pod", "data")
    return rules


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def current_rules() -> Optional[dict]:
    return _rules()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate constraint emission for model code inside a mesh context."""
    prev = getattr(_state, "rules", None), getattr(_state, "mesh", None)
    _state.rules = rules or (rules_for_mesh(mesh) if mesh is not None else None)
    _state.mesh = mesh
    try:
        yield
    finally:
        _state.rules, _state.mesh = prev


def logical_to_spec(axes: Tuple[Optional[str], ...], rules=None) -> P:
    rules = rules or _rules() or DEFAULT_RULES
    parts = []
    for a in axes:
        m = rules.get(a) if a else None
        parts.append(m if m else None)
    return P(*parts)


def constrain(x: jax.Array, axes: Tuple[Optional[str], ...]) -> jax.Array:
    """with_sharding_constraint by logical axes; no-op outside use_mesh."""
    rules = _rules()
    mesh = getattr(_state, "mesh", None)
    if rules is None or mesh is None:
        return x
    # Drop constraints whose dims don't divide evenly (e.g. 8 kv heads on a
    # 16-way model axis) — XLA would reject them; propagation handles it.
    # Also drop a mesh axis already used by an earlier dim (duplicates are
    # illegal in a PartitionSpec).
    spec_parts = []
    used = set()
    for dim, a in enumerate(axes):
        m = rules.get(a) if a else None
        if m:
            m_t = m if isinstance(m, tuple) else (m,)
            size = 1
            for ax in m_t:
                size *= mesh.shape[ax]
            if x.shape[dim] % size == 0 and not (used & set(m_t)):
                spec_parts.append(m)
                used.update(m_t)
            else:
                spec_parts.append(None)
        else:
            spec_parts.append(None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec_parts))
    )


# ---------------------------------------------------------------------------
# Parameter sharding by tree path.
# ---------------------------------------------------------------------------

#: (path regex, logical axes per dim, where dim order matches the param's).
#: Leading stacked-unit dims (from scan-over-layers) are handled separately.
_PARAM_RULES = (
    # attention projections
    (r"\bwq$", ("fsdp", "heads", None)),          # (d, H, hd)
    (r"\bwk$", ("fsdp", "kv_heads", None)),
    (r"\bwv$", ("fsdp", "kv_heads", None)),
    (r"\bwo$", ("heads", None, "fsdp")),          # (H, hd, d)
    # dense mlp
    (r"\bwi$", ("fsdp", "ff")),                   # (d, ff)
    (r"\bwg$", ("fsdp", "ff")),
    (r"\bwd$", ("ff", "fsdp")),                   # (ff, d)
    # moe
    (r"\brouter$", ("fsdp", None)),               # (d, E) router replicated-ish
    (r"\bmoe_wi$", ("experts", "fsdp", None)),    # (E, d, ff)
    (r"\bmoe_wg$", ("experts", "fsdp", None)),
    (r"\bmoe_wd$", ("experts", None, "fsdp")),    # (E, ff, d)
    # embeddings / head
    (r"\bembed$", ("vocab", "fsdp")),             # (V, d)
    (r"\bunembed$", ("fsdp", "vocab")),           # (d, V)
    (r"\bpos_embed$", (None, "fsdp")),
    # ssm
    (r"\bin_proj$", ("fsdp", "ff")),              # (d, inner+...)
    (r"\bout_proj$", ("ff", "fsdp")),
    (r"\bconv_w$", (None, "ff")),                 # (d_conv, channels)
    # rglru
    (r"\bw_gate$", ("fsdp", "ff")),
    (r"\bw_rec$", ("fsdp", "ff")),
    (r"\bw_out$", ("ff", "fsdp")),
    (r"\ba_gate$", ("ff",)),
    (r"\bx_gate$", ("ff",)),
)


def _spec_for_path(path: str, ndim: int, n_stacked: int, rules) -> P:
    for pat, axes in _PARAM_RULES:
        if re.search(pat, path):
            if len(axes) + n_stacked != ndim:
                break  # fall through to replicated
            parts = [None] * n_stacked + [
                (rules.get(a) if a else None) or None for a in axes
            ]
            return P(*parts)
    return P(*([None] * ndim))


def param_specs(params, mesh: Mesh):
    """PartitionSpec pytree for a parameter tree (stacked units aware)."""
    rules = rules_for_mesh(mesh)

    def spec(path, leaf):
        keys = [getattr(p, "key", getattr(p, "idx", None)) for p in path]
        path_s = "/".join(str(k) for k in keys)
        # Stacked unit dim: params under .../units/... carry a leading U dim.
        n_stacked = 1 if "units" in path_s.split("/") else 0
        s = _spec_for_path(path_s, leaf.ndim, n_stacked, rules)
        # Validate divisibility + axis uniqueness; drop offending axes.
        parts = []
        used = set()
        for dim, m in enumerate(tuple(s) + (None,) * (leaf.ndim - len(tuple(s)))):
            if m:
                m_t = m if isinstance(m, tuple) else (m,)
                size = 1
                for ax in m_t:
                    size *= mesh.shape[ax]
                if leaf.shape[dim] % size == 0 and not (used & set(m_t)):
                    parts.append(m)
                    used.update(m_t)
                else:
                    parts.append(None)
            else:
                parts.append(None)
        return P(*parts)

    return jax.tree_util.tree_map_with_path(spec, params)


def named_shardings(params, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(params, mesh)
    )
