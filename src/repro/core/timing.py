"""Latency composition for regular, PR²-pipelined, and AR²-scaled read-retry.

A flash read with k retry steps executes k+1 *read attempts* (the initial
read plus k retries).  Each attempt is a three-stage operation:

    sense (tR, die-local)  ->  transfer (tDMA, channel)  ->  decode (tECC)

Regular read-retry serializes attempts: the controller only issues retry
i+1 after decode i fails.  PR² exploits the NAND CACHE READ command: the
die has a page register *and* a cache register, so sensing of attempt i+1
proceeds while attempt i's data streams out of the cache register and
decodes.  The steady-state per-attempt cost collapses from
(tR + tDMA + tECC) to max(tR, tDMA + tECC) = tR for realistic timings —
the paper's 28.5% per-step reduction.

AR² scales tR itself by the characterized safe factor for the block's
operating condition (s = 0.75 worst-case), on *every* attempt: early
attempts fail regardless, and the final attempt's ECC margin absorbs the
extra sensing noise.

These closed forms are used by unit tests and napkin math; the SSD
simulator (repro.flashsim) re-derives the same schedules event-by-event
with channel/die/ECC-engine contention on top.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import constants as C


@dataclasses.dataclass(frozen=True)
class TimingParams:
    tr_us: dict = dataclasses.field(default_factory=lambda: dict(C.TR_US))
    tdma_us: float = C.TDMA_US
    tecc_us: float = C.TECC_US
    tprog_us: float = C.TPROG_US

    def tr(self, page_type: str, tr_scale: float = 1.0) -> float:
        return self.tr_us[page_type] * tr_scale

    @property
    def transfer_decode_us(self) -> float:
        return self.tdma_us + self.tecc_us


DEFAULT_TIMING = TimingParams()


def sequential_read_latency(
    n_attempts: int | np.ndarray,
    page_type: str = "csb",
    tr_scale: float = 1.0,
    timing: TimingParams = DEFAULT_TIMING,
) -> np.ndarray:
    """Regular read-retry: attempts fully serialized."""
    n = np.asarray(n_attempts, np.float64)
    per = timing.tr(page_type, tr_scale) + timing.transfer_decode_us
    return n * per


def pipelined_read_latency(
    n_attempts: int | np.ndarray,
    page_type: str = "csb",
    tr_scale: float = 1.0,
    timing: TimingParams = DEFAULT_TIMING,
) -> np.ndarray:
    """PR²: CACHE READ overlaps sense i+1 with transfer+decode of attempt i.

    latency = tR_0 + sum_{i=1..n-1} max(tR_i, tDMA+tECC) + tDMA + tECC.
    """
    n = np.asarray(n_attempts, np.float64)
    tr = timing.tr(page_type, tr_scale)
    steady = max(tr, timing.transfer_decode_us)
    return tr + np.maximum(n - 1, 0) * steady + timing.transfer_decode_us


def read_latency(
    n_attempts: int | np.ndarray,
    mechanism: str,
    page_type: str = "csb",
    tr_scale: float = 0.75,
    timing: TimingParams = DEFAULT_TIMING,
) -> np.ndarray:
    """Closed-form read latency for each mechanism.

    ``tr_scale`` is only applied by the AR² variants; pass the
    characterization-table value for the block's operating condition.
    """
    if mechanism in ("baseline", "sota"):
        return sequential_read_latency(n_attempts, page_type, 1.0, timing)
    if mechanism == "pr2":
        return pipelined_read_latency(n_attempts, page_type, 1.0, timing)
    if mechanism == "ar2":
        return sequential_read_latency(n_attempts, page_type, tr_scale, timing)
    if mechanism in ("pr2ar2", "pr2+ar2", "sota+pr2ar2"):
        return pipelined_read_latency(n_attempts, page_type, tr_scale, timing)
    raise ValueError(f"unknown mechanism: {mechanism}")


def per_step_reduction_pr2(timing: TimingParams = DEFAULT_TIMING) -> float:
    """Steady-state per-retry-step latency reduction from PR² alone.

    With the calibrated timings this is the paper's 28.5%: transfer+decode
    leave the critical path, so a step costs tR instead of tR+tDMA+tECC.
    """
    tr_avg = float(np.mean(list(timing.tr_us.values())))
    full = tr_avg + timing.transfer_decode_us
    return timing.transfer_decode_us / full


def die_busy_us(
    n_attempts: int,
    mechanism: str,
    page_type: str = "csb",
    tr_scale: float = 0.75,
    timing: TimingParams = DEFAULT_TIMING,
) -> float:
    """Time the die itself is occupied (for simulator contention modeling).

    Under PR² the die frees once the final sense lands in the cache
    register; transfer/decode of the last attempt proceed off-die.  One
    speculative extra sense may be in flight when decode succeeds — the
    simulator charges it to die occupancy (not to the read's response time).
    """
    s = tr_scale if mechanism in ("ar2", "pr2ar2", "pr2+ar2", "sota+pr2ar2") else 1.0
    tr = timing.tr(page_type, s)
    if mechanism in ("baseline", "sota", "ar2"):
        return n_attempts * tr  # transfer happens from cache register
    # PR² variants: senses are back-to-back, plus one speculative sense.
    return (n_attempts + 1) * tr
