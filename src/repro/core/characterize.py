"""160-chip characterization harness — the paper's §3 observations.

Reproduces the paper's three characterization results over a population of
simulated chips with process variation (the paper used 160 real 3D TLC
chips; our population is 160 calibrated analytical chips):

  Observation 1: reads frequently need multiple retry steps even at modest
    conditions (mean ~= 4.5 retry steps @ 3-month retention, 0 P/E).
  Observation 2: when read-retry succeeds, the final step has a large
    ECC-capability margin, even at the worst prescribed condition
    (1-year retention, 1.5K P/E cycles).
  Observation 3: the margin buys a safe tR reduction of 25% worst-case —
    the AR² table maps operating condition -> best (smallest safe) tR scale
    without ever increasing the attempt count.

The safe-scale table produced here *is* AR²'s lookup table; the simulator
and the serving/data-path integrations consume it through
:func:`lookup_tr_scale`.
"""

from __future__ import annotations

import dataclasses
import functools
import hashlib
import json
import os
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants as C
from repro.core import ecc as ecc_mod
from repro.core import retry as R
from repro.core import voltage as V
from repro.core.constants import NandParams, DEFAULT_NAND

#: Operating-condition grid used throughout (days, P/E cycles).
RETENTION_GRID_DAYS = (0.0, 7.0, 30.0, 90.0, 180.0, 365.0)
PEC_GRID = (0.0, 500.0, 1000.0, 1500.0)

#: Candidate tR scales for the AR² search (1.0 = full sensing time).
TR_SCALE_GRID = (1.0, 0.95, 0.9, 0.85, 0.8, 0.75, 0.7, 0.65, 0.6)

#: AR² acceptance: the expected attempt count with reduced tR may exceed
#: the full-tR expectation by at most this many attempts (the paper's
#: "without increasing the number of retry steps", enforced statistically
#: per operating condition — an aggressive scale makes tail pages
#: undecodable at every table entry, which blows this budget and rejects
#: the scale).
EXTRA_ATTEMPT_BUDGET = 0.30

#: Never sense faster than this regardless of margin (circuit floor).
TR_SCALE_FLOOR = 0.7


# -- on-disk characterization cache ----------------------------------------
#
# The JAX population characterization costs seconds per (condition, scale)
# cell and is pure in its arguments, so results are also persisted across
# processes.  Benchmark sweeps (simulate_batch, e2e, microbench) then pay
# each characterization once per machine, not once per run.  Disable with
# REPRO_CHAR_CACHE=0; relocate with REPRO_CHAR_CACHE_DIR.

_CHAR_CACHE_VERSION = 1


def _char_cache_dir() -> Optional[str]:
    if os.environ.get("REPRO_CHAR_CACHE", "1") == "0":
        return None
    return os.environ.get("REPRO_CHAR_CACHE_DIR") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro_flashsim"
    )


def _char_cache_path(kind: str, ext: str, **kw) -> Optional[str]:
    d = _char_cache_dir()
    if d is None:
        return None
    blob = repr((_CHAR_CACHE_VERSION, kind, sorted(kw.items())))
    h = hashlib.sha1(blob.encode()).hexdigest()[:24]
    return os.path.join(d, f"{kind}_{h}.{ext}")


def _char_cache_load(path: Optional[str]):
    if path is None or not os.path.exists(path):
        return None
    try:
        if path.endswith(".npy"):
            return np.load(path)
        with open(path) as f:
            return json.load(f)
    except Exception:
        return None  # corrupt/partial entry: fall through to recompute


def _char_cache_store(path: Optional[str], value) -> None:
    if path is None:
        return
    try:
        os.makedirs(os.path.dirname(path), exist_ok=True)
        tmp = f"{path}.{os.getpid()}.tmp"
        if path.endswith(".npy"):
            with open(tmp, "wb") as f:
                np.save(f, value)
        else:
            with open(tmp, "w") as f:
                json.dump(value, f)
        os.replace(tmp, path)
    except Exception:
        pass  # cache is best-effort; never fail the computation


@dataclasses.dataclass(frozen=True)
class ConditionStats:
    retention_days: float
    pec: float
    mean_retry_steps: float        # attempts - 1, averaged over population
    p99_retry_steps: float
    frac_reads_with_retry: float   # P[attempts > 1]
    mean_margin_final: float       # ECC-capability margin at success entry
    p01_margin_final: float        # 1st-percentile margin (worst pages)
    safe_tr_scale: float           # AR² table entry


def _population_rber(
    key: jax.Array,
    retention_days: float,
    pec: float,
    page_type: str,
    n_chips: int,
    n_blocks: int,
    n_pages: int,
    tr_scale,
    params: NandParams,
) -> jax.Array:
    """(chips, blocks, pages, steps) RBER tensor for one page type."""
    k_var, k_jit = jax.random.split(key)
    rate = V.sample_process_variation(k_var, n_chips, n_blocks, params)
    mu, sigma = V.degraded_distributions(
        jnp.float32(retention_days), jnp.float32(pec), rate, params
    )
    jitter = C.PAGE_JITTER_SIGMA * jax.random.normal(
        k_jit, (n_chips, n_blocks, n_pages, 7)
    )
    return R.rber_per_retry_step(
        mu[..., None, :], sigma[..., None, :], page_type,
        tr_scale, level_jitter=jitter, params=params,
    )


@functools.lru_cache(maxsize=256)
def characterize_condition(
    retention_days: float,
    pec: float,
    n_chips: int = C.N_CHIPS,
    n_blocks: int = 8,
    n_pages: int = 16,
    seed: int = 0,
    params: NandParams = DEFAULT_NAND,
) -> ConditionStats:
    """Full characterization of one operating condition (cached)."""
    cache_path = _char_cache_path(
        "cond", "json",
        retention_days=retention_days, pec=pec, n_chips=n_chips,
        n_blocks=n_blocks, n_pages=n_pages, seed=seed, params=repr(params),
        ecc=repr(ecc_mod.DEFAULT_ECC),
    )
    cached = _char_cache_load(cache_path)
    if cached is not None:
        try:
            return ConditionStats(**cached)
        except TypeError:
            pass  # entry from an older ConditionStats schema: recompute
    cap = ecc_mod.DEFAULT_ECC.rber_cap
    steps_all, margins_all = [], []
    safe_scales = []
    for i, pt in enumerate(C.PAGE_TYPES):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        rber = _population_rber(
            key, retention_days, pec, pt, n_chips, n_blocks, n_pages, 1.0, params
        )
        k = R.first_success_step(rber)                       # (C, B, P)
        rber_final = jnp.take_along_axis(rber, k[..., None], axis=-1)[..., 0]
        margin = ecc_mod.capability_margin(rber_final)
        steps_all.append(np.asarray(k))
        margins_all.append(np.asarray(margin))

        # AR² search: re-run the *whole* retry search at each candidate
        # scale (every attempt senses faster, per the paper).  A scale is
        # admissible if the expected attempt count stays within
        # ATTEMPT_RATIO_BUDGET of full-tR; among admissible scales pick the
        # one minimizing expected pipelined read latency (the paper's
        # "best tR value for a certain operating condition").
        from repro.core import timing as T

        mean_attempts_1 = float(jnp.mean(k + 1))
        best_s, best_lat = 1.0, None
        for s in TR_SCALE_GRID:
            if s < TR_SCALE_FLOOR:
                break
            rber_s = _population_rber(
                key, retention_days, pec, pt, n_chips, n_blocks, n_pages,
                float(s), params,
            )
            k_s = R.first_success_step(rber_s, max_steps=params.max_retry_steps)
            mean_attempts_s = float(jnp.mean(k_s + 1))
            if mean_attempts_s > mean_attempts_1 + EXTRA_ATTEMPT_BUDGET:
                continue
            lat = float(
                np.mean(
                    T.pipelined_read_latency(
                        np.asarray(k_s + 1), page_type=pt, tr_scale=float(s)
                    )
                )
            )
            if best_lat is None or lat < best_lat:
                best_s, best_lat = float(s), lat
        safe_scales.append(best_s)

    steps = np.concatenate([s.ravel() for s in steps_all])
    margins = np.concatenate([m.ravel() for m in margins_all])
    stats = ConditionStats(
        retention_days=retention_days,
        pec=pec,
        mean_retry_steps=float(steps.mean()),
        p99_retry_steps=float(np.percentile(steps, 99)),
        frac_reads_with_retry=float((steps > 0).mean()),
        mean_margin_final=float(margins.mean()),
        p01_margin_final=float(np.percentile(margins, 1)),
        safe_tr_scale=float(max(safe_scales)),  # safe for ALL page types
    )
    _char_cache_store(cache_path, dataclasses.asdict(stats))
    return stats


@functools.lru_cache(maxsize=8)
def safe_tr_table(
    retentions: Tuple[float, ...] = RETENTION_GRID_DAYS,
    pecs: Tuple[float, ...] = PEC_GRID,
    seed: int = 0,
) -> Dict[Tuple[float, float], float]:
    """AR²'s condition -> best-safe-tR-scale lookup table."""
    return {
        (r, p): characterize_condition(r, p, seed=seed).safe_tr_scale
        for r in retentions
        for p in pecs
    }


def snap_pec(pec: float) -> float:
    """Snap a continuous P/E count *up* to the characterization grid.

    Used for per-block condition resolution: a block worn past its bin is
    characterized at the next-worse bin (data only gets older, wear only
    grows), keeping the set of distinct characterizations bounded by
    ``PEC_GRID`` regardless of how many wear levels a trace produces.
    """
    for p in PEC_GRID:
        if p >= pec:
            return float(p)
    return float(PEC_GRID[-1])


def lookup_tr_scale(retention_days: float, pec: float) -> float:
    """AR² table lookup with conservative (next-worse-bin) snapping.

    Characterizes only the snapped bin (cached) — building the full grid
    eagerly costs minutes on CPU and is only needed by the table benchmark.
    """
    # Snap *up* to the next characterized bin when between bins (data only
    # gets older), and likewise for wear — conservative by construction.
    r_candidates = [r for r in RETENTION_GRID_DAYS if r >= retention_days]
    r_bin = r_candidates[0] if r_candidates else RETENTION_GRID_DAYS[-1]
    return characterize_condition(r_bin, snap_pec(pec)).safe_tr_scale


@functools.lru_cache(maxsize=512)
def attempt_histogram(
    retention_days: float,
    pec: float,
    page_type: str = "csb",
    sota: bool = False,
    tr_scale: float = 1.0,
    seed: int = 0,
    max_attempts: int = C.MAX_RETRY_STEPS + 1,
) -> np.ndarray:
    """Empirical attempt-count distribution for one page type (cached).

    The SSD simulator samples per-read attempt counts from this histogram
    (normalized).  ``tr_scale`` < 1 models AR²: the whole retry search runs
    at reduced sensing time, so the occasional extra attempt it induces is
    captured faithfully.  Shape: (max_attempts + 1,); index = attempts.
    """
    cache_path = _char_cache_path(
        "hist", "npy",
        retention_days=retention_days, pec=pec, page_type=page_type,
        sota=sota, tr_scale=tr_scale, seed=seed, max_attempts=max_attempts,
        # The histogram depends on the NAND/ECC model this build uses;
        # key them in so model changes invalidate stale on-disk entries.
        params=repr(DEFAULT_NAND), ecc_cap=C.ECC_RBER_CAP,
    )
    cached = _char_cache_load(cache_path)
    if cached is not None and cached.shape == (max_attempts + 1,):
        return cached
    key = jax.random.fold_in(
        jax.random.PRNGKey(seed + 101), C.PAGE_TYPES.index(page_type)
    )
    attempts, _ = R.attempts_for_population(
        key, retention_days, pec, page_type, sota=sota, tr_scale=tr_scale
    )
    a = np.asarray(attempts).ravel()
    counts = np.bincount(
        np.clip(a, 0, max_attempts), minlength=max_attempts + 1
    ).astype(np.float64)
    hist = counts / counts.sum()
    _char_cache_store(cache_path, hist)
    return hist


@functools.lru_cache(maxsize=512)
def attempt_cdf(
    retention_days: float,
    pec: float,
    page_type: str = "csb",
    sota: bool = False,
    tr_scale: float = 1.0,
    seed: int = 0,
    max_attempts: int = C.MAX_RETRY_STEPS + 1,
) -> np.ndarray:
    """Cumulative form of :func:`attempt_histogram` (cached, read-only).

    The SSD simulator inverse-CDF-samples per-read attempt counts from
    this; caching the cumsum here lets every SSDSim instance of a sweep
    share one table instead of re-accumulating the histogram.
    """
    cdf = np.cumsum(
        attempt_histogram(
            retention_days, pec, page_type=page_type, sota=sota,
            tr_scale=tr_scale, seed=seed, max_attempts=max_attempts,
        )
    )
    cdf.setflags(write=False)
    return cdf
