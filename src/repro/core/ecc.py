"""ECC model: per-codeword correction capability and the capability margin.

The paper's key second observation: when a read-retry *succeeds*, the final
retry step reads the page with near-optimal V_REF, so the observed error
count sits far below the ECC capability — a *large ECC-capability margin*
that AR² spends on reduced sensing time.

We model the reference ECC from the paper ([24]): t = 72 correctable bits
per 1 KiB codeword, 16 codewords per 16 KiB page.  Two evaluation modes:

  * expectation mode (deterministic): a page is correctable iff its RBER is
    at or below t/n.  Used by characterization sweeps (per-page jitter is
    folded into the RBER itself), keeps everything differentiable/jittable.
  * sampling mode: per-codeword error counts drawn Binomial(n, rber) via a
    Gaussian approximation; the page fails if *any* codeword exceeds t.
    Used by the SSD simulator for realistic tail behaviour.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import constants as C


@dataclasses.dataclass(frozen=True)
class ECCConfig:
    t: int = C.ECC_T
    n_bits: int = C.ECC_N_BITS
    codewords_per_page: int = C.CODEWORDS_PER_PAGE

    @property
    def rber_cap(self) -> float:
        """Deterministic capability expressed as an RBER threshold."""
        return self.t / float(self.n_bits)


DEFAULT_ECC = ECCConfig()


def correctable(rber: jax.Array, ecc: ECCConfig = DEFAULT_ECC) -> jax.Array:
    """Expectation-mode correctability: RBER within capability."""
    return rber <= ecc.rber_cap


def capability_margin(rber: jax.Array, ecc: ECCConfig = DEFAULT_ECC) -> jax.Array:
    """Fraction of the ECC capability left unused at the given RBER.

    margin = (t - E[errors per codeword]) / t.  Positive for any read that
    succeeds; the paper's observation is that it is *large* (>> 0) in the
    final retry step even at worst-case operating conditions.
    """
    expected_errors = rber * ecc.n_bits
    return (ecc.t - expected_errors) / ecc.t


def sample_codeword_errors(
    key: jax.Array, rber: jax.Array, ecc: ECCConfig = DEFAULT_ECC
) -> jax.Array:
    """Per-codeword error counts ~ Binomial(n, rber), Gaussian approximation.

    Returns an integer array of shape rber.shape + (codewords_per_page,).
    """
    mean = rber[..., None] * ecc.n_bits
    var = jnp.maximum(mean * (1.0 - rber[..., None]), 1e-9)
    noise = jax.random.normal(key, rber.shape + (ecc.codewords_per_page,))
    return jnp.maximum(jnp.round(mean + jnp.sqrt(var) * noise), 0.0).astype(jnp.int32)


def page_read_fails(
    key: jax.Array, rber: jax.Array, ecc: ECCConfig = DEFAULT_ECC
) -> jax.Array:
    """Sampling-mode page failure: any codeword exceeds t errors."""
    errors = sample_codeword_errors(key, rber, ecc)
    return jnp.any(errors > ecc.t, axis=-1)


def page_fail_probability(rber: jax.Array, ecc: ECCConfig = DEFAULT_ECC) -> jax.Array:
    """Analytic page-failure probability (Gaussian codeword approximation).

    P[page fails] = 1 - P[codeword ok]^16 with
    P[codeword ok] = Phi((t - n*rber) / sqrt(n*rber*(1-rber))).
    """
    mean = rber * ecc.n_bits
    std = jnp.sqrt(jnp.maximum(mean * (1.0 - rber), 1e-12))
    z = (ecc.t - mean) / std
    p_cw_ok = 0.5 * jax.scipy.special.erfc(-z / jnp.sqrt(2.0))
    return 1.0 - p_cw_ok**ecc.codewords_per_page
