"""Calibration sweep (dev tool) — fits the physics constants to the paper.

Targets (all quoted in the extended abstract):
  T1: mean retry steps ~= 4.5 at 3-month retention, 0 P/E (Obs. 1);
  T2: reads succeed at the worst prescribed condition (1 yr, 1.5K P/E)
      with a LARGE final-step ECC margin (Obs. 2);
  T3: safe tR scale at the worst condition = 0.75 (25% reduction, Obs. 3),
      and 0.70 must NOT be safe there (0.75 is the paper's worst-case best);
  T4: fresh blocks (0 d, 0 P/E) read without retries;
  T5: aged SSDs under the SOTA predictor still need >= 3 steps (paper §2).

Run:  PYTHONPATH=src python -m repro.core.calibrate
The chosen constants are baked into core/constants.py; this module exists
so the fit is reproducible and auditable.
"""

from __future__ import annotations

import dataclasses
import itertools
import sys

import jax
import numpy as np

from repro.core import constants as C
from repro.core.constants import NandParams


def evaluate(params: NandParams, verbose: bool = False) -> dict:
    # Imported here so the sweep can rebuild with fresh params.
    from repro.core import retry as R
    from repro.core import ecc as ecc_mod
    from repro.core import voltage as V
    import jax.numpy as jnp

    key = jax.random.PRNGKey(0)
    out = {}

    def steps(ret, pec, sota=False, tr=1.0):
        vals = []
        for i, pt in enumerate(C.PAGE_TYPES):
            a, _ = R.attempts_for_population(
                jax.random.fold_in(key, i), ret, pec, pt, sota=sota,
                tr_scale=tr, params=params, n_blocks=4, n_pages=8,
            )
            vals.append(np.asarray(a) - 1)
        return np.concatenate([v.ravel() for v in vals])

    out["t1_mean_steps_3mo"] = steps(90.0, 0.0).mean()
    worst = steps(365.0, 1500.0)
    out["t2_worst_mean_steps"] = worst.mean()
    out["t2_worst_fail_frac"] = (worst >= params.max_retry_steps).mean()

    # Margin at success entry, worst condition, worst page type tail.
    margins = []
    for i, pt in enumerate(C.PAGE_TYPES):
        _, rf = R.attempts_for_population(
            jax.random.fold_in(key, i), 365.0, 1500.0, pt, params=params,
            n_blocks=4, n_pages=8,
        )
        margins.append(np.asarray(ecc_mod.capability_margin(rf)).ravel())
    margins = np.concatenate(margins)
    out["t2_margin_mean"] = margins.mean()
    out["t2_margin_p01"] = np.percentile(margins, 1)

    # T3: expected-attempt ratio when the whole retry search senses at a
    # reduced tR (the AR² acceptance test), worst condition.
    def attempt_ratio(scale):
        import jax.numpy as jnp
        ratios = []
        for i, pt in enumerate(C.PAGE_TYPES):
            kk = jax.random.fold_in(key, i)
            k_var, k_jit, _ = jax.random.split(kk, 3)
            rate = V.sample_process_variation(k_var, C.N_CHIPS, 4, params)
            mu, sigma = V.degraded_distributions(
                jnp.float32(365.0), jnp.float32(1500.0), rate, params)
            jitter = C.PAGE_JITTER_SIGMA * jax.random.normal(k_jit, (C.N_CHIPS, 4, 8, 7))
            rb1 = R.rber_per_retry_step(mu[..., None, :], sigma[..., None, :], pt,
                                        1.0, jitter, params)
            rbs = R.rber_per_retry_step(mu[..., None, :], sigma[..., None, :], pt,
                                        scale, jitter, params)
            k1 = R.first_success_step(rb1, max_steps=params.max_retry_steps)
            ks = R.first_success_step(rbs, max_steps=params.max_retry_steps)
            ratios.append(float(jnp.mean(ks + 1)) / float(jnp.mean(k1 + 1)))
        return max(ratios)

    out["t3_ratio_075"] = attempt_ratio(0.75)
    out["t3_ratio_070"] = attempt_ratio(0.70)
    out["t4_fresh_steps"] = steps(0.0, 0.0).mean()
    out["t5_sota_aged_steps"] = steps(365.0, 1500.0, sota=True).mean()
    if verbose:
        for k, v in out.items():
            print(f"  {k:24s} = {v:.4f}")
    return out


def score(m: dict) -> float:
    """Lower is better; hard targets weighted heavily."""
    s = 0.0
    s += 4.0 * abs(m["t1_mean_steps_3mo"] - 4.5)
    s += 1000.0 * m["t2_worst_fail_frac"]
    s += 6.0 * abs(m["t2_margin_mean"] - 0.50)          # 'large' margin
    s += 50.0 * max(m["t3_ratio_075"] - 1.016, 0.0) / 0.01   # 0.75 must pass
    s += 50.0 * max(1.016 - m["t3_ratio_070"], 0.0) / 0.01   # 0.70 must fail
    s += 10.0 * m["t4_fresh_steps"]
    s += 1.0 * abs(m["t5_sota_aged_steps"] - 3.5)
    return s


def main():
    sigma0 = (0.30, 0.085, 0.08, 0.08, 0.08, 0.08, 0.08, 0.085)
    best = None
    grid = itertools.product(
        (0.075, 0.082, 0.090, 0.098),       # alpha_r
        (0.0030, 0.0035, 0.0040),           # sigma_r
        (0.16, 0.20, 0.24),                 # sense_eta
        (0.045, 0.05, 0.055),               # retry_step_v
    )
    for alpha_r, sigma_r, eta, step in grid:
        p = NandParams(sigma0=sigma0, alpha_r=alpha_r, sigma_r=sigma_r,
                       sense_eta=eta, sigma_w=0.014, retry_step_v=step)
        m = evaluate(p)
        sc = score(m)
        if best is None or sc < best[0]:
            best = (sc, p, m)
            print(f"new best score={sc:.3f}  alpha_r={alpha_r} sigma_r={sigma_r} "
                  f"eta={eta} step={step}")
            for k, v in m.items():
                print(f"    {k:24s} = {v:.4f}")
    print("\nBEST:", dataclasses.asdict(best[1]))


if __name__ == "__main__":
    main()
