"""Threshold-voltage (V_TH) model for 3D TLC NAND flash.

Implements the analytical device model the reproduction is built on:

  * each of the 8 TLC levels is a Gaussian N(mu_i, sigma_i);
  * retention loss shifts programmed levels down proportionally to their
    stored charge and to log(time), amplified by P/E cycling
    (Cai+ HPCA'15, Luo+ SIGMETRICS'18 style);
  * wear widens the distributions;
  * reading with a shortened sensing time tR adds sensing noise
    sigma_sense = eta * (1 - tr_scale) — the AR² trade-off;
  * a page's RBER for a given set of read voltages is the sum of Gaussian
    tail overlaps at the boundaries that page type senses (2-3-2 Gray code).

Everything is pure jnp and broadcasts over arbitrary leading batch dims so
the 160-chip characterization runs as one vectorized call.  The hot loop
(RBER over pages x retry-levels) also exists as a Pallas TPU kernel in
``repro.kernels.rber`` validated against :func:`rber_from_distributions`.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import constants as C
from repro.core.constants import NandParams, DEFAULT_NAND


def qfunc(x: jax.Array) -> jax.Array:
    """Gaussian tail probability Q(x) = P[N(0,1) > x]."""
    return 0.5 * jax.scipy.special.erfc(x / jnp.sqrt(2.0).astype(x.dtype))


def charge_fraction(params: NandParams = DEFAULT_NAND) -> jax.Array:
    """Charge stored in each level, as a fraction of the top level.

    The erased state holds ~no charge (clamped to 0), so retention loss —
    which is proportional to stored charge — leaves it in place.
    """
    mu0 = jnp.asarray(params.mu0)
    return jnp.maximum(mu0, 0.0) / mu0[-1]


def degradation_scale(
    retention_days: jax.Array,
    pec: jax.Array,
    params: NandParams = DEFAULT_NAND,
) -> jax.Array:
    """Dimensionless degradation magnitude g(t, c) = ln(1+t/t0)*(1+c/K)^beta."""
    t = jnp.asarray(retention_days, jnp.float32)
    c = jnp.asarray(pec, jnp.float32)
    return jnp.log1p(t / params.t0_days) * (1.0 + c / params.pec_knee) ** params.pec_beta


def degraded_distributions(
    retention_days: jax.Array,
    pec: jax.Array,
    rate_factor: jax.Array = 1.0,
    params: NandParams = DEFAULT_NAND,
):
    """Level means/sigmas after (retention, P/E) stress.

    Args:
      retention_days, pec: broadcastable arrays of operating conditions.
      rate_factor: per-chip/block multiplicative process variation on the
        degradation rate (lognormal around 1.0).

    Returns:
      (mu, sigma): arrays of shape broadcast(...)+(8,).
    """
    mu0 = jnp.asarray(params.mu0, jnp.float32)
    sigma0 = jnp.asarray(params.sigma0, jnp.float32)
    q = charge_fraction(params)
    g = degradation_scale(retention_days, pec, params) * jnp.asarray(
        rate_factor, jnp.float32
    )
    g = g[..., None]
    mu = mu0 - params.alpha_r * q * g
    c = jnp.asarray(pec, jnp.float32)[..., None]
    sig_ret = params.sigma_r * q * g
    sig_wear = params.sigma_w * jnp.where(q > 0, 1.0, 0.0) * (c / 1000.0) ** 0.7
    sigma = jnp.sqrt(sigma0**2 + sig_ret**2 + sig_wear**2)
    return mu, sigma


def optimal_boundaries(mu: jax.Array, sigma: jax.Array) -> jax.Array:
    """Closed-form optimal read voltages (adjacent-Gaussian intersections).

    Solves (x-m1)^2/(2 s1^2) + ln s1 = (x-m2)^2/(2 s2^2) + ln s2 for the root
    between m1 and m2; for s1 == s2 this degenerates to the midpoint.

    Args:
      mu, sigma: (..., 8) level parameters.

    Returns:
      (..., 7) optimal boundary voltages R1..R7.
    """
    m1, m2 = mu[..., :-1], mu[..., 1:]
    s1, s2 = sigma[..., :-1], sigma[..., 1:]
    # Quadratic a x^2 + b x + c = 0 from equating the two log-densities.
    a = s2**2 - s1**2
    b = 2.0 * (s1**2 * m2 - s2**2 * m1)
    c = s2**2 * m1**2 - s1**2 * m2**2 - 2.0 * (s1 * s2) ** 2 * jnp.log(s2 / s1)
    midpoint = 0.5 * (m1 + m2)
    disc = jnp.maximum(b**2 - 4.0 * a * c, 0.0)
    # Numerically-stable root selection; fall back to midpoint when a ~ 0.
    safe_a = jnp.where(jnp.abs(a) < 1e-9, 1.0, a)
    r1 = (-b + jnp.sqrt(disc)) / (2.0 * safe_a)
    r2 = (-b - jnp.sqrt(disc)) / (2.0 * safe_a)
    in_between1 = (r1 > m1) & (r1 < m2)
    root = jnp.where(in_between1, r1, r2)
    return jnp.where(jnp.abs(a) < 1e-9, midpoint, root)


def default_read_levels(params: NandParams = DEFAULT_NAND) -> jax.Array:
    """Factory-default read levels: optimal for a fresh (t=0, c=0) block."""
    mu0 = jnp.asarray(params.mu0, jnp.float32)
    sigma0 = jnp.asarray(params.sigma0, jnp.float32)
    return optimal_boundaries(mu0, sigma0)


def boundary_charge_fraction(params: NandParams = DEFAULT_NAND) -> jax.Array:
    """Charge fraction at each boundary (average of the adjacent levels).

    Manufacturer retry tables step high-charge boundaries further per entry,
    mirroring that retention loss is proportional to stored charge.
    """
    q = charge_fraction(params)
    return 0.5 * (q[:-1] + q[1:])


def retry_read_levels(
    step: jax.Array,
    params: NandParams = DEFAULT_NAND,
    base_levels: jax.Array | None = None,
) -> jax.Array:
    """Read levels for retry-table entry ``step`` (0 = default read).

    offsets_k[b] = -k * RETRY_STEP_V * q_b   (charge-proportional decrement)
    """
    if base_levels is None:
        base_levels = default_read_levels(params)
    qb = boundary_charge_fraction(params)
    k = jnp.asarray(step, jnp.float32)[..., None]
    return base_levels - k * params.retry_step_v * qb


def sensing_sigma(
    sigma: jax.Array, tr_scale: jax.Array, params: NandParams = DEFAULT_NAND
) -> jax.Array:
    """Effective sigma when sensing with reduced tR (AR² trade-off)."""
    s = jnp.asarray(tr_scale, jnp.float32)
    extra = params.sense_eta * jnp.maximum(1.0 - s, 0.0)
    return jnp.sqrt(sigma**2 + extra[..., None] ** 2)


def boundary_error_rates(
    mu: jax.Array,
    sigma: jax.Array,
    read_levels: jax.Array,
    tr_scale: jax.Array = 1.0,
    params: NandParams = DEFAULT_NAND,
) -> jax.Array:
    """Per-boundary raw bit error contribution (uniform random data).

    A cell in level j-1 misreads above R_j with prob Q((R_j - mu_{j-1})/s);
    a cell in level j misreads below R_j with prob Q((mu_j - R_j)/s).  Each
    level holds 1/8 of the cells.

    Returns:
      (..., 7) per-boundary error rates; a page's RBER sums the boundaries
      its page type senses.
    """
    sig = sensing_sigma(sigma, tr_scale, params)
    m_lo, m_hi = mu[..., :-1], mu[..., 1:]
    s_lo, s_hi = sig[..., :-1], sig[..., 1:]
    up = qfunc((read_levels - m_lo) / s_lo)     # lower level read as upper
    dn = qfunc((m_hi - read_levels) / s_hi)     # upper level read as lower
    return (up + dn) / 8.0


_PAGE_MASKS = {
    pt: tuple(1.0 if (b + 1) in C.PAGE_BOUNDARIES[pt] else 0.0 for b in range(7))
    for pt in C.PAGE_TYPES
}


def page_mask(page_type: str) -> jax.Array:
    """0/1 mask over the 7 boundaries selecting a page type's read levels."""
    return jnp.asarray(_PAGE_MASKS[page_type], jnp.float32)


def rber_from_distributions(
    mu: jax.Array,
    sigma: jax.Array,
    read_levels: jax.Array,
    page_type: str,
    tr_scale: jax.Array = 1.0,
    params: NandParams = DEFAULT_NAND,
) -> jax.Array:
    """RBER of one page type under the given distributions and read levels."""
    per_boundary = boundary_error_rates(mu, sigma, read_levels, tr_scale, params)
    return jnp.sum(per_boundary * page_mask(page_type), axis=-1)


def rber_all_page_types(
    mu: jax.Array,
    sigma: jax.Array,
    read_levels: jax.Array,
    tr_scale: jax.Array = 1.0,
    params: NandParams = DEFAULT_NAND,
) -> jax.Array:
    """Stacked RBER for (lsb, csb, msb): shape (..., 3)."""
    per_boundary = boundary_error_rates(mu, sigma, read_levels, tr_scale, params)
    masks = jnp.stack([page_mask(pt) for pt in C.PAGE_TYPES])  # (3, 7)
    return jnp.einsum("...b,pb->...p", per_boundary, masks)


def sample_process_variation(
    key: jax.Array,
    n_chips: int,
    n_blocks: int,
    params: NandParams = DEFAULT_NAND,
):
    """Lognormal per-chip and per-block degradation-rate factors.

    Returns:
      rate: (n_chips, n_blocks) multiplicative factors around 1.0.
    """
    k1, k2 = jax.random.split(key)
    chip = jnp.exp(C.CHIP_VAR_SIGMA * jax.random.normal(k1, (n_chips, 1)))
    block = jnp.exp(C.BLOCK_VAR_SIGMA * jax.random.normal(k2, (n_chips, n_blocks)))
    return chip * block
