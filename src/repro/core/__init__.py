"""Paper core: PR²/AR² read-retry optimization (Park+, ASPLOS'21).

Public surface:
  constants    — calibrated physics/timing/ECC/roofline constants
  voltage      — TLC V_TH model, RBER, optimal read levels
  ecc          — capability, margin, codeword failure sampling
  timing       — closed-form latency for each mechanism
  retry        — retry mechanisms + RetryPolicy (framework-wide knob)
  characterize — 160-chip characterization (paper §3 observations + AR² table)
"""

from repro.core.constants import DEFAULT_NAND, NandParams
from repro.core.retry import MECHANISMS, RetryPolicy
from repro.core.timing import DEFAULT_TIMING, TimingParams, read_latency

__all__ = [
    "DEFAULT_NAND",
    "NandParams",
    "MECHANISMS",
    "RetryPolicy",
    "DEFAULT_TIMING",
    "TimingParams",
    "read_latency",
]
