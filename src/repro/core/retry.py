"""Read-retry mechanisms: BASELINE, SOTA[25], PR², AR², PR²+AR².

The retry *table* is charge-proportional: entry k lowers boundary b by
k * RETRY_STEP_V * q_b (see voltage.retry_read_levels).  Because retention
loss is also charge-proportional, some entry k* brings every boundary close
to its optimum simultaneously — exactly why the paper's final retry step
reads at *near-optimal* V_REF and enjoys a large ECC margin.

Mechanisms differ along two independent axes the paper identifies:

  * where the search *starts* (``vref_start``):
      - "default": entry 0 (factory levels) — the high-end-SSD baseline;
      - "sota": the history-based predictor of Shim+ [MICRO'19] ([25]);
        the paper quotes it removing ~70% of retry steps, so we model the
        prediction as landing 70% of the way to the success entry (plus
        sampling noise), which also reproduces the paper's observation
        that *aged* SSDs still need >= 3 steps per read under SOTA.
  * how each step *executes*:
      - pipelined or not (PR², CACHE READ), and
      - full or scaled tR (AR², characterized safe scale).

Step execution changes latency only; where the search starts changes the
*number* of attempts.  This is the paper's complementarity argument, and it
is explicit in the code structure.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import constants as C
from repro.core import ecc as ecc_mod
from repro.core import voltage as V
from repro.core.constants import NandParams, DEFAULT_NAND

MECHANISMS = ("baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2")

#: Fraction of retry steps removed by the SOTA predictor (paper: "about 70%").
SOTA_STEP_REDUCTION = 0.70


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """First-class framework knob threaded through data/serving/checkpoint."""

    mechanism: str = "pr2ar2"
    #: "auto" looks up the characterized safe scale for the operating
    #: condition; a float forces a specific scale (tests/ablations).
    tr_scale: float | str = "auto"

    def __post_init__(self):
        if self.mechanism not in MECHANISMS:
            raise ValueError(f"unknown mechanism {self.mechanism!r}")

    @property
    def pipelined(self) -> bool:
        return self.mechanism in ("pr2", "pr2ar2", "sota+pr2ar2")

    @property
    def adaptive_tr(self) -> bool:
        return self.mechanism in ("ar2", "pr2ar2", "sota+pr2ar2")

    @property
    def sota_start(self) -> bool:
        return self.mechanism in ("sota", "sota+pr2ar2")


def rber_per_retry_step(
    mu: jax.Array,
    sigma: jax.Array,
    page_type: str,
    tr_scale: jax.Array = 1.0,
    level_jitter: jax.Array | None = None,
    params: NandParams = DEFAULT_NAND,
) -> jax.Array:
    """RBER of a page at every retry-table entry.

    Args:
      mu, sigma: (..., 8) degraded level distributions.
      level_jitter: optional (..., 7) per-page boundary jitter (process
        variation not captured by the chip/block rate factors).

    Returns:
      (..., MAX_RETRY_STEPS + 1) RBER at entries 0..MAX.
    """
    steps = jnp.arange(params.max_retry_steps + 1, dtype=jnp.float32)
    levels = V.retry_read_levels(steps, params)            # (S, 7)
    if level_jitter is not None:
        levels = levels + level_jitter[..., None, :]       # (..., S, 7)
    return V.rber_from_distributions(
        mu[..., None, :], sigma[..., None, :], levels, page_type, tr_scale, params
    )


def first_success_step(
    rber_steps: jax.Array,
    start_step: jax.Array = 0,
    cap: float = C.ECC_RBER_CAP,
    max_steps: int = C.MAX_RETRY_STEPS,
) -> jax.Array:
    """First retry-table entry >= start_step whose RBER is correctable.

    Returns max_steps where no entry succeeds (read failure -> the SSD
    would fall back to soft-decision decode / RAID; rare by construction).
    """
    steps = jnp.arange(rber_steps.shape[-1])
    ok = (rber_steps <= cap) & (steps >= jnp.asarray(start_step)[..., None])
    any_ok = jnp.any(ok, axis=-1)
    idx = jnp.argmax(ok, axis=-1)
    return jnp.where(any_ok, idx, max_steps)


def sota_start_step(success_step: jax.Array, key: jax.Array | None = None) -> jax.Array:
    """History-based predictor start entry (models Shim+ [25]).

    Lands SOTA_STEP_REDUCTION of the way to the success entry, with one
    entry of prediction noise (the V_TH keeps drifting between the history
    update and the read — the reason aged SSDs still retry >= 3 times).
    """
    pred = jnp.floor(SOTA_STEP_REDUCTION * success_step.astype(jnp.float32))
    if key is not None:
        noise = jax.random.randint(key, success_step.shape, -1, 1)  # {-1, 0}
        pred = pred + noise
    return jnp.clip(pred, 0, None).astype(jnp.int32)


def attempts_for_population(
    key: jax.Array,
    retention_days: float,
    pec: float,
    page_type: str,
    n_chips: int = C.N_CHIPS,
    n_blocks: int = 8,
    n_pages: int = 32,
    sota: bool = False,
    tr_scale: float = 1.0,
    params: NandParams = DEFAULT_NAND,
) -> Tuple[jax.Array, jax.Array]:
    """Retry attempts (initial read + retries) across a chip population.

    Returns:
      attempts: (n_chips, n_blocks, n_pages) int32 — k_success + 1.
      rber_final: RBER observed at the success entry (for margin analysis).
    """
    k_var, k_jit, k_sota = jax.random.split(key, 3)
    rate = V.sample_process_variation(k_var, n_chips, n_blocks, params)  # (C, B)
    mu, sigma = V.degraded_distributions(
        jnp.float32(retention_days), jnp.float32(pec), rate, params
    )  # (C, B, 8)
    jitter = C.PAGE_JITTER_SIGMA * jax.random.normal(
        k_jit, (n_chips, n_blocks, n_pages, 7)
    )
    rber = rber_per_retry_step(
        mu[..., None, :],       # (C, B, 1, 8) — broadcast over pages
        sigma[..., None, :],
        page_type,
        tr_scale,
        level_jitter=jitter,
        params=params,
    )
    # rber: (C, B, P, S)
    k_default = first_success_step(rber)
    start = sota_start_step(k_default, k_sota) if sota else jnp.zeros_like(k_default)
    k = first_success_step(rber, start)
    rber_final = jnp.take_along_axis(rber, k[..., None], axis=-1)[..., 0]
    # Attempts actually executed: from the start entry to the success entry
    # inclusive (SOTA skips the entries before its predicted start).
    attempts = (k - start + 1).astype(jnp.int32)
    return attempts, rber_final


def mean_retry_steps(
    key: jax.Array,
    retention_days: float,
    pec: float,
    sota: bool = False,
    params: NandParams = DEFAULT_NAND,
) -> float:
    """Population-mean number of *retry steps* (attempts - 1), page-type mix."""
    totals = []
    for i, pt in enumerate(C.PAGE_TYPES):
        attempts, _ = attempts_for_population(
            jax.random.fold_in(key, i), retention_days, pec, pt,
            sota=sota, params=params,
        )
        totals.append(jnp.mean(attempts - 1))
    return float(jnp.mean(jnp.stack(totals)))
