"""Physical and architectural constants for the PR²/AR² reproduction.

Voltage units are normalized (the paper never discloses absolute volts; all
published 3D-TLC characterization work — Cai+ DATE'13, Luo+ SIGMETRICS'18 —
is presented in normalized units as well).  Timing constants are chosen to
match the paper's quoted figures exactly:

  * PR² removes transfer+decode from the retry critical path and the paper
    reports a 28.5% per-step latency reduction, i.e.
    (tDMA + tECC) / (tR_avg + tDMA + tECC) = 0.285.
    With the 3D-TLC page-type sensing times below (tR_avg = 62.43 us) this
    pins tDMA + tECC = 24.9 us, which matches a 16 KiB page + LDPC parity at
    1.2 GB/s NV-DDR3 (15.4 us) plus a ~9.5 us LDPC decode.
  * AR² reduces tR by 25% worst-case (1-year retention, 1.5K P/E cycles),
    so the sensing-noise coefficient is calibrated such that scale 0.75 is
    safe at the worst prescribed operating condition and 0.65 is not.

TPU roofline constants (v5e-class, per task spec) also live here so the
roofline tooling has a single source of truth.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

# --------------------------------------------------------------------------
# TLC threshold-voltage model (8 levels, Gray-coded 2-3-2 page mapping).
# --------------------------------------------------------------------------

#: Mean V_TH per level at programming time (t = 0, fresh block).  The
#: erased state sits deep below P1 (negative V_TH), as in real 3D TLC.
LEVEL_MU0: Tuple[float, ...] = (-1.20, 1.10, 1.70, 2.30, 2.90, 3.50, 4.10, 4.70)

#: V_TH standard deviation per level at programming time.  The erased state
#: is much wider than programmed states (no program-and-verify loop).
LEVEL_SIGMA0: Tuple[float, ...] = (0.30, 0.085, 0.08, 0.08, 0.08, 0.08, 0.08, 0.085)

#: Boundary -> page-type mapping for TLC 2-3-2 Gray coding.  Read level R_j
#: (j in 1..7) separates level j-1 from level j.
PAGE_BOUNDARIES = {
    "lsb": (1, 5),
    "csb": (2, 4, 6),
    "msb": (3, 7),
}
PAGE_TYPES = ("lsb", "csb", "msb")

# --------------------------------------------------------------------------
# Degradation model — calibrated against the paper's three observations.
# See core/calibrate.py for the calibration sweep that produced these.
# --------------------------------------------------------------------------

#: Retention charge-loss coefficient (V per unit charge-fraction per ln-day).
ALPHA_RETENTION = 0.094
#: Distribution widening with retention (same units).
SIGMA_RETENTION = 0.0020
#: P/E-cycle knee and exponent: degradation scales with (1 + pec/K)^beta.
PEC_KNEE = 2000.0
PEC_BETA = 1.1
#: Wear-induced widening coefficient, scales with (pec/1000)^0.7.
SIGMA_WEAR = 0.014
#: Sensing-noise coefficient: sigma_sense = SENSE_ETA * (1 - tr_scale).
#: Calibrated so a 25% tR reduction is safe at (1 yr, 1.5K P/E) and a 35%
#: reduction is not (benchmarks/tr_reduction.py reproduces the table).
SENSE_ETA = 0.11
#: log-time constant (days).
RETENTION_T0_DAYS = 1.0

#: Process variation (lognormal sigma of the per-chip / per-block / per-page
#: multiplicative factor on the degradation rate).
CHIP_VAR_SIGMA = 0.06
BLOCK_VAR_SIGMA = 0.04
#: Additive per-page, per-boundary V_REF jitter (V).
PAGE_JITTER_SIGMA = 0.010

#: Number of chips in the characterization population (paper: 160 real chips).
N_CHIPS = 160

# --------------------------------------------------------------------------
# Read-retry table.
# --------------------------------------------------------------------------

#: Per-step V_REF decrement applied to each boundary, scaled by the
#: boundary's charge fraction (retention loss is proportional to stored
#: charge, so manufacturer retry tables step high boundaries further).
RETRY_STEP_V = 0.06
#: Maximum retry entries in the table (real tables: ~30-50 entries).
MAX_RETRY_STEPS = 40

# --------------------------------------------------------------------------
# ECC (per the paper's reference: 72 bits correctable per 1 KiB codeword).
# --------------------------------------------------------------------------

ECC_T = 72                    # correctable bits per codeword
ECC_K_BITS = 8192             # data bits per codeword (1 KiB)
ECC_PARITY_BITS = 1280        # LDPC parity (code rate ~0.865)
ECC_N_BITS = ECC_K_BITS + ECC_PARITY_BITS
CODEWORDS_PER_PAGE = 16       # 16 KiB page

#: Deterministic ECC capability expressed as an RBER threshold.
ECC_RBER_CAP = ECC_T / float(ECC_N_BITS)   # ~7.6e-3

# --------------------------------------------------------------------------
# NAND / SSD timing (microseconds) — see module docstring for calibration.
# --------------------------------------------------------------------------

TR_US = {"lsb": 48.0, "csb": 61.3, "msb": 78.0}
TR_AVG_US = sum(TR_US.values()) / 3.0           # 62.43
TDMA_US = 15.4                                   # 16 KiB + parity @ 1.2 GB/s
TECC_US = 9.5                                    # LDPC decode
TPROG_US = 660.0                                 # TLC program
TERASE_US = 3500.0
PAGE_KIB = 16

#: Worst-case operating condition prescribed by manufacturers (paper §3):
#: 1-year retention [13] at 1.5K P/E cycles [24].
WORST_RETENTION_DAYS = 365.0
WORST_PEC = 1500.0

# --------------------------------------------------------------------------
# TPU v5e-class roofline constants (per task spec).
# --------------------------------------------------------------------------

TPU_PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
TPU_HBM_BW = 819e9             # bytes/s per chip
TPU_ICI_BW = 50e9              # bytes/s per link
TPU_HBM_GIB = 16.0             # v5e HBM capacity


@dataclasses.dataclass(frozen=True)
class NandParams:
    """Bundle of the physics constants (overridable for sensitivity tests)."""

    mu0: Tuple[float, ...] = LEVEL_MU0
    sigma0: Tuple[float, ...] = LEVEL_SIGMA0
    alpha_r: float = ALPHA_RETENTION
    sigma_r: float = SIGMA_RETENTION
    pec_knee: float = PEC_KNEE
    pec_beta: float = PEC_BETA
    sigma_w: float = SIGMA_WEAR
    sense_eta: float = SENSE_ETA
    t0_days: float = RETENTION_T0_DAYS
    retry_step_v: float = RETRY_STEP_V
    max_retry_steps: int = MAX_RETRY_STEPS


DEFAULT_NAND = NandParams()
