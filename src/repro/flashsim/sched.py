"""Die-queue scheduling policies for the array event-core.

The event core (:mod:`repro.flashsim.engine`) serves each die through one
queue object.  This module is the policy layer: it defines the queue
disciplines and the registry the config/run API validates against.  Three
policies ship:

``fcfs``
    Strict arrival order — the pre-refactor behavior, bit-identical to
    the original monolithic engine (the queue *is* a ``collections.deque``
    and the event core drives it with the same append/popleft sequence).

``host_prio``
    Two-class priority: host reads always dequeue before anything else
    (host programs, GC copy-back reads/programs, erases).  Within a
    class, order stays FIFO.  This models firmware that reorders the die
    command queue in favor of latency-critical host reads but never
    interrupts an operation already on the die.

``host_prio_aged``
    ``host_prio`` with a **starvation bound**: under a sustained
    100%-read phase plain host_prio can park a queued GC program or
    erase forever (free blocks never reclaim, and with online GC the
    device eventually wedges on writes).  Here a waiting low-priority op
    *ages*: once ``age_bound`` host reads have dequeued past a waiting
    GC/program op, the next dispatch serves the low class first.  The
    head-of-line low op is therefore bypassed at most ``age_bound``
    times — bounded staleness for GC work, near-host_prio read latency
    otherwise.  The bound is configurable through the registry name:
    ``"host_prio_aged:8"`` (default 16).

``preempt``
    ``host_prio`` ordering *plus* read-suspend firmware semantics: an
    in-flight GC operation yields the die to a waiting host read —
    erases and GC programs suspend immediately and later resume with
    their residual time; GC reads suspend at retry-attempt boundaries
    and resume with their remaining attempts (completed attempts are
    never re-executed).  Host operations are never suspended.  Suspended
    ops re-enter at the *front* of the low-priority class so GC work
    resumes in service order.

Queue protocol (duck-typed, engine-facing)
------------------------------------------
``append(op)``      enqueue a ready op (policy decides the class);
``pop_next()``      dequeue the next op to serve;
``resume_push(op)`` re-enqueue a suspended op at the front of its class;
``has_host()``      True when a host read is waiting (preemption probe);
truthiness / ``len()``  queue emptiness / total queued ops.

``FCFSQueue`` subclasses ``deque`` so ``append`` / ``__bool__`` stay
C-speed on the hot path; ``pop_next`` aliases ``deque.popleft``.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Sequence, Tuple

#: Registered policy names, in documentation order.  ``host_prio_aged``
#: also accepts a bound suffix (``"host_prio_aged:8"``).
SCHEDULERS: Tuple[str, ...] = (
    "fcfs", "host_prio", "host_prio_aged", "preempt"
)

#: Host reads that dequeue past a waiting low-priority op before it ages
#: to the front (``host_prio_aged`` default).
DEFAULT_AGE_BOUND = 16


class FCFSQueue(deque):
    """Strict-FIFO die queue — a ``deque`` with the queue protocol.

    ``append``/``__bool__``/``__len__`` are inherited C implementations,
    so the fcfs hot path pays nothing for the abstraction.
    """

    __slots__ = ()

    pop_next = deque.popleft
    resume_push = deque.appendleft  # unused under fcfs (nothing suspends)

    def has_host(self) -> bool:  # pragma: no cover - preempt-only probe
        return False


class HostPrioQueue:
    """Two-class die queue: host reads (hi) jump everything else (lo).

    ``host_read`` is the engine's per-op host-read table (a growing list
    — online GC appends ops mid-run; the reference is shared, so new ops
    classify correctly).  FIFO within each class.
    """

    __slots__ = ("hi", "lo", "_host")

    def __init__(self, host_read: Sequence[bool]):
        self.hi: deque = deque()
        self.lo: deque = deque()
        self._host = host_read

    def append(self, op: int) -> None:
        (self.hi if self._host[op] else self.lo).append(op)

    def pop_next(self) -> int:
        hi = self.hi
        return hi.popleft() if hi else self.lo.popleft()

    def resume_push(self, op: int) -> None:
        # Suspended ops are never host reads: front of the low class.
        self.lo.appendleft(op)

    def has_host(self) -> bool:
        return bool(self.hi)

    def __bool__(self) -> bool:
        return bool(self.hi) or bool(self.lo)

    def __len__(self) -> int:
        return len(self.hi) + len(self.lo)


class AgedHostPrioQueue(HostPrioQueue):
    """Host-priority die queue with a starvation bound (GC aging).

    Counts how many high-priority (host-read) dispatches have bypassed
    the waiting low class; at ``age_bound`` the next dispatch serves the
    low class and the counter resets.  The counter also resets whenever
    the low class drains or is served naturally, so the bound is per
    head-of-line wait, not cumulative.
    """

    __slots__ = ("age_bound", "_bypassed")

    def __init__(self, host_read: Sequence[bool],
                 age_bound: int = DEFAULT_AGE_BOUND):
        super().__init__(host_read)
        if age_bound < 1:
            raise ValueError(f"age_bound must be >= 1, got {age_bound}")
        self.age_bound = age_bound
        self._bypassed = 0

    def pop_next(self) -> int:
        hi, lo = self.hi, self.lo
        if hi and lo and self._bypassed >= self.age_bound:
            self._bypassed = 0
            return lo.popleft()       # aged: GC/program jumps the reads
        if hi:
            if lo:
                self._bypassed += 1
            return hi.popleft()
        self._bypassed = 0
        return lo.popleft()


@dataclasses.dataclass(frozen=True)
class SchedulerPolicy:
    """One die-queue scheduling policy (registry entry).

    ``prioritized`` selects the two-class queue; ``preemptive`` addition-
    ally arms the engine's suspend/resume paths.  The queue factory gets
    the engine's per-op host-read table (may grow during the run).
    """

    name: str
    prioritized: bool
    preemptive: bool
    make_queue: Callable[[Sequence[bool]], object]

    def make_queues(self, n_dies: int, host_read: Sequence[bool]) -> List:
        return [self.make_queue(host_read) for _ in range(n_dies)]


_REGISTRY: Dict[str, SchedulerPolicy] = {
    "fcfs": SchedulerPolicy(
        "fcfs", prioritized=False, preemptive=False,
        make_queue=lambda host_read: FCFSQueue(),
    ),
    "host_prio": SchedulerPolicy(
        "host_prio", prioritized=True, preemptive=False,
        make_queue=HostPrioQueue,
    ),
    "host_prio_aged": SchedulerPolicy(
        "host_prio_aged", prioritized=True, preemptive=False,
        make_queue=AgedHostPrioQueue,
    ),
    "preempt": SchedulerPolicy(
        "preempt", prioritized=True, preemptive=True,
        make_queue=HostPrioQueue,
    ),
}


def get_scheduler(name: str) -> SchedulerPolicy:
    """Resolve a policy by name (raises ``ValueError`` on unknown names).

    ``host_prio_aged`` accepts an optional starvation bound suffix —
    ``"host_prio_aged:8"`` ages a waiting GC/program op to the front
    after 8 bypassing host reads (default ``DEFAULT_AGE_BOUND``).
    """
    base, sep, arg = name.partition(":")
    policy = _REGISTRY.get(base)
    if policy is None or (sep and (base != "host_prio_aged" or not arg)):
        raise ValueError(
            f"unknown scheduler {name!r} (choose from {SCHEDULERS}; "
            f"only host_prio_aged takes a ':bound' suffix)"
        )
    if arg:
        try:
            bound = int(arg)
        except ValueError:
            raise ValueError(
                f"scheduler {name!r}: age bound must be an integer"
            ) from None
        if bound < 1:
            raise ValueError(
                f"scheduler {name!r}: age bound must be >= 1"
            )
        return dataclasses.replace(
            policy, name=name,
            make_queue=lambda host_read: AgedHostPrioQueue(host_read, bound),
        )
    return policy
