"""Die-queue scheduling policies for the array event-core.

The event core (:mod:`repro.flashsim.engine`) serves each die through one
queue object.  This module is the policy layer: it defines the queue
disciplines and the registry the config/run API validates against.  Three
policies ship:

``fcfs``
    Strict arrival order — the pre-refactor behavior, bit-identical to
    the original monolithic engine (the queue *is* a ``collections.deque``
    and the event core drives it with the same append/popleft sequence).

``host_prio``
    Two-class priority: host reads always dequeue before anything else
    (host programs, GC copy-back reads/programs, erases).  Within a
    class, order stays FIFO.  This models firmware that reorders the die
    command queue in favor of latency-critical host reads but never
    interrupts an operation already on the die.

``host_prio_aged``
    ``host_prio`` with a **starvation bound**: under a sustained
    100%-read phase plain host_prio can park a queued GC program or
    erase forever (free blocks never reclaim, and with online GC the
    device eventually wedges on writes).  Here a waiting low-priority op
    *ages*: once ``age_bound`` host reads have dequeued past a waiting
    GC/program op, the next dispatch serves the low class first.  The
    head-of-line low op is therefore bypassed at most ``age_bound``
    times — bounded staleness for GC work, near-host_prio read latency
    otherwise.  The bound is configurable through the registry name:
    ``"host_prio_aged:8"`` (default 16).

``tokens``
    Per-die **read/write token budgets** (deficit-round-robin style):
    while both classes are backlogged, each dispatch round serves up to
    ``r`` host reads and then up to ``w`` low-priority ops (host
    programs, GC copy-back, erases), so reads keep priority but writes
    are guaranteed ``w`` slots per ``r + w`` dispatches — a smoother
    bandwidth split than ``host_prio_aged``'s all-or-nothing aging.
    Budgets only meter *contention*: when one class is empty the other
    is served immediately (work conservation) and the round resets, so
    an uncontended die behaves exactly like FIFO-within-class.
    Configured through the registry name: ``"tokens:6,2"``
    (default ``tokens`` = 8 reads / 2 writes).

``preempt``
    ``host_prio`` ordering *plus* read-suspend firmware semantics: an
    in-flight GC operation yields the die to a waiting host read —
    erases and GC programs suspend immediately and later resume with
    their residual time; GC reads suspend at retry-attempt boundaries
    and resume with their remaining attempts (completed attempts are
    never re-executed).  Host operations are never suspended.  Suspended
    ops re-enter at the *front* of the low-priority class so GC work
    resumes in service order.

Queue protocol (duck-typed, engine-facing)
------------------------------------------
``append(op)``      enqueue a ready op (policy decides the class);
``pop_next()``      dequeue the next op to serve;
``resume_push(op)`` re-enqueue a suspended op at the front of its class;
``has_host()``      True when a host read is waiting (preemption probe);
truthiness / ``len()``  queue emptiness / total queued ops.

``FCFSQueue`` subclasses ``deque`` so ``append`` / ``__bool__`` stay
C-speed on the hot path; ``pop_next`` aliases ``deque.popleft``.

Closed-loop frontend
--------------------
The closed-loop interpreter (``engine.run_closed_loop``, selected with
``ncq_depth=``) reuses these same queue objects for die scheduling, so
``fcfs`` / ``host_prio`` / ``host_prio_aged`` / ``tokens`` all work
unchanged under NCQ admission.  ``preempt`` is the exception: its
suspend/resume bookkeeping lives in the open-loop event core only, so
combining ``sched="preempt"`` with ``ncq_depth=`` raises
``NotImplementedError``.
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

#: Registered policy names, in documentation order.  ``host_prio_aged``
#: also accepts a bound suffix (``"host_prio_aged:8"``); ``tokens`` a
#: budget suffix (``"tokens:6,2"``).
SCHEDULERS: Tuple[str, ...] = (
    "fcfs", "host_prio", "host_prio_aged", "tokens", "preempt"
)

#: Host reads that dequeue past a waiting low-priority op before it ages
#: to the front (``host_prio_aged`` default).
DEFAULT_AGE_BOUND = 16

#: Default per-round (read, write) dispatch budgets for ``tokens``.
DEFAULT_TOKEN_BUDGETS = (8, 2)


class FCFSQueue(deque):
    """Strict-FIFO die queue — a ``deque`` with the queue protocol.

    ``append``/``__bool__``/``__len__`` are inherited C implementations,
    so the fcfs hot path pays nothing for the abstraction.
    """

    __slots__ = ()

    pop_next = deque.popleft
    resume_push = deque.appendleft  # unused under fcfs (nothing suspends)

    def has_host(self) -> bool:  # pragma: no cover - preempt-only probe
        return False


class HostPrioQueue:
    """Two-class die queue: host reads (hi) jump everything else (lo).

    ``host_read`` is the engine's per-op host-read table (a growing list
    — online GC appends ops mid-run; the reference is shared, so new ops
    classify correctly).  FIFO within each class.  Superpage-parity
    rebuild reads injected by the fault-recovery ladder carry
    ``host_read=True``: they gate a blocked host request, so they jump
    GC traffic exactly like the read they are rebuilding.
    """

    __slots__ = ("hi", "lo", "_host")

    def __init__(self, host_read: Sequence[bool]):
        self.hi: deque = deque()
        self.lo: deque = deque()
        self._host = host_read

    def append(self, op: int) -> None:
        (self.hi if self._host[op] else self.lo).append(op)

    def pop_next(self) -> int:
        hi = self.hi
        return hi.popleft() if hi else self.lo.popleft()

    def resume_push(self, op: int) -> None:
        # Suspended ops are never host reads: front of the low class.
        self.lo.appendleft(op)

    def has_host(self) -> bool:
        return bool(self.hi)

    def __bool__(self) -> bool:
        return bool(self.hi) or bool(self.lo)

    def __len__(self) -> int:
        return len(self.hi) + len(self.lo)


class AgedHostPrioQueue(HostPrioQueue):
    """Host-priority die queue with a starvation bound (GC aging).

    Counts how many high-priority (host-read) dispatches have bypassed
    the waiting low class; at ``age_bound`` the next dispatch serves the
    low class and the counter resets.  The counter also resets whenever
    the low class drains or is served naturally, so the bound is per
    head-of-line wait, not cumulative.
    """

    __slots__ = ("age_bound", "_bypassed")

    def __init__(self, host_read: Sequence[bool],
                 age_bound: int = DEFAULT_AGE_BOUND):
        super().__init__(host_read)
        if age_bound < 1:
            raise ValueError(f"age_bound must be >= 1, got {age_bound}")
        self.age_bound = age_bound
        self._bypassed = 0

    def pop_next(self) -> int:
        hi, lo = self.hi, self.lo
        if hi and lo and self._bypassed >= self.age_bound:
            self._bypassed = 0
            return lo.popleft()       # aged: GC/program jumps the reads
        if hi:
            if lo:
                self._bypassed += 1
            return hi.popleft()
        self._bypassed = 0
        return lo.popleft()


class TokenBudgetQueue(HostPrioQueue):
    """Two-class die queue metered by per-round read/write token budgets.

    Deficit-round-robin over the two classes of :class:`HostPrioQueue`:
    while **both** classes are backlogged, a round spends up to
    ``r_budget`` read tokens (host reads, served first) and then up to
    ``w_budget`` write tokens (everything else); when the write tokens
    exhaust, the round resets.  Budgets meter contention only — a
    dispatch finding one class empty serves the other immediately *and*
    resets the round, so the budget bound is per contention interval:
    once both classes are backlogged, at most ``r_budget`` reads dequeue
    before a write does, and writes can never take more than
    ``w_budget`` consecutive slots from waiting reads.

    Work conservation is structural: ``pop_next`` always dispatches when
    the queue is non-empty, tokens decide only *which class* goes first.
    """

    __slots__ = ("r_budget", "w_budget", "r_tok", "w_tok")

    def __init__(self, host_read: Sequence[bool],
                 r_budget: int = DEFAULT_TOKEN_BUDGETS[0],
                 w_budget: int = DEFAULT_TOKEN_BUDGETS[1]):
        super().__init__(host_read)
        if r_budget < 1 or w_budget < 1:
            raise ValueError(
                f"token budgets must be >= 1, got ({r_budget}, {w_budget})"
            )
        self.r_budget = r_budget
        self.w_budget = w_budget
        self.r_tok = r_budget
        self.w_tok = w_budget

    def pop_next(self) -> int:
        hi, lo = self.hi, self.lo
        if not lo:                       # uncontended: serve, reset round
            self.r_tok = self.r_budget
            self.w_tok = self.w_budget
            return hi.popleft()
        if not hi:
            self.r_tok = self.r_budget
            self.w_tok = self.w_budget
            return lo.popleft()
        if self.r_tok > 0:               # contended: reads spend first
            self.r_tok -= 1
            return hi.popleft()
        self.w_tok -= 1
        op = lo.popleft()
        if self.w_tok <= 0:              # write tokens spent: new round
            self.r_tok = self.r_budget
            self.w_tok = self.w_budget
        return op


@dataclasses.dataclass(frozen=True)
class SchedulerPolicy:
    """One die-queue scheduling policy (registry entry).

    ``prioritized`` selects the two-class queue; ``preemptive`` addition-
    ally arms the engine's suspend/resume paths.  The queue factory gets
    the engine's per-op host-read table (may grow during the run).

    ``ring_lowering`` is the policy's batched-kernel lowering descriptor
    (:mod:`repro.flashsim.engine_batched` /
    :mod:`repro.kernels.fcfs_core`): ``("fifo", 0.0)`` for the single
    FIFO ring, ``("prio", bound)`` for the dual host/low priority rings
    where ``bound`` is the aging bound (``math.inf`` = plain
    ``host_prio`` — the low class never ages to the front), or ``None``
    when the policy has no lockstep lowering (``tokens``, ``preempt``)
    and the batched engine must reject it.  The descriptor is metadata
    only: the Python queue objects above remain the semantic reference
    the kernel is bit-pinned against.
    """

    name: str
    prioritized: bool
    preemptive: bool
    make_queue: Callable[[Sequence[bool]], object]
    ring_lowering: Optional[Tuple[str, float]] = None

    def make_queues(self, n_dies: int, host_read: Sequence[bool]) -> List:
        return [self.make_queue(host_read) for _ in range(n_dies)]


_REGISTRY: Dict[str, SchedulerPolicy] = {
    "fcfs": SchedulerPolicy(
        "fcfs", prioritized=False, preemptive=False,
        make_queue=lambda host_read: FCFSQueue(),
        ring_lowering=("fifo", 0.0),
    ),
    "host_prio": SchedulerPolicy(
        "host_prio", prioritized=True, preemptive=False,
        make_queue=HostPrioQueue,
        # Plain host_prio == aged with an infinite bound: the low class
        # never jumps the reads.  One compiled dual-ring kernel serves
        # both (the bound is a traced scalar).
        ring_lowering=("prio", math.inf),
    ),
    "host_prio_aged": SchedulerPolicy(
        "host_prio_aged", prioritized=True, preemptive=False,
        make_queue=AgedHostPrioQueue,
        ring_lowering=("prio", float(DEFAULT_AGE_BOUND)),
    ),
    "tokens": SchedulerPolicy(
        "tokens", prioritized=True, preemptive=False,
        make_queue=TokenBudgetQueue,
    ),
    "preempt": SchedulerPolicy(
        "preempt", prioritized=True, preemptive=True,
        make_queue=HostPrioQueue,
    ),
}

#: Policies that accept a ``:arg`` suffix (and what the arg means).
_SUFFIXED = ("host_prio_aged", "tokens")


def get_scheduler(name: str) -> SchedulerPolicy:
    """Resolve a policy by name (raises ``ValueError`` on unknown names).

    ``host_prio_aged`` accepts an optional starvation bound suffix —
    ``"host_prio_aged:8"`` ages a waiting GC/program op to the front
    after 8 bypassing host reads (default ``DEFAULT_AGE_BOUND``).
    ``tokens`` accepts a ``:reads,writes`` budget suffix —
    ``"tokens:6,2"`` serves up to 6 host reads then up to 2 low-priority
    ops per contended round (default ``DEFAULT_TOKEN_BUDGETS``).
    """
    base, sep, arg = name.partition(":")
    policy = _REGISTRY.get(base)
    if policy is None or (sep and (base not in _SUFFIXED or not arg)):
        raise ValueError(
            f"unknown scheduler {name!r} (choose from {SCHEDULERS}; only "
            f"host_prio_aged takes a ':bound' suffix and tokens a "
            f"':reads,writes' suffix)"
        )
    if not arg:
        return policy
    if base == "host_prio_aged":
        try:
            bound = int(arg)
        except ValueError:
            raise ValueError(
                f"scheduler {name!r}: age bound must be an integer"
            ) from None
        if bound < 1:
            raise ValueError(
                f"scheduler {name!r}: age bound must be >= 1"
            )
        return dataclasses.replace(
            policy, name=name,
            make_queue=lambda host_read: AgedHostPrioQueue(host_read, bound),
            ring_lowering=("prio", float(bound)),
        )
    parts = arg.split(",")
    try:
        budgets = tuple(int(p) for p in parts)
    except ValueError:
        raise ValueError(
            f"scheduler {name!r}: token budgets must be integers "
            f"(expected 'tokens:reads,writes')"
        ) from None
    if len(budgets) != 2:
        raise ValueError(
            f"scheduler {name!r}: token budgets must be 'reads,writes' "
            f"(two comma-separated integers)"
        )
    r, w = budgets
    if r < 1 or w < 1:
        raise ValueError(
            f"scheduler {name!r}: token budgets must be >= 1"
        )
    return dataclasses.replace(
        policy, name=name,
        make_queue=lambda host_read: TokenBudgetQueue(host_read, r, w),
    )
