"""Event-driven multi-queue SSD simulator (MQSim-analogue), array event-core.

A true discrete-event simulation of what matters for read-retry latency at
the device level:

  * 8 channels x 8 dies; FCFS die queues and FCFS channel arbitration;
  * every retry attempt senses on the die, transfers over the shared
    channel, and decodes on the channel's LDPC engine — retries consume
    channel bandwidth, so heavy retry regresses *other* dies' reads too.
    (With one LDPC engine per channel and tECC < tDMA the decode stage can
    never backpressure a serial channel, so decode is folded in as a fixed
    +tECC after each transfer — an exact simplification, not an
    approximation.)
  * CACHE READ semantics for PR²: the die has a page register and a cache
    register; sensing of attempt i+1 overlaps the transfer+decode of
    attempt i (the copy into the cache register waits for the previous
    transfer to finish); one speculative sense is charged to die occupancy
    when a retried sequence terminates;
  * AR² scales every attempt's tR by the characterized safe scale for the
    simulated operating condition, and samples attempt counts from the
    reduced-tR retry distribution so its rare extra attempts are charged;
  * the SOTA baseline [25] starts the retry search at its predicted entry,
    shrinking attempt counts ~70%.

Per-read attempt counts are sampled from the 160-chip characterization
histograms (repro.core.characterize) for the simulated (retention, P/E)
condition — the same transplant of real-device statistics into MQSim that
the paper performs.

Engine architecture
-------------------
The original engine scheduled a Python closure per page-op state transition
on a ``(time, seq, fn, args)`` tuple heap and sampled attempt counts per
request at admit time.  The hot path is now an integer-opcode event core:

  * the whole trace is expanded to flat per-page-op NumPy arrays up front
    (:func:`expand_trace`), and attempt counts for every read page are
    sampled in one batched pass — the RNG stream is consumed in the same
    order as the old per-request sampler, so attempt assignments are
    bit-identical for a given seed;
  * heap records are ``(time, seq, op_id << 2 | opcode)`` — no closures,
    no argument tuples; the serial and PR²-pipelined read state machines
    and the write path are opcode transitions over preallocated per-op
    state buffers;
  * admissions never enter the heap: page-ops are pre-sorted by arrival
    time and merged into the event loop with a moving cursor;
  * die/channel FCFS state lives in flat ``busy_until``/``busy_total``
    buffers with per-resource FIFO queues.

  * channels are single-server FCFS with constant-duration transfers whose
    requests are always issued at the current sim time, so channel state
    collapses to a cumulative busy-until scalar: a transfer's grant and
    completion times are exact at issue, eliminating the per-transfer
    completion event (and the channel queues) entirely — one heap event
    per read attempt instead of two.

The retired closure engine is preserved in
:mod:`repro.flashsim.engine_ref` (``engine="reference"``); the array core
reproduces its SimStats bit-for-bit on typical traces (see
tests/test_flashsim_equiv.py) at a large wall-clock speedup (tracked in
``BENCH_sim.json`` by ``benchmarks/microbench_sim.py``).  One caveat: die
releases are scheduled with issue-time sequence numbers, so when two
events collide at the *exact same float timestamp* their order can differ
from the reference engine's; such ties are rare (a handful of requests per
hundred thousand) and shift per-request times by at most a transfer slot,
leaving every distribution statistically unchanged.
"""

from __future__ import annotations

import dataclasses
import functools
import heapq
from collections import deque
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

from repro.core import characterize as CH
from repro.core.retry import RetryPolicy
from repro.flashsim.config import DEFAULT_SSD, OperatingCondition, SSDConfig
from repro.flashsim.workloads import RequestTrace, Workload, cached_trace

PAGE_TYPE_ORDER = ("lsb", "csb", "msb")

#: Event opcodes (low 2 bits of a heap record's packed code).
_EV_NEXT = 0    # serial read: sense done -> issue transfer, schedule next
_EV_COPY = 1    # pipelined read: copy into cache register -> issue transfer
_EV_ACQ = 2     # write: transfer landed -> acquire die for programming
_EV_REL = 3     # die release (read end / write program end)

_INF = float("inf")


@dataclasses.dataclass
class SimStats:
    """Response-time statistics over completed requests (microseconds)."""

    mean_us: float
    p50_us: float
    p95_us: float
    p99_us: float
    read_mean_us: float
    n_requests: int
    mean_read_attempts: float
    die_util: float
    channel_util: float

    def as_row(self) -> str:
        return (
            f"mean={self.mean_us:9.1f}us p50={self.p50_us:8.1f} p95={self.p95_us:9.1f} "
            f"p99={self.p99_us:9.1f} attempts={self.mean_read_attempts:5.2f} "
            f"die_u={self.die_util:.2f} ch_u={self.channel_util:.2f}"
        )


@dataclasses.dataclass(frozen=True)
class TraceExpansion:
    """Mechanism-independent flat page-op view of a trace (admission order).

    Shared across all mechanisms of a sweep: only the per-op attempt counts
    and sense times depend on the policy, and those are sampled separately.
    """

    arrival_us: np.ndarray   # (P,) op admission time = its request's arrival
    rid: np.ndarray          # (P,) owning request index
    die: np.ndarray          # (P,) die id
    chan: np.ndarray         # (P,) channel id
    ptype: np.ndarray        # (P,) page type index into PAGE_TYPE_ORDER
    is_read: np.ndarray      # (P,) bool
    n_requests: int

    @property
    def n_ops(self) -> int:
        return int(self.rid.shape[0])

    @functools.cached_property
    def admission_lists(self):
        """Mechanism-independent per-op buffers as plain Python lists.

        The event loop reads flat lists (scalar list indexing is ~4x faster
        than ndarray scalar access); converting once here instead of per
        ``run()`` lets a mechanism sweep reuse the views.
        """
        return (
            self.arrival_us.tolist(),
            self.rid.tolist(),
            self.die.tolist(),
            self.chan.tolist(),
            self.is_read.tolist(),
        )


def expand_trace(trace: RequestTrace, cfg: SSDConfig = DEFAULT_SSD) -> TraceExpansion:
    """Vectorized request -> page-op expansion (no per-request Python loop).

    Ops come out in admission order.  Traces from :func:`generate_trace`
    arrive sorted; externally-supplied traces (e.g. future MSR/blktrace
    ingestion) may not, so unsorted arrivals are stably sorted here —
    matching the retired heap engine's (time, request-index) admission
    order exactly.
    """
    arrival = trace.arrival_us
    n = len(arrival)
    if np.any(np.diff(arrival) < 0):
        req_order = np.argsort(arrival, kind="stable")
    else:
        req_order = np.arange(n)
    n_pages = trace.n_pages[req_order]
    rid = np.repeat(req_order, n_pages)
    # Within-request page offsets 0..n_pages[r]-1, flattened.
    starts = np.cumsum(n_pages) - n_pages
    off = np.arange(int(n_pages.sum()), dtype=np.int64) - np.repeat(starts, n_pages)
    page_ids = trace.start_page[rid] + off
    die = (page_ids % cfg.n_dies).astype(np.int64)
    return TraceExpansion(
        arrival_us=trace.arrival_us[rid],
        rid=rid,
        die=die,
        chan=cfg.channel_of(die),
        ptype=(page_ids % 3).astype(np.int64),
        is_read=trace.is_read[rid],
        n_requests=n,
    )


class SSDSim:
    """One simulation run = (workload trace, operating condition, policy)."""

    def __init__(
        self,
        cfg: SSDConfig = DEFAULT_SSD,
        condition: OperatingCondition = OperatingCondition(),
        policy: RetryPolicy = RetryPolicy("baseline"),
        seed: int = 0,
    ):
        self.cfg = cfg
        self.cond = condition
        self.policy = policy
        self.rng = np.random.default_rng(seed)
        self.events_processed = 0
        # AR² tR scale for this operating condition (characterized table).
        if policy.adaptive_tr:
            if policy.tr_scale == "auto":
                self.tr_scale = CH.characterize_condition(
                    condition.retention_days, condition.pec
                ).safe_tr_scale
            else:
                self.tr_scale = float(policy.tr_scale)
        else:
            self.tr_scale = 1.0
        # Per-page-type attempt-count CDFs under this mechanism (cached
        # across SSDSim instances in repro.core.characterize).
        self._attempt_cdfs = {
            pt: CH.attempt_cdf(
                condition.retention_days,
                condition.pec,
                page_type=pt,
                sota=policy.sota_start,
                tr_scale=self.tr_scale,
            )
            for pt in PAGE_TYPE_ORDER
        }

    # -- attempt sampling ----------------------------------------------------

    def _sample_attempts(self, page_types: np.ndarray) -> np.ndarray:
        """Inverse-CDF attempt counts for a batch of page-type indices.

        Consumes ``self.rng`` exactly like the retired per-request sampler
        (one uniform per read page, in admission order), so a given seed
        yields identical attempts under both engines.
        """
        u = self.rng.random(page_types.shape)
        out = np.empty(page_types.shape, np.int64)
        for i, pt in enumerate(PAGE_TYPE_ORDER):
            m = page_types == i
            if m.any():
                out[m] = np.searchsorted(self._attempt_cdfs[pt], u[m])
        return np.maximum(out, 1)

    # -- array event-core ----------------------------------------------------

    def run(
        self,
        trace: RequestTrace,
        expansion: Optional[TraceExpansion] = None,
    ) -> SimStats:
        """Simulate one trace; ``expansion`` may be shared across mechanisms."""
        cfg, t = self.cfg, self.cfg.timing
        tdma, tecc, tprog = t.tdma_us, t.tecc_us, t.tprog_us
        pipelined = self.policy.pipelined
        tr_by_type = (
            np.array([t.tr_us[pt] for pt in PAGE_TYPE_ORDER]) * self.tr_scale
        )

        ex = expansion if expansion is not None else expand_trace(trace, cfg)
        P = ex.n_ops
        read_mask = ex.is_read

        # Batched per-trace attempt schedule (admit-time work, done up front).
        attempts_np = np.ones(P, np.int64)
        attempts_np[read_mask] = self._sample_attempts(ex.ptype[read_mask])
        total_read_pages = int(read_mask.sum())
        total_attempts = int(attempts_np[read_mask].sum())
        tr_np = tr_by_type[ex.ptype]

        # Flat per-op state.  The schedules above are the NumPy source of
        # truth; the interpreter loop reads them as plain Python buffers —
        # the mechanism-independent views are converted once per expansion
        # and shared across a sweep, only the policy-dependent attempt and
        # sense-time buffers are built per run.
        adm_t, op_rid, op_die, op_ch, op_read = ex.admission_lists
        op_a = attempts_np.tolist()
        op_tr = tr_np.tolist()

        op_rem = op_a[:]            # serial: attempts left; pipelined: copy idx
        op_held = [0.0] * P         # die-held-since timestamp

        n_dies, n_ch = cfg.n_dies, cfg.n_channels
        die_busy = [0.0] * n_dies   # busy_until; inf while held
        die_tot = [0.0] * n_dies
        dieq = [deque() for _ in range(n_dies)]
        # Channels are single-server FCFS with constant-duration jobs whose
        # requests are always issued at the *current* sim time, so a
        # cumulative busy-until scalar is an exact queue: a transfer's grant
        # is max(now, busy_until) and its completion is known at issue time.
        # That removes the per-transfer completion event (and the queue) —
        # the dominant heap traffic of the retired engine.
        ch_busy = [0.0] * n_ch
        ch_tot = [0.0] * n_ch

        req_done = [0.0] * ex.n_requests

        # Heap records are 2-tuples ``(time, seq << 40 | op << 2 | opcode)``:
        # the packed int both tie-breaks FIFO (seq in the high bits — same
        # push-order discipline as the reference engine's seq field) and
        # carries the whole event, so an event costs one tuple, no closures,
        # no argument unpacking.  All state transitions are inlined: at one
        # event per read attempt the interpreter dispatch itself is the hot
        # path, and a helper call per event would cost more than the
        # transition it performs.
        heap: list = []
        push = heapq.heappush
        pop = heapq.heappop
        replace = heapq.heapreplace
        seqc = 0                      # already-shifted seq (increments 1<<40)
        _SEQ1 = 1 << 40
        _OPSHIFT_MASK = (1 << 40) - 1
        n_events = 0

        read_start_ev = _EV_COPY if pipelined else _EV_NEXT

        # Each event handler schedules AT MOST one successor event, so the
        # pop+push pair collapses into a single heapreplace sift (pop alone
        # when nothing is scheduled).  Events are peeked, dispatched, then
        # replaced — never popped first.
        ai = 0
        next_adm = adm_t[0] if P else _INF
        while True:
            # Admission cursor merged with the heap (admits never queue).
            if heap:
                top = heap[0]
                tt = top[0]
            elif next_adm < _INF:
                top = None
                tt = _INF
            else:
                break
            if next_adm <= tt:
                op = ai
                tm = next_adm
                ai += 1
                next_adm = adm_t[ai] if ai < P else _INF
                # Reads contend for their die; writes go straight to
                # the channel (program happens after the transfer).
                if op_read[op]:
                    d = op_die[op]
                    if tm >= die_busy[d] and not dieq[d]:
                        die_busy[d] = _INF
                        op_held[op] = tm
                        if pipelined:
                            op_rem[op] = 0
                        push(heap, (tm + op_tr[op],
                                    seqc | op << 2 | read_start_ev))
                        seqc += _SEQ1
                    else:
                        dieq[d].append(op)
                else:
                    c = op_ch[op]
                    b = ch_busy[c]
                    done = (b if b > tm else tm) + tdma
                    ch_busy[c] = done
                    ch_tot[c] += tdma
                    push(heap, (done, seqc | op << 2 | _EV_ACQ))
                    seqc += _SEQ1
                continue

            tm, code = top
            ev = code & 3
            op = (code & _OPSHIFT_MASK) >> 2
            n_events += 1

            if ev == _EV_COPY:
                # Pipelined copy into the cache register at tm: the sense is
                # done and the previous transfer has drained.  Issue the
                # transfer (completion time exact at issue) and schedule the
                # next copy at max(sense done, transfer drained) — both
                # already known — or end the sequence.
                c = op_ch[op]
                b = ch_busy[c]
                done = (b if b > tm else tm) + tdma
                ch_busy[c] = done
                ch_tot[c] += tdma
                i = op_rem[op]
                a = op_a[op]
                if i + 1 < a:
                    op_rem[op] = i + 1
                    tnext = tm + op_tr[op]
                    if done > tnext:
                        tnext = done
                    replace(heap, (tnext, seqc | op << 2 | _EV_COPY))
                else:
                    rid = op_rid[op]
                    fin = done + tecc
                    if fin > req_done[rid]:
                        req_done[rid] = fin
                    # Final attempt leaves the die: charge one speculative
                    # sense when the sequence actually retried.
                    rel = tm + op_tr[op] if a > 1 else tm
                    replace(heap, (rel, seqc | op << 2 | _EV_REL))
                seqc += _SEQ1
            elif ev == _EV_NEXT:
                # Serial read: sense done at tm -> transfer -> decode; on
                # failure the firmware re-senses with the next table entry.
                c = op_ch[op]
                b = ch_busy[c]
                done = (b if b > tm else tm) + tdma
                ch_busy[c] = done
                ch_tot[c] += tdma
                rem = op_rem[op] - 1
                if rem:
                    op_rem[op] = rem
                    replace(heap, (done + tecc + op_tr[op],
                                   seqc | op << 2 | _EV_NEXT))
                else:
                    rid = op_rid[op]
                    fin = done + tecc
                    if fin > req_done[rid]:
                        req_done[rid] = fin
                    # Die freed at last transfer; the decode tail is off-die.
                    replace(heap, (done, seqc | op << 2 | _EV_REL))
                seqc += _SEQ1
            elif ev == _EV_REL:
                # Die release: read end or write program end.
                d = op_die[op]
                die_tot[d] += tm - op_held[op]
                die_busy[d] = tm
                dq = dieq[d]
                if dq:
                    op2 = dq.popleft()
                    die_busy[d] = _INF
                    op_held[op2] = tm
                    if op_read[op2]:
                        if pipelined:
                            op_rem[op2] = 0
                        replace(heap, (tm + op_tr[op2],
                                       seqc | op2 << 2 | read_start_ev))
                    else:
                        replace(heap, (tm + tprog,
                                       seqc | op2 << 2 | _EV_REL))
                    seqc += _SEQ1
                else:
                    pop(heap)
                if not op_read[op]:
                    rid = op_rid[op]
                    if tm > req_done[rid]:
                        req_done[rid] = tm
            else:
                # _EV_ACQ — write transfer landed: acquire the die.
                d = op_die[op]
                if tm >= die_busy[d] and not dieq[d]:
                    die_busy[d] = _INF
                    op_held[op] = tm
                    replace(heap, (tm + tprog, seqc | op << 2 | _EV_REL))
                    seqc += _SEQ1
                else:
                    dieq[d].append(op)
                    pop(heap)

        self.events_processed = n_events

        req_done_at = np.asarray(req_done)
        self.last_req_done_us = req_done_at
        response = req_done_at - trace.arrival_us + cfg.host_overhead_us
        read_resp = response[trace.is_read]
        span = float(req_done_at.max())
        return SimStats(
            mean_us=float(response.mean()),
            p50_us=float(np.percentile(response, 50)),
            p95_us=float(np.percentile(response, 95)),
            p99_us=float(np.percentile(response, 99)),
            read_mean_us=float(read_resp.mean()) if read_resp.size else 0.0,
            n_requests=ex.n_requests,
            mean_read_attempts=(
                total_attempts / total_read_pages if total_read_pages else 0.0
            ),
            die_util=sum(die_tot) / (span * n_dies),
            channel_util=sum(ch_tot) / (span * n_ch),
        )


# -- run API ---------------------------------------------------------------


def _make_sim(cfg, condition, mechanism, seed, engine):
    if engine == "array":
        return SSDSim(cfg, condition, RetryPolicy(mechanism), seed=seed)
    if engine == "reference":
        from repro.flashsim.engine_ref import SSDSimRef

        return SSDSimRef(cfg, condition, RetryPolicy(mechanism), seed=seed)
    raise ValueError(f"unknown engine {engine!r} (use 'array' or 'reference')")


def simulate(
    workload: Workload,
    condition: OperatingCondition,
    mechanism: str,
    seed: int = 0,
    cfg: SSDConfig = DEFAULT_SSD,
    n_requests: Optional[int] = None,
    trace: Optional[RequestTrace] = None,
    engine: str = "array",
) -> SimStats:
    """Convenience wrapper: one (workload, condition, mechanism) cell.

    Pass ``trace=`` to reuse a pre-generated trace across calls (all
    mechanisms then see the *same* arrivals); otherwise the trace is
    generated (and memoized) from ``(workload, seed)``.
    """
    if trace is None:
        if n_requests is not None:
            workload = dataclasses.replace(workload, n_requests=n_requests)
        trace = cached_trace(workload, seed=seed)
    sim = _make_sim(cfg, condition, mechanism, seed + 7, engine)
    return sim.run(trace)


def compare_mechanisms(
    workload: Workload,
    condition: OperatingCondition,
    mechanisms=("baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2"),
    seed: int = 0,
    cfg: SSDConfig = DEFAULT_SSD,
    n_requests: Optional[int] = None,
    engine: str = "array",
) -> Dict[str, SimStats]:
    """All mechanisms over ONE shared trace (generated once, expanded once)."""
    if n_requests is not None:
        workload = dataclasses.replace(workload, n_requests=n_requests)
    trace = cached_trace(workload, seed=seed)
    if engine != "array":
        return {
            m: simulate(workload, condition, m, seed, cfg, trace=trace,
                        engine=engine)
            for m in mechanisms
        }
    expansion = expand_trace(trace, cfg)
    out = {}
    for m in mechanisms:
        sim = SSDSim(cfg, condition, RetryPolicy(m), seed=seed + 7)
        out[m] = sim.run(trace, expansion=expansion)
    return out


def simulate_batch(
    workload: Workload,
    conditions: Iterable[OperatingCondition],
    mechanisms: Sequence[str] = (
        "baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2",
    ),
    seeds: Sequence[int] = (0,),
    cfg: SSDConfig = DEFAULT_SSD,
    n_requests: Optional[int] = None,
    engine: str = "array",
) -> Dict[Tuple[str, OperatingCondition, int], SimStats]:
    """Sweep (mechanism x condition x seed) cells for one workload.

    Throughput-structured: each seed's trace is generated and expanded once
    and shared by every (mechanism, condition) cell; characterization
    tables (AR² safe scales, attempt histograms) are memoized per condition
    in :mod:`repro.core.characterize`, so the grid pays each JAX
    characterization exactly once.  Returns
    ``{(mechanism, condition, seed): SimStats}``.
    """
    conditions = tuple(conditions)
    if n_requests is not None:
        workload = dataclasses.replace(workload, n_requests=n_requests)
    out: Dict[Tuple[str, OperatingCondition, int], SimStats] = {}
    for s in seeds:
        trace = cached_trace(workload, seed=s)
        expansion = expand_trace(trace, cfg) if engine == "array" else None
        for cond in conditions:
            for m in mechanisms:
                sim = _make_sim(cfg, cond, m, s + 7, engine)
                if expansion is not None:
                    out[(m, cond, s)] = sim.run(trace, expansion=expansion)
                else:
                    out[(m, cond, s)] = sim.run(trace)
    return out
