"""Event-driven multi-queue SSD simulator (MQSim-analogue), layered.

A true discrete-event simulation of what matters for read-retry latency at
the device level:

  * 8 channels x 8 dies; per-die queues under a pluggable scheduling
    policy and FCFS channel arbitration;
  * every retry attempt senses on the die, transfers over the shared
    channel, and decodes on the channel's LDPC engine — retries consume
    channel bandwidth, so heavy retry regresses *other* dies' reads too.
    (With one LDPC engine per channel and tECC < tDMA the decode stage can
    never backpressure a serial channel, so decode is folded in as a fixed
    +tECC after each transfer — an exact simplification, not an
    approximation.)
  * CACHE READ semantics for PR²: the die has a page register and a cache
    register; sensing of attempt i+1 overlaps the transfer+decode of
    attempt i (the copy into the cache register waits for the previous
    transfer to finish); one speculative sense is charged to die occupancy
    when a retried sequence terminates;
  * AR² scales every attempt's tR by the characterized safe scale for the
    operating condition — resolved **per block** when the FTL tracks
    block wear — and samples attempt counts from the reduced-tR retry
    distribution so its rare extra attempts are charged;
  * the SOTA baseline [25] starts the retry search at its predicted entry,
    shrinking attempt counts ~70%.

Per-read attempt counts are sampled from the 160-chip characterization
histograms (repro.core.characterize) for the simulated (retention, P/E)
condition — the same transplant of real-device statistics into MQSim that
the paper performs.

Layered architecture
--------------------
This module is the orchestration layer of a four-module package:

  * :mod:`repro.flashsim.engine` — the array event-core: integer-opcode
    heap records ``(time, seq << 40 | op_id << 2 | opcode)``, the
    busy-until channel collapse, and op-kind dispatch;
  * :mod:`repro.flashsim.sched` — die-queue scheduling policies
    (``fcfs`` / ``host_prio`` / ``preempt``, selected by
    ``SSDConfig.scheduler`` or the run APIs' ``scheduler=`` knob);
  * :mod:`repro.flashsim.gc_online` — completion-time-triggered garbage
    collection (``GCConfig.mode = "online"`` or the ``gc="online"``
    knob);
  * **this module** — policy/CDF setup, batched attempt sampling, run
    orchestration (:class:`SSDSim`), statistics, and the
    ``simulate`` / ``compare_mechanisms`` / ``simulate_batch`` run APIs;
  * :mod:`repro.flashsim.runtime` — the parallel sweep executor behind
    the run APIs' ``workers=`` knob (process-pool fan-out of grid cells
    with deterministic assembly), complementing the engine's
    per-channel ``shard=`` decomposition.

The whole trace is expanded to flat per-page-op NumPy arrays up front
(:func:`expand_trace`); attempt counts for every read page are sampled in
one batched pass (RNG-stream-compatible with the retired per-request
sampler), and the event core interprets the flat schedule.

FTL / garbage collection (``SSDConfig.gc.enabled``)
---------------------------------------------------
By default writes program in place and the flash never fills.  With the
page-mapping FTL enabled (:mod:`repro.flashsim.ftl`):

  * ``gc="prepass"`` (default): a deterministic pre-pass maps every host
    op and interleaves GC copy-back page-ops into the admission stream —
    the PR 2 behavior, retained as the compatibility mode the
    equivalence suite pins;
  * ``gc="online"``: the FTL advances *inside* the event loop — writes
    allocate at simulated program start, GC triggers on free-block-pool
    watermarks, erased blocks return to the pool when their erase
    completes, and writes stall when the pool runs dry (see
    :mod:`repro.flashsim.gc_online`).

Either way GC page-ops run through the same heap and contend with host
reads on the die queues, GC reads sample retry attempts at the victim
block's *per-block* wear (``OperatingCondition.with_wear``), and — new
in this layer — AR² resolves its safe tR scale per block as well, so a
worn block senses at the scale its own characterization bin allows
rather than the device-level one.

The seed engine (PR 1's closure-based DES) is preserved in
:mod:`repro.flashsim.engine_ref` (``engine="reference"``); the array core
reproduces its SimStats bit-for-bit on fixed in-place traces under the
default ``scheduler="fcfs"`` (see tests/test_flashsim_equiv.py and
tests/test_sched.py) at a large wall-clock speedup (tracked in
``BENCH_sim.json`` by ``benchmarks/microbench_sim.py``).  One caveat:
die releases are scheduled with issue-time sequence numbers, so when two
events collide at the *exact same float timestamp* their order can
differ from the reference engine's; such ties are rare (a handful of
requests per hundred thousand) and shift per-request times by at most a
transfer slot, leaving every distribution statistically unchanged.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Iterable, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core import characterize as CH
from repro.core.retry import RetryPolicy
from repro.flashsim.config import (
    DEFAULT_SSD,
    FaultConfig,
    OperatingCondition,
    SSDConfig,
)
from repro.flashsim.engine import make_buffers, run_event_core
from repro.flashsim.sched import get_scheduler
from repro.flashsim.workloads import (
    RequestTrace,
    SyntheticSource,
    TraceSource,
    Truncate,
    Workload,
    cached_trace,
    get_source,
)

PAGE_TYPE_ORDER = ("lsb", "csb", "msb")

#: What the run APIs accept as a workload: a synthetic profile, a
#: registry spec string ("websearch", "msr:web_0?rescale=0.5", ...), or
#: any TraceSource.
WorkloadLike = Union[Workload, str, TraceSource]


def _pctl(a: np.ndarray, qs) -> np.ndarray:
    """``np.percentile(a, qs)`` for 1-D float64 without the per-call
    dispatch machinery (argument normalization costs more than the
    partition on sweep-cell-sized arrays).  Bit-identical to numpy's
    default linear method: same ``q/100 * (n-1)`` virtual indexes, the
    same shared partition across quantiles, and numpy's own two-sided
    lerp (the ``t >= 0.5`` branch computes ``b - (b-a)*(1-t)``).
    """
    n = a.size
    virt = np.true_divide(np.asarray(qs, np.float64), 100) * (n - 1)
    prev = np.floor(virt)
    nxt = np.minimum(prev + 1, n - 1)
    pi = prev.astype(np.intp)
    ni = nxt.astype(np.intp)
    part = np.partition(a, np.concatenate([pi, ni]))
    va, vb = part[pi], part[ni]
    t = virt - prev
    diff = vb - va
    out = va + diff * t
    hi = t >= 0.5
    out[hi] = vb[hi] - diff[hi] * (1 - t[hi])
    return out


def resolve_trace(
    workload: WorkloadLike, seed: int = 0, n_requests: Optional[int] = None
) -> RequestTrace:
    """Resolve a workload-like argument to a (cached, frozen) trace.

    :class:`Workload` profiles take the exact legacy path —
    ``dataclasses.replace(n_requests=...)`` + :func:`cached_trace` — so
    synthetic runs stay bit-identical to the pre-package module.  Spec
    strings resolve through :func:`repro.flashsim.workloads.registry.
    get_source`; for sources, ``n_requests`` adds a ``Truncate``
    transform (first N requests in arrival order), slotted *before* any
    dense footprint remap so the registry's canonical order — and the
    dense ``[0, footprint)`` guarantee — hold exactly as they would for
    ``?limit=N``.
    """
    if isinstance(workload, Workload):
        if n_requests is not None:
            workload = dataclasses.replace(workload, n_requests=n_requests)
        return cached_trace(workload, seed=seed)
    src = workload if isinstance(workload, TraceSource) else \
        get_source(workload)
    if n_requests is not None:
        if isinstance(src, SyntheticSource) and not src.transforms:
            # A bare profile spelled as a string regenerates at length N
            # exactly like the Workload-object call — the two spellings
            # must never diverge (truncating the full default-length
            # trace would give different arrays AND cost a 40x build).
            w = dataclasses.replace(src.workload, n_requests=n_requests)
            return cached_trace(w, seed=seed)
        from repro.flashsim.workloads.registry import POST_LIMIT_TRANSFORMS

        tfs = list(src.transforms)
        # Canonical ?limit=N position (defined by the registry order).
        at = next((i for i, t in enumerate(tfs)
                   if isinstance(t, POST_LIMIT_TRANSFORMS)), len(tfs))
        tfs.insert(at, Truncate(n_requests))
        src = dataclasses.replace(src, transforms=tuple(tfs))
    return src.trace(seed)


@dataclasses.dataclass
class SimStats:
    """Response-time statistics over completed requests.

    All times are microseconds; utilizations are fractions of the trace
    span.  The GC block (``wa`` onward) is populated only when the run
    went through the FTL (``SSDConfig.gc.enabled``); with the FTL off the
    defaults state the in-place-program facts (WA = 1.0, no GC traffic).
    ``gc_suspensions`` counts preempt-scheduler suspend events;
    ``write_stalls`` counts online-GC host-write stalls (both 0 when the
    feature is off).

    The fault block (``mispredicted_reads`` onward) is populated only
    when a fault model is attached (``SSDConfig.faults`` / the run APIs'
    ``faults=`` knob — :mod:`repro.flashsim.faults`); with faults off
    the defaults state the no-failure facts.  ``recovery_p99_us`` is the
    p99 response time over the *recovery-affected* requests only (0.0
    when none were).
    """

    mean_us: float            # mean response time over ALL requests (us)
    p50_us: float             # response-time percentiles, all requests (us)
    p95_us: float
    p99_us: float
    read_mean_us: float       # mean response time over host READS only (us)
    n_requests: int           # completed requests (reads + writes)
    mean_read_attempts: float # read attempts per host read page (>= 1)
    die_util: float           # busy fraction, averaged over dies [0, 1]
    channel_util: float       # busy fraction, averaged over channels [0, 1]
    read_p99_us: float = 0.0  # p99 response time over host READS only (us)
    wa: float = 1.0           # write amplification: phys/host programs
    gc_invocations: int = 0   # GC victim-collection passes
    gc_page_reads: int = 0    # pages read back by GC copy-back
    gc_page_progs: int = 0    # pages re-programmed by GC copy-back
    blocks_erased: int = 0    # blocks erased by GC
    gc_suspensions: int = 0   # preempt: GC ops suspended for host reads
    write_stalls: int = 0     # online GC: host writes stalled on free pool
    mispredicted_reads: int = 0  # AR² reduced-tR decode failures (re-read)
    rescued_reads: int = 0    # uncorrectables recovered by escalation
    parity_rebuilds: int = 0  # superpage stripe rebuilds run
    rebuild_reads: int = 0    # stripe-peer read page-ops issued
    retired_blocks: int = 0   # bad blocks retired
    program_fails: int = 0    # host programs that needed a reprogram
    erase_fails: int = 0      # erases that failed verification
    unrecoverable: int = 0    # reads lost after the full recovery ladder
    recovery_p99_us: float = 0.0  # p99 response over recovery-affected reqs
    # Closed-loop block: populated only when the NCQ frontend is on
    # (``SSDConfig.ncq_depth`` / the run APIs' ``ncq_depth=`` knob).
    # Response time decomposes exactly:  response = hostq wait
    # + device time + host_overhead_us.
    hostq_wait_mean_us: float = 0.0   # mean admission wait in the host queue
    hostq_wait_p99_us: float = 0.0    # p99 admission wait
    device_mean_us: float = 0.0       # mean admit -> complete device time
    read_device_p99_us: float = 0.0   # p99 device time over host reads —
    #                                   the QD-bounded latency figure
    throughput_iops: float = 0.0      # sustained n_requests / makespan
    max_inflight: int = 0             # peak admitted-and-incomplete requests
    cache_hit_reads: int = 0          # reads served entirely from the cache
    cache_hit_pages: int = 0          # read pages served from dirty lines
    cache_absorbed_writes: int = 0    # writes absorbed by the write cache
    cache_flush_pages: int = 0        # page programs issued by cache flushes
    cache_stalled_writes: int = 0     # writes that waited on cache capacity
    die_sense_util: float = 0.0       # fraction of span dies spent sensing
    #: Events retired by the batched lockstep (Pallas) fast path — 0 for
    #: interpreter runs, ``== n_events`` for ``engine="batched"`` runs.
    #: Observability only: excluded from equality so batched-vs-array
    #: bit-identity asserts compare the simulation outcome, not the
    #: engine that produced it.
    fast_path_events: int = dataclasses.field(default=0, compare=False)
    #: Engine that actually ran this cell — the resolved concrete engine
    #: for ``engine="auto"``, the engine's own name for explicit
    #: selections.  ``engine_fallback_reason`` is non-empty exactly when
    #: auto fell back to the interpreter: it carries the
    #: ``BatchedUnsupported`` message the explicit batched engine would
    #: have raised, so auto documents rather than hides its decision.
    #: Observability only (``compare=False``): auto-vs-explicit equality
    #: asserts compare the simulation outcome, not the selection path.
    engine_selected: str = dataclasses.field(default="", compare=False)
    engine_fallback_reason: str = dataclasses.field(default="",
                                                    compare=False)
    #: Number of sweep cells that shared this cell's kernel dispatch
    #: (0 = the cell ran alone).  Observability only (``compare=False``):
    #: fused-vs-sequential bit-identity asserts compare the simulation
    #: outcome, not the dispatch grouping.
    fused_cells: int = dataclasses.field(default=0, compare=False)

    def as_row(self) -> str:
        row = (
            f"mean={self.mean_us:9.1f}us p50={self.p50_us:8.1f} p95={self.p95_us:9.1f} "
            f"p99={self.p99_us:9.1f} attempts={self.mean_read_attempts:5.2f} "
            f"die_u={self.die_util:.2f} ch_u={self.channel_util:.2f}"
        )
        if self.wa > 1.0 or self.gc_invocations:
            row += f" wa={self.wa:.2f} gc={self.gc_invocations}"
        return row


@dataclasses.dataclass(frozen=True)
class TraceExpansion:
    """Mechanism-independent flat page-op view of a trace (admission order).

    Shared across all mechanisms of a sweep: only the per-op attempt counts
    and sense times depend on the policy, and those are sampled separately.
    """

    arrival_us: np.ndarray   # (P,) op admission time = its request's arrival (us)
    rid: np.ndarray          # (P,) owning request index
    die: np.ndarray          # (P,) die id
    chan: np.ndarray         # (P,) channel id
    ptype: np.ndarray        # (P,) page type index into PAGE_TYPE_ORDER
    is_read: np.ndarray      # (P,) bool
    page_id: np.ndarray      # (P,) logical page number (FTL input)
    n_requests: int

    @property
    def n_ops(self) -> int:
        return int(self.rid.shape[0])

    @functools.cached_property
    def admission_lists(self):
        """Mechanism-independent per-op buffers as plain Python lists.

        The event loop reads flat lists (scalar list indexing is ~4x faster
        than ndarray scalar access); converting once here instead of per
        ``run()`` lets a mechanism sweep reuse the views.
        """
        return (
            self.arrival_us.tolist(),
            self.rid.tolist(),
            self.die.tolist(),
            self.chan.tolist(),
            self.is_read.tolist(),
        )

    @functools.cached_property
    def admission_arrays(self):
        """The same per-op buffers as dtype-pinned numpy columns.

        The batched engine consumes whole columns (``_lane_tables``
        re-``asarray``s every buffer), so batched-resolved runs take the
        expansion's own arrays and skip the list round-trip entirely;
        the interpreter keeps :attr:`admission_lists` (scalar list
        indexing is faster there).  Values are identical either way.
        """
        return (
            np.asarray(self.arrival_us, np.float64),
            np.asarray(self.rid, np.int64),
            np.asarray(self.die, np.int64),
            np.asarray(self.chan, np.int64),
            np.asarray(self.is_read, bool),
        )


def expand_trace(trace: RequestTrace, cfg: SSDConfig = DEFAULT_SSD) -> TraceExpansion:
    """Vectorized request -> page-op expansion (no per-request Python loop).

    Ops come out in admission order.  Traces from :func:`generate_trace`
    arrive sorted; externally-supplied traces (e.g. future MSR/blktrace
    ingestion) may not, so unsorted arrivals are stably sorted here —
    matching the retired heap engine's (time, request-index) admission
    order exactly.
    """
    arrival = trace.arrival_us
    n = len(arrival)
    if np.any(np.diff(arrival) < 0):
        req_order = np.argsort(arrival, kind="stable")
    else:
        req_order = np.arange(n)
    n_pages = trace.n_pages[req_order]
    rid = np.repeat(req_order, n_pages)
    # Within-request page offsets 0..n_pages[r]-1, flattened.
    starts = np.cumsum(n_pages) - n_pages
    off = np.arange(int(n_pages.sum()), dtype=np.int64) - np.repeat(starts, n_pages)
    page_ids = trace.start_page[rid] + off
    die = (page_ids % cfg.n_dies).astype(np.int64)
    return TraceExpansion(
        arrival_us=trace.arrival_us[rid],
        rid=rid,
        die=die,
        chan=cfg.channel_of(die),
        ptype=(page_ids % 3).astype(np.int64),
        is_read=trace.is_read[rid],
        page_id=page_ids.astype(np.int64),
        n_requests=n,
    )


class SSDSim:
    """One simulation run = (workload trace, operating condition, policy)."""

    def __init__(
        self,
        cfg: SSDConfig = DEFAULT_SSD,
        condition: OperatingCondition = OperatingCondition(),
        policy: RetryPolicy = RetryPolicy("baseline"),
        seed: int = 0,
        engine: str = "array",
    ):
        if engine not in ("array", "batched", "auto"):
            raise ValueError(
                f"SSDSim engine must be 'array', 'batched' or 'auto', got "
                f"{engine!r} (engine='reference' is SSDSimRef)"
            )
        if engine == "batched":
            from repro.flashsim.engine_batched import check_batched_config

            check_batched_config(cfg)
        # engine="auto" defers resolution to run(), where validate= is
        # known; it never raises BatchedUnsupported — the decision (and
        # any fallback reason) is recorded on the returned SimStats.
        self.cfg = cfg
        self.cond = condition
        self.policy = policy
        self.seed = seed
        self.engine = engine
        self.rng = np.random.default_rng(seed)
        self.events_processed = 0
        # AR² tR scale for this operating condition (characterized table).
        if policy.adaptive_tr:
            if policy.tr_scale == "auto":
                self.tr_scale = CH.characterize_condition(
                    condition.retention_days, condition.pec
                ).safe_tr_scale
            else:
                self.tr_scale = float(policy.tr_scale)
        else:
            self.tr_scale = 1.0
        # Per-block AR² scale memo: snapped effective P/E -> safe scale.
        self._wear_scales: Dict[float, float] = {}
        # Worn-block attempt-CDF memo: (page type, wear) -> CDF.  One
        # resolution per distinct (condition, mechanism, wear bin) for
        # the whole run — the sharded/batched paths and every unique-wear
        # loop hit this dict instead of re-deriving the worn condition
        # and re-keying the characterization LRU per lookup.
        self._wear_cdfs: Dict[Tuple[str, float], np.ndarray] = {}
        # Unscaled per-page-type tR (scale applied per op: device-level for
        # unworn blocks, per-block for GC-worn ones).
        self._tr_base = np.array(
            [cfg.timing.tr_us[pt] for pt in PAGE_TYPE_ORDER]
        )
        # Per-page-type attempt-count CDFs under this mechanism (cached
        # across SSDSim instances in repro.core.characterize).
        self._attempt_cdfs = {
            pt: CH.attempt_cdf(
                condition.retention_days,
                condition.pec,
                page_type=pt,
                sota=policy.sota_start,
                tr_scale=self.tr_scale,
            )
            for pt in PAGE_TYPE_ORDER
        }

    # -- attempt sampling ----------------------------------------------------

    def _scale_for(self, wear_pec: float) -> float:
        """AR² tR scale at a block's effective wear (per-block resolution).

        Zero wear — or a non-adaptive / pinned-scale policy — uses the
        device-condition scale.  Worn blocks resolve the condition per
        block (``OperatingCondition.with_wear``), snap the effective P/E
        count up to the characterization grid, and look up *that* bin's
        safe scale: a worn block senses at the scale its own
        characterization allows, not the (faster) device-level one.
        Memoized per snapped bin, so the set of distinct lookups stays
        grid-bounded.
        """
        if (wear_pec <= 0.0 or not self.policy.adaptive_tr
                or self.policy.tr_scale != "auto"):
            return self.tr_scale
        worn = self.cond.with_wear(wear_pec)
        key = CH.snap_pec(worn.pec)
        s = self._wear_scales.get(key)
        if s is None:
            s = CH.characterize_condition(
                self.cond.retention_days, key
            ).safe_tr_scale
            self._wear_scales[key] = s
        return s

    def _cdf_for(self, page_type: str, wear_pec: float) -> np.ndarray:
        """Attempt CDF for one page type at a block's effective wear.

        ``wear_pec`` is the block-local added P/E count from GC erases.
        Zero wear uses the device-condition table untouched (bit-identical
        to the pre-FTL sampler); worn blocks resolve the condition per
        block (``OperatingCondition.with_wear``), snap the effective
        P/E count up to the characterization grid (so the handful of
        distinct wear bins stays cache-bounded), and — for adaptive-tR
        policies — evaluate the search at the *per-block* AR² scale
        (:meth:`_scale_for`), so the attempt distribution and the sense
        time of a worn block come from the same characterization bin.
        """
        if wear_pec <= 0.0:
            return self._attempt_cdfs[page_type]
        key = (page_type, wear_pec)
        cdf = self._wear_cdfs.get(key)
        if cdf is None:
            worn = self.cond.with_wear(wear_pec)
            cdf = CH.attempt_cdf(
                self.cond.retention_days,
                CH.snap_pec(worn.pec),
                page_type=page_type,
                sota=self.policy.sota_start,
                tr_scale=self._scale_for(wear_pec),
            )
            self._wear_cdfs[key] = cdf
        return cdf

    def _draw_attempts(self, ptype_idx: int, wear_pec: float,
                       rng: Optional[np.random.Generator] = None) -> int:
        """One attempt count at (page type, block wear).

        The online-GC driver samples reads one at a time as the mapping
        resolves them (wear is not known until the simulated instant),
        passing its per-die substream as ``rng`` so the draw order is a
        die-local property (shard-invariant); ``None`` falls back to the
        run-global ``self.rng``.
        """
        pt = PAGE_TYPE_ORDER[ptype_idx]
        r = self.rng if rng is None else rng
        a = int(np.searchsorted(self._cdf_for(pt, wear_pec), r.random()))
        return a if a > 1 else 1

    def _tr_for(self, ptype_idx: int, wear_pec: float) -> float:
        """Per-attempt sense time at (page type, block wear)."""
        return float(self._tr_base[ptype_idx]) * self._scale_for(wear_pec)

    def _sample_attempts(
        self,
        page_types: np.ndarray,
        wear_pec: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """Inverse-CDF attempt counts for a batch of page-type indices.

        Consumes ``self.rng`` exactly like the retired per-request sampler
        (one uniform per read page, in admission order), so a given seed
        yields identical attempts under both engines.  With ``wear_pec``
        (FTL runs) each read samples from the CDF of its block's effective
        wear; the uniform stream is unchanged, only the inverse CDF varies.
        """
        u = self.rng.random(page_types.shape)
        out = np.empty(page_types.shape, np.int64)
        for i, pt in enumerate(PAGE_TYPE_ORDER):
            m = page_types == i
            if not m.any():
                continue
            if wear_pec is None:
                out[m] = np.searchsorted(self._attempt_cdfs[pt], u[m])
            else:
                um, wm = u[m], wear_pec[m]
                om = np.empty(um.shape, np.int64)
                for wv in np.unique(wm):
                    sel = wm == wv
                    om[sel] = np.searchsorted(self._cdf_for(pt, float(wv)),
                                              um[sel])
                out[m] = om
        return np.maximum(out, 1)

    # -- run orchestration ---------------------------------------------------

    def _tr_scales_for_schedule(self, schedule, read_like: np.ndarray):
        """Per-op AR² scale over an FTL schedule (per-block resolution)."""
        P = schedule.n_ops
        scale = np.full(P, self.tr_scale)
        if self.policy.adaptive_tr and self.policy.tr_scale == "auto":
            wear = schedule.wear_pec
            worn = read_like & (wear > 0.0)
            if worn.any():
                for wv in np.unique(wear[worn]):
                    scale[worn & (wear == wv)] = self._scale_for(float(wv))
        return scale

    def _prepare(
        self,
        trace: RequestTrace,
        expansion: Optional[TraceExpansion] = None,
        schedule=None,
        validate: bool = False,
    ) -> "_PreparedRun":
        """Everything :meth:`run` does before the engine dispatch.

        Resolves the engine, samples the attempt schedule (consuming
        ``self.rng`` in admission order, exactly as the sequential path
        does), and builds the admission buffers.  Split out so the fused
        sweep driver can prepare many cells, run them in one kernel
        dispatch, and :meth:`_finalize` each — any fusion decision sees
        byte-identical inputs and produces byte-identical stats.
        """
        cfg, t = self.cfg, self.cfg.timing
        tprog = t.tprog_us
        pipelined = self.policy.pipelined
        sched_policy = get_scheduler(cfg.scheduler)
        gc_mode = cfg.gc.mode if cfg.gc.enabled else None
        closed = cfg.ncq_depth is not None
        engine_selected = self.engine
        engine_reason = ""
        if self.engine == "auto":
            from repro.flashsim.engine_batched import resolve_engine

            engine_selected, engine_reason = resolve_engine(cfg, validate)
        batched = engine_selected == "batched"
        if batched and self.engine == "batched":
            from repro.flashsim.engine_batched import check_batched_config

            check_batched_config(cfg)
        if closed:
            if gc_mode == "online":
                raise NotImplementedError(
                    "closed-loop frontend (ncq_depth) does not support "
                    "online GC yet — use gc='prepass'"
                )
            if sched_policy.preemptive:
                raise NotImplementedError(
                    "closed-loop frontend (ncq_depth) does not support "
                    "the preempt scheduler"
                )

        if schedule is None and gc_mode == "prepass":
            from repro.flashsim.ftl import build_ftl_schedule

            schedule = build_ftl_schedule(trace, cfg)

        fm = None
        if cfg.faults is not None:
            # Fresh model per run: per-die fault substreams seeded
            # (run seed, salt, die), separate from the attempt streams.
            from repro.flashsim.faults import FaultModel

            fm = FaultModel(cfg.faults, cfg, self.cond, self.policy,
                            self.seed, self)

        online = None
        if schedule is not None:
            # Prepass FTL path: host + GC page-ops, attempts and AR² tR
            # scale resolved per block wear.
            from repro.flashsim import ftl as _ftl

            P = schedule.n_ops
            host_read_np = schedule.kind == _ftl.OP_READ
            read_like_np = schedule.kind <= _ftl.OP_GC_READ
            attempts_np = np.ones(P, np.int64)
            attempts_np[read_like_np] = self._sample_attempts(
                schedule.ptype[read_like_np],
                schedule.wear_pec[read_like_np],
            )
            total_read_pages = int(host_read_np.sum())
            total_attempts = int(attempts_np[host_read_np].sum())
            tr_np = (self._tr_base[schedule.ptype]
                     * self._tr_scales_for_schedule(schedule, read_like_np))
            if not (fm is None and batched):
                (adm_t, op_rid, op_die, op_ch, op_read,
                 op_erase, op_dur) = schedule.admission_lists
            n_requests = schedule.n_requests
            # Only the closed-loop frontend and the fault planner read
            # the per-op lpn list; batched runs are neither.
            op_lpn = (schedule.lpn.tolist()
                      if schedule.lpn is not None and not batched
                      else None)
            if fm is None and batched:
                # Batched runs read whole columns; hand them the
                # schedule's numpy views and the per-cell sample arrays
                # directly — same values, no list round-trip.
                (adm_a, rid_a, die_a, ch_a, read_a,
                 erase_a, dur_a) = schedule.admission_arrays
                bufs = make_buffers(adm_a, rid_a, die_a, ch_a, read_a,
                                    erase_a, dur_a, attempts_np, tr_np)
            elif fm is None:
                bufs = make_buffers(adm_t, op_rid, op_die, op_ch, op_read,
                                    op_erase, op_dur, attempts_np.tolist(),
                                    tr_np.tolist())
            else:
                from repro.flashsim.faults import plan_faults

                plan = plan_faults(
                    fm, adm_t, op_rid, op_die, op_ch, op_read, op_erase,
                    op_dur, attempts_np.tolist(), tr_np.tolist(),
                    schedule.ptype.tolist(), schedule.wear_pec.tolist(),
                    lpn=op_lpn,
                )
                bufs = make_buffers(plan.arrival, plan.rid, plan.die,
                                    plan.ch, plan.read, plan.erase,
                                    plan.dur, plan.a, plan.tr)
                bufs.xa, bufs.xtr = plan.xa, plan.xtr
                op_lpn = plan.lpn
        elif gc_mode == "online":
            # Online FTL path: host ops only in the admission stream;
            # attempt counts / tR resolve at admission, GC injects live.
            from repro.flashsim.gc_online import OnlineGC

            ex = expansion if expansion is not None else expand_trace(trace, cfg)
            P = ex.n_ops
            adm_t, op_rid, op_die, op_ch, op_read = ex.admission_lists
            # The buffers grow (GC injection): copy the shared views.
            bufs = make_buffers(
                adm_t, list(op_rid), list(op_die), list(op_ch),
                list(op_read), [False] * P, [tprog] * P,
                [1] * P, [0.0] * P,
            )
            if fm is not None:
                bufs.xa = [0] * P
                bufs.xtr = [0.0] * P
            online = OnlineGC(cfg, ex, self, faults=fm)
            n_requests = ex.n_requests
            op_lpn = None
            total_read_pages = total_attempts = 0   # engine-accumulated
        else:
            ex = expansion if expansion is not None else expand_trace(trace, cfg)
            P = ex.n_ops
            read_mask = ex.is_read

            # Batched per-trace attempt schedule (admit-time work, up front).
            attempts_np = np.ones(P, np.int64)
            attempts_np[read_mask] = self._sample_attempts(ex.ptype[read_mask])
            total_read_pages = int(read_mask.sum())
            total_attempts = int(attempts_np[read_mask].sum())
            tr_np = (self._tr_base * self.tr_scale)[ex.ptype]
            n_requests = ex.n_requests
            # Only the closed-loop frontend and the fault planner read
            # the per-op lpn list; batched runs are neither.
            op_lpn = None if batched else ex.page_id.tolist()
            if fm is None and batched:
                # Batched runs read whole columns; hand them the
                # expansion's numpy views and the per-cell sample arrays
                # directly — same values, no list round-trip.
                adm_a, rid_a, die_a, ch_a, read_a = ex.admission_arrays
                bufs = make_buffers(adm_a, rid_a, die_a, ch_a, read_a,
                                    np.zeros(P, bool),
                                    np.full(P, tprog, np.float64),
                                    attempts_np, tr_np)
            elif fm is None:
                adm_t, op_rid, op_die, op_ch, op_read = ex.admission_lists
                bufs = make_buffers(adm_t, op_rid, op_die, op_ch, op_read,
                                    [False] * P,    # no erases without FTL
                                    [tprog] * P,    # write-like ops: tPROG
                                    attempts_np.tolist(), tr_np.tolist())
            else:
                adm_t, op_rid, op_die, op_ch, op_read = ex.admission_lists
                from repro.flashsim.faults import plan_faults

                plan = plan_faults(
                    fm, adm_t, op_rid, op_die, op_ch, op_read,
                    [False] * P, [tprog] * P, attempts_np.tolist(),
                    tr_np.tolist(), ex.ptype.tolist(), None,
                    lpn=op_lpn,
                )
                bufs = make_buffers(plan.arrival, plan.rid, plan.die,
                                    plan.ch, plan.read, plan.erase,
                                    plan.dur, plan.a, plan.tr)
                bufs.xa, bufs.xtr = plan.xa, plan.xtr
                op_lpn = plan.lpn

        return _PreparedRun(
            trace=trace, validate=validate, pipelined=pipelined,
            sched_policy=sched_policy, closed=closed, batched=batched,
            engine_selected=engine_selected, engine_reason=engine_reason,
            schedule=schedule, online=online, fm=fm, bufs=bufs,
            n_requests=n_requests, op_lpn=op_lpn,
            total_read_pages=total_read_pages,
            total_attempts=total_attempts,
        )

    def run(
        self,
        trace: RequestTrace,
        expansion: Optional[TraceExpansion] = None,
        schedule=None,
        validate: bool = False,
        shard: bool = False,
        trace_phases: bool = False,
    ) -> SimStats:
        """Simulate one trace.

        ``expansion`` (in-place and online-GC runs) or ``schedule`` (an
        :class:`repro.flashsim.ftl.FTLSchedule`, prepass-GC runs) may be
        shared across the mechanisms of a sweep.  When ``cfg.gc.enabled``
        and no schedule is supplied, the configured GC mode decides:
        ``prepass`` builds the FTL schedule here; ``online`` attaches a
        :class:`repro.flashsim.gc_online.OnlineGC` driver to the event
        core.  ``shard=True`` runs the event core as one loop per channel
        with a deterministic merge — bit-identical to the monolithic
        default (see :mod:`repro.flashsim.engine`).  ``validate=True``
        turns on the engine's work-conservation checks (test
        instrumentation).

        With ``cfg.ncq_depth`` set the run goes through the closed-loop
        frontend (:func:`repro.flashsim.engine.run_closed_loop`): NCQ-
        gated admission, optional write-back cache, explicit channel DMA
        phase.  Closed-loop supports prepass GC and faults but not the
        preempt scheduler or online GC; ``shard=`` is ignored (the NCQ
        couples channels through the shared slot pool — the monolithic
        closed loop is the defined semantics for any ``shard``/
        ``workers`` setting).  ``trace_phases=True`` (closed loop only)
        records per-op sense/transfer/program intervals into
        ``self.last_phases`` for the interval-invariant property tests.
        """
        cfg = self.cfg
        prep = self._prepare(trace, expansion=expansion,
                             schedule=schedule, validate=validate)
        bufs, n_requests = prep.bufs, prep.n_requests
        if prep.closed:
            from repro.flashsim.engine import run_closed_loop

            cache = None
            if cfg.host_cache is not None:
                from repro.flashsim.hostcache import WriteCache

                cache = WriteCache(cfg.host_cache)
            res = run_closed_loop(
                cfg, prep.pipelined, prep.sched_policy, bufs, n_requests,
                trace.arrival_us.tolist(), trace.is_read.tolist(),
                cfg.ncq_depth, op_lpn=prep.op_lpn, cache=cache,
                validate=validate, trace_phases=trace_phases,
            )
        elif prep.batched:
            from repro.flashsim.engine_batched import run_event_core_batched

            res = run_event_core_batched(cfg, prep.pipelined,
                                         prep.sched_policy, bufs,
                                         n_requests, online=prep.online,
                                         validate=validate)
        else:
            res = run_event_core(cfg, prep.pipelined, prep.sched_policy,
                                 bufs, n_requests, online=prep.online,
                                 validate=validate, shard=shard)
        return self._finalize(prep, res)

    def _finalize(self, prep: "_PreparedRun", res) -> SimStats:
        """Assemble :class:`SimStats` from one engine result — the back
        half of :meth:`run` (pure code motion from it; any change here
        is a bit-parity change for every engine and fusion decision)."""
        cfg = self.cfg
        trace = prep.trace
        schedule, online, fm = prep.schedule, prep.online, prep.fm
        closed = prep.closed
        n_requests = prep.n_requests
        engine_selected = prep.engine_selected
        engine_reason = prep.engine_reason
        total_attempts = prep.total_attempts
        total_read_pages = prep.total_read_pages
        closed_kw = {}
        if closed:
            gc_suspensions = 0
            total_attempts = res.attempts_issued
            total_read_pages = res.read_pages_issued
            self.last_phases = res.phases
        else:
            gc_suspensions = res.gc_suspensions
            self.last_phases = None
            if online is not None:
                total_attempts = res.online_attempts
                total_read_pages = res.online_read_pages
        self.events_processed = res.n_events
        self.last_gc_suspensions = gc_suspensions
        self.last_die_busy_us = float(sum(res.die_tot))

        req_done_at = np.asarray(res.req_done)
        self.last_req_done_us = req_done_at
        response = req_done_at - trace.arrival_us + cfg.host_overhead_us
        read_resp = response[trace.is_read]
        span = float(req_done_at.max())
        if closed:
            # Closed-loop span: the makespan of everything the device did
            # (flush programs / GC can outlive the last host completion).
            span = max(span, max(res.die_busy), max(res.ch_busy))
            admit_at = np.asarray(res.req_admit)
            wait = admit_at - trace.arrival_us
            device = req_done_at - admit_at
            read_dev = device[trace.is_read]
            closed_kw = dict(
                hostq_wait_mean_us=float(wait.mean()),
                hostq_wait_p99_us=float(np.percentile(wait, 99)),
                device_mean_us=float(device.mean()),
                read_device_p99_us=(
                    float(np.percentile(read_dev, 99))
                    if read_dev.size else 0.0
                ),
                throughput_iops=n_requests / span * 1e6,
                max_inflight=res.max_inflight,
                cache_hit_reads=res.full_hit_reads,
                cache_hit_pages=res.hit_pages,
                cache_absorbed_writes=res.absorbed_writes,
                cache_flush_pages=res.flush_pages,
                cache_stalled_writes=res.stalled_writes,
                die_sense_util=sum(res.die_sense_tot) / (span * cfg.n_dies),
            )
        gc_kw = {}
        if schedule is not None or online is not None:
            # GC traffic can outlive the last host completion (an erase
            # triggered by the final write holds its die past it); extend
            # the utilization span to the last resource release so
            # die/channel utilization stays a fraction in [0, 1].  After
            # the loop every die_busy/ch_busy entry is a finite release
            # time.  (In-place runs keep the host-completion span for
            # bit-parity with the reference engine.)
            span = max(span, max(res.die_busy), max(res.ch_busy))
            fs = schedule.stats if schedule is not None else online.stats()
            gc_kw = dict(
                wa=fs.write_amplification,
                gc_invocations=fs.gc_invocations,
                gc_page_reads=fs.gc_page_reads,
                gc_page_progs=fs.gc_page_progs,
                blocks_erased=fs.blocks_erased,
                gc_suspensions=gc_suspensions,
                write_stalls=online.write_stalls if online is not None else 0,
            )
        elif gc_suspensions:
            gc_kw = dict(gc_suspensions=gc_suspensions)
        fault_kw = {}
        if fm is not None:
            oc = fm.outcome
            rec_p99 = 0.0
            if oc.affected_rids:
                idx = np.fromiter(oc.affected_rids, np.int64,
                                  len(oc.affected_rids))
                rec_p99 = float(np.percentile(response[idx], 99))
            fault_kw = dict(
                mispredicted_reads=oc.mispredicted_reads,
                rescued_reads=oc.rescued_reads,
                parity_rebuilds=oc.parity_rebuilds,
                rebuild_reads=oc.rebuild_reads,
                retired_blocks=oc.retired_blocks,
                program_fails=oc.program_fails,
                erase_fails=oc.erase_fails,
                unrecoverable=oc.unrecoverable,
                recovery_p99_us=rec_p99,
            )
        # One percentile call shares the partition pass across the three
        # quantiles; per-q interpolation is unchanged, so the values are
        # bit-identical to three separate calls.
        p50, p95, p99 = _pctl(response, (50.0, 95.0, 99.0))
        return SimStats(
            mean_us=float(response.mean()),
            p50_us=float(p50),
            p95_us=float(p95),
            p99_us=float(p99),
            read_mean_us=float(read_resp.mean()) if read_resp.size else 0.0,
            n_requests=n_requests,
            mean_read_attempts=(
                total_attempts / total_read_pages if total_read_pages else 0.0
            ),
            die_util=sum(res.die_tot) / (span * cfg.n_dies),
            channel_util=sum(res.ch_tot) / (span * cfg.n_channels),
            read_p99_us=(
                float(_pctl(read_resp, (99.0,))[0]) if read_resp.size
                else 0.0
            ),
            fast_path_events=getattr(res, "fast_path_events", 0),
            engine_selected=engine_selected,
            engine_fallback_reason=engine_reason,
            fused_cells=getattr(res, "fused_cells", 0),
            **gc_kw,
            **fault_kw,
            **closed_kw,
        )


@dataclasses.dataclass
class _PreparedRun:
    """Inputs of one engine dispatch, held between :meth:`SSDSim._prepare`
    and :meth:`SSDSim._finalize` so the fused sweep driver can batch many
    cells into one kernel launch."""

    trace: RequestTrace
    validate: bool
    pipelined: bool
    sched_policy: object
    closed: bool
    batched: bool
    engine_selected: str
    engine_reason: str
    schedule: object
    online: object
    fm: object
    bufs: object
    n_requests: int
    op_lpn: object
    total_read_pages: int
    total_attempts: int


def _run_prepared_fused(items):
    """Run many prepared batched-eligible cells in fused kernel dispatches.

    ``items``: sequence of ``(sim, prep)`` pairs (from
    :meth:`SSDSim._prepare`, every cell resolved to the batched engine).
    Dispatches them through
    :func:`repro.flashsim.engine_batched.run_event_cores_fused` — cells
    grouped by static kernel parameters, each group one kernel launch —
    and finalizes each cell on its own sim.  Bit-identical to calling
    ``sim.run(...)`` per cell (the cell-axis law); raises
    :class:`~repro.flashsim.engine_batched.BatchedUnsupported` before
    any dispatch if a cell is ineligible (callers pre-filter, so this is
    a fail-fast guard, never a silent fallback).  Returns one
    :class:`SimStats` per item, in order.
    """
    from repro.flashsim.engine_batched import (FusedRun,
                                               run_event_cores_fused)

    runs = [FusedRun(sim.cfg, prep.pipelined, prep.sched_policy,
                     prep.bufs, prep.n_requests) for sim, prep in items]
    res_list = run_event_cores_fused(runs)
    return [sim._finalize(prep, res)
            for (sim, prep), res in zip(items, res_list)]


# -- run API ---------------------------------------------------------------


def _with_knobs(
    cfg: SSDConfig, scheduler: Optional[str], gc: Optional[str],
    faults: Optional[FaultConfig] = None,
    ncq_depth: Optional[int] = None,
    host_cache=None,
) -> SSDConfig:
    """Overlay the run-API ``scheduler=`` / ``gc=`` / ``faults=`` knobs
    onto a config.

    ``scheduler`` picks the die-queue policy; ``gc`` is ``"off"``,
    ``"prepass"``, or ``"online"`` (the latter two imply
    ``gc.enabled=True``); ``faults`` attaches a
    :class:`~repro.flashsim.config.FaultConfig`; ``ncq_depth`` /
    ``host_cache`` switch on the closed-loop frontend
    (:class:`~repro.flashsim.config.HostCacheConfig`).  None leaves the
    config untouched.
    """
    if scheduler is not None:
        cfg = dataclasses.replace(cfg, scheduler=scheduler)
    if faults is not None:
        cfg = dataclasses.replace(cfg, faults=faults)
    if ncq_depth is not None:
        cfg = dataclasses.replace(cfg, ncq_depth=ncq_depth)
    if host_cache is not None:
        cfg = dataclasses.replace(cfg, host_cache=host_cache)
    if gc is not None:
        if gc == "off":
            gcc = dataclasses.replace(cfg.gc, enabled=False)
        elif gc in ("prepass", "online"):
            gcc = dataclasses.replace(cfg.gc, enabled=True, mode=gc)
        else:
            raise ValueError(
                f"gc knob must be 'off', 'prepass' or 'online', got {gc!r}"
            )
        cfg = dataclasses.replace(cfg, gc=gcc)
    return cfg


def _shared_views(trace, cfg):
    """(expansion, schedule) pair shared by every mechanism of a sweep.

    Online GC has no shareable schedule (the FTL advances inside each
    run), so only the expansion is shared there.
    """
    expansion = expand_trace(trace, cfg)
    if not cfg.gc.enabled or cfg.gc.mode != "prepass":
        return expansion, None
    from repro.flashsim.ftl import build_ftl_schedule

    return expansion, build_ftl_schedule(trace, cfg, expansion=expansion)


def _fuse_resolved(cfg, engine: str, fuse: Optional[bool]) -> bool:
    """Whether a sweep over ``cfg`` takes the fused batched path.

    True iff fusion is enabled (the ``fuse=`` knob, defaulting to
    ``cfg.fuse``) *and* the config resolves inside the batched matrix
    for the requested engine.  ``engine="batched"`` with an ineligible
    config returns False so the sequential loop raises the exact
    :class:`BatchedUnsupported` the non-fused path would — fusion never
    changes error behavior, and ``engine="auto"`` fallbacks record
    their reason per cell as before.
    """
    if engine not in ("batched", "auto"):
        return False
    if not (cfg.fuse if fuse is None else fuse):
        return False
    from repro.flashsim.engine_batched import resolve_engine

    return resolve_engine(cfg)[0] == "batched"


def _make_sim(cfg, condition, mechanism, seed, engine):
    if engine in ("array", "batched", "auto"):
        # "batched": SSDSim validates the config against the batched
        # core's supported matrix (ring-lowerable scheduler / gc
        # off|prepass / no faults / open loop) and raises
        # BatchedUnsupported outside it.  "auto" never raises — it
        # resolves per run (validate-aware) and records the decision on
        # SimStats.engine_selected / engine_fallback_reason.
        return SSDSim(cfg, condition, RetryPolicy(mechanism), seed=seed,
                      engine=engine)
    if engine == "reference":
        if cfg.faults is not None:
            raise NotImplementedError(
                "faults require the array engine (the reference engine "
                "predates the fault-injection subsystem)"
            )
        if cfg.ncq_depth is not None:
            raise NotImplementedError(
                "the closed-loop frontend (ncq_depth) requires the array "
                "engine"
            )
        from repro.flashsim.engine_ref import SSDSimRef

        return SSDSimRef(cfg, condition, RetryPolicy(mechanism), seed=seed)
    raise ValueError(
        f"unknown engine {engine!r} (use 'array', 'batched', 'auto' or "
        f"'reference')"
    )


def simulate(
    workload: WorkloadLike,
    condition: OperatingCondition,
    mechanism: str,
    seed: int = 0,
    cfg: SSDConfig = DEFAULT_SSD,
    n_requests: Optional[int] = None,
    trace: Optional[RequestTrace] = None,
    engine: Optional[str] = None,
    scheduler: Optional[str] = None,
    gc: Optional[str] = None,
    shard: bool = False,
    faults: Optional[FaultConfig] = None,
    ncq_depth: Optional[int] = None,
    host_cache=None,
    validate: bool = False,
) -> SimStats:
    """Convenience wrapper: one (workload, condition, mechanism) cell.

    ``workload`` is a synthetic :class:`Workload` profile, a trace-source
    spec string (``"websearch"``, ``"msr:web_0?rescale=0.5"`` — see
    :mod:`repro.flashsim.workloads.registry`), or any
    :class:`~repro.flashsim.workloads.TraceSource`.  Pass ``trace=`` to
    reuse a pre-generated trace across calls (all mechanisms then see
    the *same* arrivals); otherwise the trace is resolved (and memoized)
    from ``(workload, seed)``.  ``scheduler=`` (``"fcfs"`` /
    ``"host_prio"`` / ``"host_prio_aged"`` / ``"preempt"``) and ``gc=``
    (``"off"`` / ``"prepass"`` / ``"online"``) overlay the config without
    building an ``SSDConfig`` by hand.  With GC enabled the trace runs
    through the page-mapping FTL (:mod:`repro.flashsim.ftl`) and the
    returned stats carry WA/GC counters; the reference engine predates
    the FTL and the scheduler layer and rejects both.  ``shard=True``
    runs the array event core as one loop per channel (bit-identical;
    :mod:`repro.flashsim.engine`); the reference engine rejects it.
    ``engine="batched"`` runs all channel loops in lockstep inside one
    compiled kernel (:mod:`repro.flashsim.engine_batched`) — bit-
    identical to the array engine on its supported matrix (fcfs /
    host_prio / host_prio_aged[:bound] schedulers, gc off/prepass, no
    faults, open loop) and raising
    :class:`~repro.flashsim.engine_batched.BatchedUnsupported`
    elsewhere, never silently falling back.  ``engine="auto"`` picks the
    batched core when the cell is inside that matrix and the array
    interpreter otherwise — results identical either way, with the
    decision (and any fallback reason) recorded on
    ``SimStats.engine_selected`` / ``engine_fallback_reason``.
    ``faults=`` attaches a :class:`~repro.flashsim.config.FaultConfig`
    (:mod:`repro.flashsim.faults` — array engine only).  ``ncq_depth=``
    switches on the closed-loop frontend (bounded NCQ admission, explicit
    channel DMA phase); ``host_cache=`` additionally attaches the host
    write-back cache (:class:`~repro.flashsim.config.HostCacheConfig`).
    Closed-loop runs are always monolithic (``shard`` is ignored) and
    reject the preempt scheduler, online GC, and the reference engine.
    """
    if engine is None:
        engine = cfg.engine
    cfg = _with_knobs(cfg, scheduler, gc, faults, ncq_depth, host_cache)
    if trace is None:
        trace = resolve_trace(workload, seed=seed, n_requests=n_requests)
    sim = _make_sim(cfg, condition, mechanism, seed + 7, engine)
    if shard:
        if engine == "reference":
            raise NotImplementedError(
                "shard=True requires the array engine (the reference "
                "engine predates the sharded event core)"
            )
        # engine="batched" IS the per-channel decomposition: shard=True
        # is a no-op there (the lockstep core always runs one lane per
        # channel, bit-identical to both array paths).
        return sim.run(trace, shard=True, validate=validate)
    return sim.run(trace, validate=validate)


def compare_mechanisms(
    workload: WorkloadLike,
    condition: OperatingCondition,
    mechanisms=("baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2"),
    seed: int = 0,
    cfg: SSDConfig = DEFAULT_SSD,
    n_requests: Optional[int] = None,
    engine: Optional[str] = None,
    scheduler: Optional[str] = None,
    gc: Optional[str] = None,
    shard: bool = False,
    workers: int = 1,
    faults: Optional[FaultConfig] = None,
    ncq_depth: Optional[int] = None,
    host_cache=None,
    fuse: Optional[bool] = None,
) -> Dict[str, SimStats]:
    """All mechanisms over ONE shared trace (resolved once, expanded once).

    ``workload`` accepts profiles, registry spec strings, and
    :class:`TraceSource`\\ s (see :func:`resolve_trace`) — real ingested
    traces replay through the identical shared-trace machinery.  With
    prepass GC the FTL pre-pass also runs once and its schedule is
    shared: every mechanism sees identical GC traffic and per-block wear,
    so mechanism deltas isolate the retry policy.  (Online GC advances
    the FTL inside each run — mechanisms still share the trace and
    expansion, but GC timing legitimately responds to each mechanism's
    latencies.)  ``shard=True`` selects the per-channel sharded event
    core; ``workers > 1`` fans mechanisms over a process pool
    (:func:`repro.flashsim.runtime.run_compare` — fork platforms only,
    results identical to the inline run; the fan-out shares the array
    expansion/schedule with workers, so it supports the ``array`` and
    ``batched`` engines — ``engine="reference"`` runs its mechanisms
    sequentially as before).
    ``ncq_depth=`` / ``host_cache=`` select the closed-loop frontend for
    every mechanism (see :func:`simulate`).  ``fuse=`` controls the
    fused sweep path (default ``cfg.fuse``): when the config resolves
    inside the batched matrix, the mechanisms' op tables are stacked
    along the kernel's lane axis and dispatched together (one launch
    per static-shape group) — results bit-identical to the sequential
    batched runs either way.
    """
    if engine is None:
        engine = cfg.engine
    cfg = _with_knobs(cfg, scheduler, gc, faults, ncq_depth, host_cache)
    if workers > 1 and engine in ("array", "batched", "auto"):
        from repro.flashsim.runtime import run_compare

        return run_compare(workload, condition, mechanisms, seed, cfg,
                           n_requests, None, None, shard, workers,
                           engine=engine, fuse=fuse)
    trace = resolve_trace(workload, seed=seed, n_requests=n_requests)
    if engine == "reference":
        return {
            m: simulate(workload, condition, m, seed, cfg, trace=trace,
                        engine=engine, shard=shard)
            for m in mechanisms
        }
    expansion, schedule = _shared_views(trace, cfg)
    if _fuse_resolved(cfg, engine, fuse) and len(tuple(mechanisms)) > 1:
        items = []
        for m in mechanisms:
            sim = _make_sim(cfg, condition, m, seed + 7, engine)
            items.append((sim, sim._prepare(trace, expansion=expansion,
                                            schedule=schedule)))
        return dict(zip(mechanisms, _run_prepared_fused(items)))
    out = {}
    for m in mechanisms:
        sim = _make_sim(cfg, condition, m, seed + 7, engine)
        out[m] = sim.run(trace, expansion=expansion, schedule=schedule,
                         shard=shard)
    return out


def simulate_batch(
    workload: WorkloadLike,
    conditions: Iterable[OperatingCondition],
    mechanisms: Sequence[str] = (
        "baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2",
    ),
    seeds: Sequence[int] = (0,),
    cfg: SSDConfig = DEFAULT_SSD,
    n_requests: Optional[int] = None,
    engine: Optional[str] = None,
    scheduler: Optional[str] = None,
    gc: Optional[str] = None,
    shard: bool = False,
    workers: int = 1,
    faults: Optional[FaultConfig] = None,
    journal=None,
    ncq_depth: Optional[int] = None,
    host_cache=None,
    fuse: Optional[bool] = None,
) -> Dict[Tuple[str, OperatingCondition, int], SimStats]:
    """Sweep (mechanism x condition x seed) cells for one workload.

    Throughput-structured: each seed's trace is generated and expanded
    once — and, with prepass GC, run through the FTL pre-pass once —
    then shared by every (mechanism, condition) cell; characterization
    tables (AR² safe scales, attempt histograms) are memoized per
    condition in :mod:`repro.core.characterize`, so the grid pays each
    JAX characterization exactly once.  ``workload`` accepts profiles,
    registry spec strings, and :class:`TraceSource`\\ s; for
    deterministic file traces, seed variation comes from seeded
    transforms (e.g. ``?sample=0.9``) — without one, every seed replays
    the same trace (only attempt sampling varies, via ``seed + 7``).
    ``shard=True`` selects the per-channel sharded event core;
    ``workers > 1`` schedules seed groups across a process pool
    (:func:`repro.flashsim.runtime.run_sweep`) — cell values and dict
    order are identical for every worker count.  ``faults=`` attaches a
    :class:`~repro.flashsim.config.FaultConfig` to every cell;
    ``journal=`` names a checkpoint file — completed cells are recorded
    as they finish and a re-run resumes from them byte-identically
    (:func:`repro.flashsim.runtime.run_cells`).
    ``ncq_depth=`` / ``host_cache=`` select the closed-loop frontend for
    every cell (see :func:`simulate`).  ``fuse=`` controls the fused
    sweep path (default ``cfg.fuse``): when the config resolves inside
    the batched matrix, each seed's (condition × mechanism) cells are
    stacked along the kernel's lane axis and dispatched together (one
    launch per static-shape group) — cell values bit-identical to the
    sequential batched runs for any fusion decision.
    Returns ``{(mechanism, condition, seed): SimStats}``.
    """
    if engine is None:
        engine = cfg.engine
    if shard and engine == "reference":
        raise NotImplementedError(
            "shard=True requires the array engine (the reference engine "
            "predates the sharded event core)"
        )
    cfg = _with_knobs(cfg, scheduler, gc, faults, ncq_depth, host_cache)
    if workers > 1 or journal is not None:
        from repro.flashsim.runtime import run_sweep

        # Engine-agnostic: seed-group cells re-enter this function with
        # workers=1 inside each worker, reference engine included.
        return run_sweep(workload, conditions, mechanisms, seeds, cfg,
                         n_requests, engine, None, None, shard, workers,
                         journal=journal, fuse=fuse)
    conditions = tuple(conditions)
    seeds = tuple(seeds)
    fused = (_fuse_resolved(cfg, engine, fuse)
             and len(conditions) * len(mechanisms) * len(seeds) > 1)
    if fused:
        # Cross-seed fusion: every (seed, condition, mechanism) cell of
        # the grid is prepared (each seed's trace resolved and expanded
        # once, shared by its cells) and dispatched through ONE fused
        # engine call — the engine chunks the whole grid by static
        # kernel shape and step homogeneity, so same-condition cells of
        # different seeds share a dispatch.  Output order (seed-major)
        # is unchanged.
        keys, items = [], []
        for s in seeds:
            trace = resolve_trace(workload, seed=s,
                                  n_requests=n_requests)
            expansion, schedule = _shared_views(trace, cfg)
            for cond in conditions:
                for m in mechanisms:
                    sim = _make_sim(cfg, cond, m, s + 7, engine)
                    keys.append((m, cond, s))
                    items.append((sim, sim._prepare(
                        trace, expansion=expansion, schedule=schedule)))
        return dict(zip(keys, _run_prepared_fused(items)))
    out: Dict[Tuple[str, OperatingCondition, int], SimStats] = {}
    for s in seeds:
        trace = resolve_trace(workload, seed=s, n_requests=n_requests)
        if engine in ("array", "batched", "auto"):
            expansion, schedule = _shared_views(trace, cfg)
        else:
            expansion = schedule = None
        for cond in conditions:
            for m in mechanisms:
                sim = _make_sim(cfg, cond, m, s + 7, engine)
                if expansion is not None:
                    out[(m, cond, s)] = sim.run(trace, expansion=expansion,
                                                schedule=schedule,
                                                shard=shard)
                else:
                    out[(m, cond, s)] = sim.run(trace)
    return out
