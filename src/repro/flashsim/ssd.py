"""Event-driven multi-queue SSD simulator (MQSim-analogue).

A true discrete-event simulation of what matters for read-retry latency at
the device level:

  * 8 channels x 8 dies; FCFS die queues and FCFS channel arbitration;
  * every retry attempt senses on the die, transfers over the shared
    channel, and decodes on the channel's LDPC engine — retries consume
    channel bandwidth, so heavy retry regresses *other* dies' reads too.
    (With one LDPC engine per channel and tECC < tDMA the decode stage can
    never backpressure a serial channel, so decode is folded in as a fixed
    +tECC after each transfer — an exact simplification, not an
    approximation.)
  * CACHE READ semantics for PR²: the die has a page register and a cache
    register; sensing of attempt i+1 overlaps the transfer+decode of
    attempt i (the copy into the cache register waits for the previous
    transfer to finish); one speculative sense is charged to die occupancy
    when a retried sequence terminates;
  * AR² scales every attempt's tR by the characterized safe scale for the
    simulated operating condition, and samples attempt counts from the
    reduced-tR retry distribution so its rare extra attempts are charged;
  * the SOTA baseline [25] starts the retry search at its predicted entry,
    shrinking attempt counts ~70%.

Per-read attempt counts are sampled from the 160-chip characterization
histograms (repro.core.characterize) for the simulated (retention, P/E)
condition — the same transplant of real-device statistics into MQSim that
the paper performs.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from typing import Dict, List, Optional

import numpy as np

from repro.core import characterize as CH
from repro.core.retry import RetryPolicy
from repro.flashsim.config import DEFAULT_SSD, OperatingCondition, SSDConfig
from repro.flashsim.workloads import RequestTrace, Workload, generate_trace

PAGE_TYPE_ORDER = ("lsb", "csb", "msb")


@dataclasses.dataclass
class SimStats:
    """Response-time statistics over completed requests (microseconds)."""

    mean_us: float
    p50_us: float
    p95_us: float
    p99_us: float
    read_mean_us: float
    n_requests: int
    mean_read_attempts: float
    die_util: float
    channel_util: float

    def as_row(self) -> str:
        return (
            f"mean={self.mean_us:9.1f}us p50={self.p50_us:8.1f} p95={self.p95_us:9.1f} "
            f"p99={self.p99_us:9.1f} attempts={self.mean_read_attempts:5.2f} "
            f"die_u={self.die_util:.2f} ch_u={self.channel_util:.2f}"
        )


class _Resource:
    """Single-server FCFS resource (a die or a channel)."""

    __slots__ = ("busy_until", "queue", "busy_total")

    def __init__(self):
        self.busy_until = 0.0
        self.queue: deque = deque()
        self.busy_total = 0.0


class SSDSim:
    """One simulation run = (workload trace, operating condition, policy)."""

    def __init__(
        self,
        cfg: SSDConfig = DEFAULT_SSD,
        condition: OperatingCondition = OperatingCondition(),
        policy: RetryPolicy = RetryPolicy("baseline"),
        seed: int = 0,
    ):
        self.cfg = cfg
        self.cond = condition
        self.policy = policy
        self.rng = np.random.default_rng(seed)
        # AR² tR scale for this operating condition (characterized table).
        if policy.adaptive_tr:
            if policy.tr_scale == "auto":
                self.tr_scale = CH.characterize_condition(
                    condition.retention_days, condition.pec
                ).safe_tr_scale
            else:
                self.tr_scale = float(policy.tr_scale)
        else:
            self.tr_scale = 1.0
        # Per-page-type attempt-count CDFs under this mechanism.
        self._attempt_cdfs = {}
        for pt in PAGE_TYPE_ORDER:
            hist = CH.attempt_histogram(
                condition.retention_days,
                condition.pec,
                page_type=pt,
                sota=policy.sota_start,
                tr_scale=self.tr_scale,
            )
            self._attempt_cdfs[pt] = np.cumsum(hist)

    # -- attempt sampling ----------------------------------------------------

    def _sample_attempts(self, page_types: np.ndarray) -> np.ndarray:
        u = self.rng.random(page_types.shape)
        out = np.empty(page_types.shape, np.int64)
        for i, pt in enumerate(PAGE_TYPE_ORDER):
            m = page_types == i
            if m.any():
                out[m] = np.searchsorted(self._attempt_cdfs[pt], u[m])
        return np.maximum(out, 1)

    # -- discrete-event engine -------------------------------------------------

    def run(self, trace: RequestTrace) -> SimStats:
        cfg, t = self.cfg, self.cfg.timing
        tdma, tecc, tprog = t.tdma_us, t.tecc_us, t.tprog_us
        pipelined = self.policy.pipelined
        tr_by_type = (
            np.array([t.tr_us[pt] for pt in PAGE_TYPE_ORDER]) * self.tr_scale
        )

        dies = [_Resource() for _ in range(cfg.n_dies)]
        chans = [_Resource() for _ in range(cfg.n_channels)]

        heap: List = []
        seq = 0

        def push(time_, fn, *args):
            nonlocal seq
            heapq.heappush(heap, (time_, seq, fn, args))
            seq += 1

        n = len(trace.arrival_us)
        req_remaining = np.zeros(n, np.int64)
        req_done_at = np.zeros(n)
        total_attempts = 0
        total_read_pages = 0

        # ------- resource helpers ------------------------------------------

        def die_acquire(d: int, now: float, fn, *args):
            res = dies[d]
            if now >= res.busy_until and not res.queue:
                res.busy_until = np.inf  # held until explicit release
                fn(now, *args)
            else:
                res.queue.append((fn, args))

        def die_release(d: int, now: float, held_since: float):
            res = dies[d]
            res.busy_total += now - held_since
            res.busy_until = now
            if res.queue:
                fn, args = res.queue.popleft()
                res.busy_until = np.inf
                fn(now, *args)

        def chan_request(ch: int, now: float, dur: float, fn):
            """FCFS channel: start the transfer asap; fn fires at completion.

            The channel chains its own job-done events, so callbacks never
            manage channel state.
            """
            res = chans[ch]
            if res.busy_until <= now and not res.queue:
                res.busy_until = now + dur
                res.busy_total += dur
                push(now + dur, _chan_job_done, ch, fn)
            else:
                res.queue.append((dur, fn))

        def _chan_job_done(tm: float, ch: int, fn):
            res = chans[ch]
            if res.queue:
                dur, fn2 = res.queue.popleft()
                res.busy_until = tm + dur
                res.busy_total += dur
                push(tm + dur, _chan_job_done, ch, fn2)
            fn(tm)

        # ------- read page-op state machines --------------------------------

        def page_complete(now: float, rid: int):
            req_remaining[rid] -= 1
            req_done_at[rid] = max(req_done_at[rid], now)

        def start_read_serial(now: float, rid: int, d: int, ch: int,
                              a: int, tr: float):
            held_since = now
            state = {"i": 0}

            def xfer_done(tm):
                ecc_done = tm + tecc
                state["i"] += 1
                if state["i"] >= a:
                    die_release(d, tm, held_since)       # die freed at last xfer
                    page_complete(ecc_done, rid)
                else:
                    # Decode failed; firmware re-senses with the next entry.
                    push(ecc_done + tr, sense_fire)

            def sense_fire(tm):
                chan_request(ch, tm, tdma, xfer_done)

            push(now + tr, sense_fire)

        def start_read_pipelined(now: float, rid: int, d: int, ch: int,
                                 a: int, tr: float):
            held_since = now
            sense_done_t = [None] * a       # per-attempt milestones
            xfer_done_t = [None] * a
            copied = [False] * a

            def try_copy(i: int, tm: float):
                """copy_i fires when sense i is done and cache reg is free."""
                if copied[i] or sense_done_t[i] is None:
                    return
                if i > 0 and xfer_done_t[i - 1] is None:
                    return
                tc = max(sense_done_t[i], xfer_done_t[i - 1] if i else 0.0)
                copied[i] = True
                chan_request(ch, tc, tdma, lambda tm2: on_xfer(i, tm2))
                if i + 1 < a:
                    push(tc + tr, lambda tm2: on_sense(i + 1, tm2))
                else:
                    # Final attempt leaves the die: charge one speculative
                    # sense when the sequence actually retried.
                    spec = tr if a > 1 else 0.0
                    push(tc + spec, lambda tm2: die_release(d, tm2, held_since))

            def on_sense(i: int, tm: float):
                sense_done_t[i] = tm
                try_copy(i, tm)

            def on_xfer(i: int, tm: float):
                xfer_done_t[i] = tm
                if i + 1 < a:
                    try_copy(i + 1, tm)
                if i == a - 1:
                    page_complete(tm + tecc, rid)

            push(now + tr, lambda tm: on_sense(0, tm))

        # ------- write page-op ----------------------------------------------

        def start_write(now: float, rid: int, d: int, ch: int):
            def xfer_done(tm):
                die_acquire(d, tm, prog_start)

            def prog_start(tm):
                push(tm + tprog, lambda tm2: prog_done(tm2))
                state["held"] = tm

            def prog_done(tm):
                die_release(d, tm, state["held"])
                page_complete(tm, rid)

            state = {"held": now}
            chan_request(ch, now, tdma, xfer_done)

        # ------- request admission ------------------------------------------

        def admit(now: float, rid: int):
            pages = int(trace.n_pages[rid])
            first = int(trace.start_page[rid])
            req_remaining[rid] = pages
            page_ids = first + np.arange(pages)
            if trace.is_read[rid]:
                ptypes = (page_ids % 3).astype(np.int64)
                attempts = self._sample_attempts(ptypes)
                nonlocal_totals[0] += int(attempts.sum())
                nonlocal_totals[1] += pages
                for j in range(pages):
                    d = int(page_ids[j] % cfg.n_dies)
                    ch = d % cfg.n_channels
                    a = int(attempts[j])
                    tr = float(tr_by_type[ptypes[j]])
                    starter = start_read_pipelined if pipelined else start_read_serial
                    die_acquire(d, now, starter, rid, d, ch, a, tr)
            else:
                for j in range(pages):
                    d = int(page_ids[j] % cfg.n_dies)
                    ch = d % cfg.n_channels
                    start_write(now, rid, d, ch)

        nonlocal_totals = [0, 0]  # attempts, read pages

        for rid in range(n):
            push(float(trace.arrival_us[rid]), admit, rid)

        # ------- main loop ----------------------------------------------------

        while heap:
            tm, _, fn, args = heapq.heappop(heap)
            fn(tm, *args)

        total_attempts, total_read_pages = nonlocal_totals
        response = req_done_at - trace.arrival_us + cfg.host_overhead_us
        read_resp = response[trace.is_read]
        span = float(req_done_at.max())
        return SimStats(
            mean_us=float(response.mean()),
            p50_us=float(np.percentile(response, 50)),
            p95_us=float(np.percentile(response, 95)),
            p99_us=float(np.percentile(response, 99)),
            read_mean_us=float(read_resp.mean()) if read_resp.size else 0.0,
            n_requests=n,
            mean_read_attempts=(
                total_attempts / total_read_pages if total_read_pages else 0.0
            ),
            die_util=sum(r.busy_total for r in dies) / (span * cfg.n_dies),
            channel_util=sum(r.busy_total for r in chans) / (span * cfg.n_channels),
        )


def simulate(
    workload: Workload,
    condition: OperatingCondition,
    mechanism: str,
    seed: int = 0,
    cfg: SSDConfig = DEFAULT_SSD,
    n_requests: Optional[int] = None,
) -> SimStats:
    """Convenience wrapper: one (workload, condition, mechanism) cell."""
    if n_requests is not None:
        workload = dataclasses.replace(workload, n_requests=n_requests)
    trace = generate_trace(workload, seed=seed)
    sim = SSDSim(cfg, condition, RetryPolicy(mechanism), seed=seed + 7)
    return sim.run(trace)


def compare_mechanisms(
    workload: Workload,
    condition: OperatingCondition,
    mechanisms=("baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2"),
    seed: int = 0,
    cfg: SSDConfig = DEFAULT_SSD,
    n_requests: Optional[int] = None,
) -> Dict[str, SimStats]:
    return {
        m: simulate(workload, condition, m, seed, cfg, n_requests)
        for m in mechanisms
    }
