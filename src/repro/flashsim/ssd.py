"""Event-driven multi-queue SSD simulator (MQSim-analogue), array event-core.

A true discrete-event simulation of what matters for read-retry latency at
the device level:

  * 8 channels x 8 dies; FCFS die queues and FCFS channel arbitration;
  * every retry attempt senses on the die, transfers over the shared
    channel, and decodes on the channel's LDPC engine — retries consume
    channel bandwidth, so heavy retry regresses *other* dies' reads too.
    (With one LDPC engine per channel and tECC < tDMA the decode stage can
    never backpressure a serial channel, so decode is folded in as a fixed
    +tECC after each transfer — an exact simplification, not an
    approximation.)
  * CACHE READ semantics for PR²: the die has a page register and a cache
    register; sensing of attempt i+1 overlaps the transfer+decode of
    attempt i (the copy into the cache register waits for the previous
    transfer to finish); one speculative sense is charged to die occupancy
    when a retried sequence terminates;
  * AR² scales every attempt's tR by the characterized safe scale for the
    simulated operating condition, and samples attempt counts from the
    reduced-tR retry distribution so its rare extra attempts are charged;
  * the SOTA baseline [25] starts the retry search at its predicted entry,
    shrinking attempt counts ~70%.

Per-read attempt counts are sampled from the 160-chip characterization
histograms (repro.core.characterize) for the simulated (retention, P/E)
condition — the same transplant of real-device statistics into MQSim that
the paper performs.

Engine architecture
-------------------
The event core is an integer-opcode interpreter over flat arrays:

  * the whole trace is expanded to flat per-page-op NumPy arrays up front
    (:func:`expand_trace`), and attempt counts for every read page are
    sampled in one batched pass — the RNG stream is consumed in the same
    order as the retired per-request sampler, so attempt assignments are
    bit-identical for a given seed;
  * heap records are 2-tuples ``(time, seq << 40 | op_id << 2 | opcode)``
    — no closures, no argument tuples; the serial and PR²-pipelined read
    state machines, the write path, and block erases are opcode
    transitions over preallocated per-op state buffers;
  * admissions never enter the heap: page-ops are pre-sorted by arrival
    time and merged into the event loop with a moving cursor;
  * die FCFS state lives in flat ``busy_until``/``busy_total`` buffers
    with per-die FIFO queues;
  * channels are single-server FCFS with constant-duration transfers whose
    requests are always issued at the current sim time, so channel state
    collapses to a cumulative busy-until scalar: a transfer's grant and
    completion times are exact at issue, eliminating the per-transfer
    completion event (and the channel queues) entirely — one heap event
    per read attempt instead of two.

FTL / garbage collection (``SSDConfig.gc.enabled``)
---------------------------------------------------
By default writes program in place and the flash never fills.  With the
page-mapping FTL enabled (:mod:`repro.flashsim.ftl`), a deterministic
pre-pass maps every host op and interleaves GC copy-back page-ops
(``OP_GC_READ`` / ``OP_GC_PROG`` / ``OP_ERASE``) into the admission
stream.  Inside the event loop they are ordinary page-ops scheduled
through the same heap — GC reads run the policy's read state machine
(with retry attempts sampled at the victim block's *per-block* wear via
``OperatingCondition.with_wear``), GC programs transfer over the channel
and hold the die for tPROG, and erases hold the die for ``t_erase_us`` —
so GC traffic contends with host reads on the die queues, and SimStats
gains write-amplification / GC counters plus host-read p99.

The seed engine (PR 1's closure-based DES) is preserved in
:mod:`repro.flashsim.engine_ref` (``engine="reference"``); the array core
reproduces its SimStats bit-for-bit on fixed in-place traces (see
tests/test_flashsim_equiv.py) at a large wall-clock speedup (tracked in
``BENCH_sim.json`` by ``benchmarks/microbench_sim.py``).  The reference
engine predates the FTL and only validates the in-place path.  One
caveat: die releases are scheduled with issue-time sequence numbers, so
when two events collide at the *exact same float timestamp* their order
can differ from the reference engine's; such ties are rare (a handful of
requests per hundred thousand) and shift per-request times by at most a
transfer slot, leaving every distribution statistically unchanged.
"""

from __future__ import annotations

import dataclasses
import functools
import heapq
from collections import deque
from typing import Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

from repro.core import characterize as CH
from repro.core.retry import RetryPolicy
from repro.flashsim.config import DEFAULT_SSD, OperatingCondition, SSDConfig
from repro.flashsim.workloads import RequestTrace, Workload, cached_trace

PAGE_TYPE_ORDER = ("lsb", "csb", "msb")

#: Event opcodes (low 2 bits of a heap record's packed code).
_EV_NEXT = 0    # serial read: sense done -> issue transfer, schedule next
_EV_COPY = 1    # pipelined read: copy into cache register -> issue transfer
_EV_ACQ = 2     # write: transfer landed -> acquire die for programming
_EV_REL = 3     # die release (read end / program end / erase end)

_INF = float("inf")


@dataclasses.dataclass
class SimStats:
    """Response-time statistics over completed requests.

    All times are microseconds; utilizations are fractions of the trace
    span.  The GC block (``wa`` onward) is populated only when the run
    went through the FTL (``SSDConfig.gc.enabled``); with the FTL off the
    defaults state the in-place-program facts (WA = 1.0, no GC traffic).
    """

    mean_us: float            # mean response time over ALL requests (us)
    p50_us: float             # response-time percentiles, all requests (us)
    p95_us: float
    p99_us: float
    read_mean_us: float       # mean response time over host READS only (us)
    n_requests: int           # completed requests (reads + writes)
    mean_read_attempts: float # read attempts per host read page (>= 1)
    die_util: float           # busy fraction, averaged over dies [0, 1]
    channel_util: float       # busy fraction, averaged over channels [0, 1]
    read_p99_us: float = 0.0  # p99 response time over host READS only (us)
    wa: float = 1.0           # write amplification: phys/host programs
    gc_invocations: int = 0   # GC victim-collection passes
    gc_page_reads: int = 0    # pages read back by GC copy-back
    gc_page_progs: int = 0    # pages re-programmed by GC copy-back
    blocks_erased: int = 0    # blocks erased by GC

    def as_row(self) -> str:
        row = (
            f"mean={self.mean_us:9.1f}us p50={self.p50_us:8.1f} p95={self.p95_us:9.1f} "
            f"p99={self.p99_us:9.1f} attempts={self.mean_read_attempts:5.2f} "
            f"die_u={self.die_util:.2f} ch_u={self.channel_util:.2f}"
        )
        if self.wa > 1.0 or self.gc_invocations:
            row += f" wa={self.wa:.2f} gc={self.gc_invocations}"
        return row


@dataclasses.dataclass(frozen=True)
class TraceExpansion:
    """Mechanism-independent flat page-op view of a trace (admission order).

    Shared across all mechanisms of a sweep: only the per-op attempt counts
    and sense times depend on the policy, and those are sampled separately.
    """

    arrival_us: np.ndarray   # (P,) op admission time = its request's arrival (us)
    rid: np.ndarray          # (P,) owning request index
    die: np.ndarray          # (P,) die id
    chan: np.ndarray         # (P,) channel id
    ptype: np.ndarray        # (P,) page type index into PAGE_TYPE_ORDER
    is_read: np.ndarray      # (P,) bool
    page_id: np.ndarray      # (P,) logical page number (FTL input)
    n_requests: int

    @property
    def n_ops(self) -> int:
        return int(self.rid.shape[0])

    @functools.cached_property
    def admission_lists(self):
        """Mechanism-independent per-op buffers as plain Python lists.

        The event loop reads flat lists (scalar list indexing is ~4x faster
        than ndarray scalar access); converting once here instead of per
        ``run()`` lets a mechanism sweep reuse the views.
        """
        return (
            self.arrival_us.tolist(),
            self.rid.tolist(),
            self.die.tolist(),
            self.chan.tolist(),
            self.is_read.tolist(),
        )


def expand_trace(trace: RequestTrace, cfg: SSDConfig = DEFAULT_SSD) -> TraceExpansion:
    """Vectorized request -> page-op expansion (no per-request Python loop).

    Ops come out in admission order.  Traces from :func:`generate_trace`
    arrive sorted; externally-supplied traces (e.g. future MSR/blktrace
    ingestion) may not, so unsorted arrivals are stably sorted here —
    matching the retired heap engine's (time, request-index) admission
    order exactly.
    """
    arrival = trace.arrival_us
    n = len(arrival)
    if np.any(np.diff(arrival) < 0):
        req_order = np.argsort(arrival, kind="stable")
    else:
        req_order = np.arange(n)
    n_pages = trace.n_pages[req_order]
    rid = np.repeat(req_order, n_pages)
    # Within-request page offsets 0..n_pages[r]-1, flattened.
    starts = np.cumsum(n_pages) - n_pages
    off = np.arange(int(n_pages.sum()), dtype=np.int64) - np.repeat(starts, n_pages)
    page_ids = trace.start_page[rid] + off
    die = (page_ids % cfg.n_dies).astype(np.int64)
    return TraceExpansion(
        arrival_us=trace.arrival_us[rid],
        rid=rid,
        die=die,
        chan=cfg.channel_of(die),
        ptype=(page_ids % 3).astype(np.int64),
        is_read=trace.is_read[rid],
        page_id=page_ids.astype(np.int64),
        n_requests=n,
    )


class SSDSim:
    """One simulation run = (workload trace, operating condition, policy)."""

    def __init__(
        self,
        cfg: SSDConfig = DEFAULT_SSD,
        condition: OperatingCondition = OperatingCondition(),
        policy: RetryPolicy = RetryPolicy("baseline"),
        seed: int = 0,
    ):
        self.cfg = cfg
        self.cond = condition
        self.policy = policy
        self.rng = np.random.default_rng(seed)
        self.events_processed = 0
        # AR² tR scale for this operating condition (characterized table).
        if policy.adaptive_tr:
            if policy.tr_scale == "auto":
                self.tr_scale = CH.characterize_condition(
                    condition.retention_days, condition.pec
                ).safe_tr_scale
            else:
                self.tr_scale = float(policy.tr_scale)
        else:
            self.tr_scale = 1.0
        # Per-page-type attempt-count CDFs under this mechanism (cached
        # across SSDSim instances in repro.core.characterize).
        self._attempt_cdfs = {
            pt: CH.attempt_cdf(
                condition.retention_days,
                condition.pec,
                page_type=pt,
                sota=policy.sota_start,
                tr_scale=self.tr_scale,
            )
            for pt in PAGE_TYPE_ORDER
        }

    # -- attempt sampling ----------------------------------------------------

    def _cdf_for(self, page_type: str, wear_pec: float) -> np.ndarray:
        """Attempt CDF for one page type at a block's effective wear.

        ``wear_pec`` is the block-local added P/E count from GC erases.
        Zero wear uses the device-condition table untouched (bit-identical
        to the pre-FTL sampler); worn blocks resolve the condition per
        block (``OperatingCondition.with_wear``) and snap the effective
        P/E count up to the characterization grid, so the handful of
        distinct wear bins stays cache-bounded.  The search still executes
        at the *device-condition* AR² tR scale — the firmware looks its
        scale up per condition, not per block (per-block scale resolution
        is a noted ROADMAP follow-up) — so worn blocks honestly pay extra
        attempts rather than silently sensing slower.
        """
        if wear_pec <= 0.0:
            return self._attempt_cdfs[page_type]
        worn = self.cond.with_wear(wear_pec)
        return CH.attempt_cdf(
            self.cond.retention_days,
            CH.snap_pec(worn.pec),
            page_type=page_type,
            sota=self.policy.sota_start,
            tr_scale=self.tr_scale,
        )

    def _sample_attempts(
        self,
        page_types: np.ndarray,
        wear_pec: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """Inverse-CDF attempt counts for a batch of page-type indices.

        Consumes ``self.rng`` exactly like the retired per-request sampler
        (one uniform per read page, in admission order), so a given seed
        yields identical attempts under both engines.  With ``wear_pec``
        (FTL runs) each read samples from the CDF of its block's effective
        wear; the uniform stream is unchanged, only the inverse CDF varies.
        """
        u = self.rng.random(page_types.shape)
        out = np.empty(page_types.shape, np.int64)
        for i, pt in enumerate(PAGE_TYPE_ORDER):
            m = page_types == i
            if not m.any():
                continue
            if wear_pec is None:
                out[m] = np.searchsorted(self._attempt_cdfs[pt], u[m])
            else:
                um, wm = u[m], wear_pec[m]
                om = np.empty(um.shape, np.int64)
                for wv in np.unique(wm):
                    sel = wm == wv
                    om[sel] = np.searchsorted(self._cdf_for(pt, float(wv)),
                                              um[sel])
                out[m] = om
        return np.maximum(out, 1)

    # -- array event-core ----------------------------------------------------

    def run(
        self,
        trace: RequestTrace,
        expansion: Optional[TraceExpansion] = None,
        schedule=None,
    ) -> SimStats:
        """Simulate one trace.

        ``expansion`` (in-place runs) or ``schedule`` (an
        :class:`repro.flashsim.ftl.FTLSchedule`, FTL/GC runs) may be
        shared across the mechanisms of a sweep.  When ``cfg.gc.enabled``
        and no schedule is supplied, the FTL pre-pass runs here.
        """
        cfg, t = self.cfg, self.cfg.timing
        tdma, tecc, tprog = t.tdma_us, t.tecc_us, t.tprog_us
        pipelined = self.policy.pipelined
        tr_by_type = (
            np.array([t.tr_us[pt] for pt in PAGE_TYPE_ORDER]) * self.tr_scale
        )

        if schedule is None and cfg.gc.enabled:
            from repro.flashsim.ftl import build_ftl_schedule

            schedule = build_ftl_schedule(trace, cfg)

        if schedule is not None:
            # FTL path: host + GC page-ops, attempts sampled per block wear.
            from repro.flashsim import ftl as _ftl

            P = schedule.n_ops
            host_read_np = schedule.kind == _ftl.OP_READ
            read_like_np = schedule.kind <= _ftl.OP_GC_READ
            attempts_np = np.ones(P, np.int64)
            attempts_np[read_like_np] = self._sample_attempts(
                schedule.ptype[read_like_np],
                schedule.wear_pec[read_like_np],
            )
            total_read_pages = int(host_read_np.sum())
            total_attempts = int(attempts_np[host_read_np].sum())
            tr_np = tr_by_type[schedule.ptype]
            (adm_t, op_rid, op_die, op_ch, op_read,
             op_erase, op_dur) = schedule.admission_lists
            n_requests = schedule.n_requests
        else:
            ex = expansion if expansion is not None else expand_trace(trace, cfg)
            P = ex.n_ops
            read_mask = ex.is_read

            # Batched per-trace attempt schedule (admit-time work, up front).
            attempts_np = np.ones(P, np.int64)
            attempts_np[read_mask] = self._sample_attempts(ex.ptype[read_mask])
            total_read_pages = int(read_mask.sum())
            total_attempts = int(attempts_np[read_mask].sum())
            tr_np = tr_by_type[ex.ptype]
            adm_t, op_rid, op_die, op_ch, op_read = ex.admission_lists
            op_erase = [False] * P      # no erase traffic without the FTL
            op_dur = [tprog] * P        # write-like ops all program-length
            n_requests = ex.n_requests

        # Flat per-op state.  The schedules above are the NumPy source of
        # truth; the interpreter loop reads them as plain Python buffers —
        # the mechanism-independent views are converted once per
        # expansion/schedule and shared across a sweep, only the
        # policy-dependent attempt and sense-time buffers are built per run.
        op_a = attempts_np.tolist()
        op_tr = tr_np.tolist()

        op_rem = op_a[:]            # serial: attempts left; pipelined: copy idx
        op_held = [0.0] * P         # die-held-since timestamp

        n_dies, n_ch = cfg.n_dies, cfg.n_channels
        die_busy = [0.0] * n_dies   # busy_until; inf while held
        die_tot = [0.0] * n_dies
        dieq = [deque() for _ in range(n_dies)]
        # Channels are single-server FCFS with constant-duration jobs whose
        # requests are always issued at the *current* sim time, so a
        # cumulative busy-until scalar is an exact queue: a transfer's grant
        # is max(now, busy_until) and its completion is known at issue time.
        # That removes the per-transfer completion event (and the queue) —
        # the dominant heap traffic of the retired engine.
        ch_busy = [0.0] * n_ch
        ch_tot = [0.0] * n_ch

        req_done = [0.0] * n_requests

        # Heap records are 2-tuples ``(time, seq << 40 | op << 2 | opcode)``:
        # the packed int both tie-breaks FIFO (seq in the high bits — same
        # push-order discipline as the reference engine's seq field) and
        # carries the whole event, so an event costs one tuple, no closures,
        # no argument unpacking.  All state transitions are inlined: at one
        # event per read attempt the interpreter dispatch itself is the hot
        # path, and a helper call per event would cost more than the
        # transition it performs.
        heap: list = []
        push = heapq.heappush
        pop = heapq.heappop
        replace = heapq.heapreplace
        seqc = 0                      # already-shifted seq (increments 1<<40)
        _SEQ1 = 1 << 40
        _OPSHIFT_MASK = (1 << 40) - 1
        n_events = 0

        read_start_ev = _EV_COPY if pipelined else _EV_NEXT

        # Each event handler schedules AT MOST one successor event, so the
        # pop+push pair collapses into a single heapreplace sift (pop alone
        # when nothing is scheduled).  Events are peeked, dispatched, then
        # replaced — never popped first.
        ai = 0
        next_adm = adm_t[0] if P else _INF
        while True:
            # Admission cursor merged with the heap (admits never queue).
            if heap:
                top = heap[0]
                tt = top[0]
            elif next_adm < _INF:
                top = None
                tt = _INF
            else:
                break
            if next_adm <= tt:
                op = ai
                tm = next_adm
                ai += 1
                next_adm = adm_t[ai] if ai < P else _INF
                # Reads contend for their die; writes go straight to
                # the channel (program happens after the transfer);
                # erases hold their die with no channel traffic.
                if op_read[op]:
                    d = op_die[op]
                    if tm >= die_busy[d] and not dieq[d]:
                        die_busy[d] = _INF
                        op_held[op] = tm
                        if pipelined:
                            op_rem[op] = 0
                        push(heap, (tm + op_tr[op],
                                    seqc | op << 2 | read_start_ev))
                        seqc += _SEQ1
                    else:
                        dieq[d].append(op)
                elif op_erase[op]:
                    d = op_die[op]
                    if tm >= die_busy[d] and not dieq[d]:
                        die_busy[d] = _INF
                        op_held[op] = tm
                        push(heap, (tm + op_dur[op],
                                    seqc | op << 2 | _EV_REL))
                        seqc += _SEQ1
                    else:
                        dieq[d].append(op)
                else:
                    c = op_ch[op]
                    b = ch_busy[c]
                    done = (b if b > tm else tm) + tdma
                    ch_busy[c] = done
                    ch_tot[c] += tdma
                    push(heap, (done, seqc | op << 2 | _EV_ACQ))
                    seqc += _SEQ1
                continue

            tm, code = top
            ev = code & 3
            op = (code & _OPSHIFT_MASK) >> 2
            n_events += 1

            if ev == _EV_COPY:
                # Pipelined copy into the cache register at tm: the sense is
                # done and the previous transfer has drained.  Issue the
                # transfer (completion time exact at issue) and schedule the
                # next copy at max(sense done, transfer drained) — both
                # already known — or end the sequence.
                c = op_ch[op]
                b = ch_busy[c]
                done = (b if b > tm else tm) + tdma
                ch_busy[c] = done
                ch_tot[c] += tdma
                i = op_rem[op]
                a = op_a[op]
                if i + 1 < a:
                    op_rem[op] = i + 1
                    tnext = tm + op_tr[op]
                    if done > tnext:
                        tnext = done
                    replace(heap, (tnext, seqc | op << 2 | _EV_COPY))
                else:
                    rid = op_rid[op]
                    if rid >= 0:            # GC reads complete no request
                        fin = done + tecc
                        if fin > req_done[rid]:
                            req_done[rid] = fin
                    # Final attempt leaves the die: charge one speculative
                    # sense when the sequence actually retried.
                    rel = tm + op_tr[op] if a > 1 else tm
                    replace(heap, (rel, seqc | op << 2 | _EV_REL))
                seqc += _SEQ1
            elif ev == _EV_NEXT:
                # Serial read: sense done at tm -> transfer -> decode; on
                # failure the firmware re-senses with the next table entry.
                c = op_ch[op]
                b = ch_busy[c]
                done = (b if b > tm else tm) + tdma
                ch_busy[c] = done
                ch_tot[c] += tdma
                rem = op_rem[op] - 1
                if rem:
                    op_rem[op] = rem
                    replace(heap, (done + tecc + op_tr[op],
                                   seqc | op << 2 | _EV_NEXT))
                else:
                    rid = op_rid[op]
                    if rid >= 0:            # GC reads complete no request
                        fin = done + tecc
                        if fin > req_done[rid]:
                            req_done[rid] = fin
                    # Die freed at last transfer; the decode tail is off-die.
                    replace(heap, (done, seqc | op << 2 | _EV_REL))
                seqc += _SEQ1
            elif ev == _EV_REL:
                # Die release: read end, write program end, or erase end.
                d = op_die[op]
                die_tot[d] += tm - op_held[op]
                die_busy[d] = tm
                dq = dieq[d]
                if dq:
                    op2 = dq.popleft()
                    die_busy[d] = _INF
                    op_held[op2] = tm
                    if op_read[op2]:
                        if pipelined:
                            op_rem[op2] = 0
                        replace(heap, (tm + op_tr[op2],
                                       seqc | op2 << 2 | read_start_ev))
                    else:
                        # Program or erase: hold the die for the op's
                        # duration (tPROG / t_erase), then release.
                        replace(heap, (tm + op_dur[op2],
                                       seqc | op2 << 2 | _EV_REL))
                    seqc += _SEQ1
                else:
                    pop(heap)
                if not op_read[op]:
                    rid = op_rid[op]
                    if rid >= 0 and tm > req_done[rid]:
                        req_done[rid] = tm
            else:
                # _EV_ACQ — write transfer landed: acquire the die.
                d = op_die[op]
                if tm >= die_busy[d] and not dieq[d]:
                    die_busy[d] = _INF
                    op_held[op] = tm
                    replace(heap, (tm + op_dur[op], seqc | op << 2 | _EV_REL))
                    seqc += _SEQ1
                else:
                    dieq[d].append(op)
                    pop(heap)

        self.events_processed = n_events

        req_done_at = np.asarray(req_done)
        self.last_req_done_us = req_done_at
        response = req_done_at - trace.arrival_us + cfg.host_overhead_us
        read_resp = response[trace.is_read]
        span = float(req_done_at.max())
        gc_kw = {}
        if schedule is not None:
            # GC traffic can outlive the last host completion (an erase
            # triggered by the final write holds its die past it); extend
            # the utilization span to the last resource release so
            # die/channel utilization stays a fraction in [0, 1].  After
            # the loop every die_busy/ch_busy entry is a finite release
            # time.  (In-place runs keep the host-completion span for
            # bit-parity with the reference engine.)
            span = max(span, max(die_busy), max(ch_busy))
            fs = schedule.stats
            gc_kw = dict(
                wa=fs.write_amplification,
                gc_invocations=fs.gc_invocations,
                gc_page_reads=fs.gc_page_reads,
                gc_page_progs=fs.gc_page_progs,
                blocks_erased=fs.blocks_erased,
            )
        return SimStats(
            mean_us=float(response.mean()),
            p50_us=float(np.percentile(response, 50)),
            p95_us=float(np.percentile(response, 95)),
            p99_us=float(np.percentile(response, 99)),
            read_mean_us=float(read_resp.mean()) if read_resp.size else 0.0,
            n_requests=n_requests,
            mean_read_attempts=(
                total_attempts / total_read_pages if total_read_pages else 0.0
            ),
            die_util=sum(die_tot) / (span * n_dies),
            channel_util=sum(ch_tot) / (span * n_ch),
            read_p99_us=(
                float(np.percentile(read_resp, 99)) if read_resp.size else 0.0
            ),
            **gc_kw,
        )


# -- run API ---------------------------------------------------------------


def _shared_views(trace, cfg):
    """(expansion, schedule) pair shared by every mechanism of a sweep."""
    expansion = expand_trace(trace, cfg)
    if not cfg.gc.enabled:
        return expansion, None
    from repro.flashsim.ftl import build_ftl_schedule

    return expansion, build_ftl_schedule(trace, cfg, expansion=expansion)


def _make_sim(cfg, condition, mechanism, seed, engine):
    if engine == "array":
        return SSDSim(cfg, condition, RetryPolicy(mechanism), seed=seed)
    if engine == "reference":
        from repro.flashsim.engine_ref import SSDSimRef

        return SSDSimRef(cfg, condition, RetryPolicy(mechanism), seed=seed)
    raise ValueError(f"unknown engine {engine!r} (use 'array' or 'reference')")


def simulate(
    workload: Workload,
    condition: OperatingCondition,
    mechanism: str,
    seed: int = 0,
    cfg: SSDConfig = DEFAULT_SSD,
    n_requests: Optional[int] = None,
    trace: Optional[RequestTrace] = None,
    engine: str = "array",
) -> SimStats:
    """Convenience wrapper: one (workload, condition, mechanism) cell.

    Pass ``trace=`` to reuse a pre-generated trace across calls (all
    mechanisms then see the *same* arrivals); otherwise the trace is
    generated (and memoized) from ``(workload, seed)``.  With
    ``cfg.gc.enabled`` the trace runs through the page-mapping FTL
    (:mod:`repro.flashsim.ftl`) and the returned stats carry WA/GC
    counters; the reference engine predates the FTL and rejects it.
    """
    if trace is None:
        if n_requests is not None:
            workload = dataclasses.replace(workload, n_requests=n_requests)
        trace = cached_trace(workload, seed=seed)
    sim = _make_sim(cfg, condition, mechanism, seed + 7, engine)
    return sim.run(trace)


def compare_mechanisms(
    workload: Workload,
    condition: OperatingCondition,
    mechanisms=("baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2"),
    seed: int = 0,
    cfg: SSDConfig = DEFAULT_SSD,
    n_requests: Optional[int] = None,
    engine: str = "array",
) -> Dict[str, SimStats]:
    """All mechanisms over ONE shared trace (generated once, expanded once).

    With ``cfg.gc.enabled`` the FTL pre-pass also runs once and its
    schedule is shared: every mechanism sees identical GC traffic and
    per-block wear, so mechanism deltas isolate the retry policy.
    """
    if n_requests is not None:
        workload = dataclasses.replace(workload, n_requests=n_requests)
    trace = cached_trace(workload, seed=seed)
    if engine != "array":
        return {
            m: simulate(workload, condition, m, seed, cfg, trace=trace,
                        engine=engine)
            for m in mechanisms
        }
    expansion, schedule = _shared_views(trace, cfg)
    out = {}
    for m in mechanisms:
        sim = SSDSim(cfg, condition, RetryPolicy(m), seed=seed + 7)
        out[m] = sim.run(trace, expansion=expansion, schedule=schedule)
    return out


def simulate_batch(
    workload: Workload,
    conditions: Iterable[OperatingCondition],
    mechanisms: Sequence[str] = (
        "baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2",
    ),
    seeds: Sequence[int] = (0,),
    cfg: SSDConfig = DEFAULT_SSD,
    n_requests: Optional[int] = None,
    engine: str = "array",
) -> Dict[Tuple[str, OperatingCondition, int], SimStats]:
    """Sweep (mechanism x condition x seed) cells for one workload.

    Throughput-structured: each seed's trace is generated and expanded
    once — and, with ``cfg.gc.enabled``, run through the FTL pre-pass
    once — then shared by every (mechanism, condition) cell;
    characterization tables (AR² safe scales, attempt histograms) are
    memoized per condition in :mod:`repro.core.characterize`, so the grid
    pays each JAX characterization exactly once.  Returns
    ``{(mechanism, condition, seed): SimStats}``.
    """
    conditions = tuple(conditions)
    if n_requests is not None:
        workload = dataclasses.replace(workload, n_requests=n_requests)
    out: Dict[Tuple[str, OperatingCondition, int], SimStats] = {}
    for s in seeds:
        trace = cached_trace(workload, seed=s)
        if engine == "array":
            expansion, schedule = _shared_views(trace, cfg)
        else:
            expansion = schedule = None
        for cond in conditions:
            for m in mechanisms:
                sim = _make_sim(cfg, cond, m, s + 7, engine)
                if expansion is not None:
                    out[(m, cond, s)] = sim.run(trace, expansion=expansion,
                                                schedule=schedule)
                else:
                    out[(m, cond, s)] = sim.run(trace)
    return out
