"""Device fault model and controller recovery ladder for flashsim.

Before this module the simulator had no failure path: every read
succeeded within its sampled attempt count and
:func:`repro.core.ecc.page_fail_probability` was consumed by nothing.
That made AR²'s "does not sacrifice reliability" claim a best-case one —
the latency cost of the reliability guard (a reduced-tR read whose RBER
exceeds the shaved ECC margin must re-read at nominal tR) was never
charged.  This module models the recovery ladder real controllers run
(Cai et al.'s error survey; Luo's reliability-architecture work):

  1. **retry escalation** — an uncorrectable final retry step triggers up
     to ``FaultConfig.escalation_attempts`` full-strength re-reads at
     nominal tR (serial, die held throughout);
  2. **superpage-parity rebuild** — if escalation fails, the page is
     reconstructed from its superpage stripe peers: *real* read page-ops
     on the other dies of the channel, carrying the original request id,
     contending on the die queues like GC traffic;
  3. **bad-block retirement** — the failing block is retired
     (:meth:`repro.flashsim.ftl.PageMapFTL.retire_block`): valid pages
     relocate through the GC frontier and the block never returns to the
     free pool;
  4. a rebuild whose peer reads also fail counts as **unrecoverable**
     (data loss) — ~impossible at paper-default ECC margins.

AR² mispredictions ride the same machinery as a 1-step ladder: the
reduced-tR read's decode fails against the shaved margin and one extra
*nominal*-tR attempt is charged before the data returns.

Determinism contract
--------------------
All draws come from per-die RNG substreams seeded
``(run seed, FaultConfig.salt, die)`` and are consumed in die-local
event order, which is shard-invariant (the same argument that makes the
online-GC attempt streams shard-exact — see
:mod:`repro.flashsim.gc_online`).  The fault streams are *separate* from
the attempt-sampling streams, so enabling faults never changes which
retry-attempt counts a run draws, and ``faults=None`` runs are
bit-identical to a build without this module.

Three execution paths
---------------------
* **in-place / prepass** runs plan faults in a deterministic pre-pass
  (:func:`plan_faults`) over the admission stream: extra recovery
  attempts land in the per-op ``xa``/``xtr`` buffers the engine converts
  into serial nominal-tR continuations, and rebuild peer reads /
  retirement relocation ops are *inserted* into the admission stream at
  the trigger op's arrival — the same approximation the prepass FTL
  documents for GC traffic.  Retirement here charges relocation traffic
  (``pages_per_block // 2`` page copies) without touching the
  pre-computed mapping; exact FTL retirement is online-mode only.
* **online GC** draws at the simulated instants
  (:class:`repro.flashsim.gc_online.OnlineGC` hooks): wear-resolved
  probabilities per block, real :meth:`~repro.flashsim.ftl.PageMapFTL.
  retire_block` relocation, erase failures that drop blocks from the
  pool, and program failures that stretch the op on the die.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set

import numpy as np

from repro.core import ecc
from repro.core import characterize as CH
from repro.flashsim.config import FaultConfig, OperatingCondition, SSDConfig

__all__ = ["FaultModel", "FaultOutcome", "FaultPlan", "plan_faults"]


@dataclasses.dataclass
class FaultOutcome:
    """Mutable per-run recovery counters (one instance per FaultModel)."""

    mispredicted_reads: int = 0   # AR² reduced-tR decode failures
    rescued_reads: int = 0        # uncorrectables saved by escalation
    parity_rebuilds: int = 0      # escalation exhausted -> stripe rebuild
    rebuild_reads: int = 0        # peer read page-ops issued by rebuilds
    retired_blocks: int = 0       # bad blocks retired (rebuild + erase-fail)
    program_fails: int = 0        # host programs that needed a reprogram
    erase_fails: int = 0          # erases that failed verification
    unrecoverable: int = 0        # rebuilds whose peers also failed
    #: Request ids that paid any recovery latency (mispredict, escalation,
    #: rebuild, program retry) — the population of the recovery-p99 tail.
    affected_rids: Set[int] = dataclasses.field(default_factory=set)


class FaultModel:
    """Seeded, deterministic fault draws for one simulation run.

    Construct once per :meth:`repro.flashsim.ssd.SSDSim.run` call; the
    per-die streams make draw order die-local, so the monolithic and
    per-channel-sharded engines consume identical streams.
    """

    def __init__(
        self,
        fc: FaultConfig,
        cfg: SSDConfig,
        condition: OperatingCondition,
        policy,
        seed: int,
        sim,
    ):
        self.fc = fc
        self.cfg = cfg
        self.cond = condition
        self.policy = policy
        self.sim = sim
        self.rngs = [
            np.random.default_rng((seed, fc.salt, d))
            for d in range(cfg.n_dies)
        ]
        self._mult = {int(d): float(m) for d, m in fc.failslow_dies}
        self._p_unc: Dict[float, float] = {}
        self._p_mis: Dict[float, float] = {}
        self.outcome = FaultOutcome()

    # -- probability derivation ---------------------------------------------

    def die_mult(self, die: int) -> float:
        """Fail-slow latency multiplier of a die (1.0 when healthy)."""
        return self._mult.get(die, 1.0)

    @staticmethod
    def _rber_at(margin: float) -> float:
        """Capability margin -> RBER: margin = (t - rber*n)/t."""
        return (1.0 - margin) * ecc.DEFAULT_ECC.rber_cap

    def p_unc(self, wear_pec: float) -> float:
        """Uncorrectable probability of a read's final retry step.

        Derived from :func:`repro.core.ecc.page_fail_probability` at the
        final-step mean margin of the block's wear-resolved condition
        (snapped to the characterization grid, memoized per bin), unless
        ``FaultConfig.uncorrectable_prob`` pins it explicitly.
        """
        key = CH.snap_pec(self.cond.with_wear(wear_pec).pec)
        p = self._p_unc.get(key)
        if p is None:
            fc = self.fc
            if fc.uncorrectable_prob is not None:
                base = fc.uncorrectable_prob
            else:
                st = CH.characterize_condition(self.cond.retention_days, key)
                base = float(ecc.page_fail_probability(
                    self._rber_at(st.mean_margin_final)))
            p = min(1.0, base * fc.uncorrectable_scale)
            self._p_unc[key] = p
        return p

    def p_mis(self, wear_pec: float) -> float:
        """AR² misprediction probability at a block's wear.

        Only adaptive-tR policies sensing below scale 1.0 can mispredict.
        Derivation: the reduced sense leaves a fraction ``scale`` of the
        mean final-step RBER margin, so the shaved-margin RBER is
        ``cap - scale * (cap - rber_mean)``; the misprediction
        probability is the page-failure probability there minus the
        full-strength one (a misprediction is a read the nominal sense
        *would* have decoded — ~1-2% at aged conditions, growing with
        wear).  ``FaultConfig.mispredict_prob`` pins it explicitly.
        """
        if not self.policy.adaptive_tr:
            return 0.0
        scale = self.sim._scale_for(wear_pec)
        if scale >= 1.0:
            return 0.0
        key = CH.snap_pec(self.cond.with_wear(wear_pec).pec)
        p = self._p_mis.get(key)
        if p is None:
            fc = self.fc
            if fc.mispredict_prob is not None:
                base = fc.mispredict_prob
            else:
                st = CH.characterize_condition(self.cond.retention_days, key)
                cap = ecc.DEFAULT_ECC.rber_cap
                rber_full = self._rber_at(st.mean_margin_final)
                rber_red = cap - scale * (cap - rber_full)
                pf_red = float(ecc.page_fail_probability(rber_red))
                pf_full = float(ecc.page_fail_probability(rber_full))
                base = max(0.0, pf_red - pf_full)
            p = min(1.0, base * fc.mispredict_scale)
            self._p_mis[key] = p
        return p

    # -- the recovery ladder -------------------------------------------------

    def read_ladder(self, die: int, wear_pec: float):
        """Draw one host read's failure ladder from ``die``'s substream.

        Returns ``(extra_attempts, rebuild, affected)``:
        ``extra_attempts`` serial nominal-tR re-reads to charge (the
        misprediction re-read and/or escalation attempts), ``rebuild``
        whether escalation exhausted and a parity rebuild must run, and
        ``affected`` whether the request paid any recovery latency.
        """
        fc = self.fc
        rng = self.rngs[die]
        out = self.outcome
        extra = 0
        affected = False
        pm = self.p_mis(wear_pec)
        if pm > 0.0 and rng.random() < pm:
            extra += 1
            out.mispredicted_reads += 1
            affected = True
        pu = self.p_unc(wear_pec)
        rebuild = False
        if pu > 0.0 and rng.random() < pu:
            affected = True
            rescued = False
            for _ in range(fc.escalation_attempts):
                extra += 1
                if rng.random() >= pu:
                    rescued = True
                    break
            if rescued:
                out.rescued_reads += 1
            elif fc.parity_rebuild:
                rebuild = True
            else:
                out.unrecoverable += 1
        return extra, rebuild, affected

    def rebuild_peers(self, die: int) -> List[int]:
        """Superpage stripe peers: the other dies of ``die``'s channel."""
        c = die % self.cfg.n_channels
        return [d for d in range(c, self.cfg.n_dies, self.cfg.n_channels)
                if d != die]

    def rebuild_outcome(self, die: int, n_peers: int) -> bool:
        """Account one parity rebuild; draw per-peer uncorrectables.

        Returns True when the rebuild itself failed (any stripe peer
        uncorrectable at device-baseline wear -> data loss).
        """
        out = self.outcome
        out.parity_rebuilds += 1
        out.rebuild_reads += n_peers
        pu = self.p_unc(0.0)
        failed = False
        if pu > 0.0:
            rng = self.rngs[die]
            for _ in range(n_peers):
                if rng.random() < pu:
                    failed = True
        if failed:
            out.unrecoverable += 1
        return failed

    def draw_program_fail(self, die: int) -> bool:
        p = self.fc.program_fail_prob
        return p > 0.0 and self.rngs[die].random() < p

    def draw_erase_fail(self, die: int) -> bool:
        p = self.fc.erase_fail_prob
        return p > 0.0 and self.rngs[die].random() < p


@dataclasses.dataclass
class FaultPlan:
    """Admission stream rewritten by the fault pre-pass (plain lists).

    Same layout :func:`repro.flashsim.engine.make_buffers` takes, plus
    the per-op recovery buffers ``xa`` (extra serial attempts the engine
    appends after the last sampled attempt) and ``xtr`` (their per-
    attempt sense time — nominal tR, fail-slow multiplied).
    """

    arrival: List[float]
    rid: List[int]
    die: List[int]
    ch: List[int]
    read: List[bool]
    erase: List[bool]
    dur: List[float]
    a: List[int]
    tr: List[float]
    xa: List[int]
    xtr: List[float]
    #: Logical page per op (-1 for GC/inserted recovery ops); only present
    #: when the caller passed ``lpn`` — the closed-loop frontend needs it
    #: for write-cache hit detection.
    lpn: Optional[List[int]] = None


def plan_faults(
    model: FaultModel,
    adm: List[float],
    rid: List[int],
    die: List[int],
    ch: List[int],
    read: List[bool],
    erase: List[bool],
    dur: List[float],
    a: List[int],
    tr: List[float],
    ptype: List[int],
    wear: Optional[List[float]],
    lpn: Optional[List[int]] = None,
) -> FaultPlan:
    """Deterministic fault pre-pass over an admission stream.

    Walks the ops in admission order drawing each die's substream in
    die-local order (shard partitioning never reorders a die's ops, so
    the plan is identical however the engine is decomposed — and it runs
    *before* the engine either way).  Host reads run the recovery
    ladder: extra attempts land in ``xa``/``xtr``; a parity rebuild
    inserts its stripe-peer reads (carrying the original request id,
    admitted at the trigger's arrival — the same trigger-time
    approximation the prepass FTL uses for GC traffic) and, with
    ``retire_blocks``, ``pages_per_block // 2`` relocation page-ops on
    the failing die.  Host programs draw program failures (+tPROG);
    erases draw (counted-only — prepass mapping is fixed) erase
    failures.  Fail-slow multipliers stretch sense and hold durations.
    """
    sim = model.sim
    fc = model.fc
    cfg = model.cfg
    out = model.outcome
    tprog = cfg.timing.tprog_us
    n_ch = cfg.n_channels
    n_reloc = cfg.gc.pages_per_block // 2

    o_adm: List[float] = []
    o_rid: List[int] = []
    o_die: List[int] = []
    o_ch: List[int] = []
    o_read: List[bool] = []
    o_erase: List[bool] = []
    o_dur: List[float] = []
    o_a: List[int] = []
    o_tr: List[float] = []
    o_xa: List[int] = []
    o_xtr: List[float] = []
    o_lpn: List[int] = []

    def emit(t, r, d, c, rd, er, du, at, sn, x=0, xt=0.0, lp=-1):
        o_adm.append(t)
        o_rid.append(r)
        o_die.append(d)
        o_ch.append(c)
        o_read.append(rd)
        o_erase.append(er)
        o_dur.append(du)
        o_a.append(at)
        o_tr.append(sn)
        o_xa.append(x)
        o_xtr.append(xt)
        o_lpn.append(lp)

    for i in range(len(adm)):
        d = die[i]
        mult = model.die_mult(d)
        w = float(wear[i]) if wear is not None else 0.0
        r = rid[i]
        lp_i = lpn[i] if lpn is not None else -1
        if read[i]:
            tr_i = tr[i] * mult
            xa_i, xtr_i, rebuild = 0, 0.0, False
            if r >= 0:
                extra, rebuild, affected = model.read_ladder(d, w)
                if extra:
                    xa_i = extra
                    xtr_i = float(sim._tr_base[ptype[i]]) * mult
                if affected:
                    out.affected_rids.add(r)
            emit(adm[i], r, d, ch[i], True, False, dur[i], a[i], tr_i,
                 xa_i, xtr_i, lp_i)
            if rebuild:
                pt = ptype[i]
                peers = model.rebuild_peers(d)
                model.rebuild_outcome(d, len(peers))
                for dd in peers:
                    pm = model.die_mult(dd)
                    pa = sim._draw_attempts(pt, 0.0, rng=model.rngs[d])
                    emit(adm[i], r, dd, dd % n_ch, True, False, 0.0, pa,
                         sim._tr_for(pt, 0.0) * pm)
                if fc.retire_blocks:
                    out.retired_blocks += 1
                    for _ in range(n_reloc):
                        ra = sim._draw_attempts(pt, w, rng=model.rngs[d])
                        emit(adm[i], -1, d, ch[i], True, False, 0.0, ra,
                             sim._tr_for(pt, w) * mult)
                        emit(adm[i], -1, d, ch[i], False, False,
                             tprog * mult, 1, 0.0)
        elif erase[i]:
            if model.draw_erase_fail(d):
                # Prepass mapping is fixed before the run; charge the
                # counter (and the retirement) without rewriting history.
                out.erase_fails += 1
                out.retired_blocks += 1
            emit(adm[i], r, d, ch[i], False, True, dur[i] * mult, a[i],
                 tr[i])
        else:
            dur_i = dur[i] * mult
            if r >= 0 and model.draw_program_fail(d):
                out.program_fails += 1
                out.affected_rids.add(r)
                dur_i += tprog * mult
            emit(adm[i], r, d, ch[i], False, False, dur_i, a[i], tr[i],
                 lp=lp_i)

    return FaultPlan(
        arrival=o_adm, rid=o_rid, die=o_die, ch=o_ch, read=o_read,
        erase=o_erase, dur=o_dur, a=o_a, tr=o_tr, xa=o_xa, xtr=o_xtr,
        lpn=o_lpn if lpn is not None else None,
    )
