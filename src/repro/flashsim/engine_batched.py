"""Batched shard core: lockstep-vectorized event loops over all channels.

``run_event_core_batched`` is a drop-in replacement for
:func:`repro.flashsim.engine.run_event_core` on the **open-loop fast
path**: every per-channel shard loop advances in lockstep inside one
compiled kernel (:mod:`repro.kernels.fcfs_core`) instead of running
sequentially in Python.  The result is bit-identical to the interpreter
— the kernel replays the exact event order (push-order seq discipline)
and the exact float arithmetic (the busy-until collapse's add/max
sequence) of :func:`repro.flashsim.engine._run_shard` per lane; see the
kernel module docstring for the construction.

Eligibility (the supported matrix) is checked **explicitly** — an
unsupported configuration raises :class:`BatchedUnsupported` rather
than silently falling back to the interpreter:

  ===================  ========================================
  scheduler            any policy with a ring lowering —
                       ``fcfs`` (single FIFO ring),
                       ``host_prio`` and ``host_prio_aged[:b]``
                       (dual priority rings, traced aging
                       bound); ``tokens`` and ``preempt`` have
                       none and are rejected
  GC                   ``none`` or ``prepass`` (the prepass
                       schedule is just a longer admission
                       stream); ``online`` injects ops mid-loop
  faults               ``None`` (recovery ladders are serial
                       continuations the kernel doesn't model)
  frontend             open loop (``ncq_depth=None``) — checked
                       by the caller, which owns the config
  validate             ``False`` (work-conservation asserts are
                       interpreter instrumentation)
  ===================  ========================================

``engine="auto"`` resolution lives here too (:func:`resolve_engine`):
it runs the same checks non-fatally and returns ``("batched", "")``
when eligible, else ``("array", reason)`` — the recorded reason string
is the matching ``BatchedUnsupported`` message, so auto documents
rather than hides its fallback.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.flashsim.engine import EngineResult
from repro.flashsim.sched import SchedulerPolicy


class BatchedUnsupported(NotImplementedError):
    """Raised when a run configuration is outside the batched core's
    supported matrix (never a silent fallback)."""


def check_batched_config(cfg) -> None:
    """Config-level eligibility for ``engine='batched'`` (fail fast at
    construction; run-time state is checked again by
    :func:`check_batched_supported`)."""
    from repro.flashsim.sched import get_scheduler

    pol = get_scheduler(cfg.scheduler)
    if pol.ring_lowering is None:
        raise BatchedUnsupported(
            f"engine='batched' supports ring-lowerable schedulers only "
            f"(fcfs, host_prio, host_prio_aged[:bound]), got "
            f"{cfg.scheduler!r}; use engine='array'"
        )
    if cfg.gc.enabled and cfg.gc.mode == "online":
        raise BatchedUnsupported(
            "engine='batched' does not support online GC (ops are "
            "injected mid-loop); use gc='prepass' or engine='array'"
        )
    if cfg.faults is not None:
        raise BatchedUnsupported(
            "engine='batched' does not support fault injection; use "
            "engine='array'"
        )
    if cfg.ncq_depth is not None:
        raise BatchedUnsupported(
            "engine='batched' is open-loop only (ncq_depth=None); the "
            "closed-loop frontend requires engine='array'"
        )


def check_batched_supported(
    policy: SchedulerPolicy,
    bufs,
    online,
    validate: bool,
) -> None:
    """Raise :class:`BatchedUnsupported` unless this run is eligible."""
    if policy.ring_lowering is None:
        raise BatchedUnsupported(
            f"engine='batched' supports ring-lowerable schedulers only "
            f"(fcfs, host_prio, host_prio_aged[:bound]), got "
            f"{policy.name!r}; run this scheduler with engine='array'"
        )
    if online is not None:
        raise BatchedUnsupported(
            "engine='batched' does not support online GC (ops are "
            "injected mid-loop); use gc='prepass' or engine='array'"
        )
    if bufs.xa is not None:
        raise BatchedUnsupported(
            "engine='batched' does not support fault injection "
            "(recovery-ladder continuations); use engine='array'"
        )
    if validate:
        raise BatchedUnsupported(
            "validate=True is interpreter instrumentation; use "
            "engine='array' for work-conservation checks"
        )


def resolve_engine(cfg, validate: bool = False) -> Tuple[str, str]:
    """Resolve ``engine="auto"`` for a config: ``(engine, reason)``.

    Returns ``("batched", "")`` when the config is inside the batched
    matrix, else ``("array", reason)`` where ``reason`` is the exact
    :class:`BatchedUnsupported` message the explicit engine would have
    raised — auto records, never hides, its fallback.  ``validate=True``
    always resolves to the instrumented interpreter.
    """
    if validate:
        return ("array", "validate=True is interpreter instrumentation")
    try:
        check_batched_config(cfg)
    except BatchedUnsupported as e:
        return ("array", str(e))
    return ("batched", "")


def run_event_core_batched(
    cfg,
    pipelined: bool,
    policy: SchedulerPolicy,
    bufs,
    n_requests: int,
    online=None,
    validate: bool = False,
) -> EngineResult:
    """Run the admission stream through the lockstep kernel.

    Same contract as ``run_event_core(..., shard=True)`` on the
    supported matrix: one lane per channel, results merged exactly as
    :func:`repro.flashsim.engine.merge_shard_results` would.
    """
    check_batched_supported(policy, bufs, online, validate)

    t = cfg.timing
    n_ch, n_dies = cfg.n_channels, cfg.n_dies
    P = len(bufs.arrival)

    arrival = np.asarray(bufs.arrival, dtype=np.float64)
    rid = np.asarray(bufs.rid, dtype=np.int64)
    die = np.asarray(bufs.die, dtype=np.int64)
    ch = np.asarray(bufs.ch, dtype=np.int64)
    read = np.asarray(bufs.read, dtype=bool)
    erase = np.asarray(bufs.erase, dtype=bool)
    dur = np.asarray(bufs.dur, dtype=np.float64)
    att = np.asarray(bufs.a, dtype=np.float64)
    tr = np.asarray(bufs.tr, dtype=np.float64)

    if P and not np.array_equal(ch, die % n_ch):
        # The lockstep decomposition leans on the static die stripe the
        # same way shard=True does; an op off its die's channel would
        # break lane ownership.
        raise BatchedUnsupported(
            "engine='batched' requires the die->channel stripe "
            "(ch == die % n_channels) for every op"
        )

    kind = np.where(read, 0.0, np.where(erase, 2.0, 1.0))
    die_local = (die // n_ch).astype(np.float64)
    # Scheduling class: the interpreter's host_read table is
    # ``read and rid >= 0`` (GC copy-back reads carry rid = -1; the
    # fault ladder's parity reads are excluded from this matrix).
    hp = (read & (rid >= 0)).astype(np.float64)
    table = np.stack([arrival, kind, die_local, dur, att, tr, hp],
                     axis=1)

    # Per-channel admission substreams, original order preserved — the
    # same partition run_event_core's shard path builds.
    lane_idx = [np.flatnonzero(ch == c) for c in range(n_ch)]

    from repro.kernels.fcfs_core import fcfs_core
    from repro.kernels.fcfs_core.ops import pad_ops

    mode, bound = policy.ring_lowering
    ops = pad_ops([table[idx] for idx in lane_idx])
    n_dies_local = -(-n_dies // n_ch)
    fin, diestat, lane = fcfs_core(
        ops, n_dies_local, pipelined, t.tdma_us, t.tecc_us,
        age_bound=bound if mode == "prio" else None)

    # -- reassemble an EngineResult exactly as merge_shard_results would
    req_done = np.zeros(n_requests, dtype=np.float64)
    for c, idx in enumerate(lane_idx):
        if not idx.size:
            continue
        rid_l = rid[idx]
        fin_l = fin[c, : idx.size]
        sel = rid_l >= 0
        np.maximum.at(req_done, rid_l[sel], fin_l[sel])

    die_tot = [0.0] * n_dies
    die_busy = [0.0] * n_dies
    for c in range(n_ch):
        for j in range(n_dies_local):
            d = j * n_ch + c
            if d < n_dies:
                die_tot[d] = float(diestat[c, j, 0])
                die_busy[d] = float(diestat[c, j, 1])

    n_events = int(lane[:, 2].sum())
    return EngineResult(
        req_done=req_done.tolist(),
        die_tot=die_tot,
        ch_tot=lane[:, 1].tolist(),
        die_busy=die_busy,
        ch_busy=lane[:, 0].tolist(),
        n_events=n_events,
        gc_suspensions=0,
        online_attempts=0,
        online_read_pages=0,
        fast_path_events=n_events,
    )
