"""Batched shard core: lockstep-vectorized event loops over all channels.

``run_event_core_batched`` is a drop-in replacement for
:func:`repro.flashsim.engine.run_event_core` on the **open-loop fast
path**: every per-channel shard loop advances in lockstep inside one
compiled kernel (:mod:`repro.kernels.fcfs_core`) instead of running
sequentially in Python.  The result is bit-identical to the interpreter
— the kernel replays the exact event order (push-order seq discipline)
and the exact float arithmetic (the busy-until collapse's add/max
sequence) of :func:`repro.flashsim.engine._run_shard` per lane; see the
kernel module docstring for the construction.

Eligibility (the supported matrix) is checked **explicitly** — an
unsupported configuration raises :class:`BatchedUnsupported` rather
than silently falling back to the interpreter:

  ===================  ========================================
  scheduler            any policy with a ring lowering —
                       ``fcfs`` (single FIFO ring),
                       ``host_prio`` and ``host_prio_aged[:b]``
                       (dual priority rings, traced aging
                       bound); ``tokens`` and ``preempt`` have
                       none and are rejected
  GC                   ``none`` or ``prepass`` (the prepass
                       schedule is just a longer admission
                       stream); ``online`` injects ops mid-loop
  faults               ``None`` (recovery ladders are serial
                       continuations the kernel doesn't model)
  frontend             open loop (``ncq_depth=None``) — checked
                       by the caller, which owns the config
  validate             ``False`` (work-conservation asserts are
                       interpreter instrumentation)
  ===================  ========================================

``engine="auto"`` resolution lives here too (:func:`resolve_engine`):
it runs the same checks non-fatally and returns ``("batched", "")``
when eligible, else ``("array", reason)`` — the recorded reason string
is the matching ``BatchedUnsupported`` message, so auto documents
rather than hides its fallback.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.flashsim.engine import EngineResult
from repro.flashsim.sched import SchedulerPolicy


class BatchedUnsupported(NotImplementedError):
    """Raised when a run configuration is outside the batched core's
    supported matrix (never a silent fallback)."""


def check_batched_config(cfg) -> None:
    """Config-level eligibility for ``engine='batched'`` (fail fast at
    construction; run-time state is checked again by
    :func:`check_batched_supported`)."""
    from repro.flashsim.sched import get_scheduler

    pol = get_scheduler(cfg.scheduler)
    if pol.ring_lowering is None:
        raise BatchedUnsupported(
            f"engine='batched' supports ring-lowerable schedulers only "
            f"(fcfs, host_prio, host_prio_aged[:bound]), got "
            f"{cfg.scheduler!r}; use engine='array'"
        )
    if cfg.gc.enabled and cfg.gc.mode == "online":
        raise BatchedUnsupported(
            "engine='batched' does not support online GC (ops are "
            "injected mid-loop); use gc='prepass' or engine='array'"
        )
    if cfg.faults is not None:
        raise BatchedUnsupported(
            "engine='batched' does not support fault injection; use "
            "engine='array'"
        )
    if cfg.ncq_depth is not None:
        raise BatchedUnsupported(
            "engine='batched' is open-loop only (ncq_depth=None); the "
            "closed-loop frontend requires engine='array'"
        )


def check_batched_supported(
    policy: SchedulerPolicy,
    bufs,
    online,
    validate: bool,
) -> None:
    """Raise :class:`BatchedUnsupported` unless this run is eligible."""
    if policy.ring_lowering is None:
        raise BatchedUnsupported(
            f"engine='batched' supports ring-lowerable schedulers only "
            f"(fcfs, host_prio, host_prio_aged[:bound]), got "
            f"{policy.name!r}; run this scheduler with engine='array'"
        )
    if online is not None:
        raise BatchedUnsupported(
            "engine='batched' does not support online GC (ops are "
            "injected mid-loop); use gc='prepass' or engine='array'"
        )
    if bufs.xa is not None:
        raise BatchedUnsupported(
            "engine='batched' does not support fault injection "
            "(recovery-ladder continuations); use engine='array'"
        )
    if validate:
        raise BatchedUnsupported(
            "validate=True is interpreter instrumentation; use "
            "engine='array' for work-conservation checks"
        )


def resolve_engine(cfg, validate: bool = False) -> Tuple[str, str]:
    """Resolve ``engine="auto"`` for a config: ``(engine, reason)``.

    Returns ``("batched", "")`` when the config is inside the batched
    matrix, else ``("array", reason)`` where ``reason`` is the exact
    :class:`BatchedUnsupported` message the explicit engine would have
    raised — auto records, never hides, its fallback.  ``validate=True``
    always resolves to the instrumented interpreter.
    """
    if validate:
        return ("array", "validate=True is interpreter instrumentation")
    try:
        check_batched_config(cfg)
    except BatchedUnsupported as e:
        return ("array", str(e))
    return ("batched", "")


def _lane_tables(cfg, bufs):
    """Build the per-channel (P_l, 7) op tables of one run.

    Returns ``(tables, lane_idx, rid)`` — the per-lane tables in
    admission order, the per-channel index partition, and the op→request
    id map (used to reassemble ``req_done``).  This is the shared front
    half of both the per-run and the fused batched drivers.
    """
    n_ch = cfg.n_channels
    P = len(bufs.arrival)

    arrival = np.asarray(bufs.arrival, dtype=np.float64)
    rid = np.asarray(bufs.rid, dtype=np.int64)
    die = np.asarray(bufs.die, dtype=np.int64)
    ch = np.asarray(bufs.ch, dtype=np.int64)
    read = np.asarray(bufs.read, dtype=bool)
    erase = np.asarray(bufs.erase, dtype=bool)
    dur = np.asarray(bufs.dur, dtype=np.float64)
    att = np.asarray(bufs.a, dtype=np.float64)
    tr = np.asarray(bufs.tr, dtype=np.float64)

    if P and not np.array_equal(ch, die % n_ch):
        # The lockstep decomposition leans on the static die stripe the
        # same way shard=True does; an op off its die's channel would
        # break lane ownership.
        raise BatchedUnsupported(
            "engine='batched' requires the die->channel stripe "
            "(ch == die % n_channels) for every op"
        )

    kind = np.where(read, 0.0, np.where(erase, 2.0, 1.0))
    die_local = (die // n_ch).astype(np.float64)
    # Scheduling class: the interpreter's host_read table is
    # ``read and rid >= 0`` (GC copy-back reads carry rid = -1; the
    # fault ladder's parity reads are excluded from this matrix).
    hp = (read & (rid >= 0)).astype(np.float64)
    table = np.stack([arrival, kind, die_local, dur, att, tr, hp],
                     axis=1)

    # Per-channel admission substreams, original order preserved — the
    # same partition run_event_core's shard path builds.
    lane_idx = [np.flatnonzero(ch == c) for c in range(n_ch)]
    return [table[idx] for idx in lane_idx], lane_idx, rid


def _assemble_result(cfg, rid, lane_idx, fin, diestat, lane,
                     n_requests: int, fused_cells: int = 0) -> EngineResult:
    """Reassemble an :class:`EngineResult` from one cell's kernel rows
    exactly as ``merge_shard_results`` would."""
    n_ch, n_dies = cfg.n_channels, cfg.n_dies
    n_dies_local = -(-n_dies // n_ch)

    req_done = np.zeros(n_requests, dtype=np.float64)
    live = [(c, idx) for c, idx in enumerate(lane_idx) if idx.size]
    if live:
        # One flat scatter-max over every lane's ops (max is
        # order-free, so flattening the per-channel loop is exact).
        rid_all = np.concatenate([rid[idx] for _, idx in live])
        fin_all = np.concatenate([fin[c, : idx.size] for c, idx in live])
        sel = rid_all >= 0
        np.maximum.at(req_done, rid_all[sel], fin_all[sel])

    # diestat rows are (lane c, local die j) for die d = j*n_ch + c;
    # transpose to d-order and trim the padding rows past n_dies.
    ds = np.asarray(diestat).transpose(1, 0, 2).reshape(-1, 2)[:n_dies]
    die_tot = ds[:, 0].tolist()
    die_busy = ds[:, 1].tolist()

    n_events = int(lane[:, 2].sum())
    return EngineResult(
        req_done=req_done.tolist(),
        die_tot=die_tot,
        ch_tot=lane[:, 1].tolist(),
        die_busy=die_busy,
        ch_busy=lane[:, 0].tolist(),
        n_events=n_events,
        gc_suspensions=0,
        online_attempts=0,
        online_read_pages=0,
        fast_path_events=n_events,
        fused_cells=fused_cells,
    )


def run_event_core_batched(
    cfg,
    pipelined: bool,
    policy: SchedulerPolicy,
    bufs,
    n_requests: int,
    online=None,
    validate: bool = False,
) -> EngineResult:
    """Run the admission stream through the lockstep kernel.

    Same contract as ``run_event_core(..., shard=True)`` on the
    supported matrix: one lane per channel, results merged exactly as
    :func:`repro.flashsim.engine.merge_shard_results` would.
    """
    check_batched_supported(policy, bufs, online, validate)

    t = cfg.timing
    tables, lane_idx, rid = _lane_tables(cfg, bufs)

    from repro.kernels.fcfs_core import fcfs_core
    from repro.kernels.fcfs_core.ops import pad_ops

    mode, bound = policy.ring_lowering
    ops = pad_ops(tables)
    n_dies_local = -(-cfg.n_dies // cfg.n_channels)
    fin, diestat, lane = fcfs_core(
        ops, n_dies_local, pipelined, t.tdma_us, t.tecc_us,
        age_bound=bound if mode == "prio" else None)
    return _assemble_result(cfg, rid, lane_idx, fin, diestat, lane,
                            n_requests)


@dataclasses.dataclass
class FusedRun:
    """One prepared cell of a fused sweep dispatch: the same inputs
    ``run_event_core_batched`` takes, held so many cells can share one
    kernel launch."""

    cfg: object
    pipelined: bool
    policy: SchedulerPolicy
    bufs: object
    n_requests: int


#: Lane budget of one fused dispatch.  The kernel's per-lane-step cost
#: is flat while the working set (op table + state rows) stays
#: cache-resident and climbs ~30% past it; 64 lanes is the measured
#: knee on the 8-channel default geometry, so groups chunk at
#: ``_FUSE_LANE_CAP // n_channels`` cells rather than stacking without
#: bound.
_FUSE_LANE_CAP = 64

#: Step-homogeneity bound of one chunk.  Every lane of a fused dispatch
#: runs the *group-max* step count (finished lanes no-op but still pay
#: the lockstep body), so stacking a short cell under a long one wastes
#: (max - own) steps of per-lane work.  Fusing saves roughly the fixed
#: per-dispatch cost (~ the cell's own step count in lane-step units),
#: so cells within a 1.5x step band win and wider bands lose — chunks
#: split when the next cell's bound exceeds the chunk minimum by more.
_FUSE_STEP_RATIO = 1.5


def _fuse_cell_cap(n_channels: int) -> int:
    """Max cells of one fused chunk for an ``n_channels``-lane cell."""
    return max(1, _FUSE_LANE_CAP // max(1, n_channels))


def _fuse_chunks(cells, n_channels: int):
    """Split one static-shape group into step-homogeneous chunks.

    ``cells`` is a sequence of ``(steps, index, payload)`` triples; the
    split is deterministic — sort by (steps, index), then greedily chunk
    while the cell count stays under :func:`_fuse_cell_cap` and the step
    bound within ``_FUSE_STEP_RATIO`` of the chunk minimum.  Chunking
    never affects results (the cell-axis law), only which cells share a
    dispatch.
    """
    cap = _fuse_cell_cap(n_channels)
    chunks, cur = [], []
    for steps, idx, payload in sorted(cells, key=lambda t: t[:2]):
        if cur and (len(cur) >= cap
                    or steps > cur[0][0] * _FUSE_STEP_RATIO):
            chunks.append(cur)
            cur = []
        cur.append((steps, idx, payload))
    if cur:
        chunks.append(cur)
    return chunks


def run_event_cores_fused(runs) -> list:
    """Run many eligible cells in as few kernel dispatches as possible.

    Stacks the per-cell padded op tables of ``runs`` (a sequence of
    :class:`FusedRun`) along the lane axis — cell c's channels occupy
    lane rows [c*L, (c+1)*L) — and dispatches each *chunk* once.  A
    group is the maximal sub-grid sharing every static kernel parameter:
    (n_channels, local die count, pipelined, scheduler lowering mode,
    padded-width bucket); each group then chunks by the two measured
    perf cliffs (:func:`_fuse_chunks`): at most ``_FUSE_LANE_CAP``
    stacked lanes per dispatch (cache residency) and step bounds within
    ``_FUSE_STEP_RATIO`` of each other (every lane runs the chunk-max
    step count, so step-heterogeneous stacking wastes lane-steps).  The
    cap doubles as the shape-bucket bound: chunk cell counts range over
    at most ``_fuse_cell_cap`` values per static key, so the compiled
    (and persistently cached) kernel-variant count stays small without
    padding dead filler lanes.  Ring capacities / step cap are the
    chunk maxima — all semantics-neutral, so each cell's rows are
    bit-identical to its own :func:`run_event_core_batched` dispatch
    (the cell-axis law; see the kernel docstring and
    :func:`fused_core_ref`).  Per-cell scalars (tdma, tecc, aging
    bound) ride as per-lane traced timing rows, so cells with different
    timing models or ``host_prio_aged`` bounds still fuse.

    Eligibility is checked per cell up front —
    :class:`BatchedUnsupported` propagates before any dispatch (callers
    route ineligible cells to their own engine runs and record the
    reason; nothing silently falls back here).  Returns one
    :class:`EngineResult` per run, in order, each with
    ``fused_cells = len(its chunk)``.
    """
    from repro.kernels.fcfs_core.ops import (
        count_steps, fused_core, pad_ops, pad_width, ring_caps,
        _pow2_at_least)

    prepped = []
    for r in runs:
        check_batched_supported(r.policy, r.bufs, None, False)
        tables, lane_idx, rid = _lane_tables(r.cfg, r.bufs)
        mode, bound = r.policy.ring_lowering
        widest = max((t.shape[0] for t in tables), default=0)
        prepped.append((r, tables, lane_idx, rid, mode, bound, widest))

    # Group key = every static kernel parameter; per-cell dynamics
    # (timing, bound, table contents) ride in traced operands.
    groups = {}
    for i, (r, tables, lane_idx, rid, mode, bound, widest) in \
            enumerate(prepped):
        n_ch = r.cfg.n_channels
        key = (n_ch, -(-r.cfg.n_dies // n_ch), r.pipelined, mode,
               pad_width(widest))
        groups.setdefault(key, []).append(i)

    results = [None] * len(prepped)
    for (n_ch, n_dies_local, pipelined, mode, maxp), idxs in \
            groups.items():
        cells = []
        for i in idxs:
            _, tables, _, _, _, _, _ = prepped[i]
            ops_c = pad_ops(tables, maxp=maxp)
            cells.append((count_steps(ops_c), i, ops_c))
        for chunk in _fuse_chunks(cells, n_ch):
            C = len(chunk)
            cell_ops = [ops_c for _, _, ops_c in chunk]
            timing_rows = []
            for _, i, _ in chunk:
                r, _, _, _, _, bound, _ = prepped[i]
                b = bound if mode == "prio" else 0.0
                timing_rows.append(np.tile(
                    [[r.cfg.timing.tdma_us, r.cfg.timing.tecc_us, b]],
                    (n_ch, 1)))
            stacked = np.concatenate(cell_ops, axis=0)
            timing = np.concatenate(timing_rows,
                                    axis=0).astype(np.float64)

            # Chunk-wide static caps: ring bounds read off the stacked
            # table in one pass — the lane-wise max over all cells, and
            # pow2 bucketing commutes with max (ring pairing is by
            # monotone counters and idle lanes no-op, so growing a cap
            # never changes a cell's rows).  The chunk-max step count
            # doubles as the stacked table's exact step bound (max over
            # lanes), so the dispatch skips its recount.
            steps = max(st for st, _, _ in chunk)
            capq, capw = ring_caps(stacked, n_dies_local)
            caps = (capq, capw, _pow2_at_least(max(steps, 16)))

            fin, diestat, lane = fused_core(
                stacked, n_dies_local, pipelined, timing,
                prio=(mode == "prio"), caps=caps, steps=steps)
            for j, (_, i, _) in enumerate(chunk):
                r, _, lane_idx, rid, _, _, _ = prepped[i]
                rows = slice(j * n_ch, (j + 1) * n_ch)
                results[i] = _assemble_result(
                    r.cfg, rid, lane_idx, fin[rows], diestat[rows],
                    lane[rows], r.n_requests, fused_cells=C)
    return results
