"""Six workload profiles with distinct I/O characteristics.

The paper evaluates on six real-world block traces.  Traces are not
redistributable, so we generate statistically-shaped equivalents covering
the same axes the paper varies: read ratio (read-dominant vs mixed),
request size, arrival burstiness, and intensity.  Profiles are named after
the MSR-Cambridge / enterprise classes they emulate.

Arrivals are a Markov-modulated Poisson process (bursty <-> idle phases);
sizes are drawn from a small-page-biased geometric mixture, matching the
4-64 KiB concentration of the original traces.
"""

from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    read_ratio: float          # fraction of requests that are reads
    iops: float                # mean arrival rate (requests/s)
    burstiness: float          # >1: bursty MMPP; 1: plain Poisson
    mean_pages: float          # mean request size in 16 KiB pages
    n_requests: int = 20000

    @property
    def read_dominant(self) -> bool:
        return self.read_ratio >= 0.90


#: The six profiles (read ratio / intensity / size / burstiness all vary).
PROFILES = (
    Workload("websearch", read_ratio=0.99, iops=14000, burstiness=2.0, mean_pages=1.6),
    Workload("ycsb-b",    read_ratio=0.95, iops=20000, burstiness=1.0, mean_pages=1.0),
    Workload("graph",     read_ratio=0.98, iops=15000, burstiness=3.0, mean_pages=1.2),
    Workload("usr",       read_ratio=0.91, iops=9000,  burstiness=2.5, mean_pages=2.2),
    Workload("oltp",      read_ratio=0.70, iops=18000, burstiness=1.5, mean_pages=1.0),
    Workload("prxy",      read_ratio=0.55, iops=12000, burstiness=2.0, mean_pages=1.4),
)


def make_workloads() -> Dict[str, Workload]:
    return {w.name: w for w in PROFILES}


@dataclasses.dataclass
class RequestTrace:
    """Flat arrays describing one generated trace (times in us)."""

    arrival_us: np.ndarray     # (N,) sorted arrival times
    is_read: np.ndarray        # (N,) bool
    n_pages: np.ndarray        # (N,) int, pages per request
    start_page: np.ndarray     # (N,) int, first logical page (for striping)


def generate_trace(w: Workload, seed: int = 0) -> RequestTrace:
    """Generate a trace for a profile (deterministic per seed).

    The per-profile salt is a stable CRC32 of the name — ``hash(str)`` is
    randomized per process, which silently made traces unreproducible
    across runs.
    """
    rng = np.random.default_rng(seed ^ zlib.crc32(w.name.encode()))
    n = w.n_requests

    # MMPP arrivals: alternate burst (rate*burstiness) and idle phases so
    # the long-run mean rate is w.iops.
    if w.burstiness > 1.0:
        # Half the *requests* arrive in bursts at r_burst = b * iops; the
        # idle-phase rate is set so the long-run mean gap is 1/iops:
        #   0.5/r_burst + 0.5/r_idle = 1/iops.
        b = w.burstiness
        r_burst = b * w.iops
        r_idle = 0.5 * w.iops / max(1.0 - 0.5 / b, 1e-6)
        # Phases are sustained over runs of ~64 requests.
        run = 64
        idx = np.arange(n) // run
        phase_of_run = rng.random(idx.max() + 1) < 0.5
        burst_mask = phase_of_run[idx]
        gaps = np.where(
            burst_mask,
            rng.exponential(1e6 / r_burst, n),
            rng.exponential(1e6 / r_idle, n),
        )
    else:
        gaps = rng.exponential(1e6 / w.iops, n)
    arrival = np.cumsum(gaps)

    is_read = rng.random(n) < w.read_ratio
    # Geometric page counts with the requested mean (>= 1 page).
    p = min(1.0 / w.mean_pages, 1.0)
    n_pages = rng.geometric(p, n).clip(1, 64)
    start_page = rng.integers(0, 1 << 22, n)
    return RequestTrace(arrival, is_read, n_pages.astype(np.int64), start_page)


@functools.lru_cache(maxsize=128)
def cached_trace(w: Workload, seed: int = 0) -> RequestTrace:
    """Memoized :func:`generate_trace` — one trace per (workload, seed).

    Mechanism sweeps (``compare_mechanisms``/``simulate_batch``) call this
    so every mechanism sees the *same* arrivals without regenerating the
    trace.  The arrays are marked read-only: treat the result as immutable
    (call :func:`generate_trace` for a private copy).
    """
    t = generate_trace(w, seed=seed)
    for arr in (t.arrival_us, t.is_read, t.n_pages, t.start_page):
        arr.setflags(write=False)
    return t
