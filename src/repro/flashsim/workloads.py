"""Workload profiles with distinct I/O characteristics.

The paper evaluates on six real-world block traces.  Traces are not
redistributable, so we generate statistically-shaped equivalents covering
the same axes the paper varies: read ratio (read-dominant vs mixed),
request size, arrival burstiness, and intensity — plus a logical-span
axis that the write-heavy FTL/GC profiles (``GC_PROFILES``) shrink to
force overwrites and garbage collection.  Profiles are named after the
MSR-Cambridge / enterprise classes they emulate.

Arrivals are a Markov-modulated Poisson process (bursty <-> idle phases);
sizes are drawn from a small-page-biased geometric mixture, matching the
4-64 KiB concentration of the original traces.
"""

from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Dict

import numpy as np


@dataclasses.dataclass(frozen=True)
class Workload:
    """One synthetic trace profile (the generator's six statistical axes)."""

    name: str
    read_ratio: float          # fraction of requests that are reads [0, 1]
    iops: float                # mean arrival rate (requests/s)
    burstiness: float          # >1: bursty MMPP; 1: plain Poisson
    mean_pages: float          # mean request size (16 KiB pages)
    n_requests: int = 20000    # trace length (requests)
    #: Logical address-space footprint (pages).  The paper's read-dominant
    #: profiles roam a large cold span; write-heavy FTL/GC profiles use a
    #: small span so sustained writes overwrite hot data, fill the
    #: over-provisioned capacity, and force garbage collection.
    span_pages: int = 1 << 22

    @property
    def read_dominant(self) -> bool:
        return self.read_ratio >= 0.90


#: The six profiles (read ratio / intensity / size / burstiness all vary).
PROFILES = (
    Workload("websearch", read_ratio=0.99, iops=14000, burstiness=2.0, mean_pages=1.6),
    Workload("ycsb-b",    read_ratio=0.95, iops=20000, burstiness=1.0, mean_pages=1.0),
    Workload("graph",     read_ratio=0.98, iops=15000, burstiness=3.0, mean_pages=1.2),
    Workload("usr",       read_ratio=0.91, iops=9000,  burstiness=2.5, mean_pages=2.2),
    Workload("oltp",      read_ratio=0.70, iops=18000, burstiness=1.5, mean_pages=1.0),
    Workload("prxy",      read_ratio=0.55, iops=12000, burstiness=2.0, mean_pages=1.4),
)

#: Write-heavy MMPP profiles for the FTL/GC regime (MSR-Cambridge print/
#: research/source-control server classes: write-dominated traffic
#: re-walking a small hot span).  Sustained small-span overwrites are
#: what fill the over-provisioned capacity and keep the garbage
#: collector busy — the contention regime the in-place simulator could
#: never reach.  ``src`` mixes in a substantial read fraction so the
#: scheduler sweep (host-read priority / GC preemption) measures the
#: read tail on a statistically meaningful read population.
GC_PROFILES = (
    Workload("prn",   read_ratio=0.11, iops=16000, burstiness=2.0,
             mean_pages=1.6, span_pages=1 << 13),
    Workload("rsrch", read_ratio=0.09, iops=10000, burstiness=3.0,
             mean_pages=1.1, span_pages=1 << 12),
    Workload("src",   read_ratio=0.30, iops=14000, burstiness=2.0,
             mean_pages=1.3, span_pages=1 << 13),
)


def make_workloads() -> Dict[str, Workload]:
    """Name -> profile map over the paper's six profiles + GC profiles."""
    return {w.name: w for w in PROFILES + GC_PROFILES}


@dataclasses.dataclass
class RequestTrace:
    """Flat arrays describing one trace (generated or externally loaded).

    Requests touch ``n_pages`` consecutive logical pages starting at
    ``start_page``; the simulator stripes logical pages across dies.
    """

    arrival_us: np.ndarray     # (N,) arrival times (us; need not be sorted)
    is_read: np.ndarray        # (N,) bool: True = read, False = write
    n_pages: np.ndarray        # (N,) request length (16 KiB pages)
    start_page: np.ndarray     # (N,) first logical page number


def generate_trace(w: Workload, seed: int = 0) -> RequestTrace:
    """Generate a trace for a profile (deterministic per seed).

    The per-profile salt is a stable CRC32 of the name — ``hash(str)`` is
    randomized per process, which silently made traces unreproducible
    across runs.
    """
    rng = np.random.default_rng(seed ^ zlib.crc32(w.name.encode()))
    n = w.n_requests

    # MMPP arrivals: alternate burst (rate*burstiness) and idle phases so
    # the long-run mean rate is w.iops.
    if w.burstiness > 1.0:
        # Half the *requests* arrive in bursts at r_burst = b * iops; the
        # idle-phase rate is set so the long-run mean gap is 1/iops:
        #   0.5/r_burst + 0.5/r_idle = 1/iops.
        b = w.burstiness
        r_burst = b * w.iops
        r_idle = 0.5 * w.iops / max(1.0 - 0.5 / b, 1e-6)
        # Phases are sustained over runs of ~64 requests.
        run = 64
        idx = np.arange(n) // run
        phase_of_run = rng.random(idx.max() + 1) < 0.5
        burst_mask = phase_of_run[idx]
        gaps = np.where(
            burst_mask,
            rng.exponential(1e6 / r_burst, n),
            rng.exponential(1e6 / r_idle, n),
        )
    else:
        gaps = rng.exponential(1e6 / w.iops, n)
    arrival = np.cumsum(gaps)

    is_read = rng.random(n) < w.read_ratio
    # Geometric page counts with the requested mean (>= 1 page).
    p = min(1.0 / w.mean_pages, 1.0)
    n_pages = rng.geometric(p, n).clip(1, 64)
    start_page = rng.integers(0, w.span_pages, n)
    return RequestTrace(arrival, is_read, n_pages.astype(np.int64), start_page)


@functools.lru_cache(maxsize=128)
def cached_trace(w: Workload, seed: int = 0) -> RequestTrace:
    """Memoized :func:`generate_trace` — one trace per (workload, seed).

    Mechanism sweeps (``compare_mechanisms``/``simulate_batch``) call this
    so every mechanism sees the *same* arrivals without regenerating the
    trace.  The arrays are marked read-only: treat the result as immutable
    (call :func:`generate_trace` for a private copy).
    """
    t = generate_trace(w, seed=seed)
    for arr in (t.arrival_us, t.is_read, t.n_pages, t.start_page):
        arr.setflags(write=False)
    return t
