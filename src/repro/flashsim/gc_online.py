"""Online garbage collection: completion-time watermark triggering.

The prepass FTL (:func:`repro.flashsim.ftl.build_ftl_schedule`) decides
*when* GC runs by walking the trace in write-admission order: a host
write admitted at ``t`` schedules its GC at ``t``, regardless of when the
write actually reaches its die.  That is exact for the *mapping* but
approximates the trigger instant — under bursts the pre-pass front-loads
GC storms that real firmware would spread across the burst's drain time.

This module replaces the trigger with device dynamics.  An
:class:`OnlineGC` driver rides inside the event core and advances the
FTL at *simulated* instants:

  * **reads** map (with lazy pre-fill) when admitted, resolving per-block
    wear for attempt sampling and the per-block AR² tR scale;
  * **writes** allocate their physical page when the die actually takes
    the program — the free-block pool is consumed at simulated
    program-start times, not admission times;
  * when a die's projected free-block pool — free blocks plus erases
    already in flight — falls to the **watermark**
    (``GCConfig.watermark_blocks``, default ``gc_threshold_blocks``), the
    driver collects greedy victims *now*: copy-back page-ops and the
    erase are injected into the event core at the current sim time and
    contend through the die scheduler like any other op;
  * an erased block re-enters the free pool only when its **erase
    completes** on the die — reclaim takes simulated time, which is the
    whole point;
  * a write that finds no free page **stalls** (host write throttling):
    it is parked off-queue, its die is released to the GC traffic ahead
    of it, and it re-dispatches when an erase completes.  A device whose
    stalls can never drain raises at end of run rather than reporting
    truncated statistics.

Mapping state machine and victim policy are shared with the prepass
(:class:`repro.flashsim.ftl.PageMapFTL` with ``auto_gc=False`` +
``defer_free=True``); only the trigger and free-pool dynamics differ.

RNG discipline: shard-invariant per-die substreams
--------------------------------------------------
Attempt counts for online-mode reads (host reads at admission, GC reads
at injection) are drawn from **per-die RNG substreams** seeded as
``(run seed, die)``, not from one run-global stream.  A die's draw
sequence then depends only on that die's own event order — which is
identical whether the event core runs one monolithic loop or one loop
per channel (:mod:`repro.flashsim.engine` ``shard=True``) — so sharded
and monolithic online runs are bit-identical.  (There is no bit-parity
contract with the prepass stream; online mode has always sampled on its
own schedule.)

Cross-shard coupling contract
-----------------------------
The only state online GC touches that *could* couple shards is FTL
allocation and host-write stalls — and both are die-partitioned by
construction (see the "Die-partitioned state" section of
:mod:`repro.flashsim.ftl`): free pools, frontiers, sealed sets, and the
stall lists are all per-die, and a die is owned by exactly one channel
shard.  The engine makes the contract explicit through
:meth:`OnlineGC.set_shard_scope`: while a shard's loop runs, the driver
fails fast if any allocation, stall, injection, or erase completion
touches a die outside the shard.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.flashsim.config import SSDConfig
from repro.flashsim.ftl import OP_ERASE, OP_GC_READ, PageMapFTL


class OnlineGC:
    """Event-core driver for completion-time-triggered garbage collection.

    Engine-facing protocol (called by :func:`repro.flashsim.engine.
    run_event_core`):

    ``bind(bufs)``                 attach the run's growing op buffers;
    ``on_read_admit(op, tm)``      map a host read; returns (attempts, tR);
    ``on_program_start(op, tm)``   map a host write at program start;
                                   False = no free page (caller stalls it);
    ``stall(op)``                  park a write that could not start;
    ``on_erase_complete(op, tm)``  return the erased block to the pool;
    ``take_injected()``            drain newly-emitted GC ops to admit;
    ``take_unstalled()``           drain writes made runnable by an erase;
    ``set_shard_scope(dies)``      restrict to one shard's dies (None
                                   clears; sharded engine runs only);
    ``assert_drained()``           end-of-run wedge check.
    """

    def __init__(self, cfg: SSDConfig, expansion, sim, faults=None):
        gc = cfg.gc
        self.cfg = cfg
        self.sim = sim
        #: Optional :class:`repro.flashsim.faults.FaultModel`.  Online mode
        #: draws the recovery ladder at the simulated admission instants
        #: and runs *real* FTL bad-block retirement; draws stay die-local
        #: (the fault model's streams are per-die), preserving the shard
        #: contract.
        self.faults = faults
        self.ftl = PageMapFTL(cfg, lpns=expansion.page_id,
                              auto_gc=False, defer_free=True)
        self.watermark = (
            gc.watermark_blocks if gc.watermark_blocks is not None
            else gc.gc_threshold_blocks
        )
        self.tprog = cfg.timing.tprog_us
        self.terase = gc.t_erase_us
        self.n_dies = cfg.n_dies
        self.n_channels = cfg.n_channels

        self._lpn = expansion.page_id.tolist()
        self._ptype = expansion.ptype.tolist()

        # Per-die attempt-sampling substreams, seeded (run seed, die):
        # a die's draw order is a die-local property, so sharded and
        # monolithic loops consume identical streams (module docstring).
        self._rngs = [
            np.random.default_rng((sim.seed, d)) for d in range(self.n_dies)
        ]
        self._scope: Optional[frozenset] = None

        self.inflight_erases = [0] * self.n_dies
        self._stalled: List[List[int]] = [[] for _ in range(self.n_dies)]
        self._erase_block: Dict[int, Tuple[int, int]] = {}
        self.injected: List[int] = []
        self.unstalled: List[int] = []
        self.write_stalls = 0
        self.prefill_skips = 0
        self.host_reads = 0
        self.bufs = None

    # -- engine protocol -----------------------------------------------------

    def bind(self, bufs) -> None:
        self.bufs = bufs

    def on_read_admit(self, op: int, tm: float) -> Tuple[int, float]:
        """Map a host read at admission; lazy pre-fill may consume pages
        (and thus cross the watermark).  Returns the per-block-resolved
        (attempt count, per-attempt tR).

        Unlike writes, reads can never stall on the free pool: when an
        unmapped lpn arrives while the die has no page to pre-fill into
        (reclaim in flight, pool momentarily dry), the read senses an
        unwritten page at zero wear without consuming capacity —
        counted in ``prefill_skips``.
        """
        lpn = self._lpn[op]
        ftl = self.ftl
        d = lpn % self.n_dies
        self.host_reads += 1
        if lpn in ftl.l2p or ftl.can_alloc(d):
            wear = ftl.host_read(lpn)
            self._check_watermark(d)
        else:
            wear = 0.0
            self.prefill_skips += 1
        pt = self._ptype[op]
        a = self.sim._draw_attempts(pt, wear, rng=self._rngs[d])
        tr = self.sim._tr_for(pt, wear)
        fm = self.faults
        if fm is not None:
            mult = fm.die_mult(d)
            tr *= mult
            extra, rebuild, affected = fm.read_ladder(d, wear)
            b = self.bufs
            rid = b.rid[op]
            if affected:
                fm.outcome.affected_rids.add(rid)
            if extra:
                # Failed decodes re-read at full strength: the engine
                # appends `extra` serial nominal-tR attempts after the
                # op's last sampled attempt (die held throughout).
                b.xa[op] = extra
                b.xtr[op] = float(self.sim._tr_base[pt]) * mult
            if rebuild:
                self._parity_rebuild(d, pt, wear, rid, lpn)
        return (a, tr)

    def _parity_rebuild(self, d: int, pt: int, wear: float, rid: int,
                        lpn: int) -> None:
        """Escalation exhausted: rebuild the page from its superpage
        stripe peers and retire the bad block.

        Peer reads are injected as *real* page-ops on the other dies of
        the channel, carrying the original request id (the request
        completes only when the slowest peer's data is in — ``req_done``
        is a max) and host-read priority under prioritized schedulers.
        Retirement relocates the block's valid pages through the FTL's
        GC frontier; the relocation traffic contends like GC copy-back.
        """
        fm = self.faults
        sim = self.sim
        peers = fm.rebuild_peers(d)
        fm.rebuild_outcome(d, len(peers))
        for dd in peers:
            # Peer draws come from the *trigger* die's fault substream —
            # die-local order, so sharding never reorders them (peers
            # share the trigger's channel, hence its shard).
            pa = sim._draw_attempts(pt, 0.0, rng=fm.rngs[d])
            ptr = sim._tr_for(pt, 0.0) * fm.die_mult(dd)
            self._inject_host_read(dd, rid, pa, ptr)
        if fm.fc.retire_blocks:
            ftl = self.ftl
            ppn = ftl.l2p.get(lpn, -1)
            if ppn >= 0 and ftl.retire_block(d, ppn // ftl.ppb):
                fm.outcome.retired_blocks += 1
                for kind, gd, pt2, w2, blk2 in ftl.drain_events():
                    self._inject(kind, gd, pt2, w2, blk2)
                self._check_watermark(d)

    def on_program_start(self, op: int, tm: float) -> bool:
        """Allocate the write's physical page at simulated program start.

        Returns False when the die has no free page — the caller parks
        the op via :meth:`stall` and it re-dispatches after an erase.
        """
        d = self.bufs.die[op]
        if self._scope is not None and d not in self._scope:
            raise AssertionError(
                f"online GC shard-scope violation: program start on die "
                f"{d} outside the active shard"
            )
        if not self.ftl.can_alloc(d):
            self.write_stalls += 1
            return False
        self.ftl.host_write(self._lpn[op])
        self._check_watermark(d)
        fm = self.faults
        if fm is not None:
            # Reached exactly once per op (stalled retries return False
            # above): apply fail-slow stretch and draw a program failure
            # (+tPROG for the internal reprogram).
            b = self.bufs
            mult = fm.die_mult(d)
            if mult != 1.0:
                b.dur[op] = b.dur[op] * mult
            if fm.draw_program_fail(d):
                fm.outcome.program_fails += 1
                fm.outcome.affected_rids.add(b.rid[op])
                b.dur[op] += self.tprog * mult
        return True

    def stall(self, op: int) -> None:
        d = self.bufs.die[op]
        if self._scope is not None and d not in self._scope:
            raise AssertionError(
                f"online GC shard-scope violation: write stall on die "
                f"{d} outside the active shard"
            )
        self._stalled[d].append(op)

    def on_erase_complete(self, op: int, tm: float) -> None:
        d, blk = self._erase_block.pop(op)
        if self._scope is not None and d not in self._scope:
            raise AssertionError(
                f"online GC shard-scope violation: erase completion on "
                f"die {d} outside the active shard"
            )
        fm = self.faults
        apply_fail = False
        if fm is not None and fm.draw_erase_fail(d):
            # The draw is always consumed (stream position is config-
            # independent), but the failure is suppressed when this erase
            # is the only reclaim a dry die's stalled writes wait on —
            # losing it would wedge the device.  The guard reads only
            # die-local state, so it is shard-invariant.
            if self.ftl.free[d] or not self._stalled[d]:
                apply_fail = True
        if apply_fail:
            fm.outcome.erase_fails += 1
            fm.outcome.retired_blocks += 1
            self.ftl.retire_erase_failed(d, blk)
        else:
            self.ftl.erase_complete(d, blk)
        self.inflight_erases[d] -= 1
        stalled = self._stalled[d]
        if stalled:
            self.unstalled.extend(stalled)
            stalled.clear()

    def take_injected(self) -> List[int]:
        out = self.injected
        self.injected = []
        return out

    def take_unstalled(self) -> List[int]:
        out = self.unstalled
        self.unstalled = []
        return out

    def set_shard_scope(self, dies) -> None:
        """Restrict the driver to one shard's dies (engine sharding).

        While a scope is set, any FTL allocation, write stall, GC
        injection, or erase completion on a die outside it raises — the
        fail-fast form of the cross-shard coupling contract (module
        docstring).  ``None`` clears the scope (monolithic runs never
        set one).
        """
        self._scope = None if dies is None else frozenset(dies)

    def assert_drained(self) -> None:
        parked = sum(len(s) for s in self._stalled)
        if parked or any(self.inflight_erases) or self.injected:
            raise RuntimeError(
                f"online GC wedged at end of run: {parked} stalled writes, "
                f"{sum(self.inflight_erases)} erases still in flight "
                f"(device capacity exhausted? raise GCConfig.blocks_per_die "
                f"or op_ratio)"
            )

    # -- internals -----------------------------------------------------------

    def _check_watermark(self, d: int) -> None:
        """Collect victims while the projected free pool sits at/below the
        watermark.  Projected = free now + erases already in flight — each
        collection queues one erase, so the loop converges without waiting
        for reclaim."""
        ftl = self.ftl
        wm = self.watermark
        while len(ftl.free[d]) + self.inflight_erases[d] <= wm:
            if not ftl._collect(d):
                break
            for kind, gd, pt, wear, blk in ftl.drain_events():
                self._inject(kind, gd, pt, wear, blk)

    def _inject(self, kind: int, d: int, pt: int, wear: float,
                blk: int) -> None:
        """Append one GC page-op to the run's op buffers (admitted by the
        engine at the current sim time)."""
        b = self.bufs
        sim = self.sim
        if self._scope is not None and d not in self._scope:
            raise AssertionError(
                f"online GC shard-scope violation: GC op injected on die "
                f"{d} outside the active shard"
            )
        is_read = kind == OP_GC_READ
        is_erase = kind == OP_ERASE
        fm = self.faults
        mult = 1.0 if fm is None else fm.die_mult(d)
        if is_read:
            a = sim._draw_attempts(pt, wear, rng=self._rngs[d])
            tr = sim._tr_for(pt, wear) * mult
            dur = 0.0
        else:
            a, tr = 1, 0.0
            dur = (self.terase if is_erase else self.tprog) * mult
        b.rid.append(-1)
        b.die.append(d)
        b.ch.append(d % self.n_channels)
        b.read.append(is_read)
        b.erase.append(is_erase)
        b.dur.append(dur)
        b.a.append(a)
        b.tr.append(tr)
        b.rem.append(a)
        b.held.append(0.0)
        b.end.append(0.0)
        b.resid.append(0.0)
        b.susp.append(False)
        if b.host_read is not None:
            b.host_read.append(False)
        if b.xa is not None:
            b.xa.append(0)
            b.xtr.append(0.0)
        o = len(b.rid) - 1
        if is_erase:
            self._erase_block[o] = (d, blk)
            self.inflight_erases[d] += 1
        self.injected.append(o)

    def _inject_host_read(self, d: int, rid: int, a: int, tr: float) -> None:
        """Inject a parity-rebuild stripe-peer read: a real page-op on
        ``d`` carrying the *original* request id (and host-read priority
        under prioritized schedulers), admitted at the current sim time."""
        b = self.bufs
        if self._scope is not None and d not in self._scope:
            raise AssertionError(
                f"online GC shard-scope violation: rebuild read injected "
                f"on die {d} outside the active shard"
            )
        b.rid.append(rid)
        b.die.append(d)
        b.ch.append(d % self.n_channels)
        b.read.append(True)
        b.erase.append(False)
        b.dur.append(0.0)
        b.a.append(a)
        b.tr.append(tr)
        b.rem.append(a)
        b.held.append(0.0)
        b.end.append(0.0)
        b.resid.append(0.0)
        b.susp.append(False)
        if b.host_read is not None:
            b.host_read.append(True)
        if b.xa is not None:
            b.xa.append(0)
            b.xtr.append(0.0)
        self.injected.append(len(b.rid) - 1)

    def stats(self):
        """FTL summary for SimStats (WA, GC traffic, wear)."""
        return self.ftl.stats(host_reads=self.host_reads)
