"""Online garbage collection: completion-time watermark triggering.

The prepass FTL (:func:`repro.flashsim.ftl.build_ftl_schedule`) decides
*when* GC runs by walking the trace in write-admission order: a host
write admitted at ``t`` schedules its GC at ``t``, regardless of when the
write actually reaches its die.  That is exact for the *mapping* but
approximates the trigger instant — under bursts the pre-pass front-loads
GC storms that real firmware would spread across the burst's drain time.

This module replaces the trigger with device dynamics.  An
:class:`OnlineGC` driver rides inside the event core and advances the
FTL at *simulated* instants:

  * **reads** map (with lazy pre-fill) when admitted, resolving per-block
    wear for attempt sampling and the per-block AR² tR scale;
  * **writes** allocate their physical page when the die actually takes
    the program — the free-block pool is consumed at simulated
    program-start times, not admission times;
  * when a die's projected free-block pool — free blocks plus erases
    already in flight — falls to the **watermark**
    (``GCConfig.watermark_blocks``, default ``gc_threshold_blocks``), the
    driver collects greedy victims *now*: copy-back page-ops and the
    erase are injected into the event core at the current sim time and
    contend through the die scheduler like any other op;
  * an erased block re-enters the free pool only when its **erase
    completes** on the die — reclaim takes simulated time, which is the
    whole point;
  * a write that finds no free page **stalls** (host write throttling):
    it is parked off-queue, its die is released to the GC traffic ahead
    of it, and it re-dispatches when an erase completes.  A device whose
    stalls can never drain raises at end of run rather than reporting
    truncated statistics.

Mapping state machine and victim policy are shared with the prepass
(:class:`repro.flashsim.ftl.PageMapFTL` with ``auto_gc=False`` +
``defer_free=True``); only the trigger and free-pool dynamics differ.
GC-read attempt counts are drawn from the owning run's RNG at injection
time (there is no bit-parity contract with the prepass stream), at the
victim block's wear and per-block AR² scale.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.flashsim.config import SSDConfig
from repro.flashsim.ftl import OP_ERASE, OP_GC_READ, PageMapFTL


class OnlineGC:
    """Event-core driver for completion-time-triggered garbage collection.

    Engine-facing protocol (called by :func:`repro.flashsim.engine.
    run_event_core`):

    ``bind(bufs)``                 attach the run's growing op buffers;
    ``on_read_admit(op, tm)``      map a host read; returns (attempts, tR);
    ``on_program_start(op, tm)``   map a host write at program start;
                                   False = no free page (caller stalls it);
    ``stall(op)``                  park a write that could not start;
    ``on_erase_complete(op, tm)``  return the erased block to the pool;
    ``take_injected()``            drain newly-emitted GC ops to admit;
    ``take_unstalled()``           drain writes made runnable by an erase;
    ``assert_drained()``           end-of-run wedge check.
    """

    def __init__(self, cfg: SSDConfig, expansion, sim):
        gc = cfg.gc
        self.cfg = cfg
        self.sim = sim
        self.ftl = PageMapFTL(cfg, lpns=expansion.page_id,
                              auto_gc=False, defer_free=True)
        self.watermark = (
            gc.watermark_blocks if gc.watermark_blocks is not None
            else gc.gc_threshold_blocks
        )
        self.tprog = cfg.timing.tprog_us
        self.terase = gc.t_erase_us
        self.n_dies = cfg.n_dies
        self.n_channels = cfg.n_channels

        self._lpn = expansion.page_id.tolist()
        self._ptype = expansion.ptype.tolist()

        self.inflight_erases = [0] * self.n_dies
        self._stalled: List[List[int]] = [[] for _ in range(self.n_dies)]
        self._erase_block: Dict[int, Tuple[int, int]] = {}
        self.injected: List[int] = []
        self.unstalled: List[int] = []
        self.write_stalls = 0
        self.prefill_skips = 0
        self.host_reads = 0
        self.bufs = None

    # -- engine protocol -----------------------------------------------------

    def bind(self, bufs) -> None:
        self.bufs = bufs

    def on_read_admit(self, op: int, tm: float) -> Tuple[int, float]:
        """Map a host read at admission; lazy pre-fill may consume pages
        (and thus cross the watermark).  Returns the per-block-resolved
        (attempt count, per-attempt tR).

        Unlike writes, reads can never stall on the free pool: when an
        unmapped lpn arrives while the die has no page to pre-fill into
        (reclaim in flight, pool momentarily dry), the read senses an
        unwritten page at zero wear without consuming capacity —
        counted in ``prefill_skips``.
        """
        lpn = self._lpn[op]
        ftl = self.ftl
        d = lpn % self.n_dies
        self.host_reads += 1
        if lpn in ftl.l2p or ftl.can_alloc(d):
            wear = ftl.host_read(lpn)
            self._check_watermark(d)
        else:
            wear = 0.0
            self.prefill_skips += 1
        pt = self._ptype[op]
        return self.sim._draw_attempts(pt, wear), self.sim._tr_for(pt, wear)

    def on_program_start(self, op: int, tm: float) -> bool:
        """Allocate the write's physical page at simulated program start.

        Returns False when the die has no free page — the caller parks
        the op via :meth:`stall` and it re-dispatches after an erase.
        """
        d = self.bufs.die[op]
        if not self.ftl.can_alloc(d):
            self.write_stalls += 1
            return False
        self.ftl.host_write(self._lpn[op])
        self._check_watermark(d)
        return True

    def stall(self, op: int) -> None:
        self._stalled[self.bufs.die[op]].append(op)

    def on_erase_complete(self, op: int, tm: float) -> None:
        d, blk = self._erase_block.pop(op)
        self.ftl.erase_complete(d, blk)
        self.inflight_erases[d] -= 1
        stalled = self._stalled[d]
        if stalled:
            self.unstalled.extend(stalled)
            stalled.clear()

    def take_injected(self) -> List[int]:
        out = self.injected
        self.injected = []
        return out

    def take_unstalled(self) -> List[int]:
        out = self.unstalled
        self.unstalled = []
        return out

    def assert_drained(self) -> None:
        parked = sum(len(s) for s in self._stalled)
        if parked or any(self.inflight_erases) or self.injected:
            raise RuntimeError(
                f"online GC wedged at end of run: {parked} stalled writes, "
                f"{sum(self.inflight_erases)} erases still in flight "
                f"(device capacity exhausted? raise GCConfig.blocks_per_die "
                f"or op_ratio)"
            )

    # -- internals -----------------------------------------------------------

    def _check_watermark(self, d: int) -> None:
        """Collect victims while the projected free pool sits at/below the
        watermark.  Projected = free now + erases already in flight — each
        collection queues one erase, so the loop converges without waiting
        for reclaim."""
        ftl = self.ftl
        wm = self.watermark
        while len(ftl.free[d]) + self.inflight_erases[d] <= wm:
            if not ftl._collect(d):
                break
            for kind, gd, pt, wear, blk in ftl.drain_events():
                self._inject(kind, gd, pt, wear, blk)

    def _inject(self, kind: int, d: int, pt: int, wear: float,
                blk: int) -> None:
        """Append one GC page-op to the run's op buffers (admitted by the
        engine at the current sim time)."""
        b = self.bufs
        sim = self.sim
        is_read = kind == OP_GC_READ
        is_erase = kind == OP_ERASE
        if is_read:
            a = sim._draw_attempts(pt, wear)
            tr = sim._tr_for(pt, wear)
            dur = 0.0
        else:
            a, tr = 1, 0.0
            dur = self.terase if is_erase else self.tprog
        b.rid.append(-1)
        b.die.append(d)
        b.ch.append(d % self.n_channels)
        b.read.append(is_read)
        b.erase.append(is_erase)
        b.dur.append(dur)
        b.a.append(a)
        b.tr.append(tr)
        b.rem.append(a)
        b.held.append(0.0)
        b.end.append(0.0)
        b.resid.append(0.0)
        b.susp.append(False)
        if b.host_read is not None:
            b.host_read.append(False)
        o = len(b.rid) - 1
        if is_erase:
            self._erase_block[o] = (d, blk)
            self.inflight_erases[d] += 1
        self.injected.append(o)

    def stats(self):
        """FTL summary for SimStats (WA, GC traffic, wear)."""
        return self.ftl.stats(host_reads=self.host_reads)
