"""String-addressable trace sources: ``"msr:web_0?rescale=0.5&limit=2000"``.

The registry lets every run API, benchmark cell, and doc example name a
trace with one string instead of wiring loaders and transform chains by
hand.  Grammar::

    spec        := [scheme ":"] name ["?" params]
    scheme      := "synthetic" | "msr" | "blktrace"
    params      := key "=" value ("&" key "=" value)*

Bare names (no scheme) resolve to synthetic profiles (``"websearch"``)
or to sources registered via :func:`register_source`.  File schemes
(``msr`` / ``blktrace``) resolve ``name`` against the search path
(:func:`add_search_path`; defaults: ``$REPRO_TRACE_DIR``, ``./traces``,
``./tests/data``, and the repo's ``tests/data`` when running from a
checkout), trying the bare name plus ``.csv`` / ``.txt`` / ``.gz``
suffixes.

Recognized params (each maps to one transform, applied in a fixed
canonical order — filter, window, limit, dense, rescale, sample — so a
spec is a *set* of knobs, not an ordered program; build a
:class:`~repro.flashsim.workloads.base.TraceSource` directly for custom
chains):

    ``rw=read|write``     keep one request class (RWFilter)
    ``start=U`` ``end=U`` arrival window in us (Window)
    ``limit=N``           first N requests (Truncate)
    ``dense=0|1``         dense footprint remap (DenseRemap;
                          **default 1 for file schemes**, 0 for synthetic)
    ``rescale=F``         arrival-rate multiplier (TimeRescale)
    ``iops=X``            rescale to an absolute target IOPS (TimeRescale)
    ``sample=F``          seeded Bernoulli subsample (Subsample)
    ``pages=K``           ingestion page size in KiB (file schemes only)
    ``action=A``          blktrace event class to keep (default Q)

Every resolved source flows through the shared content-hash-keyed trace
cache (:meth:`TraceSource.trace`), the file-backed extension of the
synthetic layer's ``cached_trace`` memoization.
"""

from __future__ import annotations

import os
from pathlib import Path
from typing import Callable, Dict, List, Union

from repro.flashsim.workloads.base import TraceSource, Workload
from repro.flashsim.workloads.ingest import FileSource
from repro.flashsim.workloads.synthetic import SyntheticSource, make_workloads
from repro.flashsim.workloads.transforms import (
    DenseRemap,
    RWFilter,
    Subsample,
    TimeRescale,
    Truncate,
    Window,
)

_FILE_SCHEMES = {"msr": ("csv",), "blktrace": ("txt", "log")}

#: Transform types that sit AFTER ``limit`` in the canonical param order
#: (filter, window, limit, dense, rescale, sample).  The run APIs'
#: ``n_requests`` knob inserts its Truncate before the first of these so
#: it lands exactly where ``?limit=N`` would — keep this tuple in sync
#: with :func:`_build_transforms`, which is the order's definition.
POST_LIMIT_TRANSFORMS = (DenseRemap, TimeRescale, Subsample)

#: Explicitly registered named sources (name -> source or factory).
_NAMED: Dict[str, Union[TraceSource, Callable[[], TraceSource]]] = {}

#: Directories searched (in order) when resolving file-scheme names.
_SEARCH_PATHS: List[Path] = []


def _default_search_paths() -> List[Path]:
    paths = []
    env = os.environ.get("REPRO_TRACE_DIR")
    if env:
        paths.append(Path(env))
    cwd = Path.cwd()
    paths += [cwd / "traces", cwd / "tests" / "data"]
    # Running from a checkout: <repo>/tests/data relative to this file
    # (src/repro/flashsim/workloads/registry.py -> parents[4] == <repo>).
    repo = Path(__file__).resolve().parents[4]
    paths.append(repo / "tests" / "data")
    return paths


def trace_search_paths() -> List[Path]:
    """The active search path (user-added entries first)."""
    return list(_SEARCH_PATHS) + _default_search_paths()


def add_search_path(path) -> None:
    """Prepend a directory to the file-scheme search path."""
    _SEARCH_PATHS.insert(0, Path(path))


def register_source(name: str,
                    source: Union[TraceSource,
                                  Callable[[], TraceSource]]) -> None:
    """Register a source (or zero-arg factory) under a bare name."""
    _NAMED[name] = source


def resolve_trace_file(name: str, scheme: str) -> Path:
    """Find ``name`` on the search path (exact, +ext, +.gz variants)."""
    exts = _FILE_SCHEMES[scheme]
    candidates = [name]
    for ext in exts:
        candidates += [f"{name}.{ext}", f"{name}.{ext}.gz"]
    candidates.append(f"{name}.gz")
    tried = []
    for d in trace_search_paths():
        for c in candidates:
            p = d / c
            tried.append(p)
            if p.is_file():
                return p
    raise FileNotFoundError(
        f"trace {scheme}:{name} not found; searched "
        f"{[str(d) for d in trace_search_paths()]} for {candidates} "
        f"(set REPRO_TRACE_DIR or add_search_path() to extend)"
    )


def _parse_params(query: str, spec: str) -> Dict[str, str]:
    params: Dict[str, str] = {}
    for item in query.split("&"):
        if not item:
            continue
        if "=" not in item:
            raise ValueError(
                f"malformed param {item!r} in trace spec {spec!r} "
                f"(expected key=value)"
            )
        k, v = item.split("=", 1)
        if k in params:
            raise ValueError(f"duplicate param {k!r} in trace spec {spec!r}")
        params[k] = v
    return params


def _build_transforms(params: Dict[str, str], default_dense: bool,
                      spec: str) -> list:
    """Params -> transform chain in canonical order (see module doc)."""
    def pop_num(key, conv):
        v = params.pop(key, None)
        if v is None:
            return None
        try:
            return conv(v)
        except ValueError:
            raise ValueError(
                f"trace spec {spec!r}: {key}= must be a number, got {v!r}"
            ) from None

    def pop_float(key):
        return pop_num(key, float)

    chain = []
    rw = params.pop("rw", None)
    if rw is not None:
        chain.append(RWFilter(keep=rw))
    start, end = pop_float("start"), pop_float("end")
    if start is not None or end is not None:
        chain.append(Window(start_us=start or 0.0,
                            end_us=end if end is not None else float("inf")))
    limit = pop_num("limit", int)
    if limit is not None:
        chain.append(Truncate(limit))
    dense = params.pop("dense", None)
    if dense is None:
        dense_on = default_dense
    elif dense.lower() in ("0", "false", "no", "off"):
        dense_on = False
    elif dense.lower() in ("1", "true", "yes", "on"):
        dense_on = True
    else:
        raise ValueError(
            f"trace spec {spec!r}: dense= must be 0/1 (got {dense!r})"
        )
    if dense_on:
        chain.append(DenseRemap())
    rescale, iops = pop_float("rescale"), pop_float("iops")
    if rescale is not None and iops is not None:
        raise ValueError(
            f"trace spec {spec!r} sets both rescale= and iops= "
            f"(pick one intensity knob)"
        )
    if rescale is not None:
        chain.append(TimeRescale(factor=rescale))
    elif iops is not None:
        chain.append(TimeRescale(target_iops=iops))
    sample = pop_float("sample")
    if sample is not None:
        chain.append(Subsample(sample))
    return chain


def get_source(spec: Union[str, TraceSource, Workload]) -> TraceSource:
    """Resolve a spec string (or pass through a source / wrap a profile)."""
    if isinstance(spec, TraceSource):
        return spec
    if isinstance(spec, Workload):
        return SyntheticSource(spec)
    if not isinstance(spec, str):
        raise TypeError(
            f"trace spec must be a str, TraceSource or Workload, "
            f"got {type(spec).__name__}"
        )

    body, _, query = spec.partition("?")
    scheme, sep, name = body.partition(":")
    if not sep:
        scheme, name = "", body
    params = _parse_params(query, spec)

    if scheme in _FILE_SCHEMES:
        pages = params.pop("pages", None)
        try:
            page_kib = 16 if pages is None else int(pages)
        except ValueError:
            raise ValueError(
                f"trace spec {spec!r}: pages= must be an integer (KiB), "
                f"got {pages!r}"
            ) from None
        # action= is a blktrace knob; on msr specs it must fail like any
        # other unknown param rather than being silently swallowed.
        action = params.pop("action", "Q") if scheme == "blktrace" else "Q"
        chain = _build_transforms(params, default_dense=True, spec=spec)
        if params:
            raise ValueError(
                f"unknown param(s) {sorted(params)} in trace spec {spec!r}"
            )
        path = resolve_trace_file(name, scheme)
        return FileSource(
            path=str(path), fmt=scheme, page_kib=page_kib,
            blktrace_action=action, transforms=tuple(chain), label=body,
        )

    if scheme in ("", "synthetic"):
        chain = _build_transforms(params, default_dense=False, spec=spec)
        if params:
            raise ValueError(
                f"unknown param(s) {sorted(params)} in trace spec {spec!r}"
            )
        profiles = make_workloads()
        if name in profiles:
            return SyntheticSource(profiles[name], transforms=tuple(chain))
        reg = _NAMED.get(name)
        if reg is not None:
            src = reg() if callable(reg) and not isinstance(
                reg, TraceSource) else reg
            return src.with_transforms(*chain) if chain else src
        raise KeyError(
            f"unknown trace source {name!r}: not a synthetic profile "
            f"({sorted(profiles)}) or a registered source "
            f"({sorted(_NAMED)})"
        )

    raise ValueError(
        f"unknown trace scheme {scheme!r} in {spec!r} "
        f"(choose from: synthetic, {', '.join(_FILE_SCHEMES)})"
    )
