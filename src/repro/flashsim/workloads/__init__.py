"""Workload subsystem: pluggable trace sources for the SSD simulator.

Layered package (formerly the single-module synthetic generator; every
pre-refactor ``repro.flashsim.workloads`` import keeps working):

  * :mod:`~repro.flashsim.workloads.base`       — trace schema
    (:class:`Workload`, :class:`RequestTrace` + validation) and the
    :class:`TraceSource` abstraction with process-wide trace caching;
  * :mod:`~repro.flashsim.workloads.synthetic`  — the MMPP generator and
    the ``PROFILES`` / ``GC_PROFILES`` presets (moved verbatim;
    bit-identical per seed, pinned by tests);
  * :mod:`~repro.flashsim.workloads.ingest`     — MSR-Cambridge CSV and
    blktrace text-dump loaders (:class:`FileSource`);
  * :mod:`~repro.flashsim.workloads.transforms` — composable trace
    transforms (dense footprint remap, time rescale, filters, windows,
    seeded subsampling);
  * :mod:`~repro.flashsim.workloads.stats`      — measured trace
    statistics (:func:`trace_stats`), validating the synthetic
    generator's shapes and summarizing ingested traces;
  * :mod:`~repro.flashsim.workloads.registry`   — string-addressable
    sources (``"msr:web_0?rescale=0.5"``) with search-path file
    resolution.

See ``docs/workloads.md`` for the trace schema, the registry grammar,
and ingestion quick-starts.
"""

from repro.flashsim.workloads.base import (
    RequestTrace,
    TraceSource,
    Workload,
    clear_trace_cache,
    freeze_trace,
    touched_pages,
)
from repro.flashsim.workloads.ingest import (
    FileSource,
    file_content_hash,
    load_blktrace_txt,
    load_msr_csv,
    open_trace_file,
)
from repro.flashsim.workloads.registry import (
    add_search_path,
    get_source,
    register_source,
    resolve_trace_file,
    trace_search_paths,
)
from repro.flashsim.workloads.stats import (
    TraceStats,
    burstiness_from_scv,
    trace_stats,
)
from repro.flashsim.workloads.synthetic import (
    GC_PROFILES,
    PROFILES,
    SyntheticSource,
    cached_trace,
    generate_trace,
    make_workloads,
)
from repro.flashsim.workloads.transforms import (
    DenseRemap,
    RWFilter,
    Subsample,
    TimeRescale,
    Truncate,
    Window,
)

__all__ = [
    # schema + sources
    "RequestTrace",
    "TraceSource",
    "Workload",
    "SyntheticSource",
    "FileSource",
    "clear_trace_cache",
    "freeze_trace",
    "touched_pages",
    # synthetic profiles (pre-refactor surface)
    "GC_PROFILES",
    "PROFILES",
    "cached_trace",
    "generate_trace",
    "make_workloads",
    # ingestion
    "file_content_hash",
    "load_blktrace_txt",
    "load_msr_csv",
    "open_trace_file",
    # registry
    "add_search_path",
    "get_source",
    "register_source",
    "resolve_trace_file",
    "trace_search_paths",
    # stats
    "TraceStats",
    "burstiness_from_scv",
    "trace_stats",
    # transforms
    "DenseRemap",
    "RWFilter",
    "Subsample",
    "TimeRescale",
    "Truncate",
    "Window",
]
