"""Trace schema and the :class:`TraceSource` abstraction.

The workload subsystem feeds the simulator flat per-request arrays
(:class:`RequestTrace`) regardless of where they came from.  Two kinds
of producers exist:

  * the **synthetic** MMPP generator (:mod:`repro.flashsim.workloads.
    synthetic`) — statistically-shaped stand-ins for the paper's twelve
    real-world block traces, parameterized by a :class:`Workload`
    profile;
  * **file-backed** loaders (:mod:`repro.flashsim.workloads.ingest`) —
    MSR-Cambridge CSVs and blktrace text dumps parsed into the same
    arrays.

:class:`TraceSource` unifies them: a source *names* a trace, builds it
on demand (``trace(seed)``), supports composable post-processing
(:meth:`TraceSource.with_transforms`), and carries a structural
``cache_key`` so built traces are memoized process-wide — the
content-hash-keyed extension of the synthetic layer's ``cached_trace``.
The run APIs (``simulate`` / ``compare_mechanisms`` / ``simulate_batch``)
accept a :class:`Workload`, a registry spec string (see
:mod:`repro.flashsim.workloads.registry`), or any :class:`TraceSource`.
"""

from __future__ import annotations

import abc
import dataclasses
from collections import OrderedDict
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Workload:
    """One synthetic trace profile (the generator's six statistical axes)."""

    name: str
    read_ratio: float          # fraction of requests that are reads [0, 1]
    iops: float                # mean arrival rate (requests/s)
    burstiness: float          # >1: bursty MMPP; 1: plain Poisson
    mean_pages: float          # mean request size (16 KiB pages)
    n_requests: int = 20000    # trace length (requests)
    #: Logical address-space footprint (pages).  The paper's read-dominant
    #: profiles roam a large cold span; write-heavy FTL/GC profiles use a
    #: small span so sustained writes overwrite hot data, fill the
    #: over-provisioned capacity, and force garbage collection.
    span_pages: int = 1 << 22

    @property
    def read_dominant(self) -> bool:
        return self.read_ratio >= 0.90


@dataclasses.dataclass
class RequestTrace:
    """Flat arrays describing one trace (generated or externally loaded).

    Requests touch ``n_pages`` consecutive logical pages starting at
    ``start_page``; the simulator stripes logical pages across dies.
    Construction validates the schema (:meth:`validate`), so a malformed
    ingested trace fails loudly instead of corrupting the page-op
    expansion downstream.
    """

    arrival_us: np.ndarray     # (N,) arrival times (us; need not be sorted)
    is_read: np.ndarray        # (N,) bool: True = read, False = write
    n_pages: np.ndarray        # (N,) request length (16 KiB pages)
    start_page: np.ndarray     # (N,) first logical page number

    def __post_init__(self):
        self.validate()

    def validate(self) -> None:
        """Schema check; raises ``ValueError`` with the specific defect.

        Enforced invariants: all four arrays 1-D with equal lengths;
        arrivals finite and non-negative; ``is_read`` boolean;
        ``n_pages``/``start_page`` integer dtypes with ``n_pages >= 1``
        and ``start_page >= 0``.
        """
        arrays = {
            "arrival_us": self.arrival_us, "is_read": self.is_read,
            "n_pages": self.n_pages, "start_page": self.start_page,
        }
        for fname, a in arrays.items():
            if not isinstance(a, np.ndarray):
                raise ValueError(
                    f"RequestTrace.{fname} must be a numpy array, "
                    f"got {type(a).__name__}"
                )
            if a.ndim != 1:
                raise ValueError(
                    f"RequestTrace.{fname} must be 1-D, got shape {a.shape}"
                )
        n = self.arrival_us.shape[0]
        for fname, a in arrays.items():
            if a.shape[0] != n:
                raise ValueError(
                    f"RequestTrace arrays must have equal lengths: "
                    f"arrival_us has {n}, {fname} has {a.shape[0]}"
                )
        if n == 0:
            raise ValueError("RequestTrace must hold at least one request")
        if self.is_read.dtype != np.bool_:
            raise ValueError(
                f"RequestTrace.is_read must be bool, got {self.is_read.dtype}"
            )
        for fname in ("n_pages", "start_page"):
            a = arrays[fname]
            if not np.issubdtype(a.dtype, np.integer):
                raise ValueError(
                    f"RequestTrace.{fname} must be an integer dtype, "
                    f"got {a.dtype}"
                )
        if not np.isfinite(self.arrival_us).all():
            raise ValueError("RequestTrace.arrival_us has non-finite entries")
        if self.arrival_us.size and float(self.arrival_us.min()) < 0.0:
            raise ValueError(
                f"RequestTrace.arrival_us must be non-negative "
                f"(min={float(self.arrival_us.min())!r})"
            )
        if int(self.n_pages.min()) < 1:
            raise ValueError(
                f"RequestTrace.n_pages must be >= 1 "
                f"(min={int(self.n_pages.min())})"
            )
        if int(self.start_page.min()) < 0:
            raise ValueError(
                f"RequestTrace.start_page must be >= 0 "
                f"(min={int(self.start_page.min())})"
            )

    def __len__(self) -> int:
        return int(self.arrival_us.shape[0])


def touched_pages(trace: RequestTrace) -> np.ndarray:
    """Sorted unique logical pages the trace touches (its footprint).

    A request covers the interval ``[start_page, start_page + n_pages)``;
    the union of all intervals, flattened and deduplicated.  Shared by
    the dense-footprint remap (:mod:`~repro.flashsim.workloads.
    transforms`) and :func:`~repro.flashsim.workloads.stats.trace_stats`.
    """
    n_pages = np.asarray(trace.n_pages, np.int64)
    starts = np.asarray(trace.start_page, np.int64)
    total = int(n_pages.sum())
    base = np.cumsum(n_pages) - n_pages
    off = np.arange(total, dtype=np.int64) - np.repeat(base, n_pages)
    return np.unique(np.repeat(starts, n_pages) + off)


def freeze_trace(trace: RequestTrace) -> RequestTrace:
    """Mark a trace's arrays read-only (shared/cached traces are immutable)."""
    for a in (trace.arrival_us, trace.is_read, trace.n_pages,
              trace.start_page):
        a.setflags(write=False)
    return trace


#: Process-wide built-trace cache: ``TraceSource.cache_key(seed)`` ->
#: frozen RequestTrace.  The file-backed analogue of the synthetic
#: layer's ``functools.lru_cache`` on ``cached_trace`` — keys embed the
#: source identity (file content hash for file sources) and the
#: transform chain, so a changed file or chain never aliases.  Bounded
#: like its synthetic counterpart: LRU-evicted past ``_TRACE_CACHE_MAX``
#: entries, so long seeded sweeps over large traces don't grow memory
#: without limit.
_TRACE_CACHE: "OrderedDict[tuple, RequestTrace]" = OrderedDict()
_TRACE_CACHE_MAX = 128


def clear_trace_cache() -> None:
    """Drop every memoized source-built trace (test/tooling hook)."""
    _TRACE_CACHE.clear()


class TraceSource(abc.ABC):
    """A named producer of :class:`RequestTrace` objects.

    Subclasses implement :meth:`_build` (construct the raw trace for a
    seed) and :meth:`cache_key`.  :meth:`trace` adds the shared behavior:
    transform application (deterministic per seed) and process-wide
    memoization with read-only arrays — callers must treat results as
    immutable, exactly like ``cached_trace``.
    """

    #: Human-readable identity (registry spec or profile name).
    name: str = "<anonymous>"
    #: Composable post-processing chain, applied in order by ``trace()``.
    transforms: Tuple = ()

    @abc.abstractmethod
    def _build(self, seed: int) -> RequestTrace:
        """Construct the raw (pre-transform) trace for ``seed``."""

    @abc.abstractmethod
    def cache_key(self, seed: int) -> tuple:
        """Structural identity of ``trace(seed)`` — must change whenever
        the built arrays could (source content, parameters, transforms)."""

    def trace(self, seed: int = 0) -> RequestTrace:
        """The (memoized, frozen) trace for ``seed``.

        The raw build — and the longest deterministic (unseeded) prefix
        of the transform chain — is memoized separately through a
        shorter-chain copy of this source, so a seeded chain over an
        expensive build (``"msr:<1M rows>?sample=0.85"``: parse + dense
        remap, then Bernoulli thinning) pays the parse and the remap
        once and re-runs only the seeded tail per seed.
        """
        key = self.cache_key(seed)
        t = _TRACE_CACHE.get(key)
        if t is None:
            chain = self.transforms
            if chain:
                n_det = 0
                for tf in chain:
                    if getattr(tf, "seeded", True):
                        break
                    n_det += 1
                # Recurse on a strictly shorter chain (the all-
                # deterministic case keeps n_det=0 -> raw build, since
                # its cache_key already collapses the seed where legal).
                if n_det == len(chain):
                    n_det = 0
                base = dataclasses.replace(self, transforms=chain[:n_det])
                t = base.trace(seed)
                for j in range(n_det, len(chain)):
                    t = chain[j].apply(
                        t, seed=self._transform_seed(seed, j, chain[j]))
            else:
                t = self._build(seed)
            t = freeze_trace(t)
            _TRACE_CACHE[key] = t
            if len(_TRACE_CACHE) > _TRACE_CACHE_MAX:
                _TRACE_CACHE.popitem(last=False)
        else:
            _TRACE_CACHE.move_to_end(key)
        return t

    @staticmethod
    def _transform_seed(seed: int, index: int, transform) -> int:
        """Per-transform RNG seed: deterministic in (seed, chain position,
        transform identity), so identical chains replay identically and
        repeated transforms in one chain draw independent streams."""
        import zlib

        tag = f"{index}:{getattr(transform, 'key', repr(transform))}"
        return (seed ^ zlib.crc32(tag.encode())) & 0x7FFFFFFF

    def with_transforms(self, *transforms) -> "TraceSource":
        """A copy of this source with ``transforms`` appended to the chain.

        Concrete sources are frozen dataclasses carrying a ``transforms``
        field, so this is a structural copy — the original is untouched.
        """
        return dataclasses.replace(
            self, transforms=tuple(self.transforms) + tuple(transforms)
        )

    # -- conveniences --------------------------------------------------------

    def stats(self, seed: int = 0):
        """Measured :class:`~repro.flashsim.workloads.stats.TraceStats`."""
        from repro.flashsim.workloads.stats import trace_stats

        return trace_stats(self.trace(seed))

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        tf = f", transforms={list(self.transforms)!r}" if self.transforms \
            else ""
        return f"{type(self).__name__}({self.name!r}{tf})"
