"""Synthetic MMPP workload profiles with distinct I/O characteristics.

The paper evaluates on twelve real-world block traces.  Traces are not
redistributable, so we generate statistically-shaped equivalents covering
the same axes the paper varies: read ratio (read-dominant vs mixed),
request size, arrival burstiness, and intensity — plus a logical-span
axis that the write-heavy FTL/GC profiles (``GC_PROFILES``) shrink to
force overwrites and garbage collection.  Profiles are named after the
MSR-Cambridge / enterprise classes they emulate.

Arrivals are a Markov-modulated Poisson process (bursty <-> idle phases);
sizes are drawn from a small-page-biased geometric mixture, matching the
4-64 KiB concentration of the original traces.

**Stability contract**: :func:`generate_trace` / :func:`cached_trace`
are deterministic per ``(profile, seed)`` and pinned bit-for-bit by
``tests/test_workloads.py`` against checksums recorded before the
workloads package refactor — the generator here is the pre-refactor
module's, moved verbatim.  Real ingested traces validate the generator's
*shapes* through :func:`~repro.flashsim.workloads.stats.trace_stats`.
"""

from __future__ import annotations

import dataclasses
import functools
import zlib
from typing import Dict, Tuple

import numpy as np

from repro.flashsim.workloads.base import (
    RequestTrace,
    TraceSource,
    Workload,
    freeze_trace,
)

#: The six profiles (read ratio / intensity / size / burstiness all vary).
PROFILES = (
    Workload("websearch", read_ratio=0.99, iops=14000, burstiness=2.0, mean_pages=1.6),
    Workload("ycsb-b",    read_ratio=0.95, iops=20000, burstiness=1.0, mean_pages=1.0),
    Workload("graph",     read_ratio=0.98, iops=15000, burstiness=3.0, mean_pages=1.2),
    Workload("usr",       read_ratio=0.91, iops=9000,  burstiness=2.5, mean_pages=2.2),
    Workload("oltp",      read_ratio=0.70, iops=18000, burstiness=1.5, mean_pages=1.0),
    Workload("prxy",      read_ratio=0.55, iops=12000, burstiness=2.0, mean_pages=1.4),
)

#: Write-heavy MMPP profiles for the FTL/GC regime (MSR-Cambridge print/
#: research/source-control server classes: write-dominated traffic
#: re-walking a small hot span).  Sustained small-span overwrites are
#: what fill the over-provisioned capacity and keep the garbage
#: collector busy — the contention regime the in-place simulator could
#: never reach.  ``src`` mixes in a substantial read fraction so the
#: scheduler sweep (host-read priority / GC preemption) measures the
#: read tail on a statistically meaningful read population.
GC_PROFILES = (
    Workload("prn",   read_ratio=0.11, iops=16000, burstiness=2.0,
             mean_pages=1.6, span_pages=1 << 13),
    Workload("rsrch", read_ratio=0.09, iops=10000, burstiness=3.0,
             mean_pages=1.1, span_pages=1 << 12),
    Workload("src",   read_ratio=0.30, iops=14000, burstiness=2.0,
             mean_pages=1.3, span_pages=1 << 13),
)


def make_workloads() -> Dict[str, Workload]:
    """Name -> profile map over the paper's six profiles + GC profiles."""
    return {w.name: w for w in PROFILES + GC_PROFILES}


def generate_trace(w: Workload, seed: int = 0) -> RequestTrace:
    """Generate a trace for a profile (deterministic per seed).

    The per-profile salt is a stable CRC32 of the name — ``hash(str)`` is
    randomized per process, which silently made traces unreproducible
    across runs.
    """
    rng = np.random.default_rng(seed ^ zlib.crc32(w.name.encode()))
    n = w.n_requests

    # MMPP arrivals: alternate burst (rate*burstiness) and idle phases so
    # the long-run mean rate is w.iops.
    if w.burstiness > 1.0:
        # Half the *requests* arrive in bursts at r_burst = b * iops; the
        # idle-phase rate is set so the long-run mean gap is 1/iops:
        #   0.5/r_burst + 0.5/r_idle = 1/iops.
        b = w.burstiness
        r_burst = b * w.iops
        r_idle = 0.5 * w.iops / max(1.0 - 0.5 / b, 1e-6)
        # Phases are sustained over runs of ~64 requests.
        run = 64
        idx = np.arange(n) // run
        phase_of_run = rng.random(idx.max() + 1) < 0.5
        burst_mask = phase_of_run[idx]
        gaps = np.where(
            burst_mask,
            rng.exponential(1e6 / r_burst, n),
            rng.exponential(1e6 / r_idle, n),
        )
    else:
        gaps = rng.exponential(1e6 / w.iops, n)
    arrival = np.cumsum(gaps)

    is_read = rng.random(n) < w.read_ratio
    # Geometric page counts with the requested mean (>= 1 page).
    p = min(1.0 / w.mean_pages, 1.0)
    n_pages = rng.geometric(p, n).clip(1, 64)
    start_page = rng.integers(0, w.span_pages, n)
    return RequestTrace(arrival, is_read, n_pages.astype(np.int64), start_page)


@functools.lru_cache(maxsize=128)
def cached_trace(w: Workload, seed: int = 0) -> RequestTrace:
    """Memoized :func:`generate_trace` — one trace per (workload, seed).

    Mechanism sweeps (``compare_mechanisms``/``simulate_batch``) call this
    so every mechanism sees the *same* arrivals without regenerating the
    trace.  The arrays are marked read-only: treat the result as immutable
    (call :func:`generate_trace` for a private copy).
    """
    return freeze_trace(generate_trace(w, seed=seed))


@dataclasses.dataclass(frozen=True)
class SyntheticSource(TraceSource):
    """A :class:`TraceSource` over one synthetic :class:`Workload` profile.

    With an empty transform chain, ``trace(seed)`` delegates straight to
    :func:`cached_trace` — byte-identical arrays, same memoization — so
    wrapping a profile in a source costs nothing and changes nothing.
    Transforms route through the shared :class:`TraceSource` machinery.
    """

    workload: Workload
    transforms: Tuple = ()

    @property
    def name(self) -> str:
        return self.workload.name

    def _build(self, seed: int) -> RequestTrace:
        return cached_trace(self.workload, seed=seed)

    def cache_key(self, seed: int) -> tuple:
        return ("synthetic", dataclasses.astuple(self.workload),
                tuple(t.key for t in self.transforms), seed)

    def trace(self, seed: int = 0) -> RequestTrace:
        if not self.transforms:           # exact legacy path, exact cache
            return cached_trace(self.workload, seed=seed)
        return super().trace(seed)
