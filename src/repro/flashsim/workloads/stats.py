"""Measured trace statistics — the bridge between real and synthetic.

:func:`trace_stats` measures, from the flat arrays alone, the same six
axes the synthetic generator is parameterized by: intensity (IOPS), read
ratio, request size, burstiness, footprint, and span.  Two uses:

  * **generator validation** — for every synthetic profile the measured
    stats must land within documented tolerance of the ``Workload`` spec
    (regression-tested in ``tests/test_workloads.py``), so the MMPP
    stand-ins provably have the shapes they claim;
  * **ingest sanity** — a freshly parsed MSR/blktrace file gets a
    one-line summary (``TraceStats.as_row``) whose IOPS/read-ratio can be
    checked against the trace's published characteristics.

Burstiness is recovered from the squared coefficient of variation (SCV)
of inter-arrival gaps.  For the repo's MMPP (half the requests in burst
phases at rate ``b * iops``, idle rate chosen to keep the long-run mean)
the marginal gap SCV is ``2/b² - 4/b + 3``, which inverts to

    ``b = 1 / (1 - sqrt((scv - 1) / 2))``

— exact at 1 for plain Poisson and monotone in ``b``; the estimator is
moment-based, so it needs no knowledge of phase boundaries and applies
unchanged to real traces (reported as ``mmpp_burstiness``, i.e. "the
MMPP b that would produce this dispersion").
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from repro.flashsim.workloads.base import RequestTrace, touched_pages


@dataclasses.dataclass(frozen=True)
class TraceStats:
    """Measured statistics of one :class:`RequestTrace`."""

    n_requests: int        # requests in the trace
    duration_s: float      # arrival span (first to last, seconds)
    iops: float            # n_requests / duration (requests/s)
    read_ratio: float      # fraction of read requests [0, 1]
    mean_pages: float      # mean request length (16 KiB pages)
    total_pages: int       # total page-ops the trace expands to
    footprint_pages: int   # distinct logical pages touched
    span_pages: int        # max touched page + 1 (raw address span)
    gap_scv: float         # squared coeff. of variation of arrival gaps
    mmpp_burstiness: float # MMPP b recovered from gap_scv (>= 1)

    @property
    def sparsity(self) -> float:
        """span / footprint — 1.0 for dense traces, large for raw LBAs."""
        return self.span_pages / max(self.footprint_pages, 1)

    def as_row(self) -> str:
        return (
            f"n={self.n_requests} dur={self.duration_s:7.3f}s "
            f"iops={self.iops:9.0f} rd={self.read_ratio:.2f} "
            f"pages={self.mean_pages:4.2f} burst={self.mmpp_burstiness:4.2f} "
            f"footprint={self.footprint_pages} span={self.span_pages}"
        )


def burstiness_from_scv(scv: float) -> float:
    """Invert the MMPP gap-SCV relation ``scv = 2/b² - 4/b + 3``.

    Clipped to ``b >= 1`` (sub-Poisson dispersion reads as 1) and capped
    where the closed form blows up (``scv -> 3`` is the ``b -> inf``
    limit of this MMPP family; beyond it the dispersion exceeds what the
    family can express and the cap keeps the estimate finite).
    """
    excess = max(scv - 1.0, 0.0)
    root = math.sqrt(excess / 2.0)
    if root >= 0.999:
        root = 0.999
    return 1.0 / (1.0 - root)


def trace_stats(trace: RequestTrace) -> TraceStats:
    """Measure a trace's statistical axes (see module docstring)."""
    arrival = np.sort(np.asarray(trace.arrival_us, np.float64))
    n = arrival.size
    duration_s = float(arrival[-1] - arrival[0]) / 1e6
    iops = n / duration_s if duration_s > 0 else float("inf")

    gaps = np.diff(arrival)
    if gaps.size >= 2 and float(gaps.mean()) > 0:
        m = float(gaps.mean())
        scv = float(gaps.var()) / (m * m)
    else:
        scv = 0.0

    touched = touched_pages(trace)
    return TraceStats(
        n_requests=n,
        duration_s=duration_s,
        iops=iops,
        read_ratio=float(np.asarray(trace.is_read).mean()),
        mean_pages=float(np.asarray(trace.n_pages).mean()),
        total_pages=int(np.asarray(trace.n_pages).sum()),
        footprint_pages=int(touched.size),
        span_pages=int(touched[-1]) + 1,
        gap_scv=scv,
        mmpp_burstiness=burstiness_from_scv(scv),
    )
