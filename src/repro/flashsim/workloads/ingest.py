"""Real-trace ingestion: MSR-Cambridge CSVs and blktrace text dumps.

Both loaders produce the standard :class:`RequestTrace` arrays at the
simulator's page granularity (``page_kib``, default 16 KiB — matching
``SSDConfig.page_kib``): byte offsets/sizes become the covered page
interval ``[offset // page, ceil((offset + size) / page))``, timestamps
become microseconds relative to the first record, and the file's record
order is preserved (the page-op expansion stable-sorts unsorted arrivals
itself).

**MSR-Cambridge** (`load_msr_csv`): the SNIA block-trace format —
7 CSV columns ``Timestamp,Hostname,DiskNumber,Type,Offset,Size,
ResponseTime`` with Windows FILETIME timestamps (100 ns ticks since
1601).  Values that are clearly not FILETIME (< 1e14) are read as
seconds, so pre-normalized excerpts load too.  Gzip is detected by
magic bytes, not filename.

**blktrace** (`load_blktrace_txt`): default ``blkparse`` text output —
``dev cpu seq time pid action rwbs sector + nsectors [proc]`` — keeping
one event class per request (``action="Q"``, the issue queue, by
default) and 512-byte sectors.

Raw ingested traces are *sparse*: a few hundred MB of touched pages
scattered across the volume's full LBA span.  Run them through
:class:`~repro.flashsim.workloads.transforms.DenseRemap` (the registry
does this by default) before FTL-enabled simulation so auto-OP sizing
sees the footprint, not the span.
"""

from __future__ import annotations

import dataclasses
import gzip
import hashlib
import io
import os
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.flashsim.workloads.base import RequestTrace, TraceSource

#: FILETIME tick values are ~1.2e17 for the MSR collection era; anything
#: this large cannot be seconds or microseconds since any epoch in use.
_FILETIME_THRESHOLD = 1e14

_SECTOR_BYTES = 512


def open_trace_file(path) -> io.TextIOBase:
    """Open a trace file for text reading, transparently ungzipping.

    Detection is by the gzip magic bytes (``1f 8b``), not the suffix, so
    both ``web_0.csv`` and ``web_0.csv.gz`` work under either name.
    """
    path = os.fspath(path)
    with open(path, "rb") as probe:
        magic = probe.read(2)
    if magic == b"\x1f\x8b":
        return io.TextIOWrapper(gzip.open(path, "rb"), encoding="utf-8")
    return open(path, "r", encoding="utf-8")


def _looks_numeric(s: str) -> bool:
    try:
        int(s)
        return True
    except ValueError:
        return False


def _pages_of(offset_bytes: int, size_bytes: int,
              page_bytes: int) -> Tuple[int, int]:
    """Byte extent -> (start_page, n_pages >= 1) covered page interval."""
    start = offset_bytes // page_bytes
    end = -((offset_bytes + max(size_bytes, 1)) // -page_bytes)  # ceil-div
    return start, max(end - start, 1)


def _finalize(arrival_us, rows_r: List[bool], rows_s: List[int],
              rows_n: List[int], what: str, path) -> RequestTrace:
    if len(arrival_us) == 0:
        raise ValueError(f"no parsable {what} records in {os.fspath(path)!r}")
    t = np.asarray(arrival_us, np.float64)
    t = t - float(t.min())
    return RequestTrace(
        arrival_us=t,
        is_read=np.asarray(rows_r, bool),
        n_pages=np.asarray(rows_n, np.int64),
        start_page=np.asarray(rows_s, np.int64),
    )


def load_msr_csv(path, page_kib: int = 16) -> RequestTrace:
    """Parse an MSR-Cambridge CSV (optionally gzipped) into a trace.

    Malformed rows (wrong field count, non-numeric offset/size, unknown
    Type) raise with the offending line number — a half-garbled file
    should fail loudly, not simulate quietly.  A single leading header
    line is tolerated and skipped.
    """
    page_bytes = page_kib * 1024
    rows_r: List[bool] = []
    rows_s: List[int] = []
    rows_n: List[int] = []
    raw_ts: List = []   # int (FILETIME ticks) or float (seconds)
    with open_trace_file(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            parts = line.split(",")
            if len(parts) != 7:
                raise ValueError(
                    f"{os.fspath(path)!r}:{lineno}: expected 7 CSV fields "
                    f"(MSR-Cambridge format), got {len(parts)}"
                )
            ts_s, _host, _disk, typ, off_s, size_s, _resp = parts
            typ = typ.strip().lower()
            if typ in ("read", "r"):
                is_read = True
            elif typ in ("write", "w"):
                is_read = False
            elif lineno == 1 and not _looks_numeric(off_s):
                continue  # a real header line ("...,Offset,Size,...")
            else:
                # A malformed FIRST record must fail like any other —
                # only a genuinely non-numeric line 1 reads as a header.
                raise ValueError(
                    f"{os.fspath(path)!r}:{lineno}: unknown Type {typ!r} "
                    f"(expected Read/Write)"
                )
            try:
                # Timestamps parse as int when possible: FILETIME ticks
                # (~1.28e17) exceed float64's 2^53 exact-integer range
                # (ulp = 16 ticks = 1.6 us), so the rebase below must
                # happen in integer arithmetic to keep gaps exact.
                try:
                    ts = int(ts_s)
                except ValueError:
                    ts = float(ts_s)
                off = int(off_s)
                size = int(size_s)
            except ValueError as e:
                raise ValueError(
                    f"{os.fspath(path)!r}:{lineno}: non-numeric "
                    f"timestamp/offset/size: {e}"
                ) from None
            if off < 0 or size < 0:
                raise ValueError(
                    f"{os.fspath(path)!r}:{lineno}: negative offset/size"
                )
            start, n = _pages_of(off, size, page_bytes)
            raw_ts.append(ts)
            rows_r.append(is_read)
            rows_s.append(start)
            rows_n.append(n)
    if not raw_ts:
        raise ValueError(
            f"no parsable MSR records in {os.fspath(path)!r}"
        )
    if max(raw_ts) > _FILETIME_THRESHOLD:
        # FILETIME ticks -> us, rebased exactly while still integer
        t0 = min(raw_ts)
        arrival = np.array([t - t0 for t in raw_ts], np.float64) / 10.0
    else:
        arrival = np.asarray(raw_ts, np.float64) * 1e6   # seconds -> us
    return _finalize(arrival, rows_r, rows_s, rows_n, "MSR", path)


def load_blktrace_txt(path, page_kib: int = 16,
                      action: str = "Q") -> RequestTrace:
    """Parse default ``blkparse`` text output into a trace.

    Keeps lines whose action field equals ``action`` (default ``"Q"``,
    the request-queue event — one per host request) and whose RWBS field
    marks a data read or write; everything else (plugs, completions,
    non-matching events, the trailing summary) is skipped.  Sector
    arithmetic assumes 512-byte sectors.
    """
    page_bytes = page_kib * 1024
    rows_t: List[float] = []
    rows_r: List[bool] = []
    rows_s: List[int] = []
    rows_n: List[int] = []
    with open_trace_file(path) as f:
        for line in f:
            parts = line.split()
            # dev cpu seq time pid action rwbs sector + nsectors [proc]
            if len(parts) < 10 or parts[5] != action or parts[8] != "+":
                continue
            rwbs = parts[6]
            if "R" in rwbs and "W" not in rwbs:
                is_read = True
            elif "W" in rwbs:
                is_read = False
            else:
                continue  # barrier/discard/etc.
            try:
                t_us = float(parts[3]) * 1e6
                sector = int(parts[7])
                nsect = int(parts[9])
            except ValueError:
                continue  # summary/garbage line
            start, n = _pages_of(sector * _SECTOR_BYTES,
                                 nsect * _SECTOR_BYTES, page_bytes)
            rows_t.append(t_us)
            rows_r.append(is_read)
            rows_s.append(start)
            rows_n.append(n)
    return _finalize(rows_t, rows_r, rows_s, rows_n,
                     f"blktrace {action!r}", path)


_LOADERS = {"msr": load_msr_csv, "blktrace": load_blktrace_txt}

#: (path, size, mtime_ns) -> content sha256; avoids re-hashing the same
#: file for every cache_key probe while still catching edits.
_CONTENT_HASHES: Dict[Tuple[str, int, int], str] = {}


def file_content_hash(path) -> str:
    """SHA-256 of the file bytes (memoized per (path, size, mtime))."""
    p = os.fspath(path)
    st = os.stat(p)
    key = (p, st.st_size, st.st_mtime_ns)
    h = _CONTENT_HASHES.get(key)
    if h is None:
        digest = hashlib.sha256()
        with open(p, "rb") as f:
            for chunk in iter(lambda: f.read(1 << 20), b""):
                digest.update(chunk)
        h = digest.hexdigest()
        _CONTENT_HASHES[key] = h
    return h


@dataclasses.dataclass(frozen=True)
class FileSource(TraceSource):
    """A :class:`TraceSource` over one on-disk trace file.

    ``fmt`` selects the loader (``"msr"`` or ``"blktrace"``).  The cache
    key embeds the file's *content hash* (not its path), so a re-pointed
    symlink or edited excerpt can never serve a stale cached trace, and
    identical files under different paths share one build.
    """

    path: str
    fmt: str = "msr"
    page_kib: int = 16
    blktrace_action: str = "Q"
    transforms: Tuple = ()
    label: Optional[str] = None

    def __post_init__(self):
        if self.fmt not in _LOADERS:
            raise ValueError(
                f"unknown trace format {self.fmt!r} "
                f"(choose from {tuple(_LOADERS)})"
            )

    @property
    def name(self) -> str:
        return self.label or f"{self.fmt}:{Path(self.path).stem}"

    def _build(self, seed: int) -> RequestTrace:
        if self.fmt == "blktrace":
            return load_blktrace_txt(self.path, page_kib=self.page_kib,
                                     action=self.blktrace_action)
        return load_msr_csv(self.path, page_kib=self.page_kib)

    def cache_key(self, seed: int) -> tuple:
        # The seed only matters when a transform actually consumes RNG
        # (``seeded`` — e.g. Subsample); deterministic chains (the
        # default DenseRemap) build once and serve every seed, so a
        # multi-seed sweep never re-parses the file.  Unknown/custom
        # transforms conservatively count as seeded.
        seeded = any(getattr(t, "seeded", True) for t in self.transforms)
        return ("file", self.fmt, file_content_hash(self.path),
                self.page_kib, self.blktrace_action,
                tuple(t.key for t in self.transforms),
                seed if seeded else 0)
