"""Composable trace transforms.

Real block traces rarely fit the simulator as-recorded: an hour-long MSR
volume replayed verbatim would idle the device for minutes between
bursts, its LBA footprint is a sparse scatter across a terabyte span,
and a 10^6-row file is far past what a per-PR benchmark cell needs.
Each transform here is a small frozen dataclass mapping
``RequestTrace -> RequestTrace``:

  * :class:`TimeRescale` — scale arrival times to a target IOPS (or by a
    rate factor), preserving relative burst structure;
  * :class:`DenseRemap`  — bijective remap of the touched logical pages
    onto the dense range ``[0, footprint)``, preserving request order
    and intra-request contiguity (what FTL auto-OP sizing and die
    striping want to see);
  * :class:`RWFilter`    — keep only reads or only writes;
  * :class:`Window`      — keep requests with arrivals in ``[start, end)``
    (rebased to 0);
  * :class:`Truncate`    — keep the first N requests in arrival order;
  * :class:`Subsample`   — seeded Bernoulli thinning (per-request keep
    probability), the sampling axis mechanism sweeps use for multi-seed
    confidence intervals on deterministic file traces.

Transforms are applied by :meth:`TraceSource.trace` in chain order; each
receives a seed derived from ``(run seed, chain position, transform
key)``, so chains are deterministic under a fixed seed and repeated
transforms draw independent streams.  ``key`` is the transform's
structural identity inside trace cache keys and the registry grammar.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.flashsim.workloads.base import RequestTrace, touched_pages


def _take(trace: RequestTrace, idx: np.ndarray,
          rebase_time: bool = False) -> RequestTrace:
    """A sub-trace at request indices ``idx`` (file order preserved)."""
    if idx.size == 0:
        raise ValueError(
            "transform selected zero requests — widen the Window/filter "
            "or raise the Subsample fraction"
        )
    arrival = trace.arrival_us[idx].astype(np.float64, copy=True)
    if rebase_time:
        arrival -= float(arrival.min())
    return RequestTrace(
        arrival_us=arrival,
        is_read=trace.is_read[idx].copy(),
        n_pages=trace.n_pages[idx].copy(),
        start_page=trace.start_page[idx].copy(),
    )


@dataclasses.dataclass(frozen=True)
class TimeRescale:
    """Scale arrival times so the trace replays at a different intensity.

    Exactly one of ``factor`` (rate multiplier: 2.0 = twice the IOPS) or
    ``target_iops`` (absolute requests/s, measured rate computed from the
    trace span) must be set.  Gaps scale uniformly, so burst structure
    (the ratio of burst to idle rates) is preserved — only the clock
    speed changes.
    """

    #: Whether ``apply`` consumes the seed (cache-key relevance).
    seeded = False

    factor: Optional[float] = None
    target_iops: Optional[float] = None

    def __post_init__(self):
        if (self.factor is None) == (self.target_iops is None):
            raise ValueError(
                "TimeRescale needs exactly one of factor= or target_iops="
            )
        if self.factor is not None and self.factor <= 0:
            raise ValueError(f"factor must be > 0, got {self.factor}")
        if self.target_iops is not None and self.target_iops <= 0:
            raise ValueError(
                f"target_iops must be > 0, got {self.target_iops}"
            )

    @property
    def key(self) -> str:
        if self.factor is not None:
            return f"rescale({self.factor!r})"
        return f"rescale(iops={self.target_iops!r})"

    def apply(self, trace: RequestTrace, seed: int = 0) -> RequestTrace:
        arrival = trace.arrival_us.astype(np.float64, copy=True)
        lo = float(arrival.min())
        if self.factor is not None:
            factor = self.factor
        else:
            span_s = (float(arrival.max()) - lo) / 1e6
            if span_s <= 0:
                raise ValueError(
                    "TimeRescale(target_iops=...) needs a trace with a "
                    "positive time span"
                )
            measured = len(trace) / span_s
            factor = self.target_iops / measured
        arrival = lo + (arrival - lo) / factor
        return RequestTrace(
            arrival_us=arrival,
            is_read=trace.is_read.copy(),
            n_pages=trace.n_pages.copy(),
            start_page=trace.start_page.copy(),
        )


@dataclasses.dataclass(frozen=True)
class DenseRemap:
    """Remap the touched logical pages onto the dense range [0, footprint).

    Real traces scatter a few hundred MB of touched data across terabyte
    LBA spans.  The remap is a bijection on the *touched* page set (sorted
    order preserved, so spatially-close pages stay close) and keeps every
    request's pages contiguous: a request's interval ``[s, s+n)`` is
    entirely touched, hence consecutive in the sorted unique page array,
    hence mapped to consecutive dense ids.  Downstream this is what makes
    ``PageMapFTL`` auto-OP sizing see the real footprint rather than the
    raw sparse span, and what spreads die striping (``page % n_dies``)
    evenly for strided address patterns.
    """

    #: Whether ``apply`` consumes the seed (cache-key relevance).
    seeded = False

    @property
    def key(self) -> str:
        return "dense"

    def apply(self, trace: RequestTrace, seed: int = 0) -> RequestTrace:
        touched = touched_pages(trace)
        start = np.searchsorted(touched, np.asarray(trace.start_page,
                                                    np.int64))
        return RequestTrace(
            arrival_us=trace.arrival_us.astype(np.float64, copy=True),
            is_read=trace.is_read.copy(),
            n_pages=trace.n_pages.copy(),
            start_page=start.astype(np.int64),
        )


@dataclasses.dataclass(frozen=True)
class RWFilter:
    """Keep only reads (``keep="read"``) or only writes (``keep="write"``)."""

    #: Whether ``apply`` consumes the seed (cache-key relevance).
    seeded = False

    keep: str = "read"

    def __post_init__(self):
        if self.keep not in ("read", "write"):
            raise ValueError(
                f"RWFilter.keep must be 'read' or 'write', got {self.keep!r}"
            )

    @property
    def key(self) -> str:
        return f"rw({self.keep})"

    def apply(self, trace: RequestTrace, seed: int = 0) -> RequestTrace:
        mask = trace.is_read if self.keep == "read" else ~trace.is_read
        return _take(trace, np.flatnonzero(mask))


@dataclasses.dataclass(frozen=True)
class Window:
    """Keep requests whose arrival falls in ``[start_us, end_us)``.

    Arrivals are rebased so the window starts at 0 (the simulator should
    not idle through the cut prefix).
    """

    #: Whether ``apply`` consumes the seed (cache-key relevance).
    seeded = False

    start_us: float = 0.0
    end_us: float = float("inf")

    def __post_init__(self):
        if self.end_us <= self.start_us:
            raise ValueError(
                f"Window needs start_us < end_us, got "
                f"[{self.start_us}, {self.end_us})"
            )

    @property
    def key(self) -> str:
        return f"window({self.start_us!r},{self.end_us!r})"

    def apply(self, trace: RequestTrace, seed: int = 0) -> RequestTrace:
        a = trace.arrival_us
        idx = np.flatnonzero((a >= self.start_us) & (a < self.end_us))
        return _take(trace, idx, rebase_time=True)


@dataclasses.dataclass(frozen=True)
class Truncate:
    """Keep the first ``n`` requests in arrival order (stable on ties)."""

    #: Whether ``apply`` consumes the seed (cache-key relevance).
    seeded = False

    n: int

    def __post_init__(self):
        if self.n < 1:
            raise ValueError(f"Truncate.n must be >= 1, got {self.n}")

    @property
    def key(self) -> str:
        return f"truncate({self.n})"

    def apply(self, trace: RequestTrace, seed: int = 0) -> RequestTrace:
        if len(trace) <= self.n:
            return trace
        a = trace.arrival_us
        if np.any(np.diff(a) < 0):
            idx = np.sort(np.argsort(a, kind="stable")[: self.n])
        else:
            idx = np.arange(self.n)
        return _take(trace, idx)


@dataclasses.dataclass(frozen=True)
class Subsample:
    """Seeded Bernoulli thinning: keep each request with probability
    ``fraction`` (order preserved, arrivals untouched).

    This is the sampling axis that gives deterministic file traces a
    seed dimension: benchmark cells run the same excerpt under several
    subsample seeds and report mean ± CI, mirroring the multi-seed
    convention of the synthetic cells.
    """

    #: Whether ``apply`` consumes the seed (cache-key relevance).
    seeded = True

    fraction: float

    def __post_init__(self):
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(
                f"Subsample.fraction must be in (0, 1], got {self.fraction}"
            )

    @property
    def key(self) -> str:
        return f"sample({self.fraction!r})"

    def apply(self, trace: RequestTrace, seed: int = 0) -> RequestTrace:
        if self.fraction >= 1.0:
            return trace
        rng = np.random.default_rng(seed)
        keep = rng.random(len(trace)) < self.fraction
        return _take(trace, np.flatnonzero(keep))
