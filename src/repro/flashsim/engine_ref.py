"""Reference (seed) closure-based DES engine — kept for validation.

This is the original pure-Python engine: per-page closures scheduled on a
``(time, seq, fn, args)`` tuple heap, with attempt counts sampled per
request at admit time.  The production engine (:mod:`repro.flashsim.ssd`)
replaced it with an integer-opcode event core over preallocated arrays;
this module is retained for

  * the seed-equivalence regression test (the array engine must reproduce
    these SimStats exactly on a fixed trace), and
  * ``benchmarks/microbench_sim.py``, which reports the array engine's
    speedup over this engine in ``BENCH_sim.json``.

Select it at the API level with ``simulate(..., engine="reference")``.

Parity notes
------------
This engine predates the FTL/GC subsystem (:mod:`repro.flashsim.ftl`) and
models the original *in-place-program* device only.  The array-vs-
reference equivalence contract therefore covers exactly the surface both
engines implement: host reads (serial and PR²-pipelined) and host writes,
with ``SSDConfig.gc.enabled = False`` — including write-heavy traces,
which tests/test_flashsim_equiv.py pins.  Running it with GC enabled
raises ``NotImplementedError`` rather than silently simulating a
different device; FTL runs are validated by their own invariant tests
(tests/test_ftl.py) instead of by cross-engine equivalence.
"""

from __future__ import annotations

import heapq
from collections import deque
from typing import List, Optional

import numpy as np

from repro.flashsim.ssd import PAGE_TYPE_ORDER, SSDSim, SimStats, TraceExpansion
from repro.flashsim.workloads import RequestTrace


class _Resource:
    """Single-server FCFS resource (a die or a channel)."""

    __slots__ = ("busy_until", "queue", "busy_total")

    def __init__(self):
        self.busy_until = 0.0
        self.queue: deque = deque()
        self.busy_total = 0.0


class SSDSimRef(SSDSim):
    """The seed closure engine behind the SSDSim policy/CDF setup.

    Subclasses :class:`SSDSim` so the policy resolution, AR² scale lookup,
    and attempt-CDF construction are literally shared with the array
    engine — only the event core differs, which is exactly the surface the
    equivalence tests compare.
    """

    # -- discrete-event engine -------------------------------------------------

    def run(
        self,
        trace: RequestTrace,
        expansion: Optional[TraceExpansion] = None,  # unused: closure engine
        schedule=None,                               # FTL: not supported here
        validate: bool = False,                      # engine-core flag: n/a
    ) -> SimStats:
        if schedule is not None or self.cfg.gc.enabled:
            raise NotImplementedError(
                "the reference (seed) engine predates the FTL/GC subsystem; "
                "run FTL configurations with engine='array' "
                "(see the parity notes in repro/flashsim/engine_ref.py)"
            )
        if self.cfg.scheduler != "fcfs":
            raise NotImplementedError(
                "the reference (seed) engine predates the scheduler layer "
                "and implements strict FCFS die queues only; run "
                f"scheduler={self.cfg.scheduler!r} with engine='array'"
            )
        cfg, t = self.cfg, self.cfg.timing
        tdma, tecc, tprog = t.tdma_us, t.tecc_us, t.tprog_us
        pipelined = self.policy.pipelined
        tr_by_type = (
            np.array([t.tr_us[pt] for pt in PAGE_TYPE_ORDER]) * self.tr_scale
        )

        dies = [_Resource() for _ in range(cfg.n_dies)]
        chans = [_Resource() for _ in range(cfg.n_channels)]

        heap: List = []
        seq = 0

        def push(time_, fn, *args):
            nonlocal seq
            heapq.heappush(heap, (time_, seq, fn, args))
            seq += 1

        n = len(trace.arrival_us)
        req_remaining = np.zeros(n, np.int64)
        req_done_at = np.zeros(n)
        total_attempts = 0
        total_read_pages = 0

        # ------- resource helpers ------------------------------------------

        def die_acquire(d: int, now: float, fn, *args):
            res = dies[d]
            if now >= res.busy_until and not res.queue:
                res.busy_until = np.inf  # held until explicit release
                fn(now, *args)
            else:
                res.queue.append((fn, args))

        def die_release(d: int, now: float, held_since: float):
            res = dies[d]
            res.busy_total += now - held_since
            res.busy_until = now
            if res.queue:
                fn, args = res.queue.popleft()
                res.busy_until = np.inf
                fn(now, *args)

        def chan_request(ch: int, now: float, dur: float, fn):
            """FCFS channel: start the transfer asap; fn fires at completion.

            The channel chains its own job-done events, so callbacks never
            manage channel state.
            """
            res = chans[ch]
            if res.busy_until <= now and not res.queue:
                res.busy_until = now + dur
                res.busy_total += dur
                push(now + dur, _chan_job_done, ch, fn)
            else:
                res.queue.append((dur, fn))

        def _chan_job_done(tm: float, ch: int, fn):
            res = chans[ch]
            if res.queue:
                dur, fn2 = res.queue.popleft()
                res.busy_until = tm + dur
                res.busy_total += dur
                push(tm + dur, _chan_job_done, ch, fn2)
            fn(tm)

        # ------- read page-op state machines --------------------------------

        def page_complete(now: float, rid: int):
            req_remaining[rid] -= 1
            req_done_at[rid] = max(req_done_at[rid], now)

        def start_read_serial(now: float, rid: int, d: int, ch: int,
                              a: int, tr: float):
            held_since = now
            state = {"i": 0}

            def xfer_done(tm):
                ecc_done = tm + tecc
                state["i"] += 1
                if state["i"] >= a:
                    die_release(d, tm, held_since)       # die freed at last xfer
                    page_complete(ecc_done, rid)
                else:
                    # Decode failed; firmware re-senses with the next entry.
                    push(ecc_done + tr, sense_fire)

            def sense_fire(tm):
                chan_request(ch, tm, tdma, xfer_done)

            push(now + tr, sense_fire)

        def start_read_pipelined(now: float, rid: int, d: int, ch: int,
                                 a: int, tr: float):
            held_since = now
            sense_done_t = [None] * a       # per-attempt milestones
            xfer_done_t = [None] * a
            copied = [False] * a

            def try_copy(i: int, tm: float):
                """copy_i fires when sense i is done and cache reg is free."""
                if copied[i] or sense_done_t[i] is None:
                    return
                if i > 0 and xfer_done_t[i - 1] is None:
                    return
                tc = max(sense_done_t[i], xfer_done_t[i - 1] if i else 0.0)
                copied[i] = True
                chan_request(ch, tc, tdma, lambda tm2: on_xfer(i, tm2))
                if i + 1 < a:
                    push(tc + tr, lambda tm2: on_sense(i + 1, tm2))
                else:
                    # Final attempt leaves the die: charge one speculative
                    # sense when the sequence actually retried.
                    spec = tr if a > 1 else 0.0
                    push(tc + spec, lambda tm2: die_release(d, tm2, held_since))

            def on_sense(i: int, tm: float):
                sense_done_t[i] = tm
                try_copy(i, tm)

            def on_xfer(i: int, tm: float):
                xfer_done_t[i] = tm
                if i + 1 < a:
                    try_copy(i + 1, tm)
                if i == a - 1:
                    page_complete(tm + tecc, rid)

            push(now + tr, lambda tm: on_sense(0, tm))

        # ------- write page-op ----------------------------------------------

        def start_write(now: float, rid: int, d: int, ch: int):
            def xfer_done(tm):
                die_acquire(d, tm, prog_start)

            def prog_start(tm):
                push(tm + tprog, lambda tm2: prog_done(tm2))
                state["held"] = tm

            def prog_done(tm):
                die_release(d, tm, state["held"])
                page_complete(tm, rid)

            state = {"held": now}
            chan_request(ch, now, tdma, xfer_done)

        # ------- request admission ------------------------------------------

        def admit(now: float, rid: int):
            pages = int(trace.n_pages[rid])
            first = int(trace.start_page[rid])
            req_remaining[rid] = pages
            page_ids = first + np.arange(pages)
            if trace.is_read[rid]:
                ptypes = (page_ids % 3).astype(np.int64)
                attempts = self._sample_attempts(ptypes)
                nonlocal_totals[0] += int(attempts.sum())
                nonlocal_totals[1] += pages
                for j in range(pages):
                    d = int(page_ids[j] % cfg.n_dies)
                    ch = cfg.channel_of(d)
                    a = int(attempts[j])
                    tr = float(tr_by_type[ptypes[j]])
                    starter = start_read_pipelined if pipelined else start_read_serial
                    die_acquire(d, now, starter, rid, d, ch, a, tr)
            else:
                for j in range(pages):
                    d = int(page_ids[j] % cfg.n_dies)
                    ch = cfg.channel_of(d)
                    start_write(now, rid, d, ch)

        nonlocal_totals = [0, 0]  # attempts, read pages

        for rid in range(n):
            push(float(trace.arrival_us[rid]), admit, rid)

        # ------- main loop ----------------------------------------------------

        n_events = 0
        while heap:
            tm, _, fn, args = heapq.heappop(heap)
            fn(tm, *args)
            n_events += 1
        self.events_processed = n_events

        total_attempts, total_read_pages = nonlocal_totals
        self.last_req_done_us = req_done_at
        response = req_done_at - trace.arrival_us + cfg.host_overhead_us
        read_resp = response[trace.is_read]
        span = float(req_done_at.max())
        return SimStats(
            mean_us=float(response.mean()),
            p50_us=float(np.percentile(response, 50)),
            p95_us=float(np.percentile(response, 95)),
            p99_us=float(np.percentile(response, 99)),
            read_mean_us=float(read_resp.mean()) if read_resp.size else 0.0,
            n_requests=n,
            mean_read_attempts=(
                total_attempts / total_read_pages if total_read_pages else 0.0
            ),
            die_util=sum(r.busy_total for r in dies) / (span * cfg.n_dies),
            channel_util=sum(r.busy_total for r in chans) / (span * cfg.n_channels),
            read_p99_us=(
                float(np.percentile(read_resp, 99)) if read_resp.size else 0.0
            ),
        )
