"""SSD organization and simulation configuration (MQSim-analogue)."""

from __future__ import annotations

import dataclasses

from repro.core.timing import DEFAULT_TIMING, TimingParams


@dataclasses.dataclass(frozen=True)
class GCConfig:
    """FTL / garbage-collection knobs (see :mod:`repro.flashsim.ftl`).

    With ``enabled=False`` (the default) writes program in place and the
    simulator behaves exactly as before the FTL existed — bit-identical
    event streams, no mapping state.  With ``enabled=True`` host writes go
    through a page-mapping FTL: out-of-place programs, greedy garbage
    collection, and GC copy-back traffic injected into the event core as
    page-ops that contend with host reads on die/channel queues.
    """

    #: Master switch for the page-mapping FTL + garbage collection.
    enabled: bool = False
    #: GC trigger mode.  ``"prepass"`` (default): the deterministic
    #: admission-order pre-pass (:func:`repro.flashsim.ftl.
    #: build_ftl_schedule`) — mapping exact, trigger instants approximated
    #: at write admission; the compatibility mode the equivalence suite
    #: pins.  ``"online"``: completion-time triggering (:mod:`repro.
    #: flashsim.gc_online`) — pages allocate when the die takes the
    #: program, GC fires when the projected free-block pool crosses the
    #: watermark, and erased blocks return to the pool only when their
    #: erase *completes* on the simulated die.
    mode: str = "prepass"
    #: Online mode only: collect while (free + in-flight-erase) blocks per
    #: die <= this watermark.  None uses ``gc_threshold_blocks``.  Raise it
    #: to start reclaim earlier (fewer write stalls, more copy-back).
    watermark_blocks: int | None = None
    #: Over-provisioning: fraction of *physical* capacity held as spare
    #: (industry-typical 7% ~ 0.07).  Used when ``blocks_per_die`` is None
    #: (auto-sizing from the trace footprint); smaller OP -> earlier and
    #: heavier GC.
    op_ratio: float = 0.07
    #: Physical pages per erase block (pages).  Sim-scaled: real TLC
    #: erase blocks hold hundreds-to-thousands of pages, but with 64-way
    #: die parallelism and 10^4-request traces, small blocks let the FTL
    #: reach steady-state GC within a trace; the WA/contention dynamics
    #: are geometry-relative (utilization decides them, not block size).
    pages_per_block: int = 16
    #: Blocks per die; None auto-sizes from the trace's logical footprint
    #: so physical capacity = footprint / (1 - op_ratio).
    blocks_per_die: int | None = None
    #: GC runs while a die's free-block count is <= this (blocks).
    gc_threshold_blocks: int = 2
    #: Block erase latency charged to the die (us; TLC-class ~3 ms).
    t_erase_us: float = 3000.0
    #: P/E cycles a block accrues per erase.  1.0 is physical; larger
    #: values accelerate wear so short traces exercise per-block retry
    #: growth (the wear axis of Cai et al., arXiv:1706.08642).
    pec_per_erase: float = 1.0

    def __post_init__(self):
        if not 0.0 < self.op_ratio < 1.0:
            raise ValueError(f"op_ratio must be in (0, 1), got {self.op_ratio}")
        if self.pages_per_block < 1:
            raise ValueError("pages_per_block must be >= 1")
        if self.gc_threshold_blocks < 1:
            raise ValueError("gc_threshold_blocks must be >= 1")
        if self.mode not in ("prepass", "online"):
            raise ValueError(
                f"GCConfig.mode must be 'prepass' or 'online', "
                f"got {self.mode!r}"
            )
        if self.watermark_blocks is not None and self.watermark_blocks < 1:
            raise ValueError("watermark_blocks must be >= 1 (or None)")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Device fault-injection knobs (see :mod:`repro.flashsim.faults`).

    Attached via ``SSDConfig.faults`` (or the run APIs' ``faults=`` knob);
    ``None`` — the default everywhere — disables the whole failure path
    and keeps runs bit-identical to a fault-free build.  All injection is
    seeded and deterministic: draws come from per-die RNG substreams
    seeded ``(run seed, salt, die)``, separate from the attempt-sampling
    streams, so identical ``(seed, FaultConfig)`` produce identical
    failure sets under any ``shard=`` / ``workers=`` setting — and
    enabling faults never changes which retry-attempt counts are drawn.

    Probabilities default to *derived* values: the uncorrectable-read
    probability comes from :func:`repro.core.ecc.page_fail_probability`
    at the block's wear-resolved condition, and the AR² misprediction
    probability from the mean final-step margin shaved by the reduced-tR
    sense.  Explicit ``*_prob`` overrides replace the derivation (fault-
    matrix sweeps); ``*_scale`` multiplies whichever is in effect.
    """

    #: Probability a read's *final* retry step is uncorrectable.  None
    #: derives it from the ECC page-failure model at the block's
    #: wear-resolved condition (effectively ~0 at paper-default margins).
    uncorrectable_prob: float | None = None
    #: Multiplier on the uncorrectable probability (derived or explicit).
    uncorrectable_scale: float = 1.0
    #: Probability an AR² reduced-tR read exceeds the shaved ECC margin
    #: and must re-read at nominal tR.  None derives it from the mean
    #: final-step margin at the reduced scale; only adaptive-tR policies
    #: sensing below scale 1.0 can mispredict.
    mispredict_prob: float | None = None
    #: Multiplier on the misprediction probability.
    mispredict_scale: float = 1.0
    #: Escalation re-reads (full-strength, nominal tR) attempted before
    #: the controller falls back to a superpage-parity rebuild.
    escalation_attempts: int = 4
    #: Rebuild an uncorrectable page from its superpage stripe peers
    #: (real reads on the other dies of the channel).  False counts the
    #: read as unrecoverable once escalation is exhausted.
    parity_rebuild: bool = True
    #: Retire the failing block after a parity rebuild (FTL relocates its
    #: valid pages; the block never returns to the free pool).
    retire_blocks: bool = True
    #: Fail-slow dies: ``((die, multiplier), ...)`` — the die's sense and
    #: program/erase durations are multiplied (>= 1.0).
    failslow_dies: tuple[tuple[int, float], ...] = ()
    #: Probability a host program fails and is retried (+tPROG latency).
    program_fail_prob: float = 0.0
    #: Probability an erase fails verification: the block is retired
    #: instead of returning to the free pool (online GC only).
    erase_fail_prob: float = 0.0
    #: Seed salt separating fault streams from attempt-sampling streams.
    salt: int = 0x5EED

    def __post_init__(self):
        for name in ("uncorrectable_prob", "mispredict_prob"):
            v = getattr(self, name)
            if v is not None and not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1] or None, got {v}")
        for name in ("program_fail_prob", "erase_fail_prob"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {v}")
        for name in ("uncorrectable_scale", "mispredict_scale"):
            if getattr(self, name) < 0.0:
                raise ValueError(f"{name} must be >= 0, got "
                                 f"{getattr(self, name)}")
        if self.escalation_attempts < 1:
            raise ValueError("escalation_attempts must be >= 1")
        for d, m in self.failslow_dies:
            if d < 0:
                raise ValueError(f"failslow die id must be >= 0, got {d}")
            if m < 1.0:
                raise ValueError(
                    f"failslow multiplier must be >= 1.0 (fail-SLOW), got {m}"
                )


@dataclasses.dataclass(frozen=True)
class HostCacheConfig:
    """Host write-back cache knobs (see :mod:`repro.flashsim.hostcache`).

    Only meaningful on the closed-loop path (``SSDConfig.ncq_depth`` set):
    writes that fit are absorbed into a host-side DRAM cache and complete
    at cache speed; their flash programs are issued later ("flushed") when
    the dirty watermark is crossed, entering the device through the normal
    scheduler/GC machinery as low-priority (non-host-read) programs.
    Reads that hit a dirty/flushing line are served from the cache.
    """

    #: Cache capacity in flash pages.  Occupancy counts every absorbed
    #: page-program until its flush completes on the die.
    capacity_pages: int = 4096
    #: Flushing starts when dirty (not-yet-issued) pages exceed
    #: ``flush_high * capacity_pages`` ...
    flush_high: float = 0.75
    #: ... and stops once they drop to ``flush_low * capacity_pages``.
    flush_low: float = 0.5
    #: Host-side service time (us) for a cache-absorbed write or a
    #: full-cache-hit read (DRAM access; no flash op, no tDMA).
    hit_us: float = 2.0
    #: Flush-order / eviction policy.  ``"fifo"`` (default) flushes
    #: cache lines in absorption order; ``"lru"`` flushes the least
    #: recently *used* line first — read hits and rewrites refresh a
    #: line's recency, so hot dirty lines stay cached longer and keep
    #: serving hits.  Write-amplification accounting is identical under
    #: both (every absorbed page flushes exactly once; only the order
    #: changes).
    eviction: str = "fifo"

    def __post_init__(self):
        if self.capacity_pages < 1:
            raise ValueError("capacity_pages must be >= 1")
        if not 0.0 < self.flush_low <= self.flush_high <= 1.0:
            raise ValueError(
                "need 0 < flush_low <= flush_high <= 1, got "
                f"low={self.flush_low} high={self.flush_high}"
            )
        if self.hit_us < 0.0:
            raise ValueError("hit_us must be >= 0")
        if self.eviction not in ("fifo", "lru"):
            raise ValueError(
                f"HostCacheConfig.eviction must be 'fifo' or 'lru', "
                f"got {self.eviction!r}"
            )


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    """High-end NVMe SSD organization, matching the paper's MQSim setup.

    8 channels x 8 dies (64-way die parallelism), NV-DDR3-class channel
    bandwidth (folded into tDMA), one LDPC engine per channel.
    """

    #: Number of flash channels (each: one shared bus + one LDPC engine).
    n_channels: int = 8
    #: Dies per channel; total die parallelism = n_channels * dies_per_channel.
    dies_per_channel: int = 8
    #: LDPC decoders per channel (modeled as a fixed +tECC; see ssd.py).
    ecc_engines_per_channel: int = 1
    #: Physical page size (KiB); one page-op senses/transfers one page.
    page_kib: int = 16
    #: Host-interface constant overhead per request (us): NVMe submission/
    #: completion, FTL lookup.
    host_overhead_us: float = 8.0
    #: NAND operation latencies (tR / tDMA / tECC / tPROG, all us).
    timing: TimingParams = DEFAULT_TIMING
    #: FTL / garbage-collection configuration (disabled by default).
    gc: GCConfig = GCConfig()
    #: Die-queue scheduling policy (:mod:`repro.flashsim.sched`):
    #: ``"fcfs"`` (strict arrival order — bit-identical to the original
    #: engine), ``"host_prio"`` (host reads jump GC/program ops),
    #: ``"host_prio_aged"`` (host_prio with a starvation bound — GC and
    #: program ops age to the front after ``:N`` bypassing host reads,
    #: e.g. ``"host_prio_aged:8"``), ``"tokens"`` (per-die read/write
    #: token budgets — up to ``r`` host reads then up to ``w`` other ops
    #: per contended round, e.g. ``"tokens:6,2"``), or ``"preempt"``
    #: (host_prio + read-suspend of in-flight GC ops).
    scheduler: str = "fcfs"
    #: Device fault model (:mod:`repro.flashsim.faults`).  ``None`` (the
    #: default) disables fault injection entirely — no failure draws, no
    #: recovery traffic, bit-identical to a fault-free run.
    faults: FaultConfig | None = None
    #: Host NCQ depth for the CLOSED-LOOP frontend.  ``None`` (the
    #: default) keeps the simulator open-loop — every request admitted at
    #: its trace arrival time, bit-identical to all prior output.  An
    #: integer ``>= 1`` bounds the number of in-flight requests: arrivals
    #: wait in a host queue until a device slot frees, `SimStats` gains
    #: queue-wait vs device-time decomposition and throughput counters,
    #: and the engine runs the explicit sense/transfer channel model.
    ncq_depth: int | None = None
    #: Host write-back cache (closed-loop only; requires ``ncq_depth``).
    #: ``None`` sends every write straight to the device.
    host_cache: HostCacheConfig | None = None
    #: Event-core implementation the run APIs select when their
    #: ``engine=`` argument is left unset: ``"array"`` (the bit-pinned
    #: default interpreter), ``"batched"`` (all channel loops advance in
    #: lockstep inside one compiled kernel — bit-identical on its
    #: supported matrix, rejects everything else), ``"auto"`` (resolve
    #: per run: ``batched`` when the config is inside the batched
    #: matrix, else ``array`` — the choice and any fallback reason are
    #: recorded on ``SimStats.engine_selected`` /
    #: ``engine_fallback_reason``, never hidden), or ``"reference"``
    #: (the retired seed engine).  An explicit ``engine=`` on
    #: ``simulate``/``compare_mechanisms``/``simulate_batch`` overrides
    #: this.
    engine: str = "array"
    #: Fused sweep dispatch policy for the batched engine: when a sweep
    #: (``simulate_batch``/``compare_mechanisms``/``runtime.run_cells``)
    #: resolves a grid of cells inside the batched matrix, stack their
    #: op tables along the kernel's lane axis and launch each
    #: static-shape group once instead of dispatching per cell.  Cell
    #: results are bit-identical either way (the cell-axis law; see
    #: :mod:`repro.flashsim.engine_batched`); ``False`` forces one
    #: dispatch per cell.  A ``fuse=`` argument on the sweep APIs
    #: overrides this.
    fuse: bool = True

    def __post_init__(self):
        if self.engine not in ("array", "batched", "auto", "reference"):
            raise ValueError(
                f"SSDConfig.engine must be 'array', 'batched', 'auto', "
                f"or 'reference', got {self.engine!r}"
            )
        if self.n_channels < 1 or self.dies_per_channel < 1:
            raise ValueError(
                f"SSDConfig needs >=1 channel and >=1 die per channel, got "
                f"{self.n_channels}x{self.dies_per_channel}"
            )
        if self.ncq_depth is not None and self.ncq_depth < 1:
            raise ValueError(
                f"ncq_depth must be >= 1 or None, got {self.ncq_depth}"
            )
        if self.host_cache is not None and self.ncq_depth is None:
            raise ValueError(
                "host_cache requires the closed-loop frontend: set "
                "ncq_depth as well"
            )
        from repro.flashsim.sched import get_scheduler

        get_scheduler(self.scheduler)   # raises ValueError on unknown names

    @property
    def n_dies(self) -> int:
        return self.n_channels * self.dies_per_channel

    def channel_of(self, die):
        """Die -> channel mapping (interleaved).  Accepts int or ndarray —
        the single striping rule both simulator engines and the vectorized
        trace expansion share."""
        return die % self.n_channels


@dataclasses.dataclass(frozen=True)
class OperatingCondition:
    """Retention age + wear state the SSD is simulated under.

    Without an FTL this is a *device-global* condition.  With the FTL/GC
    layer enabled it is the **base** condition of the whole device, and
    blocks that garbage collection has erased resolve to a *per-block*
    condition via :meth:`with_wear` — their retry-attempt distributions
    are characterized at the block's higher effective P/E count.
    """

    #: Data retention age (days since program).
    retention_days: float = 90.0
    #: Program/erase cycles endured (device-wide baseline wear).
    pec: float = 0.0

    def with_wear(self, extra_pec: float) -> "OperatingCondition":
        """Per-block resolution: this condition plus block-local wear.

        ``extra_pec`` is the additional P/E cycles a specific block has
        accumulated (e.g. from GC erases) on top of the device baseline.
        Returns ``self`` unchanged for non-positive wear, so the common
        unworn path stays identical to the global-condition path.
        """
        if extra_pec <= 0:
            return self
        return dataclasses.replace(self, pec=self.pec + extra_pec)

    def label(self) -> str:
        if self.retention_days >= 30:
            age = f"{self.retention_days / 30:.0f}mo"
        else:
            age = f"{self.retention_days:.0f}d"
        return f"{age}/{self.pec:.0f}PEC"


DEFAULT_SSD = SSDConfig()
