"""SSD organization and simulation configuration (MQSim-analogue)."""

from __future__ import annotations

import dataclasses

from repro.core.timing import DEFAULT_TIMING, TimingParams


@dataclasses.dataclass(frozen=True)
class SSDConfig:
    """High-end NVMe SSD organization, matching the paper's MQSim setup.

    8 channels x 8 dies (64-way die parallelism), NV-DDR3-class channel
    bandwidth (folded into tDMA), one LDPC engine per channel.
    """

    n_channels: int = 8
    dies_per_channel: int = 8
    ecc_engines_per_channel: int = 1
    page_kib: int = 16
    #: Host-interface constant overhead per request (us): NVMe submission/
    #: completion, FTL lookup.
    host_overhead_us: float = 8.0
    timing: TimingParams = DEFAULT_TIMING

    def __post_init__(self):
        if self.n_channels < 1 or self.dies_per_channel < 1:
            raise ValueError(
                f"SSDConfig needs >=1 channel and >=1 die per channel, got "
                f"{self.n_channels}x{self.dies_per_channel}"
            )

    @property
    def n_dies(self) -> int:
        return self.n_channels * self.dies_per_channel

    def channel_of(self, die):
        """Die -> channel mapping (interleaved).  Accepts int or ndarray —
        the single striping rule both simulator engines and the vectorized
        trace expansion share."""
        return die % self.n_channels


@dataclasses.dataclass(frozen=True)
class OperatingCondition:
    """Retention age + wear state the SSD is simulated under."""

    retention_days: float = 90.0
    pec: float = 0.0

    def label(self) -> str:
        if self.retention_days >= 30:
            age = f"{self.retention_days / 30:.0f}mo"
        else:
            age = f"{self.retention_days:.0f}d"
        return f"{age}/{self.pec:.0f}PEC"


DEFAULT_SSD = SSDConfig()
