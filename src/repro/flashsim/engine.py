"""Array event-core: the flashsim discrete-event interpreter loop.

This module is the bottom layer of the simulator's layered architecture:

  * :mod:`repro.flashsim.ssd` (run orchestration: attempt sampling, stats)
  * :mod:`repro.flashsim.sched` (die-queue policies: fcfs / host_prio / preempt)
  * :mod:`repro.flashsim.gc_online` (completion-time-triggered GC, optional)
  * **this module** — the heap, the busy-until channel collapse, and the
    op-kind dispatch.

Heap records are 2-tuples ``(time, seq << 40 | op_id << 2 | opcode)``: the
packed integer both tie-breaks FIFO (``seq`` in the high bits — push-order
discipline) and carries the whole event, so an event costs one tuple — no
closures, no argument unpacking.  Channels are single-server FCFS with
constant-duration transfers always requested at the current sim time, so
channel state collapses to a cumulative busy-until scalar (a transfer's
grant and completion times are exact at issue) — one heap event per read
attempt instead of two.  Each handler schedules at most one successor
event on its own behalf, so pop+push collapses into a ``heapreplace``
sift; online-GC injections may push extra events mid-handler.

Scheduler integration
---------------------
Die queues are policy objects from :mod:`repro.flashsim.sched`.  Under
``fcfs`` the queue *is* a ``deque`` and the loop executes the exact heap
sequence of the pre-refactor monolithic engine — bit-identical SimStats.
``host_prio`` changes only which op a release dispatches.  ``preempt``
additionally arms two suspend paths:

  * **duration ops** (GC programs, erases): a host read admitted to a die
    held by an in-flight GC duration op suspends it immediately; the op
    re-enters the front of the low-priority class carrying its *residual*
    time (``op_end - now``), and its now-stale release event is ignored
    when it pops (detected by ``op_end[op] != time``).  Suspended elapsed
    time plus residual always sums to the op's original duration.
  * **GC reads**: checked at retry-attempt boundaries (the only points
    read-suspend firmware can interrupt a sense); the op yields with its
    remaining attempts — completed attempts are never re-executed — and
    resumes under the same copy/decode constraints it suspended with
    (``op_end`` stores the constraint instant while suspended).

Host operations are never suspended.

Per-channel sharding (``shard=True``)
-------------------------------------
The loop is parallel by construction: an op's die and channel are bound
by the static stripe (``die % n_channels == channel``), so ops of
different channels never share a die queue, a channel busy-until scalar,
or a scheduler instance.  ``run_event_core(..., shard=True)`` exploits
this by running one *shard loop* per channel — the same interpreter
(:func:`_run_shard`) over the admission substream of that channel's ops,
owning that channel's dies, queues, busy-until scalar, and (online mode)
its slice of the per-die GC state — and then combining the per-shard
completion streams with a thin deterministic merge
(:func:`merge_shard_results`): ``req_done`` is an elementwise max (a
request's pages may span channels), die/channel vectors take each
shard's owned entries, counters add.

The sharded run is **bit-identical** to the monolithic run: within one
shard, events are pushed in the same relative order as the monolithic
loop's events restricted to that channel (push-order tie-breaking is a
per-shard property), and cross-shard state is limited to the commutative
``req_done`` max and additive counters.  Online GC keeps this exact
because the FTL is die-partitioned (see :mod:`repro.flashsim.ftl`) and
its attempt draws come from per-die RNG substreams
(:mod:`repro.flashsim.gc_online`), so the draw sequence of a die does
not depend on how loops interleave across channels.  The shard loops
run sequentially in-process; cross-*run* parallelism lives a layer up in
:mod:`repro.flashsim.runtime`.

Online-GC integration
---------------------
With an :class:`repro.flashsim.gc_online.OnlineGC` driver attached, the
loop calls back at three points: host-read admission (FTL map + lazy
pre-fill + per-block attempt/tR resolution), host-program start (page
allocation at the *simulated* instant the die takes the program — the
free-block watermark trigger), and erase completion (the erased block
re-enters the free pool; stalled writes re-dispatch).  GC page-ops the
driver emits are admitted immediately at the current sim time through
the same queues as everything else.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional

from repro.flashsim.sched import SchedulerPolicy

#: Event opcodes (low 2 bits of a heap record's packed code).
_EV_NEXT = 0    # serial read: sense done -> issue transfer, schedule next
_EV_COPY = 1    # pipelined read: copy into cache register -> issue transfer
_EV_ACQ = 2     # write: transfer landed -> acquire die for programming
_EV_REL = 3     # die release (read end / program end / erase end)

_INF = float("inf")
_SEQ1 = 1 << 40
_OPSHIFT_MASK = (1 << 40) - 1


@dataclasses.dataclass
class OpBuffers:
    """Flat per-op state driving one engine run (plain Python lists).

    The first ``len(arrival)`` entries are the admission stream (pre-
    sorted by arrival time); online GC appends further ops mid-run, so
    every consumer that needs per-op state holds a reference to these
    *growing* lists.  ``host_read`` is built by the engine when the
    scheduler classifies ops (None under fcfs).
    """

    arrival: List[float]      # admission times of the initial stream
    rid: List[int]            # owning request id; -1 for GC/erase ops
    die: List[int]
    ch: List[int]
    read: List[bool]          # read-like (host read or GC read)
    erase: List[bool]
    dur: List[float]          # die-hold duration for write-like/erase ops
    a: List[int]              # attempt counts (reads)
    tr: List[float]           # per-attempt sense time (reads)
    rem: List[int]            # serial: attempts left; pipelined: copy idx
    held: List[float]         # die-held-since timestamp
    end: List[float]          # scheduled release / suspend constraint
    resid: List[float]        # residual duration of a suspended op
    susp: List[bool]          # suspended flag (preempt)
    host_read: Optional[List[bool]] = None
    #: Fault recovery (None without a fault model): extra full-strength
    #: re-reads appended after the op's last sampled attempt — the AR²
    #: misprediction re-read and/or uncorrectable-escalation attempts —
    #: executed as a serial continuation at ``xtr`` (nominal tR) with the
    #: die held throughout.
    xa: Optional[List[int]] = None
    xtr: Optional[List[float]] = None


@dataclasses.dataclass
class EngineResult:
    """Raw outcome of one event-core run (stats assembled by the caller)."""

    req_done: List[float]
    die_tot: List[float]
    ch_tot: List[float]
    die_busy: List[float]
    ch_busy: List[float]
    n_events: int
    gc_suspensions: int       # preempt: suspend events (duration + boundary)
    online_attempts: int      # online mode: total host-read attempts
    online_read_pages: int    # online mode: host read pages admitted
    #: Events retired by the batched lockstep kernel (0 for interpreter
    #: runs) — the "Pallas fast path actually ran" observability counter.
    fast_path_events: int = 0
    #: Number of sweep cells sharing the kernel dispatch that produced
    #: this result (0 = not a fused dispatch) — the "fused sweep
    #: actually ran" observability counter.
    fused_cells: int = 0


def make_buffers(arrival, rid, die, ch, read, erase, dur, a, tr) -> OpBuffers:
    """Assemble :class:`OpBuffers`, deriving the per-run mutable state."""
    P = len(arrival)
    return OpBuffers(
        arrival=arrival, rid=rid, die=die, ch=ch, read=read, erase=erase,
        dur=dur, a=a, tr=tr, rem=a[:], held=[0.0] * P, end=[0.0] * P,
        resid=[0.0] * P, susp=[False] * P,
    )


def run_event_core(
    cfg,
    pipelined: bool,
    policy: SchedulerPolicy,
    bufs: OpBuffers,
    n_requests: int,
    online=None,
    validate: bool = False,
    shard: bool = False,
) -> EngineResult:
    """Run the interpreter loop over one admission stream.

    ``shard=False`` (default) runs the monolithic loop — one heap over
    every channel, the pre-refactor behavior.  ``shard=True`` decomposes
    the run into one loop per channel and merges the per-shard results
    (bit-identical; see the module docstring).  ``validate=True`` asserts
    work conservation (no die left idle while its queue holds a runnable
    op) after every step — test instrumentation, off on the hot path.
    """
    P = len(bufs.arrival)
    host_read = None
    if policy.prioritized:
        op_read, op_rid = bufs.read, bufs.rid
        host_read = [op_read[i] and op_rid[i] >= 0 for i in range(P)]
    bufs.host_read = host_read
    if online is not None:
        online.bind(bufs)

    if not shard or cfg.n_channels == 1:
        res = _run_shard(cfg, pipelined, policy, bufs, n_requests,
                         host_read, online, validate, None)
        if online is not None:
            online.assert_drained()
        return res

    # Per-channel decomposition: partition the admission stream by the
    # static die -> channel stripe.  Online injections never enter these
    # lists (they are admitted mid-loop at the current sim time) and are
    # die-local by the gc_online shard-scope contract, so the partition
    # computed up front stays exhaustive.
    n_ch = cfg.n_channels
    shard_ops: List[List[int]] = [[] for _ in range(n_ch)]
    for i, c in enumerate(bufs.ch[:P]):
        shard_ops[c].append(i)
    results = []
    for c in range(n_ch):
        if online is not None:
            online.set_shard_scope(range(c, cfg.n_dies, n_ch))
        results.append(
            _run_shard(cfg, pipelined, policy, bufs, n_requests,
                       host_read, online, validate, shard_ops[c])
        )
    if online is not None:
        online.set_shard_scope(None)
        online.assert_drained()
    return merge_shard_results(cfg, results)


def _run_shard(
    cfg,
    pipelined: bool,
    policy: SchedulerPolicy,
    bufs: OpBuffers,
    n_requests: int,
    host_read: Optional[List[bool]],
    online,
    validate: bool,
    shard_ops: Optional[List[int]],
) -> EngineResult:
    """One interpreter loop over an admission (sub)stream.

    ``shard_ops=None`` runs the whole stream (the monolithic loop);
    otherwise it is the list of op ids this shard admits, and the loop
    touches only those ops' dies and channel.  State vectors are
    allocated full-size either way — a shard writes only its owned
    entries, which is what :func:`merge_shard_results` reads back out.
    """
    t = cfg.timing
    tdma, tecc = t.tdma_us, t.tecc_us

    adm_t = bufs.arrival
    op_rid, op_die, op_ch = bufs.rid, bufs.die, bufs.ch
    op_read, op_erase, op_dur = bufs.read, bufs.erase, bufs.dur
    op_a, op_tr, op_rem = bufs.a, bufs.tr, bufs.rem
    op_held, op_end, op_resid, op_susp = (
        bufs.held, bufs.end, bufs.resid, bufs.susp
    )
    op_xa, op_xtr = bufs.xa, bufs.xtr
    P = len(adm_t)

    preempt = policy.preemptive

    n_dies, n_ch = cfg.n_dies, cfg.n_channels
    die_busy = [0.0] * n_dies   # busy_until; inf while held
    die_tot = [0.0] * n_dies
    dieq = policy.make_queues(n_dies, host_read)
    die_cur = [-1] * n_dies     # op currently holding the die
    ch_busy = [0.0] * n_ch
    ch_tot = [0.0] * n_ch

    req_done = [0.0] * n_requests

    heap: list = []
    push = heapq.heappush
    pop = heapq.heappop
    replace = heapq.heapreplace
    seqc = 0                      # already-shifted seq (increments 1<<40)
    n_events = 0
    gc_susp = 0
    online_attempts = 0
    online_read_pages = 0

    read_start_ev = _EV_COPY if pipelined else _EV_NEXT

    def admit_gc(o: int, tm: float) -> None:
        """Admit an online-injected GC page-op at the current instant."""
        nonlocal seqc
        if op_read[o]:
            d = op_die[o]
            if tm >= die_busy[d] and not dieq[d]:
                die_busy[d] = _INF
                op_held[o] = tm
                die_cur[d] = o
                if pipelined:
                    op_rem[o] = 0
                push(heap, (tm + op_tr[o], seqc | o << 2 | read_start_ev))
                seqc += _SEQ1
            else:
                dieq[d].append(o)
        elif op_erase[o]:
            d = op_die[o]
            if tm >= die_busy[d] and not dieq[d]:
                die_busy[d] = _INF
                op_held[o] = tm
                die_cur[d] = o
                rel = tm + op_dur[o]
                op_end[o] = rel
                push(heap, (rel, seqc | o << 2 | _EV_REL))
                seqc += _SEQ1
            else:
                dieq[d].append(o)
        else:
            c = op_ch[o]
            b = ch_busy[c]
            done = (b if b > tm else tm) + tdma
            ch_busy[c] = done
            ch_tot[c] += tdma
            push(heap, (done, seqc | o << 2 | _EV_ACQ))
            seqc += _SEQ1

    def drain_online(tm: float) -> None:
        for o in online.take_injected():
            admit_gc(o, tm)

    # Admission cursor merged with the heap (admits never enter it).  The
    # event sequence under fcfs is byte-for-byte the pre-refactor loop's.
    # A shard admits only its own ops (``shard_ops``); the monolithic
    # loop admits positionally (op == ai).
    n_adm = P if shard_ops is None else len(shard_ops)
    ai = 0
    if not n_adm:
        next_adm = _INF
    elif shard_ops is None:
        next_adm = adm_t[0]
    else:
        next_adm = adm_t[shard_ops[0]]
    while True:
        if heap:
            top = heap[0]
            tt = top[0]
        elif next_adm < _INF:
            top = None
            tt = _INF
        else:
            break
        if next_adm <= tt:
            op = ai if shard_ops is None else shard_ops[ai]
            tm = next_adm
            ai += 1
            if ai >= n_adm:
                next_adm = _INF
            elif shard_ops is None:
                next_adm = adm_t[ai]
            else:
                next_adm = adm_t[shard_ops[ai]]
            # Reads contend for their die; writes go straight to
            # the channel (program happens after the transfer);
            # erases hold their die with no channel traffic.
            if op_read[op]:
                if online is not None:
                    a_, tr_ = online.on_read_admit(op, tm)
                    op_a[op] = a_
                    op_rem[op] = a_
                    op_tr[op] = tr_
                    online_attempts += a_
                    online_read_pages += 1
                    if online.injected:
                        drain_online(tm)
                d = op_die[op]
                if tm >= die_busy[d] and not dieq[d]:
                    die_busy[d] = _INF
                    op_held[op] = tm
                    die_cur[d] = op
                    if pipelined:
                        op_rem[op] = 0
                    push(heap, (tm + op_tr[op],
                                seqc | op << 2 | read_start_ev))
                    seqc += _SEQ1
                elif preempt and host_read[op]:
                    dieq[d].append(op)
                    cur = die_cur[d]
                    if cur >= 0 and op_rid[cur] < 0 and not op_read[cur]:
                        # Read-suspend: the in-flight GC program/erase
                        # yields now; its pending release event goes
                        # stale (op_end mismatch) and the op carries its
                        # residual time back into the queue.
                        gc_susp += 1
                        die_tot[d] += tm - op_held[cur]
                        op_resid[cur] = op_end[cur] - tm
                        op_end[cur] = -1.0    # pending release is now stale
                        op_susp[cur] = True
                        dq = dieq[d]
                        dq.resume_push(cur)
                        op2 = dq.pop_next()     # oldest waiting host read
                        op_held[op2] = tm
                        die_cur[d] = op2
                        if pipelined:
                            op_rem[op2] = 0
                        push(heap, (tm + op_tr[op2],
                                    seqc | op2 << 2 | read_start_ev))
                        seqc += _SEQ1
                else:
                    dieq[d].append(op)
            elif op_erase[op]:
                d = op_die[op]
                if tm >= die_busy[d] and not dieq[d]:
                    die_busy[d] = _INF
                    op_held[op] = tm
                    die_cur[d] = op
                    rel = tm + op_dur[op]
                    if preempt:
                        op_end[op] = rel
                    push(heap, (rel, seqc | op << 2 | _EV_REL))
                    seqc += _SEQ1
                else:
                    dieq[d].append(op)
            else:
                c = op_ch[op]
                b = ch_busy[c]
                done = (b if b > tm else tm) + tdma
                ch_busy[c] = done
                ch_tot[c] += tdma
                push(heap, (done, seqc | op << 2 | _EV_ACQ))
                seqc += _SEQ1
            if validate:
                _check_work_conserving(die_busy, dieq)
            continue

        tm, code = top
        ev = code & 3
        op = (code & _OPSHIFT_MASK) >> 2
        n_events += 1

        if ev == _EV_COPY:
            # Pipelined copy into the cache register at tm: the sense is
            # done and the previous transfer has drained.  Issue the
            # transfer (completion time exact at issue) and schedule the
            # next copy at max(sense done, transfer drained) — both
            # already known — or end the sequence.
            c = op_ch[op]
            b = ch_busy[c]
            done = (b if b > tm else tm) + tdma
            ch_busy[c] = done
            ch_tot[c] += tdma
            i = op_rem[op]
            a = op_a[op]
            if i + 1 < a:
                op_rem[op] = i + 1
                if preempt and op_rid[op] < 0 and dieq[op_die[op]].has_host():
                    # Attempt boundary: the GC read yields to the waiting
                    # host read, keeping its remaining attempts and the
                    # cache-register constraint (previous transfer ends
                    # at `done`) for resume.
                    d = op_die[op]
                    dq = dieq[d]
                    gc_susp += 1
                    die_tot[d] += tm - op_held[op]
                    op_susp[op] = True
                    op_end[op] = done
                    dq.resume_push(op)
                    op2 = dq.pop_next()
                    op_held[op2] = tm
                    die_cur[d] = op2
                    op_rem[op2] = 0
                    replace(heap, (tm + op_tr[op2],
                                   seqc | op2 << 2 | _EV_COPY))
                else:
                    tnext = tm + op_tr[op]
                    if done > tnext:
                        tnext = done
                    replace(heap, (tnext, seqc | op << 2 | _EV_COPY))
            elif op_xa is not None and op_xa[op] > 0:
                # Recovery continuation: this attempt's decode *failed*
                # (misprediction or uncorrectable — known at done+tecc).
                # The firmware re-senses serially at full strength; the
                # die stays held for the whole ladder.
                op_rem[op] = op_xa[op]
                op_xa[op] = 0
                op_tr[op] = op_xtr[op]
                replace(heap, (done + tecc + op_tr[op],
                               seqc | op << 2 | _EV_NEXT))
            else:
                rid = op_rid[op]
                if rid >= 0:            # GC reads complete no request
                    fin = done + tecc
                    if fin > req_done[rid]:
                        req_done[rid] = fin
                # Final attempt leaves the die: charge one speculative
                # sense when the sequence actually retried.
                rel = tm + op_tr[op] if a > 1 else tm
                if preempt:
                    op_end[op] = rel
                replace(heap, (rel, seqc | op << 2 | _EV_REL))
            seqc += _SEQ1
        elif ev == _EV_NEXT:
            # Serial read: sense done at tm -> transfer -> decode; on
            # failure the firmware re-senses with the next table entry.
            c = op_ch[op]
            b = ch_busy[c]
            done = (b if b > tm else tm) + tdma
            ch_busy[c] = done
            ch_tot[c] += tdma
            rem = op_rem[op] - 1
            if rem:
                op_rem[op] = rem
                if preempt and op_rid[op] < 0 and dieq[op_die[op]].has_host():
                    # Attempt boundary: yield with remaining attempts;
                    # the decode verdict of this attempt is known at
                    # done + tecc, the resume constraint.
                    d = op_die[op]
                    dq = dieq[d]
                    gc_susp += 1
                    die_tot[d] += tm - op_held[op]
                    op_susp[op] = True
                    op_end[op] = done + tecc
                    dq.resume_push(op)
                    op2 = dq.pop_next()
                    op_held[op2] = tm
                    die_cur[d] = op2
                    replace(heap, (tm + op_tr[op2],
                                   seqc | op2 << 2 | _EV_NEXT))
                else:
                    replace(heap, (done + tecc + op_tr[op],
                                   seqc | op << 2 | _EV_NEXT))
            elif op_xa is not None and op_xa[op] > 0:
                # Recovery continuation (see _EV_COPY): extra serial
                # full-strength re-reads after the failed final attempt.
                op_rem[op] = op_xa[op]
                op_xa[op] = 0
                op_tr[op] = op_xtr[op]
                replace(heap, (done + tecc + op_tr[op],
                               seqc | op << 2 | _EV_NEXT))
            else:
                rid = op_rid[op]
                if rid >= 0:            # GC reads complete no request
                    fin = done + tecc
                    if fin > req_done[rid]:
                        req_done[rid] = fin
                # Die freed at last transfer; the decode tail is off-die.
                if preempt:
                    op_end[op] = done
                replace(heap, (done, seqc | op << 2 | _EV_REL))
            seqc += _SEQ1
        elif ev == _EV_REL:
            # Die release: read end, write program end, or erase end.
            if preempt and op_end[op] != tm:
                # Stale release of an op that was suspended (and possibly
                # rescheduled) after this event was pushed.
                pop(heap)
                if validate:
                    _check_work_conserving(die_busy, dieq)
                continue
            d = op_die[op]
            die_tot[d] += tm - op_held[op]
            die_busy[d] = tm
            if online is not None and op_erase[op]:
                # The erased block re-enters the free pool *now* —
                # writes stalled on this die become runnable again.
                online.on_erase_complete(op, tm)
                unstalled = online.take_unstalled()
                if unstalled:
                    dq0 = dieq[d]
                    for o in unstalled:
                        dq0.append(o)
            dq = dieq[d]
            op2 = -1
            while dq:
                cand = dq.pop_next()
                if (online is not None and not op_read[cand]
                        and not op_erase[cand] and op_rid[cand] >= 0):
                    # Host program start: the FTL maps the page at the
                    # simulated instant the die takes the program.
                    die_busy[d] = _INF    # reserve while the FTL maps
                    if online.on_program_start(cand, tm):
                        if online.injected:
                            drain_online(tm)
                        op2 = cand
                        break
                    die_busy[d] = tm      # no free page: stall, try next
                    online.stall(cand)
                    continue
                op2 = cand
                break
            if op2 >= 0:
                die_busy[d] = _INF
                op_held[op2] = tm
                die_cur[d] = op2
                if op_read[op2]:
                    if preempt and op_susp[op2]:
                        # Resume a boundary-suspended GC read under the
                        # constraints it suspended with.
                        op_susp[op2] = False
                        if pipelined:
                            t2 = tm + op_tr[op2]
                            c2 = op_end[op2]
                            if c2 > t2:
                                t2 = c2
                            replace(heap, (t2, seqc | op2 << 2 | _EV_COPY))
                        else:
                            base = op_end[op2]
                            t2 = (base if base > tm else tm) + op_tr[op2]
                            replace(heap, (t2, seqc | op2 << 2 | _EV_NEXT))
                    else:
                        if pipelined:
                            op_rem[op2] = 0
                        replace(heap, (tm + op_tr[op2],
                                       seqc | op2 << 2 | read_start_ev))
                else:
                    # Program or erase: hold the die for the op's
                    # duration (tPROG / t_erase / residual), then release.
                    dur = op_dur[op2]
                    if preempt and op_susp[op2]:
                        op_susp[op2] = False
                        dur = op_resid[op2]
                    rel2 = tm + dur
                    if preempt:
                        op_end[op2] = rel2
                    replace(heap, (rel2, seqc | op2 << 2 | _EV_REL))
                seqc += _SEQ1
            else:
                die_cur[d] = -1
                pop(heap)
            if not op_read[op]:
                rid = op_rid[op]
                if rid >= 0 and tm > req_done[rid]:
                    req_done[rid] = tm
        else:
            # _EV_ACQ — write transfer landed: acquire the die.
            d = op_die[op]
            if tm >= die_busy[d] and not dieq[d]:
                granted = True
                if online is not None and op_rid[op] >= 0:
                    die_busy[d] = _INF    # reserve while the FTL maps
                    granted = online.on_program_start(op, tm)
                    if granted:
                        if online.injected:
                            drain_online(tm)
                    else:
                        die_busy[d] = tm
                        online.stall(op)
                        pop(heap)
                if granted:
                    die_busy[d] = _INF
                    op_held[op] = tm
                    die_cur[d] = op
                    rel = tm + op_dur[op]
                    if preempt:
                        op_end[op] = rel
                    replace(heap, (rel, seqc | op << 2 | _EV_REL))
                    seqc += _SEQ1
            else:
                dieq[d].append(op)
                pop(heap)
        if validate:
            _check_work_conserving(die_busy, dieq)

    return EngineResult(
        req_done=req_done,
        die_tot=die_tot,
        ch_tot=ch_tot,
        die_busy=die_busy,
        ch_busy=ch_busy,
        n_events=n_events,
        gc_suspensions=gc_susp,
        online_attempts=online_attempts,
        online_read_pages=online_read_pages,
    )


def merge_shard_results(cfg, results: List[EngineResult]) -> EngineResult:
    """Deterministically combine per-channel shard results into one.

    ``results[c]`` is channel ``c``'s shard.  Cross-shard state is, by
    construction, limited to commutative/additive quantities:

      * ``req_done`` — elementwise max across shards (a request's pages
        may stripe over several channels; each shard recorded the last
        completion among *its* pages);
      * die vectors — each die is owned by exactly one shard
        (``die % n_channels == channel``), so the merge selects the
        owner's entries;
      * channel vectors — shard ``c`` owns exactly channel ``c``;
      * event/suspension/attempt counters — sums.

    The merge is independent of shard execution order, which is what
    makes the decomposition safe to parallelize at a higher layer.
    """
    n_ch = cfg.n_channels
    n_dies = cfg.n_dies
    if len(results) != n_ch:
        raise ValueError(
            f"expected one shard result per channel ({n_ch}), "
            f"got {len(results)}"
        )
    n_req = len(results[0].req_done)
    req_done = [0.0] * n_req
    die_tot = [0.0] * n_dies
    die_busy = [0.0] * n_dies
    ch_tot = [0.0] * n_ch
    ch_busy = [0.0] * n_ch
    n_events = gc_susp = attempts = read_pages = 0
    for c, r in enumerate(results):
        for i, v in enumerate(r.req_done):
            if v > req_done[i]:
                req_done[i] = v
        for d in range(c, n_dies, n_ch):
            die_tot[d] = r.die_tot[d]
            die_busy[d] = r.die_busy[d]
        ch_tot[c] = r.ch_tot[c]
        ch_busy[c] = r.ch_busy[c]
        n_events += r.n_events
        gc_susp += r.gc_suspensions
        attempts += r.online_attempts
        read_pages += r.online_read_pages
    return EngineResult(
        req_done=req_done,
        die_tot=die_tot,
        ch_tot=ch_tot,
        die_busy=die_busy,
        ch_busy=ch_busy,
        n_events=n_events,
        gc_suspensions=gc_susp,
        online_attempts=attempts,
        online_read_pages=read_pages,
    )


# ---------------------------------------------------------------------------
# Closed-loop interpreter (ncq_depth set): bounded NCQ admission, host
# write-back cache, and an explicit channel transfer phase.
# ---------------------------------------------------------------------------

#: Closed-loop event kinds (tuple field, not packed — this loop favors
#: legibility; the open-loop packed encoding above stays untouched).
_CL_ARRIVE = 0   # a queued request reaches the device boundary
_CL_SENSE = 1    # a read attempt's sense finished on the die
_CL_XFER = 2     # a channel DMA transfer finished
_CL_REL = 3      # scheduled die release (program end / speculative sense)
_CL_RDONE = 4    # request complete -> free its NCQ slot

#: Tail state of a pipelined read once its last sampled attempt copied.
_TAIL_NONE = 0
_TAIL_FIN = 1    # final transfer in flight; decode tail completes the op
_TAIL_XA = 2     # final decode fails; serial recovery ladder follows


@dataclasses.dataclass
class ClosedLoopResult:
    """Raw outcome of one closed-loop run (stats assembled by ssd.py)."""

    req_done: List[float]         # completion time per request
    req_admit: List[float]        # device-admission time per request
    die_tot: List[float]          # die-held time (same meaning as open loop)
    die_sense_tot: List[float]    # time each die spent actually sensing
    ch_tot: List[float]           # channel transfer occupancy
    die_busy: List[float]         # final busy-until (span accounting)
    ch_busy: List[float]
    n_events: int
    attempts_issued: int          # host-read attempts sent to the device
    read_pages_issued: int        # host-read page-ops sent to the device
    max_inflight: int             # peak admitted-and-incomplete requests
    full_hit_reads: int           # reads served entirely from the cache
    hit_pages: int                # read page-ops served from dirty lines
    absorbed_writes: int          # writes absorbed by the cache
    flush_pages: int              # page programs issued by cache flushes
    stalled_writes: int           # writes that waited on cache capacity
    #: Only with ``trace_phases=True``: ``(op, kind, resource, start,
    #: end)`` tuples, kind in {"sense", "xfer", "prog", "erase"} —
    #: the raw material for the interval-invariant property tests.
    phases: Optional[list] = None


def run_closed_loop(
    cfg,
    pipelined: bool,
    policy: SchedulerPolicy,
    bufs: OpBuffers,
    n_requests: int,
    req_arrival: List[float],
    req_is_read: List[bool],
    ncq_depth: int,
    op_lpn: Optional[List[int]] = None,
    cache=None,
    validate: bool = False,
    trace_phases: bool = False,
) -> ClosedLoopResult:
    """Closed-loop run: NCQ-gated admission over an admission stream.

    The stream in ``bufs`` is the same one the open-loop core executes
    (expansion / FTL prepass / fault plan — attempt counts pre-sampled),
    but requests are admitted **on completion**, not at trace time: ops
    are grouped by owning request (each request's ops plus the GC/fault
    ops interleaved at its trigger point form one *group*), at most
    ``ncq_depth`` requests occupy slots at once, and a group's ops enter
    the device only when its slot frees and every earlier group has been
    admitted (stream order — exactly the order the FTL prepass and fault
    plan assumed, so their precomputed mappings stay valid; only times
    shift).

    Unlike the open-loop core's busy-until collapse, the channel here is
    an explicit single-server FIFO: transfers are *requested* at sense
    end (or program issue) and *granted* when the wire frees, which keeps
    the same FCFS timing while making the sense/transfer split — the
    die/DMA overlap that CACHE READ pipelining (PR²) exploits —
    observable per phase (``trace_phases``) and per die
    (``die_sense_tot``).

    With a :class:`~repro.flashsim.hostcache.WriteCache` attached, write
    groups that fit are absorbed (completing at ``cache.cfg.hit_us``),
    their programs parked until a watermark flush re-issues them as
    low-priority device traffic; reads that hit a resident dirty line
    are served from the cache.  Not supported here: ``preempt``
    scheduling and online GC (both raise upstream in ssd.py).
    """
    if policy.preemptive:
        raise NotImplementedError(
            "closed-loop frontend does not support the preempt scheduler"
        )
    t = cfg.timing
    tdma, tecc = t.tdma_us, t.tecc_us
    hit_us = cache.cfg.hit_us if cache is not None else 0.0

    op_rid, op_die, op_ch = bufs.rid, bufs.die, bufs.ch
    op_read, op_erase, op_dur = bufs.read, bufs.erase, bufs.dur
    op_a, op_tr = bufs.a, bufs.tr
    op_xa = bufs.xa if bufs.xa is not None else None
    op_xtr = bufs.xtr
    P = len(bufs.arrival)

    host_read = None
    if policy.prioritized:
        host_read = [op_read[i] and op_rid[i] >= 0 for i in range(P)]
    bufs.host_read = host_read

    # ---- request groups: contiguous runs of the admission stream ------
    # Each group is one request's ops plus every rid = -1 op interleaved
    # at its trigger point (GC traffic, fault relocations) and the
    # stripe-peer rebuild reads (which carry the request's own rid).
    grp_lo: List[int] = []
    grp_hi: List[int] = []
    grp_rid: List[int] = []
    cur_rid = None
    for i in range(P):
        r = op_rid[i]
        if r >= 0 and r != cur_rid:
            if cur_rid is None and grp_lo:
                raise AssertionError("admission stream starts with GC ops")
            grp_lo.append(i)
            grp_rid.append(r)
            if len(grp_lo) > 1:
                grp_hi.append(i)
            cur_rid = r
        elif not grp_lo:
            raise AssertionError("admission stream starts with GC ops")
    grp_hi.append(P)
    n_groups = len(grp_lo)
    # Each request must own exactly one contiguous run.  Rids need not be
    # sorted (unsorted traces admit in stream order, a permutation of
    # 0..n-1) — only uniqueness and completeness are required.
    if n_groups != n_requests or len(set(grp_rid)) != n_requests:
        raise AssertionError(
            "closed-loop grouping expects one contiguous op run per "
            "request in the admission stream"
        )

    # ---- per-op state --------------------------------------------------
    o_rem = op_a[:]               # serial: attempts left (incl. in flight)
    o_left = [0] * P              # pipelined: attempts not yet sensed
    o_tr = op_tr[:]               # live sense time (xa swaps in xtr)
    o_xa = op_xa[:] if op_xa is not None else [0] * P
    o_serial = [not pipelined] * P
    o_regfree = [True] * P        # pipelined: cache register drained
    o_sense_t = [-1.0] * P        # pipelined: sense done, waiting on reg
    o_tail = [_TAIL_NONE] * P
    o_held = [0.0] * P
    o_defer = [False] * P         # cache-deferred op (no request account)
    o_fver = [0] * P              # flush version of a deferred program

    n_dies, n_ch = cfg.n_dies, cfg.n_channels
    die_cur = [-1] * n_dies
    die_busy = [0.0] * n_dies
    die_tot = [0.0] * n_dies
    die_sense = [0.0] * n_dies
    dieq = policy.make_queues(n_dies, host_read)
    ch_cur = [-1] * n_ch
    ch_q = [[] for _ in range(n_ch)]      # FIFO via index cursor
    ch_head = [0] * n_ch
    ch_busy = [0.0] * n_ch
    ch_tot = [0.0] * n_ch

    req_done = [0.0] * n_requests
    req_admit = [0.0] * n_requests
    req_pend = [0] * n_requests

    heap: list = []
    push = heapq.heappush
    seq = 0
    n_events = 0
    attempts_issued = 0
    read_pages_issued = 0
    inflight = 0
    max_inflight = 0
    full_hit_reads = 0
    stalled_writes = 0
    phases: Optional[list] = [] if trace_phases else None

    def emit(tm, ev, idx):
        nonlocal seq
        push(heap, (tm, seq, ev, idx))
        seq += 1

    # ---- channel: explicit single-server FIFO transfer phase -----------
    def start_transfer(c, o, tm):
        ch_cur[c] = o
        ch_tot[c] += tdma
        ch_busy[c] = tm + tdma
        emit(tm + tdma, _CL_XFER, o)
        if phases is not None:
            phases.append((o, "xfer", c, tm, tm + tdma))

    def request_transfer(o, tm):
        c = op_ch[o]
        if ch_cur[c] < 0:
            start_transfer(c, o, tm)
        else:
            ch_q[c].append(o)

    # ---- die: grant / release ------------------------------------------
    def start_sense(o, tm):
        d = op_die[o]
        die_sense[d] += o_tr[o]
        emit(tm + o_tr[o], _CL_SENSE, o)
        if phases is not None:
            phases.append((o, "sense", d, tm, tm + o_tr[o]))

    def grant_die(o, tm):
        d = op_die[o]
        die_cur[d] = o
        die_busy[d] = _INF
        o_held[o] = tm
        if op_read[o]:
            if not o_serial[o]:
                o_left[o] = op_a[o] - 1
            start_sense(o, tm)
        else:
            emit(tm + op_dur[o], _CL_REL, o)
            if phases is not None:
                kind = "erase" if op_erase[o] else "prog"
                phases.append((o, kind, d, tm, tm + op_dur[o]))

    def admit_to_die(o, tm):
        d = op_die[o]
        if die_cur[d] < 0 and not dieq[d]:
            grant_die(o, tm)
        else:
            dieq[d].append(o)

    def release_die(o, tm):
        d = op_die[o]
        die_tot[d] += tm - o_held[o]
        die_cur[d] = -1
        die_busy[d] = tm
        if dieq[d]:
            grant_die(dieq[d].pop_next(), tm)

    # ---- request completion bookkeeping --------------------------------
    def complete_page(o, fin):
        r = op_rid[o]
        if r < 0 or o_defer[o]:
            return
        if fin > req_done[r]:
            req_done[r] = fin
        req_pend[r] -= 1
        if req_pend[r] == 0:
            emit(req_done[r], _CL_RDONE, r)

    def finish_at_host(r, tm):
        """Complete a request host-side (cache absorb / full cache hit)."""
        req_done[r] = tm + hit_us
        emit(tm + hit_us, _CL_RDONE, r)

    # ---- read state machines (mirror the open-loop timing exactly) -----
    def _copy(o, tm):
        """Pipelined: sense data lands in the cache register at ``tm`` —
        issue its DMA and (CACHE READ) start the next sense under it."""
        o_regfree[o] = False
        request_transfer(o, tm)
        if o_left[o] > 0:
            o_left[o] -= 1
            start_sense(o, tm)            # overlaps the transfer: the PR² win
        elif o_xa[o] > 0:
            o_tail[o] = _TAIL_XA          # recovery ladder; die stays held
        else:
            o_tail[o] = _TAIL_FIN
            if op_a[o] > 1:
                # The speculatively-started next sense occupies the die
                # until tm + tr even though its data is never needed.
                die_sense[op_die[o]] += o_tr[o]
                if phases is not None:
                    phases.append((o, "sense", op_die[o], tm, tm + o_tr[o]))
                emit(tm + o_tr[o], _CL_REL, o)
            else:
                release_die(o, tm)

    def _pipelined_xfer(o, tm):
        """Pipelined read transfer drained at ``tm``."""
        o_regfree[o] = True
        tail = o_tail[o]
        if tail == _TAIL_XA:
            # Decode of the final sampled attempt failed (known at
            # tm + tecc): serial full-strength re-reads, die held.
            o_tail[o] = _TAIL_NONE
            o_serial[o] = True
            o_rem[o] = o_xa[o]
            o_xa[o] = 0
            o_tr[o] = op_xtr[o]
            start_sense(o, tm + tecc)
        elif tail == _TAIL_FIN:
            complete_page(o, tm + tecc)   # decode tail is off-die
        elif o_sense_t[o] >= 0.0:
            o_sense_t[o] = -1.0
            _copy(o, tm)                  # a sense was waiting on the reg

    def _serial_xfer(o, tm):
        """Serial read transfer drained at ``tm`` -> decode at tm + tecc."""
        rem = o_rem[o] - 1
        if rem > 0:
            o_rem[o] = rem
            start_sense(o, tm + tecc)     # decode failed: next table entry
        elif o_xa[o] > 0:
            o_rem[o] = o_xa[o]            # recovery: full-strength ladder
            o_xa[o] = 0
            o_tr[o] = op_xtr[o]
            start_sense(o, tm + tecc)
        else:
            complete_page(o, tm + tecc)
            release_die(o, tm)            # die freed at last transfer end

    # ---- write-back cache ----------------------------------------------
    blocked_group = -1            # group waiting on cache capacity

    def host_page_ops(g):
        r = grp_rid[g]
        return [o for o in range(grp_lo[g], grp_hi[g])
                if op_rid[o] == r and not op_read[o] and not op_erase[o]]

    def issue_entry(entry, tm):
        """Issue one flushed cache entry's device ops (low priority)."""
        g = entry.payload
        pages = iter(entry.versions)
        r = grp_rid[g]
        for o in range(grp_lo[g], grp_hi[g]):
            if op_rid[o] == r and not op_read[o] and not op_erase[o]:
                o_fver[o] = next(pages)
            issue_op(o, tm)

    def maybe_flush(tm):
        if cache.need_flush():
            while not cache.flushed_enough():
                entry = cache.pop_entry()
                if entry is None:
                    break
                issue_entry(entry, tm)

    def drain_cache(tm):
        for entry in cache.drain():
            issue_entry(entry, tm)

    # ---- admission ------------------------------------------------------
    def issue_op(o, tm):
        nonlocal attempts_issued, read_pages_issued
        if op_read[o]:
            if op_rid[o] >= 0 and not o_defer[o]:
                attempts_issued += op_a[o]
                read_pages_issued += 1
            admit_to_die(o, tm)
        elif op_erase[o]:
            admit_to_die(o, tm)
        else:
            request_transfer(o, tm)   # program: DMA first, then the die

    def admit_group(g, tm):
        """Issue (or absorb) group ``g`` now.  False = blocked on cache."""
        nonlocal blocked_group, stalled_writes, full_hit_reads, inflight
        nonlocal max_inflight
        r = grp_rid[g]
        if cache is not None and not req_is_read[r]:
            pages = host_page_ops(g)
            if cache.fits(len(pages)):
                if not cache.can_absorb(len(pages)):
                    # Backpressure: hold the slot, force the oldest dirty
                    # entries out, retry as their programs land.
                    stalled_writes += 1
                    blocked_group = g
                    while (cache.dirty_pages >
                           cache.capacity - len(pages)):
                        entry = cache.pop_entry()
                        if entry is None:
                            break
                        issue_entry(entry, tm)
                    return False
                req_admit[r] = tm
                inflight += 1
                if inflight > max_inflight:
                    max_inflight = inflight
                entry = cache.absorb([op_lpn[o] for o in pages], payload=g)
                for o in range(grp_lo[g], grp_hi[g]):
                    o_defer[o] = True
                finish_at_host(r, tm)
                maybe_flush(tm)
                return True
            # Oversized write: fall through to write-through.
        req_admit[r] = tm
        inflight += 1
        if inflight > max_inflight:
            max_inflight = inflight
        for o in range(grp_lo[g], grp_hi[g]):
            if (cache is not None and op_read[o] and op_rid[o] == r
                    and op_lpn is not None and op_lpn[o] >= 0
                    and cache.contains(op_lpn[o])):
                cache.note_hit()
                cache.touch(op_lpn[o])
                continue
            if op_rid[o] == r:
                req_pend[r] += 1
            issue_op(o, tm)
        if req_pend[r] == 0:
            # Every page hit the cache (reads) — no device traffic.
            full_hit_reads += 1
            finish_at_host(r, tm)
        return True

    # NCQ slots: reserve a slot per queued request up front (SNIPPETS
    # FTL-SIM discipline — an arrival is *scheduled* the moment a slot
    # frees, firing at max(trace arrival, now)); admission additionally
    # waits for stream order so the prepass/fault mappings stay valid.
    free_slots = ncq_depth
    next_sched = 0                # next group to receive a slot
    adm_head = 0                  # next group to admit (stream order)
    arrived = [False] * n_groups

    def schedule_arrivals(tm):
        nonlocal free_slots, next_sched
        while free_slots > 0 and next_sched < n_groups:
            g = next_sched
            next_sched += 1
            free_slots -= 1
            ta = req_arrival[grp_rid[g]]
            emit(ta if ta > tm else tm, _CL_ARRIVE, g)

    def pump_admissions(tm):
        nonlocal adm_head
        while (adm_head < n_groups and arrived[adm_head]
               and blocked_group < 0):
            if not admit_group(adm_head, tm):
                break
            adm_head += 1
        if cache is not None and adm_head == n_groups and blocked_group < 0:
            drain_cache(tm)

    schedule_arrivals(0.0)

    # ---- the loop -------------------------------------------------------
    while heap:
        tm, _, ev, idx = heapq.heappop(heap)
        n_events += 1

        if ev == _CL_ARRIVE:
            arrived[idx] = True
            pump_admissions(tm)

        elif ev == _CL_SENSE:
            o = idx
            if o_serial[o]:
                request_transfer(o, tm)     # die stays held through DMA
            elif o_regfree[o]:
                _copy(o, tm)
            else:
                o_sense_t[o] = tm           # wait for the register

        elif ev == _CL_XFER:
            o = idx
            c = op_ch[o]
            q = ch_q[c]
            h = ch_head[c]
            if h < len(q):                  # grant the next transfer
                nxt = q[h]
                ch_head[c] = h + 1
                if ch_head[c] > 64 and ch_head[c] * 2 > len(q):
                    del q[:ch_head[c]]
                    ch_head[c] = 0
                start_transfer(c, nxt, tm)
            else:
                ch_cur[c] = -1
            if op_read[o]:
                if o_serial[o]:
                    _serial_xfer(o, tm)
                else:
                    _pipelined_xfer(o, tm)
            else:
                admit_to_die(o, tm)         # program transfer landed

        elif ev == _CL_REL:
            o = idx
            release_die(o, tm)
            if not op_read[o]:
                if o_defer[o] and not op_erase[o] and op_rid[o] >= 0:
                    # A flushed cache page became durable: free its slot,
                    # retry a blocked write, keep draining if done.
                    cache.page_durable(op_lpn[o], o_fver[o])
                    if blocked_group >= 0:
                        g = blocked_group
                        need = len(host_page_ops(g))
                        if cache.can_absorb(need):
                            blocked_group = -1
                            pump_admissions(tm)
                    elif adm_head == n_groups:
                        pass    # end-of-trace drain already issued
                else:
                    complete_page(o, tm)

        else:                               # _CL_RDONE
            inflight -= 1
            free_slots += 1
            schedule_arrivals(tm)

        if validate:
            if inflight > ncq_depth:
                raise AssertionError(
                    f"NCQ violated: {inflight} > depth {ncq_depth}"
                )
            for d, q in enumerate(dieq):
                if q and die_cur[d] < 0:
                    raise AssertionError(
                        f"work conservation violated on die {d}"
                    )

    if adm_head != n_groups or blocked_group >= 0:
        raise AssertionError("closed loop finished with unadmitted groups")
    if cache is not None and cache.pending_pages:
        raise AssertionError("closed loop finished with undrained cache")

    return ClosedLoopResult(
        req_done=req_done,
        req_admit=req_admit,
        die_tot=die_tot,
        die_sense_tot=die_sense,
        ch_tot=ch_tot,
        die_busy=die_busy,
        ch_busy=ch_busy,
        n_events=n_events,
        attempts_issued=attempts_issued,
        read_pages_issued=read_pages_issued,
        max_inflight=max_inflight,
        full_hit_reads=full_hit_reads,
        hit_pages=cache.hit_pages if cache is not None else 0,
        absorbed_writes=cache.absorbed_writes if cache is not None else 0,
        flush_pages=cache.flush_pages if cache is not None else 0,
        stalled_writes=stalled_writes,
        phases=phases,
    )


def _check_work_conserving(die_busy, dieq) -> None:
    """Raise when any die sits idle while its queue holds a runnable op.

    Stalled writes are parked *outside* the die queues (gc_online), so
    everything queued here is runnable by construction.
    """
    for d, q in enumerate(dieq):
        if q and die_busy[d] != _INF:
            raise AssertionError(
                f"work conservation violated: die {d} idle "
                f"(free since t={die_busy[d]:.3f}) with {len(q)} queued ops"
            )
