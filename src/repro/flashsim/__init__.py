"""MQSim-analogue SSD simulator used for the paper's end-to-end evaluation."""

from repro.flashsim.config import (
    DEFAULT_SSD,
    GCConfig,
    OperatingCondition,
    SSDConfig,
)
from repro.flashsim.engine import EngineResult, OpBuffers, run_event_core
from repro.flashsim.ftl import (
    FTLSchedule,
    FTLStats,
    PageMapFTL,
    build_ftl_schedule,
)
from repro.flashsim.gc_online import OnlineGC
from repro.flashsim.sched import (
    SCHEDULERS,
    FCFSQueue,
    HostPrioQueue,
    SchedulerPolicy,
    get_scheduler,
)
from repro.flashsim.ssd import (
    SSDSim,
    SimStats,
    TraceExpansion,
    compare_mechanisms,
    expand_trace,
    simulate,
    simulate_batch,
)
from repro.flashsim.workloads import (
    GC_PROFILES,
    PROFILES,
    RequestTrace,
    Workload,
    cached_trace,
    generate_trace,
    make_workloads,
)

__all__ = [
    "DEFAULT_SSD",
    "GCConfig",
    "OperatingCondition",
    "SSDConfig",
    "EngineResult",
    "OpBuffers",
    "run_event_core",
    "FTLSchedule",
    "FTLStats",
    "PageMapFTL",
    "build_ftl_schedule",
    "OnlineGC",
    "SCHEDULERS",
    "FCFSQueue",
    "HostPrioQueue",
    "SchedulerPolicy",
    "get_scheduler",
    "SSDSim",
    "SimStats",
    "TraceExpansion",
    "compare_mechanisms",
    "expand_trace",
    "simulate",
    "simulate_batch",
    "GC_PROFILES",
    "PROFILES",
    "RequestTrace",
    "Workload",
    "cached_trace",
    "generate_trace",
    "make_workloads",
]
