"""MQSim-analogue SSD simulator used for the paper's end-to-end evaluation."""

from repro.flashsim.config import DEFAULT_SSD, OperatingCondition, SSDConfig
from repro.flashsim.ssd import SSDSim, SimStats, compare_mechanisms, simulate
from repro.flashsim.workloads import (
    PROFILES,
    RequestTrace,
    Workload,
    generate_trace,
    make_workloads,
)

__all__ = [
    "DEFAULT_SSD",
    "OperatingCondition",
    "SSDConfig",
    "SSDSim",
    "SimStats",
    "compare_mechanisms",
    "simulate",
    "PROFILES",
    "RequestTrace",
    "Workload",
    "generate_trace",
    "make_workloads",
]
