"""MQSim-analogue SSD simulator used for the paper's end-to-end evaluation."""

from repro.flashsim.config import (
    DEFAULT_SSD,
    GCConfig,
    OperatingCondition,
    SSDConfig,
)
from repro.flashsim.ftl import (
    FTLSchedule,
    FTLStats,
    PageMapFTL,
    build_ftl_schedule,
)
from repro.flashsim.ssd import (
    SSDSim,
    SimStats,
    TraceExpansion,
    compare_mechanisms,
    expand_trace,
    simulate,
    simulate_batch,
)
from repro.flashsim.workloads import (
    GC_PROFILES,
    PROFILES,
    RequestTrace,
    Workload,
    cached_trace,
    generate_trace,
    make_workloads,
)

__all__ = [
    "DEFAULT_SSD",
    "GCConfig",
    "OperatingCondition",
    "SSDConfig",
    "FTLSchedule",
    "FTLStats",
    "PageMapFTL",
    "build_ftl_schedule",
    "SSDSim",
    "SimStats",
    "TraceExpansion",
    "compare_mechanisms",
    "expand_trace",
    "simulate",
    "simulate_batch",
    "GC_PROFILES",
    "PROFILES",
    "RequestTrace",
    "Workload",
    "cached_trace",
    "generate_trace",
    "make_workloads",
]
