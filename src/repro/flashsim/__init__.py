"""MQSim-analogue SSD simulator used for the paper's end-to-end evaluation."""

from repro.flashsim.config import DEFAULT_SSD, OperatingCondition, SSDConfig
from repro.flashsim.ssd import (
    SSDSim,
    SimStats,
    TraceExpansion,
    compare_mechanisms,
    expand_trace,
    simulate,
    simulate_batch,
)
from repro.flashsim.workloads import (
    PROFILES,
    RequestTrace,
    Workload,
    cached_trace,
    generate_trace,
    make_workloads,
)

__all__ = [
    "DEFAULT_SSD",
    "OperatingCondition",
    "SSDConfig",
    "SSDSim",
    "SimStats",
    "TraceExpansion",
    "compare_mechanisms",
    "expand_trace",
    "simulate",
    "simulate_batch",
    "PROFILES",
    "RequestTrace",
    "Workload",
    "cached_trace",
    "generate_trace",
    "make_workloads",
]
