"""Page-mapping FTL with greedy garbage collection for the SSD simulator.

Before this layer existed the simulator programmed writes *in place*: a
host write occupied its die for tPROG and the flash never filled up, so
sustained-write workloads could not exercise read-retry behind GC-induced
die contention — exactly the regime where PR²'s pipelining and AR²'s
latency scaling matter most.  This module adds the missing subsystem:

  * a logical→physical **page map** (``l2p`` dict + ``p2l`` reverse array)
    with out-of-place programs: each host write allocates the next free
    page of its die's *active block* and invalidates the previous mapping;
  * configurable **over-provisioning** (:class:`~repro.flashsim.config.
    GCConfig.op_ratio`): physical capacity is auto-sized from the trace's
    logical **footprint** — the count of *distinct* pages each die's
    stripe touches, never the raw LBA span — so utilization = 1 − OP at
    full pre-fill, or pinned explicitly with ``blocks_per_die``.  Real
    ingested traces scatter their footprint across volume-sized sparse
    spans; sizing stays footprint-proportional regardless, but run them
    through the dense-footprint remap (:class:`repro.flashsim.workloads.
    DenseRemap`, the registry default for file sources) so the
    ``lpn % n_dies`` stripe also spreads evenly instead of following the
    trace's offset stride;
  * **greedy victim selection**: when a die's free-block count falls to
    the GC threshold, the sealed block with the fewest valid pages is
    compacted — its valid pages are read (``OP_GC_READ``), re-programmed
    into the die's dedicated GC frontier block (``OP_GC_PROG``), and the
    victim is erased (``OP_ERASE``);
  * **per-block P/E tracking**: every erase bumps the block's wear by
    ``pec_per_erase`` cycles; reads of relocated data resolve the device
    :class:`~repro.flashsim.config.OperatingCondition` per block
    (``condition.with_wear``), so their retry-attempt distributions come
    from the characterization at the block's *effective* wear.

GC traffic is not simulated here — it is *scheduled* here.  The FTL walk
happens as a deterministic pre-pass over the trace in admission order
(:func:`build_ftl_schedule`), and the GC page-ops it emits are injected
into the array event-core's admission stream with the arrival time of the
host write that triggered them.  Inside the event loop they are ordinary
page-ops: GC reads run the same (possibly PR²-pipelined) read state
machine and sample retry attempts like host reads; GC programs transfer
over the channel and hold the die for tPROG; erases hold the die for
``t_erase_us``.  They therefore contend with host reads on the same die
FCFS queues and channel busy-until state — the contention the paper's
MQSim evaluation bakes in.

Die-partitioned state (the sharding contract)
---------------------------------------------
Every piece of FTL state that simulation-time code paths touch is
partitioned by die, keyed by the same static stripe the simulator uses
(``lpn % n_dies``):

  * allocation — free pools (``free[die]``), frontiers (``active`` /
    ``gc_active``), and sealed sets are per-die lists/sets; ``_alloc``,
    :meth:`PageMapFTL.can_alloc`, and :meth:`PageMapFTL.erase_complete`
    take the die explicitly and touch no other die's entries;
  * mapping — an lpn lives on exactly one die, and block-indexed arrays
    (``valid`` / ``wp`` / ``erases`` / ``p2l``) are partitioned into
    per-die block ranges (``[die*blocks_per_die, (die+1)*blocks_per_die)``);
  * victim selection / collection — :meth:`_collect` reads and writes
    only its die's structures.

Only *statistics* (page/invocation counters, ``gc_log``) are shared, and
those are additive.  This is what makes the per-channel sharded event
core (:mod:`repro.flashsim.engine` ``shard=True``) exact: a channel
shard owns its dies' FTL slice outright, and the two cross-shard-looking
couplings — page allocation and host-write stalls — are in fact die-local
(the stall lists in :mod:`repro.flashsim.gc_online` are per-die too).
Code extending the FTL must preserve this partitioning or the sharded
engine's bit-equality contract breaks; the online driver's
``set_shard_scope`` guard fails fast on violations.

Approximation notes (documented, deliberate):

  * GC is triggered by write *admission order*, not by simulated write
    completion times.  Mapping state is exact; only the trigger instant is
    approximated (a host write admitted at t schedules its GC at t).
  * Within one GC invocation the reads/programs/erase are all admitted at
    the trigger time and serialize through the die's FCFS queue rather
    than through explicit read→program→erase dependencies.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import deque
from typing import Deque, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.flashsim.config import DEFAULT_SSD, SSDConfig
from repro.flashsim.workloads import RequestTrace

#: Page-op kinds of the FTL schedule.  ``OP_READ``/``OP_GC_READ`` are
#: read-like (die sense + channel transfer per retry attempt);
#: ``OP_PROG``/``OP_GC_PROG`` are write-like (channel transfer, then die
#: held for the op's duration); ``OP_ERASE`` holds the die only.
OP_READ = 0
OP_GC_READ = 1
OP_PROG = 2
OP_GC_PROG = 3
OP_ERASE = 4

_READ_LIKE_MAX = OP_GC_READ


@dataclasses.dataclass(frozen=True)
class FTLStats:
    """Mapping-layer summary of one FTL pre-pass (page counts, not time)."""

    host_reads: int            # host read page-ops (pages)
    host_progs: int            # host write page-ops (pages)
    prefill_progs: int         # lazy pre-fill mappings for never-written reads
    gc_page_reads: int         # valid pages read back by GC (pages)
    gc_page_progs: int         # valid pages re-programmed by GC (pages)
    blocks_erased: int         # erase operations issued (blocks)
    gc_invocations: int        # victim-collection passes
    write_amplification: float # (host_progs + gc_page_progs) / host_progs
    blocks_per_die: int        # physical geometry actually used (blocks)
    pages_per_block: int       # physical geometry actually used (pages)
    footprint_pages: int       # distinct logical pages referenced (pages)
    max_block_pe: float        # highest per-block added wear (P/E cycles)
    blocks_retired: int = 0    # bad blocks retired (never return to pool)


@dataclasses.dataclass(frozen=True)
class FTLSchedule:
    """Flat page-op schedule of a trace run through the FTL (admission order).

    The FTL-aware analogue of :class:`repro.flashsim.ssd.TraceExpansion`:
    host page-ops in admission order with GC page-ops interleaved at their
    trigger points.  Mechanism- and condition-independent, so one schedule
    is shared by every mechanism of a sweep; only attempt sampling (which
    reads ``wear_pec``) depends on the policy/condition.
    """

    arrival_us: np.ndarray   # (P,) op admission time (us)
    rid: np.ndarray          # (P,) owning request index; -1 for GC/erase ops
    die: np.ndarray          # (P,) die id
    chan: np.ndarray         # (P,) channel id
    ptype: np.ndarray        # (P,) page-type index (lsb/csb/msb)
    kind: np.ndarray         # (P,) OP_* code
    dur_us: np.ndarray       # (P,) die-hold duration for write-like/erase ops
    wear_pec: np.ndarray     # (P,) block-local added wear at read time (P/E)
    n_requests: int
    stats: FTLStats
    #: (P,) logical page of each host op; -1 for GC/erase ops.  Only the
    #: closed-loop frontend reads it (write-cache hit detection); None on
    #: schedules built before the field existed.
    lpn: Optional[np.ndarray] = None

    @property
    def n_ops(self) -> int:
        return int(self.rid.shape[0])

    @functools.cached_property
    def admission_lists(self):
        """Per-op buffers as plain Python lists for the event loop.

        Mirrors ``TraceExpansion.admission_lists`` (scalar list indexing is
        ~4x faster than ndarray scalar access in the interpreter loop) with
        two extra views: ``is_erase`` and ``dur_us``.
        """
        return (
            self.arrival_us.tolist(),
            self.rid.tolist(),
            self.die.tolist(),
            self.chan.tolist(),
            (self.kind <= _READ_LIKE_MAX).tolist(),   # read-like
            (self.kind == OP_ERASE).tolist(),
            self.dur_us.tolist(),
        )

    @functools.cached_property
    def admission_arrays(self):
        """The same per-op buffers as dtype-pinned numpy columns.

        Mirrors ``TraceExpansion.admission_arrays``: batched-resolved
        runs hand the lockstep core whole columns and skip the
        list round-trip; the interpreter keeps
        :attr:`admission_lists`.  Values are identical either way.
        """
        return (
            np.asarray(self.arrival_us, np.float64),
            np.asarray(self.rid, np.int64),
            np.asarray(self.die, np.int64),
            np.asarray(self.chan, np.int64),
            np.asarray(self.kind <= _READ_LIKE_MAX, bool),
            np.asarray(self.kind == OP_ERASE, bool),
            np.asarray(self.dur_us, np.float64),
        )


class PageMapFTL:
    """Per-die page-mapping FTL with greedy GC (deterministic, no RNG).

    Logical pages are statically striped across dies (``lpn % n_dies`` —
    the same rule the in-place simulator uses), so enabling the FTL changes
    *where on the die* data lives and what extra traffic exists, never
    which die a host op targets.  Within a die, programs are log-structured
    over two frontier blocks: ``active`` (host writes + pre-fill) and
    ``gc_active`` (GC relocations) — the standard hot/cold split, and the
    reason GC can never select the block it is compacting into (the
    frontier blocks are not sealed, and only sealed blocks are victims).

    The class is pure mapping state — it emits page-op *events* (tuples)
    into an internal buffer that :func:`build_ftl_schedule` drains; it
    never touches simulated time.

    Two construction flags adapt the same state machine to the *online*
    GC driver (:mod:`repro.flashsim.gc_online`):

    ``auto_gc=False``
        host ops never trigger collection themselves; the driver calls
        :meth:`_collect` explicitly at watermark crossings.
    ``defer_free=True``
        an erased victim does **not** re-enter the free pool inside
        :meth:`_collect`; the driver returns it via
        :meth:`erase_complete` when the erase finishes on the simulated
        die — reclaim takes simulated time.
    """

    def __init__(self, cfg: SSDConfig = DEFAULT_SSD,
                 lpns: Optional[np.ndarray] = None,
                 auto_gc: bool = True, defer_free: bool = False):
        gc = cfg.gc
        self.cfg = cfg
        self.gc = gc
        self.auto_gc = auto_gc
        self.defer_free = defer_free
        self.n_dies = cfg.n_dies
        self.ppb = gc.pages_per_block

        if gc.blocks_per_die is not None:
            bpd = int(gc.blocks_per_die)
            footprint = int(np.unique(lpns).size) if lpns is not None else 0
        else:
            if lpns is None:
                raise ValueError(
                    "GCConfig.blocks_per_die is None (auto-size): "
                    "PageMapFTL needs the trace's lpns to size capacity"
                )
            uniq = np.unique(lpns)
            footprint = int(uniq.size)
            per_die = np.bincount(
                (uniq % self.n_dies).astype(np.int64), minlength=self.n_dies
            )
            data_blocks = max(int(np.ceil(per_die.max() / self.ppb)), 1)
            bpd = int(np.ceil(data_blocks / (1.0 - gc.op_ratio)))
            # Floor: the live footprint plus one frontier and one spare
            # must always fit, or a write-once fill could exhaust the
            # allocator before GC has anything to reclaim.
            bpd = max(bpd, data_blocks + 2)
        # Room for both frontier blocks + the GC threshold, whatever OP says.
        bpd = max(bpd, gc.gc_threshold_blocks + 3)
        self.blocks_per_die = bpd
        self.footprint = footprint

        nb = self.n_dies * bpd
        self.n_blocks = nb
        self.valid = np.zeros(nb, np.int64)       # valid pages per block
        self.wp = np.zeros(nb, np.int64)          # pages programmed per block
        self.erases = np.zeros(nb, np.int64)      # erase count per block
        self.p2l = np.full(nb * self.ppb, -1, np.int64)
        self.l2p: Dict[int, int] = {}
        self.free: List[Deque[int]] = [
            deque(range(d * bpd, (d + 1) * bpd)) for d in range(self.n_dies)
        ]
        self.active = [-1] * self.n_dies          # host/pre-fill frontier
        self.gc_active = [-1] * self.n_dies       # GC relocation frontier
        self.sealed: List[Set[int]] = [set() for _ in range(self.n_dies)]

        self.host_progs = 0
        self.prefill_progs = 0
        self.gc_page_reads = 0
        self.gc_page_progs = 0
        self.blocks_erased = 0
        self.gc_invocations = 0
        #: Bad blocks taken out of service (:meth:`retire_block` /
        #: :meth:`retire_erase_failed`) — never re-enter any free pool.
        self.retired: Set[int] = set()
        self.blocks_retired = 0
        #: (die, victim, gc_frontier_at_selection) per collection — lets
        #: tests assert GC never evicts the block it compacts into.
        self.gc_log: List[Tuple[int, int, int]] = []
        self._events: List[Tuple[int, int, int, float, int]] = []

    # -- allocation ---------------------------------------------------------

    def _alloc(self, die: int, gc_stream: bool) -> int:
        """Next free physical page slot on ``die`` (pops a free block as
        needed, sealing the filled frontier).

        Under extreme pressure (no free block left) the allocation borrows
        room from the *sibling* stream's frontier instead of failing: at
        tiny sim-scaled geometries the last invalid slack can sit entirely
        in the other frontier, and refusing it would wedge a device whose
        live data still fits.  The borrow briefly mixes the hot/cold
        streams; it is rare and only happens at the edge of device-full.
        """
        frontier = self.gc_active if gc_stream else self.active
        blk = frontier[die]
        if blk < 0 or self.wp[blk] >= self.ppb:
            if blk >= 0:
                self.sealed[die].add(blk)
                frontier[die] = -1
            free = self.free[die]
            if free:
                blk = free.popleft()
                frontier[die] = blk
            else:
                other = (self.active if gc_stream else self.gc_active)[die]
                if other >= 0 and self.wp[other] < self.ppb:
                    blk = other  # borrowed: ownership stays with sibling
                else:
                    raise RuntimeError(
                        f"FTL die {die} out of free blocks "
                        f"(blocks_per_die={self.blocks_per_die} too small "
                        f"for the workload footprint; raise it or op_ratio)"
                    )
        ppn = blk * self.ppb + int(self.wp[blk])
        self.wp[blk] += 1
        return ppn

    def can_alloc(self, die: int, gc_stream: bool = False) -> bool:
        """Whether :meth:`_alloc` on ``die`` would succeed right now.

        The online driver probes this before mapping a host write at
        program start; False means the write must stall until an erase
        completes (host write throttling).
        """
        frontier = (self.gc_active if gc_stream else self.active)[die]
        if frontier >= 0 and self.wp[frontier] < self.ppb:
            return True
        if self.free[die]:
            return True
        other = (self.active if gc_stream else self.gc_active)[die]
        return other >= 0 and self.wp[other] < self.ppb

    def _map_write(self, lpn: int, gc_stream: bool) -> int:
        """(Re)map ``lpn`` to a fresh physical page; invalidate the old one."""
        old = self.l2p.get(lpn, -1)
        if old >= 0:
            self.valid[old // self.ppb] -= 1
            self.p2l[old] = -1
        ppn = self._alloc(lpn % self.n_dies, gc_stream)
        self.l2p[lpn] = ppn
        self.p2l[ppn] = lpn
        self.valid[ppn // self.ppb] += 1
        return ppn

    # -- garbage collection -------------------------------------------------

    def _pick_victim(self, die: int) -> int:
        """Greedy: sealed block with the fewest valid pages (ties: lowest
        id, for determinism).  Returns -1 when no block would free space."""
        best, best_valid = -1, self.ppb
        for b in sorted(self.sealed[die]):
            v = int(self.valid[b])
            if v < best_valid:
                best, best_valid = b, v
        return best

    def _collect(self, die: int) -> bool:
        """One GC pass: compact the greedy victim, erase it.  False when no
        victim can yield free space (device effectively full)."""
        victim = self._pick_victim(die)
        if victim < 0:
            return False
        v = int(self.valid[victim])
        gdst = self.gc_active[die]
        room = 0 if gdst < 0 else self.ppb - int(self.wp[gdst])
        ha = self.active[die]
        if ha >= 0:  # pressure fallback may borrow the host frontier
            room += self.ppb - int(self.wp[ha])
        if v > room + len(self.free[die]) * self.ppb:
            return False  # nowhere to relocate into
        self.gc_invocations += 1
        self.gc_log.append((die, victim, gdst))
        base = victim * self.ppb
        wear = float(self.erases[victim]) * self.gc.pec_per_erase
        for slot in range(int(self.wp[victim])):
            lpn = int(self.p2l[base + slot])
            if lpn < 0:
                continue  # already invalidated by a newer host write
            self._events.append((OP_GC_READ, die, lpn % 3, wear, victim))
            self.gc_page_reads += 1
            self._map_write(lpn, gc_stream=True)
            self._events.append((OP_GC_PROG, die, lpn % 3, 0.0, victim))
            self.gc_page_progs += 1
        # Victim is now fully invalid: erase it and (prepass) return it to
        # the pool; under defer_free the online driver returns it via
        # erase_complete() when the erase finishes on the simulated die.
        self.erases[victim] += 1
        self.wp[victim] = 0
        self.valid[victim] = 0
        self.sealed[die].discard(victim)
        if not self.defer_free:
            self.free[die].append(victim)
        self.blocks_erased += 1
        self._events.append((OP_ERASE, die, 0, 0.0, victim))
        return True

    def erase_complete(self, die: int, block: int) -> None:
        """Return an erased (defer_free) victim to ``die``'s free pool."""
        self.free[die].append(block)

    # -- bad-block retirement ------------------------------------------------

    def retire_block(self, die: int, block: int) -> bool:
        """Take a sealed block out of service, relocating its valid pages.

        The controller's end-of-ladder action after a parity rebuild: the
        block's valid pages are compacted through the GC frontier (page
        read + reprogram events, drained like GC traffic) and the block
        never re-enters the free pool.  Returns False — retirement is
        refused — when the block is not a retirable sealed block of
        ``die`` (frontiers and in-flight-erase victims are not), is
        already retired, or when relocating it would consume the die's
        last free block (a wedged device is worse than a bad block; the
        block then stays in service and may be retried later).

        Die-partitioned like every other mutation here: only ``die``'s
        structures are touched, so the sharded engine's contract holds.
        """
        ppb = self.ppb
        if block in self.retired:
            return False
        if block // self.blocks_per_die != die:
            return False
        if block not in self.sealed[die]:
            return False   # frontier / erasing / already free: not ours
        v = int(self.valid[block])
        gdst = self.gc_active[die]
        room = 0 if gdst < 0 else ppb - int(self.wp[gdst])
        ha = self.active[die]
        if ha >= 0:
            room += ppb - int(self.wp[ha])
        # Keep one free block in reserve: retirement must never eat the
        # last allocation room a stalled host write is waiting on.
        if v > room + max(len(self.free[die]) - 1, 0) * ppb:
            return False
        base = block * ppb
        wear = float(self.erases[block]) * self.gc.pec_per_erase
        for slot in range(int(self.wp[block])):
            lpn = int(self.p2l[base + slot])
            if lpn < 0:
                continue
            self._events.append((OP_GC_READ, die, lpn % 3, wear, block))
            self.gc_page_reads += 1
            self._map_write(lpn, gc_stream=True)
            self._events.append((OP_GC_PROG, die, lpn % 3, 0.0, block))
            self.gc_page_progs += 1
        self.sealed[die].discard(block)
        self.wp[block] = ppb      # never allocatable again
        self.valid[block] = 0
        self.retired.add(block)
        self.blocks_retired += 1
        return True

    def retire_erase_failed(self, die: int, block: int) -> None:
        """Retire a block whose erase failed verification.

        Called by the online driver *instead of* :meth:`erase_complete`:
        the block was already compacted and erased by :meth:`_collect`
        (no valid data on it), so retirement is just never returning it
        to ``die``'s free pool.
        """
        self.wp[block] = self.ppb
        self.retired.add(block)
        self.blocks_retired += 1

    def _maybe_gc(self, die: int) -> None:
        if not self.auto_gc:
            return
        guard = 4 * self.blocks_per_die
        while len(self.free[die]) <= self.gc.gc_threshold_blocks and guard > 0:
            if not self._collect(die):
                break
            guard -= 1

    # -- host-facing API ----------------------------------------------------

    def host_write(self, lpn: int) -> None:
        """Out-of-place program of one logical page; may trigger GC."""
        self._map_write(lpn, gc_stream=False)
        self.host_progs += 1
        self._maybe_gc(lpn % self.n_dies)

    def host_read(self, lpn: int) -> float:
        """Resolve a read; returns the mapped block's added wear (P/E).

        A never-written lpn is lazily *pre-filled* (the drive shipped with
        that data): it consumes a physical page and can advance frontiers,
        but is not counted as a host program and emits no program traffic.
        """
        ppn = self.l2p.get(lpn, -1)
        if ppn < 0:
            ppn = self._map_write(lpn, gc_stream=False)
            self.prefill_progs += 1
            self._maybe_gc(lpn % self.n_dies)
        return float(self.erases[ppn // self.ppb]) * self.gc.pec_per_erase

    def drain_events(self) -> List[Tuple[int, int, int, float, int]]:
        """Take the GC page-op events emitted since the last drain —
        ``(kind, die, ptype, wear_pec, victim_block)`` tuples in emission
        order (the block id lets the online driver credit the right free
        pool when the erase completes)."""
        ev = self._events
        self._events = []
        return ev

    @property
    def write_amplification(self) -> float:
        """Physical programs per host program (>= 1.0 by construction)."""
        if self.host_progs == 0:
            return 1.0
        return (self.host_progs + self.gc_page_progs) / self.host_progs

    def stats(self, host_reads: int = 0) -> FTLStats:
        return FTLStats(
            host_reads=host_reads,
            host_progs=self.host_progs,
            prefill_progs=self.prefill_progs,
            gc_page_reads=self.gc_page_reads,
            gc_page_progs=self.gc_page_progs,
            blocks_erased=self.blocks_erased,
            gc_invocations=self.gc_invocations,
            write_amplification=self.write_amplification,
            blocks_per_die=self.blocks_per_die,
            pages_per_block=self.ppb,
            footprint_pages=self.footprint,
            max_block_pe=float(self.erases.max()) * self.gc.pec_per_erase,
            blocks_retired=self.blocks_retired,
        )


def build_ftl_schedule(
    trace: RequestTrace, cfg: SSDConfig = DEFAULT_SSD, expansion=None
) -> FTLSchedule:
    """Run a trace through the FTL and emit the combined page-op schedule.

    Deterministic pre-pass in admission order: host ops keep exactly the
    (arrival, rid, die, channel, page type) the in-place expansion gives
    them; GC/erase ops are interleaved right after the host write that
    triggered them, carrying that write's arrival time, ``rid = -1``, and
    the victim block's wear.  The result is shared across every mechanism
    of a sweep, like ``expand_trace``'s output.  Pass ``expansion`` to
    reuse an already-computed ``expand_trace(trace, cfg)`` result.
    """
    from repro.flashsim.ssd import expand_trace  # deferred: ssd imports us

    ex = expansion if expansion is not None else expand_trace(trace, cfg)
    ftl = PageMapFTL(cfg, lpns=ex.page_id)
    tprog = cfg.timing.tprog_us
    terase = cfg.gc.t_erase_us
    n_ch = cfg.n_channels

    arrival: List[float] = []
    rid: List[int] = []
    die: List[int] = []
    chan: List[int] = []
    ptype: List[int] = []
    kind: List[int] = []
    dur: List[float] = []
    wear: List[float] = []
    lpns: List[int] = []

    def emit(a, r, d, pt, k, du, w, lp=-1):
        arrival.append(a)
        rid.append(r)
        die.append(d)
        chan.append(d % n_ch)
        ptype.append(pt)
        kind.append(k)
        dur.append(du)
        wear.append(w)
        lpns.append(lp)

    arr_l = ex.arrival_us.tolist()
    rid_l = ex.rid.tolist()
    lpn_l = ex.page_id.tolist()
    read_l = ex.is_read.tolist()
    n_dies = cfg.n_dies
    host_reads = 0
    for i in range(ex.n_ops):
        lpn = lpn_l[i]
        a = arr_l[i]
        d = lpn % n_dies
        if read_l[i]:
            w = ftl.host_read(lpn)
            emit(a, rid_l[i], d, lpn % 3, OP_READ, 0.0, w, lpn)
            host_reads += 1
        else:
            ftl.host_write(lpn)
            emit(a, rid_l[i], d, lpn % 3, OP_PROG, tprog, 0.0, lpn)
        for (k, gd, pt, gw, _blk) in ftl.drain_events():
            gdur = tprog if k == OP_GC_PROG else (terase if k == OP_ERASE else 0.0)
            emit(a, -1, gd, pt, k, gdur, gw)

    return FTLSchedule(
        arrival_us=np.asarray(arrival, np.float64),
        rid=np.asarray(rid, np.int64),
        die=np.asarray(die, np.int64),
        chan=np.asarray(chan, np.int64),
        ptype=np.asarray(ptype, np.int64),
        kind=np.asarray(kind, np.int64),
        dur_us=np.asarray(dur, np.float64),
        wear_pec=np.asarray(wear, np.float64),
        n_requests=ex.n_requests,
        stats=ftl.stats(host_reads=host_reads),
        lpn=np.asarray(lpns, np.int64),
    )
