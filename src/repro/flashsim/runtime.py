"""Parallel sweep runtime: grid cells scheduled across a process pool.

This is the middle layer of the sharded simulation runtime (ISSUE 5):

  * :mod:`repro.flashsim.engine` — *intra-run* decomposition: one event
    loop per channel behind ``shard=True``, bit-identical to the
    monolithic loop;
  * **this module** — *inter-cell* parallelism: a sweep's
    (mechanism x condition x seed x trace) grid cells are scheduled
    across a process pool with deterministic assembly, so a
    ``workers=4`` sweep returns exactly what ``workers=1`` returns —
    byte-identical once serialized (:func:`sweep_to_json`) — only
    faster;
  * :mod:`repro.flashsim.ssd` — the run APIs' ``workers=`` / ``shard=``
    knobs, which delegate here.

Scheduling unit
---------------
A :class:`Cell` is one schedulable unit.  ``kind="batch"`` cells are the
sweet spot: one *seed group* of a ``simulate_batch`` grid, which keeps
the single-seed trace generation, page-op expansion, and FTL pre-pass
shared across that group's (mechanism x condition) cells inside one
worker — the same sharing ``simulate_batch`` does inline.  ``simulate``
and ``compare`` cells wrap the corresponding run APIs for benchmark
harnesses that sweep per-seed cells directly.

Cache reuse across workers
--------------------------
Workers are forked when the platform allows (``fork`` start method, the
Linux default): a forked worker inherits the parent's process-wide
caches copy-on-write — the content-hash trace cache
(:func:`repro.flashsim.workloads.cached_trace`) and the in-process
characterization memos — so :func:`run_cells` pre-warms every
(condition, mechanism) characterization table in the parent *before*
creating the pool and no worker ever re-enters JAX.  Under ``spawn``
(or any cold worker) the on-disk characterization cache
(``~/.cache/repro_flashsim``, see :mod:`repro.core.characterize`) fills
the same role at a one-read-per-table cost.  Force a start method with
``REPRO_SWEEP_START_METHOD``; force inline execution (no pool, e.g. in
sandboxes without working semaphores) with ``REPRO_SWEEP_INLINE=1``.

Determinism
-----------
Cell *results* never depend on the worker count — each cell runs the
identical code path a ``workers=1`` run executes — and cell *ordering*
is fixed by the caller's input order (:func:`run_cells` returns results
positionally; :func:`run_sweep` assembles its dict in canonical
seed -> condition -> mechanism order).  :func:`sweep_to_json` is the
canonical serialization used by the determinism tests and the CI
bench-smoke lane: byte-identical output for any ``workers``.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import multiprocessing
import os
import platform
import threading
import time
from concurrent.futures import (
    FIRST_COMPLETED,
    BrokenExecutor,
    ProcessPoolExecutor,
    wait,
)
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.flashsim.config import (
    DEFAULT_SSD,
    FaultConfig,
    OperatingCondition,
    SSDConfig,
)

__all__ = [
    "Cell",
    "host_fingerprint",
    "prewarm_characterization",
    "run_cells",
    "run_compare",
    "run_sweep",
    "sweep_cell_key",
    "sweep_to_json",
]


@dataclasses.dataclass(frozen=True)
class Cell:
    """One schedulable unit of a sweep.

    ``kind`` selects the run API the worker executes:

      * ``"simulate"`` — one (mechanism, condition, seed) run; returns
        a :class:`repro.flashsim.ssd.SimStats`;
      * ``"compare"`` — all ``mechanisms`` over one shared trace
        (:func:`repro.flashsim.ssd.compare_mechanisms`); returns
        ``{mechanism: SimStats}``;
      * ``"batch"`` — one full single-seed ``simulate_batch`` group
        (shares trace/expansion/FTL pre-pass across
        mechanisms x conditions); returns the batch dict.

    Cells must be picklable: ``workload`` is a
    :class:`~repro.flashsim.workloads.Workload`, a registry spec string,
    or a picklable :class:`~repro.flashsim.workloads.TraceSource`.
    """

    kind: str
    workload: object
    conditions: Tuple[OperatingCondition, ...]
    mechanisms: Tuple[str, ...]
    seed: int
    cfg: SSDConfig = DEFAULT_SSD
    n_requests: Optional[int] = None
    #: ``None`` defers to ``cfg.engine`` (itself ``"array"`` by default).
    engine: Optional[str] = None
    scheduler: Optional[str] = None
    gc: Optional[str] = None
    shard: bool = False
    faults: Optional[FaultConfig] = None
    ncq_depth: Optional[int] = None
    host_cache: object = None
    #: Fused-sweep dispatch policy (``None`` defers to ``cfg.fuse``).
    #: ``"batch"``/``"compare"`` cells fuse their inner grid inside
    #: ``simulate_batch``/``compare_mechanisms``; eligible
    #: ``"simulate"`` cells sharing a trace and config are additionally
    #: fused *across cells* by :func:`run_cells` (same results either
    #: way — the fused path is bit-identical).
    fuse: Optional[bool] = None

    def __post_init__(self):
        if self.kind not in ("simulate", "compare", "batch"):
            raise ValueError(
                f"Cell.kind must be 'simulate', 'compare' or 'batch', "
                f"got {self.kind!r}"
            )
        if self.kind == "simulate" and len(self.mechanisms) != 1:
            raise ValueError(
                "a 'simulate' cell takes exactly one mechanism, got "
                f"{self.mechanisms!r}"
            )
        if self.kind != "batch" and len(self.conditions) != 1:
            raise ValueError(
                f"a {self.kind!r} cell takes exactly one condition, got "
                f"{len(self.conditions)}"
            )


def _run_cell(cell: Cell):
    """Execute one cell (in a worker or inline) — pure in its argument."""
    from repro.flashsim.ssd import (
        compare_mechanisms,
        simulate,
        simulate_batch,
    )

    if cell.kind == "simulate":
        return simulate(
            cell.workload, cell.conditions[0], cell.mechanisms[0],
            seed=cell.seed, cfg=cell.cfg, n_requests=cell.n_requests,
            engine=cell.engine, scheduler=cell.scheduler, gc=cell.gc,
            shard=cell.shard, faults=cell.faults,
            ncq_depth=cell.ncq_depth, host_cache=cell.host_cache,
        )
    if cell.kind == "compare":
        return compare_mechanisms(
            cell.workload, cell.conditions[0], mechanisms=cell.mechanisms,
            seed=cell.seed, cfg=cell.cfg, n_requests=cell.n_requests,
            engine=cell.engine, scheduler=cell.scheduler, gc=cell.gc,
            shard=cell.shard, faults=cell.faults,
            ncq_depth=cell.ncq_depth, host_cache=cell.host_cache,
            fuse=cell.fuse,
        )
    return simulate_batch(
        cell.workload, cell.conditions, mechanisms=cell.mechanisms,
        seeds=(cell.seed,), cfg=cell.cfg, n_requests=cell.n_requests,
        engine=cell.engine, scheduler=cell.scheduler, gc=cell.gc,
        shard=cell.shard, faults=cell.faults,
        ncq_depth=cell.ncq_depth, host_cache=cell.host_cache,
        fuse=cell.fuse,
    )


def _fusable_cfg(cell: Cell):
    """Knob-overlaid config when a ``"simulate"`` cell is eligible for
    cross-cell fusion, else ``None``.

    Eligibility mirrors the inline sweeps: the cell's engine must be
    ``"batched"``/``"auto"``, fusion enabled (``cell.fuse``, defaulting
    to ``cfg.fuse``), and the overlaid config must resolve inside the
    batched matrix (ring-lowerable scheduler, gc off/prepass, no
    faults, open loop).  Ineligible cells run :func:`_run_cell` alone —
    ``"auto"`` fallbacks record their reason on ``SimStats`` exactly as
    without fusion, and explicit-``"batched"`` misconfigurations raise
    the same :class:`BatchedUnsupported` they always did.
    """
    if cell.kind != "simulate":
        return None
    engine = cell.engine if cell.engine is not None else cell.cfg.engine
    from repro.flashsim.ssd import _fuse_resolved, _with_knobs

    cfg = _with_knobs(cell.cfg, cell.scheduler, cell.gc, cell.faults,
                      cell.ncq_depth, cell.host_cache)
    return cfg if _fuse_resolved(cfg, engine, cell.fuse) else None


def _fusion_groups(items: Sequence[Tuple[int, Cell]]):
    """Partition (index, cell) pairs into host-prep groups and leftovers.

    A group is a maximal set of eligible ``"simulate"`` cells sharing
    the *resolved trace object* (cached and frozen, so equal
    (workload, seed, n_requests) cells resolve to one identity) and the
    knob-overlaid config (compared by ``repr`` — configs carry an
    unhashable timing dict); the shared trace/expansion/schedule are
    then computed once per group.  The
    grouping only decides host-side sharing — the kernel dispatch fuses
    *across* groups (:func:`_run_items_fused` hands every prepared cell
    to one engine call, which chunks by static kernel shape and step
    homogeneity), so a lone cell of one trace still stacks with cells
    of another.  Returns ``(groups, singles)`` where each group is
    ``(trace, cfg, [(index, cell), ...])``.
    """
    from repro.flashsim.ssd import resolve_trace

    buckets: Dict[Tuple[str, str], list] = {}
    singles: List[Tuple[int, Cell]] = []
    for i, cell in items:
        cfg = _fusable_cfg(cell)
        if cfg is None:
            singles.append((i, cell))
            continue
        trace = resolve_trace(cell.workload, seed=cell.seed,
                              n_requests=cell.n_requests)
        # Trace identity, not content hash: resolved traces are cached
        # frozen objects, so equal (workload, seed, n) cells share one.
        # Grouping only decides host-prep sharing — results are
        # grouping-invariant (the cell-axis law), so a cache miss can
        # only cost sharing, never correctness.
        key = (id(trace), repr(cfg))
        buckets.setdefault(key, []).append((i, cell, cfg, trace))
    groups = []
    for members in buckets.values():
        _, _, cfg, trace = members[0]
        groups.append((trace, cfg, [(i, c) for i, c, _, _ in members]))
    return groups, singles


def _run_items_fused(items: Sequence[Tuple[int, Cell]]) -> Dict[int, object]:
    """Results for the fused-eligible subset of ``items`` (cross-cell
    fusion); cells not covered by the returned dict run per-cell.

    Host prep is shared per trace/config group, then every prepared
    cell goes through ONE fused engine call — cells of different
    workloads and seeds stack along the kernel's cell axis whenever
    their static shapes and step bounds line up.  A lone eligible cell
    runs per-cell (nothing to amortize).  A batch that turns out
    unsupported at dispatch time (a guard the pre-filter should make
    unreachable) falls back to per-cell runs by simply not contributing
    results — never a silent wrong answer.
    """
    from repro.flashsim.engine_batched import BatchedUnsupported
    from repro.flashsim.ssd import (_make_sim, _run_prepared_fused,
                                    _shared_views)

    groups, _ = _fusion_groups(items)
    if sum(len(members) for _, _, members in groups) < 2:
        return {}
    prepped: List[Tuple[int, object, object]] = []
    for trace, cfg, members in groups:
        expansion, schedule = _shared_views(trace, cfg)
        for i, cell in members:
            engine = (cell.engine if cell.engine is not None
                      else cell.cfg.engine)
            sim = _make_sim(cfg, cell.conditions[0], cell.mechanisms[0],
                            cell.seed + 7, engine)
            prepped.append((i, sim, sim._prepare(
                trace, expansion=expansion, schedule=schedule)))
    try:
        stats = _run_prepared_fused([(s, p) for _, s, p in prepped])
    except BatchedUnsupported:
        return {}
    return {i: st for (i, _, _), st in zip(prepped, stats)}


def prewarm_characterization(cells: Iterable[Cell]) -> int:
    """Build every (condition, mechanism) table the cells will touch.

    Called in the parent before the pool is created so forked workers
    inherit warm in-process memos (and never call into JAX themselves);
    under spawn the work instead lands once in the on-disk cache.
    Returns the number of distinct tables touched.
    """
    from repro.core.retry import RetryPolicy
    from repro.flashsim.ssd import SSDSim

    seen = set()
    for cell in cells:
        for cond in cell.conditions:
            for mech in cell.mechanisms:
                key = (cond, mech)
                if key in seen:
                    continue
                seen.add(key)
                SSDSim(cell.cfg, cond, RetryPolicy(mech))
    return len(seen)


def _batched_sigs(cells: Iterable[Cell]):
    """Distinct batched-kernel signatures the cells will (or may) run.

    A cell contributes when its engine is ``"batched"`` or ``"auto"``
    *and* its knob-overlaid config resolves inside the batched matrix —
    the same :func:`~repro.flashsim.engine_batched.resolve_engine` call
    run() will make (auto cells that fall back contribute nothing;
    that per-cell gate is what keeps prewarm from compiling variants an
    ``"auto"`` sweep would never launch).  Signature = (lane count,
    local die count, pipelined, scheduler lowering mode): exactly the
    static parts of the kernel's jit key that the cell list determines
    up front.  Fusion-enabled cells additionally contribute their
    *fused* lane counts — a batch/compare cell's inner grid dispatches
    at ``min(C, cap) * n_channels`` lanes per pipelined class (``cap``
    = the engine's fused cell cap), and fusable simulate cells sharing
    a (workload, n_requests, config) proxy key are counted as one
    cross-cell chunk — so the widened kernel variants are warmed too,
    not just the per-cell ones.  (Step-heterogeneous grids may chunk
    smaller at dispatch time; those narrower variants compile on first
    use and land in the same persistent cache.)
    """
    from repro.core.retry import RetryPolicy
    from repro.flashsim.engine_batched import (_fuse_cell_cap,
                                               resolve_engine)
    from repro.flashsim.sched import get_scheduler
    from repro.flashsim.ssd import _with_knobs

    sigs = set()
    cross: Dict[Tuple, Tuple[int, int, int]] = {}
    for cell in cells:
        engine = cell.engine if cell.engine is not None else cell.cfg.engine
        if engine not in ("batched", "auto"):
            continue
        cfg = _with_knobs(cell.cfg, cell.scheduler, cell.gc, cell.faults,
                          cell.ncq_depth, cell.host_cache)
        if resolve_engine(cfg)[0] != "batched":
            continue
        mode, _ = get_scheduler(cfg.scheduler).ring_lowering
        n_ch = cfg.n_channels
        n_dies_local = -(-cfg.n_dies // n_ch)
        for mech in cell.mechanisms:
            sigs.add((n_ch, n_dies_local, RetryPolicy(mech).pipelined, mode))
        if not (cfg.fuse if cell.fuse is None else cell.fuse):
            continue
        if cell.kind in ("batch", "compare"):
            # Inner-grid fusion: one dispatch per pipelined class, cell
            # axis = conditions x same-class mechanisms, pow2-bucketed.
            for pipe in (False, True):
                n_mech = sum(1 for m in cell.mechanisms
                             if RetryPolicy(m).pipelined == pipe)
                grid = len(cell.conditions) * n_mech
                if grid > 1:
                    grid = min(grid, _fuse_cell_cap(n_ch))
                    sigs.add((grid * n_ch, n_dies_local, pipe, mode))
        else:
            # Cross-cell fusion stacks simulate cells whenever their
            # static kernel shapes and step bounds line up; the
            # (workload, n_requests, config) proxy (seed-blind — same
            # workload at different seeds has near-identical step
            # bounds, so those cells land in one chunk) avoids
            # resolving traces here.
            pipe = RetryPolicy(cell.mechanisms[0]).pipelined
            key = (repr(cell.workload), cell.n_requests,
                   repr(cfg), pipe, mode)
            count, _, _ = cross.get(key, (0, 0, 0))
            cross[key] = (count + 1, n_ch, n_dies_local)
    for (_, _, _, pipe, mode), (count, n_ch, n_dl) in cross.items():
        if count > 1:
            count = min(count, _fuse_cell_cap(n_ch))
            sigs.add((count * n_ch, n_dl, pipe, mode))
    return sigs


def prewarm_batched(cells: Iterable[Cell]) -> int:
    """Compile the batched core's kernel variants before the pool starts.

    For every distinct signature in :func:`_batched_sigs`, runs the
    lockstep kernel once on a tiny synthetic op table in the parent
    process.  The payoff is the *persistent* compilation cache
    (:mod:`repro.kernels.fcfs_core.ops`): the parent's compile lands on
    disk, so every (spawned) worker's first batched cell is a cache hit
    instead of an XLA compile.  Timing constants, step counts, and
    aging bounds are traced (not compile keys), so the tiny table warms
    the same executable a real floor-bucket cell uses; larger shape
    buckets still compile on first use but land in the same on-disk
    cache for every later process.  Fused signatures warm through the
    same :func:`~repro.kernels.fcfs_core.ops._dispatch` path, so a
    ``C * n_channels``-lane warm run hits the exact jit key a fused
    chunk with equal statics will ask for (including the ``wide``
    scatter lowering above 8 lanes).  Returns the number of kernel
    variants warmed.
    """
    sigs = _batched_sigs(cells)
    if not sigs:
        return 0
    import numpy as np

    from repro.kernels.fcfs_core import fcfs_core
    from repro.kernels.fcfs_core.ops import pad_ops

    for n_lanes, n_dies_local, pipelined, mode in sigs:
        # One host read per lane: [arrival kind die dur attempts tr hp].
        lane = np.array([[0.0, 0.0, 0.0, 0.0, 1.0, 40.0, 1.0]])
        fcfs_core(pad_ops([lane] * n_lanes), n_dies_local, pipelined,
                  100.0, 10.0,
                  age_bound=16.0 if mode == "prio" else None)
    return len(sigs)


def _mp_context(use_jax: bool = False):
    """Pool start-method: fork by default, spawn for JAX-using workers.

    Forked children of a JAX-initialized parent deadlock the moment
    they call back into XLA (the runtime's thread pool does not survive
    ``os.fork``) — array-engine sweeps never do (workers only read the
    parent's memoized characterization tables), but batched cells run
    the kernel *in* the worker, so any sweep whose cells may select the
    batched engine takes a ``spawn`` pool instead.  Spawned workers pay
    a fresh interpreter + import, and their kernel compiles are
    persistent-cache hits thanks to :func:`prewarm_batched`.
    ``REPRO_SWEEP_START_METHOD`` still overrides both defaults.
    """
    method = os.environ.get("REPRO_SWEEP_START_METHOD")
    if not method:
        methods = multiprocessing.get_all_start_methods()
        if use_jax:
            method = "spawn" if "spawn" in methods else None
        else:
            method = "fork" if "fork" in methods else None
    return multiprocessing.get_context(method)


def _inline_forced() -> bool:
    return os.environ.get("REPRO_SWEEP_INLINE", "0") == "1"


# -- checkpoint journal ----------------------------------------------------


def _encode_result(r):
    """Cell result -> JSON-safe journal record (floats repr-round-trip)."""
    from repro.flashsim.ssd import SimStats

    if isinstance(r, SimStats):
        return {"t": "stats", "v": dataclasses.asdict(r)}
    if isinstance(r, dict):
        if all(isinstance(k, str) for k in r):       # compare: {mech: stats}
            return {"t": "mechs",
                    "v": {m: dataclasses.asdict(s) for m, s in r.items()}}
        return {"t": "cells",                        # batch: {(m, cond, s): stats}
                "v": [[m, cond.retention_days, cond.pec, s,
                       dataclasses.asdict(st)]
                      for (m, cond, s), st in r.items()]}
    raise TypeError(f"cell result of type {type(r).__name__} cannot be "
                    f"journaled")


def _stats_from_journal(d):
    """Rebuild a SimStats from a journal record, tolerating schema drift.

    SimStats grows additive zero-default fields over time (GC, fault and
    closed-loop blocks landed in separate PRs).  A journal written by an
    older build lacks the new keys (defaults fill them in), and one
    written by a *newer* build may carry keys this build doesn't know —
    drop those rather than crash, so resume never breaks on additive
    stats.
    """
    from repro.flashsim.ssd import SimStats

    known = {f.name for f in dataclasses.fields(SimStats)}
    return SimStats(**{k: v for k, v in d.items() if k in known})


def _decode_result(e):
    t, v = e["t"], e["v"]
    if t == "stats":
        return _stats_from_journal(v)
    if t == "mechs":
        return {m: _stats_from_journal(d) for m, d in v.items()}
    return {
        (m, OperatingCondition(ret, pec), s): _stats_from_journal(d)
        for m, ret, pec, s, d in v
    }


class _Journal:
    """Append-only JSONL checkpoint of completed cells.

    Line 0 is a header carrying the *run key* — a hash over the cell
    list's reprs — so a journal can only ever resume the exact sweep
    that wrote it; any other cell list starts the file over.  Each
    subsequent line records one completed cell ``{"i": index, "r":
    encoded result}``, flushed as it lands, so a run killed mid-sweep
    (even SIGKILL — the write syscall has happened) loses at most the
    in-flight cells.  JSON floats round-trip exactly through ``repr``,
    so a resumed sweep's assembled results — and its
    :func:`sweep_to_json` — are byte-identical to an uninterrupted run.
    A torn trailing line (killed mid-append) is ignored.
    """

    def __init__(self, path, cells: Sequence[Cell]):
        self.path = os.fspath(path)
        self.key = hashlib.sha256(
            "\n".join(repr(c) for c in cells).encode()
        ).hexdigest()
        self.done: Dict[int, object] = {}
        try:
            with open(self.path) as f:
                lines = f.read().splitlines()
        except OSError:
            lines = []
        resumable = False
        if lines:
            try:
                resumable = json.loads(lines[0]).get("run") == self.key
            except ValueError:
                resumable = False
        if resumable:
            for ln in lines[1:]:
                try:
                    ent = json.loads(ln)
                    self.done[int(ent["i"])] = _decode_result(ent["r"])
                except (ValueError, KeyError, TypeError):
                    break                      # torn tail: drop it
            self._f = open(self.path, "a")
        else:
            self._f = open(self.path, "w")
            self._f.write(json.dumps({"run": self.key}) + "\n")
            self._f.flush()

    def record(self, i: int, result) -> None:
        self._f.write(
            json.dumps({"i": i, "r": _encode_result(result)}) + "\n"
        )
        self._f.flush()


# Oversubscription factor for chunked submission: pending cells are
# grouped into ~workers * _CHUNK_OVERSUB tasks, so one pickled round
# trip carries several small cells (per-task IPC was costing more than
# the cells themselves: BENCH_sim recorded speedup 0.92 at workers=4)
# while still leaving enough tasks per worker for load balancing.
_CHUNK_OVERSUB = 4


def _chunk_pending(pending: Dict[int, Cell],
                   workers: int) -> List[List[Tuple[int, Cell]]]:
    items = sorted(pending.items())
    n_tasks = workers * _CHUNK_OVERSUB
    size = max(1, -(-len(items) // n_tasks))
    return [items[k:k + size] for k in range(0, len(items), size)]


def _run_cell_chunk(items: List[Tuple[int, Cell]]):
    """Worker entry: run a chunk of (index, cell) pairs in order.

    Fusable ``"simulate"`` cells that landed in the same chunk run as
    fused kernel dispatches (:func:`_run_items_fused`); the rest — and
    any fused group that falls back — run per-cell.  Bit-identical
    either way, so chunking policy never changes results.
    """
    fused = _run_items_fused(items)
    return [(i, fused[i] if i in fused else _run_cell(c))
            for i, c in items]


def _finish_inline(results: List, pending: Dict[int, Cell],
                   jr: Optional[_Journal]) -> List:
    """Run the leftover cells inline (in index order), journaling each.

    Like the chunked worker path, fusable ``"simulate"`` cells run as
    fused dispatches first; journal records are still written in index
    order, so resume semantics are unchanged.
    """
    fused = _run_items_fused(sorted(pending.items()))
    for i in sorted(pending):
        r = fused[i] if i in fused else _run_cell(pending[i])
        results[i] = r
        if jr is not None:
            jr.record(i, r)
    return results


def run_cells(cells: Sequence[Cell], workers: int = 1,
              prewarm: bool = True, journal=None,
              cell_timeout: Optional[float] = None,
              max_retries: int = 2, backoff_s: float = 0.1) -> List:
    """Execute ``cells``; results are returned in input order.

    ``workers <= 1`` runs inline (no pool, no pickling — the exact
    ``workers=1`` code path).  Larger counts fan cells out over a
    process pool in *chunks* of several cells per task (amortizing the
    per-task pickle/IPC overhead that made small-cell sweeps slower
    than inline); results are still assembled positionally, so the
    output is independent of completion order, worker count, and
    chunking.

    Self-healing: pool-*infrastructure* failures never cost completed
    work.  Results are harvested per-cell as futures finish, so when
    workers die (``BrokenExecutor`` — fork breakage, an OOM-killed or
    SIGKILLed child) only the genuinely unfinished cells are retried —
    on a fresh pool, up to ``max_retries`` times with exponential
    backoff (``backoff_s * 2**attempt``), then inline as the last
    resort.  ``cell_timeout`` (seconds) bounds the wait for *progress*:
    if no cell completes within it, the pool is declared stalled and
    abandoned (a hung worker cannot hang the sweep) and the remainder
    is retried the same way (progress is observed per completed
    *chunk*).  An exception raised *by a cell itself*
    propagates unchanged — it would fail inline too, so retrying would
    only duplicate the work.

    ``journal`` (a path) checkpoints every completed cell to an
    append-only JSONL file keyed by the cell list: a killed sweep
    re-run with the same cells and journal skips the recorded cells and
    returns byte-identical results (:class:`_Journal`).
    """
    cells = list(cells)
    jr = _Journal(journal, cells) if journal is not None else None
    results: List = [None] * len(cells)
    pending: Dict[int, Cell] = {}
    for i, c in enumerate(cells):
        if jr is not None and i in jr.done:
            results[i] = jr.done[i]
        else:
            pending[i] = c
    if not pending:
        return results
    workers = min(int(workers), len(pending))
    if workers <= 1 or _inline_forced():
        return _finish_inline(results, pending, jr)
    # Cells that may run the batched engine execute JAX *in* the
    # worker: they need a spawn pool (fork would inherit a broken XLA
    # runtime — see _mp_context) and, with prewarm, a populated
    # persistent compile cache so each spawned worker's kernels are
    # disk hits rather than fresh XLA compiles.
    use_jax = bool(_batched_sigs(pending.values()))
    if prewarm:
        prewarm_characterization(pending.values())
        if use_jax:
            prewarm_batched(pending.values())
    attempt = 0
    while True:
        try:
            pool = ProcessPoolExecutor(
                max_workers=min(workers, len(pending)),
                mp_context=_mp_context(use_jax),
            )
        except (OSError, PermissionError):
            # Sandboxed semaphores / fork unavailable: no pool at all.
            break
        stalled = False
        try:
            # Chunked submission: one task carries several cells, so
            # the pickle/IPC round trip is amortized (results are still
            # placed positionally — output is identical for any worker
            # count or chunking).
            futures = {pool.submit(_run_cell_chunk, ch): [i for i, _ in ch]
                       for ch in _chunk_pending(pending, workers)}
            not_done = set(futures)
            while not_done:
                done, not_done = wait(not_done, timeout=cell_timeout,
                                      return_when=FIRST_COMPLETED)
                if not done:
                    stalled = True        # no progress within cell_timeout
                    break
                for fut in done:
                    try:
                        chunk_results = fut.result()
                    except BrokenExecutor:
                        # This future's worker died; siblings that DID
                        # complete still carry their results — keep
                        # harvesting, never discard finished work.
                        stalled = True
                        continue
                    for i, r in chunk_results:
                        results[i] = r
                        del pending[i]
                        if jr is not None:
                            jr.record(i, r)
        except BrokenExecutor:
            stalled = True
        except BaseException:
            pool.shutdown(wait=False, cancel_futures=True)
            raise
        # A stalled pool may hold a hung worker: abandon it without
        # waiting (its processes drain in the background).
        pool.shutdown(wait=not stalled, cancel_futures=True)
        if not pending:
            return results
        attempt += 1
        if attempt > max_retries:
            break
        time.sleep(backoff_s * (2 ** (attempt - 1)))
    return _finish_inline(results, pending, jr)


def run_sweep(
    workload,
    conditions: Iterable[OperatingCondition],
    mechanisms: Sequence[str],
    seeds: Sequence[int],
    cfg: SSDConfig = DEFAULT_SSD,
    n_requests: Optional[int] = None,
    engine: str = "array",
    scheduler: Optional[str] = None,
    gc: Optional[str] = None,
    shard: bool = False,
    workers: int = 1,
    faults: Optional[FaultConfig] = None,
    journal=None,
    ncq_depth: Optional[int] = None,
    host_cache=None,
    fuse: Optional[bool] = None,
) -> Dict[Tuple[str, OperatingCondition, int], "object"]:
    """``simulate_batch`` semantics with seed groups fanned over workers.

    One :class:`Cell` per seed keeps each group's trace / expansion /
    FTL pre-pass shared inside its worker, exactly like the inline
    sweep.  The result dict is assembled in the canonical
    seed -> condition -> mechanism order regardless of worker count, so
    iteration order — and :func:`sweep_to_json` output — is byte-stable.
    ``journal=`` names a checkpoint file: completed seed groups are
    recorded as they finish and a killed sweep re-run with the same
    arguments resumes from it byte-identically (:func:`run_cells`).
    ``fuse=`` overrides ``cfg.fuse`` per cell: each seed group's
    eligible (condition x mechanism) grid runs as fused kernel
    dispatches inside its worker, bit-identical either way.
    """
    conditions = tuple(conditions)
    mechanisms = tuple(mechanisms)
    seeds = tuple(seeds)
    cells = [
        Cell("batch", workload, conditions, mechanisms, s, cfg, n_requests,
             engine, scheduler, gc, shard, faults=faults,
             ncq_depth=ncq_depth, host_cache=host_cache, fuse=fuse)
        for s in seeds
    ]
    groups = run_cells(cells, workers=workers, journal=journal)
    out: Dict[Tuple[str, OperatingCondition, int], object] = {}
    for s, group in zip(seeds, groups):
        for cond in conditions:
            for mech in mechanisms:
                out[(mech, cond, s)] = group[(mech, cond, s)]
    return out


# -- compare_mechanisms fan-out -------------------------------------------
#
# Mechanisms of one compare share the trace, the expansion, and (prepass
# GC) the FTL schedule.  Shipping those to workers by pickle would cost
# more than it saves, so the parallel path relies on fork inheritance:
# the parent materializes the shared views in _COMPARE_PAYLOAD, forks the
# pool, and each task reads them back copy-on-write.  Without fork the
# call simply runs inline — correctness never depends on the pool.
# _COMPARE_LOCK serializes the payload's lifetime so concurrent
# compare_mechanisms(..., workers>1) calls from different threads cannot
# fork a pool against each other's views.

_COMPARE_PAYLOAD = None
_COMPARE_LOCK = threading.Lock()


def _run_compare_mech(mechanism: str):
    from repro.flashsim.ssd import _make_sim

    trace, expansion, schedule, cfg, condition, seed, shard, engine = \
        _COMPARE_PAYLOAD
    sim = _make_sim(cfg, condition, mechanism, seed + 7, engine)
    return sim.run(trace, expansion=expansion, schedule=schedule,
                   shard=shard)


def run_compare(
    workload,
    condition: OperatingCondition,
    mechanisms: Sequence[str],
    seed: int,
    cfg: SSDConfig,
    n_requests: Optional[int],
    scheduler: Optional[str],
    gc: Optional[str],
    shard: bool,
    workers: int,
    engine: str = "array",
    fuse: Optional[bool] = None,
) -> Dict[str, "object"]:
    """Parallel ``compare_mechanisms``: one worker per mechanism.

    Requires the ``fork`` start method (shared views are inherited, not
    pickled); otherwise — or on pool failure — falls back to the inline
    run API.  Results match ``compare_mechanisms(..., workers=1)``
    exactly, in the caller's mechanism order.  Supports the ``array``
    and ``batched`` engines (both consume the shared expansion/schedule
    views).  A fusable batched compare (``fuse=``, default
    ``cfg.fuse``) skips the pool entirely — one fused dispatch in-process
    beats per-mechanism fork workers, and the results are bit-identical.
    """
    global _COMPARE_PAYLOAD
    from repro.flashsim import ssd

    mechanisms = tuple(mechanisms)
    ctx = _mp_context()
    fused = ssd._fuse_resolved(
        ssd._with_knobs(cfg, scheduler, gc), engine, fuse
    ) and len(mechanisms) > 1
    if (fused or workers <= 1 or len(mechanisms) <= 1 or _inline_forced()
            or ctx.get_start_method() != "fork"):
        return ssd.compare_mechanisms(
            workload, condition, mechanisms=mechanisms, seed=seed, cfg=cfg,
            n_requests=n_requests, engine=engine, scheduler=scheduler,
            gc=gc, shard=shard, fuse=fuse,
        )
    cfg = ssd._with_knobs(cfg, scheduler, gc)
    trace = ssd.resolve_trace(workload, seed=seed, n_requests=n_requests)
    expansion, schedule = ssd._shared_views(trace, cfg)
    # Materialize the lazy list views now so forked children share them.
    expansion.admission_lists
    if schedule is not None:
        schedule.admission_lists
    prewarm_characterization(
        [Cell("compare", workload, (condition,), mechanisms, seed, cfg)]
    )
    with _COMPARE_LOCK:
        _COMPARE_PAYLOAD = (trace, expansion, schedule, cfg, condition,
                            seed, shard, engine)
        try:
            try:
                pool = ProcessPoolExecutor(
                    max_workers=min(workers, len(mechanisms)),
                    mp_context=ctx,
                )
            except (OSError, PermissionError):
                pool = None
            if pool is None:
                stats = [_run_compare_mech(m) for m in mechanisms]
            else:
                try:
                    with pool:
                        futures = [pool.submit(_run_compare_mech, m)
                                   for m in mechanisms]
                        stats = [f.result() for f in futures]
                except BrokenExecutor:
                    stats = [_run_compare_mech(m) for m in mechanisms]
        finally:
            _COMPARE_PAYLOAD = None
    return dict(zip(mechanisms, stats))


# -- canonical serialization ----------------------------------------------


def sweep_cell_key(mechanism: str, condition: OperatingCondition,
                   seed: int) -> str:
    """Collision-free string key for one sweep cell (JSON dict key).

    Condition floats are rendered with ``repr`` (exact round-trip), so
    two distinct conditions can never collapse to one key.
    """
    return (f"{mechanism}|ret{condition.retention_days!r}"
            f"|pec{condition.pec!r}|seed{seed}")


def _stats_payload(stats) -> Dict[str, object]:
    """SimStats -> JSON dict of *compared* fields only.

    ``compare=False`` fields (engine_selected, fast_path_events,
    fused_cells, ...) describe how a result was computed, not what it
    is — including them would make the serialization depend on engine
    and fusion decisions that are defined to be outcome-neutral.
    """
    d = dataclasses.asdict(stats)
    return {f.name: d[f.name] for f in dataclasses.fields(stats)
            if f.compare}


def sweep_to_json(results: Dict) -> str:
    """Canonical, byte-stable serialization of a sweep result dict.

    Keys sort lexicographically and floats serialize via ``repr`` (exact
    round-trip), so two sweeps are byte-identical iff every cell's
    SimStats match exactly — the contract the worker-count determinism
    tests and the CI bench-smoke lane assert.  Observability fields
    (``compare=False`` on :class:`~repro.flashsim.ssd.SimStats`) are
    excluded, so the bytes are invariant across engine selection,
    worker count, and fusion decisions.
    """
    payload = {
        sweep_cell_key(m, cond, s): _stats_payload(stats)
        for (m, cond, s), stats in results.items()
    }
    return json.dumps(payload, sort_keys=True, indent=1) + "\n"


# -- host fingerprint ------------------------------------------------------


def host_fingerprint() -> Dict[str, object]:
    """CPU model, core count, and interpreter/library versions.

    Recorded alongside every absolute timing in ``BENCH_sim.json`` so a
    number measured on one machine class can no longer masquerade as a
    regression when re-measured on another (the PR 4 incident: a slower
    session machine read as a ~35% engine slowdown).
    """
    cpu_model = None
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.lower().startswith("model name"):
                    cpu_model = line.split(":", 1)[1].strip()
                    break
    except OSError:
        pass
    return {
        "cpu_model": cpu_model or platform.processor() or None,
        "cpu_count": os.cpu_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "numpy": np.__version__,
    }
