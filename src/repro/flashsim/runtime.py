"""Parallel sweep runtime: grid cells scheduled across a process pool.

This is the middle layer of the sharded simulation runtime (ISSUE 5):

  * :mod:`repro.flashsim.engine` — *intra-run* decomposition: one event
    loop per channel behind ``shard=True``, bit-identical to the
    monolithic loop;
  * **this module** — *inter-cell* parallelism: a sweep's
    (mechanism x condition x seed x trace) grid cells are scheduled
    across a process pool with deterministic assembly, so a
    ``workers=4`` sweep returns exactly what ``workers=1`` returns —
    byte-identical once serialized (:func:`sweep_to_json`) — only
    faster;
  * :mod:`repro.flashsim.ssd` — the run APIs' ``workers=`` / ``shard=``
    knobs, which delegate here.

Scheduling unit
---------------
A :class:`Cell` is one schedulable unit.  ``kind="batch"`` cells are the
sweet spot: one *seed group* of a ``simulate_batch`` grid, which keeps
the single-seed trace generation, page-op expansion, and FTL pre-pass
shared across that group's (mechanism x condition) cells inside one
worker — the same sharing ``simulate_batch`` does inline.  ``simulate``
and ``compare`` cells wrap the corresponding run APIs for benchmark
harnesses that sweep per-seed cells directly.

Cache reuse across workers
--------------------------
Workers are forked when the platform allows (``fork`` start method, the
Linux default): a forked worker inherits the parent's process-wide
caches copy-on-write — the content-hash trace cache
(:func:`repro.flashsim.workloads.cached_trace`) and the in-process
characterization memos — so :func:`run_cells` pre-warms every
(condition, mechanism) characterization table in the parent *before*
creating the pool and no worker ever re-enters JAX.  Under ``spawn``
(or any cold worker) the on-disk characterization cache
(``~/.cache/repro_flashsim``, see :mod:`repro.core.characterize`) fills
the same role at a one-read-per-table cost.  Force a start method with
``REPRO_SWEEP_START_METHOD``; force inline execution (no pool, e.g. in
sandboxes without working semaphores) with ``REPRO_SWEEP_INLINE=1``.

Determinism
-----------
Cell *results* never depend on the worker count — each cell runs the
identical code path a ``workers=1`` run executes — and cell *ordering*
is fixed by the caller's input order (:func:`run_cells` returns results
positionally; :func:`run_sweep` assembles its dict in canonical
seed -> condition -> mechanism order).  :func:`sweep_to_json` is the
canonical serialization used by the determinism tests and the CI
bench-smoke lane: byte-identical output for any ``workers``.
"""

from __future__ import annotations

import dataclasses
import json
import multiprocessing
import os
import platform
import threading
from concurrent.futures import BrokenExecutor, ProcessPoolExecutor
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.flashsim.config import DEFAULT_SSD, OperatingCondition, SSDConfig

__all__ = [
    "Cell",
    "host_fingerprint",
    "prewarm_characterization",
    "run_cells",
    "run_compare",
    "run_sweep",
    "sweep_cell_key",
    "sweep_to_json",
]


@dataclasses.dataclass(frozen=True)
class Cell:
    """One schedulable unit of a sweep.

    ``kind`` selects the run API the worker executes:

      * ``"simulate"`` — one (mechanism, condition, seed) run; returns
        a :class:`repro.flashsim.ssd.SimStats`;
      * ``"compare"`` — all ``mechanisms`` over one shared trace
        (:func:`repro.flashsim.ssd.compare_mechanisms`); returns
        ``{mechanism: SimStats}``;
      * ``"batch"`` — one full single-seed ``simulate_batch`` group
        (shares trace/expansion/FTL pre-pass across
        mechanisms x conditions); returns the batch dict.

    Cells must be picklable: ``workload`` is a
    :class:`~repro.flashsim.workloads.Workload`, a registry spec string,
    or a picklable :class:`~repro.flashsim.workloads.TraceSource`.
    """

    kind: str
    workload: object
    conditions: Tuple[OperatingCondition, ...]
    mechanisms: Tuple[str, ...]
    seed: int
    cfg: SSDConfig = DEFAULT_SSD
    n_requests: Optional[int] = None
    engine: str = "array"
    scheduler: Optional[str] = None
    gc: Optional[str] = None
    shard: bool = False

    def __post_init__(self):
        if self.kind not in ("simulate", "compare", "batch"):
            raise ValueError(
                f"Cell.kind must be 'simulate', 'compare' or 'batch', "
                f"got {self.kind!r}"
            )
        if self.kind == "simulate" and len(self.mechanisms) != 1:
            raise ValueError(
                "a 'simulate' cell takes exactly one mechanism, got "
                f"{self.mechanisms!r}"
            )
        if self.kind != "batch" and len(self.conditions) != 1:
            raise ValueError(
                f"a {self.kind!r} cell takes exactly one condition, got "
                f"{len(self.conditions)}"
            )


def _run_cell(cell: Cell):
    """Execute one cell (in a worker or inline) — pure in its argument."""
    from repro.flashsim.ssd import (
        compare_mechanisms,
        simulate,
        simulate_batch,
    )

    if cell.kind == "simulate":
        return simulate(
            cell.workload, cell.conditions[0], cell.mechanisms[0],
            seed=cell.seed, cfg=cell.cfg, n_requests=cell.n_requests,
            engine=cell.engine, scheduler=cell.scheduler, gc=cell.gc,
            shard=cell.shard,
        )
    if cell.kind == "compare":
        return compare_mechanisms(
            cell.workload, cell.conditions[0], mechanisms=cell.mechanisms,
            seed=cell.seed, cfg=cell.cfg, n_requests=cell.n_requests,
            engine=cell.engine, scheduler=cell.scheduler, gc=cell.gc,
            shard=cell.shard,
        )
    return simulate_batch(
        cell.workload, cell.conditions, mechanisms=cell.mechanisms,
        seeds=(cell.seed,), cfg=cell.cfg, n_requests=cell.n_requests,
        engine=cell.engine, scheduler=cell.scheduler, gc=cell.gc,
        shard=cell.shard,
    )


def prewarm_characterization(cells: Iterable[Cell]) -> int:
    """Build every (condition, mechanism) table the cells will touch.

    Called in the parent before the pool is created so forked workers
    inherit warm in-process memos (and never call into JAX themselves);
    under spawn the work instead lands once in the on-disk cache.
    Returns the number of distinct tables touched.
    """
    from repro.core.retry import RetryPolicy
    from repro.flashsim.ssd import SSDSim

    seen = set()
    for cell in cells:
        for cond in cell.conditions:
            for mech in cell.mechanisms:
                key = (cond, mech)
                if key in seen:
                    continue
                seen.add(key)
                SSDSim(cell.cfg, cond, RetryPolicy(mech))
    return len(seen)


def _mp_context():
    method = os.environ.get("REPRO_SWEEP_START_METHOD")
    if not method:
        methods = multiprocessing.get_all_start_methods()
        method = "fork" if "fork" in methods else None
    return multiprocessing.get_context(method)


def _inline_forced() -> bool:
    return os.environ.get("REPRO_SWEEP_INLINE", "0") == "1"


def run_cells(cells: Sequence[Cell], workers: int = 1,
              prewarm: bool = True) -> List:
    """Execute ``cells``; results are returned in input order.

    ``workers <= 1`` runs inline (no pool, no pickling — the exact
    ``workers=1`` code path).  Larger counts fan cells out over a
    process pool; results are still assembled positionally, so the
    output is independent of completion order.  Pool-*infrastructure*
    failures (no semaphores at construction, workers dying —
    ``BrokenExecutor``) fall back to inline execution; an exception
    raised *by a cell itself* propagates unchanged — it would fail
    inline too, so re-running the sweep would only duplicate the work.
    """
    cells = list(cells)
    workers = min(int(workers), len(cells))
    if workers <= 1 or _inline_forced():
        return [_run_cell(c) for c in cells]
    if prewarm:
        prewarm_characterization(cells)
    try:
        pool = ProcessPoolExecutor(max_workers=workers,
                                   mp_context=_mp_context())
    except (OSError, PermissionError):
        # Sandboxed semaphores / fork unavailable: no pool, run inline.
        return [_run_cell(c) for c in cells]
    try:
        with pool:
            futures = [pool.submit(_run_cell, c) for c in cells]
            return [f.result() for f in futures]
    except BrokenExecutor:
        # Workers died underneath us (fork breakage, OOM-killed child):
        # re-run everything inline — identical results, no parallelism.
        return [_run_cell(c) for c in cells]


def run_sweep(
    workload,
    conditions: Iterable[OperatingCondition],
    mechanisms: Sequence[str],
    seeds: Sequence[int],
    cfg: SSDConfig = DEFAULT_SSD,
    n_requests: Optional[int] = None,
    engine: str = "array",
    scheduler: Optional[str] = None,
    gc: Optional[str] = None,
    shard: bool = False,
    workers: int = 1,
) -> Dict[Tuple[str, OperatingCondition, int], "object"]:
    """``simulate_batch`` semantics with seed groups fanned over workers.

    One :class:`Cell` per seed keeps each group's trace / expansion /
    FTL pre-pass shared inside its worker, exactly like the inline
    sweep.  The result dict is assembled in the canonical
    seed -> condition -> mechanism order regardless of worker count, so
    iteration order — and :func:`sweep_to_json` output — is byte-stable.
    """
    conditions = tuple(conditions)
    mechanisms = tuple(mechanisms)
    seeds = tuple(seeds)
    cells = [
        Cell("batch", workload, conditions, mechanisms, s, cfg, n_requests,
             engine, scheduler, gc, shard)
        for s in seeds
    ]
    groups = run_cells(cells, workers=workers)
    out: Dict[Tuple[str, OperatingCondition, int], object] = {}
    for s, group in zip(seeds, groups):
        for cond in conditions:
            for mech in mechanisms:
                out[(mech, cond, s)] = group[(mech, cond, s)]
    return out


# -- compare_mechanisms fan-out -------------------------------------------
#
# Mechanisms of one compare share the trace, the expansion, and (prepass
# GC) the FTL schedule.  Shipping those to workers by pickle would cost
# more than it saves, so the parallel path relies on fork inheritance:
# the parent materializes the shared views in _COMPARE_PAYLOAD, forks the
# pool, and each task reads them back copy-on-write.  Without fork the
# call simply runs inline — correctness never depends on the pool.
# _COMPARE_LOCK serializes the payload's lifetime so concurrent
# compare_mechanisms(..., workers>1) calls from different threads cannot
# fork a pool against each other's views.

_COMPARE_PAYLOAD = None
_COMPARE_LOCK = threading.Lock()


def _run_compare_mech(mechanism: str):
    from repro.core.retry import RetryPolicy
    from repro.flashsim.ssd import SSDSim

    trace, expansion, schedule, cfg, condition, seed, shard = \
        _COMPARE_PAYLOAD
    sim = SSDSim(cfg, condition, RetryPolicy(mechanism), seed=seed + 7)
    return sim.run(trace, expansion=expansion, schedule=schedule,
                   shard=shard)


def run_compare(
    workload,
    condition: OperatingCondition,
    mechanisms: Sequence[str],
    seed: int,
    cfg: SSDConfig,
    n_requests: Optional[int],
    scheduler: Optional[str],
    gc: Optional[str],
    shard: bool,
    workers: int,
) -> Dict[str, "object"]:
    """Parallel ``compare_mechanisms``: one worker per mechanism.

    Requires the ``fork`` start method (shared views are inherited, not
    pickled); otherwise — or on pool failure — falls back to the inline
    run API.  Results match ``compare_mechanisms(..., workers=1)``
    exactly, in the caller's mechanism order.
    """
    global _COMPARE_PAYLOAD
    from repro.flashsim import ssd

    mechanisms = tuple(mechanisms)
    ctx = _mp_context()
    if (workers <= 1 or len(mechanisms) <= 1 or _inline_forced()
            or ctx.get_start_method() != "fork"):
        return ssd.compare_mechanisms(
            workload, condition, mechanisms=mechanisms, seed=seed, cfg=cfg,
            n_requests=n_requests, scheduler=scheduler, gc=gc, shard=shard,
        )
    cfg = ssd._with_knobs(cfg, scheduler, gc)
    trace = ssd.resolve_trace(workload, seed=seed, n_requests=n_requests)
    expansion, schedule = ssd._shared_views(trace, cfg)
    # Materialize the lazy list views now so forked children share them.
    expansion.admission_lists
    if schedule is not None:
        schedule.admission_lists
    prewarm_characterization(
        [Cell("compare", workload, (condition,), mechanisms, seed, cfg)]
    )
    with _COMPARE_LOCK:
        _COMPARE_PAYLOAD = (trace, expansion, schedule, cfg, condition,
                            seed, shard)
        try:
            try:
                pool = ProcessPoolExecutor(
                    max_workers=min(workers, len(mechanisms)),
                    mp_context=ctx,
                )
            except (OSError, PermissionError):
                pool = None
            if pool is None:
                stats = [_run_compare_mech(m) for m in mechanisms]
            else:
                try:
                    with pool:
                        futures = [pool.submit(_run_compare_mech, m)
                                   for m in mechanisms]
                        stats = [f.result() for f in futures]
                except BrokenExecutor:
                    stats = [_run_compare_mech(m) for m in mechanisms]
        finally:
            _COMPARE_PAYLOAD = None
    return dict(zip(mechanisms, stats))


# -- canonical serialization ----------------------------------------------


def sweep_cell_key(mechanism: str, condition: OperatingCondition,
                   seed: int) -> str:
    """Collision-free string key for one sweep cell (JSON dict key).

    Condition floats are rendered with ``repr`` (exact round-trip), so
    two distinct conditions can never collapse to one key.
    """
    return (f"{mechanism}|ret{condition.retention_days!r}"
            f"|pec{condition.pec!r}|seed{seed}")


def sweep_to_json(results: Dict) -> str:
    """Canonical, byte-stable serialization of a sweep result dict.

    Keys sort lexicographically and floats serialize via ``repr`` (exact
    round-trip), so two sweeps are byte-identical iff every cell's
    SimStats match exactly — the contract the worker-count determinism
    tests and the CI bench-smoke lane assert.
    """
    payload = {
        sweep_cell_key(m, cond, s): dataclasses.asdict(stats)
        for (m, cond, s), stats in results.items()
    }
    return json.dumps(payload, sort_keys=True, indent=1) + "\n"


# -- host fingerprint ------------------------------------------------------


def host_fingerprint() -> Dict[str, object]:
    """CPU model, core count, and interpreter/library versions.

    Recorded alongside every absolute timing in ``BENCH_sim.json`` so a
    number measured on one machine class can no longer masquerade as a
    regression when re-measured on another (the PR 4 incident: a slower
    session machine read as a ~35% engine slowdown).
    """
    cpu_model = None
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.lower().startswith("model name"):
                    cpu_model = line.split(":", 1)[1].strip()
                    break
    except OSError:
        pass
    return {
        "cpu_model": cpu_model or platform.processor() or None,
        "cpu_count": os.cpu_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "numpy": np.__version__,
    }
