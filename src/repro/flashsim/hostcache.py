"""Host-side write-back cache for the closed-loop frontend.

:class:`WriteCache` models the controller DRAM write buffer a real host
sees in front of the flash array: incoming writes that fit are *absorbed*
(the request completes at DRAM speed), their page programs are parked in
an eviction-ordered dirty list, and a watermark policy later *flushes*
them to the device, where they enter the ordinary scheduler/GC machinery
as low-priority programs.  Reads that hit a dirty (or still-flushing)
line are served from the cache without touching flash.

Flush (eviction) order is a policy knob
(:attr:`~repro.flashsim.config.HostCacheConfig.eviction`): ``"fifo"``
pops entries in absorption order; ``"lru"`` pops the least-recently-used
entry — read hits (:meth:`WriteCache.touch`) refresh the dirty entries
holding the line, so hot write-then-read lines stay cached longer.  The
policy only permutes *when* each program is issued, never how many:
flush traffic, occupancy accounting, and WA are identical under both.

The class is engine-agnostic and fully synchronous — the event loop in
:mod:`repro.flashsim.engine` drives it and decides *when* pops/completions
happen; this module only owns the bookkeeping contract:

* **Occupancy** counts every absorbed page program from ``absorb()``
  until ``page_durable()`` — dirty *and* in-flight-flush pages both hold
  capacity, so backpressure is honest.
* **Read-after-write**: ``version(lpn)`` always returns the newest
  version in stream order (cached if any copy is resident, else the
  durable one).  Per-page version counters make the durable map
  *landing-order independent* — ``page_durable()`` only advances a line
  to a newer version — so LRU's recency-permuted flush order (which can
  land two programs of one LPN out of stream order) still drains to the
  same durable state as a synchronous replay of the write stream.
* **No coalescing**: re-writing a cached LPN appends a new entry (a new
  program will be issued) rather than merging — each absorbed page-op
  occupies its own slot until it lands, which keeps flush traffic equal
  to absorbed traffic and the capacity accounting trivially auditable.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

from repro.flashsim.config import HostCacheConfig

__all__ = ["CacheEntry", "WriteCache"]


@dataclasses.dataclass(frozen=True)
class CacheEntry:
    """One absorbed write: its page LPNs, their versions, and an opaque
    payload the engine uses to find the deferred device ops."""

    lpns: Tuple[int, ...]
    versions: Tuple[int, ...]
    payload: Any = None


class WriteCache:
    """Page-granular write-back cache with a configurable flush order
    (``fifo`` / ``lru``) and high/low watermarks (see
    :class:`~repro.flashsim.config.HostCacheConfig`)."""

    def __init__(self, cfg: HostCacheConfig):
        self.cfg = cfg
        self.capacity = cfg.capacity_pages
        self.high_mark = cfg.flush_high * cfg.capacity_pages
        self.low_mark = cfg.flush_low * cfg.capacity_pages
        self.lru = cfg.eviction == "lru"
        #: absorbed-but-not-issued page programs
        self.dirty_pages = 0
        #: issued-but-not-durable page programs
        self.flushing_pages = 0
        # Dirty entries in eviction order (head = next to flush).  With
        # no touches this is exactly absorption order, so one structure
        # serves both policies; touch() re-ranks under lru only.
        self._dirty: "OrderedDict[int, CacheEntry]" = OrderedDict()
        self._next_eid = 0
        #: lpn -> ids of dirty entries holding a copy (touch/pop upkeep)
        self._dirty_eids: Dict[int, List[int]] = {}
        #: lpn -> number of resident (dirty or flushing) copies
        self._resident: Dict[int, int] = {}
        #: lpn -> newest absorbed version (monotone per lpn)
        self._latest: Dict[int, int] = {}
        #: lpn -> newest version that has landed on flash
        self.durable: Dict[int, int] = {}
        self._next_version = 1
        # counters (engine copies these into SimStats)
        self.absorbed_writes = 0
        self.absorbed_pages = 0
        self.hit_pages = 0
        self.flush_pages = 0

    # -- occupancy ---------------------------------------------------------

    @property
    def pending_pages(self) -> int:
        """Pages currently holding capacity (dirty + flushing)."""
        return self.dirty_pages + self.flushing_pages

    def fits(self, n_pages: int) -> bool:
        """Could a write of ``n_pages`` EVER be absorbed?  False means the
        caller must fall back to write-through."""
        return n_pages <= self.capacity

    def can_absorb(self, n_pages: int) -> bool:
        return self.pending_pages + n_pages <= self.capacity

    # -- write path --------------------------------------------------------

    def absorb(self, lpns: Sequence[int], payload: Any = None) -> CacheEntry:
        """Absorb one write (its pages become dirty).  Caller must have
        checked :meth:`can_absorb`."""
        if not self.can_absorb(len(lpns)):
            raise RuntimeError("absorb() without capacity — caller bug")
        versions = []
        for lpn in lpns:
            v = self._next_version
            self._next_version += 1
            self._latest[lpn] = v
            self._resident[lpn] = self._resident.get(lpn, 0) + 1
            versions.append(v)
        entry = CacheEntry(tuple(lpns), tuple(versions), payload)
        eid = self._next_eid
        self._next_eid += 1
        self._dirty[eid] = entry            # appended at the MRU end
        for lpn in set(lpns):
            self._dirty_eids.setdefault(lpn, []).append(eid)
        self.dirty_pages += len(lpns)
        self.absorbed_writes += 1
        self.absorbed_pages += len(lpns)
        return entry

    # -- read path ---------------------------------------------------------

    def contains(self, lpn: int) -> bool:
        """Read hit: a dirty or flushing copy of ``lpn`` is resident."""
        return lpn in self._resident

    def version(self, lpn: int) -> Optional[int]:
        """Version a read admitted *now* observes: the newest resident
        copy if cached, else the durable copy (None if never written)."""
        if lpn in self._resident:
            return self._latest[lpn]
        return self.durable.get(lpn)

    def note_hit(self, n_pages: int = 1) -> None:
        self.hit_pages += n_pages

    def touch(self, lpn: int) -> None:
        """Record a read hit's recency: under ``lru``, every dirty entry
        holding ``lpn`` moves to the MRU end (kept in their relative
        order, so per-LPN flush order is preserved); a no-op under
        ``fifo`` and for lines that are flushing-only or absent."""
        if not self.lru:
            return
        for eid in self._dirty_eids.get(lpn, ()):
            self._dirty.move_to_end(eid)

    # -- flush policy ------------------------------------------------------

    def need_flush(self) -> bool:
        """High watermark crossed — start issuing flush entries."""
        return self.dirty_pages > self.high_mark

    def flushed_enough(self) -> bool:
        """Low watermark reached — stop issuing."""
        return self.dirty_pages <= self.low_mark

    def pop_entry(self) -> Optional[CacheEntry]:
        """Next dirty entry in eviction order (absorption order under
        ``fifo``, least-recently-used under ``lru``), moved
        dirty -> flushing; None when clean."""
        if not self._dirty:
            return None
        eid, entry = self._dirty.popitem(last=False)
        for lpn in set(entry.lpns):
            eids = self._dirty_eids[lpn]
            eids.remove(eid)
            if not eids:
                del self._dirty_eids[lpn]
        n = len(entry.lpns)
        self.dirty_pages -= n
        self.flushing_pages += n
        self.flush_pages += n
        return entry

    def drain(self) -> Iterator[CacheEntry]:
        """Pop every remaining dirty entry (end-of-trace drain)."""
        while self._dirty:
            yield self.pop_entry()

    def page_durable(self, lpn: int, version: int) -> None:
        """One flushed page program completed on the die: free its slot,
        update the durable map, evict the line if no newer copy exists."""
        self.flushing_pages -= 1
        if self.flushing_pages < 0:
            raise RuntimeError("page_durable() without a flush in flight")
        if version >= self.durable.get(lpn, -1):
            self.durable[lpn] = version
        rc = self._resident[lpn] - 1
        if rc:
            self._resident[lpn] = rc
        else:
            del self._resident[lpn]
            del self._latest[lpn]
