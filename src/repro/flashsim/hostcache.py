"""Host-side write-back cache for the closed-loop frontend.

:class:`WriteCache` models the controller DRAM write buffer a real host
sees in front of the flash array: incoming writes that fit are *absorbed*
(the request completes at DRAM speed), their page programs are parked in
an admission-order FIFO, and a watermark policy later *flushes* them to
the device, where they enter the ordinary scheduler/GC machinery as
low-priority programs.  Reads that hit a dirty (or still-flushing) line
are served from the cache without touching flash.

The class is engine-agnostic and fully synchronous — the event loop in
:mod:`repro.flashsim.engine` drives it and decides *when* pops/completions
happen; this module only owns the bookkeeping contract:

* **Occupancy** counts every absorbed page program from ``absorb()``
  until ``page_durable()`` — dirty *and* in-flight-flush pages both hold
  capacity, so backpressure is honest.
* **Read-after-write**: ``version(lpn)`` always returns the newest
  version in stream order (cached if any copy is resident, else the
  durable one), and FIFO flushing preserves per-LPN program order, so the
  durable state after a full drain equals a synchronous replay of the
  write stream.
* **No coalescing**: re-writing a cached LPN appends a new entry (a new
  program will be issued) rather than merging — each absorbed page-op
  occupies its own slot until it lands, which keeps flush traffic equal
  to absorbed traffic and the capacity accounting trivially auditable.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Deque, Dict, Iterator, Optional, Sequence, Tuple

from repro.flashsim.config import HostCacheConfig

__all__ = ["CacheEntry", "WriteCache"]


@dataclasses.dataclass(frozen=True)
class CacheEntry:
    """One absorbed write: its page LPNs, their versions, and an opaque
    payload the engine uses to find the deferred device ops."""

    lpns: Tuple[int, ...]
    versions: Tuple[int, ...]
    payload: Any = None


class WriteCache:
    """Page-granular write-back cache with FIFO flush order and
    high/low watermarks (see :class:`~repro.flashsim.config.
    HostCacheConfig`)."""

    def __init__(self, cfg: HostCacheConfig):
        self.cfg = cfg
        self.capacity = cfg.capacity_pages
        self.high_mark = cfg.flush_high * cfg.capacity_pages
        self.low_mark = cfg.flush_low * cfg.capacity_pages
        #: absorbed-but-not-issued page programs
        self.dirty_pages = 0
        #: issued-but-not-durable page programs
        self.flushing_pages = 0
        self._fifo: Deque[CacheEntry] = deque()
        #: lpn -> number of resident (dirty or flushing) copies
        self._resident: Dict[int, int] = {}
        #: lpn -> newest absorbed version (monotone per lpn)
        self._latest: Dict[int, int] = {}
        #: lpn -> newest version that has landed on flash
        self.durable: Dict[int, int] = {}
        self._next_version = 1
        # counters (engine copies these into SimStats)
        self.absorbed_writes = 0
        self.absorbed_pages = 0
        self.hit_pages = 0
        self.flush_pages = 0

    # -- occupancy ---------------------------------------------------------

    @property
    def pending_pages(self) -> int:
        """Pages currently holding capacity (dirty + flushing)."""
        return self.dirty_pages + self.flushing_pages

    def fits(self, n_pages: int) -> bool:
        """Could a write of ``n_pages`` EVER be absorbed?  False means the
        caller must fall back to write-through."""
        return n_pages <= self.capacity

    def can_absorb(self, n_pages: int) -> bool:
        return self.pending_pages + n_pages <= self.capacity

    # -- write path --------------------------------------------------------

    def absorb(self, lpns: Sequence[int], payload: Any = None) -> CacheEntry:
        """Absorb one write (its pages become dirty).  Caller must have
        checked :meth:`can_absorb`."""
        if not self.can_absorb(len(lpns)):
            raise RuntimeError("absorb() without capacity — caller bug")
        versions = []
        for lpn in lpns:
            v = self._next_version
            self._next_version += 1
            self._latest[lpn] = v
            self._resident[lpn] = self._resident.get(lpn, 0) + 1
            versions.append(v)
        entry = CacheEntry(tuple(lpns), tuple(versions), payload)
        self._fifo.append(entry)
        self.dirty_pages += len(lpns)
        self.absorbed_writes += 1
        self.absorbed_pages += len(lpns)
        return entry

    # -- read path ---------------------------------------------------------

    def contains(self, lpn: int) -> bool:
        """Read hit: a dirty or flushing copy of ``lpn`` is resident."""
        return lpn in self._resident

    def version(self, lpn: int) -> Optional[int]:
        """Version a read admitted *now* observes: the newest resident
        copy if cached, else the durable copy (None if never written)."""
        if lpn in self._resident:
            return self._latest[lpn]
        return self.durable.get(lpn)

    def note_hit(self, n_pages: int = 1) -> None:
        self.hit_pages += n_pages

    # -- flush policy ------------------------------------------------------

    def need_flush(self) -> bool:
        """High watermark crossed — start issuing flush entries."""
        return self.dirty_pages > self.high_mark

    def flushed_enough(self) -> bool:
        """Low watermark reached — stop issuing."""
        return self.dirty_pages <= self.low_mark

    def pop_entry(self) -> Optional[CacheEntry]:
        """Oldest dirty entry, moved dirty -> flushing; None when clean."""
        if not self._fifo:
            return None
        entry = self._fifo.popleft()
        n = len(entry.lpns)
        self.dirty_pages -= n
        self.flushing_pages += n
        self.flush_pages += n
        return entry

    def drain(self) -> Iterator[CacheEntry]:
        """Pop every remaining dirty entry (end-of-trace drain)."""
        while self._fifo:
            yield self.pop_entry()

    def page_durable(self, lpn: int, version: int) -> None:
        """One flushed page program completed on the die: free its slot,
        update the durable map, evict the line if no newer copy exists."""
        self.flushing_pages -= 1
        if self.flushing_pages < 0:
            raise RuntimeError("page_durable() without a flush in flight")
        if version >= self.durable.get(lpn, -1):
            self.durable[lpn] = version
        rc = self._resident[lpn] - 1
        if rc:
            self._resident[lpn] = rc
        else:
            del self._resident[lpn]
            del self._latest[lpn]
