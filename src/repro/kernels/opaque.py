"""Opaque kernel stand-ins for the CPU dry-run (hillclimb measurement).

On a real TPU, a `pl.pallas_call` lowers to one opaque custom-call whose
HBM traffic is exactly its operands + results — the fused intermediate
tiles live in VMEM and never appear in the HLO.  This container has no
TPU, so optimized variants substitute a `jax.pure_callback` stand-in:
also a single custom-call with the same operands/results, hence the same
(honest) roofline bytes.  FLOPs for these calls are supplied analytically
by launch/hlo_cost.py, which identifies each call through a *marker*
output (a tiny f32 vector whose length encodes kernel + static config) —
pure_callback erases the callee name, the marker survives.

Stand-ins are active only when REPRO_OPAQUE_KERNELS=1 (set by
``dryrun.py --opt``); on TPU the real Pallas kernels take this code path
instead; everywhere else callers fall back to the pure-jnp reference
implementations, which the kernels are allclose-validated against.

Marker registry (length of the marker vector):
  101            flash attention fwd, causal
  102            flash attention bwd, causal
  103            flash attention fwd, bidirectional/cross
  104            flash attention bwd, bidirectional/cross
  401            fused decode attention, bf16 KV
  402            fused decode attention, int8 KV (the AR² fast-read)
  10000 + w      windowed flash fwd, window w
  20000 + w      windowed flash bwd, window w
  30000 + L      ssd chunked scan fwd, chunk L
  40000 + L      ssd chunked scan bwd, chunk L
"""

from __future__ import annotations

import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

M_FLASH_FWD_CAUSAL = 101
M_FLASH_BWD_CAUSAL = 102
M_FLASH_FWD_FULL = 103
M_FLASH_BWD_FULL = 104
M_DECODE_BF16 = 401
M_DECODE_INT8 = 402
M_WINDOW_FWD_BASE = 10000
M_WINDOW_BWD_BASE = 20000
M_SSD_FWD_BASE = 30000
M_SSD_BWD_BASE = 40000


def opaque_mode() -> bool:
    return os.environ.get("REPRO_OPAQUE_KERNELS", "0") == "1"


def _axis_size(mesh, m) -> int:
    size = 1
    for ax in (m if isinstance(m, tuple) else (m,)):
        size *= mesh.shape[ax]
    return size


def _spec_for(shape, axes, mesh, rules):
    """Logical axes -> PartitionSpec with the same divisibility/duplicate
    guards as sharding.constrain (so the stand-in shards exactly like the
    surrounding activations — no gathers at the call boundary)."""
    from jax.sharding import PartitionSpec as P

    parts, used = [], set()
    for dim, a in enumerate(axes):
        m = rules.get(a) if a else None
        if m:
            m_t = m if isinstance(m, tuple) else (m,)
            if shape[dim] % _axis_size(mesh, m) == 0 and not (used & set(m_t)):
                parts.append(m)
                used.update(m_t)
                continue
        parts.append(None)
    return P(*parts)


def _local_shape(shape, spec, mesh):
    out = []
    for dim, m in enumerate(tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))):
        out.append(shape[dim] // (_axis_size(mesh, m) if m else 1))
    return tuple(out)


def _call(
    marker: int,
    result_specs: Sequence[jax.ShapeDtypeStruct],
    *args,
    args_axes=None,
    result_axes=None,
):
    """One custom-call with a marker output; results are zeros (the dry-run
    never executes; numerics come from the real kernel / reference path).

    When a mesh context is active (the production dry-run), the call is
    wrapped in shard_map with specs derived from ``args_axes`` so operands
    stay sharded — a pallas_call on TPU partitions the same way; without
    this, XLA would all-gather every operand to feed the callback.
    """
    from repro.distributed import sharding as SH

    mesh = SH.current_mesh()
    marker_spec = jax.ShapeDtypeStruct((marker,), jnp.float32)

    if mesh is None or args_axes is None:
        specs = tuple(result_specs) + (marker_spec,)

        def host_impl(*xs):
            return tuple(np.zeros(s.shape, s.dtype) for s in specs)

        outs = jax.pure_callback(
            host_impl, specs, *args, vmap_method="sequential"
        )
        return outs[:-1]

    rules = SH.current_rules() or SH.rules_for_mesh(mesh)
    in_specs = tuple(
        _spec_for(a.shape, ax, mesh, rules) for a, ax in zip(args, args_axes)
    )
    out_specs_np = tuple(
        _spec_for(r.shape, ax, mesh, rules)
        for r, ax in zip(result_specs, result_axes)
    )
    from jax.sharding import PartitionSpec as P

    local_specs = tuple(
        jax.ShapeDtypeStruct(_local_shape(r.shape, sp, mesh), r.dtype)
        for r, sp in zip(result_specs, out_specs_np)
    ) + (marker_spec,)

    def body(*xs):
        def host_impl(*ys):
            return tuple(np.zeros(s.shape, s.dtype) for s in local_specs)

        return jax.pure_callback(
            host_impl, local_specs, *xs, vmap_method="sequential"
        )

    outs = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs_np + (P(),),
        check_vma=False,
    )(*args)
    return outs[:-1]


# ---------------------------------------------------------------------------
# Flash attention (full-sequence): custom_vjp so train cells stay opaque.
# ---------------------------------------------------------------------------


def _fwd_marker(causal: bool, window) -> int:
    if window is not None:
        return M_WINDOW_FWD_BASE + int(window)
    return M_FLASH_FWD_CAUSAL if causal else M_FLASH_FWD_FULL


def _bwd_marker(causal: bool, window) -> int:
    if window is not None:
        return M_WINDOW_BWD_BASE + int(window)
    return M_FLASH_BWD_CAUSAL if causal else M_FLASH_BWD_FULL


#: Query layout: context-parallel — the query sequence shards over the
#: "model" axis ("act_seq" rule).  Heads rarely divide the 16-way model
#: axis (8 kv-heads / 24..56 q-heads across the assigned archs), so
#: head-TP would replicate attention compute 16x; sequence-sharding keeps
#: every rank busy on T/16 queries instead.  K/V replicate over "model"
#: inside the kernel region (the entry all-gather is real, counted
#: traffic); a windowed kernel only needs a halo exchange instead — the
#: stand-in conservatively charges the full gather.
_Q_AXES = ("batch", "act_seq", "kv_heads", None, None)
_KV_AXES = ("batch", None, "kv_heads", None)


def make_flash_opaque(causal: bool, window):
    """(q (B,T,K,G,hd), k/v (B,S,K,hd)) -> o (B,T,K,G,hd), opaque."""

    @jax.custom_vjp
    def flash(q, k, v):
        (o,) = _call(
            _fwd_marker(causal, window),
            [jax.ShapeDtypeStruct(q.shape, q.dtype)],
            q, k, v,
            args_axes=(_Q_AXES, _KV_AXES, _KV_AXES),
            result_axes=(_Q_AXES,),
        )
        return o

    def fwd(q, k, v):
        return flash(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        dq, dk, dv = _call(
            _bwd_marker(causal, window),
            [
                jax.ShapeDtypeStruct(q.shape, q.dtype),
                jax.ShapeDtypeStruct(k.shape, k.dtype),
                jax.ShapeDtypeStruct(v.shape, v.dtype),
            ],
            q, k, v, g,
            args_axes=(_Q_AXES, _KV_AXES, _KV_AXES, _Q_AXES),
            result_axes=(_Q_AXES, _KV_AXES, _KV_AXES),
        )
        return dq, dk, dv

    flash.defvjp(fwd, bwd)
    return flash


# ---------------------------------------------------------------------------
# Fused decode attention (KV read + attend, optionally int8 fast-tier).
# ---------------------------------------------------------------------------


def decode_attention_opaque(q, ck, cv, valid_len, *, int8: bool,
                            scales=None):
    """q (B,1,K,G,hd); ck/cv (B,K,S,hd) [int8 when int8=True, with
    per-page scales (B,K,S,1)] -> o (B,1,K,G,hd).

    The int8 variant is the AR² fast read: the wire/HBM format is 1 B/elt
    plus scales; margin-failing pages re-read from backing *inside* the
    kernel (the PR²-overlapped retry), so the call's operand bytes are the
    honest fast-path traffic."""
    B, _, K, G, hd = q.shape
    marker = M_DECODE_INT8 if int8 else M_DECODE_BF16
    cache_axes = ("batch", "kv_heads", "kv_seq", None)
    args = [q, ck, cv]
    axes = [_Q_AXES, cache_axes, cache_axes]
    if int8:
        args += list(scales)
        axes += [cache_axes, cache_axes]
    args.append(jnp.asarray(valid_len, jnp.int32))
    axes.append(())
    (o,) = _call(
        marker, [jax.ShapeDtypeStruct(q.shape, q.dtype)], *args,
        args_axes=tuple(axes), result_axes=(_Q_AXES,),
    )
    # NB: with the KV sequence sharded over "model", the real kernel adds
    # one tiny partial-softmax combine (an all-reduce of (B,K,G,hd)+stats,
    # ~KBs); omitted from the stand-in's accounting as negligible.
    return o


# ---------------------------------------------------------------------------
# SSD chunked scan.
# ---------------------------------------------------------------------------


def make_ssd_opaque(chunk: int):
    x_axes = ("batch", None, "heads", None)
    bc_axes = ("batch", None, None)
    dt_axes = ("batch", None, "heads")
    h_axes = ("batch", "heads", None, None)

    @jax.custom_vjp
    def ssd(x, Bm, Cm, dt, A):
        B, T, nh, hd = x.shape
        ds = Bm.shape[-1]
        o_spec = jax.ShapeDtypeStruct(x.shape, x.dtype)
        h_spec = jax.ShapeDtypeStruct((B, nh, hd, ds), jnp.float32)
        o, H = _call(
            M_SSD_FWD_BASE + chunk, [o_spec, h_spec], x, Bm, Cm, dt, A,
            args_axes=(x_axes, bc_axes, bc_axes, dt_axes, (None,)),
            result_axes=(x_axes, h_axes),
        )
        return o, H

    def fwd(x, Bm, Cm, dt, A):
        return ssd(x, Bm, Cm, dt, A), (x, Bm, Cm, dt, A)

    def bwd(res, g):
        x, Bm, Cm, dt, A = res
        go, _ = g
        all_axes = (x_axes, bc_axes, bc_axes, dt_axes, (None,))
        outs = _call(
            M_SSD_BWD_BASE + chunk,
            [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in res],
            x, Bm, Cm, dt, A, go,
            args_axes=all_axes + (x_axes,),
            result_axes=all_axes,
        )
        return tuple(outs)

    ssd.defvjp(fwd, bwd)
    return ssd
