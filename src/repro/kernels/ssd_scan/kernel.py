"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid: (BH, nc) with the chunk dimension innermost and *arbitrary*
(sequential) semantics: the inter-chunk recurrent state H (ds, hd) lives
in VMEM scratch and persists across the chunk steps of one (b, h) cell.

Per chunk of length L the kernel computes (all f32 in VMEM):

  scores  = C @ B^T                          (L, ds) @ (ds, L) -> MXU
  y_intra = (scores * decay * tril) @ (x*dt) (L, L) @ (L, hd)  -> MXU
  y_inter = (C @ H) * exp(cum)               (L, ds) @ (ds, hd)-> MXU
  S       = B^T @ (x * dt * seg)             (ds, L) @ (L, hd) -> MXU
  H      <- H * exp(total) + S

which is exactly the state-space-duality evaluation order of Dao & Gu
(arXiv:2405.21060) — quadratic attention-like form inside the chunk,
linear recurrence across chunks.  MXU dims are hardware-aligned for the
assigned config (L = 256, ds = 128, hd = 64).

The decay factors come in pre-multiplied as dA = dt * A (per head), so the
kernel touches only 2-D tiles; cumulative sums are plain vector ops.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions; take
# whichever this installation provides.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

CLIP = -60.0  # exp underflow guard, matches the jnp oracle


def _ssd_kernel(
    x_ref, b_ref, c_ref, dt_ref, da_ref,   # VMEM tiles
    y_ref, h_out_ref,                       # outputs
    h_scr,                                  # (ds, hd) f32 scratch carry
    *,
    L: int,
    nc: int,
):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0].astype(jnp.float32)        # (L, hd)
    Bm = b_ref[0].astype(jnp.float32)       # (L, ds)
    Cm = c_ref[0].astype(jnp.float32)       # (L, ds)
    dt = dt_ref[0].astype(jnp.float32)      # (L,)
    dA = da_ref[0].astype(jnp.float32)      # (L,) = dt * A  (<= 0)

    cum = jnp.cumsum(dA)                    # (L,)
    total = cum[-1]

    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                        # (L, L)
    li = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.exp(jnp.clip(cum[:, None] - cum[None, :], CLIP, 0.0))
    w = jnp.where(lj <= li, scores * decay, 0.0)

    xdt = x * dt[:, None]                    # (L, hd)
    y = jax.lax.dot_general(
        w, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # carried-state contribution
    ch = jax.lax.dot_general(
        Cm, h_scr[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                        # (L, hd)
    y = y + ch * jnp.exp(jnp.clip(cum, CLIP, 0.0))[:, None]
    y_ref[0] = y.astype(y_ref.dtype)

    # chunk summary + recurrence
    seg = jnp.exp(jnp.clip(total - cum, CLIP, 0.0))  # (L,)
    S = jax.lax.dot_general(
        Bm, xdt * seg[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                        # (ds, hd)
    h_scr[...] = h_scr[...] * jnp.exp(jnp.clip(total, CLIP, 0.0)) + S

    @pl.when(ci == nc - 1)
    def _emit_state():
        h_out_ref[0] = h_scr[...]


def ssd_scan_fwd(
    x: jax.Array,      # (BH, T, hd) — head-major
    Bm: jax.Array,     # (BH, T, ds)
    Cm: jax.Array,     # (BH, T, ds)
    dt: jax.Array,     # (BH, T)  post-softplus step sizes
    dA: jax.Array,     # (BH, T)  dt * A per head (negative)
    *,
    chunk: int = 256,
    interpret: bool = False,
):
    """Returns (y: (BH, T, hd), H: (BH, ds, hd) f32)."""
    BH, T, hd = x.shape
    ds = Bm.shape[-1]
    L = min(chunk, T)
    Tp = -(-T // L) * L
    if Tp != T:
        # dA pad of 0 => exp(0) decay 1, but dt pad of 0 zeroes the token's
        # contribution, so padded tokens are inert.
        x = jnp.pad(x, ((0, 0), (0, Tp - T), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, Tp - T), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, Tp - T), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, Tp - T)))
        dA = jnp.pad(dA, ((0, 0), (0, Tp - T)))
    nc = Tp // L

    kernel = functools.partial(_ssd_kernel, L=L, nc=nc)
    y, H = pl.pallas_call(
        kernel,
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, L, hd), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, L, ds), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, L, ds), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, L), lambda bh, c: (bh, c)),
            pl.BlockSpec((1, L), lambda bh, c: (bh, c)),
        ],
        out_specs=[
            pl.BlockSpec((1, L, hd), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, ds, hd), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, Tp, hd), x.dtype),
            jax.ShapeDtypeStruct((BH, ds, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((ds, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, Bm, Cm, dt, dA)
    return y[:, :T], H
