"""Pure-jnp oracle for the SSD chunked-scan kernel.

Evaluates the *sequential* (unchunked) state-space recurrence directly —
the ground truth both the chunked jnp path (models/ssm.ssd_chunked) and
the Pallas kernel must reproduce:

  H_t = H_{t-1} * exp(dt_t * A) + dt_t * x_t B_t^T
  y_t = C_t H_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(
    x: jax.Array,      # (BH, T, hd)
    Bm: jax.Array,     # (BH, T, ds)
    Cm: jax.Array,     # (BH, T, ds)
    dt: jax.Array,     # (BH, T)
    dA: jax.Array,     # (BH, T) = dt * A
):
    """Returns (y: (BH, T, hd), H: (BH, ds, hd) f32)."""
    xf = x.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    dAf = dA.astype(jnp.float32)

    def step(H, inp):
        xt, bt, ct, dtt, dat = inp          # (BH,hd) (BH,ds) (BH,ds) (BH,) (BH,)
        g = jnp.exp(jnp.clip(dat, -60.0, 0.0))[:, None, None]
        H = H * g + jnp.einsum("bd,bh,b->bdh", bt, xt, dtt)
        y = jnp.einsum("bd,bdh->bh", ct, H)
        return H, y

    BH, T, hd = x.shape
    ds = Bm.shape[-1]
    H0 = jnp.zeros((BH, ds, hd), jnp.float32)
    H, ys = jax.lax.scan(
        step,
        H0,
        (
            jnp.moveaxis(xf, 1, 0), jnp.moveaxis(Bf, 1, 0),
            jnp.moveaxis(Cf, 1, 0), jnp.moveaxis(dtf, 1, 0),
            jnp.moveaxis(dAf, 1, 0),
        ),
    )
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), H
