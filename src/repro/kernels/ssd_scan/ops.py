"""Jitted wrapper: model-layout adapter + backend dispatch for ssd_scan."""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_fwd


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,      # (B, T, nh, hd) — model layout
    Bm: jax.Array,     # (B, T, ds)     shared across heads (ngroups=1)
    Cm: jax.Array,     # (B, T, ds)
    dt: jax.Array,     # (B, T, nh)     post-softplus
    A: jax.Array,      # (nh,)          negative per-head decay
    chunk: int = 256,
    interpret: bool | None = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y: (B, T, nh, hd), H: (B, nh, hd, ds) f32) — the exact
    interface of models/ssm.ssd_chunked, Pallas-backed."""
    B, T, nh, hd = x.shape
    ds = Bm.shape[-1]
    xh = x.transpose(0, 2, 1, 3).reshape(B * nh, T, hd)
    dth = dt.transpose(0, 2, 1).reshape(B * nh, T)
    dAh = dth * jnp.tile(A.astype(dth.dtype), B)[:, None]
    Bh = jnp.broadcast_to(Bm[:, None], (B, nh, T, ds)).reshape(B * nh, T, ds)
    Ch = jnp.broadcast_to(Cm[:, None], (B, nh, T, ds)).reshape(B * nh, T, ds)
    y, H = ssd_scan_fwd(
        xh, Bh, Ch, dth, dAh,
        chunk=chunk,
        interpret=_use_interpret() if interpret is None else interpret,
    )
    y = y.reshape(B, nh, T, hd).transpose(0, 2, 1, 3)
    H = H.reshape(B, nh, ds, hd).transpose(0, 1, 3, 2)  # -> (B, nh, hd, ds)
    return y, H
