"""Pallas TPU kernel for the 160-chip RBER characterization hot loop.

The characterization sweeps RBER over (pages x retry-table entries); at
population scale that is ~10^5 pages x 41 entries x 7 boundaries x 3 page
types of Q-function evaluations per (retention, P/E, tR-scale) condition
— the dominant compute of the paper's §3 study and of AR²'s table build.

Grid: (N / bn, S / bs).  Each step loads a (bn, 8) slice of the level
means/sigmas and a (bs, 7) slice of the retry table into VMEM, evaluates
all 7 boundary error integrals on the VPU (erfc), and writes the three
page-type outputs as (bn, bs) tiles.  bn x bs tiles are (8,128)-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions; take
# whichever this installation provides.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

from repro.kernels.rber.ref import PAGE_MASKS


def _rber_kernel(mu_ref, sigma_ref, lvl_ref, lsb_ref, csb_ref, msb_ref, *,
                 bn: int, bs: int):
    mu = mu_ref[...]          # (bn, 8)
    sig = sigma_ref[...]      # (bn, 8)
    lvl = lvl_ref[...]        # (bs, 7)
    inv_sqrt2 = 0.7071067811865475

    outs = [jnp.zeros((bn, bs), jnp.float32) for _ in range(3)]
    masks = [tuple(row) for row in PAGE_MASKS.tolist()]
    for b in range(7):
        m_lo = mu[:, b][:, None]          # (bn, 1)
        m_hi = mu[:, b + 1][:, None]
        s_lo = sig[:, b][:, None]
        s_hi = sig[:, b + 1][:, None]
        L = lvl[:, b][None, :]            # (1, bs)
        up = 0.5 * jax.lax.erfc((L - m_lo) / s_lo * inv_sqrt2)
        dn = 0.5 * jax.lax.erfc((m_hi - L) / s_hi * inv_sqrt2)
        e = (up + dn) * 0.125             # (bn, bs)
        for p in range(3):
            if masks[p][b]:
                outs[p] = outs[p] + e
    lsb_ref[...], csb_ref[...], msb_ref[...] = outs


def rber_pallas(mu, sigma, levels, *, bn: int = 256, bs: int = 128,
                interpret: bool = False):
    """mu, sigma: (N, 8); levels: (S, 7) -> (3, N, S) float32."""
    N = mu.shape[0]
    S = levels.shape[0]
    bn = min(bn, max(8, N))
    bs = min(bs, max(1, S))
    Np = -(-N // bn) * bn
    Sp = -(-S // bs) * bs
    if Np != N:
        pad = Np - N
        mu = jnp.pad(mu, ((0, pad), (0, 0)), constant_values=1.0)
        sigma = jnp.pad(sigma, ((0, pad), (0, 0)), constant_values=1.0)
    if Sp != S:
        levels = jnp.pad(levels, ((0, Sp - S), (0, 0)), constant_values=0.0)

    kernel = functools.partial(_rber_kernel, bn=bn, bs=bs)
    out_shape = [jax.ShapeDtypeStruct((Np, Sp), jnp.float32)] * 3
    lsb, csb, msb = pl.pallas_call(
        kernel,
        grid=(Np // bn, Sp // bs),
        in_specs=[
            pl.BlockSpec((bn, 8), lambda ni, si: (ni, 0)),
            pl.BlockSpec((bn, 8), lambda ni, si: (ni, 0)),
            pl.BlockSpec((bs, 7), lambda ni, si: (si, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bs), lambda ni, si: (ni, si)),
            pl.BlockSpec((bn, bs), lambda ni, si: (ni, si)),
            pl.BlockSpec((bn, bs), lambda ni, si: (ni, si)),
        ],
        out_shape=out_shape,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(mu, sigma, levels)
    return jnp.stack([lsb[:N, :S], csb[:N, :S], msb[:N, :S]])
