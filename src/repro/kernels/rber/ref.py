"""Oracle for the RBER characterization kernel (pure jnp)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

# TLC 2-3-2 page-type masks over the 7 boundaries (lsb, csb, msb).
PAGE_MASKS = jnp.asarray(
    [
        [1, 0, 0, 0, 1, 0, 0],
        [0, 1, 0, 1, 0, 1, 0],
        [0, 0, 1, 0, 0, 0, 1],
    ],
    jnp.float32,
)


def qfunc(x):
    return 0.5 * jax.lax.erfc(x / jnp.sqrt(2.0).astype(x.dtype))


def rber_ref(mu, sigma, levels):
    """RBER per page x retry entry x page type.

    mu, sigma: (N, 8); levels: (S, 7) -> (3, N, S).
    """
    m_lo = mu[:, None, :-1]          # (N, 1, 7)
    m_hi = mu[:, None, 1:]
    s_lo = sigma[:, None, :-1]
    s_hi = sigma[:, None, 1:]
    L = levels[None, :, :]           # (1, S, 7)
    up = qfunc((L - m_lo) / s_lo)
    dn = qfunc((m_hi - L) / s_hi)
    per_boundary = (up + dn) / 8.0   # (N, S, 7)
    return jnp.einsum("nsb,pb->pns", per_boundary, PAGE_MASKS)
