"""Jitted wrapper for the RBER characterization kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.rber.kernel import rber_pallas
from repro.kernels.rber.ref import rber_ref


@functools.partial(jax.jit, static_argnames=("interpret",))
def rber_table(mu, sigma, levels, interpret=None):
    """(N,8),(N,8),(S,7) -> (3,N,S); Pallas on TPU, interpret elsewhere."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return rber_pallas(mu, sigma, levels, interpret=interpret)
