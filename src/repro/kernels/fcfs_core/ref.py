"""Plain-Python reference for the lockstep FCFS shard core.

Implements the same bounded-stream-merge algorithm as the Pallas kernel
(:mod:`repro.kernels.fcfs_core.kernel`) — per-die single event slot,
write-transfer FIFO, admission cursor, explicit seq counters — one lane
at a time, with the identical float arithmetic (Python floats are IEEE
f64, and every add/max is written in the interpreter's association
order).  Used by the parity tests to pin the kernel bit-for-bit, and as
the unbatched fallback oracle.
"""

from __future__ import annotations

import numpy as np

_INF = float("inf")


def fcfs_core_ref(ops: np.ndarray, n_dies: int, pipelined: bool,
                  tdma: float, tecc: float):
    """Run the shard core per lane in pure Python.

    ``ops``: (L, MAXP, 6) f64 — [arrival, kind, die, dur, attempts, tr],
    admission order per lane, padded rows with ``arrival == inf``.
    Returns ``(fin, diestat, lane)`` with the same shapes/meaning as
    :func:`repro.kernels.fcfs_core.kernel.fcfs_core_fwd`.
    """
    L, maxp, _ = ops.shape
    fin = np.zeros((L, maxp + 1), dtype=np.float64)
    diestat = np.zeros((L, n_dies, 2), dtype=np.float64)
    lane = np.zeros((L, 4), dtype=np.float64)

    for l in range(L):
        arr = ops[l, :, 0]
        kind = ops[l, :, 1]
        die = np.where(np.isfinite(ops[l, :, 2]),
                       ops[l, :, 2], 0.0).astype(np.int64)
        dur = ops[l, :, 3]
        att = ops[l, :, 4]
        tr = ops[l, :, 5]
        n_adm = int((kind != 3.0).sum())   # pads are trailing

        ev_t = [_INF] * n_dies
        ev_seq = [0.0] * n_dies
        ev_op = [0] * n_dies
        ev_kind = [0] * n_dies      # 0=sense/copy, 1=release
        held = [0.0] * n_dies
        free = [True] * n_dies
        rem = [0.0] * n_dies
        a_act = [0.0] * n_dies
        tr_act = [0.0] * n_dies
        tot = [0.0] * n_dies
        busy = [0.0] * n_dies
        fifo: list = [[] for _ in range(n_dies)]
        acq: list = []              # (done, seq, op) in push order
        aq_head = 0

        chb = 0.0
        ch_tot = 0.0
        seqc = 0.0
        n_ev = 0.0
        ai = 0

        def grant(d: int, o: int, tm: float) -> None:
            nonlocal seqc
            held[d] = tm
            free[d] = False
            ev_op[d] = o
            ev_seq[d] = seqc
            if kind[o] == 0.0:
                ev_t[d] = tm + tr[o]
                ev_kind[d] = 0
                rem[d] = 0.0 if pipelined else att[o]
                a_act[d] = att[o]
                tr_act[d] = tr[o]
            else:                   # write program or erase
                ev_t[d] = tm + dur[o]
                ev_kind[d] = 1
            seqc += 1.0

        while True:
            # candidate: min (time, seq) over die slots + ACQ head
            tmin, smin, widx = _INF, _INF, -1
            for d in range(n_dies):
                if ev_t[d] < tmin or (ev_t[d] == tmin and ev_seq[d] < smin):
                    tmin, smin, widx = ev_t[d], ev_seq[d], d
            if aq_head < len(acq):
                at, asq, _ = acq[aq_head]
                if at < tmin or (at == tmin and asq < smin):
                    tmin, smin, widx = at, asq, n_dies
            adm_t = arr[ai] if ai < n_adm else _INF
            if adm_t == _INF and tmin == _INF:
                break

            if adm_t <= tmin:       # admission wins ties
                o = ai
                tm = adm_t
                ai += 1
                k = kind[o]
                if k == 1.0:        # write: channel transfer now
                    done = (chb if chb > tm else tm) + tdma
                    chb = done
                    ch_tot += tdma
                    acq.append((done, seqc, o))
                    seqc += 1.0
                else:               # read or erase: contend for the die
                    d = die[o]
                    if free[d] and not fifo[d]:
                        grant(d, o, tm)
                    else:
                        fifo[d].append(o)
                continue

            n_ev += 1.0
            if widx == n_dies:      # ACQ: write transfer landed
                tm, _, o = acq[aq_head]
                aq_head += 1
                d = die[o]
                if free[d] and not fifo[d]:
                    grant(d, o, tm)
                else:
                    fifo[d].append(o)
                continue

            d = widx
            tm = ev_t[d]
            o = ev_op[d]
            if ev_kind[d] == 0:     # sense done / pipelined copy
                done = (chb if chb > tm else tm) + tdma
                chb = done
                ch_tot += tdma
                if not pipelined:
                    r = rem[d] - 1.0
                    if r:
                        rem[d] = r
                        ev_t[d] = (done + tecc) + tr_act[d]
                    else:
                        fin[l, o] = done + tecc
                        ev_t[d] = done
                        ev_kind[d] = 1
                else:
                    i = rem[d]
                    if i + 1.0 < a_act[d]:
                        rem[d] = i + 1.0
                        tnext = tm + tr_act[d]
                        if done > tnext:
                            tnext = done
                        ev_t[d] = tnext
                    else:
                        fin[l, o] = done + tecc
                        ev_t[d] = tm + tr_act[d] if a_act[d] > 1.0 else tm
                        ev_kind[d] = 1
                ev_seq[d] = seqc
                seqc += 1.0
            else:                   # release
                tot[d] += tm - held[d]
                busy[d] = tm
                if kind[o] != 0.0:
                    fin[l, o] = tm
                if fifo[d]:
                    o2 = fifo[d].pop(0)
                    grant(d, o2, tm)
                else:
                    free[d] = True
                    ev_t[d] = _INF

        diestat[l, :, 0] = tot
        diestat[l, :, 1] = busy
        lane[l] = (chb, ch_tot, n_ev, seqc)

    return fin, diestat, lane
