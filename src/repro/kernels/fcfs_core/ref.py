"""Plain-Python reference for the lockstep sched-aware shard core.

Implements the same bounded-stream-merge algorithm as the Pallas kernel
(:mod:`repro.kernels.fcfs_core.kernel`) — per-die single event slot,
write-transfer FIFO, admission cursor, explicit seq counters — one lane
at a time, with the identical float arithmetic (Python floats are IEEE
f64, and every add/max is written in the interpreter's association
order).  Used by the parity tests to pin the kernel bit-for-bit, and as
the unbatched fallback oracle.

``age_bound`` selects the scheduler: ``None`` is the single FIFO ring;
a float bound (``inf`` = plain host_prio) runs the dual priority rings
with the *verbatim* ``AgedHostPrioQueue.pop_next`` logic from
:mod:`repro.flashsim.sched` — this oracle deliberately restates that
policy in queue-object terms so kernel parity is checked against an
independent restatement, not against itself.
"""

from __future__ import annotations

from typing import Optional

import numpy as np

_INF = float("inf")


def fcfs_core_ref(ops: np.ndarray, n_dies: int, pipelined: bool,
                  tdma: float, tecc: float,
                  age_bound: Optional[float] = None):
    """Run the shard core per lane in pure Python.

    ``ops``: (L, MAXP, 6 or 7) f64 — [arrival, kind, die, dur,
    attempts, tr, (hp)], admission order per lane, padded rows with
    ``arrival == inf``.  Column 6 (``hp``: 1.0 = host read) is the
    scheduling class; required when ``age_bound`` is not ``None``.
    Returns ``(fin, diestat, lane)`` with the same shapes/meaning as
    :func:`repro.kernels.fcfs_core.kernel.fcfs_core_fwd`.
    """
    L, maxp, ncol = ops.shape
    prio = age_bound is not None
    if prio and ncol < 7:
        raise ValueError("priority lowering needs the hp column (7-col "
                         f"op table), got {ncol} columns")
    fin = np.zeros((L, maxp + 1), dtype=np.float64)
    diestat = np.zeros((L, n_dies, 2), dtype=np.float64)
    lane = np.zeros((L, 4), dtype=np.float64)

    for l in range(L):
        arr = ops[l, :, 0]
        kind = ops[l, :, 1]
        die = np.where(np.isfinite(ops[l, :, 2]),
                       ops[l, :, 2], 0.0).astype(np.int64)
        dur = ops[l, :, 3]
        att = ops[l, :, 4]
        tr = ops[l, :, 5]
        hp = ops[l, :, 6] if ncol > 6 else np.zeros(maxp)
        n_adm = int((kind != 3.0).sum())   # pads are trailing

        ev_t = [_INF] * n_dies
        ev_seq = [0.0] * n_dies
        ev_op = [0] * n_dies
        ev_kind = [0] * n_dies      # 0=sense/copy, 1=release
        held = [0.0] * n_dies
        free = [True] * n_dies
        rem = [0.0] * n_dies
        a_act = [0.0] * n_dies
        tr_act = [0.0] * n_dies
        tot = [0.0] * n_dies
        busy = [0.0] * n_dies
        fifo: list = [[] for _ in range(n_dies)]       # hi ring (prio)
        fifo_lo: list = [[] for _ in range(n_dies)]
        byp = [0.0] * n_dies        # bypass counters (prio only)
        acq: list = []              # (done, seq, op) in push order
        aq_head = 0

        chb = 0.0
        ch_tot = 0.0
        seqc = 0.0
        n_ev = 0.0
        ai = 0

        def q_has(d: int) -> bool:
            return bool(fifo[d]) or bool(fifo_lo[d])

        def q_push(d: int, o: int) -> None:
            if prio and hp[o] != 1.0:
                fifo_lo[d].append(o)
            else:
                fifo[d].append(o)

        def q_pop(d: int) -> int:
            # AgedHostPrioQueue.pop_next (sched.py), restated: aged low
            # op jumps; else hi first (count the bypass iff low work
            # waits); any low pop resets the counter.
            if not prio:
                return fifo[d].pop(0)
            if fifo[d] and fifo_lo[d] and byp[d] >= age_bound:
                byp[d] = 0.0
                return fifo_lo[d].pop(0)
            if fifo[d]:
                if fifo_lo[d]:
                    byp[d] += 1.0
                return fifo[d].pop(0)
            byp[d] = 0.0
            return fifo_lo[d].pop(0)

        def grant(d: int, o: int, tm: float) -> None:
            nonlocal seqc
            held[d] = tm
            free[d] = False
            ev_op[d] = o
            ev_seq[d] = seqc
            if kind[o] == 0.0:
                ev_t[d] = tm + tr[o]
                ev_kind[d] = 0
                rem[d] = 0.0 if pipelined else att[o]
                a_act[d] = att[o]
                tr_act[d] = tr[o]
            else:                   # write program or erase
                ev_t[d] = tm + dur[o]
                ev_kind[d] = 1
            seqc += 1.0

        while True:
            # candidate: min (time, seq) over die slots + ACQ head
            tmin, smin, widx = _INF, _INF, -1
            for d in range(n_dies):
                if ev_t[d] < tmin or (ev_t[d] == tmin and ev_seq[d] < smin):
                    tmin, smin, widx = ev_t[d], ev_seq[d], d
            if aq_head < len(acq):
                at, asq, _ = acq[aq_head]
                if at < tmin or (at == tmin and asq < smin):
                    tmin, smin, widx = at, asq, n_dies
            adm_t = arr[ai] if ai < n_adm else _INF
            if adm_t == _INF and tmin == _INF:
                break

            if adm_t <= tmin:       # admission wins ties
                o = ai
                tm = adm_t
                ai += 1
                k = kind[o]
                if k == 1.0:        # write: channel transfer now
                    done = (chb if chb > tm else tm) + tdma
                    chb = done
                    ch_tot += tdma
                    acq.append((done, seqc, o))
                    seqc += 1.0
                else:               # read or erase: contend for the die
                    d = die[o]
                    if free[d] and not q_has(d):
                        grant(d, o, tm)
                    else:
                        q_push(d, o)
                continue

            n_ev += 1.0
            if widx == n_dies:      # ACQ: write transfer landed
                tm, _, o = acq[aq_head]
                aq_head += 1
                d = die[o]
                if free[d] and not q_has(d):
                    grant(d, o, tm)
                else:
                    q_push(d, o)
                continue

            d = widx
            tm = ev_t[d]
            o = ev_op[d]
            if ev_kind[d] == 0:     # sense done / pipelined copy
                done = (chb if chb > tm else tm) + tdma
                chb = done
                ch_tot += tdma
                if not pipelined:
                    r = rem[d] - 1.0
                    if r:
                        rem[d] = r
                        ev_t[d] = (done + tecc) + tr_act[d]
                    else:
                        fin[l, o] = done + tecc
                        ev_t[d] = done
                        ev_kind[d] = 1
                else:
                    i = rem[d]
                    if i + 1.0 < a_act[d]:
                        rem[d] = i + 1.0
                        tnext = tm + tr_act[d]
                        if done > tnext:
                            tnext = done
                        ev_t[d] = tnext
                    else:
                        fin[l, o] = done + tecc
                        ev_t[d] = tm + tr_act[d] if a_act[d] > 1.0 else tm
                        ev_kind[d] = 1
                ev_seq[d] = seqc
                seqc += 1.0
            else:                   # release
                tot[d] += tm - held[d]
                busy[d] = tm
                if kind[o] != 0.0:
                    fin[l, o] = tm
                if q_has(d):
                    o2 = q_pop(d)
                    grant(d, o2, tm)
                else:
                    free[d] = True
                    ev_t[d] = _INF

        diestat[l, :, 0] = tot
        diestat[l, :, 1] = busy
        lane[l] = (chb, ch_tot, n_ev, seqc)

    return fin, diestat, lane


def fused_core_ref(cells, n_dies: int, pipelined: bool):
    """Cell-axis oracle for the fused sweep lowering.

    Restates the *cell-axis law*: lanes never communicate, so running C
    independent cells stacked along the lane axis in one dispatch must
    equal running each cell alone with its own timing scalars.  This
    oracle therefore never sees a stacked table — it runs
    :func:`fcfs_core_ref` once per cell and concatenates, which is the
    independent restatement the fused-kernel parity tests pin
    :func:`repro.kernels.fcfs_core.ops.fused_core` against.

    ``cells``: sequence of ``(ops, tdma, tecc, age_bound)`` tuples, one
    per cell, every ``ops`` of shape (L, MAXP, 6 or 7) with a common
    (L, MAXP).  Returns ``(fin, diestat, lane)`` with the cell-stacked
    shapes of :func:`fused_core` — cell c occupies rows
    [c*L, (c+1)*L).
    """
    fins, diestats, lanes = [], [], []
    for ops, tdma, tecc, age_bound in cells:
        fin, diestat, lane = fcfs_core_ref(
            ops, n_dies, pipelined, tdma, tecc, age_bound=age_bound)
        fins.append(fin)
        diestats.append(diestat)
        lanes.append(lane)
    return (np.concatenate(fins, axis=0),
            np.concatenate(diestats, axis=0),
            np.concatenate(lanes, axis=0))
