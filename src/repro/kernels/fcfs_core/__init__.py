"""Batched FCFS open-loop fast path: lockstep shard-core Pallas kernel.

The constant-duration FCFS channel collapse (``busy = max(busy, t) +
tDMA``) is a sequential max-plus recurrence over event-ordered channel
touches; this package executes the whole per-channel shard loop — the
recurrence plus the die-grant bookkeeping that feeds it — as one
lockstep-vectorized kernel advancing every channel's next event per
step.  ``ops.fcfs_core`` is the dispatch entry, ``ref.fcfs_core_ref``
the plain-Python reference used for bitwise parity tests.
"""

from repro.kernels.fcfs_core.ops import fcfs_core  # noqa: F401
from repro.kernels.fcfs_core.ref import fcfs_core_ref  # noqa: F401
