"""Dispatch wrapper for the lockstep FCFS shard core.

``fcfs_core`` takes the padded per-lane op table as numpy, runs the
Pallas kernel (natively on TPU, under ``interpret=True`` on CPU — which
lowers the identical loop to XLA in f64), and returns numpy results.
All jax work happens inside a scoped ``enable_x64`` context so the f64
requirement never leaks into the process-global jax config (other
kernels in this repo compile under the default f32).

The kernel is jit-cached per (lane count, padded width, die count,
pipelined flag, timing constants); the step count is a traced scalar so
different workload sizes reuse the same executable.
"""

from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.kernels.fcfs_core.kernel import fcfs_core_fwd


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit,
    static_argnames=("n_dies", "capq", "capw", "capsteps", "pipelined",
                     "interpret"))
def _core_jit(ops, steps, timing, *, n_dies, capq, capw, capsteps,
              pipelined, interpret):
    return fcfs_core_fwd(ops, steps, timing, n_dies=n_dies, capq=capq,
                         capw=capw, capsteps=capsteps,
                         pipelined=pipelined, interpret=interpret)


def pad_ops(lanes_ops) -> np.ndarray:
    """Stack per-lane (P_l, 6) op tables into one padded (L, MAXP, 6).

    Pad rows carry ``arrival = inf`` (the admission cursor's stop
    sentinel); the padded width is the next power of two strictly above
    the widest lane, so the cursor's clipped lookahead always lands on a
    pad row.
    """
    L = len(lanes_ops)
    widest = max((t.shape[0] for t in lanes_ops), default=0)
    maxp = 1
    while maxp <= widest:
        maxp *= 2
    ops = np.full((L, maxp, 6), np.inf, dtype=np.float64)
    ops[:, :, 1] = 3.0          # kind: pad
    ops[:, :, 2] = 0.0          # pad die: keep int casts well-defined
    for l, t in enumerate(lanes_ops):
        ops[l, :t.shape[0]] = t
    return ops


def augment_ops(ops: np.ndarray, pipelined: bool) -> np.ndarray:
    """Append the host-precomputed grant-attribute columns.

    ``gdt`` — delta from grant time to the op's first event (tR for
    reads, dur for writes/erases); ``gk0`` — the first event's kind
    (0 sense, 1 release), which doubles as the op's non-read flag;
    ``grem0`` — initial remaining-attempt counter (serial mode counts
    down from ``attempts``; pipelined counts issued copies up from 0).
    These collapse the read/write/erase dispatch at grant time to
    single blends inside the kernel.
    """
    kind = ops[:, :, 1]
    is_read = kind == 0.0
    gdt = np.where(is_read, ops[:, :, 5], ops[:, :, 3])
    gk0 = np.where(is_read, 0.0, 1.0)
    if pipelined:
        grem0 = np.zeros_like(gdt)
    else:
        grem0 = np.where(is_read, ops[:, :, 4], 0.0)
    return np.concatenate(
        [ops, np.stack([gdt, gk0, grem0], axis=2)], axis=2)


def count_steps(ops: np.ndarray) -> int:
    """Lockstep step bound: max over lanes of admissions + heap pops.

    Per op the interpreter pops ``attempts + 1`` events for a read
    (senses + release), 2 for a write (transfer-landed + release), and 1
    for an erase (release) — computable up front because the supported
    matrix has no preemption or online injection.
    """
    kind = ops[:, :, 1]
    att = ops[:, :, 4]
    is_r = kind == 0.0
    per_op = np.where(is_r, np.where(np.isfinite(att), att, 0.0) + 1.0,
                      np.where(kind == 1.0, 2.0,
                               np.where(kind == 2.0, 1.0, 0.0)))
    n_adm = (kind != 3.0).sum(axis=1)
    return int((n_adm + per_op.sum(axis=1)).max(initial=0.0))


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def ring_caps(ops: np.ndarray, n_dies: int):
    """Static FIFO/ACQ ring capacities for a padded op table.

    ``capq`` bounds the deepest per-die FIFO (every op targeting a die
    can be queued there at once, at most); ``capw`` bounds the in-flight
    write transfers of a lane (each write pushes ACQ exactly once).
    Rounded up to powers of two so jit variants stay few; tiny floors
    keep the ``%`` ring arithmetic trivially safe for op-free lanes.
    """
    kind = ops[:, :, 1]
    die = np.where(np.isfinite(ops[:, :, 2]), ops[:, :, 2], -1.0)
    per_die = 0
    for l in range(ops.shape[0]):
        real = kind[l] != 3.0
        if real.any():
            counts = np.bincount(die[l, real].astype(np.int64),
                                 minlength=n_dies)
            per_die = max(per_die, int(counts.max()))
    writes = int((kind == 1.0).sum(axis=1).max(initial=0.0))
    return _pow2_at_least(max(per_die, 2)), _pow2_at_least(max(writes, 2))


def fcfs_core(ops: np.ndarray, n_dies: int, pipelined: bool,
              tdma: float, tecc: float):
    """Run the lockstep shard core on a padded op table.

    Returns numpy ``(fin, diestat, lane)`` — per-op completion
    contributions (L, MAXP+1), per-die [busy_total, last_release]
    (L, n_dies, 2), and per-lane [ch_busy, ch_tot, n_events, seq]
    (L, 4).  Bit-identical to :func:`fcfs_core_ref` on CPU.
    """
    steps = count_steps(ops)
    capq, capw = ring_caps(ops, n_dies)
    capsteps = _pow2_at_least(max(steps, 1))
    L, maxp = ops.shape[0], ops.shape[1]
    with enable_x64():
        log, diestat, lane = _core_jit(
            jnp.asarray(augment_ops(ops, pipelined), jnp.float64),
            jnp.asarray([steps], jnp.int32),
            jnp.asarray([float(tdma), float(tecc)], jnp.float64),
            n_dies=n_dies, capq=capq, capw=capw, capsteps=capsteps,
            pipelined=pipelined, interpret=_use_interpret())
        log = np.asarray(log)
    # Scatter the per-step completion log into the per-op fin table.
    # Each real op id appears at most once; idle rows carry the sink id
    # maxp, zeroed afterwards.  Rows past ``steps`` were never written
    # (all-sink) — skip them.
    fin = np.zeros((L, maxp + 1), dtype=np.float64)
    fin[np.arange(L)[None, :], log[:steps, L:].astype(np.int64)] = \
        log[:steps, :L]
    fin[:, maxp] = 0.0
    return (fin, np.asarray(diestat), np.asarray(lane))
