"""Dispatch wrapper for the lockstep sched-aware shard core.

``fcfs_core`` takes the padded per-lane op table as numpy, runs the
Pallas kernel (natively on TPU, under ``interpret=True`` on CPU — which
lowers the identical loop to XLA in f64), and returns numpy results.
All jax work happens inside a scoped ``enable_x64`` context so the f64
requirement never leaks into the process-global jax config (other
kernels in this repo compile under the default f32).

Compiled-variant reuse (the dispatch-overhead contract)
-------------------------------------------------------
The kernel is jit-cached per (lane count, padded width, die count,
ring capacities, pipelined flag, scheduler lowering); the step count,
timing constants, and aging bound are *traced* scalars, so different
workload sizes, timing models, and ``host_prio_aged`` bounds all reuse
one executable.  Every static shape is bucketed to a power of two with
a small floor (``pad_ops``, ``ring_caps``, ``capsteps``), so a sweep
grid's cells collapse onto a handful of compiled variants.  On top of
the in-process jit cache, the first call points JAX's *persistent*
compilation cache at the repo's standard on-disk cache directory
(``~/.cache/repro_flashsim`` — same ``REPRO_CHAR_CACHE`` /
``REPRO_CHAR_CACHE_DIR`` conventions as the characterization cache in
:mod:`repro.core.characterize`), so fresh processes — spawned sweep
workers, CI lanes, repeated benchmark runs — skip XLA compilation
entirely after the first run on a machine.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.kernels.fcfs_core.kernel import fcfs_core_fwd


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


_COMP_CACHE_READY = False


def _enable_persistent_cache() -> None:
    """Point JAX's compilation cache at ``~/.cache/repro_flashsim``.

    Best-effort and idempotent: respects ``REPRO_CHAR_CACHE=0`` (fully
    disabled) and ``REPRO_CHAR_CACHE_DIR`` (relocated), and never fails
    the computation — an unwritable cache dir just means cold compiles.
    The thresholds are zeroed because the kernels here are small but
    re-traced in every fresh worker process; default thresholds would
    skip exactly the entries we want persisted.
    """
    global _COMP_CACHE_READY
    if _COMP_CACHE_READY:
        return
    _COMP_CACHE_READY = True
    if os.environ.get("REPRO_CHAR_CACHE", "1") == "0":
        return
    d = os.environ.get("REPRO_CHAR_CACHE_DIR") or os.path.join(
        os.path.expanduser("~"), ".cache", "repro_flashsim"
    )
    try:
        os.makedirs(d, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", d)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    except Exception:
        pass  # cache is best-effort; never fail the computation


@functools.partial(
    jax.jit,
    static_argnames=("n_dies", "capq", "capw", "capsteps", "pipelined",
                     "prio", "wide", "interpret"))
def _core_jit(ops, steps, timing, *, n_dies, capq, capw, capsteps,
              pipelined, prio, wide, interpret):
    return fcfs_core_fwd(ops, steps, timing, n_dies=n_dies, capq=capq,
                         capw=capw, capsteps=capsteps,
                         pipelined=pipelined, prio=prio, wide=wide,
                         interpret=interpret)


#: Number of kernel dispatches issued by this process (both the
#: per-run and the fused entry points).  Read by tests/CI to assert the
#: single-dispatch accounting of the fused sweep path.
KERNEL_DISPATCHES = 0

#: Lane counts above this use the batched-scatter (``wide``) carry
#: updates.  The unrolled per-lane dynamic_update_slice is measurably
#: faster everywhere the fused sweep operates (its cell cap keeps
#: stacked dispatches at or under 64 lanes), so ``wide`` only takes
#: over beyond that — oversized single-cell topologies where the
#: unroll would bloat the traced loop body.
_WIDE_LANES = 64


def pad_width(widest: int) -> int:
    """Padded-table width bucket: next power of two strictly above
    ``widest`` (floor 16), the :func:`pad_ops` policy."""
    maxp = 16
    while maxp <= widest:
        maxp *= 2
    return maxp


def pad_ops(lanes_ops, maxp: Optional[int] = None) -> np.ndarray:
    """Stack per-lane (P_l, 7) op tables into one padded (L, MAXP, 7).

    Pad rows carry ``arrival = inf`` (the admission cursor's stop
    sentinel) and ``hp = 0.0``; the padded width is the next power of
    two strictly above the widest lane (floor 16), so the cursor's
    clipped lookahead always lands on a pad row and nearby cell sizes
    share one compiled variant.  ``maxp`` forces a wider bucket (the
    fused sweep pads every cell of a group to the group-wide bucket);
    it must still exceed the widest lane.
    """
    L = len(lanes_ops)
    widest = max((t.shape[0] for t in lanes_ops), default=0)
    if maxp is None:
        maxp = pad_width(widest)
    elif maxp <= widest:
        raise ValueError(f"maxp {maxp} <= widest lane {widest}")
    ops = np.full((L, maxp, 7), np.inf, dtype=np.float64)
    ops[:, :, 1] = 3.0          # kind: pad
    ops[:, :, 2] = 0.0          # pad die: keep int casts well-defined
    ops[:, :, 6] = 0.0          # pad hp: low class, never enqueued
    for l, t in enumerate(lanes_ops):
        ops[l, :t.shape[0]] = t
    return ops


def augment_ops(ops: np.ndarray, pipelined: bool) -> np.ndarray:
    """Append the host-precomputed grant-attribute columns.

    ``gdt`` — delta from grant time to the op's first event (tR for
    reads, dur for writes/erases); ``gk0`` — the first event's kind
    (0 sense, 1 release), which doubles as the op's non-read flag;
    ``grem0`` — initial remaining-attempt counter (serial mode counts
    down from ``attempts``; pipelined counts issued copies up from 0).
    These collapse the read/write/erase dispatch at grant time to
    single blends inside the kernel.
    """
    kind = ops[:, :, 1]
    is_read = kind == 0.0
    gdt = np.where(is_read, ops[:, :, 5], ops[:, :, 3])
    gk0 = np.where(is_read, 0.0, 1.0)
    if pipelined:
        grem0 = np.zeros_like(gdt)
    else:
        grem0 = np.where(is_read, ops[:, :, 4], 0.0)
    return np.concatenate(
        [ops, np.stack([gdt, gk0, grem0], axis=2)], axis=2)


def count_steps(ops: np.ndarray) -> int:
    """Lockstep step bound: max over lanes of admissions + heap pops.

    Per op the interpreter pops ``attempts + 1`` events for a read
    (senses + release), 2 for a write (transfer-landed + release), and 1
    for an erase (release) — computable up front because the supported
    matrix has no preemption or online injection.  Priority policies
    reorder events but never change their count, so the bound is
    lowering-independent.
    """
    kind = ops[:, :, 1]
    att = ops[:, :, 4]
    is_r = kind == 0.0
    per_op = np.where(is_r, np.where(np.isfinite(att), att, 0.0) + 1.0,
                      np.where(kind == 1.0, 2.0,
                               np.where(kind == 2.0, 1.0, 0.0)))
    n_adm = (kind != 3.0).sum(axis=1)
    return int((n_adm + per_op.sum(axis=1)).max(initial=0.0))


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def ring_caps(ops: np.ndarray, n_dies: int):
    """Static FIFO/ACQ ring capacities for a padded op table.

    ``capq`` bounds the deepest per-die FIFO (every op targeting a die
    can be queued there at once, at most — and per-class occupancy of
    the dual priority rings is bounded by the same per-die total, so
    one capacity serves both lowerings); ``capw`` bounds the in-flight
    write transfers of a lane (each write pushes ACQ exactly once).
    Rounded up to powers of two with a floor of 4 so jit variants stay
    few and the ``%`` ring arithmetic is trivially safe for op-free
    lanes.
    """
    kind = ops[:, :, 1]
    real = kind != 3.0
    per_die = 0
    if real.any():
        # One flat bincount over (lane, die) pairs — same counts as a
        # per-lane loop, without L Python iterations.
        lane_of = np.broadcast_to(
            np.arange(ops.shape[0])[:, None], kind.shape)
        flat = lane_of[real] * n_dies + ops[:, :, 2][real].astype(np.int64)
        per_die = int(np.bincount(flat).max())
    writes = int((kind == 1.0).sum(axis=1).max(initial=0.0))
    return _pow2_at_least(max(per_die, 4)), _pow2_at_least(max(writes, 4))


def _dispatch(ops: np.ndarray, n_dies: int, pipelined: bool,
              timing: np.ndarray, prio: bool,
              caps=None, steps=None):
    """One kernel dispatch on a padded table with per-lane timing rows.

    ``timing`` is (L, 3) f64 — per-lane [tdma, tecc, age_bound].
    ``caps`` optionally forces static ``(capq, capw, capsteps)`` (the
    fused sweep buckets them group-wide; capacity is semantics-neutral
    because the rings pair via monotone counters).  ``steps`` skips the
    :func:`count_steps` recount when the caller already knows the bound
    (the fused router counts per cell before stacking; the max over a
    chunk's cells equals the stacked count).  Returns numpy
    ``(fin, diestat, lane)``.
    """
    global KERNEL_DISPATCHES
    _enable_persistent_cache()
    if steps is None:
        steps = count_steps(ops)
    if caps is None:
        capq, capw = ring_caps(ops, n_dies)
        capsteps = _pow2_at_least(max(steps, 16))
    else:
        capq, capw, capsteps = caps
        if steps > capsteps:
            raise ValueError(f"steps {steps} > capsteps {capsteps}")
    L, maxp = ops.shape[0], ops.shape[1]
    with enable_x64():
        log, diestat, lane = _core_jit(
            jnp.asarray(augment_ops(ops, pipelined), jnp.float64),
            jnp.asarray([steps], jnp.int32),
            jnp.asarray(timing, jnp.float64),
            n_dies=n_dies, capq=capq, capw=capw, capsteps=capsteps,
            pipelined=pipelined, prio=prio, wide=L > _WIDE_LANES,
            interpret=_use_interpret())
        log = np.asarray(log)
    KERNEL_DISPATCHES += 1
    # Scatter the per-step completion log into the per-op fin table.
    # Each real op id appears at most once; idle rows carry the sink id
    # maxp, zeroed afterwards.  Rows past ``steps`` were never written
    # (all-sink) — skip them.
    fin = np.zeros((L, maxp + 1), dtype=np.float64)
    fin[np.arange(L)[None, :], log[:steps, L:].astype(np.int64)] = \
        log[:steps, :L]
    fin[:, maxp] = 0.0
    return (fin, np.asarray(diestat), np.asarray(lane))


def fcfs_core(ops: np.ndarray, n_dies: int, pipelined: bool,
              tdma: float, tecc: float,
              age_bound: Optional[float] = None):
    """Run the lockstep shard core on a padded op table.

    ``age_bound`` selects the scheduler lowering: ``None`` = single
    FIFO ring (fcfs); a float (``inf`` = plain host_prio) = dual
    priority rings with that aging bound, classified by the op table's
    ``hp`` column.  Returns numpy ``(fin, diestat, lane)`` — per-op
    completion contributions (L, MAXP+1), per-die
    [busy_total, last_release] (L, n_dies, 2), and per-lane
    [ch_busy, ch_tot, n_events, seq] (L, 4).  Bit-identical to
    :func:`fcfs_core_ref` on CPU.
    """
    prio = age_bound is not None
    bound = float(age_bound) if prio else 0.0
    timing = np.tile(
        np.asarray([[float(tdma), float(tecc), bound]], np.float64),
        (ops.shape[0], 1))
    return _dispatch(ops, n_dies, pipelined, timing, prio)


def fused_core(ops: np.ndarray, n_dies: int, pipelined: bool,
               timing: np.ndarray, prio: bool, caps=None, steps=None):
    """Run one dispatch over the lanes of many stacked cells.

    ``ops`` is the (C*L, MAXP, 7) cell-stacked padded table (cell c's
    lanes occupy rows [c*L, (c+1)*L)), ``timing`` the matching (C*L, 3)
    per-lane [tdma, tecc, age_bound] rows — each cell's scalars
    repeated on its lanes, which is what lets cells with different
    timing models or aging bounds share the dispatch.  ``pipelined``
    and ``prio`` are static and must be uniform across the stacked
    cells (the fused router groups by them).  Returns the same
    ``(fin, diestat, lane)`` triple as :func:`fcfs_core`; slice rows
    [c*L, (c+1)*L) for cell c.  Bit-identical per cell to a separate
    :func:`fcfs_core` dispatch — the cell-axis law restated (and
    property-pinned) by :func:`repro.kernels.fcfs_core.ref.fused_core_ref`.
    """
    if timing.shape != (ops.shape[0], 3):
        raise ValueError(
            f"timing shape {timing.shape} != ({ops.shape[0]}, 3)")
    return _dispatch(ops, n_dies, pipelined, np.asarray(timing, np.float64),
                     prio, caps=caps, steps=steps)
