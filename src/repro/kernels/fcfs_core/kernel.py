"""Lockstep Pallas kernel for the sched-aware open-loop shard core.

One kernel invocation advances *all* channel shards of a run in lockstep:
the lane dimension (axis 0 everywhere) is the shard/channel, and each
``fori_loop`` step retires exactly one event — an admission, a sense
completion, a die release, or a write-transfer landing — per active lane.
The channel busy-until collapse is the sequential max-plus recurrence

    done = max(ch_busy, t) + tDMA ;  ch_busy = done

carried as a lane vector across steps, evaluated in event order, which is
what makes the result bit-identical to the interpreter loop in
:mod:`repro.flashsim.engine` (no reassociation of float arithmetic — the
exact add/max sequence of ``_run_shard`` is replayed per lane).

The interpreter's heap is replaced by a bounded merge that is exact by
construction for the supported matrix (fcfs / host_prio /
host_prio_aged, gc in {none, prepass}, no faults, open loop):

  * each die holds at most one scheduled event (next sense/copy, or its
    release) — a (time, seq) pair in the die-state row;
  * write transfers in flight form a FIFO whose times and seqs are
    pushed in admission order (monotone, since the channel collapse
    grants at issue) — the ACQ queue;
  * the admission cursor wins ties (the interpreter's ``next_adm <= tt``).

``seq`` counters are incremented exactly where ``_run_shard`` increments
``seqc``, so heap tie-breaking (push order) is reproduced, not
approximated.

State layout (all f64; integers are exactly representable):

  ops   (L, MAXP, 10) — [arrival, kind, die, dur, attempts, tr, hp,
                        gdt, gk0, grem0] per op in admission order;
                        kind 0=read 1=write 2=erase 3=pad (arrival
                        inf); hp is the scheduling class (1.0 = host
                        read, the ``host_read`` table of
                        :mod:`repro.flashsim.sched`; pads 0.0).
                        The g* columns are host-precomputed grant
                        attributes (see :func:`augment_ops`): first
                        event delta (tR for reads, dur otherwise),
                        initial event kind (0 sense / 1 release), and
                        initial remaining-attempts — they collapse the
                        read/write/erase dispatch at grant time to
                        single blends.
  state (L, D+1, NC)  — per-die rows [evt, evseq, evop, evkind, held,
                        free, rem, a_act, tr_act, qhead, qtail, tot,
                        busy, nonread] (NC=14, the fifo lowering), plus
                        [qhead2, qtail2, byp] under the prio lowering
                        (NC=17); row D is the masked-write sink.
  fifo  (L, D+1, CAPQ)— per-die FIFO ring of queued op ids; CAPQ is a
                        host-computed bound (max ops on one die), so
                        the ring never overwrites a live entry.  Under
                        the prio lowering the last axis doubles
                        (2*CAPQ): the *host-read* (hi) ring lives in
                        slots [0, CAPQ) and the low class (programs, GC
                        copy-back, erases) in [CAPQ, 2*CAPQ) of the
                        *same* buffer — one push scatter and one pop
                        gather per step regardless of class, instead of
                        a second buffer costing its own L per-lane
                        updates.  Per-class occupancy is bounded by the
                        per-die total, so CAPQ bounds both regions.
  acq   (L, CAPW+1, 4)— ring of in-flight write transfers [done, seq,
                        op, die]; CAPW bounds the writes of one lane;
                        slot CAPW is the masked-write sink.
  log   (CAPSTEPS, 2L)— per-step completion log, one row per lockstep
                        step: [fin values | fin op ids].  Inactive
                        lanes log op id MAXP (the sink).  The per-op
                        ``fin`` table (reads: done+tECC of the final
                        attempt; writes/erases: release time) is never
                        read inside the loop, so it is reconstructed
                        from the log by one host-side scatter in
                        :func:`repro.kernels.fcfs_core.ops.fcfs_core`
                        — one log write per step instead of L per-lane
                        updates.

Scheduler lowering
------------------
``prio=False`` traces the single-ring FCFS pop — byte-for-byte the PR 8
kernel.  ``prio=True`` traces the dual-ring pop implementing
``AgedHostPrioQueue.pop_next`` exactly (``sched.py``): a release that
finds work pops the low ring when the hi ring is empty *or* when the
per-die bypass counter has reached the aging bound (both rings
non-empty), else pops the hi ring — incrementing the counter iff the
low ring was bypassed; every low-ring pop resets the counter.  The
bound rides in ``timing[2]`` as a *traced* scalar, so plain
``host_prio`` (bound = +inf: the low class never ages to the front) and
every ``host_prio_aged:N`` share one compiled kernel.  The counter
changes only at ring pops — admissions and ACQ landings that grant a
free die directly never consult the queue object in the interpreter, so
they never touch the counter here either.

Every scatter into the carry is *unconditional*: inactive lanes are
redirected to a sink row/slot instead of blending with the gathered
current value, so each carry buffer has the scatter as its only
consumer and XLA updates it in place across ``fori_loop`` steps
(masked blends forced a full copy of every buffer per step).  The FIFO
push runs before the pop gather for the same reason — a lane popping
this step never pushes, so reading the pushed buffer is semantically
identical, and it keeps the scatter the buffer's only carry consumer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# ops columns
(_ARR, _KIND, _DIE, _DUR, _A, _TR, _HP, _GDT, _GK0, _GREM0) = range(10)
# die-state columns (the last three exist only under the prio lowering)
(_EVT, _EVSEQ, _EVOP, _EVKIND, _HELD, _FREE, _REM, _AACT, _TRACT,
 _QHEAD, _QTAIL, _TOT, _BUSY, _NR, _QHEAD2, _QTAIL2, _BYP) = range(17)

_BIGSEQ = 1e18


def _core_kernel(ops_ref, steps_ref, timing_ref, log_ref, diestat_ref,
                 lane_ref, *, n_lanes, n_dies, maxp, capq, capw,
                 capsteps, pipelined, prio, wide):
    L, D = n_lanes, n_dies
    lanes = jnp.arange(L)
    inf = jnp.inf
    ops = ops_ref[...]
    steps = steps_ref[0]
    # tDMA/tECC enter as traced *per-lane vectors*, NOT Python
    # literals: XLA's algebraic simplifier folds
    # add(add(x, c1), c2) -> add(x, c1+c2) for literal constants,
    # which reassociates the sense chain (max(chb, t) + tdma) + tecc
    # and breaks bit-identity with the interpreter.  Parameters are
    # opaque to that rewrite.  A lane vector (one row per lane) lets
    # the fused sweep carry per-cell timing while the broadcast of a
    # single run stays elementwise-identical to the scalar form.
    tdma = timing_ref[:, 0]
    tecc = timing_ref[:, 1]
    # Aging bound for the prio lowering (traced, +inf = plain
    # host_prio); unread when prio=False.
    bound = timing_ref[:, 2]

    def body(t, carry):
        (state, fifo, acq, log, chb, ch_tot, seqc, n_ev,
         ai, aq_head, aq_tail) = carry

        # ---- candidate selection: per-die events + ACQ head ----------
        evt = state[:, :D, _EVT]
        evseq = state[:, :D, _EVSEQ]
        aq_row = acq[lanes, aq_head % capw]
        aq_empty = aq_head >= aq_tail
        aq_t = jnp.where(aq_empty, inf, aq_row[:, 0])
        aq_sq = jnp.where(aq_empty, _BIGSEQ, aq_row[:, 1])
        cand_t = jnp.concatenate([evt, aq_t[:, None]], axis=1)
        cand_s = jnp.concatenate([evseq, aq_sq[:, None]], axis=1)
        tmin = cand_t.min(axis=1)
        is_min = cand_t == tmin[:, None]
        smin = jnp.where(is_min, cand_s, _BIGSEQ).min(axis=1)
        widx = jnp.argmax(is_min & (cand_s == smin[:, None]), axis=1)

        adm_row = ops[lanes, ai]
        adm_t = adm_row[:, _ARR]
        active = (adm_t < inf) | (tmin < inf)
        take_adm = (adm_t <= tmin) & active
        take_ev = (~take_adm) & active

        a_kind = adm_row[:, _KIND]
        a_die = adm_row[:, _DIE].astype(jnp.int32)
        is_r = take_adm & (a_kind == 0.0)
        is_w = take_adm & (a_kind == 1.0)
        is_e = take_adm & (a_kind == 2.0)

        ev_acq = take_ev & (widx == D)
        ev_die = take_ev & (widx < D)
        o_acq = aq_row[:, 2].astype(jnp.int32)
        acq_die = aq_row[:, 3].astype(jnp.int32)
        aq_head = aq_head + ev_acq.astype(jnp.int32)

        # the one die row this step reads/writes
        tgt = jnp.where(take_adm & (is_r | is_e), a_die,
                        jnp.where(ev_die, widx.astype(jnp.int32),
                                  jnp.where(ev_acq, acq_die, D)))
        row = state[lanes, tgt]

        if prio:
            hi_empty = row[:, _QTAIL] == row[:, _QHEAD]
            lo_empty = row[:, _QTAIL2] == row[:, _QHEAD2]
            q_empty = hi_empty & lo_empty
        else:
            q_empty = row[:, _QTAIL] == row[:, _QHEAD]
        die_free = (row[:, _FREE] == 1.0) & q_empty

        ev_kind = row[:, _EVKIND]
        ev_sense = ev_die & (ev_kind == 0.0)
        ev_rel = ev_die & (ev_kind == 1.0)

        # -- the channel collapse (write admission DMA or sense DMA;
        #    a step is one or the other, so one max-plus update) --
        touches = is_w | ev_sense
        c_done = jnp.maximum(chb, jnp.where(take_adm, adm_t, tmin)) + tdma
        chb = jnp.where(touches, c_done, chb)
        ch_tot = jnp.where(touches, ch_tot + tdma, ch_tot)

        # write admission: ACQ push at its DMA-done time, unconditional
        # (non-write lanes land in the sink slot capw, never read).
        # Per-lane dynamic_update_slice with a static lane index is the
        # cheapest in-place update XLA:CPU will emit for a handful of
        # computed row indices — both the generic scatter op and a
        # one-hot blend over the ring measured slower.
        aq_slot = jnp.where(is_w, aq_tail % capw, capw)
        aq_new = jnp.stack([c_done, seqc, ai.astype(jnp.float64),
                            adm_row[:, _DIE]], axis=1)
        if wide:
            acq = acq.at[lanes, aq_slot].set(
                aq_new, unique_indices=True, indices_are_sorted=True)
        else:
            for l in range(L):
                acq = jax.lax.dynamic_update_slice(
                    acq, aq_new[l][None, None, :],
                    (jnp.int32(l), aq_slot[l], jnp.int32(0)))
        aq_tail = aq_tail + is_w.astype(jnp.int32)

        # -- sense / copy handler --
        s_tm = tmin
        s_tr = row[:, _TRACT]
        if not pipelined:
            s_more = row[:, _REM] > 1.0
            s_next = jnp.where(s_more, (c_done + tecc) + s_tr, c_done)
            s_rem = row[:, _REM] - 1.0
        else:
            s_more = row[:, _REM] + 1.0 < row[:, _AACT]
            s_rel = jnp.where(row[:, _AACT] > 1.0, s_tm + s_tr, s_tm)
            s_next = jnp.where(s_more,
                               jnp.maximum(s_tm + s_tr, c_done), s_rel)
            s_rem = row[:, _REM] + 1.0
        s_fin = c_done + tecc

        # -- grants: admission (free die), ACQ landing, release pop --
        r_tm = tmin
        g_adm = (is_r | is_e) & die_free
        g_acq = ev_acq & die_free
        queue_push = ((is_r | is_e) & ~die_free) | (ev_acq & ~die_free)
        push_val = jnp.where(take_adm, ai.astype(jnp.float64),
                             o_acq.astype(jnp.float64))

        # FIFO push before the pop gather (see module docstring)
        push_die = jnp.where(queue_push, tgt, D)
        if prio:
            # Class of the pushed op — the kernel's ``host_read`` table
            # lookup.  Non-pushing lanes read a harmless row (push_die
            # is the sink for them).  Class picks the ring *region* of
            # the shared buffer: hi at [0, capq), lo at [capq, 2*capq)
            # — one scatter per lane either way.
            push_hp = ops[lanes, push_val.astype(jnp.int32), _HP] == 1.0
            push_hi = queue_push & push_hp
            push_lo = queue_push & ~push_hp
            push_slot = jnp.where(
                push_hp, row[:, _QTAIL].astype(jnp.int32) % capq,
                capq + row[:, _QTAIL2].astype(jnp.int32) % capq)
        else:
            push_slot = row[:, _QTAIL].astype(jnp.int32) % capq
        if wide:
            fifo = fifo.at[lanes, push_die, push_slot].set(
                push_val, unique_indices=True, indices_are_sorted=True)
        else:
            for l in range(L):
                fifo = jax.lax.dynamic_update_slice(
                    fifo, push_val[l].reshape(1, 1, 1),
                    (jnp.int32(l), push_die[l], push_slot[l]))

        q_nonempty = ~q_empty
        grant2 = ev_rel & q_nonempty
        if prio:
            # AgedHostPrioQueue.pop_next, vectorized: pop the low ring
            # when the hi ring is empty or the head-of-line low op has
            # aged past the bound; else pop hi, counting the bypass iff
            # low work was waiting.  Any low pop resets the counter.
            # Selecting the ring = selecting the slot region, so one
            # gather serves both classes.
            byp = row[:, _BYP]
            lo_ne = ~lo_empty
            aged = ~hi_empty & lo_ne & (byp >= bound)
            pop_lo = aged | hi_empty
            qh = jnp.where(
                pop_lo, capq + row[:, _QHEAD2].astype(jnp.int32) % capq,
                row[:, _QHEAD].astype(jnp.int32) % capq)
        else:
            qh = row[:, _QHEAD].astype(jnp.int32) % capq
        o2 = fifo[lanes, tgt, qh].astype(jnp.int32)

        # one gather serves every grant source: popped op, admitted op,
        # or the ACQ-landed op (masked lanes read a harmless row)
        grant_any = g_adm | g_acq | grant2
        g_op = jnp.where(grant2, o2,
                         jnp.where(take_adm, ai, o_acq))
        g_row = ops[lanes, g_op]
        gr_tm = jnp.where(take_adm, adm_t, r_tm)

        # ---- assemble the new die row --------------------------------
        new_evt = jnp.where(
            ev_sense, s_next,
            jnp.where(grant_any, gr_tm + g_row[:, _GDT],
                      jnp.where(ev_rel, inf, row[:, _EVT])))
        sets_ev = ev_sense | grant_any
        new_evseq = jnp.where(sets_ev, seqc, row[:, _EVSEQ])
        new_evop = jnp.where(grant_any, g_op.astype(jnp.float64),
                             row[:, _EVOP])
        # kind after this step: sense chains stay 0 until the final
        # attempt converts to a release; grants start at the op's
        # precomputed gk0 (reads 0, writes/erases 1).
        new_evkind = jnp.where(ev_sense,
                               jnp.where(s_more, 0.0, 1.0),
                               jnp.where(grant_any, g_row[:, _GK0],
                                         row[:, _EVKIND]))
        new_held = jnp.where(grant_any, gr_tm, row[:, _HELD])
        new_free = jnp.where(grant_any, 0.0,
                             jnp.where(ev_rel & ~q_nonempty, 1.0,
                                       row[:, _FREE]))
        new_rem = jnp.where(ev_sense, s_rem,
                            jnp.where(grant_any, g_row[:, _GREM0],
                                      row[:, _REM]))
        new_aact = jnp.where(grant_any, g_row[:, _A], row[:, _AACT])
        new_tract = jnp.where(grant_any, g_row[:, _TR], row[:, _TRACT])
        new_nr = jnp.where(grant_any, g_row[:, _GK0], row[:, _NR])
        if prio:
            new_qhead = row[:, _QHEAD] + \
                (grant2 & ~pop_lo).astype(jnp.float64)
            new_qhead2 = row[:, _QHEAD2] + \
                (grant2 & pop_lo).astype(jnp.float64)
            new_qtail = row[:, _QTAIL] + push_hi.astype(jnp.float64)
            new_qtail2 = row[:, _QTAIL2] + push_lo.astype(jnp.float64)
            new_byp = jnp.where(
                grant2,
                jnp.where(pop_lo, 0.0, byp + lo_ne.astype(jnp.float64)),
                byp)
        else:
            new_qhead = row[:, _QHEAD] + grant2.astype(jnp.float64)
            new_qtail = row[:, _QTAIL] + queue_push.astype(jnp.float64)
        new_tot = jnp.where(ev_rel, row[:, _TOT] + (r_tm - row[:, _HELD]),
                            row[:, _TOT])
        new_busy = jnp.where(ev_rel, r_tm, row[:, _BUSY])

        cols = [new_evt, new_evseq, new_evop, new_evkind, new_held,
                new_free, new_rem, new_aact, new_tract, new_qhead,
                new_qtail, new_tot, new_busy, new_nr]
        if prio:
            cols += [new_qhead2, new_qtail2, new_byp]
        new_row = jnp.stack(cols, axis=1)
        # Per-lane dynamic_update_slice (static lane, computed die row):
        # measurably cheaper than both XLA:CPU's generic scatter and a
        # one-hot blend at shard-core lane counts, and still updated in
        # place.  Under the ``wide`` lowering (fused sweeps stack cells
        # into dozens of lanes) the unroll would bloat the loop body,
        # so the same update is emitted as one batched scatter — lane
        # indices are unique and sorted, so the written values and the
        # in-place carry update are identical either way.
        if wide:
            state = state.at[lanes, tgt].set(
                new_row, unique_indices=True, indices_are_sorted=True)
        else:
            for l in range(L):
                state = jax.lax.dynamic_update_slice(
                    state, new_row[l][None, None, :],
                    (jnp.int32(l), tgt[l], jnp.int32(0)))

        # fin events: final sense (reads) or release of a non-read.
        # Logged as one (2L,) row per step — the fin table is never
        # read in the loop, so one dynamic_update_slice replaces L
        # per-lane writes; the host scatters the log afterwards.
        fin_sense = ev_sense & ~s_more
        fin_rel = ev_rel & (row[:, _NR] == 1.0)
        fin_idx = jnp.where(fin_sense | fin_rel,
                            row[:, _EVOP].astype(jnp.int32), maxp)
        fin_val = jnp.where(fin_sense, s_fin, r_tm)
        entry = jnp.concatenate(
            [fin_val, fin_idx.astype(jnp.float64)])[None, :]
        log = jax.lax.dynamic_update_slice(log, entry,
                                           (t, jnp.int32(0)))

        # seq counter: one push per admission of a write (ACQ), per
        # grant, and per sense continuation — exactly the interpreter's
        # seqc increments.
        pushed = is_w | grant_any | ev_sense
        seqc = seqc + pushed.astype(jnp.float64)
        n_ev = n_ev + take_ev.astype(jnp.float64)
        ai = ai + take_adm.astype(jnp.int32)

        return (state, fifo, acq, log, chb, ch_tot, seqc, n_ev,
                ai, aq_head, aq_tail)

    zero_l = jnp.zeros((L,), jnp.float64)
    zero_i = jnp.zeros((L,), jnp.int32)
    ncols = 17 if prio else 14
    state0 = jnp.zeros((L, D + 1, ncols), jnp.float64)
    state0 = state0.at[:, :, _EVT].set(jnp.inf)
    state0 = state0.at[:, :, _FREE].set(1.0)
    # Under the prio lowering the slot axis doubles: hi ring at
    # [0, capq), low ring at [capq, 2*capq) of the same buffer.
    fifo0 = jnp.zeros((L, D + 1, capq * (2 if prio else 1)),
                      jnp.float64)
    acq0 = jnp.zeros((L, capw + 1, 4), jnp.float64)
    # Unwritten log rows (t >= steps) keep op id maxp — the sink slot
    # the host scatter discards.
    log0 = jnp.concatenate(
        [jnp.zeros((capsteps, L), jnp.float64),
         jnp.full((capsteps, L), float(maxp), jnp.float64)], axis=1)

    carry = (state0, fifo0, acq0, log0, zero_l, zero_l, zero_l,
             zero_l, zero_i, zero_i, zero_i)
    (state, fifo, acq, log, chb, ch_tot, seqc, n_ev,
     ai, aq_head, aq_tail) = jax.lax.fori_loop(0, steps, body, carry)

    log_ref[...] = log
    diestat_ref[...] = jnp.stack(
        [state[:, :D, _TOT], state[:, :D, _BUSY]], axis=2)
    lane_ref[...] = jnp.stack([chb, ch_tot, n_ev, seqc], axis=1)


def fcfs_core_fwd(ops, steps, timing, *, n_dies, capq, capw, capsteps,
                  pipelined, prio=False, wide=False, interpret=True):
    """Run the lockstep shard core.

    ``ops``: (L, MAXP, 10) f64 augmented padded op table (admission
    order per lane; see :func:`augment_ops`).  ``steps``: (1,) i32 —
    total lockstep steps (max lane admissions + events; idle lanes
    no-op).  ``timing``: (L, 3) f64 — per-lane [tdma, tecc, age_bound]
    rows; a single run broadcasts one row to all lanes, a fused sweep
    carries each cell's scalars on that cell's lanes.  The bound is
    traced (+inf = plain host_prio) and unread when ``prio`` is False.
    ``capq``/``capw`` — static FIFO/ACQ ring capacities (host-computed
    bounds: max ops on one die / max writes on one lane); ``capsteps``
    — static log length, a power of two >= steps.  ``prio`` selects
    the dual-ring scheduler lowering and ``wide`` the batched-scatter
    carry updates for large fused lane counts (both static: distinct
    compiled kernels, identical results).
    Returns ``(log, diestat, lane)``: the per-step completion log
    (scatter it into the per-op ``fin`` table host-side), per-die
    [tot, busy], and per-lane [ch_busy, ch_tot, n_events, seqc].
    """
    L, maxp, _ = ops.shape
    kernel = functools.partial(
        _core_kernel, n_lanes=L, n_dies=n_dies, maxp=maxp, capq=capq,
        capw=capw, capsteps=capsteps, pipelined=pipelined, prio=prio,
        wide=wide)
    return pl.pallas_call(
        kernel,
        out_shape=[
            jax.ShapeDtypeStruct((capsteps, 2 * L), jnp.float64),
            jax.ShapeDtypeStruct((L, n_dies, 2), jnp.float64),
            jax.ShapeDtypeStruct((L, 4), jnp.float64),
        ],
        interpret=interpret,
    )(ops, steps, timing)
