"""Pallas TPU flash-attention forward kernel.

Grid: (BH, nq, nk) with the kv dimension innermost and *arbitrary*
(sequential) semantics: the online-softmax running state (m, l, acc)
lives in VMEM scratch that persists across the kv steps of one (bh, qi)
cell; the output block is written once, on the last kv step.

BlockSpecs keep one (bq, hd) query tile, one (bk, hd) K and V tile, and
the (bq, hd) output tile in VMEM — the MXU sees (bq x hd) @ (hd x bk) and
(bq x bk) @ (bk x hd) matmuls, 128-aligned for hd in {64,128,256} via bq,
bk multiples of 128.

Causal tiles entirely above the diagonal are skipped with pl.when — zero
MXU work on real hardware (the tile still occupies a grid step).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions; take
# whichever this installation provides.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

NEG = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref,        # VMEM tiles
    o_ref,                       # output tile, revisited across kv steps
    m_scr, l_scr, acc_scr,       # VMEM scratch (persist across kv steps)
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    bq: int,
    bk: int,
    nk: int,
    kv_valid: int,
):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk

    # Skip tiles with no unmaskable element (above the causal diagonal or
    # entirely in key padding) — zero MXU work on hardware.
    run = k_start < kv_valid
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + bq - 1)

    @pl.when(run)
    def _tile():
        q = q_ref[0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale                                      # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_valid
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG)

        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_blk = jnp.max(s, axis=1, keepdims=True)     # (bq, 1)
        m_new = jnp.maximum(m_prev, m_blk)
        # NB: exp(NEG - NEG) == 1 on fully-masked rows — zero those out.
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)  # (bq, bk)
        c = jnp.exp(m_prev - m_new)                   # (bq, 1)
        l_new = l_prev * c + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                              # (bq, hd)
        acc_scr[...] = acc_scr[...] * c + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,                 # (BH, T, hd)
    k: jax.Array,                 # (BK, S, hd)
    v: jax.Array,                 # (BK, S, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    kv_valid: Optional[int] = None,
    bq: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    BH, T, hd = q.shape
    BK, S, _ = k.shape
    G = BH // BK
    kv_valid = S if kv_valid is None else kv_valid
    bq = min(bq, T)
    bk = min(bk, S)

    # Pad T and S to tile multiples (mask handles key padding; query pad
    # rows are sliced away).
    Tp = -(-T // bq) * bq
    Sp = -(-S // bk) * bk
    if Tp != T:
        q = jnp.pad(q, ((0, 0), (0, Tp - T), (0, 0)))
    if Sp != S:
        k = jnp.pad(k, ((0, 0), (0, Sp - S), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Sp - S), (0, 0)))
        kv_valid = min(kv_valid, S)
    nq, nk = Tp // bq, Sp // bk

    kernel = functools.partial(
        _fa_kernel,
        scale=hd**-0.5,
        causal=causal,
        window=window,
        softcap=softcap,
        bq=bq, bk=bk, nk=nk,
        kv_valid=kv_valid,
    )
    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh // G, ki, 0)),
            pl.BlockSpec((1, bk, hd), lambda bh, qi, ki: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Tp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :T]
