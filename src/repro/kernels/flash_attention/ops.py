"""Jitted wrapper: model-layout adapter + backend dispatch for the kernel."""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "softcap", "kv_valid", "interpret")
)
def flash_attention(
    q: jax.Array,                 # (B, T, K, G, hd) — model layout
    k: jax.Array,                 # (B, S, K, hd)
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    kv_valid: Optional[int] = None,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Returns (B, T, K, G, hd). TPU: Pallas kernel; CPU: interpret mode."""
    B, T, K, G, hd = q.shape
    S = k.shape[1]
    qf = q.transpose(0, 2, 3, 1, 4).reshape(B * K * G, T, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, S, hd)
    of = flash_attention_fwd(
        qf, kf, vf,
        causal=causal, window=window, softcap=softcap, kv_valid=kv_valid,
        interpret=_use_interpret() if interpret is None else interpret,
    )
    return of.reshape(B, K, G, T, hd).transpose(0, 3, 1, 2, 4)
