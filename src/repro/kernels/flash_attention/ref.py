"""Pure-jnp oracle for the flash-attention kernel (dense softmax)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG = -1e30


def attention_ref(
    q: jax.Array,          # (BH, T, hd)
    k: jax.Array,          # (BK, S, hd)  with BH = BK * G
    v: jax.Array,          # (BK, S, hd)
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    kv_valid: Optional[int] = None,   # keys >= kv_valid are padding
) -> jax.Array:
    """Dense reference. Heads flattened into the batch dim; GQA expressed
    by repeating kv rows G = BH // BK times."""
    BH, T, hd = q.shape
    BK, S, _ = k.shape
    G = BH // BK
    k = jnp.repeat(k, G, axis=0)
    v = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("bth,bsh->bts", q, k, preferred_element_type=jnp.float32)
    s = s * (hd**-0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & ((qpos - kpos) < window)
    if kv_valid is not None:
        mask = mask & (kpos < kv_valid)
    s = jnp.where(mask[None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bts,bsh->bth", p.astype(v.dtype), v)
