"""Pallas TPU kernel: dequantize KV pages + compute the retry margin.

Grid over page blocks; each step holds a (bp, E) int8 tile, its scales,
and the backing tile in VMEM, dequantizes on the VPU, computes the
margin statistic (one rms reduction per page), and selects dequant vs
backing per page — the fused fast-read + margin-check + retry-select of
DESIGN.md §4.  The backing tile plays the role of the CACHE READ second
register: on hardware its DMA overlaps the dequant of the previous tile
(double buffering is implicit in the Pallas pipeline).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions; take
# whichever this installation provides.
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _kv_retry_kernel(q_ref, s_ref, b_ref, out_ref, m_ref, *, tau: float):
    q = q_ref[...].astype(jnp.float32)         # (bp, E)
    s = s_ref[...]                              # (bp, 1)
    deq = q * s
    rms = jnp.sqrt(jnp.mean(deq * deq, axis=1, keepdims=True) + 1e-12)
    margin = 1.0 - (0.5 * s) / (tau * rms)      # (bp, 1)
    take_fast = margin >= 0.0
    out = jnp.where(take_fast, deq, b_ref[...].astype(jnp.float32))
    out_ref[...] = out.astype(out_ref.dtype)
    m_ref[...] = margin


def kv_retry_pallas(data_q, scale, backing, *, tau: float = 0.02,
                    bp: int = 128, interpret: bool = False):
    P, E = data_q.shape
    bp = min(bp, max(8, P))
    Pp = -(-P // bp) * bp
    if Pp != P:
        pad = Pp - P
        data_q = jnp.pad(data_q, ((0, pad), (0, 0)))
        scale = jnp.pad(scale, ((0, pad), (0, 0)), constant_values=1.0)
        backing = jnp.pad(backing, ((0, pad), (0, 0)))

    kernel = functools.partial(_kv_retry_kernel, tau=tau)
    out, margin = pl.pallas_call(
        kernel,
        grid=(Pp // bp,),
        in_specs=[
            pl.BlockSpec((bp, E), lambda pi: (pi, 0)),
            pl.BlockSpec((bp, 1), lambda pi: (pi, 0)),
            pl.BlockSpec((bp, E), lambda pi: (pi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bp, E), lambda pi: (pi, 0)),
            pl.BlockSpec((bp, 1), lambda pi: (pi, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Pp, E), backing.dtype),
            jax.ShapeDtypeStruct((Pp, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(data_q, scale, backing)
    return out[:P], margin[:P]
