"""Jitted wrapper + page quantization helpers for the KV retry read."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kv_retry.kernel import kv_retry_pallas
from repro.kernels.kv_retry.ref import kv_retry_ref


def quantize_pages(x):
    """x: (P, E) -> (int8 data, (P,1) scales). Symmetric per-page int8."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


@functools.partial(jax.jit, static_argnames=("tau", "interpret"))
def kv_read_with_retry(data_q, scale, backing, tau: float = 0.02,
                       interpret=None):
    """Margin-aware fast read with retry (Pallas on TPU, interpret on CPU)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return kv_retry_pallas(data_q, scale, backing, tau=tau, interpret=interpret)
