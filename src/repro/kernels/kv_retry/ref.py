"""Oracle for the margin-aware quantized-KV retry read (pure jnp).

The AR² analogy on TPU (DESIGN.md §4): the low-precision (int8) KV page is
the fast, reduced-"tR" read; the margin statistic is the ECC-capability
margin; pages whose quantization-error bound exceeds the tolerance are
re-read from the high-precision backing copy (the retry step).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def kv_retry_ref(data_q, scale, backing, tau: float = 0.02):
    """data_q: (P, E) int8; scale: (P, 1) f32; backing: (P, E) f32/bf16.

    Returns (out (P, E) backing-dtype, margin (P, 1) f32):
      margin = 1 - (scale/2) / (tau * rms(dequant_page))
      out    = dequant where margin >= 0 else backing  (the retry).
    """
    deq = data_q.astype(jnp.float32) * scale
    rms = jnp.sqrt(jnp.mean(jnp.square(deq), axis=-1, keepdims=True) + 1e-12)
    err_bound = 0.5 * scale
    margin = 1.0 - err_bound / (tau * rms)
    out = jnp.where(margin >= 0.0, deq, backing.astype(jnp.float32))
    return out.astype(backing.dtype), margin
