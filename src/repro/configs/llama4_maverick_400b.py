"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-*]: interleaved MoE.

48 layers, d_model=5120, 40 heads (GQA kv=8), d_ff=8192, vocab=202048.
MoE: 128 experts, top-1, sigmoid router, parallel shared expert, MoE in
every *second* layer (interleave=2, hf `interleave_moe_layer_step=2`) —
with MoE in all 48 layers the stated dims total ~780B; 1:2 interleave
totals ~398B, matching the 400B name.  Recorded in DESIGN.md §6.
"""

from repro.configs.base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    block_pattern=(ATTN, ATTN),     # dense-FFN layer, MoE layer
    mlp="swiglu",
    rope_theta=500000.0,
    moe=MoEConfig(
        n_experts=128,
        top_k=1,
        d_ff_expert=8192,
        router="sigmoid",
        shared_expert=True,
        interleave=2,
    ),
    moment_dtype="bfloat16",        # ~400B params: bf16 moments to fit HBM
    supports_long_context=False,
)
