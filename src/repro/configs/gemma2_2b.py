"""gemma2-2b [arXiv:2408.00118; hf]: alternating local/global + softcaps.

26 layers in the pattern (local, global), d_model=2304, 8 heads (GQA kv=4),
head_dim=256, d_ff=9216 GeGLU, vocab=256000, window 4096, attention logit
softcap 50, final logit softcap 30.
"""

from repro.configs.base import ATTN, LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    block_pattern=(LOCAL, ATTN),
    window=4096,
    mlp="geglu",
    rope_theta=10000.0,
    attn_softcap=50.0,
    final_softcap=30.0,
    scale_embed=True,
    tie_embeddings=True,
    supports_long_context=False,   # global layers attend over the full ctx
)
