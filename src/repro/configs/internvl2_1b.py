"""internvl2-1b [arXiv:2404.16821; hf]: InternViT frontend + 0.5B LM backbone.

24 layers, d_model=896, 14 heads (GQA kv=2), d_ff=4864, vocab=151655.
The vision frontend is a STUB per the task block: input_specs() supplies
precomputed patch embeddings prepended to the token sequence.
"""

from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151655,
    block_pattern=(ATTN,),
    mlp="swiglu",
    rope_theta=1000000.0,
    tie_embeddings=True,
    n_patches=256,
    supports_long_context=False,
)
