"""olmoe-1b-7b [arXiv:2409.02060; hf]: 64-expert top-8 MoE (1B active/7B total).

16 layers, d_model=2048, 16 heads (MHA kv=16), expert d_ff=1024,
vocab=50304, QK-norm.
"""

from repro.configs.base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab=50304,
    block_pattern=(ATTN,),
    mlp="swiglu",
    rope_theta=10000.0,
    qk_norm=True,
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024, router="softmax"),
    supports_long_context=False,
)
