"""mamba2-130m [arXiv:2405.21060]: attention-free SSD (state-space duality).

24 SSD layers, d_model=768, ssm_state=128, head_dim=64 (24 heads at
expand=2), vocab=50280.  O(1)-state decode: runs long_500k.
"""

from repro.configs.base import SSM, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    n_layers=24,
    d_model=768,
    n_heads=1,            # attention-free; unused
    n_kv_heads=1,
    d_ff=0,               # no FFN: the SSD block is the mixer
    vocab=50280,
    block_pattern=(SSM,),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
    supports_long_context=True,
)
