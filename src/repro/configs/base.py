"""Model / shape / run configuration schema shared by all architectures.

Every assigned architecture is expressed as a ``ModelConfig`` whose
``block_pattern`` describes the repeating unit of layers (scanned at
compile time, so a 95-layer model compiles as fast as a 5-layer one).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Block kinds appearing in patterns.
ATTN = "attn"          # global causal self-attention
LOCAL = "local"        # sliding-window causal self-attention
SSM = "ssm"            # Mamba-2 SSD block
RGLRU = "rglru"        # RecurrentGemma RG-LRU recurrent block
ENC_ATTN = "enc_attn"  # bidirectional self-attention (encoder)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    #: capacity factor for the dropping dispatch (tokens per expert buffer).
    capacity_factor: float = 1.25
    #: llama4-style: sigmoid router + a parallel shared expert; olmoe-style:
    #: softmax router, no shared expert.
    router: str = "softmax"          # "softmax" | "sigmoid"
    shared_expert: bool = False
    #: if set, only layers with (index % interleave == interleave - 1) are
    #: MoE; the rest use the dense FFN (llama4 maverick: 2).
    interleave: int = 1


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_conv: int = 4
    #: width of the recurrent branch (RecurrentGemma: d_model rounded to 256).
    lru_width: Optional[int] = None
    block_width: int = 2560


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    #: repeating unit of block kinds; len must divide n_layers (a remainder
    #: tail is allowed and kept unscanned).
    block_pattern: Tuple[str, ...] = (ATTN,)
    head_dim: Optional[int] = None         # default d_model // n_heads
    # attention details
    window: int = 4096                     # LOCAL window size
    rope_theta: float = 500000.0
    attn_softcap: Optional[float] = None   # gemma2: 50.0
    final_softcap: Optional[float] = None  # gemma2: 30.0
    qk_norm: bool = False
    # ffn
    mlp: str = "swiglu"                    # swiglu | geglu | gelu
    moe: Optional[MoEConfig] = None
    # recurrent families
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # families
    family: str = "decoder"                # decoder | encdec | vlm | audio
    norm: str = "rmsnorm"                  # rmsnorm | layernorm
    tie_embeddings: bool = False
    scale_embed: bool = False              # gemma-style sqrt(d) embed scale
    # enc-dec (whisper)
    max_positions: int = 32768             # learned-pos table (whisper decoder)
    n_enc_layers: int = 0
    enc_positions: int = 1500              # audio frames after conv stub
    # vlm stub
    n_patches: int = 256                   # prepended patch embeddings
    # numerics / distribution
    param_dtype: str = "float32"
    moment_dtype: str = "float32"          # bf16 for >=60B models (fits HBM)
    activation_dtype: str = "bfloat16"
    #: run long_500k? only sub-quadratic decode paths (ssm / rglru+local)
    supports_long_context: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def unit_count(self) -> int:
        return self.n_layers // len(self.block_pattern)

    def tail_pattern(self) -> Tuple[str, ...]:
        """Remainder layers not covered by whole pattern units."""
        rem = self.n_layers % len(self.block_pattern)
        return self.block_pattern[:rem]

    def n_params(self) -> int:
        """Analytic parameter count (embeddings + blocks), for rooflines."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        dense_ffn = (3 if self.mlp in ("swiglu", "geglu") else 2) * d * ff
        total = 0
        pattern = list(self.block_pattern) * self.unit_count() + list(self.tail_pattern())
        for i, kind in enumerate(pattern):
            if kind in (ATTN, LOCAL, ENC_ATTN):
                total += attn
                if self.moe is not None and (i % self.moe.interleave == self.moe.interleave - 1):
                    total += 3 * d * self.moe.d_ff_expert * self.moe.n_experts
                    if self.moe.shared_expert:
                        total += dense_ffn
                else:
                    total += dense_ffn
            elif kind == SSM:
                cfg = self.ssm
                di = cfg.d_inner(d)
                nh = cfg.n_heads(d)
                total += d * (2 * di + 2 * cfg.d_state + nh)  # in_proj(z,x,B,C,dt)
                total += di * cfg.d_conv + di * d             # conv + out_proj
            elif kind == RGLRU:
                w = (self.rglru.lru_width or d)
                total += 2 * d * w + w * d                    # in (2 branches) + out
                total += w * self.rglru.d_conv + 2 * w * w + 2 * w  # conv + gates + lambda/D-ish
        total += v * d * (1 if self.tie_embeddings else 2)
        if self.family == "encdec":
            enc_block = attn + dense_ffn
            total += self.n_enc_layers * enc_block
            # decoder cross-attention
            total += self.n_layers * attn
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE counts top_k experts only)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        full_expert = 3 * d * self.moe.d_ff_expert * self.moe.n_experts
        active_expert = 3 * d * self.moe.d_ff_expert * self.moe.top_k
        n_moe_layers = sum(
            1 for i in range(self.n_layers)
            if i % self.moe.interleave == self.moe.interleave - 1
        )
        return self.n_params() - n_moe_layers * (full_expert - active_expert)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One cell of the (arch x input-shape) grid."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Small same-family config for CPU smoke tests."""
    pattern_len = len(cfg.block_pattern)
    n_layers = pattern_len * 2 + (1 if cfg.tail_pattern() else 0) * len(cfg.tail_pattern())
    moe = cfg.moe
    if moe is not None:
        moe = dataclasses.replace(moe, n_experts=min(moe.n_experts, 8),
                                  d_ff_expert=min(moe.d_ff_expert, 128),
                                  top_k=min(moe.top_k, 2),
                                  # smoke tests check prefill/decode parity;
                                  # a generous capacity removes drop noise.
                                  capacity_factor=8.0)
    ssm = cfg.ssm
    if ssm is not None:
        ssm = dataclasses.replace(ssm, d_state=16, head_dim=16, chunk=32)
    rglru = cfg.rglru
    if rglru is not None:
        rglru = dataclasses.replace(rglru, lru_width=64)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=max(pattern_len * 2, len(cfg.block_pattern)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=16,
        d_ff=128,
        vocab=512,
        window=32,
        moe=moe,
        ssm=ssm,
        rglru=rglru,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        enc_positions=16,
        n_patches=8,
    )
