"""deepseek-coder-33b [arXiv:2401.14196; hf]: dense llama-arch code model.

62 layers, d_model=7168, 56 heads (GQA kv=8), d_ff=19200, vocab=32256.
"""

from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab=32256,
    block_pattern=(ATTN,),
    mlp="swiglu",
    rope_theta=100000.0,
    supports_long_context=False,
)
