"""whisper-large-v3 [arXiv:2212.04356]: enc-dec audio transformer backbone.

32 encoder + 32 decoder layers, d_model=1280, 20 heads (kv=20 -> MHA),
d_ff=5120, vocab=51866, GELU MLP, LayerNorm, learned/sinusoidal positions
(no RoPE).  The conv audio frontend is a STUB per the task block:
input_specs() supplies precomputed 1500-frame embeddings.
"""

from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    n_enc_layers=32,
    enc_positions=1500,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    block_pattern=(ATTN,),
    mlp="gelu",
    norm="layernorm",
    tie_embeddings=True,
    rope_theta=0.0,      # absolute positions, no rope
    supports_long_context=False,
)
