"""deepseek-67b [arXiv:2401.02954; hf]: dense llama-arch.

95 layers, d_model=8192, 64 heads (GQA kv=8), d_ff=22016, vocab=102400.
"""

from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab=102400,
    block_pattern=(ATTN,),
    mlp="swiglu",
    rope_theta=10000.0,
    moment_dtype="bfloat16",   # 67B: keep optimizer state within HBM budget
    supports_long_context=False,
)
