"""Architecture registry: one exact public-literature config per file."""

from __future__ import annotations

from typing import Dict

from repro.configs.base import (
    ATTN,
    ENC_ATTN,
    LOCAL,
    RGLRU,
    SSM,
    SHAPES,
    ModelConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
    ShapeConfig,
    reduced_config,
)
from repro.configs.whisper_large_v3 import CONFIG as WHISPER_LARGE_V3
from repro.configs.internvl2_1b import CONFIG as INTERNVL2_1B
from repro.configs.recurrentgemma_2b import CONFIG as RECURRENTGEMMA_2B
from repro.configs.deepseek_coder_33b import CONFIG as DEEPSEEK_CODER_33B
from repro.configs.llama3_2_3b import CONFIG as LLAMA3_2_3B
from repro.configs.deepseek_67b import CONFIG as DEEPSEEK_67B
from repro.configs.gemma2_2b import CONFIG as GEMMA2_2B
from repro.configs.llama4_maverick_400b import CONFIG as LLAMA4_MAVERICK_400B
from repro.configs.olmoe_1b_7b import CONFIG as OLMOE_1B_7B
from repro.configs.mamba2_130m import CONFIG as MAMBA2_130M

ARCHS: Dict[str, ModelConfig] = {
    c.name: c
    for c in (
        WHISPER_LARGE_V3,
        INTERNVL2_1B,
        RECURRENTGEMMA_2B,
        DEEPSEEK_CODER_33B,
        LLAMA3_2_3B,
        DEEPSEEK_67B,
        GEMMA2_2B,
        LLAMA4_MAVERICK_400B,
        OLMOE_1B_7B,
        MAMBA2_130M,
    )
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = [
    "ARCHS",
    "SHAPES",
    "get_config",
    "reduced_config",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "RGLRUConfig",
    "ShapeConfig",
    "ATTN",
    "LOCAL",
    "SSM",
    "RGLRU",
    "ENC_ATTN",
]
