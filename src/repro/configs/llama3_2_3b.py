"""llama3.2-3b [hf:meta-llama/Llama-3.2-3B]: small llama3.

28 layers, d_model=3072, 24 heads (GQA kv=8), d_ff=8192, vocab=128256.
"""

from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=128256,
    block_pattern=(ATTN,),
    mlp="swiglu",
    rope_theta=500000.0,
    tie_embeddings=True,
    supports_long_context=False,
)
