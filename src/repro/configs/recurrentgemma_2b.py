"""recurrentgemma-2b [arXiv:2402.19427; hf]: Griffin (RG-LRU + local attn 1:2).

26 layers in the pattern (recurrent, recurrent, local-attention), d=2560,
10 heads (kv=1 -> MQA), head_dim=256, d_ff=7680 GeGLU, vocab=256000,
window=2048.  Sub-quadratic decode: runs long_500k.
"""

from repro.configs.base import LOCAL, RGLRU, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    block_pattern=(RGLRU, RGLRU, LOCAL),   # 8 full units + (RGLRU, RGLRU) tail
    window=2048,
    mlp="geglu",
    rope_theta=10000.0,
    scale_embed=True,
    tie_embeddings=True,
    rglru=RGLRUConfig(d_conv=4, lru_width=2560),
    supports_long_context=True,
)
