"""Production serving driver: batched requests through the retry-aware
engine (see repro.serving).  ``--smoke`` runs a reduced config on CPU.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --smoke
  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-67b --dry-run
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced_config
from repro.core.retry import RetryPolicy
from repro.serving import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile prefill+decode on the production mesh")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--mechanism", default="pr2ar2")
    ap.add_argument("--tau", type=float, default=0.05)
    args = ap.parse_args()

    if args.dry_run:
        from repro.launch.dryrun import RESULTS_DIR, run_cell

        for shape in ("prefill_32k", "decode_32k"):
            rec = run_cell(args.arch, shape, "single", RESULTS_DIR)
            print(f"dry-run {shape}: {rec.get('status')}")
        return

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced_config(cfg)

    rng = np.random.default_rng(0)
    prompts = [
        rng.integers(2, cfg.vocab, size=rng.integers(4, 12)).astype(np.int32)
        for _ in range(args.batch)
    ]
    engine = ServeEngine(
        cfg, policy=RetryPolicy(args.mechanism), tau=args.tau
    )
    out, stats = engine.generate(prompts, max_new_tokens=args.max_new)
    print(stats.summary())
    for i, row in enumerate(out[: min(4, len(out))]):
        print(f"  req{i}: {row.tolist()}")


if __name__ == "__main__":
    main()
