"""Production training driver: mesh-aware, fault-tolerant, retry-aware.

On a real fleet this process runs per host under the JAX multi-controller
runtime; on this CPU container it runs the same code path on a (1, 1)
mesh with a reduced config (--smoke), proving the wiring end to end:

  mesh -> sharded train state -> flash-tier data + prefetch ->
  jit(train_step with in/out shardings) -> erasure-coded checkpoints ->
  heartbeat/straggler monitor -> elastic restart plan on failure.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch deepseek-67b --dry-run
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig, reduced_config
from repro.core.retry import RetryPolicy
from repro.data import CorpusConfig, FlashTierReader, PrefetchPipeline, SyntheticCorpus
from repro.distributed import steps as ST
from repro.distributed.elastic import plan_mesh
from repro.distributed.fault_tolerance import HeartbeatMonitor, RestartPolicy
from repro.flashsim.config import OperatingCondition
from repro.launch.mesh import make_host_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + local mesh (CPU-runnable)")
    ap.add_argument("--dry-run", action="store_true",
                    help="lower+compile only (production mesh, no execution)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--save-every", type=int, default=10)
    ap.add_argument("--retry-mechanism", default="pr2ar2")
    args = ap.parse_args()

    if args.dry_run:
        from repro.launch.dryrun import RESULTS_DIR, run_cell

        rec = run_cell(args.arch, args.shape, "single", RESULTS_DIR)
        print(f"dry-run ok: {rec.get('status')}")
        return

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    if args.smoke:
        cfg = reduced_config(cfg)
        shape = ShapeConfig("smoke", args.seq or 64, args.batch or 4, "train")
        mesh = make_host_mesh()
    else:
        mesh = make_production_mesh()

    print(f"mesh {dict(mesh.shape)} | arch {cfg.name} | "
          f"{cfg.n_params() / 1e6:.1f}M params")

    step_fn, state_shard = ST.make_train_step(cfg, mesh)
    state_spec, _ = ST.make_train_state_specs(cfg, mesh)

    # init sharded state
    from repro.models.api import build_model
    from repro.optim.adamw import AdamWConfig, init_opt_state

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = init_opt_state(params, AdamWConfig(moment_dtype=cfg.moment_dtype))
    state = {"params": params, "opt": opt}
    state = jax.tree.map(jax.device_put, state, state_shard)

    mgr = CheckpointManager(args.ckpt_dir, keep=2, save_every=args.save_every)
    step0, restored, rstats = mgr.restore_latest(state)
    if step0 is not None:
        state = jax.tree.map(jax.device_put, restored, state_shard)
        print(f"resumed from step {step0} (restore {rstats.wall_s * 1e3:.0f}ms)")
    start = step0 or 0

    corpus = SyntheticCorpus(
        CorpusConfig(vocab=cfg.vocab, seq_len=shape.seq_len,
                     batch=shape.global_batch)
    )
    reader = FlashTierReader(
        corpus, RetryPolicy(args.retry_mechanism),
        OperatingCondition(365.0, 1000.0),
    )

    def read(i):
        b = reader.read(i)
        if cfg.family == "vlm":
            b["patches"] = np.zeros(
                (shape.global_batch, cfg.n_patches, cfg.d_model), np.float32
            ).astype(jnp.dtype(cfg.activation_dtype))
        if cfg.family == "encdec":
            b["audio_embed"] = np.zeros(
                (shape.global_batch, cfg.enc_positions, cfg.d_model), np.float32
            ).astype(jnp.dtype(cfg.activation_dtype))
        return b

    monitor = HeartbeatMonitor(n_workers=jax.process_count())
    restart = RestartPolicy()
    pipe = PrefetchPipeline(read, n_batches=args.steps - start, start_index=start)

    for i, batch in pipe:
        t0 = time.perf_counter()
        try:
            state, metrics = step_fn(state, batch)
        except Exception as e:  # production: XlaRuntimeError etc.
            decision = restart.on_failure(monitor, transient=True)
            print(f"step {i} failed ({e}); decision: {decision.action}")
            if decision.action == "abort":
                raise
            if decision.action == "shrink":
                plan = plan_mesh(
                    jax.device_count() - len(decision.dead_workers),
                    tuple(mesh.shape.values()),
                )
                print("elastic plan:", plan.describe())
                raise SystemExit(3)  # orchestrator restarts with the plan
            continue
        dt = time.perf_counter() - t0
        monitor.beat(jax.process_index(), i, dt)
        if (i + 1) % 5 == 0 or i == start:
            print(f"step {i + 1:4d} loss {float(metrics['loss']):7.4f} "
                  f"{dt:6.2f}s/step", flush=True)
        if mgr.should_save(i + 1):
            host_state = jax.tree.map(np.asarray, state)
            mgr.save(i + 1, host_state)
            print(f"  checkpoint @ {i + 1}", flush=True)

    print("training run complete")


if __name__ == "__main__":
    main()
