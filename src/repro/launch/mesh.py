"""Production meshes (a FUNCTION, so importing never touches device state)."""

from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on mesh construction
    from jax.sharding import AxisType

    def _make_mesh(shape, axes):
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
except ImportError:  # older jax: Auto is the only (implicit) axis type

    def _make_mesh(shape, axes):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod 16x16 (256 chips) or 2-pod 2x16x16 (512 chips).

    The "pod" axis extends data parallelism across the inter-pod links
    (DCN-class); "data" x "model" map onto the intra-pod ICI torus.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return _make_mesh((n // model_parallel, model_parallel), ("data", "model"))
