"""Production meshes (a FUNCTION, so importing never touches device state)."""

from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Single-pod 16x16 (256 chips) or 2-pod 2x16x16 (512 chips).

    The "pod" axis extends data parallelism across the inter-pod links
    (DCN-class); "data" x "model" map onto the intra-pod ICI torus.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_host_mesh(model_parallel: int = 1):
    """Tiny mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh(
        (n // model_parallel, model_parallel),
        ("data", "model"),
        axis_types=(AxisType.Auto, AxisType.Auto),
    )
