import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices build the production meshes, every
cell's step function must lower AND compile, and the compiled artifact
yields the roofline inputs:

  * compiled.memory_analysis()  -> bytes per device (fits in 16 GiB HBM?)
  * compiled.cost_analysis()    -> per-device HLO FLOPs / bytes accessed
  * lowered/compiled HLO text   -> collective operand bytes (parsed here)

Results are written to results/dryrun/<arch>__<shape>__<mesh>.json; the
roofline benchmark (benchmarks/roofline.py) consumes them.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both] [--jobs 2]
"""

import argparse
import dataclasses
import json
import re
import subprocess
import sys
import time
from pathlib import Path

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# bytes per element for HLO shape parsing
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

#: "%name = <types> <op>(", tolerant of layout annotations {2,1,0} inside
#: the type string and of tuple types for -start variants.
_OP_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(",
)

#: replica_groups={{0,1,..},{..}} (explicit) or [G,K]<=[N] (iota) formats.
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shape_bytes(type_str: str) -> int:
    m = _SHAPE_RE.match(type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    if dt not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def _group_size(line: str) -> int:
    """Participants per replica group of a collective op line (1 if absent)."""
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-device collective traffic from the partitioned HLO.

    For each collective op we take the *output* bytes on this device (HLO
    is the per-device module) and apply the ring-algorithm traffic
    multiplier for a group of k participants:

      all-gather        out is the gathered tensor: traffic = out*(k-1)/k
      reduce-scatter    out is the shard:           traffic = out*(k-1)
      all-reduce        out full tensor:            traffic = 2*out*(k-1)/k
      all-to-all        out full tensor:            traffic = out*(k-1)/k
      collective-permute                            traffic = out

    ``bytes`` records raw output bytes; ``traffic`` the ring traffic; the
    roofline's collective term uses traffic / (1 link x 50 GB/s).
    """
    out = {k: 0 for k in _COLLECTIVES}
    traffic = {k: 0.0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_LINE_RE.match(line)
        if m:
            type_str, base, start = m.group(1), m.group(2), m.group(3)
        else:
            continue
        # -done twins carry the same tuple type; only count -start or sync.
        parts = [
            _shape_bytes(p)
            for p in re.findall(r"[a-z0-9]+\[[0-9,]*\]", type_str)
        ]
        if start:
            # async start type is (operand, result, ...): take the result
            # (largest component — exact for all-gather/all-reduce, and the
            # CPU backend emits sync ops anyway).
            total = max(parts) if parts else 0
        else:
            total = sum(parts)
        k = _group_size(line)
        mult = {
            "all-gather": (k - 1) / k,
            "reduce-scatter": float(k - 1),
            "all-reduce": 2.0 * (k - 1) / k,
            "all-to-all": (k - 1) / k,
            "collective-permute": 1.0,
        }[base]
        out[base] += total
        traffic[base] += total * mult
        counts[base] += 1
    return {"bytes": out, "traffic": traffic, "counts": counts}


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             variant: str = "base") -> dict:
    """variant: comma-joined optimization flags applied via env before
    tracing — "base" (paper-faithful XLA baseline), or any of
    {"flash" (Pallas attention via opaque stand-in), "kvint8" (AR² int8
    KV fast tier), "ssdk" (Pallas SSD-scan stand-in)}, e.g.
    "flash+kvint8"."""
    flags = set(variant.split("+")) if variant != "base" else set()
    if flags:
        os.environ["REPRO_OPAQUE_KERNELS"] = "1"
    if "flash" in flags:
        os.environ["REPRO_ATTN_IMPL"] = "flash"
    if "kvint8" in flags:
        os.environ["REPRO_KV_INT8"] = "1"
    if "ssdk" in flags:
        os.environ["REPRO_PALLAS_SSD"] = "opaque"
    if "ep" in flags:
        os.environ["REPRO_MOE_EP"] = "1"

    import jax
    import jax.numpy as jnp

    from repro.configs import SHAPES, get_config
    from repro.distributed.steps import build_cell
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.supports_long_context:
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_kind,
            "status": "skipped",
            "reason": "full-attention decode over 524k ctx is quadratic; "
                      "skipped per task rule (DESIGN.md §6)",
        }
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}__{shape_name}__{mesh_kind}.json").write_text(
            json.dumps(rec, indent=2)
        )
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    jitted, arg_specs, _ = build_cell(cfg, shape, mesh)
    lowered = jitted.lower(*arg_specs)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo_text = compiled.as_text()
    print(f"[{arch} x {shape_name} x {mesh_kind}] memory_analysis: {mem}")
    print(f"[{arch} x {shape_name} x {mesh_kind}] cost_analysis flops="
          f"{cost.get('flops', 0):.3e} bytes={cost.get('bytes accessed', 0):.3e}")
    coll = collective_bytes(hlo_text)

    # Loop-aware re-derivation: cost_analysis counts while (lax.scan)
    # bodies once; hlo_cost multiplies by known_trip_count (see module doc).
    from repro.launch import hlo_cost as HC

    loop_cost = HC.analyze(hlo_text)
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "variant": variant,
        "status": "ok",
        "n_devices": mesh.devices.size,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": loop_cost.flops,
        "bytes_accessed_per_device": loop_cost.bytes,
        "transcendentals": loop_cost.transcendentals,
        "xla_cost_analysis": {
            "flops": cost.get("flops", 0.0),
            "bytes_accessed": cost.get("bytes accessed", 0.0),
            "transcendentals": cost.get("transcendentals", 0.0),
            "note": "while bodies counted once by XLA; see flops_per_device "
                    "for the loop-corrected value",
        },
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        },
        "collectives": {
            "bytes": loop_cost.coll_bytes,
            "traffic": loop_cost.coll_traffic,
            "counts": loop_cost.coll_counts,
        },
        "collectives_loop_body_once": coll,
        "model": {
            "n_params": cfg.n_params(),
            "n_active_params": cfg.n_active_params(),
        },
        "shape_cfg": dataclasses.asdict(shape),
    }
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = "" if variant == "base" else f"__{variant}"
    path = out_dir / f"{arch}__{shape_name}__{mesh_kind}{tag}.json"
    path.write_text(json.dumps(rec, indent=2))
    print(f"wrote {path}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="base",
                    help="optimization flags, e.g. flash+kvint8 (see run_cell)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=1,
                    help="parallel compile subprocesses for --all")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if not args.all:
        assert args.arch and args.shape
        for m in meshes:
            rec = run_cell(args.arch, args.shape, m, RESULTS_DIR, args.variant)
            print(json.dumps(rec.get("memory", rec), indent=2))
        return

    from repro.configs import ARCHS, SHAPES  # safe: no device use

    cells = [
        (a, s, m)
        for a in sorted(ARCHS)
        for s in SHAPES
        for m in meshes
    ]
    if args.skip_existing:
        cells = [
            (a, s, m) for (a, s, m) in cells
            if not (RESULTS_DIR / f"{a}__{s}__{m}.json").exists()
        ]
    print(f"{len(cells)} cells to run")
    procs = []
    results = []

    def drain(block_until_below: int):
        while len(procs) >= max(block_until_below, 1):
            for p, tag in list(procs):
                if p.poll() is not None:
                    procs.remove((p, tag))
                    results.append((tag, p.returncode))
                    status = "OK" if p.returncode == 0 else f"FAIL({p.returncode})"
                    print(f"  [{len(results)}/{len(cells)}] {tag}: {status}", flush=True)
            time.sleep(1.0)

    for a, s, m in cells:
        drain(args.jobs)
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", a, "--shape", s, "--mesh", m,
        ]
        log = RESULTS_DIR / f"{a}__{s}__{m}.log"
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        p = subprocess.Popen(
            cmd, stdout=log.open("w"), stderr=subprocess.STDOUT,
            env={**os.environ, "PYTHONPATH": "src"},
        )
        procs.append((p, f"{a}x{s}x{m}"))
    drain(1)
    while procs:
        drain(1)
    failures = [t for t, rc in results if rc != 0]
    print(f"\ndone: {len(results) - len(failures)} ok, {len(failures)} failed")
    for f in failures:
        print("  FAILED:", f)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
