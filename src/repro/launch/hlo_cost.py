"""Loop-aware cost analysis over compiled HLO text.

``compiled.cost_analysis()`` counts ``while`` bodies ONCE — for scanned
layer stacks (every model here scans its units, and autodiff adds a second
while for the backward pass) that under-counts FLOPs/bytes/collectives by
the trip count (e.g. 95x for deepseek-67b).  This analyzer re-derives the
three roofline inputs from ``compiled.as_text()`` with correct multipliers:

  * every computation's local cost is summed from its instruction lines
    (dot FLOPs are exact: 2 * prod(batch+free dims) * prod(contracting);
    elementwise ~1 flop/elem; reduce ~input elems);
  * fusions charge bytes at the call site (operands + output — XLA's own
    convention) and flops from the fused computation's body;
  * ``while`` children multiply by ``backend_config.known_trip_count``
    (present for every lax.scan; falls back to the condition's compare
    constant, then 1);
  * collectives accumulate (bytes, ring traffic, count) with the same
    multipliers — traffic uses per-op replica-group ring factors.

The result is per-device (the HLO module is the partitioned one).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "sign", "compare", "select", "and", "or", "xor", "not",
    "convert", "floor", "ceil", "round-nearest-afz", "round-nearest-even",
    "clamp", "remainder", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "is-finite", "add-dependency", "atan2",
    "stochastic-convert",
}
_TRANSCENDENTAL = {
    "exponential", "tanh", "log", "log-plus-one", "exponential-minus-one",
    "rsqrt", "sqrt", "power", "logistic", "cosine", "sine", "tan", "erf",
    "cbrt",
}
_ZERO_COST = {
    "parameter", "constant", "bitcast", "tuple", "get-tuple-element",
    "after-all", "iota", "broadcast", "reshape", "partition-id",
    "replica-id", "rng-get-and-update-state", "optimization-barrier",
    "infeed", "outfeed", "domain",
}
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_COMP_START_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$"
)
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%([\w.\-]+),\s*body=%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")


def _shapes_of(type_str: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, tuple(int(d) for d in dims.split(",") if d)))
    return out


def _nelems(shapes) -> int:
    total = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = _DTYPE_BYTES[dt]
        for d in dims:
            n *= d
        total += n
    return total


def _split_operands(s: str) -> List[str]:
    """Top-level %name operands of 'opcode(...' up to the closing paren.

    Depending on the XLA version, operands print bare (``%x.1``) or with
    an inline type (``f32[64,64]{1,0} %x.1``) — take the trailing %token
    of each top-level comma field either way.
    """
    out, depth = [], 0
    cur = ""
    for ch in s:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                if cur.strip():
                    out.append(cur.strip())
                break
            depth -= 1
        elif ch == "," and depth == 0:
            if cur.strip():
                out.append(cur.strip())
            cur = ""
            continue
        cur += ch
    names = []
    for o in out:
        tok = o.split()[-1]
        if tok.startswith("%"):
            names.append(tok.lstrip("%"))
    return names


def _group_size(line: str) -> int:
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    return 1


def _opaque_kernel_cost(ins, symtab, operands):
    """Analytic FLOPs for opaque kernel stand-ins (kernels/opaque.py).

    A pallas_call on TPU — and its pure_callback stand-in here — is one
    custom-call whose HBM bytes are operands + results; internal tiles
    live in VMEM.  The marker (length of the last tuple component) says
    which kernel, so FLOPs come from the operand shapes analytically.
    Unknown custom-calls fall back to bytes-only, zero flops.
    """
    out_shapes = ins.shapes
    in_bytes = sum(_nbytes(symtab.get(o, [])) for o in operands)
    out_bytes = _nbytes(out_shapes)
    marker = out_shapes[-1][1][0] if (
        out_shapes and len(out_shapes[-1][1]) == 1
    ) else 0
    # exclude the marker vector itself from byte accounting
    bytes_total = in_bytes + out_bytes - 4 * marker

    opshape = [symtab.get(o, []) for o in operands]
    flops = 0.0
    if marker in (101, 102, 103, 104) or marker >= 10000:
        # flash attention: q (B,T,K,G,hd); k (B,S,K,hd)
        q = opshape[0][0][1] if opshape and opshape[0] else None
        k = opshape[1][0][1] if len(opshape) > 1 and opshape[1] else None
        if q and k and len(q) == 5:
            B, T, K, G, hd = q
            S = k[1]
            if marker >= 10000:
                w = marker % 10000
                S_eff = min(w, S)
                frac = 1.0
                bwd = marker >= 20000
            else:
                S_eff = S
                frac = 0.5 if marker in (101, 102) else 1.0
                bwd = marker in (102, 104)
            fwd_flops = 2.0 * 2.0 * B * T * K * G * hd * S_eff * frac
            flops = fwd_flops * (2.5 if bwd else 1.0)
    elif marker in (401, 402):
        # decode attention: q (B,1,K,G,hd); ck (B,K,S,hd)
        q = opshape[0][0][1] if opshape and opshape[0] else None
        ck = opshape[1][0][1] if len(opshape) > 1 and opshape[1] else None
        if q and ck:
            B, _, K, G, hd = q
            S = ck[2]
            flops = 2.0 * 2.0 * B * K * G * hd * S
    elif 30000 <= marker < 50000:
        # ssd scan: x (B,T,nh,hd); B (B,T,ds); chunk L = marker % 10000
        x = opshape[0][0][1] if opshape and opshape[0] else None
        bm = opshape[1][0][1] if len(opshape) > 1 and opshape[1] else None
        if x and bm:
            B, T, nh, hd = x
            ds = bm[-1]
            L = marker % 10000
            fwd = B * nh * T * (2.0 * L * (ds + hd) + 4.0 * ds * hd)
            flops = fwd * (3.0 if marker >= 40000 else 1.0)
    return flops, max(bytes_total, 0)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    transcendentals: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES}
    )
    coll_traffic: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES}
    )
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in _COLLECTIVES}
    )

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.bytes += other.bytes * mult
        for c in _COLLECTIVES:
            self.coll_bytes[c] += other.coll_bytes[c] * mult
            self.coll_traffic[c] += other.coll_traffic[c] * mult
            self.coll_counts[c] += other.coll_counts[c] * mult


@dataclasses.dataclass
class _Instr:
    name: str
    shapes: list
    opcode: str
    rest: str                 # operands + attrs tail of the line


@dataclasses.dataclass
class _Computation:
    name: str
    params: Dict[str, list]
    instrs: List[_Instr]
    is_entry: bool = False
    is_fusion_body: bool = False

    @property
    def root_opcode(self) -> str:
        return self.instrs[-1].opcode if self.instrs else ""

    @property
    def contains_dus(self) -> bool:
        return any(i.opcode == "dynamic-update-slice" for i in self.instrs)

    @property
    def contains_ds(self) -> bool:
        return any(i.opcode == "dynamic-slice" for i in self.instrs)


def _parse(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    entry_name = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_START_RE.match(line)
            if m:
                is_entry, name, sig = bool(m.group(1)), m.group(2), m.group(3)
                params: Dict[str, list] = {}
                # split signature on top-level commas
                depth, curtok, toks = 0, "", []
                for ch in sig:
                    if ch in "([":
                        depth += 1
                    elif ch in ")]":
                        depth -= 1
                    if ch == "," and depth == 0:
                        toks.append(curtok)
                        curtok = ""
                    else:
                        curtok += ch
                if curtok.strip():
                    toks.append(curtok)
                for t in toks:
                    if ":" in t:
                        pname, ptype = t.split(":", 1)
                        params[pname.strip().lstrip("%")] = _shapes_of(ptype)
                cur = _Computation(name, params, [], is_entry)
                if is_entry:
                    entry_name = name
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            cur.instrs.append(
                _Instr(m.group(1), _shapes_of(m.group(2)), m.group(3), m.group(4))
            )
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _mark_fusion_bodies(comps: Dict[str, _Computation]):
    for comp in list(comps.values()):
        for ins in comp.instrs:
            if ins.opcode == "fusion":
                m = _CALLS_RE.search(ins.rest)
                if m and m.group(1) in comps:
                    comps[m.group(1)].is_fusion_body = True


def _local_and_children(
    comp: _Computation, comps: Dict[str, _Computation]
) -> Tuple[Cost, List[Tuple[str, float, str]]]:
    """Local cost + (child computation, multiplier, kind) edges."""
    cost = Cost()
    symtab: Dict[str, list] = dict(comp.params)
    children: List[Tuple[str, float, str]] = []
    for ins in comp.instrs:
        symtab[ins.name] = ins.shapes
        op = ins.opcode
        out_elems = _nelems(ins.shapes)
        out_bytes = _nbytes(ins.shapes)
        operands = _split_operands(ins.rest)
        in_bytes = sum(_nbytes(symtab.get(o, [])) for o in operands)

        if op == "custom-call":
            fl, by = _opaque_kernel_cost(ins, symtab, operands)
            cost.flops += fl
            cost.bytes += by
            continue
        base = op[:-6] if op.endswith("-start") else op
        if base in _COLLECTIVES:
            if op.endswith("-start"):
                sizes = [_nbytes([s]) for s in ins.shapes] or [0]
                size = max(sizes)
            else:
                size = out_bytes
            k = _group_size(ins.rest)
            mult = {
                "all-gather": (k - 1) / k,
                "reduce-scatter": float(k - 1),
                "all-reduce": 2.0 * (k - 1) / k,
                "all-to-all": (k - 1) / k,
                "collective-permute": 1.0,
            }[base]
            cost.coll_bytes[base] += size
            cost.coll_traffic[base] += size * mult
            cost.coll_counts[base] += 1
            cost.bytes += out_bytes + in_bytes
            continue
        if op.endswith("-done") or op in _ZERO_COST:
            continue
        if op == "while":
            m = _WHILE_RE.search(ins.rest)
            trip = 1.0
            tm = _TRIP_RE.search(ins.rest)
            if tm:
                trip = float(tm.group(1))
            if m:
                children.append((m.group(2), trip, "while-body"))
                children.append((m.group(1), trip, "while-cond"))
            continue
        if op in ("call", "async-call"):
            m = _TO_APPLY_RE.search(ins.rest)
            if m:
                children.append((m.group(1), 1.0, "call"))
            continue
        if op == "conditional":
            for m in re.finditer(r"%([\w.\-]+)", ins.rest):
                if m.group(1) in comps:
                    children.append((m.group(1), 1.0, "branch"))
            continue
        if op == "fusion":
            m = _CALLS_RE.search(ins.rest)
            body = comps.get(m.group(1)) if m else None
            if m:
                children.append((m.group(1), 1.0, "fusion"))
            sizes = [_nbytes(symtab.get(o, [])) for o in operands]
            buf = max(sizes) if sizes else 0
            if body is not None and body.contains_dus and buf >= 0.5 * out_bytes:
                # In-place slice write (scan-stacked caches/accumulators,
                # possibly with a fused convert/select around the DUS):
                # charge 2x the non-buffer operands (update slice +
                # indices), not the full buffer — XLA-style in+out
                # accounting would count the whole stacked buffer every
                # loop iteration, inflating bytes ~O(trip count)x.
                cost.bytes += 2.0 * max(in_bytes - buf, 0)
            elif body is not None and body.contains_ds and buf > 2.0 * out_bytes:
                # Slice read: 2x the slice, not the full source buffer.
                cost.bytes += 2.0 * out_bytes + max(in_bytes - buf, 0)
            else:
                cost.bytes += out_bytes + in_bytes
            continue
        if op == "dot":
            lhs = symtab.get(operands[0], []) if operands else []
            cdims = _LHS_CDIMS_RE.search(ins.rest)
            contract = 1
            if lhs and cdims:
                dims = lhs[0][1]
                for i in (int(x) for x in cdims.group(1).split(",") if x):
                    if i < len(dims):
                        contract *= dims[i]
            cost.flops += 2.0 * out_elems * contract
            if not comp.is_fusion_body:
                cost.bytes += out_bytes + in_bytes
            continue
        if op == "convolution":
            # kernel elems per output: prod(kernel dims) excl. out-features
            rhs = symtab.get(operands[1], []) if len(operands) > 1 else []
            kelems = 1
            if rhs:
                for d in rhs[0][1]:
                    kelems *= d
                # divide by output-feature dim (last by convention)
                kelems = max(kelems // max(ins.shapes[0][1][-1], 1), 1)
            cost.flops += 2.0 * out_elems * kelems
            if not comp.is_fusion_body:
                cost.bytes += out_bytes + in_bytes
            continue
        if op == "dynamic-slice":
            if not comp.is_fusion_body:
                cost.bytes += 2.0 * out_bytes
            continue
        if op == "dynamic-update-slice":
            if not comp.is_fusion_body:
                sizes = [_nbytes(symtab.get(o, [])) for o in operands]
                buf = max(sizes) if sizes else 0
                cost.bytes += 2.0 * max(in_bytes - buf, 0)
            continue
        if op in ("reduce", "reduce-window", "scatter", "gather", "sort",
                  "pad", "slice",
                  "concatenate", "transpose", "copy", "reverse", "map",
                  "select-and-scatter", "rng-bit-generator", "cumsum",
                  "clz", "popcnt"):
            in_elems = sum(_nelems(symtab.get(o, [])) for o in operands)
            if op in ("reduce", "reduce-window", "select-and-scatter", "map"):
                cost.flops += float(in_elems)
            if not comp.is_fusion_body:
                cost.bytes += out_bytes + in_bytes
            continue
        if op in _TRANSCENDENTAL:
            cost.flops += float(out_elems)
            cost.transcendentals += float(out_elems)
            if not comp.is_fusion_body:
                cost.bytes += out_bytes + in_bytes
            continue
        # default: elementwise-ish
        cost.flops += float(out_elems)
        if not comp.is_fusion_body:
            cost.bytes += out_bytes + in_bytes
    return cost, children


def analyze(text: str) -> Cost:
    comps = _parse(text)
    _mark_fusion_bodies(comps)
    memo: Dict[str, Cost] = {}

    def total(name: str, stack=()) -> Cost:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return Cost()
        local, children = _local_and_children(comps[name], comps)
        out = Cost()
        out.add(local)
        for child, mult, _kind in children:
            out.add(total(child, stack + (name,)), mult)
        memo[name] = out
        return out

    return total("__entry__")


def breakdown(text: str, top: int = 20):
    """Per-opcode byte/flop attribution with loop multipliers — the
    'profile' view the perf hillclimb reasons from."""
    import collections

    comps = _parse(text)
    _mark_fusion_bodies(comps)
    bytes_by = collections.Counter()
    flops_by = collections.Counter()

    def walk(name, mult, stack=()):
        if name not in comps or name in stack:
            return
        comp = comps[name]
        local, children = _local_and_children(comp, comps)
        # attribute local costs per-instruction by re-walking
        symtab = dict(comp.params)
        for ins in comp.instrs:
            symtab[ins.name] = ins.shapes
            op = ins.opcode
            out_b = _nbytes(ins.shapes)
            operands = _split_operands(ins.rest)
            in_b = sum(_nbytes(symtab.get(o, [])) for o in operands)
            base = op[:-6] if op.endswith("-start") else op
            key = op
            if op == "custom-call":
                fl, by = _opaque_kernel_cost(ins, symtab, operands)
                bytes_by["custom-call(kernel)"] += by * mult
                flops_by["custom-call(kernel)"] += fl * mult
                continue
            if op == "fusion":
                m = _CALLS_RE.search(ins.rest)
                body = comps.get(m.group(1)) if m else None
                root = body.root_opcode if body else ""
                key = f"fusion:{root}"
                sizes = [_nbytes(symtab.get(o, [])) for o in operands]
                buf = max(sizes) if sizes else 0
                if body is not None and body.contains_dus and buf >= 0.5 * out_b:
                    bytes_by[key + "(inplace)"] += 2.0 * max(in_b - buf, 0) * mult
                elif body is not None and body.contains_ds and buf > 2.0 * out_b:
                    bytes_by[key + "(slice)"] += (
                        2.0 * out_b + max(in_b - buf, 0)
                    ) * mult
                else:
                    bytes_by[key] += (out_b + in_b) * mult
            elif base in _COLLECTIVES:
                bytes_by[f"collective:{base}"] += (out_b + in_b) * mult
            elif op in _ZERO_COST or op.endswith("-done") or op in (
                "while", "call", "conditional", "async-call"
            ):
                pass
            elif not comp.is_fusion_body:
                if op == "dynamic-slice":
                    bytes_by[op] += 2.0 * out_b * mult
                elif op == "dynamic-update-slice":
                    sizes = [_nbytes(symtab.get(o, [])) for o in operands]
                    buf = max(sizes) if sizes else 0
                    bytes_by[op] += 2.0 * max(in_b - buf, 0) * mult
                else:
                    bytes_by[op] += (out_b + in_b) * mult
            if op == "dot":
                lhs = symtab.get(operands[0], []) if operands else []
                cd = _LHS_CDIMS_RE.search(ins.rest)
                contract = 1
                if lhs and cd:
                    dims = lhs[0][1]
                    for i in (int(x) for x in cd.group(1).split(",") if x):
                        if i < len(dims):
                            contract *= dims[i]
                flops_by["dot"] += 2.0 * _nelems(ins.shapes) * contract * mult
        for child, cmult, kind in children:
            # fusion bodies: bytes were charged at the call site; walking
            # them is still needed for dot flops (is_fusion_body guards
            # byte double-counting).
            walk(child, mult * cmult, stack + (name,))

    walk("__entry__", 1.0)
    return bytes_by.most_common(top), flops_by.most_common(top)


def as_dict(cost: Cost) -> dict:
    return {
        "flops": cost.flops,
        "transcendentals": cost.transcendentals,
        "bytes": cost.bytes,
        "collectives": {
            "bytes": dict(cost.coll_bytes),
            "traffic": dict(cost.coll_traffic),
            "counts": dict(cost.coll_counts),
        },
    }
