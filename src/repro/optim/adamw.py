"""AdamW with dtype-configurable moments and global-norm clipping.

Moments inherit the parameter sharding (ZeRO by construction: params are
FSDP x TP sharded, so optimizer state is too).  ``moment_dtype`` is a
memory knob for the >=60B configs (bf16 moments halve optimizer HBM).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    moment_dtype: str = "float32"


def init_opt_state(params, cfg: AdamWConfig):
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads, opt_state, params, cfg: AdamWConfig, lr_scale: jax.Array = 1.0
) -> Tuple[Any, Any, dict]:
    """-> (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1.0 - b1)
        v32 = v.astype(jnp.float32) * b2 + jnp.square(g32) * (1.0 - b2)
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (
            (p.astype(jnp.float32) - lr * delta).astype(p.dtype),
            m32.astype(mdt),
            v32.astype(mdt),
        )

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    # out is a pytree of 3-tuples; unzip.
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}


def cosine_schedule(step, total_steps: int, warmup: int = 100, floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / max(warmup, 1), 1.0)
    prog = jnp.clip((s - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
    cos = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return warm * cos
