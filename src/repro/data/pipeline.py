"""Double-buffered host->device input pipeline (the PR² discipline applied
to the training input feed).

A background thread produces batch i+1 (synthetic generation + simulated
flash-tier read) while the training step consumes batch i — the same
producer/consumer overlap as CACHE READ: generation/read never sits on the
step critical path unless the producer genuinely falls behind, and the
observable stall time is recorded.
"""

from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Iterator, Optional

import jax


class PrefetchPipeline:
    """Iterate device-ready batches with a bounded lookahead."""

    def __init__(
        self,
        read_fn: Callable[[int], dict],   # index -> host batch dict
        n_batches: int,
        depth: int = 2,
        device_put: bool = True,
        start_index: int = 0,
    ):
        self.read_fn = read_fn
        self.n_batches = n_batches
        self.depth = depth
        self.device_put = device_put
        self.start_index = start_index
        self.stall_s = 0.0                # time the consumer waited
        self.produce_s = 0.0              # producer busy time (overlapped)
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._thread: Optional[threading.Thread] = None

    def _producer(self):
        for i in range(self.start_index, self.start_index + self.n_batches):
            t0 = time.perf_counter()
            batch = self.read_fn(i)
            if self.device_put:
                batch = jax.tree.map(jax.device_put, batch)
            self.produce_s += time.perf_counter() - t0
            self._q.put((i, batch))
        self._q.put((None, None))

    def __iter__(self) -> Iterator:
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()
        while True:
            t0 = time.perf_counter()
            i, batch = self._q.get()
            self.stall_s += time.perf_counter() - t0
            if i is None:
                break
            yield i, batch
        self._thread.join()
