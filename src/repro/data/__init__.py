from repro.data.corpus import CorpusConfig, SyntheticCorpus
from repro.data.flash_tier import FlashReadStats, FlashTierReader
from repro.data.pipeline import PrefetchPipeline

__all__ = [
    "CorpusConfig", "SyntheticCorpus",
    "FlashTierReader", "FlashReadStats",
    "PrefetchPipeline",
]
