"""Flash-tier reader: training batches served through the simulated SSD.

THE PAPER TIE-IN for the training data path: every batch is striped over
the simulated SSD's dies as 16 KiB page reads; per-page retry attempt
counts are sampled from the 160-chip characterization histograms for the
configured operating condition, and per-page latency follows the
``RetryPolicy`` mechanism (baseline / SOTA / PR² / AR² / PR²+AR²).

The simulated batch fetch latency is

    max over dies of  sum of page read latencies on that die

(dies operate in parallel; pages on one die serialize), which is the
steady-state behaviour of the full DES in repro.flashsim without paying
its event-queue cost per training step.  The reader reports cumulative
simulated read time so examples can quantify input-pipeline stall per
mechanism — the training-side counterpart of the paper's response-time
results.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core import characterize as CH
from repro.core import timing as T
from repro.core.retry import RetryPolicy
from repro.data.corpus import SyntheticCorpus
from repro.flashsim.config import DEFAULT_SSD, OperatingCondition, SSDConfig

PAGE_BYTES = 16 * 1024
PAGE_TYPES = ("lsb", "csb", "msb")


@dataclasses.dataclass
class FlashReadStats:
    batches: int = 0
    pages: int = 0
    attempts: int = 0
    sim_read_us: float = 0.0          # simulated wall time spent in reads

    @property
    def mean_batch_us(self) -> float:
        return self.sim_read_us / self.batches if self.batches else 0.0


class FlashTierReader:
    """corpus[i] + simulated SSD latency under a retry policy."""

    def __init__(
        self,
        corpus: SyntheticCorpus,
        policy: RetryPolicy = RetryPolicy("pr2ar2"),
        condition: OperatingCondition = OperatingCondition(),
        ssd: SSDConfig = DEFAULT_SSD,
        seed: int = 0,
    ):
        self.corpus = corpus
        self.policy = policy
        self.cond = condition
        self.ssd = ssd
        self.rng = np.random.default_rng(seed)
        self.stats = FlashReadStats()

        if policy.adaptive_tr:
            self.tr_scale = (
                CH.lookup_tr_scale(condition.retention_days, condition.pec)
                if policy.tr_scale == "auto"
                else float(policy.tr_scale)
            )
        else:
            self.tr_scale = 1.0
        self._cdfs = {}
        for pt in PAGE_TYPES:
            hist = CH.attempt_histogram(
                condition.retention_days, condition.pec, page_type=pt,
                sota=policy.sota_start, tr_scale=self.tr_scale,
            )
            self._cdfs[pt] = np.cumsum(hist)

    def _batch_latency_us(self, nbytes: int) -> float:
        n_pages = max(-(-nbytes // PAGE_BYTES), 1)
        ptypes = self.rng.integers(0, 3, n_pages)
        dies = self.rng.integers(0, self.ssd.n_dies, n_pages)
        u = self.rng.random(n_pages)
        per_die = np.zeros(self.ssd.n_dies)
        for i in range(n_pages):
            pt = PAGE_TYPES[ptypes[i]]
            a = max(int(np.searchsorted(self._cdfs[pt], u[i])), 1)
            lat = float(
                T.read_latency(
                    a, self.policy.mechanism, page_type=pt,
                    tr_scale=self.tr_scale,
                )
            )
            per_die[dies[i]] += lat
            self.stats.attempts += a
        self.stats.pages += n_pages
        return float(per_die.max()) + self.ssd.host_overhead_us

    def read(self, index: int) -> Dict[str, np.ndarray]:
        """Returns the batch dict with simulated latency charged to stats."""
        batch = self.corpus.batch(index)
        us = self._batch_latency_us(self.corpus.nbytes_per_batch())
        self.stats.batches += 1
        self.stats.sim_read_us += us
        return batch
