"""Deterministic synthetic training corpus.

Document-structured token stream with a Zipfian unigram distribution and
per-document Markov locality (tokens repeat within a document with
probability ``stickiness``) — enough statistical texture that the LM loss
decreases meaningfully during the examples' short training runs, while
staying fully deterministic per (seed, batch index): batch i is always the
same array, so data-parallel workers and checkpoint/restart replays are
reproducible by construction (the restart driver re-reads batch i, not
"the next batch").
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CorpusConfig:
    vocab: int
    seq_len: int
    batch: int
    seed: int = 0
    doc_len_mean: float = 384.0
    zipf_a: float = 1.2
    stickiness: float = 0.35
    bos_id: int = 1


class SyntheticCorpus:
    """Indexable batch source: corpus[i] -> {"tokens","labels"} int32."""

    def __init__(self, cfg: CorpusConfig):
        self.cfg = cfg
        # Precompute the Zipf unigram table once (vocab-sized).
        ranks = np.arange(1, cfg.vocab, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = p / p.sum()

    def batch(self, index: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, index])
        )
        n = cfg.batch * (cfg.seq_len + 1)
        toks = rng.choice(cfg.vocab - 1, size=n, p=self._p).astype(np.int32) + 1

        # Markov locality: with prob stickiness, copy a recent token.
        sticky = rng.random(n) < cfg.stickiness
        back = rng.integers(1, 32, n)
        idx = np.arange(n) - back
        valid = sticky & (idx >= 0)
        toks[valid] = toks[idx[valid]]

        # Document boundaries: geometric lengths, BOS restarts.
        n_docs = max(int(n / cfg.doc_len_mean), 1)
        starts = np.sort(rng.integers(0, n, n_docs))
        toks[starts] = cfg.bos_id

        seq = toks.reshape(cfg.batch, cfg.seq_len + 1)
        return {"tokens": seq[:, :-1].copy(), "labels": seq[:, 1:].copy()}

    def __getitem__(self, index: int) -> dict:
        return self.batch(index)

    def nbytes_per_batch(self) -> int:
        return self.cfg.batch * self.cfg.seq_len * 4 * 2  # tokens + labels
