"""Quantized, margin-aware KV store — the AR²/PR² adaptation for serving.

The decode-time KV working set is the serving analogue of the paper's
flash page: its read cost ("tR") is HBM bytes.  The store keeps every
attention cache leaf in two tiers:

  * fast tier: per-page symmetric int8 (a page = one sequence position's
    (kv_heads x head_dim) vector per unit/batch) — 4x fewer bytes, the
    reduced-tR read;
  * backing tier: the original bf16/f32 copy — the full-tR fallback.

A read returns the fast tier wherever the page's quantization-error bound
sits within the margin tolerance (the ECC-capability-margin analogue) and
*retries* from backing elsewhere — fused select in kernels/kv_retry, so
the retry overlaps the fast read like CACHE READ overlaps sensing with
transfer.  Non-attention cache leaves (SSM states, conv windows, RG-LRU
states) are O(1)-sized and stay unquantized — the degenerate case noted in
DESIGN.md §6 for attention-free architectures.

``RetryPolicy`` integration: mechanism "baseline" always reads backing
(no fast tier); the PR²/AR² mechanisms enable the fast tier; ``tau``
plays the role of the characterized safe-tR table entry.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.retry import RetryPolicy
from repro.kernels.kv_retry.ops import kv_read_with_retry, quantize_pages


@dataclasses.dataclass
class KVReadStats:
    pages: int = 0
    fast_pages: int = 0              # served from int8 within margin
    retried_pages: int = 0           # re-read from backing
    fast_bytes: int = 0
    backing_bytes: int = 0

    @property
    def fast_fraction(self) -> float:
        return self.fast_pages / self.pages if self.pages else 0.0

    @property
    def bytes_saved_fraction(self) -> float:
        """HBM traffic saved vs an always-backing read."""
        full = (self.fast_bytes + self.backing_bytes) * 4  # backing is 4B/elt
        if not full:
            return 0.0
        moved = self.fast_bytes + 4 * self.backing_bytes
        return 1.0 - moved / full


def _is_kv_leaf(path) -> bool:
    keys = [str(getattr(p, "key", getattr(p, "idx", ""))) for p in path]
    return any(k in ("attn", "xattn") for k in keys) and keys[-1] in ("k", "v")


class QuantizedKVStore:
    """Two-tier KV cache with margin-aware retry reads."""

    def __init__(self, policy: RetryPolicy = RetryPolicy("pr2ar2"),
                 tau: float = 0.05):
        self.policy = policy
        self.tau = tau
        self.fast: Dict[str, Tuple[jax.Array, jax.Array]] = {}
        self.backing: Any = None
        self.stats = KVReadStats()

    # -- pack ---------------------------------------------------------------

    def pack(self, cache: Any) -> None:
        """Ingest a prefill cache pytree (quantize attention leaves)."""
        self.backing = cache
        self.fast.clear()
        if not self.policy.adaptive_tr:
            return  # baseline: no fast tier
        flat = jax.tree_util.tree_flatten_with_path(cache)[0]
        for path, leaf in flat:
            if not _is_kv_leaf(path) or leaf.ndim < 2:
                continue
            key = jax.tree_util.keystr(path)
            pages = leaf.reshape(-1, leaf.shape[-1])
            q, s = quantize_pages(pages)
            self.fast[key] = (q, s)

    # -- read ------------------------------------------------------------------

    def materialize(self) -> Any:
        """Cache pytree for the next decode step, reading through the
        fast tier with margin-aware retry."""
        if not self.fast:
            return self.backing

        def read(path, leaf):
            key = jax.tree_util.keystr(path)
            if key not in self.fast:
                return leaf
            q, s = self.fast[key]
            backing_pages = leaf.reshape(-1, leaf.shape[-1])
            out, margin = kv_read_with_retry(q, s, backing_pages, tau=self.tau)
            took_fast = np.asarray(margin[:, 0] >= 0.0)
            n = took_fast.size
            self.stats.pages += n
            self.stats.fast_pages += int(took_fast.sum())
            self.stats.retried_pages += int(n - took_fast.sum())
            elt = leaf.shape[-1]
            self.stats.fast_bytes += int(took_fast.sum()) * elt
            self.stats.backing_bytes += int(n - took_fast.sum()) * elt
            return out.reshape(leaf.shape).astype(leaf.dtype)

        return jax.tree_util.tree_map_with_path(read, self.backing)

    # -- update ---------------------------------------------------------------

    def update(self, new_cache: Any) -> None:
        """Adopt the post-decode cache (re-quantize attention leaves).

        Production note: on TPU this is an incremental one-page update (the
        new token's column); re-quantizing whole leaves here keeps the CPU
        reference simple and bit-identical.
        """
        self.pack(new_cache)
