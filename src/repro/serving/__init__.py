from repro.serving.engine import ServeEngine, ServeStats
from repro.serving.kv_store import KVReadStats, QuantizedKVStore

__all__ = ["ServeEngine", "ServeStats", "QuantizedKVStore", "KVReadStats"]
