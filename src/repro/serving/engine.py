"""Batched serving engine with retry-aware KV reads.

A production-shaped (but CPU-runnable) serving loop:

  admit(prompts) -> prefill (one batched pass) -> decode loop
                     |                              |
                     v                              v
              QuantizedKVStore.pack()        materialize() -> decode_step
                                              -> update() + sample

Requests of unequal length are left-padded to the batch maximum so the
KV cache is rectangular (standard static-batch serving).  Per-token and
per-request latency statistics are recorded; the KV store's read stats
quantify the AR² fast-read fraction and HBM bytes saved.

The engine honours ``RetryPolicy``: "baseline" serves every read from the
full-precision backing tier; the PR²/AR² mechanisms serve margin-cleared
pages from int8.  Greedy sampling keeps outputs deterministic for tests.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.retry import RetryPolicy
from repro.models.api import build_model
from repro.serving.kv_store import KVReadStats, QuantizedKVStore


@dataclasses.dataclass
class ServeStats:
    n_requests: int
    prompt_tokens: int
    generated_tokens: int
    prefill_s: float
    decode_s: float
    tokens_per_s: float
    kv: KVReadStats

    def summary(self) -> str:
        return (
            f"reqs={self.n_requests} prompt={self.prompt_tokens}tok "
            f"gen={self.generated_tokens}tok prefill={self.prefill_s * 1e3:.1f}ms "
            f"decode={self.decode_s * 1e3:.1f}ms ({self.tokens_per_s:.1f} tok/s) "
            f"kv_fast={100 * self.kv.fast_fraction:.1f}% "
            f"hbm_saved={100 * self.kv.bytes_saved_fraction:.1f}%"
        )


class ServeEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params=None,
        policy: RetryPolicy = RetryPolicy("pr2ar2"),
        tau: float = 0.05,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = (
            params
            if params is not None
            else self.model.init(jax.random.PRNGKey(seed))
        )
        self.policy = policy
        self.store = QuantizedKVStore(policy, tau=tau)
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)

    def _pad_batch(self, prompts: List[np.ndarray]) -> np.ndarray:
        T = max(len(p) for p in prompts)
        out = np.zeros((len(prompts), T), np.int32)
        for i, p in enumerate(prompts):
            out[i, T - len(p):] = p  # left-pad
        return out

    def generate(
        self,
        prompts: List[np.ndarray],
        max_new_tokens: int = 16,
        eos_id: Optional[int] = None,
    ) -> Tuple[np.ndarray, ServeStats]:
        tokens = self._pad_batch(prompts)
        B, T = tokens.shape
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (B, self.cfg.n_patches, self.cfg.d_model),
                jnp.dtype(self.cfg.activation_dtype),
            )
        if self.cfg.family == "encdec":
            batch["audio_embed"] = jnp.zeros(
                (B, self.cfg.enc_positions, self.cfg.d_model),
                jnp.dtype(self.cfg.activation_dtype),
            )

        t0 = time.perf_counter()
        logits, cache = jax.block_until_ready(self._prefill(self.params, batch))
        prefill_s = time.perf_counter() - t0
        self.store.pack(cache)

        out = [np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)]
        pos = T + (self.cfg.n_patches if self.cfg.family == "vlm" else 0)
        done = np.zeros((B,), bool)

        t0 = time.perf_counter()
        for step in range(max_new_tokens - 1):
            cache_in = self.store.materialize()
            step_batch = {
                "token": jnp.asarray(out[-1][:, None]),
                "pos": jnp.int32(pos + step),
                "cache": cache_in,
            }
            logits, new_cache = jax.block_until_ready(
                self._decode(self.params, step_batch)
            )
            self.store.update(new_cache)
            nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
            if eos_id is not None:
                done |= nxt == eos_id
                nxt = np.where(done, eos_id, nxt)
            out.append(nxt)
            if eos_id is not None and done.all():
                break
        decode_s = time.perf_counter() - t0

        gen = np.stack(out, axis=1)
        stats = ServeStats(
            n_requests=B,
            prompt_tokens=int(sum(len(p) for p in prompts)),
            generated_tokens=int(gen.size),
            prefill_s=prefill_s,
            decode_s=decode_s,
            tokens_per_s=gen.size / decode_s if decode_s else 0.0,
            kv=self.store.stats,
        )
        return gen, stats
