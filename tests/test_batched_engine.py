"""Batched lockstep engine: bit-equivalence matrix + explicit rejection.

The contract for ``engine="batched"`` (:mod:`repro.flashsim.engine_batched`)
has two halves, both tested here:

  * on the supported matrix — ring-lowerable scheduling (fcfs,
    host_prio, host_prio_aged[:bound]), gc in {none, prepass}, no
    faults, open loop — every run is **bit-identical** to the array
    interpreter: full :class:`SimStats` dataclass equality, synthetic
    profiles and real MSR excerpts alike;
  * everywhere else the engine **fails fast** with
    :class:`BatchedUnsupported` — never a silent fallback to the
    interpreter.

The lockstep kernel itself is additionally pinned against an
independent pure-Python oracle (:func:`repro.kernels.fcfs_core.
fcfs_core_ref`) on randomized op tables, including the rel=0 /
single-attempt corner where every read senses exactly once and the
aging-boundary corners of the dual priority rings (bound 0 = always
bypass when low work waits, huge bound = plain host_prio).
"""

import dataclasses
from pathlib import Path

import numpy as np
import pytest

from repro.flashsim.config import (
    DEFAULT_SSD,
    FaultConfig,
    OperatingCondition,
    SSDConfig,
)
from repro.flashsim.engine_batched import BatchedUnsupported
from repro.flashsim.sched import SCHEDULERS
from repro.flashsim.ssd import (
    compare_mechanisms,
    simulate,
    simulate_batch,
)
from repro.flashsim.workloads import load_msr_csv

AGED = OperatingCondition(365.0, 1000.0)
MODEST = OperatingCondition(30.0, 0.0)
DATA = Path(__file__).parent / "data"

MECHANISMS = ("baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2")


def _pair(workload="websearch", mechanism="pr2ar2", cond=AGED, seed=0,
          n=800, **kw):
    a = simulate(workload, cond, mechanism, seed=seed, n_requests=n,
                 engine="array", **kw)
    b = simulate(workload, cond, mechanism, seed=seed, n_requests=n,
                 engine="batched", **kw)
    return a, b


class TestSupportedMatrix:
    """Full SimStats equality wherever support is claimed."""

    @pytest.mark.parametrize("mechanism", MECHANISMS)
    def test_all_mechanisms_bit_identical(self, mechanism):
        a, b = _pair(mechanism=mechanism)
        assert a == b

    @pytest.mark.parametrize("gc", [None, "prepass"])
    @pytest.mark.parametrize("workload", ["websearch", "oltp", "prxy"])
    def test_workloads_and_gc_modes(self, workload, gc):
        a, b = _pair(workload=workload, gc=gc)
        assert a == b

    @pytest.mark.parametrize("scheduler", [
        "host_prio", "host_prio_aged", "host_prio_aged:3",
    ])
    @pytest.mark.parametrize("gc", [None, "prepass"])
    def test_priority_schedulers_bit_identical(self, scheduler, gc):
        a, b = _pair(gc=gc, scheduler=scheduler)
        assert a == b
        assert b.fast_path_events > 0

    def test_priority_reordering_is_exercised(self):
        # Parity must not be vacuous: on a write-heavy profile the
        # priority rings genuinely reorder grants, so host-read
        # latency differs from fcfs — and batched still matches the
        # interpreter bit for bit on both.
        a_f, b_f = _pair(workload="prn", n=600, gc="prepass")
        a_p, b_p = _pair(workload="prn", n=600, gc="prepass",
                         scheduler="host_prio")
        assert a_f == b_f and a_p == b_p
        assert a_p.read_p99_us != a_f.read_p99_us

    def test_modest_condition(self):
        a, b = _pair(cond=MODEST)
        assert a == b

    def test_shard_flag_is_a_noop(self):
        # engine="batched" IS the per-channel decomposition; shard=True
        # selects the same lockstep run, still equal to the array core.
        a, b = _pair(shard=True)
        assert a == b
        _, b2 = _pair(shard=False)
        assert b == b2

    @pytest.mark.parametrize("spec,gc", [
        ("web_0", None), ("src1_1", None), ("src1_1", "prepass"),
    ])
    def test_msr_excerpts_bit_identical(self, spec, gc):
        trace = load_msr_csv(DATA / f"{spec}.csv.gz")
        a = simulate(spec, AGED, "pr2ar2", seed=3, trace=trace,
                     engine="array", gc=gc)
        b = simulate(spec, AGED, "pr2ar2", seed=3, trace=trace,
                     engine="batched", gc=gc)
        assert a == b

    def test_fast_path_counter(self):
        a, b = _pair()
        assert a.fast_path_events == 0
        assert b.fast_path_events > 0
        # the counter is bookkeeping, not physics: excluded from
        # equality so supported-matrix runs compare clean
        assert a == b

    def test_compare_mechanisms_batched(self):
        a = compare_mechanisms("websearch", AGED, seed=1, n_requests=600,
                               engine="array")
        b = compare_mechanisms("websearch", AGED, seed=1, n_requests=600,
                               engine="batched")
        assert list(a) == list(b)
        assert all(a[m] == b[m] for m in a)

    def test_simulate_batch_batched(self):
        conds = (AGED, MODEST)
        a = simulate_batch("websearch", conds, mechanisms=("baseline",
                           "pr2ar2"), seeds=(0, 1), n_requests=400,
                           engine="array")
        b = simulate_batch("websearch", conds, mechanisms=("baseline",
                           "pr2ar2"), seeds=(0, 1), n_requests=400,
                           engine="batched")
        assert list(a) == list(b)
        assert all(a[k] == b[k] for k in a)


class TestConfigEngineField:
    """SSDConfig.engine selects the core when engine= is left unset."""

    def test_cfg_engine_routes_batched(self):
        cfg = dataclasses.replace(DEFAULT_SSD, engine="batched")
        b = simulate("websearch", AGED, "baseline", n_requests=400,
                     cfg=cfg)
        assert b.fast_path_events > 0
        a = simulate("websearch", AGED, "baseline", n_requests=400)
        assert a == b

    def test_explicit_engine_overrides_cfg(self):
        cfg = dataclasses.replace(DEFAULT_SSD, engine="batched")
        a = simulate("websearch", AGED, "baseline", n_requests=400,
                     cfg=cfg, engine="array")
        assert a.fast_path_events == 0

    def test_invalid_engine_rejected_at_construction(self):
        with pytest.raises(ValueError, match="engine"):
            SSDConfig(engine="vectorized")


class TestExplicitRejection:
    """Unsupported configurations raise BatchedUnsupported — loudly."""

    def test_is_a_notimplementederror(self):
        assert issubclass(BatchedUnsupported, NotImplementedError)

    @pytest.mark.parametrize(
        "scheduler",
        [s for s in SCHEDULERS if s in ("tokens", "preempt")])
    def test_unlowerable_schedulers(self, scheduler):
        with pytest.raises(BatchedUnsupported, match="ring-lowerable"):
            simulate("websearch", AGED, "baseline", n_requests=200,
                     engine="batched", scheduler=scheduler)

    def test_online_gc(self):
        with pytest.raises(BatchedUnsupported, match="online"):
            simulate("prxy", AGED, "baseline", n_requests=200,
                     engine="batched", gc="online")

    def test_faults(self):
        with pytest.raises(BatchedUnsupported, match="fault"):
            simulate("websearch", AGED, "baseline", n_requests=200,
                     engine="batched", faults=FaultConfig())

    def test_closed_loop(self):
        with pytest.raises(BatchedUnsupported, match="open-loop"):
            simulate("websearch", AGED, "baseline", n_requests=200,
                     engine="batched", ncq_depth=8)

    def test_validate_flag(self):
        with pytest.raises(BatchedUnsupported, match="validate"):
            simulate("websearch", AGED, "baseline", n_requests=200,
                     engine="batched", validate=True)

    def test_compare_mechanisms_rejects_too(self):
        with pytest.raises(BatchedUnsupported):
            compare_mechanisms("websearch", AGED, n_requests=200,
                               engine="batched", scheduler="tokens")


class TestKernelVsReference:
    """Lockstep kernel vs the independent pure-Python oracle, bitwise."""

    @staticmethod
    def _random_table(rng, n_ops, n_dies, attempts):
        arr = np.sort(rng.uniform(0.0, 400.0, n_ops))
        kind = rng.choice([0.0, 0.0, 1.0, 2.0], size=n_ops)
        die = rng.integers(0, n_dies, n_ops).astype(np.float64)
        dur = rng.uniform(10.0, 60.0, n_ops)
        att = (np.full(n_ops, 1.0) if attempts == 1
               else rng.integers(1, 6, n_ops).astype(np.float64))
        tr = rng.uniform(5.0, 25.0, n_ops)
        # hp: host-read class for ~half the reads (GC copy-back reads
        # are low class, so reads with hp=0 are legal and exercised).
        hp = np.where((kind == 0.0) & (rng.random(n_ops) < 0.5),
                      1.0, 0.0)
        return np.stack([arr, kind, die, dur, att, tr, hp], axis=1)

    @pytest.mark.parametrize("pipelined", [False, True])
    @pytest.mark.parametrize("attempts", [1, None],
                             ids=["rel0-single-attempt", "multi-attempt"])
    def test_bitwise_parity_random_tables(self, pipelined, attempts):
        from repro.kernels.fcfs_core import fcfs_core, fcfs_core_ref
        from repro.kernels.fcfs_core.ops import pad_ops

        rng = np.random.default_rng(42 if pipelined else 7)
        n_dies = 4
        for _ in range(3):
            lanes = [self._random_table(rng, int(rng.integers(3, 24)),
                                        n_dies, attempts)
                     for _ in range(4)]
            ops = pad_ops(lanes)
            got = fcfs_core(ops, n_dies, pipelined, 3.0, 5.0)
            want = fcfs_core_ref(ops, n_dies, pipelined, 3.0, 5.0)
            for g, w in zip(got, want):
                assert np.array_equal(g, w)

    @pytest.mark.parametrize("age_bound", [0.0, 1.0, 4.0, 1e18],
                             ids=["bound0", "bound1", "bound4",
                                  "unbounded"])
    @pytest.mark.parametrize("pipelined", [False, True])
    def test_priority_rings_parity_random_tables(self, pipelined,
                                                 age_bound):
        # Aging-boundary corners: bound 0 bypasses whenever low work
        # waits behind a host read, bound 1e18 never does (plain
        # host_prio); 1 and 4 sit on the counter-reset boundary.
        from repro.kernels.fcfs_core import fcfs_core, fcfs_core_ref
        from repro.kernels.fcfs_core.ops import pad_ops

        rng = np.random.default_rng(int(age_bound) % 97 +
                                    (13 if pipelined else 0))
        n_dies = 3
        for _ in range(3):
            lanes = [self._random_table(rng, int(rng.integers(4, 28)),
                                        n_dies, None)
                     for _ in range(4)]
            ops = pad_ops(lanes)
            got = fcfs_core(ops, n_dies, pipelined, 3.0, 5.0,
                            age_bound=age_bound)
            want = fcfs_core_ref(ops, n_dies, pipelined, 3.0, 5.0,
                                 age_bound=age_bound)
            for g, w in zip(got, want):
                assert np.array_equal(g, w)

    def test_empty_and_single_lane_corners(self):
        from repro.kernels.fcfs_core import fcfs_core, fcfs_core_ref
        from repro.kernels.fcfs_core.ops import pad_ops

        rng = np.random.default_rng(0)
        lanes = [np.zeros((0, 7)), self._random_table(rng, 5, 2, None)]
        ops = pad_ops(lanes)
        for bound in (None, 2.0):
            got = fcfs_core(ops, 2, False, 3.0, 5.0, age_bound=bound)
            want = fcfs_core_ref(ops, 2, False, 3.0, 5.0,
                                 age_bound=bound)
            for g, w in zip(got, want):
                assert np.array_equal(g, w)
