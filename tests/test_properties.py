"""Property-based tests (hypothesis) over the system's invariants."""

import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dependency 'hypothesis' not installed; "
           "property tests skipped",
)

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ecc as E
from repro.core import retry as R
from repro.core import timing as T
from repro.core import voltage as V

_conditions = st.tuples(
    st.floats(0.0, 365.0),       # retention days
    st.floats(0.0, 1500.0),      # P/E cycles
)


@settings(max_examples=25, deadline=None)
@given(_conditions)
def test_ecc_margin_positive_at_any_success(cond):
    """Whenever the retry search succeeds, the final-step margin is > 0 —
    the paper's 'may sound contradictory' argument holds by construction."""
    retention, pec = cond
    mu, sigma = V.degraded_distributions(
        jnp.float32(retention), jnp.float32(pec)
    )
    rber = R.rber_per_retry_step(mu, sigma, "csb")
    k = R.first_success_step(rber)
    if int(k) < rber.shape[-1] - 1:  # search succeeded
        final = float(jnp.take(rber, k))
        assert float(E.capability_margin(jnp.float32(final))) > 0.0


@settings(max_examples=25, deadline=None)
@given(_conditions, st.floats(0.7, 1.0))
def test_rber_monotone_in_tr_scale(cond, scale):
    """Sensing faster never lowers RBER (the AR² trade-off direction)."""
    retention, pec = cond
    mu, sigma = V.degraded_distributions(
        jnp.float32(retention), jnp.float32(pec)
    )
    levels = V.optimal_boundaries(mu, sigma)
    r_full = float(V.rber_from_distributions(mu, sigma, levels, "csb", 1.0))
    r_fast = float(V.rber_from_distributions(mu, sigma, levels, "csb", scale))
    assert r_fast >= r_full - 1e-12


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 32), st.floats(0.7, 1.0))
def test_pipelined_latency_never_worse(attempts, scale):
    seq = float(T.sequential_read_latency(attempts, "csb", scale))
    pipe = float(T.pipelined_read_latency(attempts, "csb", scale))
    assert pipe <= seq + 1e-9
    # and the pipelined lower bound: first sense + transfers can't vanish
    assert pipe >= T.DEFAULT_TIMING.tr("csb", scale)


@settings(max_examples=40, deadline=None)
@given(
    st.integers(2, 40),
    st.floats(0.001, 0.02),
)
def test_first_success_monotone_in_cap(n_steps, cap):
    """A stronger ECC (higher cap) never needs MORE retry steps."""
    rng = np.random.default_rng(n_steps)
    rber = jnp.asarray(
        np.sort(rng.uniform(1e-4, 2e-2, size=(n_steps,)))[::-1].copy()
    )
    k1 = int(R.first_success_step(rber, cap=cap, max_steps=n_steps))
    k2 = int(R.first_success_step(rber, cap=cap * 2, max_steps=n_steps))
    assert k2 <= k1


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(2, 6))
def test_parity_reconstruction_any_single_shard(seed, group):
    """XOR parity recovers any single missing shard in a group."""
    rng = np.random.default_rng(seed)
    shards = [rng.integers(0, 256, rng.integers(10, 200), dtype=np.uint8)
              for _ in range(group)]
    size = max(len(s) for s in shards)
    parity = np.zeros(size, np.uint8)
    for s in shards:
        parity[: len(s)] ^= s
    lost = int(rng.integers(0, group))
    acc = parity.copy()
    for i, s in enumerate(shards):
        if i != lost:
            acc[: len(s)] ^= s
    np.testing.assert_array_equal(acc[: len(shards[lost])], shards[lost])


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1), st.floats(0.3, 3.0))
def test_int8_quantization_error_bound(seed, scale_mag):
    from repro.distributed.compress import dequantize_int8, quantize_int8

    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(scale=scale_mag, size=(256,)), jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
    assert err.max() <= float(s) * 0.5 + 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 512), st.integers(1, 64))
def test_elastic_plan_always_valid(n_devices, old_model):
    from repro.distributed.elastic import plan_mesh

    p = plan_mesh(n_devices, (16, old_model), global_batch=256)
    d, m = p.new_shape
    assert d * m == n_devices
    assert p.grad_accum_factor >= 1


@settings(max_examples=8, deadline=None)
@given(
    st.integers(0, 2**16),           # seed
    st.sampled_from(["websearch", "oltp", "prxy"]),
    st.sampled_from(["baseline", "pr2ar2", "sota"]),
)
def test_sim_utilization_in_unit_interval(seed, workload, mechanism):
    """DES resource accounting is physical: die/channel utilization stays
    in [0, 1] for any (seed, workload, mechanism)."""
    from repro.flashsim.config import OperatingCondition
    from repro.flashsim.ssd import simulate
    from repro.flashsim.workloads import make_workloads

    s = simulate(
        make_workloads()[workload],
        OperatingCondition(365.0, 1000.0),
        mechanism,
        seed=seed,
        n_requests=200,
    )
    assert 0.0 <= s.die_util <= 1.0
    assert 0.0 <= s.channel_util <= 1.0
    assert s.p50_us <= s.p95_us <= s.p99_us


@settings(max_examples=15, deadline=None)
@given(
    st.integers(2, 5),   # batch
    st.integers(8, 64),  # seq
    st.integers(0, 2**16),
)
def test_corpus_batches_reproducible(batch, seq, index):
    from repro.data import CorpusConfig, SyntheticCorpus

    c = SyntheticCorpus(CorpusConfig(vocab=128, seq_len=seq, batch=batch))
    b1, b2 = c.batch(index), c.batch(index)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (batch, seq)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 128).all()


# -- workload-transform properties (ISSUE 4 satellite) ---------------------


def _random_trace(seed: int, n: int):
    from repro.flashsim.workloads import RequestTrace

    rng = np.random.default_rng(seed)
    # occasionally-unsorted arrivals, multi-page requests, sparse pages
    arrival = np.cumsum(rng.exponential(50.0, n))
    if rng.random() < 0.3:
        arrival = arrival[rng.permutation(n)]
    return RequestTrace(
        arrival_us=arrival,
        is_read=rng.random(n) < rng.uniform(0.1, 0.95),
        n_pages=rng.geometric(0.5, n).clip(1, 32).astype(np.int64),
        start_page=(rng.integers(0, 1 << 30, n) * rng.integers(1, 9)),
    )


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(2, 300))
def test_dense_remap_bijection_property(seed, n):
    """DenseRemap is a bijection touched -> [0, footprint) that preserves
    request order, sizes, kinds, and intra-request page contiguity for
    ANY well-formed trace (sparse, strided, unsorted, multi-page)."""
    from repro.flashsim.workloads import DenseRemap, touched_pages

    t = _random_trace(seed, n)
    d = DenseRemap().apply(t)
    before = touched_pages(t)
    after = touched_pages(d)
    np.testing.assert_array_equal(after, np.arange(before.size))
    np.testing.assert_array_equal(d.arrival_us, t.arrival_us)
    np.testing.assert_array_equal(d.is_read, t.is_read)
    np.testing.assert_array_equal(d.n_pages, t.n_pages)
    # order-preserving page bijection: relative order of any two start
    # pages is unchanged
    order = np.argsort(t.start_page, kind="stable")
    assert (np.diff(d.start_page[order]) >= 0).all()
    # contiguity: request end pages map to start + n - 1
    np.testing.assert_array_equal(
        np.searchsorted(before, t.start_page + t.n_pages - 1),
        d.start_page + d.n_pages - 1,
    )


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(3, 300),
       st.floats(0.05, 20.0))
def test_time_rescale_property(seed, n, factor):
    """TimeRescale preserves request count, read ratio, sizes and pages;
    the measured IOPS scales by exactly the factor."""
    from repro.flashsim.workloads import TimeRescale, trace_stats

    t = _random_trace(seed, n)
    r = TimeRescale(factor=factor).apply(t)
    assert len(r) == len(t)
    np.testing.assert_array_equal(r.is_read, t.is_read)
    np.testing.assert_array_equal(r.n_pages, t.n_pages)
    np.testing.assert_array_equal(r.start_page, t.start_page)
    s_t, s_r = trace_stats(t), trace_stats(r)
    assert s_r.read_ratio == s_t.read_ratio
    if np.isfinite(s_t.iops):
        assert s_r.iops == pytest.approx(s_t.iops * factor, rel=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(10, 300),
       st.floats(0.2, 0.9), st.integers(0, 2**16))
def test_transform_chain_deterministic_property(seed, n, frac, chain_seed):
    """A (Subsample -> DenseRemap) chain replays bit-identically under a
    fixed seed and preserves the sub-trace's request order."""
    from repro.flashsim.workloads import DenseRemap, Subsample

    t = _random_trace(seed, n)
    chain = (Subsample(frac), DenseRemap())

    def run():
        out = t
        for i, tf in enumerate(chain):
            out = tf.apply(out, seed=chain_seed + i)
        return out

    a, b = run(), run()
    np.testing.assert_array_equal(a.arrival_us, b.arrival_us)
    np.testing.assert_array_equal(a.start_page, b.start_page)
    np.testing.assert_array_equal(a.is_read, b.is_read)
    # subsample kept a subsequence: arrivals are a subset in order
    assert np.isin(a.arrival_us, t.arrival_us).all()


# -- fault-injection properties (ISSUE 6 satellite) ------------------------


@settings(max_examples=5, deadline=None)
@given(
    st.integers(0, 2**16),
    st.sampled_from(["ar2", "pr2ar2"]),
    st.floats(0.0, 0.15),
    st.integers(1, 4),
)
def test_fault_failure_set_shard_invariant(seed, mech, unc, esc):
    """Identical (seed, FaultConfig) -> identical failure sets and stats
    under monolithic and per-channel-sharded execution, for any knobs."""
    from repro.flashsim.config import FaultConfig, OperatingCondition
    from repro.flashsim.ssd import simulate

    fc = FaultConfig(uncorrectable_prob=unc, escalation_attempts=esc,
                     mispredict_scale=2.0)
    kw = dict(seed=seed, n_requests=200, faults=fc)
    cond = OperatingCondition(365.0, 1000.0)
    a = simulate("websearch", cond, mech, shard=False, **kw)
    b = simulate("websearch", cond, mech, shard=True, **kw)
    assert (a.mispredicted_reads, a.rescued_reads, a.parity_rebuilds,
            a.rebuild_reads, a.retired_blocks, a.unrecoverable) == \
           (b.mispredicted_reads, b.rescued_reads, b.parity_rebuilds,
            b.rebuild_reads, b.retired_blocks, b.unrecoverable)
    assert a == b


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(20, 120))
def test_ftl_retirement_bijectivity_property(seed, n_ops):
    """The l2p/p2l mapping stays a lossless bijection — and retired
    blocks never re-enter any pool — under ANY random interleaving of
    host writes, (pre-filling) reads, and bad-block retirements."""
    from repro.flashsim.config import GCConfig, SSDConfig
    from repro.flashsim.ftl import PageMapFTL

    rng = np.random.default_rng(seed)
    cfg = SSDConfig(n_channels=2, dies_per_channel=2, gc=GCConfig(
        enabled=True, pages_per_block=4, blocks_per_die=8,
        gc_threshold_blocks=1))
    ftl = PageMapFTL(cfg, lpns=np.arange(40))
    touched = set()
    for _ in range(n_ops):
        op = rng.integers(0, 4)
        if op <= 1:
            lpn = int(rng.integers(0, 40))
            ftl.host_write(lpn)
            touched.add(lpn)
        elif op == 2:
            lpn = int(rng.integers(0, 40))
            ftl.host_read(lpn)     # may lazily pre-fill
            touched.add(lpn)
        else:
            die = int(rng.integers(0, ftl.n_dies))
            if ftl.sealed[die]:
                blk = sorted(ftl.sealed[die])[
                    int(rng.integers(0, len(ftl.sealed[die])))]
                ftl.retire_block(die, blk)
        ftl.drain_events()
    # bijection: distinct lpns on distinct ppns, p2l the exact inverse
    ppns = sorted(ftl.l2p.values())
    assert len(set(ppns)) == len(ppns)
    for lpn, ppn in ftl.l2p.items():
        assert ftl.p2l[ppn] == lpn
    # zero data loss: everything ever written or pre-filled still maps
    assert touched <= set(ftl.l2p)
    # retirement is terminal: full write pointer, invalid, out of every
    # pool and frontier
    for blk in ftl.retired:
        assert ftl.wp[blk] == ftl.ppb
        assert ftl.valid[blk] == 0
        die = blk // ftl.blocks_per_die
        assert blk not in ftl.free[die]
        assert blk not in ftl.sealed[die]
        assert ftl.active[die] != blk and ftl.gc_active[die] != blk


# -- closed-loop frontend invariants (ISSUE 7) ----------------------------


@settings(max_examples=12, deadline=None)
@given(
    st.integers(1, 24),                       # ncq_depth
    st.integers(0, 4),                        # seed
    st.sampled_from(["websearch", "prn"]),
    st.booleans(),                            # host cache attached?
)
def test_closed_loop_inflight_bounded(qd, seed, wl, with_cache):
    """In-flight requests never exceed ``ncq_depth``, for any depth,
    seed, workload and cache setting (validate=True additionally arms
    the engine's own slot/work-conservation checks every event)."""
    from repro.flashsim.config import HostCacheConfig, OperatingCondition
    from repro.flashsim.ssd import simulate

    hc = HostCacheConfig(capacity_pages=64) if with_cache else None
    stats = simulate(wl, OperatingCondition(365.0, 1000.0), "pr2ar2",
                     seed=seed, n_requests=150, gc="prepass",
                     ncq_depth=qd, host_cache=hc, validate=True)
    assert 1 <= stats.max_inflight <= qd


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_write_cache_drain_equals_synchronous_replay(data):
    """Read-after-write through the cache: at every step ``version()``
    observes the newest write in stream order, and after a full drain —
    with flush *landings* arbitrarily reordered — the durable state
    equals a synchronous replay of the write stream."""
    from repro.flashsim.config import HostCacheConfig
    from repro.flashsim.hostcache import WriteCache

    cache = WriteCache(HostCacheConfig(capacity_pages=64))
    replay = {}                              # lpn -> newest version (model)
    landed_of = []                           # issued entries awaiting land
    n_ops = data.draw(st.integers(5, 60))
    for _ in range(n_ops):
        kind = data.draw(st.sampled_from(["w", "r", "flush", "land"]))
        if kind == "w":
            lpns = data.draw(
                st.lists(st.integers(0, 15), min_size=1, max_size=4))
            if cache.can_absorb(len(lpns)):
                e = cache.absorb(lpns)
                for lpn, v in zip(e.lpns, e.versions):
                    replay[lpn] = v
        elif kind == "r":
            lpn = data.draw(st.integers(0, 15))
            assert cache.version(lpn) == replay.get(lpn), (
                "a read observed a stale version through the cache"
            )
        elif kind == "flush":
            e = cache.pop_entry()
            if e is not None:
                landed_of.extend(zip(e.lpns, e.versions))
        elif landed_of:
            i = data.draw(st.integers(0, len(landed_of) - 1))
            lpn, v = landed_of.pop(i)        # land in ARBITRARY order
            cache.page_durable(lpn, v)
    # full drain: everything still cached flushes and lands
    for e in cache.drain():
        landed_of.extend(zip(e.lpns, e.versions))
    while landed_of:
        i = data.draw(st.integers(0, len(landed_of) - 1))
        lpn, v = landed_of.pop(i)
        cache.page_durable(lpn, v)
    assert cache.pending_pages == 0
    assert cache.durable == replay, (
        "durable state after drain differs from a synchronous replay"
    )


@settings(max_examples=8, deadline=None)
@given(
    st.integers(0, 3),                        # seed
    st.sampled_from(["baseline", "pr2ar2"]),  # serial vs pipelined reads
)
def test_closed_loop_phase_intervals(seed, mech):
    """Die/channel occupancy recorded by the closed loop is physical:
    channels are single-server, die-phase intervals never overlap on a
    die, every read transfer starts only after its sense ends, and a
    read's next sense overlaps its previous transfer ONLY under the
    pipelined (PR² CACHE READ) mechanisms."""
    import dataclasses

    from repro.core.retry import RetryPolicy
    from repro.flashsim.config import DEFAULT_SSD, OperatingCondition
    from repro.flashsim.ssd import SSDSim, resolve_trace

    cfg = dataclasses.replace(DEFAULT_SSD, ncq_depth=6)
    sim = SSDSim(cfg, OperatingCondition(365.0, 1000.0),
                 RetryPolicy(mech), seed=seed + 7)
    trace = resolve_trace("websearch", seed=seed, n_requests=120)
    sim.run(trace, trace_phases=True)
    phases = sim.last_phases
    assert phases, "trace_phases=True must record intervals"

    EPS = 1e-7
    by_ch, by_die, by_op = {}, {}, {}
    for o, kind, res, t0, t1 in phases:
        assert t1 >= t0 - EPS
        if kind == "xfer":
            by_ch.setdefault(res, []).append((t0, t1))
        else:
            by_die.setdefault(res, []).append((t0, t1))
        by_op.setdefault(o, []).append((kind, t0, t1))
    for ivs in by_ch.values():               # single-server channel
        ivs.sort()
        for (a0, a1), (b0, b1) in zip(ivs, ivs[1:]):
            assert b0 >= a1 - EPS, "overlapping transfers on one channel"
    for ivs in by_die.values():              # single-server die phases
        ivs.sort()
        for (a0, a1), (b0, b1) in zip(ivs, ivs[1:]):
            assert b0 >= a1 - EPS, "overlapping die phases on one die"
    pipelined = RetryPolicy(mech).pipelined
    saw_overlap = False
    for ops in by_op.values():
        senses = sorted((t0, t1) for k, t0, t1 in ops if k == "sense")
        xfers = sorted((t0, t1) for k, t0, t1 in ops if k == "xfer")
        if not senses:
            continue                         # program/erase op
        # k-th transfer moves the k-th sense's data: starts at/after it.
        for (s0, s1), (x0, x1) in zip(senses, xfers):
            assert x0 >= s1 - EPS, "transfer started before sense ended"
        # Serial mechanisms: next sense waits for the previous transfer.
        for (x0, x1), (s0, s1) in zip(xfers, senses[1:]):
            if s0 < x1 - EPS:
                saw_overlap = True
                assert pipelined, (
                    "sense/transfer overlap under a serial mechanism"
                )
    if pipelined:
        assert saw_overlap, "pipelined run never overlapped — no PR² win"
