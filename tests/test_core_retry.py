"""Paper-claim + invariant tests for the PR²/AR² core."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import characterize as CH
from repro.core import constants as C
from repro.core import ecc as E
from repro.core import retry as R
from repro.core import timing as T
from repro.core import voltage as V


class TestVoltageModel:
    def test_fresh_chip_reads_clean(self):
        mu, sigma = V.degraded_distributions(0.0, 0.0)
        rber = V.rber_all_page_types(mu, sigma, V.default_read_levels())
        assert float(jnp.max(rber)) < E.DEFAULT_ECC.rber_cap / 4

    def test_degradation_monotone_in_retention(self):
        levels = V.default_read_levels()
        prev = -1.0
        for t in (0.0, 10.0, 90.0, 365.0):
            mu, sigma = V.degraded_distributions(t, 1000.0)
            rber = float(V.rber_from_distributions(mu, sigma, levels, "csb"))
            assert rber >= prev
            prev = rber

    def test_degradation_monotone_in_pec(self):
        levels = V.default_read_levels()
        prev = -1.0
        for pec in (0.0, 500.0, 1500.0):
            mu, sigma = V.degraded_distributions(180.0, pec)
            rber = float(V.rber_from_distributions(mu, sigma, levels, "csb"))
            assert rber >= prev
            prev = rber

    def test_optimal_boundaries_beat_default_after_stress(self):
        mu, sigma = V.degraded_distributions(365.0, 1500.0)
        r_def = float(
            V.rber_from_distributions(mu, sigma, V.default_read_levels(), "csb")
        )
        r_opt = float(
            V.rber_from_distributions(mu, sigma, V.optimal_boundaries(mu, sigma), "csb")
        )
        assert r_opt < r_def / 5

    def test_reduced_tr_raises_rber(self):
        mu, sigma = V.degraded_distributions(90.0, 0.0)
        levels = V.optimal_boundaries(mu, sigma)
        r_full = float(V.rber_from_distributions(mu, sigma, levels, "csb", 1.0))
        r_fast = float(V.rber_from_distributions(mu, sigma, levels, "csb", 0.75))
        r_faster = float(V.rber_from_distributions(mu, sigma, levels, "csb", 0.6))
        assert r_full < r_fast < r_faster


class TestRetrySearch:
    def test_first_success_step_basic(self):
        rber = jnp.array([[1e-2, 8e-3, 5e-3, 1e-3, 2e-3]])
        k = R.first_success_step(rber, cap=6e-3)
        assert int(k[0]) == 2

    def test_first_success_respects_start(self):
        rber = jnp.array([1e-3, 1e-2, 1e-2, 1e-3, 1e-3])
        assert int(R.first_success_step(rber, cap=5e-3)) == 0
        assert int(R.first_success_step(rber, jnp.int32(1), cap=5e-3)) == 3

    def test_paper_obs1_mean_steps_3mo(self):
        s = CH.characterize_condition(90.0, 0.0)
        assert abs(s.mean_retry_steps - 4.5) < 0.5, s.mean_retry_steps

    def test_aged_needs_more_steps_than_modest(self):
        modest = CH.characterize_condition(90.0, 0.0)
        aged = CH.characterize_condition(365.0, 1500.0)
        assert aged.mean_retry_steps > modest.mean_retry_steps

    def test_sota_reduces_attempts_but_not_below_one(self):
        key = jax.random.PRNGKey(0)
        a_base, _ = R.attempts_for_population(key, 365.0, 1000.0, "csb")
        a_sota, _ = R.attempts_for_population(key, 365.0, 1000.0, "csb", sota=True)
        assert float(jnp.mean(a_sota)) < 0.45 * float(jnp.mean(a_base))
        assert int(jnp.min(a_sota)) >= 1

    def test_sota_aged_still_multi_step(self):
        """Paper §2: even under SOTA, aged reads retry >= ~3 steps."""
        key = jax.random.PRNGKey(1)
        a_sota, _ = R.attempts_for_population(key, 365.0, 1500.0, "csb", sota=True)
        assert float(jnp.mean(a_sota - 1)) >= 3.0


class TestECCMargin:
    def test_paper_obs2_margin_positive_and_large(self):
        for cond in ((90.0, 0.0), (365.0, 1500.0)):
            s = CH.characterize_condition(*cond)
            assert s.p01_margin_final >= 0.0
            assert s.mean_margin_final > 0.33

    def test_margin_formula(self):
        m = float(E.capability_margin(jnp.float32(0.0)))
        assert m == pytest.approx(1.0)
        cap_rber = E.DEFAULT_ECC.rber_cap
        assert float(E.capability_margin(jnp.float32(cap_rber))) == pytest.approx(
            0.0, abs=1e-6
        )

    def test_page_fail_probability_monotone(self):
        rber = jnp.array([1e-3, 5e-3, 7e-3, 9e-3])
        p = np.asarray(E.page_fail_probability(rber))
        assert (np.diff(p) >= 0).all()
        assert p[0] < 1e-6 and p[-1] > 0.99


class TestTrReduction:
    def test_paper_obs3_worst_case_scale(self):
        s = CH.characterize_condition(365.0, 1500.0)
        assert s.safe_tr_scale <= 0.75

    def test_scale_table_never_below_floor(self):
        for cond in ((0.0, 0.0), (90.0, 0.0), (365.0, 1500.0)):
            s = CH.characterize_condition(*cond)
            assert CH.TR_SCALE_FLOOR <= s.safe_tr_scale <= 1.0

    def test_lookup_snaps_conservatively(self):
        exact = CH.characterize_condition(365.0, 1500.0).safe_tr_scale
        assert CH.lookup_tr_scale(300.0, 1200.0) >= min(
            exact, CH.lookup_tr_scale(365.0, 1500.0)
        )


class TestTiming:
    def test_paper_pr2_per_step_reduction(self):
        assert T.per_step_reduction_pr2() == pytest.approx(0.285, abs=0.005)

    def test_pipelined_never_slower(self):
        for a in range(1, 12):
            for pt in ("lsb", "csb", "msb"):
                assert T.pipelined_read_latency(a, pt) <= T.sequential_read_latency(a, pt)

    def test_single_attempt_equal(self):
        assert float(T.pipelined_read_latency(1)) == pytest.approx(
            float(T.sequential_read_latency(1))
        )

    def test_ar2_scales_only_tr(self):
        base = float(T.sequential_read_latency(3, "csb", 1.0))
        ar2 = float(T.read_latency(3, "ar2", "csb", 0.75))
        expected = base - 3 * 0.25 * C.TR_US["csb"]
        assert ar2 == pytest.approx(expected)

    def test_combined_latency_ordering(self):
        for a in (2, 4, 8):
            lat = {
                m: float(T.read_latency(a, m, tr_scale=0.75))
                for m in ("baseline", "pr2", "ar2", "pr2ar2")
            }
            assert lat["pr2ar2"] < lat["pr2"] < lat["baseline"]
            assert lat["pr2ar2"] < lat["ar2"] < lat["baseline"]

    def test_policy_flags(self):
        from repro.core.retry import RetryPolicy

        p = RetryPolicy("sota+pr2ar2")
        assert p.pipelined and p.adaptive_tr and p.sota_start
        assert not RetryPolicy("baseline").pipelined
        with pytest.raises(ValueError):
            RetryPolicy("bogus")
