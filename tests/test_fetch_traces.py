"""Offline tests for scripts/fetch_msr_traces.py (no network).

The downloader itself needs SNIA connectivity, but everything around it
— volume registry, destination resolution, the TOFU checksum manifest,
pin verification, and the MSR-loader sanity parse — is pure local logic
exercised here against the checked-in MSR-format excerpts.
"""

import gzip
import importlib.util
import io
import json
import shutil
import sys
import urllib.error
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

spec = importlib.util.spec_from_file_location(
    "fetch_msr_traces", REPO / "scripts" / "fetch_msr_traces.py"
)
fetch = importlib.util.module_from_spec(spec)
sys.modules.setdefault("fetch_msr_traces", fetch)
spec.loader.exec_module(fetch)


EXCERPT = REPO / "tests" / "data" / "web_0.csv.gz"


class TestVolumeRegistry:
    def test_36_volumes_13_servers(self):
        assert len(fetch.MSR_VOLUMES) == 36
        servers = {v.rsplit("_", 1)[0] for v in fetch.MSR_VOLUMES}
        assert len(servers) == 13
        # the two volumes the benchmark replays are real MSR names
        assert "web_0" in fetch.MSR_VOLUMES
        assert "src1_1" in fetch.MSR_VOLUMES

    def test_unknown_volume_rejected(self, capsys):
        with pytest.raises(SystemExit):
            fetch.main(["definitely_not_a_volume"])

    def test_list_mode(self, capsys):
        assert fetch.main(["--list"]) == 0
        out = capsys.readouterr().out.split()
        assert out == list(fetch.MSR_VOLUMES)


class TestDestResolution:
    def test_env_var_wins(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_TRACE_DIR", str(tmp_path / "tr"))
        assert fetch.default_dest() == tmp_path / "tr"

    def test_fallback_is_cwd_traces(self, monkeypatch, tmp_path):
        monkeypatch.delenv("REPRO_TRACE_DIR", raising=False)
        monkeypatch.chdir(tmp_path)
        assert fetch.default_dest() == tmp_path / "traces"


class TestChecksums:
    def test_sha256_and_pin_verification(self, tmp_path):
        p = tmp_path / "x.bin"
        p.write_bytes(b"msr")
        digest = fetch.sha256_file(p)
        assert len(digest) == 64
        fetch.verify_pin("x.bin", digest, {})                 # no pin: ok
        fetch.verify_pin("x.bin", digest, {"x.bin": digest})  # match: ok
        fetch.verify_pin("x.bin", digest,
                         {"x.bin": digest.upper()})           # case-insens.
        with pytest.raises(RuntimeError, match="SHA-256 mismatch"):
            fetch.verify_pin("x.bin", digest, {"x.bin": "0" * 64})

    def test_manifest_round_trip(self, tmp_path):
        assert fetch.load_manifest(tmp_path) == {}
        manifest = {"web_0.csv.gz": "ab" * 32}
        fetch.save_manifest(tmp_path, manifest)
        assert fetch.load_manifest(tmp_path) == manifest
        assert (tmp_path / fetch.MANIFEST_NAME).exists()


class TestSanityParse:
    def test_parses_checked_in_excerpt(self):
        n = fetch.sanity_parse(EXCERPT, max_rows=200)
        assert 0 < n <= 200

    def test_rejects_non_msr_content(self, tmp_path):
        bad = tmp_path / "bad.csv.gz"
        with gzip.open(bad, "wt") as f:
            f.write("this,is,not\nan,msr,trace\n")
        with pytest.raises(Exception):
            fetch.sanity_parse(bad)

    def test_gzip_detection(self, tmp_path):
        assert fetch.is_gzip(EXCERPT)
        plain = tmp_path / "plain.csv"
        plain.write_text("128166372003061629,web,0,Read,0,512,100\n")
        assert not fetch.is_gzip(plain)

    def test_recompress_is_deterministic(self, tmp_path):
        """Identical CSV bytes must gzip to identical archive bytes
        (mtime=0, no name in the header) or the SHA-256 manifest would
        spuriously flag clean re-downloads as corrupt."""
        row = "128166372003061629,web,0,Read,0,512,100\n"
        a = tmp_path / "a.csv"
        b = tmp_path / "b.csv"
        a.write_text(row * 50)
        b.write_text(row * 50)
        fetch.recompress_csv(a)
        fetch.recompress_csv(b)
        assert fetch.is_gzip(a)
        assert fetch.sha256_file(a) == fetch.sha256_file(b)
        with gzip.open(a, "rt") as f:
            assert f.read() == row * 50

    def test_recompress_rejects_html(self, tmp_path):
        page = tmp_path / "login.csv"
        page.write_text("<html>please sign in</html>")
        with pytest.raises(RuntimeError, match="neither gzip nor MSR"):
            fetch.recompress_csv(page)
        assert page.read_text().startswith("<html>")  # left untouched


class _Resp:
    """Fake urlopen response: one read() of the payload, then EOF — or a
    connection reset mid-body when ``cut`` (partial already written)."""

    def __init__(self, payload, status=200, cut=False):
        self._payload = payload
        self.status = status
        self._cut = cut
        self._done = False

    def read(self, n=-1):
        if self._done:
            if self._cut:
                raise ConnectionResetError("mirror reset mid-body")
            return b""
        self._done = True
        return self._payload

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


class FlakyServer:
    """Scripted stand-in for ``urllib.request.urlopen``.

    One script entry per request: ``"refuse"`` (URLError), an int HTTP
    status (HTTPError), ``("cut", n)`` (serve n bytes then reset),
    ``"ignore-range"`` (200 + full body despite Range), or ``"ok"``
    (honour Range with a 206).  Records each request's Range header.
    """

    def __init__(self, body, script):
        self.body = body
        self.script = list(script)
        self.requests = []          # Range header (or None) per request

    def __call__(self, req, timeout=None):
        rng = req.get_header("Range")
        self.requests.append(rng)
        start = int(rng.split("=")[1].rstrip("-")) if rng else 0
        action = self.script.pop(0) if self.script else "ok"
        if action == "refuse":
            raise urllib.error.URLError("connection refused")
        if isinstance(action, int):
            raise urllib.error.HTTPError(
                "http://mirror/x", action, "boom", {}, io.BytesIO(b"")
            )
        if isinstance(action, tuple):
            return _Resp(self.body[start:start + action[1]],
                         status=206 if rng else 200, cut=True)
        if action == "ignore-range":
            return _Resp(self.body, status=200)
        return _Resp(self.body[start:], status=206 if rng else 200)


class TestDownloadRetry:
    """Offline retry/backoff/resume behaviour against a flaky fake."""

    BODY = bytes(range(256)) * 4        # 1 KiB, position-identifiable

    def _get(self, monkeypatch, tmp_path, script, **kw):
        server = FlakyServer(self.BODY, script)
        monkeypatch.setattr(fetch.urllib.request, "urlopen", server)
        sleeps = []
        out = tmp_path / "vol.bin"
        err = None
        try:
            fetch.download("http://mirror/vol.bin", out,
                           sleep=sleeps.append, jitter=0.0, **kw)
        except Exception as e:          # noqa: BLE001 — inspected by tests
            err = e
        return server, out, sleeps, err

    def test_retry_then_success(self, monkeypatch, tmp_path):
        server, out, sleeps, err = self._get(
            monkeypatch, tmp_path, ["refuse", "refuse", "ok"]
        )
        assert err is None
        assert out.read_bytes() == self.BODY
        assert len(server.requests) == 3
        # exponential backoff: each delay doubles the previous one
        assert len(sleeps) == 2 and sleeps[1] == 2 * sleeps[0]

    def test_jitter_perturbs_backoff(self, monkeypatch, tmp_path):
        server = FlakyServer(self.BODY, ["refuse", "ok"])
        monkeypatch.setattr(fetch.urllib.request, "urlopen", server)
        sleeps = []
        fetch.download("http://mirror/vol.bin", tmp_path / "v",
                       sleep=sleeps.append, backoff_s=1.0, jitter=0.5)
        assert 1.0 <= sleeps[0] <= 1.5

    def test_cut_body_resumes_with_range(self, monkeypatch, tmp_path):
        server, out, sleeps, err = self._get(
            monkeypatch, tmp_path, [("cut", 100), "ok"]
        )
        assert err is None
        assert out.read_bytes() == self.BODY      # no gap, no duplication
        assert server.requests == [None, "bytes=100-"]

    def test_server_ignoring_range_restarts_clean(self, monkeypatch,
                                                  tmp_path):
        server, out, sleeps, err = self._get(
            monkeypatch, tmp_path, [("cut", 100), "ignore-range"]
        )
        assert err is None
        assert out.read_bytes() == self.BODY      # 200 truncated the part
        assert server.requests == [None, "bytes=100-"]

    def test_416_drops_stale_partial(self, monkeypatch, tmp_path):
        out = tmp_path / "vol.bin"
        out.write_bytes(b"x" * 4096)              # stale oversized partial
        server = FlakyServer(self.BODY, [416, "ok"])
        monkeypatch.setattr(fetch.urllib.request, "urlopen", server)
        fetch.download("http://mirror/vol.bin", out, sleep=lambda s: None)
        assert out.read_bytes() == self.BODY
        assert server.requests == ["bytes=4096-", None]

    def test_permanent_4xx_raises_immediately(self, monkeypatch, tmp_path):
        server, out, sleeps, err = self._get(monkeypatch, tmp_path, [404])
        assert isinstance(err, urllib.error.HTTPError) and err.code == 404
        assert len(server.requests) == 1 and not sleeps

    def test_gives_up_after_max_retries(self, monkeypatch, tmp_path):
        server, out, sleeps, err = self._get(
            monkeypatch, tmp_path, ["refuse"] * 10, max_retries=2
        )
        assert isinstance(err, urllib.error.URLError)
        assert len(server.requests) == 3 and len(sleeps) == 2

    def test_429_and_5xx_are_transient(self, monkeypatch, tmp_path):
        server, out, sleeps, err = self._get(
            monkeypatch, tmp_path, [429, 503, "ok"]
        )
        assert err is None and out.read_bytes() == self.BODY

    def test_partial_kept_and_resumed_across_invocations(self, monkeypatch,
                                                         tmp_path):
        """fetch_volume keeps the .part on network failure; a later run
        resumes it with a Range request and lands the verified file."""
        monkeypatch.setattr(fetch.time, "sleep", lambda s: None)
        body = EXCERPT.read_bytes()
        dest = tmp_path / "traces"
        dest.mkdir()
        killed = FlakyServer(body, [("cut", 100)] + ["refuse"] * 8)
        monkeypatch.setattr(fetch.urllib.request, "urlopen", killed)
        with pytest.raises(urllib.error.URLError):
            fetch.fetch_volume("web_0", dest, "http://mirror", {}, {})
        part = dest / ".web_0.csv.gz.part"
        assert part.exists() and part.stat().st_size == 100

        healthy = FlakyServer(body, ["ok"])
        monkeypatch.setattr(fetch.urllib.request, "urlopen", healthy)
        manifest = {}
        final = fetch.fetch_volume("web_0", dest, "http://mirror", {},
                                   manifest)
        assert healthy.requests == ["bytes=100-"]
        assert final.read_bytes() == body
        assert not part.exists()
        assert manifest["web_0.csv.gz"] == fetch.sha256_file(EXCERPT)


class TestVerifyOnly:
    """--verify-only: hash + parse local files, no network, TOFU pins."""

    def test_verify_only_pins_and_detects_corruption(self, tmp_path,
                                                     monkeypatch, capsys):
        dest = tmp_path / "traces"
        dest.mkdir()
        shutil.copy(EXCERPT, dest / "web_0.csv.gz")
        assert fetch.main(["web_0", "--verify-only",
                           "--dest", str(dest)]) == 0
        manifest = fetch.load_manifest(dest)
        assert "web_0.csv.gz" in manifest
        # second verification against the now-pinned digest passes
        assert fetch.main(["web_0", "--verify-only",
                           "--dest", str(dest)]) == 0
        # corrupt the file: the pinned manifest digest must catch it
        with gzip.open(dest / "web_0.csv.gz", "wt") as f:
            f.write("128166372003061629,web,0,Read,0,512,100\n")
        assert fetch.main(["web_0", "--verify-only",
                           "--dest", str(dest)]) == 1
        assert "FAILED" in capsys.readouterr().err

    def test_verify_only_missing_file(self, tmp_path, capsys):
        dest = tmp_path / "empty"
        dest.mkdir()
        assert fetch.main(["web_0", "--verify-only",
                           "--dest", str(dest)]) == 1

    def test_checksum_file_pins(self, tmp_path):
        dest = tmp_path / "traces"
        dest.mkdir()
        shutil.copy(EXCERPT, dest / "web_0.csv.gz")
        pins = {"web_0.csv.gz": "0" * 64}
        pin_file = tmp_path / "pins.json"
        pin_file.write_text(json.dumps(pins))
        assert fetch.main(["web_0", "--verify-only", "--dest", str(dest),
                           "--checksum-file", str(pin_file)]) == 1
