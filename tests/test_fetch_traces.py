"""Offline tests for scripts/fetch_msr_traces.py (no network).

The downloader itself needs SNIA connectivity, but everything around it
— volume registry, destination resolution, the TOFU checksum manifest,
pin verification, and the MSR-loader sanity parse — is pure local logic
exercised here against the checked-in MSR-format excerpts.
"""

import gzip
import importlib.util
import json
import shutil
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

spec = importlib.util.spec_from_file_location(
    "fetch_msr_traces", REPO / "scripts" / "fetch_msr_traces.py"
)
fetch = importlib.util.module_from_spec(spec)
sys.modules.setdefault("fetch_msr_traces", fetch)
spec.loader.exec_module(fetch)


EXCERPT = REPO / "tests" / "data" / "web_0.csv.gz"


class TestVolumeRegistry:
    def test_36_volumes_13_servers(self):
        assert len(fetch.MSR_VOLUMES) == 36
        servers = {v.rsplit("_", 1)[0] for v in fetch.MSR_VOLUMES}
        assert len(servers) == 13
        # the two volumes the benchmark replays are real MSR names
        assert "web_0" in fetch.MSR_VOLUMES
        assert "src1_1" in fetch.MSR_VOLUMES

    def test_unknown_volume_rejected(self, capsys):
        with pytest.raises(SystemExit):
            fetch.main(["definitely_not_a_volume"])

    def test_list_mode(self, capsys):
        assert fetch.main(["--list"]) == 0
        out = capsys.readouterr().out.split()
        assert out == list(fetch.MSR_VOLUMES)


class TestDestResolution:
    def test_env_var_wins(self, monkeypatch, tmp_path):
        monkeypatch.setenv("REPRO_TRACE_DIR", str(tmp_path / "tr"))
        assert fetch.default_dest() == tmp_path / "tr"

    def test_fallback_is_cwd_traces(self, monkeypatch, tmp_path):
        monkeypatch.delenv("REPRO_TRACE_DIR", raising=False)
        monkeypatch.chdir(tmp_path)
        assert fetch.default_dest() == tmp_path / "traces"


class TestChecksums:
    def test_sha256_and_pin_verification(self, tmp_path):
        p = tmp_path / "x.bin"
        p.write_bytes(b"msr")
        digest = fetch.sha256_file(p)
        assert len(digest) == 64
        fetch.verify_pin("x.bin", digest, {})                 # no pin: ok
        fetch.verify_pin("x.bin", digest, {"x.bin": digest})  # match: ok
        fetch.verify_pin("x.bin", digest,
                         {"x.bin": digest.upper()})           # case-insens.
        with pytest.raises(RuntimeError, match="SHA-256 mismatch"):
            fetch.verify_pin("x.bin", digest, {"x.bin": "0" * 64})

    def test_manifest_round_trip(self, tmp_path):
        assert fetch.load_manifest(tmp_path) == {}
        manifest = {"web_0.csv.gz": "ab" * 32}
        fetch.save_manifest(tmp_path, manifest)
        assert fetch.load_manifest(tmp_path) == manifest
        assert (tmp_path / fetch.MANIFEST_NAME).exists()


class TestSanityParse:
    def test_parses_checked_in_excerpt(self):
        n = fetch.sanity_parse(EXCERPT, max_rows=200)
        assert 0 < n <= 200

    def test_rejects_non_msr_content(self, tmp_path):
        bad = tmp_path / "bad.csv.gz"
        with gzip.open(bad, "wt") as f:
            f.write("this,is,not\nan,msr,trace\n")
        with pytest.raises(Exception):
            fetch.sanity_parse(bad)

    def test_gzip_detection(self, tmp_path):
        assert fetch.is_gzip(EXCERPT)
        plain = tmp_path / "plain.csv"
        plain.write_text("128166372003061629,web,0,Read,0,512,100\n")
        assert not fetch.is_gzip(plain)

    def test_recompress_is_deterministic(self, tmp_path):
        """Identical CSV bytes must gzip to identical archive bytes
        (mtime=0, no name in the header) or the SHA-256 manifest would
        spuriously flag clean re-downloads as corrupt."""
        row = "128166372003061629,web,0,Read,0,512,100\n"
        a = tmp_path / "a.csv"
        b = tmp_path / "b.csv"
        a.write_text(row * 50)
        b.write_text(row * 50)
        fetch.recompress_csv(a)
        fetch.recompress_csv(b)
        assert fetch.is_gzip(a)
        assert fetch.sha256_file(a) == fetch.sha256_file(b)
        with gzip.open(a, "rt") as f:
            assert f.read() == row * 50

    def test_recompress_rejects_html(self, tmp_path):
        page = tmp_path / "login.csv"
        page.write_text("<html>please sign in</html>")
        with pytest.raises(RuntimeError, match="neither gzip nor MSR"):
            fetch.recompress_csv(page)
        assert page.read_text().startswith("<html>")  # left untouched


class TestVerifyOnly:
    """--verify-only: hash + parse local files, no network, TOFU pins."""

    def test_verify_only_pins_and_detects_corruption(self, tmp_path,
                                                     monkeypatch, capsys):
        dest = tmp_path / "traces"
        dest.mkdir()
        shutil.copy(EXCERPT, dest / "web_0.csv.gz")
        assert fetch.main(["web_0", "--verify-only",
                           "--dest", str(dest)]) == 0
        manifest = fetch.load_manifest(dest)
        assert "web_0.csv.gz" in manifest
        # second verification against the now-pinned digest passes
        assert fetch.main(["web_0", "--verify-only",
                           "--dest", str(dest)]) == 0
        # corrupt the file: the pinned manifest digest must catch it
        with gzip.open(dest / "web_0.csv.gz", "wt") as f:
            f.write("128166372003061629,web,0,Read,0,512,100\n")
        assert fetch.main(["web_0", "--verify-only",
                           "--dest", str(dest)]) == 1
        assert "FAILED" in capsys.readouterr().err

    def test_verify_only_missing_file(self, tmp_path, capsys):
        dest = tmp_path / "empty"
        dest.mkdir()
        assert fetch.main(["web_0", "--verify-only",
                           "--dest", str(dest)]) == 1

    def test_checksum_file_pins(self, tmp_path):
        dest = tmp_path / "traces"
        dest.mkdir()
        shutil.copy(EXCERPT, dest / "web_0.csv.gz")
        pins = {"web_0.csv.gz": "0" * 64}
        pin_file = tmp_path / "pins.json"
        pin_file.write_text(json.dumps(pins))
        assert fetch.main(["web_0", "--verify-only", "--dest", str(dest),
                           "--checksum-file", str(pin_file)]) == 1
