"""Failure-path modeling and fault injection (ISSUE 6).

Covers the device fault model (:mod:`repro.flashsim.faults`), the
controller recovery ladder (escalation re-reads, superpage-parity
rebuilds, bad-block retirement), the determinism contract (identical
``(seed, FaultConfig)`` -> identical failure sets under any ``shard=`` /
``workers=``), the self-healing sweep runtime (worker kills, journal
checkpoint/resume), and the defaults-off guarantee (``faults=None`` is
bit-identical to a fault-free build).
"""

import dataclasses
import json
import multiprocessing
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.flashsim.config import (
    DEFAULT_SSD,
    FaultConfig,
    GCConfig,
    OperatingCondition,
    SSDConfig,
)
from repro.flashsim.ftl import PageMapFTL
from repro.flashsim.runtime import (
    Cell,
    run_cells,
    run_sweep,
    sweep_to_json,
)
from repro.flashsim.ssd import compare_mechanisms, simulate, simulate_batch
from repro.flashsim.workloads import RequestTrace, TraceSource

FRESH = OperatingCondition(0.0, 0.0)
AGED = OperatingCondition(365.0, 1000.0)
N = 300

FAULT_FIELDS = (
    "mispredicted_reads", "rescued_reads", "parity_rebuilds",
    "rebuild_reads", "retired_blocks", "program_fails", "erase_fails",
    "unrecoverable",
)


def fault_counters(stats):
    return {f: getattr(stats, f) for f in FAULT_FIELDS}


class TestFaultConfigValidation:
    def test_probabilities_bounded(self):
        with pytest.raises(ValueError, match="uncorrectable_prob"):
            FaultConfig(uncorrectable_prob=1.5)
        with pytest.raises(ValueError, match="mispredict_prob"):
            FaultConfig(mispredict_prob=-0.1)
        with pytest.raises(ValueError, match="program_fail_prob"):
            FaultConfig(program_fail_prob=2.0)
        with pytest.raises(ValueError, match="erase_fail_prob"):
            FaultConfig(erase_fail_prob=-1.0)

    def test_scales_and_escalation(self):
        with pytest.raises(ValueError, match="uncorrectable_scale"):
            FaultConfig(uncorrectable_scale=-1.0)
        with pytest.raises(ValueError, match="escalation_attempts"):
            FaultConfig(escalation_attempts=0)

    def test_failslow_is_slow(self):
        with pytest.raises(ValueError, match="fail-SLOW"):
            FaultConfig(failslow_dies=((0, 0.5),))
        with pytest.raises(ValueError, match="die id"):
            FaultConfig(failslow_dies=((-1, 2.0),))
        FaultConfig(failslow_dies=((3, 2.5),))  # valid

    def test_defaults_valid(self):
        fc = FaultConfig()
        assert fc.parity_rebuild and fc.retire_blocks
        assert fc.escalation_attempts >= 1


class TestDefaultsOff:
    """faults=None — the default everywhere — changes nothing, and an
    all-zero FaultConfig is bit-identical to it (the separate-stream
    contract: fault draws never perturb attempt sampling)."""

    @pytest.mark.parametrize("shard", [False, True])
    def test_zero_fault_config_bit_identical(self, shard):
        base = simulate("websearch", AGED, "pr2ar2", seed=7, n_requests=N,
                        shard=shard)
        zero = FaultConfig(uncorrectable_prob=0.0, mispredict_prob=0.0)
        with_zero = simulate("websearch", AGED, "pr2ar2", seed=7,
                             n_requests=N, shard=shard, faults=zero)
        assert base == with_zero

    def test_zero_fault_counters_stay_zero(self):
        zero = FaultConfig(uncorrectable_prob=0.0, mispredict_prob=0.0)
        s = simulate("websearch", AGED, "pr2ar2", seed=7, n_requests=N,
                     faults=zero)
        assert all(v == 0 for v in fault_counters(s).values())
        assert s.recovery_p99_us == 0.0

    def test_gc_paths_unaffected_by_none(self):
        for gc in ("prepass", "online"):
            a = simulate("rsrch", AGED, "pr2ar2", seed=3, n_requests=N,
                         gc=gc)
            b = simulate("rsrch", AGED, "pr2ar2", seed=3, n_requests=N,
                         gc=gc, faults=FaultConfig(
                             uncorrectable_prob=0.0, mispredict_prob=0.0))
            assert a == b


class TestMisprediction:
    """AR² mispredictions: a reduced-tR read whose RBER exceeds the
    shaved ECC margin pays one extra nominal-tR re-read."""

    def test_derived_rate_positive_when_adaptive_and_aged(self):
        s = simulate("websearch", AGED, "ar2", seed=7, n_requests=N,
                     faults=FaultConfig())
        assert s.mispredicted_reads > 0
        assert s.unrecoverable == 0

    @pytest.mark.parametrize("mech", ["baseline", "sota", "pr2"])
    def test_non_adaptive_policies_never_mispredict(self, mech):
        s = simulate("websearch", AGED, mech, seed=7, n_requests=N,
                     faults=FaultConfig(mispredict_prob=1.0))
        assert s.mispredicted_reads == 0

    def test_every_misprediction_pays_a_nominal_reread(self):
        kw = dict(seed=7, n_requests=N)
        clean = simulate("websearch", AGED, "ar2", **kw)
        faulty = simulate("websearch", AGED, "ar2", **kw,
                          faults=FaultConfig(mispredict_prob=1.0))
        # every read mispredicted: the re-read cost must show up in the
        # read-latency mean, and the request count must not change
        assert faulty.mispredicted_reads > 0
        assert faulty.n_requests == clean.n_requests
        assert faulty.read_mean_us > clean.read_mean_us
        assert faulty.recovery_p99_us > 0.0

    def test_misprediction_rate_scales(self):
        lo = simulate("websearch", AGED, "ar2", seed=7, n_requests=N,
                      faults=FaultConfig(mispredict_scale=0.2))
        hi = simulate("websearch", AGED, "ar2", seed=7, n_requests=N,
                      faults=FaultConfig(mispredict_scale=5.0))
        assert hi.mispredicted_reads > lo.mispredicted_reads


class TestUncorrectableAndRecovery:
    def test_escalation_rescues_at_default_capability(self):
        s = simulate("websearch", AGED, "pr2ar2", seed=7, n_requests=N,
                     faults=FaultConfig(uncorrectable_prob=0.05))
        assert s.rescued_reads > 0
        # 4 escalation attempts at p=0.05: rebuild probability ~6e-6
        assert s.unrecoverable == 0

    def test_derived_uncorrectable_rate_is_benign(self):
        """At the paper-default ECC capability the derived uncorrectable
        probability is ~0: the ladder never reaches data loss."""
        s = simulate("websearch", AGED, "pr2ar2", seed=7, n_requests=N,
                     faults=FaultConfig())
        assert s.unrecoverable == 0

    def test_recovery_latency_charged(self):
        kw = dict(seed=7, n_requests=N)
        clean = simulate("websearch", AGED, "pr2ar2", **kw)
        faulty = simulate("websearch", AGED, "pr2ar2", **kw,
                          faults=FaultConfig(uncorrectable_prob=0.2))
        assert faulty.read_mean_us > clean.read_mean_us
        assert faulty.recovery_p99_us > 0.0

    def test_no_parity_rebuild_counts_unrecoverable(self):
        fc = FaultConfig(uncorrectable_prob=0.9, escalation_attempts=1,
                         parity_rebuild=False)
        s = simulate("websearch", AGED, "pr2ar2", seed=7, n_requests=N,
                     faults=fc)
        assert s.unrecoverable > 0
        assert s.parity_rebuilds == 0

    def test_parity_rebuild_issues_stripe_peer_reads(self):
        fc = FaultConfig(uncorrectable_prob=0.7, escalation_attempts=1,
                         retire_blocks=False)
        s = simulate("websearch", AGED, "pr2ar2", seed=7, n_requests=N,
                     faults=fc)
        assert s.parity_rebuilds > 0
        # stripe peers = the channel's other dies
        peers = DEFAULT_SSD.dies_per_channel - 1
        assert s.rebuild_reads == s.parity_rebuilds * peers


class TestFailSlowDies:
    def test_failslow_die_stretches_latency(self):
        kw = dict(seed=7, n_requests=N)
        clean = simulate("websearch", AGED, "pr2ar2", **kw,
                         faults=FaultConfig())
        slow = simulate("websearch", AGED, "pr2ar2", **kw,
                        faults=FaultConfig(failslow_dies=((0, 4.0),
                                                          (1, 4.0))))
        assert slow.read_mean_us > clean.read_mean_us


class TestDeterminism:
    """Identical (seed, FaultConfig) -> identical failure sets and stats
    under any shard= / workers= decomposition."""

    @pytest.mark.parametrize("gc", [None, "prepass", "online"])
    def test_shard_equality_with_faults(self, gc):
        fc = FaultConfig(uncorrectable_prob=0.05, mispredict_scale=2.0)
        kw = dict(seed=7, n_requests=N, gc=gc, faults=fc)
        a = simulate("rsrch", AGED, "pr2ar2", shard=False, **kw)
        b = simulate("rsrch", AGED, "pr2ar2", shard=True, **kw)
        assert a == b

    def test_repeat_run_identical(self):
        fc = FaultConfig(uncorrectable_prob=0.05)
        kw = dict(seed=7, n_requests=N, faults=fc)
        assert (simulate("websearch", AGED, "pr2ar2", **kw)
                == simulate("websearch", AGED, "pr2ar2", **kw))

    def test_compare_mechanisms_with_faults(self):
        fc = FaultConfig(uncorrectable_prob=0.05)
        r = compare_mechanisms("websearch", AGED, seed=7, n_requests=N,
                               faults=fc)
        assert r["ar2"].mispredicted_reads > 0
        assert r["baseline"].mispredicted_reads == 0
        for mech, stats in r.items():
            solo = simulate("websearch", AGED, mech, seed=7, n_requests=N,
                            faults=fc)
            assert stats == solo

    def test_workers_equality_with_faults(self):
        kw = dict(
            conditions=[FRESH, AGED], mechanisms=["baseline", "pr2ar2"],
            seeds=[1, 2], n_requests=N, faults=FaultConfig(),
        )
        r1 = simulate_batch("websearch", workers=1, **kw)
        r2 = simulate_batch("websearch", workers=2, **kw)
        assert sweep_to_json(r1) == sweep_to_json(r2)


class TestOnlineRecovery:
    """Online-GC fault path: wear-resolved draws, real FTL retirement,
    erase/program failures at the simulated instants."""

    FC = FaultConfig(uncorrectable_prob=0.6, escalation_attempts=1)

    def _run(self, **kw):
        base = dict(seed=3, n_requests=2000, gc="online", faults=self.FC)
        base.update(kw)
        return simulate("rsrch", AGED, "pr2ar2", **base)

    def test_rebuild_and_retirement_exercised(self):
        s = self._run()
        assert s.parity_rebuilds > 0
        assert s.rebuild_reads > 0
        assert s.retired_blocks > 0

    def test_online_shard_equality(self):
        assert self._run(shard=False) == self._run(shard=True)

    def test_erase_failures_retire_blocks(self):
        fc = FaultConfig(erase_fail_prob=0.5)
        s = simulate("rsrch", AGED, "pr2ar2", seed=3, n_requests=2000,
                     gc="online", faults=fc)
        assert s.erase_fails > 0
        assert s.retired_blocks >= s.erase_fails
        assert s.n_requests == 2000

    def test_program_failures_counted_and_charged(self):
        kw = dict(seed=3, n_requests=600, gc="online")
        clean = simulate("rsrch", AGED, "pr2ar2", **kw)
        s = simulate("rsrch", AGED, "pr2ar2", **kw,
                     faults=FaultConfig(program_fail_prob=0.3))
        assert s.program_fails > 0
        assert s.mean_us > clean.mean_us


class TestReferenceEngine:
    def test_reference_engine_rejects_faults(self):
        with pytest.raises(NotImplementedError, match="fault"):
            simulate("websearch", AGED, "pr2ar2", seed=7, n_requests=50,
                     engine="reference", faults=FaultConfig())


# -- FTL bad-block retirement (unit) ---------------------------------------


def small_ftl(**gc_kw) -> PageMapFTL:
    kw = dict(enabled=True, pages_per_block=4, blocks_per_die=8,
              gc_threshold_blocks=1)
    kw.update(gc_kw)
    cfg = SSDConfig(n_channels=1, dies_per_channel=1, gc=GCConfig(**kw))
    return PageMapFTL(cfg)


class TestRetireBlock:
    def test_retire_relocates_valid_pages(self):
        ftl = small_ftl()
        for lpn in range(5):          # block 0 fills + seals, block 1 opens
            ftl.host_write(lpn)
        ftl.drain_events()
        assert 0 in ftl.sealed[0]
        assert ftl.retire_block(0, 0)
        assert 0 in ftl.retired and ftl.blocks_retired == 1
        assert ftl.valid[0] == 0 and ftl.wp[0] == ftl.ppb
        assert 0 not in ftl.free[0]
        # the four relocated lpns still resolve, off the retired block
        for lpn in range(4):
            ppn = ftl.l2p[lpn]
            assert ppn // ftl.ppb != 0
            assert ftl.p2l[ppn] == lpn
        # relocation emitted GC read+program traffic
        kinds = [ev[0] for ev in ftl.drain_events()]
        assert len(kinds) == 8        # 4 reads + 4 programs

    def test_retire_refuses_frontier_and_foreign_blocks(self):
        ftl = small_ftl()
        for lpn in range(5):
            ftl.host_write(lpn)
        active = ftl.active[0]
        assert not ftl.retire_block(0, active)      # frontier: refused
        assert not ftl.retire_block(0, 99)          # not die 0's block
        assert ftl.retire_block(0, 0)
        assert not ftl.retire_block(0, 0)           # already retired

    def test_retire_refuses_when_it_would_wedge(self):
        # 4 blocks/die min geometry: fill 2 sealed blocks, leave 1 free —
        # relocating 4 valid pages would eat the last reserve block.
        ftl = small_ftl(blocks_per_die=4, gc_threshold_blocks=1)
        for lpn in range(12):
            ftl.host_write(lpn)
        ftl.drain_events()
        assert len(ftl.free[0]) == 1
        assert not ftl.retire_block(0, 0)
        assert 0 not in ftl.retired   # stays in service

    def test_retire_erase_failed_never_returns_to_pool(self):
        ftl = small_ftl()
        blk = ftl.free[0][-1]
        ftl.retire_erase_failed(0, blk)
        assert blk in ftl.retired
        assert ftl.wp[blk] == ftl.ppb    # never allocatable


# -- self-healing runtime ---------------------------------------------------


def _synthetic_trace(seed: int, n: int) -> RequestTrace:
    rng = np.random.default_rng(seed)
    return RequestTrace(
        arrival_us=np.cumsum(rng.exponential(30.0, n)),
        is_read=rng.random(n) < 0.7,
        n_pages=np.ones(n, np.int64),
        start_page=rng.integers(0, 4096, n),
    )


@dataclasses.dataclass(frozen=True)
class KillOnceSource(TraceSource):
    """Trace source that SIGKILLs the *worker* process on first build.

    The marker file makes the kill once-only (and observable), and the
    recorded parent pid keeps inline/baseline runs alive — only a forked
    pool worker dies.  Picklable via the fork start method.
    """

    marker: str = ""
    parent_pid: int = 0
    n: int = 300
    transforms: tuple = ()

    def cache_key(self, seed: int) -> tuple:
        return ("kill-once", self.n, seed,
                tuple(t.key for t in self.transforms))

    def _build(self, seed: int) -> RequestTrace:
        if (self.marker and not os.path.exists(self.marker)
                and os.getpid() != self.parent_pid):
            Path(self.marker).touch()
            os.kill(os.getpid(), signal.SIGKILL)
        return _synthetic_trace(seed, self.n)


def _require_fork():
    if "fork" not in multiprocessing.get_all_start_methods():
        pytest.skip("fork start method unavailable")
    if os.environ.get("REPRO_SWEEP_INLINE") == "1":
        pytest.skip("pool execution disabled (REPRO_SWEEP_INLINE=1)")


class TestSelfHealingPool:
    def test_worker_kill_preserves_completed_results(self, tmp_path):
        """SIGKILL one worker mid-sweep: the pool breaks, completed
        futures' results are harvested (not discarded), only unfinished
        cells retry, and the final JSON is byte-identical to workers=1."""
        _require_fork()
        from repro.flashsim.workloads import clear_trace_cache

        marker = tmp_path / "killed"
        src = KillOnceSource(marker=str(marker), parent_pid=os.getpid())
        kw = dict(
            conditions=[AGED], mechanisms=("baseline", "pr2ar2"),
            seeds=[0, 1, 2, 3], n_requests=200,
        )
        clear_trace_cache()   # force workers to _build (and one to die)
        parallel = run_sweep(src, workers=2, **kw)
        assert marker.exists(), "no worker was killed — test is vacuous"
        inline = run_sweep(src, workers=1, **kw)
        assert sweep_to_json(parallel) == sweep_to_json(inline)

    def test_cell_exceptions_still_propagate(self):
        """A cell that *raises* (vs. dying) fails the sweep unchanged —
        retrying user errors would only duplicate the work."""
        bad = Cell("simulate", "websearch", (AGED,), ("no-such-mech",), 0,
                   n_requests=50)
        with pytest.raises((KeyError, ValueError)):
            run_cells([bad], workers=1)
        _require_fork()
        with pytest.raises((KeyError, ValueError)):
            run_cells([bad, bad], workers=2, prewarm=False)

    def test_stalled_pool_abandoned_and_finished_inline(self, tmp_path):
        """cell_timeout bounds the wait for progress: a pool that makes
        none is abandoned and the cells complete inline."""
        _require_fork()
        marker = tmp_path / "killed"
        src = KillOnceSource(marker=str(marker), parent_pid=os.getpid(),
                             n=100)
        from repro.flashsim.workloads import clear_trace_cache

        clear_trace_cache()
        cells = [Cell("simulate", src, (AGED,), ("baseline",), s,
                      n_requests=50) for s in range(2)]
        results = run_cells(cells, workers=2, cell_timeout=60.0,
                            max_retries=1)
        assert all(r is not None for r in results)
        assert [r.n_requests for r in results] == [50, 50]


class TestJournalResume:
    KW = dict(
        conditions=(FRESH, AGED), mechanisms=("baseline", "pr2ar2"),
        seeds=(1, 2, 3), n_requests=150,
    )

    def test_journal_round_trip_byte_identical(self, tmp_path):
        jpath = tmp_path / "sweep.jsonl"
        fresh = run_sweep("websearch", **self.KW)
        journaled = run_sweep("websearch", journal=jpath, **self.KW)
        assert sweep_to_json(fresh) == sweep_to_json(journaled)
        lines = jpath.read_text().splitlines()
        assert len(lines) == 1 + len(self.KW["seeds"])  # header + cells
        # resume from a complete journal recomputes nothing and matches
        resumed = run_sweep("websearch", journal=jpath, **self.KW)
        assert sweep_to_json(resumed) == sweep_to_json(fresh)

    def test_partial_journal_resumes_byte_identical(self, tmp_path):
        jpath = tmp_path / "sweep.jsonl"
        fresh = run_sweep("websearch", journal=jpath, **self.KW)
        lines = jpath.read_text().splitlines()
        # keep the header and the first completed cell only
        jpath.write_text("\n".join(lines[:2]) + "\n")
        resumed = run_sweep("websearch", journal=jpath, **self.KW)
        assert sweep_to_json(resumed) == sweep_to_json(fresh)

    def test_torn_tail_ignored(self, tmp_path):
        jpath = tmp_path / "sweep.jsonl"
        fresh = run_sweep("websearch", journal=jpath, **self.KW)
        with open(jpath, "a") as f:
            f.write('{"i": 99, "r": {"t": "cells", "v"')   # torn append
        resumed = run_sweep("websearch", journal=jpath, **self.KW)
        assert sweep_to_json(resumed) == sweep_to_json(fresh)

    def test_journal_keyed_to_cell_list(self, tmp_path):
        """A journal resumes only the exact sweep that wrote it: any
        other cell list starts the file over (no cross-contamination)."""
        jpath = tmp_path / "sweep.jsonl"
        run_sweep("websearch", journal=jpath, **self.KW)
        other = dict(self.KW, seeds=(7, 8))
        fresh = run_sweep("websearch", **other)
        rerun = run_sweep("websearch", journal=jpath, **other)
        assert sweep_to_json(rerun) == sweep_to_json(fresh)
        lines = jpath.read_text().splitlines()
        assert len(lines) == 1 + 2    # rewritten for the new run key

    def test_journal_with_faults_and_workers(self, tmp_path):
        _require_fork()
        jpath = tmp_path / "sweep.jsonl"
        kw = dict(self.KW, faults=FaultConfig())
        fresh = run_sweep("websearch", **kw)
        journaled = run_sweep("websearch", journal=jpath, workers=2, **kw)
        assert sweep_to_json(fresh) == sweep_to_json(journaled)


_KILL_SCRIPT = """
import sys
from repro.flashsim.runtime import run_sweep
from repro.flashsim.config import OperatingCondition

run_sweep(
    "websearch",
    (OperatingCondition(0.0, 0.0), OperatingCondition(365.0, 1000.0)),
    ("baseline", "pr2", "ar2", "pr2ar2"),
    seeds=range(6),
    n_requests=6000,
    journal=sys.argv[1],
)
"""


class TestSigkillResume:
    def test_sigkilled_sweep_resumes_byte_identical(self, tmp_path):
        """Kill a journaled sweep with SIGKILL mid-run; re-running with
        the same journal skips the recorded cells and the final
        sweep_to_json is byte-identical to an uninterrupted sweep."""
        jpath = tmp_path / "sweep.jsonl"
        env = dict(os.environ)
        env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
        env["REPRO_SWEEP_INLINE"] = "1"
        proc = subprocess.Popen(
            [sys.executable, "-c", _KILL_SCRIPT, str(jpath)],
            env=env, stdout=subprocess.DEVNULL, stderr=subprocess.DEVNULL,
        )
        try:
            # wait for >= 1 completed cell in the journal, then SIGKILL
            deadline = time.time() + 300
            while time.time() < deadline:
                if proc.poll() is not None:
                    break
                if jpath.exists() and len(
                        jpath.read_text().splitlines()) >= 2:
                    break
                time.sleep(0.02)
            killed_midway = proc.poll() is None
            if killed_midway:
                proc.send_signal(signal.SIGKILL)
            proc.wait(timeout=60)
        finally:
            if proc.poll() is None:
                proc.kill()
        assert jpath.exists() and jpath.read_text().splitlines(), \
            "journal never materialized — subprocess failed to start"

        kw = dict(
            conditions=(FRESH, AGED),
            mechanisms=("baseline", "pr2", "ar2", "pr2ar2"),
            seeds=range(6), n_requests=6000,
        )
        pre = len(jpath.read_text().splitlines()) - 1
        resumed = run_sweep("websearch", journal=jpath, **kw)
        fresh = run_sweep("websearch", **kw)
        assert sweep_to_json(resumed) == sweep_to_json(fresh)
        if killed_midway:
            assert 0 < pre < 6, f"kill landed outside the sweep ({pre})"
