"""Property-based check: dual priority rings == the sched.py pop law.

Randomized padded op tables (lane count, op mix, scheduling classes,
aging bound — including the boundary corners 0, 1, and effectively-
infinite) drawn by hypothesis; every draw must produce **bitwise**
equality between the lockstep kernel's priority lowering
(:func:`repro.kernels.fcfs_core.fcfs_core` with ``age_bound``) and the
pure-Python oracle (:func:`repro.kernels.fcfs_core.fcfs_core_ref`),
whose queue closures restate ``AgedHostPrioQueue.pop_next`` from
:mod:`repro.flashsim.sched` verbatim.  End-to-end SimStats equality of
the same policies is separately drawn in ``test_batched_property.py``
style by :mod:`test_batched_engine`; this suite attacks the ring
mechanics directly, where shrinking finds minimal counterexamples.
Skipped when the optional ``hypothesis`` dependency is absent (mirrors
``test_properties.py``).
"""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dependency 'hypothesis' not installed; "
           "property tests skipped",
)

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.fcfs_core import fcfs_core, fcfs_core_ref
from repro.kernels.fcfs_core.ops import pad_ops

_draws = st.tuples(
    st.integers(0, 2 ** 31 - 1),         # table rng seed
    st.integers(1, 4),                   # lanes
    st.integers(1, 4),                   # dies per lane
    st.integers(1, 30),                  # max ops per lane
    st.sampled_from([0.0, 1.0, 2.0, 4.0, 7.0, 1e18]),  # aging bound
    st.booleans(),                       # pipelined
    st.floats(0.1, 0.9),                 # host-read (hp) fraction
)


def _table(rng, n_ops, n_dies, hp_frac):
    arr = np.sort(rng.uniform(0.0, 300.0, n_ops))
    kind = rng.choice([0.0, 0.0, 1.0, 2.0], size=n_ops)
    die = rng.integers(0, n_dies, n_ops).astype(np.float64)
    dur = rng.uniform(10.0, 60.0, n_ops)
    att = rng.integers(1, 6, n_ops).astype(np.float64)
    tr = rng.uniform(5.0, 25.0, n_ops)
    hp = np.where((kind == 0.0) & (rng.random(n_ops) < hp_frac),
                  1.0, 0.0)
    return np.stack([arr, kind, die, dur, att, tr, hp], axis=1)


@settings(max_examples=25, deadline=None)
@given(_draws)
def test_priority_rings_match_sched_reference(draw):
    seed, n_lanes, n_dies, max_ops, bound, pipelined, hp_frac = draw
    rng = np.random.default_rng(seed)
    lanes = [_table(rng, int(rng.integers(1, max_ops + 1)), n_dies,
                    hp_frac)
             for _ in range(n_lanes)]
    ops = pad_ops(lanes)
    got = fcfs_core(ops, n_dies, pipelined, 3.0, 5.0, age_bound=bound)
    want = fcfs_core_ref(ops, n_dies, pipelined, 3.0, 5.0,
                         age_bound=bound)
    for g, w in zip(got, want):
        assert np.array_equal(g, w)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.booleans())
def test_fifo_lowering_unchanged_by_hp_column(seed, pipelined):
    # fcfs must ignore the scheduling class entirely: the same table
    # with hp scrambled lowers to the identical single-ring run.
    rng = np.random.default_rng(seed)
    t = _table(rng, int(rng.integers(2, 20)), 3, 0.5)
    t2 = t.copy()
    t2[:, 6] = 1.0 - t2[:, 6]
    a = fcfs_core(pad_ops([t]), 3, pipelined, 3.0, 5.0)
    b = fcfs_core(pad_ops([t2]), 3, pipelined, 3.0, 5.0)
    for g, w in zip(a, b):
        assert np.array_equal(g, w)
