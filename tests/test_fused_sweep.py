"""Fused sweep core: cross-cell vectorization contracts (ISSUE 10).

The fused path stacks the padded op tables of many batched-eligible
cells along the kernel's lane axis and runs them in one dispatch.  Its
contract has three halves, all pinned here:

  * **Bit-parity** — for every eligible (mechanism x condition x seed)
    grid, fused results are *fully* equal (SimStats dataclass equality)
    to the sequential batched engine, cell by cell, and
    :func:`sweep_to_json` is byte-identical for any fusion decision and
    worker count.
  * **Never silent** — ineligible cells run per-cell exactly as before:
    ``engine="batched"`` misconfigurations raise
    :class:`BatchedUnsupported`, ``engine="auto"`` fallbacks record
    their reason on ``SimStats.engine_fallback_reason``; ragged grids
    (mixed schedulers, a faulted cell) fuse the eligible subset only.
  * **Fewer dispatches** — a fused grid launches one kernel per
    step-homogeneous chunk of each static-shape group
    (``KERNEL_DISPATCHES`` accounting), with the cell axis capped so
    the stacked lane count stays inside the scatter-friendly regime;
    cap-boundary grid sizes stay bit-identical.

The widened kernel itself is additionally property-pinned against the
cell-axis oracle (:func:`repro.kernels.fcfs_core.ref.fused_core_ref`)
on randomized multi-cell tables with per-cell timing scalars.
"""

import dataclasses
import json

import pytest

from repro.flashsim.config import (
    DEFAULT_SSD,
    FaultConfig,
    OperatingCondition,
)
from repro.flashsim.engine_batched import (
    BatchedUnsupported,
    _fuse_cell_cap,
)
from repro.flashsim.runtime import (
    Cell,
    _batched_sigs,
    prewarm_batched,
    run_cells,
    sweep_to_json,
)
from repro.flashsim.ssd import compare_mechanisms, simulate_batch

AGED = OperatingCondition(365.0, 1000.0)
MODEST = OperatingCondition(30.0, 0.0)

#: Mixed pipelined classes: baseline/sota serial, pr2ar2 pipelined —
#: a fused grid over these must split into two static groups.
MECHS = ("baseline", "sota", "pr2ar2")


def _grid(fuse, conds=(AGED, MODEST), mechs=MECHS, seeds=(0, 1), n=200,
          **kw):
    return simulate_batch(
        "websearch", conds, mechanisms=mechs, seeds=seeds, n_requests=n,
        engine="batched", fuse=fuse, **kw,
    )


class TestFusedParity:
    """Full SimStats equality, fused vs sequential batched."""

    def test_full_grid_equality(self):
        fused, seq = _grid(True), _grid(False)
        assert list(fused) == list(seq)
        for key in seq:
            assert fused[key] == seq[key], key
        assert all(st.fused_cells > 1 for st in fused.values())
        assert all(st.fused_cells == 0 for st in seq.values())

    def test_compare_mechanisms_equality(self):
        mechs = ("baseline", "sota", "pr2", "ar2", "pr2ar2", "sota+pr2ar2")
        kw = dict(mechanisms=mechs, seed=0, n_requests=300,
                  engine="batched")
        fused = compare_mechanisms("oltp", AGED, fuse=True, **kw)
        seq = compare_mechanisms("oltp", AGED, fuse=False, **kw)
        assert list(fused) == list(seq)
        for m in mechs:
            assert fused[m] == seq[m], m
        # {baseline, sota, ar2} serial + {pr2, pr2ar2, sota+pr2ar2}
        # pipelined -> two static groups of 3, which the deterministic
        # step-homogeneity chunker further splits: the retry-reducing
        # mechanisms (sota, sota+pr2ar2) run >1.5x fewer lockstep steps
        # than their classmates, so each group fuses as a pair plus a
        # singleton.
        assert [fused[m].fused_cells for m in mechs] == [2, 1, 2, 2, 2, 1]
        assert all(st.fused_cells >= 1 for st in fused.values())

    @pytest.mark.parametrize("scheduler", [
        "host_prio", "host_prio_aged:3",
    ])
    def test_priority_schedulers(self, scheduler):
        fused = _grid(True, seeds=(0,), scheduler=scheduler)
        seq = _grid(False, seeds=(0,), scheduler=scheduler)
        for key in seq:
            assert fused[key] == seq[key], key

    def test_gc_prepass(self):
        fused = _grid(True, seeds=(0,), gc="prepass")
        seq = _grid(False, seeds=(0,), gc="prepass")
        for key in seq:
            assert fused[key] == seq[key], key

    def test_explicit_batched_still_raises(self):
        """Fusion never converts a hard rejection into a fallback."""
        with pytest.raises(BatchedUnsupported):
            _grid(True, seeds=(0,), scheduler="tokens")

    def test_auto_fallback_records_reason(self):
        out = simulate_batch(
            "websearch", (AGED,), mechanisms=("baseline",), seeds=(0,),
            n_requests=150, engine="auto", scheduler="tokens", fuse=True,
        )
        st = next(iter(out.values()))
        assert st.engine_selected == "array"
        assert st.engine_fallback_reason
        assert st.fused_cells == 0


class TestCrossCellFusion:
    """run_cells fuses eligible "simulate" cells sharing trace + config."""

    @staticmethod
    def _cells(mechs, fuse=None, **kw):
        return [
            Cell("simulate", "websearch", (AGED,), (m,), 5, DEFAULT_SSD,
                 200, "batched", None, None, False, fuse=fuse, **kw)
            for m in mechs
        ]

    def test_cross_cell_parity_and_counters(self):
        cells = self._cells(("baseline", "sota", "pr2ar2", "pr2ar2"))
        fused = run_cells(cells, workers=1)
        seq = run_cells(self._cells(
            ("baseline", "sota", "pr2ar2", "pr2ar2"), fuse=False),
            workers=1)
        assert fused == seq
        # {baseline, sota} share the serial static group but sota's
        # retry reduction puts it >1.5x under baseline's step bound, so
        # the chunker runs each alone; the identical pr2ar2 pair fuses.
        assert [st.fused_cells for st in fused] == [1, 1, 2, 2]

    def test_ragged_mixed_schedulers(self):
        eligible = self._cells(("baseline", "sota"))
        ineligible = [dataclasses.replace(c, engine="auto",
                                          scheduler="tokens")
                      for c in self._cells(("baseline",))]
        results = run_cells(eligible + ineligible, workers=1)
        seq = run_cells(
            [dataclasses.replace(c, fuse=False)
             for c in eligible + ineligible], workers=1)
        assert results == seq
        # Eligible cells route through the fused path (step-split into
        # singleton chunks here — see the chunker note above) while the
        # ineligible cell falls back per-cell with its reason recorded.
        assert [st.fused_cells for st in results[:2]] == [1, 1]
        assert results[2].engine_selected == "array"
        assert results[2].engine_fallback_reason
        assert results[2].fused_cells == 0

    def test_faulted_cell_falls_back_alone(self):
        faults = FaultConfig(uncorrectable_prob=0.01)
        eligible = self._cells(("baseline", "sota"))
        faulted = [dataclasses.replace(c, engine="auto", faults=faults)
                   for c in self._cells(("baseline",))]
        results = run_cells(eligible + faulted, workers=1)
        assert [st.fused_cells for st in results] == [1, 1, 0]
        assert results[2].engine_selected == "array"
        assert results[2].engine_fallback_reason

    def test_singleton_not_fused(self):
        [st] = run_cells(self._cells(("baseline",)), workers=1)
        assert st.fused_cells == 0


class TestBucketsAndDispatch:
    """Cell-axis chunking/cap policy and dispatch accounting."""

    @pytest.mark.parametrize("n_seeds", [7, 8, 9])
    def test_cap_boundaries_stay_bit_identical(self, n_seeds):
        """Seed grids straddling the fused cell cap (8 on the default
        8-channel geometry: one under, exactly at, one over) hold
        parity, and an over-cap grid splits into a full chunk plus the
        remainder rather than stacking past the cache knee."""
        cap = _fuse_cell_cap(DEFAULT_SSD.n_channels)
        assert cap == 8
        seeds = tuple(range(n_seeds))
        kw = dict(conds=(AGED,), mechs=("baseline",), seeds=seeds, n=150)
        fused, seq = _grid(True, **kw), _grid(False, **kw)
        for key in seq:
            assert fused[key] == seq[key], key
        sizes = sorted(st.fused_cells for st in fused.values())
        full, rem = divmod(n_seeds, cap)
        want = sorted([cap] * (cap * full) + [rem] * rem)
        assert sizes == want

    def test_mixed_condition_grid_parity(self):
        """Condition-heterogeneous grids hold parity however the
        step-homogeneity chunker splits them (AGED cells run many more
        retry steps than MODEST ones)."""
        conds = (AGED, MODEST, OperatingCondition(120.0, 500.0))
        fused = _grid(True, conds=conds, mechs=MECHS, seeds=(0,), n=150)
        seq = _grid(False, conds=conds, mechs=MECHS, seeds=(0,), n=150)
        for key in seq:
            assert fused[key] == seq[key], key
        assert all(st.fused_cells >= 1 for st in fused.values())

    def test_single_dispatch_per_chunk(self):
        from repro.kernels.fcfs_core import ops as kops

        kw = dict(conds=(AGED,), mechs=MECHS, seeds=(0, 1, 2), n=150)
        _grid(True, **kw)                      # warm caches
        before = kops.KERNEL_DISPATCHES
        _grid(True, **kw)
        fused_n = kops.KERNEL_DISPATCHES - before
        before = kops.KERNEL_DISPATCHES
        _grid(False, **kw)
        seq_n = kops.KERNEL_DISPATCHES - before
        # Seeds of one (workload, condition, mechanism) combo run
        # near-identical step counts, so each mechanism's three seeds
        # share one dispatch: 3 launches for the 9-cell grid vs one per
        # cell sequentially.
        assert fused_n == 3
        assert seq_n == 9


class TestSweepJsonByteIdentity:
    """sweep_to_json is invariant across workers x fusion decisions."""

    def _blob(self, workers, fuse):
        return sweep_to_json(_grid(
            fuse, mechs=("baseline", "pr2ar2"), seeds=(0, 1), n=150,
            workers=workers,
        ))

    def test_workers_and_fusion_invariant(self):
        blobs = {(wk, fz): self._blob(wk, fz)
                 for wk in (1, 2) for fz in (True, False)}
        vals = list(blobs.values())
        assert all(v == vals[0] for v in vals[1:])
        payload = json.loads(vals[0])
        assert len(payload) == 2 * 2 * 2
        # Observability fields must not leak into the canonical bytes.
        for cell in payload.values():
            assert "fused_cells" not in cell
            assert "engine_selected" not in cell


class TestPrewarmGating:
    """prewarm compiles only variants the sweep will actually launch."""

    def test_auto_ineligible_warms_nothing(self):
        cells = [Cell("batch", "websearch", (AGED,), MECHS, 0,
                      DEFAULT_SSD, 200, "auto", "tokens", None, False)]
        assert _batched_sigs(cells) == set()
        assert prewarm_batched(cells) == 0

    def test_array_engine_warms_nothing(self):
        cells = [Cell("batch", "websearch", (AGED,), MECHS, 0,
                      DEFAULT_SSD, 200, "array", None, None, False)]
        assert _batched_sigs(cells) == set()

    def test_fused_lane_counts_included(self):
        n_ch = DEFAULT_SSD.n_channels
        n_dl = -(-DEFAULT_SSD.n_dies // n_ch)
        cells = [Cell("batch", "websearch", (AGED, MODEST), MECHS, 0,
                      DEFAULT_SSD, 200, "batched", None, None, False)]
        sigs = _batched_sigs(cells)
        # Per-cell variants for both pipelined classes...
        assert (n_ch, n_dl, False, "fifo") in sigs
        assert (n_ch, n_dl, True, "fifo") in sigs
        # ...plus the widened fused variants: 2 conds x 2 serial mechs
        # -> 4 cells, 2 conds x 1 pipelined mech -> 2 cells (both
        # clamped to the fused cell cap).
        cap = _fuse_cell_cap(n_ch)
        assert (min(4, cap) * n_ch, n_dl, False, "fifo") in sigs
        assert (min(2, cap) * n_ch, n_dl, True, "fifo") in sigs

    def test_fuse_off_drops_widened_variants(self):
        cells = [Cell("batch", "websearch", (AGED, MODEST), MECHS, 0,
                      DEFAULT_SSD, 200, "batched", None, None, False,
                      fuse=False)]
        n_ch = DEFAULT_SSD.n_channels
        assert all(sig[0] == n_ch for sig in _batched_sigs(cells))
