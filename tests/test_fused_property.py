"""Property-based check: widened fused kernel == cell-axis oracle.

Randomized multi-cell stacked op tables (cell count, lane count, die
count, per-cell timing scalars and aging bounds) must produce
bitwise-equal ``(fin, diestat, lane)`` between one
:func:`repro.kernels.fcfs_core.ops.fused_core` dispatch and the
per-cell oracle :func:`repro.kernels.fcfs_core.ref.fused_core_ref` —
the cell-axis law.  A deterministic seeded sweep always runs; when the
optional ``hypothesis`` dependency is installed (mirrors
``test_batched_property.py``), the same check additionally runs on
hypothesis-drawn shapes.
"""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels.fcfs_core.ops import fused_core, pad_ops, pad_width
from repro.kernels.fcfs_core.ref import fused_core_ref


def _table(rng, n_ops, n_dies):
    arr = np.sort(rng.uniform(0.0, 300.0, n_ops))
    kind = rng.choice([0.0, 0.0, 1.0, 2.0], size=n_ops)
    die = rng.integers(0, n_dies, n_ops).astype(np.float64)
    dur = rng.uniform(10.0, 60.0, n_ops)
    att = rng.integers(1, 6, n_ops).astype(np.float64)
    tr = rng.uniform(5.0, 25.0, n_ops)
    hp = np.where((kind == 0.0) & (rng.random(n_ops) < 0.5), 1.0, 0.0)
    return np.stack([arr, kind, die, dur, att, tr, hp], axis=1)


def _check_draw(draw):
    seed, n_cells, n_lanes, n_dies, max_ops, pipelined, prio = draw
    rng = np.random.default_rng(seed)
    maxp = 0
    cell_specs = []
    for _ in range(n_cells):
        lanes = [_table(rng, int(rng.integers(1, max_ops + 1)), n_dies)
                 for _ in range(n_lanes)]
        tdma = float(rng.uniform(1.0, 8.0))
        tecc = float(rng.uniform(1.0, 12.0))
        bound = (float(rng.choice([0.0, 2.0, 16.0, np.inf]))
                 if prio else None)
        cell_specs.append((lanes, tdma, tecc, bound))
        maxp = max(maxp, max(len(l) for l in lanes))

    maxp = pad_width(maxp)
    padded = [pad_ops(lanes, maxp=maxp)
              for lanes, _, _, _ in cell_specs]
    stacked = np.concatenate(padded, axis=0)
    timing = np.concatenate([
        np.tile([[tdma, tecc, bound if bound is not None else 0.0]],
                (n_lanes, 1))
        for _, tdma, tecc, bound in cell_specs
    ], axis=0)
    got = fused_core(stacked, n_dies, pipelined, timing, prio=prio)
    want = fused_core_ref(
        [(p, s[1], s[2], s[3]) for p, s in zip(padded, cell_specs)],
        n_dies, pipelined)
    for g, w in zip(got, want):
        assert np.array_equal(g, w)


#: Seeded draws covering both lowerings, ragged cell shapes, and the
#: narrow/wide carry-update crossover — run unconditionally so the
#: cell-axis law stays pinned even without hypothesis installed.
_SEEDED_DRAWS = [
    # (seed, cells, lanes/cell, dies, max ops, pipelined, prio)
    (11, 2, 1, 1, 4, False, False),
    (23, 3, 2, 2, 8, False, False),
    (37, 4, 3, 3, 12, False, True),
    (41, 5, 2, 2, 10, True, False),
    (53, 3, 4, 1, 6, True, True),
    (67, 2, 4, 3, 12, True, True),
    (79, 5, 4, 2, 9, False, True),
    (83, 4, 1, 2, 5, True, False),
]


@pytest.mark.parametrize("draw", _SEEDED_DRAWS,
                         ids=[f"seed{d[0]}" for d in _SEEDED_DRAWS])
def test_fused_kernel_matches_cell_axis_oracle_seeded(draw):
    _check_draw(draw)


if HAVE_HYPOTHESIS:
    _draws = st.tuples(
        st.integers(0, 2 ** 31 - 1),         # seed
        st.integers(2, 5),                   # cells
        st.integers(1, 4),                   # lanes per cell
        st.integers(1, 3),                   # dies per lane
        st.integers(1, 12),                  # max ops per lane
        st.booleans(),                       # pipelined
        st.booleans(),                       # prio lowering
    )

    @settings(max_examples=20, deadline=None)
    @given(_draws)
    def test_fused_kernel_matches_cell_axis_oracle(draw):
        _check_draw(draw)
