"""Scheduler-layer invariants: fcfs equivalence, priorities, preemption.

The die-queue scheduler (repro.flashsim.sched) must (a) leave the default
``fcfs`` policy bit-identical to the pre-refactor engine, (b) conserve
work under every policy (no idle die with a runnable op), (c) never
starve host reads under ``host_prio``, (d) account suspend/resume time
exactly (elapsed + residual == original duration), and (e) keep GC page
ops (rid == -1) out of host-read percentiles under every policy and GC
mode.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.retry import RetryPolicy
from repro.flashsim.config import (
    DEFAULT_SSD,
    GCConfig,
    OperatingCondition,
    SSDConfig,
)
from repro.flashsim.ftl import OP_ERASE, OP_READ, FTLSchedule, FTLStats
from repro.flashsim.sched import (
    DEFAULT_TOKEN_BUDGETS,
    SCHEDULERS,
    AgedHostPrioQueue,
    FCFSQueue,
    HostPrioQueue,
    TokenBudgetQueue,
    get_scheduler,
)
from repro.flashsim.ssd import SSDSim, _with_knobs, simulate
from repro.flashsim.workloads import (
    RequestTrace,
    Workload,
    cached_trace,
    make_workloads,
)

AGED = OperatingCondition(365.0, 1000.0)
GC_SSD = SSDConfig(gc=GCConfig(enabled=True))

STAT_FIELDS = (
    "mean_us", "p50_us", "p95_us", "p99_us", "read_mean_us", "read_p99_us",
    "n_requests", "mean_read_attempts", "die_util", "channel_util",
)


def _stats_tuple(s):
    return tuple(getattr(s, f) for f in STAT_FIELDS)


class TestQueuePolicies:
    def test_registry(self):
        assert SCHEDULERS == ("fcfs", "host_prio", "host_prio_aged",
                              "tokens", "preempt")
        assert not get_scheduler("fcfs").prioritized
        assert get_scheduler("host_prio").prioritized
        assert get_scheduler("host_prio_aged").prioritized
        assert not get_scheduler("host_prio_aged").preemptive
        assert get_scheduler("tokens").prioritized
        assert not get_scheduler("tokens").preemptive
        assert get_scheduler("preempt").preemptive
        with pytest.raises(ValueError, match="unknown scheduler"):
            get_scheduler("sjf")
        with pytest.raises(ValueError, match="unknown scheduler"):
            SSDConfig(scheduler="edf")
        # the aged policy takes a ':bound' suffix; tokens a ':r,w' one
        assert get_scheduler("host_prio_aged:8").name == "host_prio_aged:8"
        SSDConfig(scheduler="host_prio_aged:8")
        assert get_scheduler("tokens:6,2").name == "tokens:6,2"
        SSDConfig(scheduler="tokens:6,2")
        with pytest.raises(ValueError, match="unknown scheduler"):
            get_scheduler("fcfs:3")
        with pytest.raises(ValueError, match="age bound"):
            get_scheduler("host_prio_aged:many")
        # bad bounds fail at config time, not mid-simulation
        for bad in ("host_prio_aged:0", "host_prio_aged:-3"):
            with pytest.raises(ValueError, match="age bound"):
                get_scheduler(bad)
            with pytest.raises(ValueError, match="age bound"):
                SSDConfig(scheduler=bad)
        # trailing-colon names are not silently coerced to base policies
        for bad in ("fcfs:", "host_prio:", "host_prio_aged:", "tokens:"):
            with pytest.raises(ValueError, match="unknown scheduler"):
                get_scheduler(bad)
        # malformed token budgets fail at config time too
        for bad in ("tokens:3", "tokens:1,2,3", "tokens:a,b"):
            with pytest.raises(ValueError, match="token budgets"):
                get_scheduler(bad)
        for bad in ("tokens:0,2", "tokens:4,-1"):
            with pytest.raises(ValueError, match=">= 1"):
                get_scheduler(bad)
            with pytest.raises(ValueError, match=">= 1"):
                SSDConfig(scheduler=bad)

    def test_fcfs_queue_is_a_deque(self):
        q = FCFSQueue()
        q.append(3)
        q.append(7)
        assert len(q) == 2 and bool(q)
        assert q.pop_next() == 3 and q.pop_next() == 7
        assert not q

    def test_host_prio_queue_ordering(self):
        host = [True, False, True, False]
        q = HostPrioQueue(host)
        for op in (1, 0, 3, 2):        # mixed arrival order
            q.append(op)
        assert q.has_host()
        assert len(q) == 4
        # host reads (0, 2) drain first in FIFO order, then others (1, 3)
        assert [q.pop_next() for _ in range(4)] == [0, 2, 1, 3]
        q.append(1)
        q.resume_push(3)               # suspended op returns to the front
        assert not q.has_host()
        assert [q.pop_next(), q.pop_next()] == [3, 1]


class TestFCFSEquivalence:
    """The refactor contract: fcfs + prepass stays bit-identical."""

    @pytest.mark.parametrize("workload", ["websearch", "prxy"])
    @pytest.mark.parametrize("mechanism", ["baseline", "pr2ar2"])
    def test_fcfs_matches_reference_engine(self, workload, mechanism):
        """Explicit scheduler="fcfs" through the layered engine still
        reproduces the seed closure engine exactly (the parity cells of
        tests/test_flashsim_equiv.py)."""
        w = make_workloads()[workload]
        a = simulate(w, AGED, mechanism, seed=0, n_requests=400,
                     engine="array", scheduler="fcfs")
        r = simulate(w, AGED, mechanism, seed=0, n_requests=400,
                     engine="reference")
        assert _stats_tuple(a) == _stats_tuple(r)

    def test_explicit_knobs_match_defaults(self):
        w = make_workloads()["oltp"]
        base = simulate(w, AGED, "pr2ar2", seed=1, n_requests=300)
        knob = simulate(w, AGED, "pr2ar2", seed=1, n_requests=300,
                        scheduler="fcfs", gc="off")
        assert _stats_tuple(base) == _stats_tuple(knob)

    def test_prepass_gc_pinned_regression(self):
        """Bit-exact pins captured from the pre-refactor monolithic engine
        (PR 2) on churning GC cells: the layered fcfs engine must keep
        reproducing them."""
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=2500)
        s = simulate(w, AGED, "baseline", seed=0, cfg=GC_SSD)
        assert s.mean_us == 21098.711579084185
        assert s.p99_us == 201301.43863927457
        assert s.read_p99_us == 175671.61373988495
        assert s.mean_read_attempts == 13.797619047619047
        assert s.wa == 2.615843949044586
        assert (s.gc_invocations, s.blocks_erased) == (292, 292)

        w = dataclasses.replace(make_workloads()["prn"], n_requests=2500)
        s = simulate(w, AGED, "baseline", seed=0, cfg=GC_SSD)
        assert s.mean_us == 7634.964356587506
        assert s.read_p99_us == 150106.91833950975
        assert s.wa == 1.3831828442437923
        assert (s.gc_invocations, s.blocks_erased) == (102, 102)

    def test_host_prio_equals_fcfs_on_pure_read_trace(self):
        """With nothing but host reads every op is in the priority class,
        so host_prio degenerates to FIFO — bit-identical to fcfs."""
        w = Workload("allread", read_ratio=1.0, iops=14000, burstiness=2.0,
                     mean_pages=1.6, n_requests=400)
        a = simulate(w, AGED, "pr2ar2", seed=0, scheduler="fcfs")
        b = simulate(w, AGED, "pr2ar2", seed=0, scheduler="host_prio")
        assert _stats_tuple(a) == _stats_tuple(b)

    def test_reference_engine_rejects_schedulers(self):
        w = make_workloads()["websearch"]
        with pytest.raises(NotImplementedError, match="scheduler"):
            simulate(w, AGED, "baseline", seed=0, n_requests=100,
                     engine="reference", scheduler="host_prio")


class TestWorkConservation:
    """Engine-validated invariant: no idle die while its queue holds a
    runnable op — checked after every admission and event under all
    (scheduler x GC-mode) combinations."""

    @pytest.mark.parametrize("scheduler", SCHEDULERS)
    @pytest.mark.parametrize("gc", ["off", "prepass", "online"])
    def test_no_idle_die_with_ready_op(self, scheduler, gc):
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=800)
        trace = cached_trace(w, seed=1)
        cfg = _with_knobs(DEFAULT_SSD, scheduler, gc)
        sim = SSDSim(cfg, AGED, RetryPolicy("pr2ar2"), seed=9)
        stats = sim.run(trace, validate=True)   # raises on violation
        assert stats.n_requests == 800

    @pytest.mark.parametrize("scheduler", SCHEDULERS)
    def test_all_requests_complete(self, scheduler):
        w = dataclasses.replace(make_workloads()["prn"], n_requests=1000)
        trace = cached_trace(w, seed=0)
        cfg = _with_knobs(GC_SSD, scheduler, None)
        sim = SSDSim(cfg, AGED, RetryPolicy("baseline"), seed=7)
        sim.run(trace)
        assert (sim.last_req_done_us >= trace.arrival_us).all()


class TestHostPrioritization:
    def test_no_host_read_starvation_under_gc(self):
        """host_prio: every host read completes, and the worst read wait
        collapses relative to FCFS (reads no longer drain behind the
        whole GC backlog)."""
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=2500)
        trace = cached_trace(w, seed=0)
        out = {}
        for sched in ("fcfs", "host_prio"):
            cfg = _with_knobs(GC_SSD, sched, None)
            sim = SSDSim(cfg, AGED, RetryPolicy("baseline"), seed=7)
            stats = sim.run(trace)
            resp = sim.last_req_done_us - trace.arrival_us
            assert (sim.last_req_done_us >= trace.arrival_us).all()
            out[sched] = (stats, float(resp[trace.is_read].max()))
        fcfs_stats, fcfs_worst = out["fcfs"]
        prio_stats, prio_worst = out["host_prio"]
        assert prio_worst < fcfs_worst / 2
        assert prio_stats.read_p99_us < fcfs_stats.read_p99_us / 2
        # Work stays conserved: GC/write traffic still completes, so die
        # busy time is policy-invariant up to suspension-free reordering.
        assert prio_stats.wa == fcfs_stats.wa

    def test_host_writes_not_prioritized(self):
        """host_prio boosts reads only: on a write-heavy trace the overall
        mean (write-dominated) must not improve at the reads' expense
        beyond what contention relief explains — writes still queue FIFO
        behind GC."""
        w = dataclasses.replace(make_workloads()["prn"], n_requests=1500)
        fcfs = simulate(w, AGED, "baseline", seed=0, cfg=GC_SSD)
        prio = simulate(w, AGED, "baseline", seed=0, cfg=GC_SSD,
                        scheduler="host_prio")
        assert prio.read_p99_us < fcfs.read_p99_us
        # reads jumped ahead; writes absorbed the wait: the write-heavy
        # overall p99 may not collapse the way the read tail does
        assert prio.p99_us > prio.read_p99_us


def _micro_erase_vs_read():
    """One die, one channel: an erase at t=0 and a host read at t=100."""
    cfg = SSDConfig(n_channels=1, dies_per_channel=1)
    trace = RequestTrace(
        arrival_us=np.array([100.0]),
        is_read=np.array([True]),
        n_pages=np.array([1], np.int64),
        start_page=np.array([0], np.int64),
    )
    stats = FTLStats(
        host_reads=1, host_progs=0, prefill_progs=0, gc_page_reads=0,
        gc_page_progs=0, blocks_erased=1, gc_invocations=1,
        write_amplification=1.0, blocks_per_die=4, pages_per_block=16,
        footprint_pages=1, max_block_pe=1.0,
    )
    schedule = FTLSchedule(
        arrival_us=np.array([0.0, 100.0]),
        rid=np.array([-1, 0], np.int64),
        die=np.array([0, 0], np.int64),
        chan=np.array([0, 0], np.int64),
        ptype=np.array([0, 0], np.int64),
        kind=np.array([OP_ERASE, OP_READ], np.int64),
        dur_us=np.array([3000.0, 0.0]),
        wear_pec=np.array([0.0, 0.0]),
        n_requests=1,
        stats=stats,
    )
    return cfg, trace, schedule


class TestPreemption:
    def test_erase_suspend_resume_accounting(self):
        """A host read arriving mid-erase suspends it; elapsed + residual
        must sum to the original t_erase — total die busy time is exactly
        policy-invariant — while the read finishes far earlier."""
        cfg, trace, schedule = _micro_erase_vs_read()
        runs = {}
        for sched in ("fcfs", "preempt"):
            c = dataclasses.replace(cfg, scheduler=sched)
            sim = SSDSim(c, OperatingCondition(0.0, 0.0),
                         RetryPolicy("baseline"), seed=3)
            stats = sim.run(trace, schedule=schedule, validate=True)
            runs[sched] = (sim, stats)
        sim_f, st_f = runs["fcfs"]
        sim_p, st_p = runs["preempt"]
        # identical RNG stream -> identical attempt draw for the read
        assert st_f.mean_read_attempts == st_p.mean_read_attempts
        # suspend happened exactly once, and only under preempt
        assert sim_f.last_gc_suspensions == 0
        assert sim_p.last_gc_suspensions == 1
        assert st_p.gc_suspensions == 1
        # time accounting: elapsed-before-suspend + residual == t_erase,
        # so total die busy time matches fcfs exactly (work conserved)
        assert sim_p.last_die_busy_us == pytest.approx(
            sim_f.last_die_busy_us, rel=1e-12)
        # the read no longer waits out the 3 ms erase
        read_f = float(sim_f.last_req_done_us[0]) - 100.0
        read_p = float(sim_p.last_req_done_us[0]) - 100.0
        assert read_f > 2900.0
        assert read_p < 300.0

    def test_erase_resumes_after_double_suspension(self):
        """Two host reads staggered across the erase: each suspends the
        residual anew; accounting still sums exactly."""
        cfg, trace, schedule = _micro_erase_vs_read()
        trace = RequestTrace(
            arrival_us=np.array([100.0, 1500.0]),
            is_read=np.array([True, True]),
            n_pages=np.array([1, 1], np.int64),
            start_page=np.array([0, 1], np.int64),
        )
        schedule = dataclasses.replace(
            schedule,
            arrival_us=np.array([0.0, 100.0, 1500.0]),
            rid=np.array([-1, 0, 1], np.int64),
            die=np.array([0, 0, 0], np.int64),
            chan=np.array([0, 0, 0], np.int64),
            ptype=np.array([0, 0, 0], np.int64),
            kind=np.array([OP_ERASE, OP_READ, OP_READ], np.int64),
            dur_us=np.array([3000.0, 0.0, 0.0]),
            wear_pec=np.zeros(3),
            n_requests=2,
        )
        runs = {}
        for sched in ("fcfs", "preempt"):
            c = dataclasses.replace(cfg, scheduler=sched)
            sim = SSDSim(c, OperatingCondition(0.0, 0.0),
                         RetryPolicy("baseline"), seed=3)
            sim.run(trace, schedule=schedule, validate=True)
            runs[sched] = sim
        assert runs["preempt"].last_gc_suspensions == 2
        assert runs["preempt"].last_die_busy_us == pytest.approx(
            runs["fcfs"].last_die_busy_us, rel=1e-12)
        assert (runs["preempt"].last_req_done_us
                < runs["fcfs"].last_req_done_us).all()

    def test_gc_read_suspends_at_attempt_boundaries(self):
        """Aged-condition GC reads retry ~14x; under preempt a waiting
        host read cuts in at a boundary.  Macro check: suspensions occur
        and the read tail tightens beyond host_prio."""
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=2500)
        prio = simulate(w, AGED, "baseline", seed=0, cfg=GC_SSD,
                        scheduler="host_prio")
        pre = simulate(w, AGED, "baseline", seed=0, cfg=GC_SSD,
                       scheduler="preempt")
        assert pre.gc_suspensions > 0
        assert prio.gc_suspensions == 0
        assert pre.read_p99_us < prio.read_p99_us
        assert pre.wa == prio.wa    # prepass mapping is policy-invariant

    @pytest.mark.parametrize("mechanism", ["baseline", "pr2ar2"])
    def test_preempt_beats_fcfs_read_tail(self, mechanism):
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=2500)
        fcfs = simulate(w, AGED, mechanism, seed=0, cfg=GC_SSD)
        pre = simulate(w, AGED, mechanism, seed=0, cfg=GC_SSD,
                       scheduler="preempt")
        assert pre.read_p99_us < fcfs.read_p99_us / 2


class TestReadP99ExcludesGC:
    """Regression (satellite): SimStats.read_p99_us is computed over host
    requests only — GC page-ops (rid == -1) must never leak into host
    percentiles under any scheduler policy or GC mode, preemption
    included."""

    @pytest.mark.parametrize("scheduler", SCHEDULERS)
    @pytest.mark.parametrize("gc", ["prepass", "online"])
    def test_read_p99_over_host_requests_only(self, scheduler, gc):
        w = dataclasses.replace(make_workloads()["prn"], n_requests=1200)
        trace = cached_trace(w, seed=0)
        cfg = _with_knobs(DEFAULT_SSD, scheduler, gc)
        sim = SSDSim(cfg, AGED, RetryPolicy("pr2ar2"), seed=7)
        stats = sim.run(trace)
        # completion vector covers exactly the host requests
        assert sim.last_req_done_us.shape == (1200,)
        assert stats.n_requests == 1200
        # GC ops ran (rid == -1 traffic existed) ...
        assert stats.gc_page_reads > 0
        # ... and the reported read p99 recomputes from host reads alone
        resp = (sim.last_req_done_us - trace.arrival_us
                + cfg.host_overhead_us)
        expect = float(np.percentile(resp[trace.is_read], 99))
        assert stats.read_p99_us == expect
        assert stats.p99_us == float(np.percentile(resp, 99))


class TestAgedHostPrio:
    """Satellite: the starvation-bounded host-priority policy."""

    def test_queue_ages_low_class_after_bound(self):
        host = [i % 2 == 0 for i in range(12)]   # even ops are host reads
        q = AgedHostPrioQueue(host, age_bound=2)
        q.append(1)                              # lo (GC/program)
        for op in (0, 2, 4, 6):                  # hi backlog
            q.append(op)
        # two hi pops bypass the waiting lo op, then it ages to the front
        assert [q.pop_next() for _ in range(3)] == [0, 2, 1]
        # counter reset: hi resumes afterwards
        assert q.pop_next() == 4

    def test_queue_counter_resets_when_low_drains(self):
        host = [True, False, True, True, True]
        q = AgedHostPrioQueue(host, age_bound=2)
        q.append(1)
        q.append(0)
        q.append(2)
        assert q.pop_next() == 0     # bypass 1
        assert q.pop_next() == 2     # bypass 2
        assert q.pop_next() == 1     # aged (hi empty anyway)
        # fresh wait: the bound applies anew to the next lo arrival
        q.append(1)
        for op in (3, 4):
            q.append(op)
        assert [q.pop_next() for _ in range(3)] == [3, 4, 1]

    @staticmethod
    def _sustained_read_phase():
        """Single die: one 3 ms erase queued at t=0.5us behind a read,
        then a 100%-read phase (80 reads, one per 20us) that keeps the
        high-priority class non-empty for the whole window — the
        starvation scenario for plain host_prio."""
        cfg = SSDConfig(n_channels=1, dies_per_channel=1)
        n_reads = 80
        arr_reads = 20.0 * np.arange(n_reads)
        trace = RequestTrace(
            arrival_us=arr_reads,
            is_read=np.ones(n_reads, bool),
            n_pages=np.ones(n_reads, np.int64),
            start_page=np.arange(n_reads, dtype=np.int64),
        )
        stats = FTLStats(
            host_reads=n_reads, host_progs=0, prefill_progs=0,
            gc_page_reads=0, gc_page_progs=0, blocks_erased=1,
            gc_invocations=1, write_amplification=1.0, blocks_per_die=4,
            pages_per_block=16, footprint_pages=n_reads, max_block_pe=1.0,
        )
        arrival = np.concatenate(([arr_reads[0]], [0.5], arr_reads[1:]))
        rid = np.concatenate(([0], [-1], np.arange(1, n_reads))).astype(np.int64)
        kind = np.concatenate(([OP_READ], [OP_ERASE],
                               np.full(n_reads - 1, OP_READ))).astype(np.int64)
        dur = np.where(kind == OP_ERASE, 3000.0, 0.0)
        z = np.zeros(n_reads + 1, np.int64)
        schedule = FTLSchedule(
            arrival_us=arrival, rid=rid, die=z, chan=z, ptype=z, kind=kind,
            dur_us=dur, wear_pec=np.zeros(n_reads + 1), n_requests=n_reads,
            stats=stats,
        )
        return cfg, trace, schedule

    def test_no_starvation_under_sustained_reads(self):
        """Satellite acceptance: under a sustained 100%-read phase,
        plain host_prio starves the queued erase until the read phase
        drains; host_prio_aged:8 serves it after at most 8 bypassing
        reads — visible as a >= 2 ms erase-sized gap inside the first
        few read completions, with exact work conservation either way."""
        cfg, trace, schedule = self._sustained_read_phase()
        done = {}
        for sched in ("host_prio", "host_prio_aged:8"):
            c = dataclasses.replace(cfg, scheduler=sched)
            sim = SSDSim(c, OperatingCondition(0.0, 0.0),
                         RetryPolicy("baseline"), seed=3)
            sim.run(trace, schedule=schedule, validate=True)
            done[sched] = np.sort(sim.last_req_done_us)
        gaps_prio = np.diff(done["host_prio"])
        gaps_aged = np.diff(done["host_prio_aged:8"])
        # host_prio: no erase-sized hole between read completions — the
        # erase waited out the entire read phase (starved)
        assert gaps_prio.max() < 2000.0
        # aged: the erase ran inside the read phase, after <= bound + the
        # in-flight read; at most 9 reads complete before the 3 ms hole
        hole = int(np.argmax(gaps_aged >= 2000.0))
        assert gaps_aged[hole] >= 2000.0, "erase never aged into the phase"
        assert hole + 1 <= 9, f"{hole + 1} reads completed before the erase"
        # and the erase still completes in both runs: the last read of the
        # aged run finishes ~t_erase later than under host_prio
        assert done["host_prio_aged:8"][-1] > done["host_prio"][-1] + 2000.0


class TestTokenBudget:
    """Satellite: per-die read/write token-budget scheduler."""

    def test_budget_enforcement_under_full_backlog(self):
        """With both classes backlogged, a round serves exactly r reads
        then w writes, repeating — the configured bandwidth split."""
        host = [i < 8 for i in range(12)]        # ops 0-7 reads, 8-11 lo
        q = TokenBudgetQueue(host, r_budget=3, w_budget=2)
        for op in range(12):
            q.append(op)
        got = [q.pop_next() for _ in range(10)]
        #       round 1: 3 reads, 2 writes | round 2: 3 reads, 2 writes
        assert got == [0, 1, 2, 8, 9, 3, 4, 5, 10, 11]
        # low class drained: remaining reads flow FIFO
        assert [q.pop_next() for _ in range(2)] == [6, 7]
        assert not q

    def test_writes_never_exceed_budget_while_reads_wait(self):
        """A waiting read sees at most w consecutive low-priority
        dispatches (once the read class drains, the write tail is
        uncontended and flows freely)."""
        host = [i % 2 == 0 for i in range(40)]
        q = TokenBudgetQueue(host, r_budget=2, w_budget=1)
        for op in range(40):
            q.append(op)
        run_lo = worst = 0
        while q:
            contended = bool(q.hi)
            if host[q.pop_next()]:
                run_lo = 0
            elif contended:
                run_lo += 1
                worst = max(worst, run_lo)
        assert worst == 1

    def test_uncontended_classes_reset_the_round(self):
        """Budgets meter contention only: an empty low class serves
        reads immediately and restarts the round."""
        host = [True, True, True, True, False]
        q = TokenBudgetQueue(host, r_budget=2, w_budget=1)
        q.append(0)
        q.append(1)
        assert [q.pop_next(), q.pop_next()] == [0, 1]   # uncontended
        q.append(2)
        q.append(3)
        q.append(4)                                     # lo arrives
        # fresh round: 2 reads, then the write
        assert [q.pop_next() for _ in range(3)] == [2, 3, 4]

    def test_queue_rejects_bad_budgets(self):
        with pytest.raises(ValueError, match=">= 1"):
            TokenBudgetQueue([True], r_budget=0, w_budget=1)

    def test_default_budgets(self):
        q = TokenBudgetQueue([True])
        assert (q.r_budget, q.w_budget) == DEFAULT_TOKEN_BUDGETS

    def test_pure_read_trace_equals_fcfs(self):
        """All ops in the read class: tokens degenerates to FIFO —
        bit-identical to fcfs (mirrors the host_prio parity test)."""
        w = Workload("allread", read_ratio=1.0, iops=14000, burstiness=2.0,
                     mean_pages=1.6, n_requests=400)
        a = simulate(w, AGED, "pr2ar2", seed=0, scheduler="fcfs")
        b = simulate(w, AGED, "pr2ar2", seed=0, scheduler="tokens:4,1")
        assert _stats_tuple(a) == _stats_tuple(b)

    def test_work_conserved_and_wa_invariant_under_gc(self):
        """Engine-validated work conservation (every step) plus the
        prepass-mapping invariant: WA must not depend on the policy."""
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=1200)
        trace = cached_trace(w, seed=0)
        fcfs = SSDSim(GC_SSD, AGED, RetryPolicy("baseline"), seed=7)
        f_stats = fcfs.run(trace)
        cfg = _with_knobs(GC_SSD, "tokens:6,2", None)
        tok = SSDSim(cfg, AGED, RetryPolicy("baseline"), seed=7)
        t_stats = tok.run(trace, validate=True)    # raises on violation
        assert t_stats.wa == f_stats.wa
        assert (t_stats.gc_invocations, t_stats.blocks_erased) == \
            (f_stats.gc_invocations, f_stats.blocks_erased)
        assert (tok.last_req_done_us >= trace.arrival_us).all()

    def test_reads_jump_gc_backlog_but_writes_keep_slots(self):
        """Against fcfs, the read tail collapses (reads bypass the GC
        burst); against host_prio, GC/write work is serviced no later —
        the budget guarantees low-priority slots during read phases."""
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=2500)
        fcfs = simulate(w, AGED, "baseline", seed=0, cfg=GC_SSD)
        tok = simulate(w, AGED, "baseline", seed=0, cfg=GC_SSD,
                       scheduler="tokens:8,1")
        assert tok.read_p99_us < fcfs.read_p99_us / 2
        assert tok.wa == fcfs.wa

    def test_no_starvation_under_sustained_reads(self):
        """The erase-vs-read-phase scenario that starves plain host_prio
        (see TestAgedHostPrio above): with tokens:4,1 the erase gets its
        slot within one round — at most 4 reads complete first."""
        cfg, trace, schedule = TestAgedHostPrio._sustained_read_phase()
        done = {}
        for sched in ("host_prio", "tokens:4,1"):
            c = dataclasses.replace(cfg, scheduler=sched)
            sim = SSDSim(c, OperatingCondition(0.0, 0.0),
                         RetryPolicy("baseline"), seed=3)
            sim.run(trace, schedule=schedule, validate=True)
            done[sched] = np.sort(sim.last_req_done_us)
        gaps = np.diff(done["tokens:4,1"])
        hole = int(np.argmax(gaps >= 2000.0))
        assert gaps[hole] >= 2000.0, "erase never ran inside the phase"
        assert hole + 1 <= 5, f"{hole + 1} reads completed before the erase"
        # host_prio starves it until the phase drains (regression anchor)
        assert np.diff(done["host_prio"]).max() < 2000.0
