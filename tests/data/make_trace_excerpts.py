"""Regenerate the checked-in trace excerpts under tests/data/.

The MSR-Cambridge traces themselves are not redistributable, so the
repo checks in two small **MSR-format** excerpts (plus one blkparse-text
sample) with the statistical shapes of their namesakes — the same
stand-in policy the synthetic MMPP profiles follow, but exercising the
*real ingestion path*: FILETIME timestamps, byte offsets/sizes over a
sparse volume-sized LBA span, gzip framing, and blkparse field layout.

  * ``web_0.csv.gz``   — read-dominant web server class (~90% reads,
    bursty arrivals, ~96 GiB span with hot regions);
  * ``src1_1.csv.gz``  — write-dominated source-control class (~25%
    reads re-walking a ~16 MiB hot set: the GC-churn regime);
  * ``blk_sample.txt`` — blkparse default text output (Q/C/G events,
    noise lines, trailing summary) for the blktrace parser.

Deterministic (fixed seeds): re-running this script reproduces the
checked-in bytes.  Run from the repo root:

    python tests/data/make_trace_excerpts.py
"""

from __future__ import annotations

import gzip
import pathlib

import numpy as np

HERE = pathlib.Path(__file__).resolve().parent

#: 2007-03-01-ish in Windows FILETIME (100 ns ticks since 1601) — the
#: MSR-Cambridge collection era.
FILETIME_BASE = 128_166_372_000_000_000

SECTOR = 512


def mmpp_gaps_us(rng, n, iops, burstiness, run=64):
    """Bursty inter-arrival gaps (us), mean rate ``iops`` (MMPP-like)."""
    if burstiness <= 1.0:
        return rng.exponential(1e6 / iops, n)
    r_burst = burstiness * iops
    r_idle = 0.5 * iops / (1.0 - 0.5 / burstiness)
    idx = np.arange(n) // run
    burst = rng.random(idx.max() + 1) < 0.5
    return np.where(burst[idx],
                    rng.exponential(1e6 / r_burst, n),
                    rng.exponential(1e6 / r_idle, n))


def sizes_bytes(rng, n, mean_kib=12.0):
    """4 KiB-granular sizes, small-biased geometric, 4-64 KiB."""
    k = rng.geometric(4.0 / mean_kib, n).clip(1, 16)   # units of 4 KiB
    return k * 4096


def write_msr_csv(path, host, ts_us, is_read, offset, size):
    rows = []
    for t, r, o, s in zip(ts_us, is_read, offset, size):
        ft = FILETIME_BASE + int(round(t * 10.0))      # us -> 100 ns ticks
        typ = "Read" if r else "Write"
        resp = 100 + (o % 9000)                        # cosmetic field
        rows.append(f"{ft},{host},0,{typ},{o},{s},{resp}")
    data = ("\n".join(rows) + "\n").encode()
    with gzip.GzipFile(path, "wb", compresslevel=9, mtime=0) as f:
        f.write(data)
    print(f"wrote {path} ({len(rows)} rows)")


def make_web_0():
    """Read-dominant, sparse: hot regions scattered across ~96 GiB."""
    rng = np.random.default_rng(20260801)
    n = 2600
    ts = np.cumsum(mmpp_gaps_us(rng, n, iops=11000, burstiness=2.5))
    is_read = rng.random(n) < 0.90
    # 24 hot regions of 4 MiB each across a 96 GiB volume + cold tail.
    region = rng.integers(0, 24, n)
    region_base = rng.integers(0, 96 * 2**30 // (4096 * 4096), 24) \
        * (4 * 2**20)
    off = region_base[region] + rng.integers(0, 4 * 2**20 // 4096, n) * 4096
    cold = rng.random(n) < 0.15
    off[cold] = rng.integers(0, 96 * 2**30 // 4096, cold.sum()) * 4096
    write_msr_csv(HERE / "web_0.csv.gz", "web", ts, is_read, off,
                  sizes_bytes(rng, n, mean_kib=14.0))


def make_src1_1():
    """Write-dominated, hot: ~16 MiB working set overwritten repeatedly."""
    rng = np.random.default_rng(19530)
    n = 2600
    ts = np.cumsum(mmpp_gaps_us(rng, n, iops=9000, burstiness=2.0))
    is_read = rng.random(n) < 0.25
    hot_bytes = 16 * 2**20
    # Zipf-ish hotness inside the working set: square a uniform so low
    # offsets are overwritten far more often (GC victims stay skewed).
    u = rng.random(n) ** 2
    off = (u * (hot_bytes // 4096 - 16)).astype(np.int64) * 4096
    write_msr_csv(HERE / "src1_1.csv.gz", "src1", ts, is_read, off,
                  sizes_bytes(rng, n, mean_kib=10.0))


def make_blk_sample():
    """blkparse default text output: Q events + non-Q noise + summary."""
    rng = np.random.default_rng(777)
    n = 420
    ts = np.cumsum(rng.exponential(1e6 / 8000, n)) / 1e6   # seconds
    is_read = rng.random(n) < 0.6
    sector = rng.integers(0, 40 * 2**30 // SECTOR // 8, n) * 8
    nsect = rng.geometric(0.35, n).clip(1, 64) * 8
    lines = []
    for i in range(n):
        t = ts[i]
        rwbs = "R" if is_read[i] else "WS"
        lines.append(
            f"  8,0   {i % 4}  {i + 1:6d} {t:12.9f} {1000 + i % 7:5d}  Q "
            f"{rwbs} {sector[i]} + {nsect[i]} [repro-gen]"
        )
        if i % 7 == 0:     # completion events the parser must skip
            lines.append(
                f"  8,0   {i % 4}  {i + 1:6d} {t + 0.0001:12.9f} "
                f"{1000 + i % 7:5d}  C {rwbs} {sector[i]} + {nsect[i]} [0]"
            )
        if i % 50 == 0:    # plug lines: no '+ nsectors' payload
            lines.append(
                f"  8,0   {i % 4}  {i + 1:6d} {t:12.9f} "
                f"{1000 + i % 7:5d}  P   N [repro-gen]"
            )
    lines += ["", "CPU0 (8,0):", " Reads Queued:         252,       1008KiB",
              " Writes Queued:        168,        672KiB"]
    (HERE / "blk_sample.txt").write_text("\n".join(lines) + "\n")
    print(f"wrote {HERE / 'blk_sample.txt'} ({n} Q events)")


if __name__ == "__main__":
    make_web_0()
    make_src1_1()
    make_blk_sample()
