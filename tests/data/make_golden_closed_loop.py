"""Regenerate tests/data/golden_closed_loop.json.

The golden file pins the OPEN-LOOP (``ncq_depth=None``) output of the
simulator across the scheduler x GC x faults matrix so that the
closed-loop frontend (PR 7) can assert bit-parity: with the NCQ knob
left at its default, every stat that existed before the closed-loop
code landed must be byte-identical.

Run from the repo root (only when the open-loop contract legitimately
changes, which should essentially never happen):

    PYTHONPATH=src python tests/data/make_golden_closed_loop.py
"""

from __future__ import annotations

import dataclasses
import json
import pathlib

from repro.flashsim import FaultConfig, OperatingCondition, simulate

OUT = pathlib.Path(__file__).resolve().parent / "golden_closed_loop.json"

N = 600
SEED = 0
COND = OperatingCondition(retention_days=365.0, pec=1000.0)

SCHEDULERS = ("fcfs", "host_prio", "host_prio_aged:8", "tokens:4,2", "preempt")
GC_MODES = ("off", "prepass", "online")
FAULTS = {
    "none": None,
    "fc": FaultConfig(
        uncorrectable_prob=0.02, mispredict_scale=4.0, escalation_attempts=2,
    ),
}


def cell_key(mech: str, sched: str, gc: str, faults: str) -> str:
    return f"{mech}|{sched}|{gc}|{faults}"


def main() -> None:
    cells = {}
    for sched in SCHEDULERS:
        for gc in GC_MODES:
            for fname, fc in FAULTS.items():
                stats = simulate(
                    "prn", COND, "pr2ar2", seed=SEED, n_requests=N,
                    scheduler=sched, gc=gc, faults=fc,
                )
                cells[cell_key("pr2ar2", sched, gc, fname)] = (
                    dataclasses.asdict(stats)
                )
    # A couple of baseline-mechanism / read-heavy cells so the pin is not
    # pr2ar2-only.
    for mech in ("baseline", "sota+pr2ar2"):
        stats = simulate(
            "websearch", COND, mech, seed=SEED, n_requests=N,
            scheduler="fcfs", gc="off",
        )
        cells[cell_key(mech, "fcfs", "off", "none")] = dataclasses.asdict(stats)

    payload = {
        "meta": {
            "workload": "prn",
            "extra_workload": "websearch",
            "n_requests": N,
            "seed": SEED,
            "condition": {"retention_days": COND.retention_days,
                          "pec": COND.pec},
            "schedulers": list(SCHEDULERS),
            "gc_modes": list(GC_MODES),
            "fault_configs": {
                "none": None,
                "fc": {"uncorrectable_prob": 0.02, "mispredict_scale": 4.0,
                       "escalation_attempts": 2},
            },
        },
        "cells": cells,
    }
    OUT.write_text(json.dumps(payload, indent=1, sort_keys=True) + "\n")
    print(f"wrote {OUT} ({len(cells)} cells)")


if __name__ == "__main__":
    main()
