"""Property-based check: batched lockstep core == scalar interpreter.

Randomized traces (profile mix, size, seed, operating corner) drawn by
hypothesis; every supported draw must produce full ``SimStats``
equality between ``engine="array"`` and ``engine="batched"``.  Skipped
when the optional ``hypothesis`` dependency is absent (mirrors
``test_properties.py``).
"""

import pytest

pytest.importorskip(
    "hypothesis",
    reason="optional dependency 'hypothesis' not installed; "
           "property tests skipped",
)

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.flashsim.config import OperatingCondition
from repro.flashsim.ssd import simulate

_draws = st.tuples(
    st.sampled_from(["websearch", "oltp", "prxy", "ycsb-b"]),
    st.sampled_from(["baseline", "pr2ar2", "sota"]),
    st.integers(0, 31),              # seed
    st.integers(50, 500),            # n_requests
    st.floats(0.0, 365.0),           # retention days
    st.floats(0.0, 1500.0),          # P/E cycles
    st.sampled_from([None, "prepass"]),
)


@settings(max_examples=20, deadline=None)
@given(_draws)
def test_batched_equals_scalar_on_random_traces(draw):
    workload, mechanism, seed, n, ret, pec, gc = draw
    cond = OperatingCondition(ret, pec)
    a = simulate(workload, cond, mechanism, seed=seed, n_requests=n,
                 engine="array", gc=gc)
    b = simulate(workload, cond, mechanism, seed=seed, n_requests=n,
                 engine="batched", gc=gc)
    assert a == b
