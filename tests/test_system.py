"""End-to-end behaviour: train->checkpoint->restart equivalence, loss
improvement, sharded lowering on a host mesh, HLO cost analyzer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow  # heavyweight: JAX training + full lowering

from repro.configs import get_config
from repro.configs.base import ShapeConfig, reduced_config
from repro.data import CorpusConfig, SyntheticCorpus
from repro.models.api import build_model
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_config(get_config("llama3.2-3b"))
    model = build_model(cfg)
    corpus = SyntheticCorpus(
        CorpusConfig(vocab=cfg.vocab, seq_len=64, batch=4, seed=0)
    )
    opt_cfg = AdamWConfig(lr=1e-3)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
        p2, o2, m = adamw_update(grads, opt, params, opt_cfg)
        return p2, o2, loss

    return cfg, model, corpus, opt_cfg, step


def _run(model, corpus, opt_cfg, step, params, opt, start, n):
    losses = []
    for i in range(start, start + n):
        batch = {k: jnp.asarray(v) for k, v in corpus.batch(i).items()}
        params, opt, loss = step(params, opt, batch)
        losses.append(float(loss))
    return params, opt, losses


class TestTrainSystem:
    def test_loss_decreases(self, setup):
        cfg, model, corpus, opt_cfg, step = setup
        params = model.init(jax.random.PRNGKey(0))
        opt = init_opt_state(params, opt_cfg)
        _, _, losses = _run(model, corpus, opt_cfg, step, params, opt, 0, 30)
        assert np.mean(losses[-5:]) < np.mean(losses[:5])
        assert np.isfinite(losses).all()

    def test_checkpoint_restart_bitwise_equivalent(self, setup, tmp_path):
        """train(8) == train(4) -> save -> restore -> train(4): the
        deterministic-replay contract checkpoint/restart relies on."""
        from repro.checkpoint import restore, save

        cfg, model, corpus, opt_cfg, step = setup
        params = model.init(jax.random.PRNGKey(0))
        opt = init_opt_state(params, opt_cfg)

        pA, oA, _ = _run(model, corpus, opt_cfg, step, params, opt, 0, 8)

        pB, oB, _ = _run(model, corpus, opt_cfg, step, params, opt, 0, 4)
        save(tmp_path / "ck", {"params": pB, "opt": oB})
        state, _ = restore(tmp_path / "ck", {"params": pB, "opt": oB})
        pB2 = jax.tree.map(jnp.asarray, state["params"])
        oB2 = jax.tree.map(jnp.asarray, state["opt"])
        pB3, oB3, _ = _run(model, corpus, opt_cfg, step, pB2, oB2, 4, 4)

        for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB3)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_gradient_compression_trains(self, setup):
        """Loss still decreases when the int8+error-feedback wire format
        replaces the exact gradients."""
        from repro.distributed.compress import compress_grads, init_error_feedback

        cfg, model, corpus, opt_cfg, _ = setup
        params = model.init(jax.random.PRNGKey(1))
        opt = init_opt_state(params, opt_cfg)
        ef = init_error_feedback(params)
        losses = []

        @jax.jit
        def step_c(params, opt, ef, batch):
            loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
            grads, ef = compress_grads(grads, ef)
            p2, o2, _ = adamw_update(grads, opt, params, opt_cfg)
            return p2, o2, ef, loss

        for i in range(25):
            batch = {k: jnp.asarray(v) for k, v in corpus.batch(i).items()}
            params, opt, ef, loss = step_c(params, opt, ef, batch)
            losses.append(float(loss))
        assert np.mean(losses[-5:]) < np.mean(losses[:5])


class TestShardedLowering:
    def test_host_mesh_train_cell_lowers(self):
        """The same build_cell the 512-device dry-run uses, on the host
        mesh — catches sharding-rule regressions in CI without 512 devices."""
        from repro.distributed.steps import build_cell
        from repro.launch.mesh import make_host_mesh

        cfg = reduced_config(get_config("gemma2-2b"))
        shape = ShapeConfig("t", 64, 4, "train")
        mesh = make_host_mesh()
        jitted, arg_specs, _ = build_cell(cfg, shape, mesh)
        compiled = jitted.lower(*arg_specs).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list):  # older jax returns [dict], newer dict
            ca = ca[0]
        assert ca.get("flops", 0) > 0

    def test_host_mesh_decode_cell_lowers(self):
        from repro.distributed.steps import build_cell
        from repro.launch.mesh import make_host_mesh

        cfg = reduced_config(get_config("olmoe-1b-7b"))
        shape = ShapeConfig("d", 128, 4, "decode")
        mesh = make_host_mesh()
        jitted, arg_specs, _ = build_cell(cfg, shape, mesh)
        assert jitted.lower(*arg_specs).compile() is not None


class TestHLOCostAnalyzer:
    def test_scan_trip_count_multiplier(self):
        from repro.launch import hlo_cost

        def make(length):
            def f(x, w):
                def body(c, _):
                    return jnp.tanh(c @ w), None
                y, _ = jax.lax.scan(body, x, None, length=length)
                return y.sum()
            return f

        spec = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        flops = {}
        for L in (2, 8):
            txt = jax.jit(jax.grad(make(L))).lower(spec, spec).compile().as_text()
            flops[L] = hlo_cost.analyze(txt).flops
        # 2 dots per scan iteration (fwd + dx): flops scale ~linearly in L
        assert flops[8] / flops[2] == pytest.approx(4.0, rel=0.3)

    def test_no_collectives_on_single_device(self):
        from repro.launch import hlo_cost

        txt = (
            jax.jit(lambda x: (x @ x).sum())
            .lower(jax.ShapeDtypeStruct((64, 64), jnp.float32))
            .compile()
            .as_text()
        )
        c = hlo_cost.analyze(txt)
        assert sum(c.coll_counts.values()) == 0
        assert c.flops >= 2 * 64**3
