"""Execute every ```python code block in README.md and docs/*.md.

The CI docs lane runs this module so quick-start snippets cannot rot:
a renamed API, changed default, or stale assertion in the docs fails the
build.  Blocks execute top-to-bottom *per document* in one shared
namespace (later snippets may reuse names introduced earlier), each
document isolated from the others.

Keep doc snippets small (n_requests <= 2000) — they run in CI.
"""

from __future__ import annotations

import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOCS = sorted([ROOT / "README.md", *(ROOT / "docs").glob("*.md")])

_BLOCK_RE = re.compile(r"```python\n(.*?)```", re.S)


def _doc_params():
    for path in DOCS:
        if not path.exists():
            continue
        blocks = _BLOCK_RE.findall(path.read_text())
        if blocks:
            yield pytest.param(path, blocks, id=path.name)


@pytest.mark.parametrize("path,blocks", list(_doc_params()))
def test_doc_snippets_execute(path, blocks):
    ns = {"__name__": f"doc_snippet[{path.name}]"}
    for i, code in enumerate(blocks):
        try:
            exec(compile(code, f"<{path.name} block {i}>", "exec"), ns)
        except Exception as e:  # pragma: no cover - failure reporting
            pytest.fail(
                f"{path.name} code block {i} failed: {type(e).__name__}: {e}\n"
                f"--- block ---\n{code}"
            )


def test_docs_exist_and_have_snippets():
    """README and the flashsim architecture doc must exist and carry
    executable quick-start examples."""
    readme = ROOT / "README.md"
    flashsim = ROOT / "docs" / "flashsim.md"
    assert readme.exists() and flashsim.exists()
    assert len(_BLOCK_RE.findall(readme.read_text())) >= 3
    assert len(_BLOCK_RE.findall(flashsim.read_text())) >= 2
