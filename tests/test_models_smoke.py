"""Per-architecture smoke tests: reduced same-family config, one forward /
train step on CPU, asserting output shapes + no NaNs (per the task spec),
plus prefill->decode parity in fp32."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config
from repro.models import build_model

ALL_ARCHS = sorted(ARCHS)


def _batch_for(cfg, key, B=2, T=32, with_labels=True):
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab)
    b = {"tokens": toks}
    if with_labels:
        b["labels"] = jax.random.randint(jax.random.fold_in(key, 1), (B, T), 0, cfg.vocab)
    if cfg.family == "encdec":
        b["audio_embed"] = 0.1 * jax.random.normal(
            jax.random.fold_in(key, 2), (B, cfg.enc_positions, cfg.d_model)
        ).astype(jnp.bfloat16)
    if cfg.family == "vlm":
        b["patches"] = 0.1 * jax.random.normal(
            jax.random.fold_in(key, 3), (B, cfg.n_patches, cfg.d_model)
        ).astype(jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, jax.random.PRNGKey(1))

    loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    # One SGD step must keep the loss finite.
    params2 = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    loss2 = model.train_loss(params2, batch)
    assert not bool(jnp.isnan(loss2)), f"{arch}: NaN after step"
    # Gradients flow to every leaf that should receive them.
    gnorms = jax.tree.map(lambda g: float(jnp.max(jnp.abs(g))), grads)
    flat = jax.tree.leaves(gnorms)
    assert any(g > 0 for g in flat), f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_logits_shape(arch):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, jax.random.PRNGKey(1), with_labels=False)
    logits, cache = model.prefill(params, batch)
    assert logits.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))
    assert cache is not None


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_parity_fp32(arch):
    """decode(prefill(T)) must match prefill(T+1) exactly in fp32."""
    cfg = dataclasses.replace(
        reduced_config(get_config(arch)), activation_dtype="float32"
    )
    model = build_model(cfg)
    key = jax.random.PRNGKey(1)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 40
    full = _batch_for(cfg, key, B=B, T=T + 1, with_labels=False)
    part = dict(full)
    part["tokens"] = full["tokens"][:, :T]

    lg_full, _ = model.prefill(params, full)
    pos = T + (cfg.n_patches if cfg.family == "vlm" else 0)
    _, cache = model.prefill(params, part, cache_len=pos + 4)
    lg_dec, new_cache = model.decode_step(
        params,
        {"token": full["tokens"][:, T : T + 1], "pos": jnp.int32(pos), "cache": cache},
    )
    np.testing.assert_allclose(
        np.asarray(lg_full, np.float32),
        np.asarray(lg_dec, np.float32),
        atol=5e-4, rtol=5e-3,
    )
    # Cache structure is stable across steps (scan-compatible).
    jax.tree.map(lambda a, b: None, cache, new_cache)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_analytic_vs_actual(arch):
    """Full-size analytic n_params within 2% of the real tree (checked on
    the reduced config, where both paths use the same formulas)."""
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
    analytic = cfg.n_params()
    # Analytic count ignores norms/bias/small vectors: allow 10% + pos table.
    slack = 0.12 * actual + cfg.max_positions * cfg.d_model
    assert abs(actual - analytic) <= slack, (arch, actual, analytic)
