"""Serving engine, KV store, data pipeline, distributed extras."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import reduced_config
from repro.core.retry import RetryPolicy
from repro.data import CorpusConfig, FlashTierReader, PrefetchPipeline, SyntheticCorpus
from repro.distributed.compress import (
    compress_grads,
    init_error_feedback,
    quantize_int8,
    dequantize_int8,
)
from repro.distributed.elastic import plan_mesh
from repro.distributed.fault_tolerance import (
    HeartbeatMonitor,
    RestartPolicy,
    StragglerMitigator,
)
from repro.flashsim.config import OperatingCondition
from repro.serving import QuantizedKVStore, ServeEngine


@pytest.fixture(scope="module")
def small_cfg():
    return reduced_config(get_config("llama3.2-3b"))


class TestServing:
    def test_retry_kv_matches_baseline_greedy(self, small_cfg):
        prompts = [np.array([5, 9, 11, 2], np.int32), np.array([7, 3], np.int32)]
        eng = ServeEngine(small_cfg, policy=RetryPolicy("pr2ar2"), tau=0.2, seed=0)
        gen, st = eng.generate(prompts, max_new_tokens=6)
        eng_b = ServeEngine(
            small_cfg, params=eng.params, policy=RetryPolicy("baseline"), seed=0
        )
        gen_b, st_b = eng_b.generate(prompts, max_new_tokens=6)
        np.testing.assert_array_equal(gen, gen_b)
        assert st.kv.fast_fraction > 0.9
        assert st_b.kv.fast_fraction == 0.0
        assert st.kv.bytes_saved_fraction > 0.5

    def test_kv_store_degenerates_for_ssm(self):
        """Attention-free arch: no KV leaves -> store is a no-op passthrough
        (the DESIGN.md §6 inapplicability case)."""
        cfg = reduced_config(get_config("mamba2-130m"))
        from repro.models.api import build_model

        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((1, 8), jnp.int32)}
        _, cache = model.prefill(params, batch)
        store = QuantizedKVStore(RetryPolicy("pr2ar2"))
        store.pack(cache)
        assert store.fast == {}  # nothing quantizable
        out = store.materialize()
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(cache)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestData:
    def test_corpus_deterministic_and_distinct(self):
        c = SyntheticCorpus(CorpusConfig(vocab=512, seq_len=64, batch=4, seed=1))
        np.testing.assert_array_equal(c.batch(3)["tokens"], c.batch(3)["tokens"])
        assert not np.array_equal(c.batch(3)["tokens"], c.batch(4)["tokens"])
        assert c.batch(0)["tokens"].max() < 512

    def test_flash_tier_mechanism_ordering(self):
        c = SyntheticCorpus(CorpusConfig(vocab=512, seq_len=256, batch=16))
        cond = OperatingCondition(365.0, 1000.0)
        means = {}
        for mech in ("baseline", "pr2", "pr2ar2", "sota+pr2ar2"):
            r = FlashTierReader(c, RetryPolicy(mech), cond, seed=2)
            for i in range(12):
                r.read(i)
            means[mech] = r.stats.mean_batch_us
        assert means["pr2ar2"] < means["pr2"] < means["baseline"]
        assert means["sota+pr2ar2"] < means["pr2ar2"]

    def test_prefetch_order_and_completeness(self):
        c = SyntheticCorpus(CorpusConfig(vocab=64, seq_len=16, batch=2))
        pipe = PrefetchPipeline(c.batch, n_batches=7, device_put=False,
                                start_index=3)
        seen = [i for i, _ in pipe]
        assert seen == list(range(3, 10))


class TestCompression:
    def test_roundtrip_error_bound(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)), jnp.float32)
        q, s = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x))
        assert err.max() <= float(s) * 0.5 + 1e-7

    def test_error_feedback_unbiased_accumulation(self):
        g = {"w": jnp.asarray(
            np.random.default_rng(1).normal(size=(500,)) * 1e-3, jnp.float32
        )}
        ef = init_error_feedback(g)
        acc_t = np.zeros(500)
        acc_c = np.zeros(500)
        for step in range(40):
            gs = {"w": g["w"] * (1.0 + 0.2 * np.sin(step))}
            comp, ef = compress_grads(gs, ef)
            acc_t += np.asarray(gs["w"])
            acc_c += np.asarray(comp["w"])
        rel = np.abs(acc_c - acc_t).max() / np.abs(acc_t).max()
        assert rel < 0.01  # residual is the (bounded) last-step error only


class TestFaultTolerance:
    def test_straggler_detection_and_redispatch(self):
        t = [0.0]
        mon = HeartbeatMonitor(8, dead_after_s=10.0, clock=lambda: t[0])
        for w in range(8):
            mon.beat(w, 1, 5.0 if w == 3 else 1.0)
        assert mon.stragglers() == [3]
        mit = StragglerMitigator(mon)
        plan = mit.plan(1, {s: s % 8 for s in range(16)})
        assert set(plan) == {3, 11}           # straggler 3's shards
        assert all(b != 3 for b in plan.values())

    def test_dead_worker_and_restart_decision(self):
        t = [100.0]
        mon = HeartbeatMonitor(4, dead_after_s=10.0, clock=lambda: t[0])
        for w in range(4):
            if w != 2:
                mon.beat(w, 5, 1.0)
        t[0] = 115.0
        for w in range(4):
            if w != 2:
                mon.beat(w, 6, 1.0)
        assert mon.dead_workers() == [2]
        pol = RestartPolicy()
        d = pol.on_failure(mon, transient=False, now=200.0)
        assert d.action == "shrink" and d.dead_workers == (2,)

    def test_failure_budget_aborts(self):
        mon = HeartbeatMonitor(2)
        pol = RestartPolicy(max_failures_per_hour=3)
        actions = [pol.on_failure(mon, True, now=float(i)).action for i in range(5)]
        assert actions[-1] == "abort"


class TestElastic:
    def test_tp_preserved_when_divisible(self):
        p = plan_mesh(512, (16, 16), global_batch=256)
        assert p.new_shape == (32, 16) and p.tp_preserved
        assert p.grad_accum_factor == 1

    def test_shrink_with_accumulation(self):
        p = plan_mesh(448, (16, 16), global_batch=256)
        assert p.new_shape == (28, 16) and p.tp_preserved
        assert p.grad_accum_factor >= 2

    def test_refactor_when_model_axis_impossible(self):
        p = plan_mesh(18, (16, 16), global_batch=256)
        assert p.new_shape[0] * p.new_shape[1] == 18
        assert not p.tp_preserved
