"""FTL/GC invariants: mapping bijectivity, WA, victim discipline, wear.

The FTL is a deterministic pre-pass (no RNG), so every invariant here is
checked after *random write/GC interleavings* driven by seeded NumPy
streams — the mapping must stay a bijection no matter how GC relocations
interleave with host overwrites.
"""

import dataclasses

import numpy as np
import pytest

from repro.flashsim.config import GCConfig, OperatingCondition, SSDConfig
from repro.flashsim.ftl import (
    OP_ERASE,
    OP_GC_PROG,
    OP_GC_READ,
    OP_PROG,
    OP_READ,
    PageMapFTL,
    build_ftl_schedule,
)
from repro.flashsim.ssd import SSDSim, expand_trace, simulate
from repro.flashsim.workloads import cached_trace, make_workloads

AGED = OperatingCondition(365.0, 1000.0)
MODEST = OperatingCondition(30.0, 0.0)

GC_SSD = SSDConfig(gc=GCConfig(enabled=True))


def small_ftl(**gc_kw) -> PageMapFTL:
    """2x2-die device with explicit tiny geometry for direct-FTL churn."""
    kw = dict(enabled=True, pages_per_block=8, blocks_per_die=6)
    kw.update(gc_kw)
    cfg = SSDConfig(n_channels=2, dies_per_channel=2, gc=GCConfig(**kw))
    return PageMapFTL(cfg)


def churn(ftl: PageMapFTL, span: int, n_writes: int, seed: int = 0,
          read_ratio: float = 0.2) -> None:
    """Random overwrite/read interleaving (drains GC events as it goes)."""
    rng = np.random.default_rng(seed)
    lpns = rng.integers(0, span, n_writes)
    reads = rng.random(n_writes) < read_ratio
    for lpn, is_read in zip(lpns, reads):
        if is_read:
            ftl.host_read(int(lpn))
        else:
            ftl.host_write(int(lpn))
        ftl.drain_events()


def assert_bijective(ftl: PageMapFTL) -> None:
    """l2p and p2l are mutually-inverse injections; valid counts agree."""
    ppns = np.array(sorted(ftl.l2p.values()))
    assert len(np.unique(ppns)) == len(ppns), "two lpns share a ppn"
    for lpn, ppn in ftl.l2p.items():
        assert ftl.p2l[ppn] == lpn
    assert int((ftl.p2l >= 0).sum()) == len(ftl.l2p)
    per_block = np.add.reduceat(
        (ftl.p2l >= 0).astype(np.int64),
        np.arange(0, ftl.n_blocks * ftl.ppb, ftl.ppb),
    )
    np.testing.assert_array_equal(per_block, ftl.valid)


class TestMappingInvariants:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_bijectivity_after_random_churn(self, seed):
        ftl = small_ftl()
        churn(ftl, span=4 * 32, n_writes=3000, seed=seed)
        assert ftl.gc_invocations > 0, "churn must actually trigger GC"
        assert_bijective(ftl)

    def test_bijectivity_without_gc_pressure(self):
        ftl = small_ftl(blocks_per_die=64)  # plenty of room: no GC
        churn(ftl, span=4 * 32, n_writes=1000, seed=3)
        assert ftl.gc_invocations == 0
        assert_bijective(ftl)

    def test_write_amplification_at_least_one(self):
        for seed in range(3):
            ftl = small_ftl()
            churn(ftl, span=4 * 32, n_writes=2500, seed=seed)
            assert ftl.write_amplification >= 1.0
            if ftl.gc_page_progs:
                assert ftl.write_amplification > 1.0

    def test_gc_never_evicts_its_own_destination(self):
        ftl = small_ftl()
        churn(ftl, span=4 * 32, n_writes=4000, seed=0)
        assert ftl.gc_log, "expected GC activity"
        for die, victim, dest in ftl.gc_log:
            assert victim != dest, (
                f"die {die}: GC selected its relocation frontier {dest}"
            )
            assert victim // ftl.blocks_per_die == die

    def test_erases_accumulate_wear(self):
        ftl = small_ftl(pec_per_erase=2.5)
        churn(ftl, span=4 * 32, n_writes=4000, seed=0)
        st = ftl.stats()
        assert st.blocks_erased > 0
        assert st.max_block_pe == pytest.approx(float(ftl.erases.max()) * 2.5)
        # wear is per block: erased blocks carry it, untouched frontiers may not
        assert ftl.erases.max() >= 1

    def test_auto_sizing_requires_lpns(self):
        with pytest.raises(ValueError, match="auto-size"):
            PageMapFTL(GC_SSD, lpns=None)

    def test_out_of_space_is_loud(self):
        ftl = small_ftl(blocks_per_die=4, gc_threshold_blocks=1)
        with pytest.raises(RuntimeError, match="out of free blocks"):
            # write-once fill (no overwrites => GC has nothing to reclaim);
            # 10 blocks' worth of unique lpns per die overruns the 4 blocks
            for lpn in range(4 * 10 * 8):
                ftl.host_write(lpn)
                ftl.drain_events()


class TestSchedule:
    def _sched(self, n=1500, seed=0, workload="prn"):
        w = dataclasses.replace(make_workloads()[workload], n_requests=n)
        trace = cached_trace(w, seed=seed)
        return trace, build_ftl_schedule(trace, GC_SSD)

    def test_host_ops_preserved_verbatim(self):
        """FTL injection must not disturb host page-ops: same arrivals,
        rids, dies, channels, page types as the in-place expansion."""
        trace, sched = self._sched()
        ex = expand_trace(trace, GC_SSD)
        host = sched.rid >= 0
        assert int(host.sum()) == ex.n_ops
        np.testing.assert_array_equal(sched.arrival_us[host], ex.arrival_us)
        np.testing.assert_array_equal(sched.rid[host], ex.rid)
        np.testing.assert_array_equal(sched.die[host], ex.die)
        np.testing.assert_array_equal(sched.chan[host], ex.chan)
        np.testing.assert_array_equal(sched.ptype[host], ex.ptype)

    def test_admission_order_and_kind_durations(self):
        trace, sched = self._sched()
        assert np.all(np.diff(sched.arrival_us) >= 0)
        t = GC_SSD.timing
        k, d = sched.kind, sched.dur_us
        assert np.all(d[(k == OP_READ) | (k == OP_GC_READ)] == 0.0)
        assert np.all(d[(k == OP_PROG) | (k == OP_GC_PROG)] == t.tprog_us)
        assert np.all(d[k == OP_ERASE] == GC_SSD.gc.t_erase_us)
        # GC traffic exists and is anonymous (rid == -1)
        gc_ops = (k == OP_GC_READ) | (k == OP_GC_PROG) | (k == OP_ERASE)
        assert gc_ops.any()
        assert np.all(sched.rid[gc_ops] == -1)

    def test_stats_consistency(self):
        trace, sched = self._sched()
        fs = sched.stats
        k = sched.kind
        assert fs.gc_page_reads == int((k == OP_GC_READ).sum())
        assert fs.gc_page_progs == int((k == OP_GC_PROG).sum())
        assert fs.blocks_erased == int((k == OP_ERASE).sum())
        assert fs.host_progs == int((k == OP_PROG).sum())
        assert fs.write_amplification == pytest.approx(
            (fs.host_progs + fs.gc_page_progs) / fs.host_progs
        )
        assert fs.write_amplification > 1.0
        # relocated data carries per-block wear into read sampling
        assert float(sched.wear_pec[k <= OP_GC_READ].max()) > 0.0

    def test_schedule_deterministic(self):
        _, s1 = self._sched(seed=4)
        _, s2 = self._sched(seed=4)
        np.testing.assert_array_equal(s1.arrival_us, s2.arrival_us)
        np.testing.assert_array_equal(s1.kind, s2.kind)
        np.testing.assert_array_equal(s1.wear_pec, s2.wear_pec)


class TestEngineWithGC:
    def test_gc_raises_read_tail_latency(self):
        """The acceptance property: a write-heavy workload under GC shows
        WA > 1 and strictly higher host-read p99 than in-place baseline."""
        w = dataclasses.replace(make_workloads()["prn"], n_requests=1500)
        off = simulate(w, AGED, "baseline", seed=0)
        on = simulate(w, AGED, "baseline", seed=0, cfg=GC_SSD)
        assert off.wa == 1.0 and off.gc_invocations == 0
        assert on.wa > 1.0
        assert on.gc_invocations > 0
        assert on.read_p99_us > off.read_p99_us
        assert on.mean_us > off.mean_us

    def test_wear_increases_attempts(self):
        """Per-block wear feeds attempt sampling: acceleration of
        pec_per_erase must raise mean host-read attempts (blocks snap to
        worse characterization bins)."""
        w = dataclasses.replace(make_workloads()["prn"], n_requests=1500)
        unworn = SSDConfig(gc=GCConfig(enabled=True, pec_per_erase=0.0))
        worn = SSDConfig(gc=GCConfig(enabled=True, pec_per_erase=300.0))
        a = simulate(w, MODEST, "baseline", seed=0, cfg=unworn)
        b = simulate(w, MODEST, "baseline", seed=0, cfg=worn)
        assert b.mean_read_attempts > a.mean_read_attempts

    def test_gc_stats_shared_across_mechanisms(self):
        from repro.flashsim.ssd import compare_mechanisms

        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=2000)
        stats = compare_mechanisms(
            w, AGED, mechanisms=("baseline", "pr2ar2"), seed=0, cfg=GC_SSD
        )
        assert stats["baseline"].wa == stats["pr2ar2"].wa > 1.0
        assert (stats["baseline"].gc_invocations
                == stats["pr2ar2"].gc_invocations > 0)

    def test_reference_engine_rejects_gc(self):
        w = dataclasses.replace(make_workloads()["prn"], n_requests=200)
        with pytest.raises(NotImplementedError, match="FTL"):
            simulate(w, AGED, "baseline", seed=0, cfg=GC_SSD,
                     engine="reference")

    def test_gc_run_deterministic(self):
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=1000)
        a = simulate(w, AGED, "pr2ar2", seed=5, cfg=GC_SSD)
        b = simulate(w, AGED, "pr2ar2", seed=5, cfg=GC_SSD)
        assert a == b


class TestOnlineGC:
    """Completion-time-triggered GC (GCConfig.mode="online")."""

    def test_online_gc_collects_and_amplifies(self):
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=2500)
        s = simulate(w, AGED, "baseline", seed=0, gc="online")
        assert s.wa > 1.0
        assert s.gc_invocations > 0
        assert s.blocks_erased > 0
        assert s.gc_page_reads > 0

    def test_online_deterministic(self):
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=1500)
        a = simulate(w, AGED, "pr2ar2", seed=5, gc="online")
        b = simulate(w, AGED, "pr2ar2", seed=5, gc="online")
        assert a == b

    def test_online_wa_close_to_prepass(self):
        """Same mapping state machine, different trigger instants: WA must
        land near the prepass figure (the victims' valid-page profile
        shifts slightly with trigger timing, nothing more)."""
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=2500)
        pre = simulate(w, AGED, "baseline", seed=0, cfg=GC_SSD)
        onl = simulate(w, AGED, "baseline", seed=0, gc="online")
        assert onl.wa == pytest.approx(pre.wa, rel=0.15)

    def test_online_wa_policy_invariant_within_tolerance(self):
        """Scheduler reordering may shift trigger instants but not the
        overwrite structure: WA across policies stays within a few %."""
        w = dataclasses.replace(make_workloads()["prn"], n_requests=2500)
        was = [
            simulate(w, AGED, "baseline", seed=0, gc="online",
                     scheduler=sched).wa
            for sched in ("fcfs", "host_prio", "preempt")
        ]
        assert max(was) <= min(was) * 1.05
        assert min(was) > 1.0

    def test_reclaim_takes_simulated_time(self):
        """Deferred frees are the point of online mode: erases in flight
        mean writes can momentarily stall on the free pool — the counter
        exists and the run still completes every request."""
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=2500)
        from repro.core.retry import RetryPolicy
        from repro.flashsim.ssd import SSDSim, _with_knobs
        from repro.flashsim.workloads import cached_trace

        trace = cached_trace(w, seed=0)
        cfg = _with_knobs(SSDConfig(), None, "online")
        sim = SSDSim(cfg, AGED, RetryPolicy("baseline"), seed=7)
        stats = sim.run(trace)
        assert (sim.last_req_done_us >= trace.arrival_us).all()
        assert stats.write_stalls >= 0    # populated (0 is legal)

    def test_watermark_knob_validated(self):
        with pytest.raises(ValueError, match="watermark_blocks"):
            GCConfig(enabled=True, mode="online", watermark_blocks=0)
        with pytest.raises(ValueError, match="mode"):
            GCConfig(enabled=True, mode="lazy")

    def test_higher_watermark_starts_gc_earlier(self):
        """Raising the watermark triggers collection earlier, when victims
        have had less time to invalidate — at least as many invocations
        and at least as much copy-back (WA)."""
        w = dataclasses.replace(make_workloads()["rsrch"], n_requests=2000)
        lo = simulate(w, AGED, "baseline", seed=0, gc="online")
        hi = simulate(
            w, AGED, "baseline", seed=0,
            cfg=SSDConfig(gc=GCConfig(enabled=True, mode="online",
                                      watermark_blocks=4)),
        )
        assert hi.gc_invocations >= lo.gc_invocations
        assert hi.wa >= lo.wa > 1.0
